//! End-to-end validation driver (DESIGN.md experiment E2E): the ternary
//! network trained at build time by `python/compile/training.py` (STE on
//! the synthetic 10-class image task — CIFAR-10 is unavailable offline,
//! see the substitution table) is evaluated on the cycle-level simulator
//! over the exported eval set, and the training loss curve, JAX-reported
//! accuracy and simulator-measured accuracy are printed side by side.
//!
//! The eval set is served the way a deployment serves it: the network is
//! booted once into a shared prepared image behind a [`NetRegistry`] and
//! every eval frame goes through [`Engine::submit`] on one session — the
//! same binding/serve path the multi-workload engine uses — instead of
//! the legacy per-scheduler `preload_weights` loop.
//!
//!     cargo run --release --example cifar_e2e

use std::sync::Arc;

use anyhow::{Context, Result};

use tcn_cutie::coordinator::{Engine, EngineConfig, NetRegistry};
use tcn_cutie::cutie::{CutieConfig, PreparedNet, SimMode};
use tcn_cutie::network::loader;
use tcn_cutie::tensor::{ttn, PackedMap, TritTensor};
use tcn_cutie::util::json::Json;

fn main() -> Result<()> {
    let dir = loader::artifacts_dir();
    anyhow::ensure!(
        dir.join("cifar9_mini.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // Training log from the build-time STE run.
    let log_text = std::fs::read_to_string(dir.join("train_log.json"))?;
    let log = Json::parse(&log_text)?;
    println!("== build-time training (python/compile/training.py) ==");
    println!("net: {}", log.get("net").and_then(|v| v.as_str()).unwrap_or("?"));
    if let Some(losses) = log.get("loss_log").and_then(|v| v.as_array()) {
        print!("loss curve: ");
        for entry in losses {
            let e = entry.as_array().context("loss entry")?;
            print!("{}:{:.2} ", e[0].as_i64().unwrap(), e[1].as_f64().unwrap());
        }
        println!();
    }
    let jax_acc = log.get("int_test_acc").and_then(|v| v.as_f64()).unwrap_or(-1.0);
    println!("JAX integer-model eval accuracy: {jax_acc:.3}");

    // Evaluate the same integer network on the cycle-level simulator.
    let net = loader::load_network(dir.join("cifar9_mini.json"))?;
    let eval = ttn::read_file(dir.join("evalset_cifar9_mini.ttn"))?;
    let images = eval["images"].as_trit()?;
    let labels = eval["labels"].as_int()?;
    let n = images.dims[0];
    let (h, w, c) = (images.dims[1], images.dims[2], images.dims[3]);

    // Boot: one shared prepared image, registered once, served by an
    // engine session bound to it.
    let image = Arc::new(PreparedNet::new(&net, &CutieConfig::kraken()));
    let registry = Arc::new(NetRegistry::single_with_image(net, image)?);
    let mut engine = Engine::with_registry(
        Arc::clone(&registry),
        EngineConfig { mode: SimMode::Accurate, workers: 1, ..Default::default() },
    )?;
    engine.open_session(0)?;
    for i in 0..n {
        let frame = TritTensor::from_vec(
            &[h, w, c],
            images.data[i * h * w * c..(i + 1) * h * w * c].to_vec(),
        );
        engine.submit(0, PackedMap::from_trit(&frame))?;
    }
    engine.drain()?;
    let report = engine.finish_session(0).context("eval session vanished")?;

    let correct =
        report.labels.iter().zip(&labels.data).filter(|(got, want)| **got as i32 == **want).count();
    let acc = correct as f64 / n as f64;
    println!("\n== simulator evaluation ({n} images, 48-channel cifar9_mini) ==");
    println!("simulator accuracy: {acc:.3}  (JAX: {jax_acc:.3})");
    println!(
        "avg core energy {:.3} µJ/inference, median {:.1} µs simulated @0.5 V",
        report.metrics.core_energy_j / n as f64 * 1e6,
        report.metrics.sim_latency_us.quantile(0.5)
    );
    anyhow::ensure!(
        (acc - jax_acc).abs() < 1e-9,
        "simulator and JAX accuracies must match bit-exactly"
    );
    println!("bit-exact match between JAX evaluation and cycle-level simulator ✓");
    Ok(())
}
