//! Voltage sweep — regenerates Figures 5 and 6 (energy/inference and
//! inferences/s vs supply; peak efficiency and throughput vs supply) and
//! prints them as aligned tables plus a CSV block for plotting.
//!
//!     cargo run --release --example voltage_sweep

use anyhow::Result;

use tcn_cutie::report;

fn main() -> Result<()> {
    println!("== Figure 5: energy + rate vs voltage (max stable frequency per corner) ==");
    let f5 = report::fig5()?;
    report::fig5_table(&f5).print();

    println!("\n== Figure 6: peak efficiency + peak throughput vs voltage (CIFAR L1) ==");
    let f6 = report::fig6()?;
    report::fig6_table(&f6).print();

    println!("\n# CSV (voltage, fmax_mhz, cifar_uj, cifar_inf_s, dvs_uj, dvs_inf_s, peak_tops, peak_tops_w)");
    for (a, b) in f5.iter().zip(&f6) {
        println!(
            "{:.2},{:.1},{:.3},{:.0},{:.3},{:.0},{:.2},{:.0}",
            a.voltage, a.freq_mhz, a.cifar_uj, a.cifar_inf_s, a.dvs_uj, a.dvs_inf_s,
            b.peak_tops, b.peak_tops_w
        );
    }

    // paper-shape sanity: 0.5 V is the µJ-optimal corner, 0.9 V the
    // throughput-optimal one
    let best_e = f5.iter().cloned().reduce(|a, b| if a.cifar_uj <= b.cifar_uj { a } else { b }).unwrap();
    let best_t = f6.iter().cloned().reduce(|a, b| if a.peak_tops >= b.peak_tops { a } else { b }).unwrap();
    println!(
        "\nenergy-optimal corner: {:.2} V ({:.2} µJ/inf) — paper: 0.5 V (2.72 µJ)",
        best_e.voltage, best_e.cifar_uj
    );
    println!(
        "throughput-optimal corner: {:.2} V ({:.1} TOp/s) — paper: 0.9 V (51.7 TOp/s)",
        best_t.voltage, best_t.peak_tops
    );
    Ok(())
}
