//! Quickstart: load the AOT-compiled CIFAR network, run one inference on
//! the cycle-level CUTIE simulator, cross-check it against the PJRT
//! golden model (the XLA execution of the same JAX-authored network), and
//! print the energy report at the paper's 0.5 V corner.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;

use tcn_cutie::cutie::{CutieConfig, Scheduler, SimMode};
use tcn_cutie::energy::{evaluate, EnergyParams};
use tcn_cutie::network::loader;
use tcn_cutie::report::print_energy_report;
use tcn_cutie::runtime::{golden, Runtime};
use tcn_cutie::tensor::TritTensor;
use tcn_cutie::util::rng::Rng;

fn main() -> Result<()> {
    let dir = loader::artifacts_dir();
    anyhow::ensure!(
        dir.join("cifar9_96.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // 1. Load the network (weights exported by python/compile/aot.py).
    let net = loader::load_network(dir.join("cifar9_96.json"))?;
    println!("loaded {} ({} layers, {} MMAC/inference)", net.name, net.layers.len(),
             net.macs_per_inference() / 1_000_000);

    // 2. One inference on the cycle-level digital twin.
    let mut rng = Rng::new(42);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
    sched.preload_weights(&net);
    let (logits, stats) = sched.run_full(&net, &input)?;
    println!("predicted class: {}  logits {:?}", logits.argmax(), logits.data);
    println!("cycles: {}  (stall-free: {} stalls)", stats.total_cycles(), stats.stall_cycles());

    // 3. Energy at the paper's energy-optimal corner.
    let r = evaluate(&stats, 0.5, None, &EnergyParams::default());
    print_energy_report("0.5 V corner", &r);

    // 4. Golden-model cross-check via PJRT (L1 Pallas kernel included in
    //    the artifact path).
    let rt = Runtime::cpu()?;
    let model = rt.load(dir.join("cifar9_96.hlo.txt"))?;
    let check = golden::check_feedforward(&rt, &model, &net, &input)?;
    println!(
        "PJRT golden model: {}",
        if check.matched { "MATCH (bit-exact)" } else { "MISMATCH" }
    );
    anyhow::ensure!(check.matched);
    Ok(())
}
