//! DVS gesture serving — the §5 autonomous data-to-label flow end to end:
//! a synthetic DVS camera streams event frames over µDMA; each frame
//! triggers CNN → TCN-memory shift → TCN classification; CUTIE's done-IRQ
//! wakes the fabric controller for readout. Reports latency percentiles,
//! sustained inference rate, µJ/inference and SoC-level power, for both
//! the inline and the threaded (producer/consumer with backpressure)
//! topologies.
//!
//!     cargo run --release --example dvs_gesture -- [--frames 64] [--voltage 0.5]

use anyhow::Result;

use tcn_cutie::coordinator::{Pipeline, PipelineConfig};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::network::loader;
use tcn_cutie::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["fast"]);
    let dir = loader::artifacts_dir();
    anyhow::ensure!(
        dir.join("dvs_hybrid_96.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let net = loader::load_network(dir.join("dvs_hybrid_96.json"))?;
    println!(
        "serving {} (5 conv + 4 TCN layers, dilations {:?}, TCN window {})",
        net.name,
        net.tcn_layers().map(|l| l.dilation).collect::<Vec<_>>(),
        net.tcn_steps
    );

    let cfg = PipelineConfig {
        voltage: args.opt_f64("voltage", 0.5)?,
        frames: args.opt_usize("frames", 64)?,
        gesture: args.opt_usize("gesture", 3)?,
        seed: args.opt_u64("seed", 7)?,
        mode: if args.flag("fast") { SimMode::Fast } else { SimMode::Accurate },
        ..Default::default()
    };

    for threaded in [false, true] {
        let pipe = Pipeline::new(net.clone(), cfg.clone());
        let mut r = if threaded { pipe.run_threaded()? } else { pipe.run_inline()? };
        println!(
            "\n[{}] {}",
            if threaded { "threaded" } else { "inline  " },
            r.metrics.summary()
        );
        println!(
            "  SoC: {:.2} µJ total, avg {:.2} mW, {} FC wakeups, {} frames ingested",
            r.soc_energy_j * 1e6,
            r.soc_avg_power_w * 1e3,
            r.fc_wakeups,
            r.metrics.frames,
        );
        let show = r.labels.len().min(12);
        println!("  last labels: {:?}", &r.labels[r.labels.len() - show..]);
    }
    Ok(())
}
