//! Minimal, API-compatible shim of the `anyhow` crate for this offline
//! build environment (crates.io is unreachable; see DESIGN.md §3 and
//! `util/mod.rs` for the same pattern). Implements exactly the surface
//! this repository uses: [`Error`], [`Result`], the [`Context`] trait on
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Context is stored as a flattened "outer: inner" message chain; the
//! alternate formatter (`{:#}`) prints the same chain, which is all the
//! CLI front-end needs.

use std::fmt;

/// A flattened error message with its context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (used by the macros).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer, anyhow-style ("context: cause").
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes this blanket conversion coherent (same trick the
// real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_ensure(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        Ok(x)
    }

    fn fails_bail() -> Result<()> {
        bail!("always {}", "fails")
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails_ensure(3).unwrap(), 3);
        assert_eq!(fails_ensure(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(fails_bail().unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<i32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let bytes = vec![0xff, 0xfe];
            let s = String::from_utf8(bytes)?;
            Ok(s)
        }
        assert!(inner().is_err());
    }
}
