//! A1 — sparsity ablation: the CUTIE paper [1] attributes a ~36% energy
//! reduction to sparse ternary operands suppressing datapath toggling.
//! Sweeps weight/activation zero-fraction and reports energy + toggle
//! rate at 0.5 V.
//!
//!     cargo bench --bench ablation_sparsity

use tcn_cutie::report;
use tcn_cutie::util::bench::{bench, Table};

fn main() {
    let fracs = [0.0, 0.1, 0.2, 0.33, 0.5, 0.7, 0.9];
    let pts = report::sparsity_sweep(&fracs).unwrap();

    println!("== A1: sparsity → energy (CIFAR-9/96 @0.5 V) ==\n");
    let mut t = Table::new(&["zero fraction", "µJ/inference", "toggle rate", "vs dense"]);
    let dense = pts[0].energy_uj;
    for p in &pts {
        t.row(&[
            format!("{:.2}", p.zero_frac),
            format!("{:.2}", p.energy_uj),
            format!("{:.3}", p.toggle_rate),
            format!("-{:.0}%", (1.0 - p.energy_uj / dense) * 100.0),
        ]);
    }
    t.print();

    // the [1] claim: very sparse nets cut energy by ~36% vs typical
    let typical = pts.iter().find(|p| p.zero_frac == 0.33).unwrap();
    let sparse = pts.iter().find(|p| p.zero_frac == 0.7).unwrap();
    println!(
        "\n[1]-style claim: 0.33→0.7 sparsity cuts inference energy {:.0}% (paper: ~36%)\n",
        (1.0 - sparse.energy_uj / typical.energy_uj) * 100.0
    );

    bench("sparsity point (1 inference, accurate)", 1, 5, || {
        report::sparsity_sweep(&[0.5]).unwrap()
    });
}
