//! A3 — accelerator configuration ablation: §8 notes the Kraken CUTIE
//! instance improves on [1] partly by "using a smaller CUTIE
//! configuration" (96 channels vs 128). Sweep the datapath width on a
//! width-matched CIFAR-9 network and report energy/throughput/efficiency.
//!
//!     cargo bench --bench ablation_config

use tcn_cutie::report;
use tcn_cutie::util::bench::{bench, Table};

fn main() {
    let widths = [32, 48, 64, 96, 128];
    let pts = report::config_sweep(&widths).unwrap();

    println!("== A3: CUTIE configuration width (CIFAR-9, width-matched net, 0.5 V) ==\n");
    let mut t = Table::new(&["channels", "cycles", "µJ/inf", "peak TOp/s", "peak TOp/s/W"]);
    for p in &pts {
        t.row(&[
            p.channels.to_string(),
            p.cycles.to_string(),
            format!("{:.2}", p.energy_uj),
            format!("{:.1}", p.peak_tops),
            format!("{:.0}", p.peak_tops_w),
        ]);
    }
    t.print();
    println!("\npaper context: the original CUTIE used 128 channels; Kraken instantiates 96.");
    println!("NOTE: in this activity model wider datapaths keep gaining peak efficiency;");
    println!("the paper's \"smaller configuration\" efficiency win is a physical-design");
    println!("effect (wires/clock tree) outside an architectural model — see EXPERIMENTS.md.\n");

    bench("config point (96ch inference)", 1, 5, || {
        report::config_sweep(&[96]).unwrap()
    });
}
