//! A2 — the §4 contribution in isolation: dilated TCN layers executed
//! through the offline 2D mapping (stall-free) vs direct strided access
//! (every non-contiguous fetch stalls the datapath). Functionally
//! identical by construction; cycles and energy differ.
//!
//!     cargo bench --bench ablation_mapping

use tcn_cutie::report;
use tcn_cutie::util::bench::{bench, Table};

fn main() {
    let a = report::mapping_ablation().unwrap();

    println!("== A2: §4 dilated-1D→2D mapping vs direct strided execution ==\n");
    let mut t = Table::new(&["strategy", "TCN cycles", "stall cycles", "TCN µJ @0.5V"]);
    t.row(&[
        "mapped (§4, this work)".into(),
        a.mapped_tcn_cycles.to_string(),
        a.mapped_stalls.to_string(),
        format!("{:.4}", a.mapped_tcn_uj),
    ]);
    t.row(&[
        "direct strided (baseline)".into(),
        a.direct_tcn_cycles.to_string(),
        a.direct_stalls.to_string(),
        format!("{:.4}", a.direct_tcn_uj),
    ]);
    t.print();
    println!(
        "\nmapping advantage: {:.2}x fewer TCN cycles, {:.2}x less TCN energy",
        a.direct_tcn_cycles as f64 / a.mapped_tcn_cycles as f64,
        a.direct_tcn_uj / a.mapped_tcn_uj
    );
    println!("paper claim (§4): strided accesses stall the specialized memory hierarchy;");
    println!("the offline mapping removes all stalls with no data marshalling.\n");

    bench("mapping ablation (4 frames, both strategies)", 1, 5, || {
        report::mapping_ablation().unwrap()
    });
}
