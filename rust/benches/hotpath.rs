//! Perf — simulator hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//! packed-bitplane OCU dot products vs a scalar i8 baseline, the
//! per-layer datapath loop (packed column-stationary vs the retained
//! i8 window-stationary baseline), the end-to-end packed-vs-i8 dataflow
//! A/B on the 64×64 DVS serving workload's CNN front-end, and
//! end-to-end serving throughput — inline vs the batched multi-frame
//! engine. The §Perf target: the full DVS pipeline simulates faster
//! than the 0.5 V silicon serves it (≥1x realtime).
//!
//! Emits the machine-readable perf ledger `BENCH_hotpath.json`
//! (override the path with the BENCH_JSON env var), tracking name,
//! median_s and speedup across PRs; CI archives it per push and flags
//! >10 % median regressions against the previous run's artifact.
//!
//!     cargo bench --bench hotpath

use tcn_cutie::coordinator::{
    DvsSource, Engine, EngineConfig, Fleet, FleetConfig, GestureClass, Pipeline, PipelineConfig,
    SessionSnapshot,
};
use tcn_cutie::cutie::datapath::{run_prepared, run_prepared_window, PreparedLayer};
use tcn_cutie::cutie::{CutieConfig, PreparedNet, Scheduler, SimMode};
use tcn_cutie::fault::{ber, FaultPlan, FaultSurface};
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random, loader};
use tcn_cutie::tensor::{ttn, PackedMap, TritTensor};
use tcn_cutie::trit::{dot_scalar, PackedVec};
use tcn_cutie::util::bench::{bench, black_box, BenchResult, BenchSuite};
use tcn_cutie::util::rng::Rng;

use std::sync::Arc;

fn main() {
    let mut rng = Rng::new(99);
    let mut suite = BenchSuite::new();

    // --- microbench: ternary dot product, packed vs scalar ---
    let a: Vec<i8> = (0..96).map(|_| rng.trit(0.33)).collect();
    let b: Vec<i8> = (0..96).map(|_| rng.trit(0.33)).collect();
    let pa = PackedVec::pack(&a);
    let pb = PackedVec::pack(&b);
    let r_scalar = bench("dot 96ch: scalar i8 loop (baseline)", 3, 30, || {
        let mut acc = 0i64;
        for _ in 0..10_000 {
            acc += dot_scalar(black_box(&a), black_box(&b)).0 as i64;
        }
        acc
    });
    let r_packed = bench("dot 96ch: bitplane popcount (with activity)", 3, 30, || {
        let mut acc = 0i64;
        for _ in 0..10_000 {
            acc += black_box(&pa).dot(black_box(&pb)).0 as i64;
        }
        acc
    });
    println!("  speedup packed vs scalar: {:.1}x\n", r_scalar.median_s / r_packed.median_s);
    suite.push(&r_scalar);
    suite.push_speedup(&r_packed, &r_scalar);

    // --- one 96x96 conv layer: i8 window-stationary vs packed column ---
    let net = cifar9_random(96, 7, 0.33);
    let cfg = CutieConfig::kraken();
    let input = TritTensor::random(&[32, 32, 96], &mut rng, 0.4);
    let input_packed = PackedMap::from_trit(&input);
    let prep = PreparedLayer::new(&net.layers[2]);
    let r_window = bench("datapath layer 32x32x96→96 i8 window (baseline)", 2, 10, || {
        run_prepared_window(&prep, &input, &cfg, SimMode::Accurate).unwrap()
    });
    let r_col = bench("datapath layer 32x32x96→96 packed (accurate)", 2, 10, || {
        run_prepared(&prep, &input_packed, &cfg, SimMode::Accurate).unwrap()
    });
    let r_col_fast = bench("datapath layer 32x32x96→96 packed (fast)", 2, 10, || {
        run_prepared(&prep, &input_packed, &cfg, SimMode::Fast).unwrap()
    });
    println!(
        "  speedup packed column vs i8 window: {:.2}x\n",
        r_window.median_s / r_col.median_s
    );
    suite.push(&r_window);
    suite.push_speedup(&r_col, &r_window);
    suite.push_speedup(&r_col_fast, &r_window);

    // --- packed-vs-i8 dataflow A/B: the 64×64 DVS CNN front-end ---
    // The tentpole measurement (perf pass iteration 8): the same 5-layer
    // CNN over the same high-sparsity event frame, once with i8 maps
    // between layers (per-pixel packing in every linebuffer fetch,
    // scalar ternarize + pooling) and once fully packed.
    let dnet = dvs_hybrid_random(96, 3, 0.5);
    let preps: Vec<PreparedLayer> = dnet.conv_layers().map(PreparedLayer::new).collect();
    let mut src = DvsSource::new(64, 11, GestureClass(3));
    let frame = src.next_frame();
    let frame_i8 = frame.to_trit();
    let r_cnn_i8 = bench("DVS CNN 64x64 frame i8 dataflow (baseline)", 2, 10, || {
        let mut x = frame_i8.clone();
        for p in &preps {
            x = run_prepared_window(p, &x, &cfg, SimMode::Accurate).unwrap().output;
        }
        x
    });
    let r_cnn_packed = bench("DVS CNN 64x64 frame packed dataflow", 2, 10, || {
        let mut x = frame.clone();
        for p in &preps {
            x = run_prepared(p, &x, &cfg, SimMode::Accurate).unwrap().output;
        }
        x
    });
    println!(
        "  speedup packed vs i8 dataflow (DVS CNN): {:.2}x\n",
        r_cnn_i8.median_s / r_cnn_packed.median_s
    );
    suite.push(&r_cnn_i8);
    suite.push_speedup(&r_cnn_packed, &r_cnn_i8);

    // --- packed-vs-i8 TCN tail A/B (perf pass iteration 9) ---
    // The same warm 24-step window through the 4-layer mapped TCN +
    // classifier, once via the retained i8 marshalling tail (window →
    // (T, C) i8 → per-layer map_input re-pack → i8 unwrap/slice) and
    // once packed-native (wrap images straight off the memory's
    // multiplexed port, word-copy unwrap, packed last-step read).
    // Counters are identical either way (tests/tcn_packed.rs proves it);
    // this measures the marshalling tax the tentpole removes.
    let mut tail = Scheduler::new(cfg.clone(), SimMode::Accurate);
    tail.preload_weights(&dnet);
    let mut warm = DvsSource::new(64, 12, GestureClass(5));
    for _ in 0..24 {
        let f = warm.next_frame();
        let (feat, _) = tail.run_cnn(&dnet, &f).unwrap();
        tail.push_feature(&feat).unwrap();
    }
    let r_tail_i8 = bench("TCN tail 24-step window i8 marshalling (baseline)", 3, 30, || {
        tail.run_tcn_i8(&dnet).unwrap()
    });
    let r_tail_packed = bench("TCN tail 24-step window packed", 3, 30, || {
        tail.run_tcn(&dnet).unwrap()
    });
    println!(
        "  speedup packed vs i8 TCN tail: {:.2}x\n",
        r_tail_i8.median_s / r_tail_packed.median_s
    );
    suite.push(&r_tail_i8);
    suite.push_speedup(&r_tail_packed, &r_tail_i8);

    // --- full DVS frame loop A/B: CNN + TCN-memory push + tail ---
    // The whole per-frame serving hot path (what every engine stream
    // pays per frame), packed end to end vs the same CNN with the i8
    // marshalling tail.
    let mut loop_i8 = Scheduler::new(cfg.clone(), SimMode::Accurate);
    let mut loop_packed = Scheduler::new(cfg.clone(), SimMode::Accurate);
    loop_i8.preload_weights(&dnet);
    loop_packed.preload_weights(&dnet);
    let r_frame_i8 = bench("DVS frame loop CNN + i8 TCN tail (baseline)", 2, 10, || {
        let (feat, _) = loop_i8.run_cnn(&dnet, &frame).unwrap();
        loop_i8.push_feature(&feat).unwrap();
        loop_i8.run_tcn_i8(&dnet).unwrap()
    });
    let r_frame_packed = bench("DVS frame loop packed serve_frame", 2, 10, || {
        loop_packed.serve_frame(&dnet, &frame).unwrap()
    });
    println!(
        "  speedup packed vs i8 full frame loop: {:.2}x\n",
        r_frame_i8.median_s / r_frame_packed.median_s
    );
    suite.push(&r_frame_i8);
    suite.push_speedup(&r_frame_packed, &r_frame_i8);

    // --- end-to-end serving throughput: inline vs batched, vs realtime ---
    for (label, mode) in [("accurate", SimMode::Accurate), ("fast", SimMode::Fast)] {
        let pipe = Pipeline::new(
            dnet.clone(),
            PipelineConfig { frames: 8, mode, ..Default::default() },
        );
        let r_inline =
            bench(&format!("DVS serve 8 frames inline ({label})"), 1, 5, || {
                pipe.run_inline().unwrap()
            });
        let r_batch =
            bench(&format!("DVS serve 8 frames batched ({label})"), 1, 5, || {
                pipe.run_batched(0).unwrap()
            });
        let rep = pipe.run_inline().unwrap();
        let sim_time = rep.metrics.sim_time_s;
        println!(
            "  serve speedup batched vs inline ({label}): {:.2}x",
            r_inline.median_s / r_batch.median_s
        );
        println!(
            "  realtime ratio ({label}): sim {:.1} µs of 0.5 V silicon in {:.1} ms wall → {:.2}x realtime (batched: {:.2}x)\n",
            sim_time * 1e6,
            r_inline.median_s * 1e3,
            sim_time / r_inline.median_s,
            sim_time / r_batch.median_s
        );
        suite.push(&r_inline);
        suite.push_speedup(&r_batch, &r_inline);
    }

    // --- boot A/B: i8 `.ttn` re-pack vs packed-image word-copy load ---
    // The shared-image pass measurement: the same full-width DVS network
    // booted from TTN1 bytes (parse + per-OCU i8 gather/pack of every
    // kernel) vs TTN2 bytes (parse + word-copy of the plane words).
    let boot_net = dvs_hybrid_random(96, 21, 0.5);
    let v1_bytes = ttn::write_bytes(&loader::network_bundle(&boot_net));
    let boot_image = PreparedNet::new(&boot_net, &cfg).to_image();
    let v2_bytes = ttn::upgrade_bytes(&v1_bytes, &boot_image).unwrap();
    let r_boot_i8 = bench("boot: preload i8 .ttn (baseline)", 2, 10, || {
        let (bundle, _) = ttn::read_bytes_full(black_box(&v1_bytes)).unwrap();
        black_box(&bundle);
        PreparedNet::new(&boot_net, &cfg)
    });
    let r_boot_packed = bench("boot: load packed image", 2, 10, || {
        let (_, img) = ttn::read_bytes_full(black_box(&v2_bytes)).unwrap();
        PreparedNet::from_image(&img.unwrap(), &boot_net, &cfg).unwrap()
    });
    println!(
        "  speedup word-copy boot vs i8 re-pack: {:.2}x  ({} B v1, {} B v2)\n",
        r_boot_i8.median_s / r_boot_packed.median_s,
        v1_bytes.len(),
        v2_bytes.len()
    );
    suite.push(&r_boot_i8);
    suite.push_speedup(&r_boot_packed, &r_boot_i8);

    // --- engine spawn: 8-worker pool over one shared Arc'd image ---
    // Before the shared-image pass every worker re-packed its own copy;
    // now spawn cost is one image build + K bank-adoptions.
    let r_spawn = bench("engine: spawn 8-worker pool", 2, 10, || {
        Engine::new(
            &boot_net,
            EngineConfig { mode: SimMode::Fast, workers: 8, ..Default::default() },
        )
        .unwrap()
    });
    suite.push(&r_spawn);

    // --- multi-stream engine serving: 4 sessions interleaved ---
    // The serving-throughput ledger entry (api_redesign pass): the same
    // 32 frames as 4 independent streams through one engine, serial vs
    // worker-pool CNN sharding. Counters are identical either way (the
    // engine determinism tests prove it); this measures wall throughput.
    let serve_streams = |workers: usize| {
        let mut engine =
            Engine::new(&dnet, EngineConfig { mode: SimMode::Fast, workers, ..Default::default() })
                .unwrap();
        let mut srcs: Vec<DvsSource> =
            (0..4).map(|s| DvsSource::new(64, 11 + s as u64, GestureClass(s % 12))).collect();
        for _ in 0..8 {
            for (sid, src) in srcs.iter_mut().enumerate() {
                engine.submit(sid, src.next_frame()).unwrap();
            }
        }
        engine.drain().unwrap();
        engine.aggregate_report()
    };
    let r_eng1 = bench("DVS engine 4 streams x 8 frames serial (fast)", 1, 5, || serve_streams(1));
    let r_engn = bench("DVS engine 4 streams x 8 frames pooled (fast)", 1, 5, || serve_streams(0));
    let engine_frames = 4 * 8;
    println!(
        "  engine speedup pooled vs serial: {:.2}x  ({engine_frames} frames, {:.0} wall inf/s pooled)\n",
        r_eng1.median_s / r_engn.median_s,
        engine_frames as f64 / r_engn.median_s
    );
    suite.push(&r_eng1);
    suite.push_speedup(&r_engn, &r_eng1);

    // --- resilience: label accuracy vs SRAM supply under bit upsets ---
    // The fault-injection pass's ledger entry (EXPERIMENTS.md §Faults):
    // the same 24 DVS frames served at each activation-SRAM supply
    // point, injecting at the BER the voltage model predicts, scored as
    // the fraction of labels disagreeing with the fault-free run. (The
    // core's energy point stays at the nominal 0.5 V — only the SRAM
    // macro is voltage-scaled here.) Encoded as `1.0 + disagreement` so
    // the regression checker's ratio math stays well-defined: a clean
    // sweep point is exactly 1.0, never 0.
    let serve_at = |plan: Option<FaultPlan>| -> Vec<usize> {
        let mut engine = Engine::new(
            &dnet,
            EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
        )
        .unwrap();
        engine.open_session(0).unwrap();
        if let Some(p) = plan {
            engine.set_fault_plan(0, p).unwrap();
        }
        let mut src = DvsSource::new(64, 31, GestureClass(4));
        for _ in 0..24 {
            engine.submit(0, src.next_frame()).unwrap();
        }
        engine.drain().unwrap();
        engine.finish_session(0).unwrap().labels
    };
    let clean_labels = serve_at(None);
    println!("resilience: DVS label accuracy vs activation-SRAM supply (24 frames):");
    for v in [0.60, 0.55, 0.50, 0.45, 0.40] {
        let plan = FaultPlan::at_voltage(FaultSurface::ActMem, v, 17);
        let labels = serve_at(Some(plan));
        let wrong = labels.iter().zip(&clean_labels).filter(|(a, b)| a != b).count();
        let dis = wrong as f64 / clean_labels.len() as f64;
        println!(
            "  {v:.2} V  ber {:>9.2e}  label disagreement {wrong}/{} ({:.1} %)",
            ber(v),
            clean_labels.len(),
            dis * 100.0
        );
        suite.push(&BenchResult {
            name: format!("resilience: DVS label disagreement @ {v:.2} V (1 = clean)"),
            iters: clean_labels.len(),
            median_s: 1.0 + dis,
            mad_s: 0.0,
        });
    }
    println!();

    // --- hibernation: snapshot/restore a warm session ---
    // The idle-tier cost entries (EXPERIMENTS.md §Hibernation): encode a
    // served session into its checksummed snapshot payload, and rebuild
    // a bit-identical session from those bytes.
    let mut warm_engine = Engine::new(
        &dnet,
        EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
    )
    .unwrap();
    warm_engine.open_session(0).unwrap();
    let mut warm_src = DvsSource::new(64, 51, GestureClass(2));
    for _ in 0..8 {
        warm_engine.submit(0, warm_src.next_frame()).unwrap();
    }
    warm_engine.drain().unwrap();
    let warm = warm_engine.session(0).unwrap();
    let r_snap = bench("hibernate: snapshot session", 3, 30, || {
        SessionSnapshot::capture(black_box(warm)).encode()
    });
    let payload = SessionSnapshot::capture(warm).encode();
    let r_restore = bench("hibernate: restore session", 3, 30, || {
        SessionSnapshot::decode(black_box(&payload), 0).unwrap().into_session().unwrap()
    });
    println!(
        "  hibernation: {} B snapshot payload ({:.2}x the 576 B Kraken TCN state)\n",
        payload.len(),
        payload.len() as f64 / 576.0
    );
    suite.push(&r_snap);
    suite.push(&r_restore);

    // --- fleet: routed submit round and live session migration ---
    // The sharded-fleet entries (EXPERIMENTS.md §Fleet): one round of 8
    // streams hash-routed and served through a 2-engine fleet, and one
    // live migration (settle → snapshot export → import → reroute).
    let mut fleet = Fleet::new(
        &dnet,
        FleetConfig {
            engines: 2,
            engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    let mut fleet_srcs: Vec<DvsSource> =
        (0..8).map(|s| DvsSource::new(64, 61 + s as u64, GestureClass(s % 12))).collect();
    for (sid, src) in fleet_srcs.iter_mut().enumerate() {
        fleet.submit(sid, src.next_frame()).unwrap();
    }
    fleet.drain().unwrap(); // warm: every session resident on its engine
    let r_route = bench("fleet: route submit", 1, 5, || {
        for (sid, src) in fleet_srcs.iter_mut().enumerate() {
            fleet.submit(sid, src.next_frame()).unwrap();
        }
        fleet.drain().unwrap()
    });
    let mut target = fleet.route(0).map(|e| (e + 1) % 2).unwrap_or(1);
    let r_migrate = bench("fleet: migrate session", 3, 30, || {
        fleet.migrate(0, target).unwrap();
        target = (target + 1) % 2;
    });
    println!(
        "  fleet: 8-stream routed round in {:.1} µs wall, live migration {:.1} µs wall\n",
        r_route.median_s * 1e6,
        r_migrate.median_s * 1e6
    );
    suite.push(&r_route);
    suite.push(&r_migrate);

    // --- multi-workload: cifar9 feed-forward frame + bound-image switch ---
    // The workload-registry entries (EXPERIMENTS.md §Workloads): the
    // second headline net's per-frame serve path (CNN front-end straight
    // into the classifier — no TCN ring), and the cost of re-binding a
    // scheduler between two registered prepared images (the per-frame
    // tax an interleaved multi-net stream pays: park the outgoing net's
    // weight banks, restore the incoming net's).
    let cifar_frame = PackedMap::from_trit(&TritTensor::random(&[32, 32, 3], &mut rng, 0.4));
    let mut cifar_sched = Scheduler::new(cfg.clone(), SimMode::Fast);
    cifar_sched.preload_weights(&net);
    let r_cifar = bench("workload: cifar9_96 frame", 2, 10, || {
        let (feat, _) = cifar_sched.run_cnn(&net, &cifar_frame).unwrap();
        cifar_sched.run_classifier(&net, &feat).unwrap()
    });
    suite.push(&r_cifar);

    let img_dvs = Arc::new(PreparedNet::new(&dnet, &cfg));
    let img_cifar = Arc::new(PreparedNet::new(&net, &cfg));
    let mut switcher = Scheduler::new(cfg.clone(), SimMode::Fast);
    switcher.swap_image(Arc::clone(&img_dvs));
    switcher.preload_weights(&dnet);
    switcher.swap_image(Arc::clone(&img_cifar));
    switcher.preload_weights(&net);
    // steady state: both nets' weight memories exist, one live one parked
    let r_switch = bench("workload: image switch", 3, 30, || {
        switcher.swap_image(Arc::clone(&img_dvs));
        switcher.swap_image(Arc::clone(&img_cifar));
    });
    println!(
        "  workload: cifar9 frame {:.1} µs, image switch pair {:.2} µs wall\n",
        r_cifar.median_s * 1e6,
        r_switch.median_s * 1e6
    );
    suite.push(&r_switch);

    // --- SIMD backend A/B: scalar vs AVX2 packed kernels ---
    // Pin the backend, run the same fused-column dot / ternarize /
    // maxpool / DVS-front-end cases under each, and record both sets
    // (entries carry the backend tag so the CI regression checker only
    // compares like-for-like). Words and counters are bit-identical
    // across backends — the kernel sweep tests prove it; this measures
    // the wall-clock gap only.
    {
        use tcn_cutie::trit::simd::{self, SimdBackend};
        use tcn_cutie::trit::{ternarize_packed, TritCol};

        let mut srng = Rng::new(77);
        let rows: Vec<Vec<i8>> =
            (0..3).map(|_| (0..96).map(|_| srng.trit(0.4)).collect()).collect();
        let packed_rows = [
            PackedVec::pack(&rows[0]),
            PackedVec::pack(&rows[1]),
            PackedVec::pack(&rows[2]),
        ];
        let xcol = TritCol::pack_rows(&packed_rows, 96);
        let wrow: Vec<i8> = (0..96).map(|_| srng.trit(0.4)).collect();
        let wcol = TritCol::pack_rows(
            &[PackedVec::pack(&wrow), packed_rows[0], packed_rows[2]],
            96,
        );
        let nwords = TritCol::words(96);
        let accs: Vec<i32> = (0..96).map(|i| (i % 7) - 3).collect();
        let lo: Vec<i32> = vec![-1; 96];
        let hi: Vec<i32> = vec![1; 96];
        let run_cases = |tag: &str| -> Vec<BenchResult> {
            let r_dot = bench(&format!("simd fused col dot 3x3x96 ({tag})"), 3, 30, || {
                let mut acc = 0i64;
                for _ in 0..10_000 {
                    let (d, t) = black_box(&wcol).dot(black_box(&xcol), nwords);
                    acc += d as i64 + t as i64;
                }
                acc
            });
            let r_tern = bench(&format!("simd ternarize 96ch ({tag})"), 3, 30, || {
                let mut acc = 0u64;
                for _ in 0..10_000 {
                    let v = ternarize_packed(black_box(&accs), &lo, &hi);
                    acc = acc.wrapping_add(v.pos[0] ^ v.mask[1]);
                }
                acc
            });
            let r_max = bench(&format!("simd maxpool word max ({tag})"), 3, 30, || {
                let mut acc = 0u64;
                for _ in 0..10_000 {
                    let v = black_box(&pa).max(black_box(&pb));
                    acc = acc.wrapping_add(v.pos[0] ^ v.mask[0]);
                }
                acc
            });
            let r_front = bench(&format!("simd DVS CNN 64x64 front-end ({tag})"), 2, 10, || {
                let mut x = frame.clone();
                for p in &preps {
                    x = run_prepared(p, &x, &cfg, SimMode::Accurate).unwrap().output;
                }
                x
            });
            vec![r_dot, r_tern, r_max, r_front]
        };
        simd::set_backend(SimdBackend::Scalar).unwrap();
        let scalar_runs = run_cases("scalar");
        for r in &scalar_runs {
            suite.push(r);
        }
        if simd::avx2_available() {
            simd::set_backend(SimdBackend::Avx2).unwrap();
            let avx_runs = run_cases("avx2");
            for (r, base) in avx_runs.iter().zip(&scalar_runs) {
                suite.push_speedup(r, base);
            }
            println!(
                "  simd speedup avx2 vs scalar: dot {:.2}x, ternarize {:.2}x, max {:.2}x, front-end {:.2}x\n",
                scalar_runs[0].median_s / avx_runs[0].median_s,
                scalar_runs[1].median_s / avx_runs[1].median_s,
                scalar_runs[2].median_s / avx_runs[2].median_s,
                scalar_runs[3].median_s / avx_runs[3].median_s
            );
        } else {
            println!("  (host lacks AVX2 — scalar SIMD entries only)\n");
        }
        simd::set_backend(SimdBackend::Auto).unwrap();
    }

    // --- cross-session lane batching: K same-net CNN front-ends ---
    // The lane-batching ledger entry (EXPERIMENTS.md §Perf iteration
    // 10): 8 same-geometry DVS frames through the shared-weight
    // front-end, one serial run_cnn per frame vs one lane-batched
    // invocation. Per-lane words and counters are bit-identical (the
    // scheduler's lane test proves it); this measures the weight-reuse
    // wall-clock win.
    let mut lane_serial = Scheduler::new(cfg.clone(), SimMode::Fast);
    lane_serial.preload_weights(&dnet);
    let mut lane_batched = Scheduler::new(cfg.clone(), SimMode::Fast);
    lane_batched.preload_weights(&dnet);
    let lane_frames: Vec<PackedMap> = (0..8)
        .map(|s| DvsSource::new(64, 71 + s as u64, GestureClass(s % 12)).next_frame())
        .collect();
    let lane_refs: Vec<&PackedMap> = lane_frames.iter().collect();
    let r_lane_serial = bench("lanes: 8-session front-end serial (baseline)", 2, 10, || {
        let mut acc = 0u64;
        for f in &lane_frames {
            let (feat, _) = lane_serial.run_cnn(&dnet, f).unwrap();
            acc = acc.wrapping_add(feat.pixels[0].mask[0]);
        }
        acc
    });
    let r_lane_batched = bench("lanes: 8-session front-end lane-batched", 2, 10, || {
        lane_batched.run_cnn_lanes(&dnet, &lane_refs).unwrap()
    });
    println!(
        "  lane batching speedup (8 lanes): {:.2}x\n",
        r_lane_serial.median_s / r_lane_batched.median_s
    );
    suite.push(&r_lane_serial);
    suite.push_speedup(&r_lane_batched, &r_lane_serial);

    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    match suite.write_json(&path) {
        Ok(_) => println!("wrote perf ledger: {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
