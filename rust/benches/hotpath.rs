//! Perf — simulator hot-path microbenchmarks (EXPERIMENTS.md §Perf):
//! packed-bitplane OCU dot products vs a scalar i8 baseline, the
//! per-layer datapath loop, and end-to-end serving throughput in both
//! sim modes. The §Perf target: the full DVS pipeline simulates faster
//! than the 0.5 V silicon serves it (≥1x realtime).
//!
//!     cargo bench --bench hotpath

use tcn_cutie::coordinator::{Pipeline, PipelineConfig};
use tcn_cutie::cutie::datapath::run_conv_layer;
use tcn_cutie::cutie::{CutieConfig, SimMode};
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random};
use tcn_cutie::tensor::TritTensor;
use tcn_cutie::trit::{dot_scalar, PackedVec};
use tcn_cutie::util::bench::{bench, black_box};
use tcn_cutie::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(99);

    // --- microbench: ternary dot product, packed vs scalar ---
    let a: Vec<i8> = (0..96).map(|_| rng.trit(0.33)).collect();
    let b: Vec<i8> = (0..96).map(|_| rng.trit(0.33)).collect();
    let pa = PackedVec::pack(&a);
    let pb = PackedVec::pack(&b);
    let r_scalar = bench("dot 96ch: scalar i8 loop (baseline)", 3, 30, || {
        let mut acc = 0i64;
        for _ in 0..10_000 {
            acc += dot_scalar(black_box(&a), black_box(&b)).0 as i64;
        }
        acc
    });
    let r_packed = bench("dot 96ch: bitplane popcount (with activity)", 3, 30, || {
        let mut acc = 0i64;
        for _ in 0..10_000 {
            acc += black_box(&pa).dot(black_box(&pb)).0 as i64;
        }
        acc
    });
    let r_fast = bench("dot 96ch: bitplane popcount (fast)", 3, 30, || {
        let mut acc = 0i64;
        for _ in 0..10_000 {
            acc += black_box(&pa).dot_fast(black_box(&pb)) as i64;
        }
        acc
    });
    println!(
        "  speedup packed vs scalar: {:.1}x (fast: {:.1}x)\n",
        r_scalar.median_s / r_packed.median_s,
        r_scalar.median_s / r_fast.median_s
    );

    // --- one 96x96 conv layer on the datapath ---
    let net = cifar9_random(96, 7, 0.33);
    let cfg = CutieConfig::kraken();
    let input = TritTensor::random(&[32, 32, 96], &mut rng, 0.4);
    bench("datapath layer 32x32x96→96 (accurate)", 2, 10, || {
        run_conv_layer(&net.layers[2], &input, &cfg, SimMode::Accurate).unwrap()
    });
    bench("datapath layer 32x32x96→96 (fast)", 2, 10, || {
        run_conv_layer(&net.layers[2], &input, &cfg, SimMode::Fast).unwrap()
    });

    // --- end-to-end serving throughput vs realtime ---
    let dnet = dvs_hybrid_random(96, 3, 0.5);
    for (label, mode) in [("accurate", SimMode::Accurate), ("fast", SimMode::Fast)] {
        let pipe = Pipeline::new(
            dnet.clone(),
            PipelineConfig { frames: 8, mode, ..Default::default() },
        );
        let r = bench(&format!("DVS serve 8 frames ({label})"), 1, 5, || pipe.run_inline().unwrap());
        let rep = pipe.run_inline().unwrap();
        let sim_time = rep.metrics.sim_time_s;
        let wall_per_run = r.median_s;
        println!(
            "  realtime ratio ({label}): sim {:.1} µs of 0.5 V silicon in {:.1} ms wall → {:.2}x realtime\n",
            sim_time * 1e6,
            wall_per_run * 1e3,
            sim_time / wall_per_run
        );
    }
}
