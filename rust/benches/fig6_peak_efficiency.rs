//! F6 — regenerates Figure 6 (peak energy efficiency and peak throughput
//! vs voltage, first CIFAR layer) and times the generation.
//!
//!     cargo bench --bench fig6_peak_efficiency

use tcn_cutie::report;
use tcn_cutie::util::bench::bench;

fn main() {
    let pts = report::fig6().unwrap();
    println!("== Figure 6: peak energy efficiency + peak throughput vs voltage ==\n");
    report::fig6_table(&pts).print();

    println!("\npaper anchors: 1036 TOp/s/W + 14.9 TOp/s @0.5 V; 318 TOp/s/W + 51.7 TOp/s @0.9 V");
    println!(
        "measured:      {:.0} TOp/s/W + {:.1} TOp/s @0.5 V; {:.0} TOp/s/W + {:.1} TOp/s @0.9 V\n",
        pts[0].peak_tops_w,
        pts[0].peak_tops,
        pts[8].peak_tops_w,
        pts[8].peak_tops
    );

    bench("fig6 sweep (9 corners)", 1, 5, || report::fig6().unwrap());
}
