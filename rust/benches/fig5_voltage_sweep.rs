//! F5 — regenerates Figure 5 (energy/inference and inferences/s vs
//! supply voltage for the CIFAR and DVS networks) and times the sweep.
//!
//!     cargo bench --bench fig5_voltage_sweep

use tcn_cutie::report;
use tcn_cutie::util::bench::bench;

fn main() {
    let pts = report::fig5().unwrap();
    println!("== Figure 5: energy per inference + inferences/s vs voltage ==\n");
    report::fig5_table(&pts).print();

    let e_ratio = pts.last().unwrap().cifar_uj / pts[0].cifar_uj;
    let r_ratio = pts.last().unwrap().cifar_inf_s / pts[0].cifar_inf_s;
    println!("\nshape check: 0.5→0.9 V energy ×{e_ratio:.2}, rate ×{r_ratio:.2}");
    println!("paper shape: energy rises ~3x across the range, rate rises with fmax;");
    println!("0.5 V is the energy-optimal corner (2.72 µJ CIFAR / 5.5 µJ DVS).\n");

    bench("fig5 full voltage sweep (9 corners, both nets)", 1, 5, || {
        report::fig5().unwrap()
    });
}
