//! T1 — regenerates Table 1 (SoA comparison on the 9-layer CIFAR-10
//! network) and times the end-to-end simulator inference that produces
//! our rows.
//!
//!     cargo bench --bench table1

use tcn_cutie::cutie::SimMode;
use tcn_cutie::report;
use tcn_cutie::util::bench::bench;

fn main() {
    println!("== Table 1: comparison with SoA highly quantized digital accelerators ==\n");
    report::table1().unwrap().print();

    println!("\npaper expectations: this work 2.72 µJ / 1036 TOp/s/W @0.5 V,");
    println!("56 TOp/s (text: 51.7) @0.9 V; [8] 617 TOp/s/W; [9] 230 TOp/s/W.");
    println!("headline: CUTIE beats the best prior (617) by ~1.67x.\n");

    // time the workload that generates our rows (end-to-end inference)
    bench("cifar9_96 inference (accurate, activity counted)", 2, 10, || {
        report::cifar_stats(SimMode::Accurate).unwrap()
    });
    bench("cifar9_96 inference (fast mode)", 2, 10, || {
        report::cifar_stats(SimMode::Fast).unwrap()
    });
}
