//! Cross-language bit-exactness: the JAX oracle's test vectors
//! (artifacts/testvec_*.ttn) must match both the functional reference
//! executor and the cycle-level CUTIE simulator, trit for trit.

use tcn_cutie::cutie::{CutieConfig, Scheduler, SimMode};
use tcn_cutie::network::{loader, reference};
use tcn_cutie::tensor::ttn;

fn artifacts() -> std::path::PathBuf {
    loader::artifacts_dir()
}

fn have(name: &str) -> bool {
    artifacts().join(name).exists()
}

fn check_net(stem: &str, n_vecs: usize) {
    if !have(&format!("{stem}.json")) {
        eprintln!("skipping {stem}: artifacts not built (run `make artifacts`)");
        return;
    }
    let net = loader::load_network(artifacts().join(format!("{stem}.json"))).unwrap();
    let vecs = ttn::read_file(artifacts().join(format!("testvec_{stem}.ttn"))).unwrap();
    for i in 0..n_vecs {
        let input = vecs[&format!("in{i}")].as_trit().unwrap();
        let want = vecs[&format!("out{i}")].as_int().unwrap();

        // functional reference executor
        let got_ref = reference::forward(&net, input).unwrap();
        assert_eq!(got_ref.data, want.data, "{stem} vec {i}: reference executor mismatch");

        // cycle-level simulator (fresh scheduler per vector: the JAX
        // test vectors were generated with a cold TCN window)
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (got_sim, stats) = sched.run_full(&net, input).unwrap();
        assert_eq!(got_sim.data, want.data, "{stem} vec {i}: simulator mismatch");
        assert!(stats.total_cycles() > 0);
        assert_eq!(stats.stall_cycles(), 0, "mapped execution must be stall-free");
    }
}

#[test]
fn cifar9_96_matches_jax_oracle() {
    check_net("cifar9_96", 4);
}

#[test]
fn cifar9_mini_trained_matches_jax_oracle() {
    check_net("cifar9_mini", 4);
}

#[test]
fn dvs_hybrid_matches_jax_oracle() {
    check_net("dvs_hybrid_96", 2);
}

#[test]
fn trained_net_accuracy_on_eval_set() {
    // End-to-end: the build-time-trained network must classify the
    // synthetic eval set on the *simulator* exactly as JAX reported
    // (train_log.json records int_test_acc; we recompute ≥ that level).
    if !have("evalset_cifar9_mini.ttn") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("cifar9_mini.json")).unwrap();
    let eval = ttn::read_file(artifacts().join("evalset_cifar9_mini.ttn")).unwrap();
    let images = eval["images"].as_trit().unwrap();
    let labels = eval["labels"].as_int().unwrap();
    let n = images.dims[0].min(64); // keep test time bounded
    let (h, w, c) = (images.dims[1], images.dims[2], images.dims[3]);
    let mut correct = 0usize;
    for i in 0..n {
        let img = tcn_cutie::tensor::TritTensor::from_vec(
            &[h, w, c],
            images.data[i * h * w * c..(i + 1) * h * w * c].to_vec(),
        );
        let logits = reference::forward(&net, &img).unwrap();
        if logits.argmax() as i32 == labels.data[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "trained-net accuracy on simulator: {acc}");
}
