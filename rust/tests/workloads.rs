//! Multi-workload serving (ISSUE 9): sessions of *different* networks —
//! the recurrent DVS gesture net and the feed-forward cifar9 classifier
//! — interleaved through one engine (and sharded across a fleet, with
//! live migration) must close byte-identical to serving each stream on
//! its own single-net engine; a shared hibernation store carries records
//! of both nets and re-binds each by its snapshot fingerprint; a record
//! bound to a net the registry does not hold is a typed refusal that
//! leaves the record in the store; and a frame that disagrees with its
//! session's binding is refused before anything moves.

use std::fs;
use std::sync::Arc;

use tcn_cutie::coordinator::{
    BindingError, DvsSource, Engine, EngineConfig, Fleet, FleetConfig, GestureClass, NetRegistry,
    ServingReport, SessionStore, SyntheticSource,
};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random, Network};
use tcn_cutie::tensor::PackedMap;

fn dvs_net() -> Network {
    dvs_hybrid_random(16, 5, 0.5)
}

fn cifar_net() -> Network {
    cifar9_random(16, 7, 0.4)
}

/// Both headline workloads behind one shared registry:
/// (registry, dvs fingerprint, cifar fingerprint).
fn mixed_registry() -> (Arc<NetRegistry>, u64, u64) {
    let mut reg = NetRegistry::single(dvs_net()).unwrap();
    let fp_dvs = reg.default_fingerprint();
    let fp_cif = reg.add(cifar_net()).unwrap();
    (Arc::new(reg), fp_dvs, fp_cif)
}

/// A per-net deterministic camera: event frames for the recurrent net,
/// dense ternary frames for the feed-forward one. The stream is a pure
/// function of (net, session), so the same session replays identically
/// on any engine.
enum Src {
    Dvs(DvsSource),
    Syn(SyntheticSource),
}

impl Src {
    fn next(&mut self) -> PackedMap {
        match self {
            Src::Dvs(s) => s.next_frame(),
            Src::Syn(s) => s.next_frame(),
        }
    }
}

fn source_for(net: &Network, s: usize) -> Src {
    if net.has_tcn() {
        Src::Dvs(DvsSource::new(net.input_hw, 100 + s as u64, GestureClass(s % 12)))
    } else {
        let ch = net.layers.first().map_or(0, |l| l.in_ch);
        Src::Syn(SyntheticSource::new(net.input_hw, ch, 100 + s as u64))
    }
}

fn assert_identical(a: &ServingReport, b: &ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}: soc power");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault summary");
}

/// The single-net oracle: session `sid` of `net` alone on its own
/// engine, one drain per frame.
fn serve_isolated(
    net: &Network,
    mode: SimMode,
    workers: usize,
    sid: usize,
    frames: usize,
) -> ServingReport {
    let cfg = EngineConfig { mode, workers, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.open_session(sid).unwrap();
    let mut src = source_for(net, sid);
    for _ in 0..frames {
        engine.submit(sid, src.next()).unwrap();
        engine.drain().unwrap();
    }
    engine.finish_session(sid).unwrap()
}

#[test]
fn interleaved_mixed_sessions_match_isolated() {
    // The tentpole acceptance gate: DVS and cifar sessions interleaved
    // frame by frame through ONE engine — the tail parks/restores each
    // net's weight-bank residency at every image switch — must close
    // byte-identical to serving each stream alone, in both sim modes,
    // serial and pooled.
    let (dvs, cif) = (dvs_net(), cifar_net());
    let frames = 3;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1, 2] {
            let (reg, fp_dvs, fp_cif) = mixed_registry();
            let cfg = EngineConfig { mode, workers, ..Default::default() };
            let mut engine = Engine::with_registry(Arc::clone(&reg), cfg).unwrap();
            let bind = [fp_dvs, fp_cif, fp_dvs, fp_cif];
            for (sid, fp) in bind.iter().enumerate() {
                engine.open_session_on(sid, *fp).unwrap();
            }
            let nets = [&dvs, &cif, &dvs, &cif];
            let mut srcs: Vec<Src> =
                nets.iter().enumerate().map(|(s, n)| source_for(n, s)).collect();
            for _ in 0..frames {
                for (sid, src) in srcs.iter_mut().enumerate() {
                    engine.submit(sid, src.next()).unwrap();
                }
                engine.drain().unwrap();
            }

            // per-net aggregate rows: one per registered net that served
            let agg = engine.aggregate_report();
            assert_eq!(agg.nets.len(), 2, "one usage row per net");
            for (fp, net) in [(fp_dvs, &dvs), (fp_cif, &cif)] {
                let row = agg.nets.iter().find(|r| r.fingerprint == fp).unwrap();
                assert_eq!(row.name, net.name);
                assert_eq!((row.sessions, row.frames), (2, 2 * frames as u64));
            }

            for (sid, rep) in engine.finish_all() {
                let solo = serve_isolated(nets[sid], mode, workers, sid, frames);
                let ctx = format!("{mode:?} workers {workers} session {sid}");
                assert_identical(&rep, &solo, &ctx);
            }
        }
    }
}

#[test]
fn fleet_mixed_workloads_with_migration_match_isolated() {
    // K=2 fleet over the shared registry, sessions of both nets, every
    // session live-migrating to the other engine mid-run: byte-identical
    // to isolation (the migrated snapshot re-binds by fingerprint on the
    // importing engine).
    let (dvs, cif) = (dvs_net(), cifar_net());
    let frames = 4;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1, 2] {
            let (reg, fp_dvs, fp_cif) = mixed_registry();
            let fcfg = FleetConfig {
                engines: 2,
                engine: EngineConfig { mode, workers, ..Default::default() },
                ..Default::default()
            };
            let mut fleet = Fleet::with_registry(Arc::clone(&reg), fcfg).unwrap();
            let bind = [fp_dvs, fp_cif, fp_dvs, fp_cif];
            for (sid, fp) in bind.iter().enumerate() {
                fleet.open_session_on(sid, *fp).unwrap();
            }
            let nets = [&dvs, &cif, &dvs, &cif];
            let mut srcs: Vec<Src> =
                nets.iter().enumerate().map(|(s, n)| source_for(n, s)).collect();
            for round in 0..frames {
                for (sid, src) in srcs.iter_mut().enumerate() {
                    fleet.submit(sid, src.next()).unwrap();
                }
                fleet.drain().unwrap();
                if round == 1 {
                    for sid in 0..4 {
                        let from = fleet.route(sid).unwrap();
                        fleet.migrate(sid, (from + 1) % 2).unwrap();
                    }
                }
            }
            assert_eq!(fleet.report().migrations, 4);

            let agg = fleet.aggregate_report();
            assert_eq!(agg.nets.len(), 2, "fleet aggregate carries per-net rows");
            for fp in [fp_dvs, fp_cif] {
                let row = agg.nets.iter().find(|r| r.fingerprint == fp).unwrap();
                assert_eq!((row.sessions, row.frames), (2, 2 * frames as u64));
            }

            for (sid, rep) in fleet.finish_all() {
                let solo = serve_isolated(nets[sid], mode, workers, sid, frames);
                let ctx = format!("fleet {mode:?} workers {workers} session {sid}");
                assert_identical(&rep, &solo, &ctx);
            }
        }
    }
}

#[test]
fn hibernated_sessions_share_one_store_across_nets() {
    // One snapshot store holds records of BOTH nets; each resumes onto
    // its own weights (re-bound by the fingerprint inside the record),
    // and the detour through the idle tier perturbs no serving ledger.
    let (dvs, cif) = (dvs_net(), cifar_net());
    let nets = [&dvs, &cif];
    let serve = |hibernate: bool| -> Vec<(usize, ServingReport)> {
        let (reg, fp_dvs, fp_cif) = mixed_registry();
        let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
        let mut engine = Engine::with_registry(reg, cfg).unwrap();
        if hibernate {
            engine.enable_hibernation(SessionStore::in_memory(), None);
        }
        engine.open_session_on(0, fp_dvs).unwrap();
        engine.open_session_on(1, fp_cif).unwrap();
        let mut srcs: Vec<Src> = nets.iter().enumerate().map(|(s, n)| source_for(n, s)).collect();
        for round in 0..4 {
            for (sid, src) in srcs.iter_mut().enumerate() {
                engine.submit(sid, src.next()).unwrap();
            }
            engine.drain().unwrap();
            if hibernate && round == 1 {
                engine.hibernate(0).unwrap();
                engine.hibernate(1).unwrap();
                let store = engine.store().unwrap();
                assert_eq!(store.len(), 2, "both nets' records share the store");
            }
        }
        engine.finish_all()
    };
    let resident = serve(false);
    let toured = serve(true);
    for ((sid, rep), (_, oracle)) in toured.iter().zip(&resident) {
        assert_identical(rep, oracle, &format!("idle-tier detour, session {sid}"));
        assert_eq!((rep.hib.hibernates, rep.hib.resumes), (1, 1), "session {sid}");
    }
}

#[test]
fn wrong_fingerprint_resume_is_refused_and_record_survives() {
    // A valid record bound to a net the registry does not hold must be a
    // typed refusal that leaves the record in the store — never a silent
    // resume onto the wrong weights — and a registry that does hold the
    // net can still consume the same record bit-exactly afterwards.
    let (dvs, cif) = (dvs_net(), cifar_net());
    let path = std::env::temp_dir().join("tcn_cutie_workloads_shared.store");
    let _ = fs::remove_file(&path);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let solo = serve_isolated(&cif, SimMode::Fast, 1, 7, 4);

    // Engine A holds both nets: serve a cifar session, hibernate it.
    let (reg, _, fp_cif) = mixed_registry();
    let mut src = source_for(&cif, 7);
    {
        let mut a = Engine::with_registry(reg, cfg.clone()).unwrap();
        a.enable_hibernation(SessionStore::open(&path).unwrap(), None);
        a.open_session_on(7, fp_cif).unwrap();
        for _ in 0..2 {
            a.submit(7, src.next()).unwrap();
            a.drain().unwrap();
        }
        a.hibernate(7).unwrap();
    }

    // Engine B holds only the DVS net but opens the same store: the
    // cifar record is refused with a typed error and NOT consumed.
    {
        let mut b = Engine::new(&dvs, cfg.clone()).unwrap();
        b.enable_hibernation(SessionStore::open(&path).unwrap(), None);
        let err = b.resume(7).unwrap_err();
        assert_eq!(
            err.downcast_ref::<BindingError>(),
            Some(&BindingError::SnapshotNet { session: 7, fingerprint: fp_cif }),
            "got {err}"
        );
        assert!(b.store().unwrap().contains(7), "the refused record stays in the store");
        // The serve path refuses the same way, before any state moves.
        let shape = (cif.input_hw, cif.input_hw, 3);
        let err = b.submit(7, PackedMap::zeros(shape.0, shape.1, shape.2)).unwrap_err();
        assert!(matches!(err, BindingError::SnapshotNet { session: 7, .. }), "got {err}");
        assert_eq!(b.pending_frames(), 0);
    }

    // Engine C holds both nets again (fingerprints are content-derived,
    // so a rebuilt registry re-binds the same record): resume and finish
    // the stream, byte-identical to never hibernating or moving engines.
    let (reg_c, _, fp_cif_c) = mixed_registry();
    assert_eq!(fp_cif_c, fp_cif);
    let mut c = Engine::with_registry(reg_c, cfg).unwrap();
    c.enable_hibernation(SessionStore::open(&path).unwrap(), None);
    assert!(c.resume(7).unwrap(), "the full registry consumes the record");
    for _ in 0..2 {
        c.submit(7, src.next()).unwrap();
        c.drain().unwrap();
    }
    let rep = c.finish_session(7).unwrap();
    assert_identical(&rep, &solo, "store handoff across engines");
    let _ = fs::remove_file(&path);
}

#[test]
fn frame_shape_mismatch_is_typed_and_enqueues_nothing() {
    // A frame that disagrees with its session's bound net is refused at
    // submit with a typed error — no RNG advanced, nothing enqueued —
    // and a session can never be re-bound to a different net.
    let (dvs, cif) = (dvs_net(), cifar_net());
    let (reg, fp_dvs, fp_cif) = mixed_registry();
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::with_registry(reg, cfg).unwrap();
    engine.open_session_on(0, fp_dvs).unwrap();
    engine.open_session_on(1, fp_cif).unwrap();

    let cif_ch = cif.layers.first().map_or(0, |l| l.in_ch);
    let err = engine.submit(0, PackedMap::zeros(cif.input_hw, cif.input_hw, cif_ch)).unwrap_err();
    assert_eq!(
        err,
        BindingError::FrameShape {
            session: 0,
            got: (cif.input_hw, cif.input_hw, cif_ch),
            want: (dvs.input_hw, dvs.input_hw, 2),
        }
    );
    let err = engine.submit(1, PackedMap::zeros(dvs.input_hw, dvs.input_hw, 2)).unwrap_err();
    assert_eq!(
        err,
        BindingError::FrameShape {
            session: 1,
            got: (dvs.input_hw, dvs.input_hw, 2),
            want: (cif.input_hw, cif.input_hw, cif_ch),
        }
    );
    assert_eq!(engine.pending_frames(), 0, "refused frames are never enqueued");

    let err = engine.open_session_on(0, fp_cif).unwrap_err();
    assert_eq!(err, BindingError::Rebind { session: 0, bound: fp_dvs, requested: fp_cif });
}
