//! Integration tests of the fault-injection and self-healing layer: the
//! zero-BER bit-exactness gate (an armed-but-inert plan must not perturb
//! a single bit of any report), per-surface injection behavior, weight
//! repair transparency, session quarantine, and the interleaved-session
//! isolation guarantee (an injected neighbor must not perturb clean
//! co-sessions).

use std::sync::Arc;

use tcn_cutie::coordinator::{
    BindingError, DvsSource, Engine, EngineConfig, FrameSource, GestureClass, NetRegistry,
    ServingReport, FAILURE_LIMIT,
};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::fault::{FaultPlan, FaultSurface};
use tcn_cutie::network::{dvs_hybrid_random, Network};
use tcn_cutie::tensor::PackedMap;

const SURFACES: [FaultSurface; 5] = [
    FaultSurface::ActMem,
    FaultSurface::TcnMem,
    FaultSurface::WeightMem,
    FaultSurface::DmaStream,
    FaultSurface::Snapshot,
];

fn source_for(net: &Network, s: usize) -> DvsSource {
    DvsSource::new(net.input_hw, 100 + s as u64, GestureClass(s % 12))
}

fn assert_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault summary");
}

/// Serve `frames` frames of stream `s` alone; `plan` arms injection.
fn serve_with_plan(
    net: &Network,
    mode: SimMode,
    workers: usize,
    s: usize,
    frames: usize,
    plan: Option<FaultPlan>,
) -> ServingReport {
    let cfg = EngineConfig { mode, workers, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.open_session(s).unwrap();
    if let Some(p) = plan {
        engine.set_fault_plan(s, p).unwrap();
    }
    let mut src = source_for(net, s);
    for _ in 0..frames {
        engine.submit(s, src.next_frame()).unwrap();
    }
    engine.drain().unwrap();
    engine.finish_session(s).unwrap()
}

#[test]
fn zero_ber_plan_serves_bit_exactly() {
    // The acceptance gate for the injection plumbing itself: a FaultPlan
    // with BER = 0 must draw zero random numbers and serve byte-for-byte
    // identically to a fault-free engine — labels, every metrics field's
    // f64 bits, latency quantiles — on every surface, in both sim modes,
    // serial and pooled.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 4;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1usize, 3] {
            let mut clean = serve_with_plan(&net, mode, workers, 0, frames, None);
            assert!(!clean.faults.any(), "fault-free run must report Default faults");
            for surface in SURFACES {
                let plan = FaultPlan::with_ber(surface, 0.0, 99);
                let mut armed = serve_with_plan(&net, mode, workers, 0, frames, Some(plan));
                assert_identical(
                    &mut armed,
                    &mut clean,
                    &format!("{mode:?} workers={workers} {surface}: zero-BER"),
                );
            }
        }
    }
}

#[test]
fn injected_session_cannot_perturb_clean_neighbors() {
    // The isolation gate: interleave three sessions through one engine,
    // injecting only the middle one. The clean sessions must stay
    // byte-identical to a fault-free solo run while their neighbor
    // degrades — faults are a per-session property, not an engine one.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 6;
    for workers in [1usize, 3] {
        let mut solo: Vec<ServingReport> = (0..3)
            .map(|s| serve_with_plan(&net, SimMode::Fast, 1, s, frames, None))
            .collect();

        let cfg = EngineConfig { mode: SimMode::Fast, workers, ..Default::default() };
        let mut engine = Engine::new(&net, cfg).unwrap();
        for s in 0..3 {
            engine.open_session(s).unwrap();
        }
        engine.set_fault_plan(1, FaultPlan::with_ber(FaultSurface::ActMem, 1e-2, 7)).unwrap();
        let mut srcs: Vec<DvsSource> = (0..3).map(|s| source_for(&net, s)).collect();
        for f in 0..frames {
            for (s, src) in srcs.iter_mut().enumerate() {
                engine.submit(s, src.next_frame()).unwrap();
            }
            if f % 2 == 0 {
                engine.drain().unwrap();
            }
        }
        engine.drain().unwrap();

        let agg = engine.aggregate_report();
        let reports = engine.finish_all();
        for (s, mut rep) in reports {
            if s == 1 {
                assert!(rep.faults.injected_flips > 0, "injected session must see flips");
                assert!(rep.faults.degraded_frames > 0, "hit frames are marked degraded");
                assert!(rep.faults.degraded_frames <= frames as u64);
                assert!(rep.faults.detected > 0, "scrub must catch orphaned pos bits");
                assert_eq!(rep.labels.len(), frames, "degraded frames still serve labels");
            } else {
                assert_identical(
                    &mut rep,
                    &mut solo[s],
                    &format!("workers={workers} clean session {s} next to injected neighbor"),
                );
            }
        }
        assert!(agg.faults.injected_flips > 0, "aggregate must carry the session summary");
    }
}

#[test]
fn weight_faults_are_repaired_transparently() {
    // WeightMem faults model parity-caught SRAM corruption: the engine
    // re-adopts the affected layers from the immutable shared image, so
    // labels match the fault-free run exactly while the report shows the
    // detection and the repair traffic (which costs scrub energy).
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 5;
    let clean = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, None);
    let plan = FaultPlan::with_ber(FaultSurface::WeightMem, 1e-3, 11);
    let faulty = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, Some(plan));

    assert_eq!(faulty.labels, clean.labels, "weight repair must be label-transparent");
    assert!(faulty.faults.injected_flips > 0, "1e-3 over the whole image must hit");
    assert_eq!(
        faulty.faults.detected, faulty.faults.injected_flips,
        "every weight flip is parity-detected"
    );
    assert!(faulty.faults.repair_words > 0, "repair re-adopts whole layers");
    assert!(
        faulty.faults.scrub_words >= faulty.faults.repair_words,
        "a parity hit scans the whole resident image"
    );
    assert_eq!(faulty.faults.degraded_frames, 0, "repaired frames are not degraded");
    assert!(
        faulty.metrics.core_energy_j > clean.metrics.core_energy_j,
        "scrub + repair traffic must cost energy"
    );
    // sanity: the clean comparison fields other than energy still line up
    assert_eq!(faulty.metrics.frames, clean.metrics.frames);
}

#[test]
fn tcn_and_dma_surfaces_detect_and_degrade() {
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 6;
    for (surface, ber) in [(FaultSurface::TcnMem, 0.05), (FaultSurface::DmaStream, 1e-2)] {
        let plan = FaultPlan::with_ber(surface, ber, 13);
        let rep = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, Some(plan));
        assert!(rep.faults.injected_flips > 0, "{surface}: flips at BER {ber}");
        assert!(rep.faults.degraded_frames > 0, "{surface}: corrupted frames are degraded");
        assert!(rep.faults.detected > 0, "{surface}: orphaned pos bits must be caught");
        assert_eq!(rep.labels.len(), frames, "{surface}: degraded frames still serve");
        assert_eq!(rep.faults.failures, 0, "{surface}: degradation is not failure");
    }
}

#[test]
fn failing_session_is_quarantined_not_fatal() {
    // A session whose frames error terminally (here: bound to a net
    // whose declared input overflows the activation SRAM, so every
    // shape-valid frame dies in the CNN) must trip the failure limit
    // and be quarantined — later frames dropped unserved — while the
    // engine keeps serving a healthy co-session and drain() never
    // errors. Shape-INVALID frames never get that far: submit refuses
    // them with a typed error and enqueues nothing.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let mut big = net.clone();
    big.name = "dvs_big".to_string();
    big.input_hw = 256;
    let mut reg = NetRegistry::single(net.clone()).unwrap();
    let fp_big = reg.add(big).unwrap();
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::with_registry(Arc::new(reg), cfg).unwrap();
    engine.open_session_on(0, fp_big).unwrap();
    engine.open_session(1).unwrap();
    let mut src = source_for(&net, 1);

    // a frame that disagrees with the binding is refused untouched
    let err = engine.submit(0, PackedMap::zeros(16, 16, 2)).unwrap_err();
    assert!(matches!(err, BindingError::FrameShape { session: 0, .. }), "got {err}");

    // FAILURE_LIMIT bad frames trip the quarantine...
    for _ in 0..FAILURE_LIMIT {
        engine.submit(0, PackedMap::zeros(256, 256, 2)).unwrap();
        engine.submit(1, src.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    assert!(engine.session(0).unwrap().is_quarantined());
    // ...and everything submitted afterwards is dropped unserved.
    for _ in 0..3 {
        engine.submit(0, PackedMap::zeros(256, 256, 2)).unwrap();
        engine.submit(1, src.next_frame()).unwrap();
    }
    engine.drain().unwrap();

    let bad = engine.finish_session(0).unwrap();
    assert_eq!(bad.faults.failures, FAILURE_LIMIT, "terminal errors counted");
    assert_eq!(bad.faults.quarantined, 1);
    assert_eq!(bad.faults.dropped_frames, 3, "post-quarantine frames dropped");
    assert!(bad.labels.is_empty(), "no label was ever produced");
    assert_eq!(bad.metrics.frames, 0, "failed frames never reach the metrics ledger");

    let good = engine.finish_session(1).unwrap();
    assert!(!good.faults.any(), "healthy co-session unaffected");
    assert_eq!(good.labels.len(), FAILURE_LIMIT as usize + 3);
}

#[test]
fn fault_plans_are_per_session_and_reseeded() {
    // Two sessions armed with the SAME plan draw different per-session
    // injection streams (the seed is mixed with the session id), and the
    // plan is queryable back from the engine.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    let plan = FaultPlan::with_ber(FaultSurface::ActMem, 5e-3, 21);
    engine.set_fault_plan(4, plan).unwrap();
    engine.set_fault_plan(9, plan).unwrap();
    assert_eq!(engine.fault_plan(4), Some(plan));
    assert_eq!(engine.fault_plan(9), Some(plan));
    assert_eq!(engine.fault_plan(5), None);

    // identical frames, identical plan — only the session id differs
    let mut src = source_for(&net, 0);
    for _ in 0..8 {
        let f = src.next_frame();
        engine.submit(4, f.clone()).unwrap();
        engine.submit(9, f).unwrap();
    }
    engine.drain().unwrap();
    let a = engine.finish_session(4).unwrap().faults;
    let b = engine.finish_session(9).unwrap().faults;
    assert!(a.injected_flips > 0 && b.injected_flips > 0);
    assert_ne!(a, b, "per-session seed mixing must decorrelate the streams");
}

#[test]
fn voltage_scaled_plan_follows_the_ber_model() {
    // FaultPlan::at_voltage ties the injector to the BER curve: at the
    // nominal 0.5 V the plan is structurally inert; down at 0.40 V it
    // must inject, and the report's accuracy visibly degrades relative
    // to fault-free (same frames, same seeds).
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 8;
    let mut clean = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, None);

    let nominal = FaultPlan::at_voltage(FaultSurface::ActMem, 0.5, 3);
    assert!(!nominal.is_active(), "0.5 V is in the validated range");
    let mut at_nominal = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, Some(nominal));
    assert_identical(&mut at_nominal, &mut clean, "0.5 V plan");

    let scaled = FaultPlan::at_voltage(FaultSurface::ActMem, 0.40, 3);
    assert!(scaled.is_active() && scaled.ber >= 1e-4, "0.40 V sits on the steep BER slope");
    let low = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, Some(scaled));
    assert!(low.faults.injected_flips > 0);
    assert_eq!(low.faults, {
        let again = serve_with_plan(&net, SimMode::Fast, 1, 0, frames, Some(scaled));
        again.faults
    });
}
