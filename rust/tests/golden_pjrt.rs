//! Golden-model co-simulation over PJRT: simulator vs XLA execution of
//! the AOT artifacts, including the Pallas-lowered first-layer kernel.

use tcn_cutie::cutie::{CutieConfig, SimMode};
use tcn_cutie::network::{loader, reference};
use tcn_cutie::runtime::{golden, to_trits, Runtime};
use tcn_cutie::tensor::TritTensor;
use tcn_cutie::util::rng::Rng;

fn artifacts() -> std::path::PathBuf {
    loader::artifacts_dir()
}

fn have(name: &str) -> bool {
    artifacts().join(name).exists()
}

#[test]
fn cifar_full_net_golden() {
    if !have("cifar9_96.hlo.txt") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(artifacts().join("cifar9_96.hlo.txt")).unwrap();
    let net = loader::load_network(artifacts().join("cifar9_96.json")).unwrap();
    let mut rng = Rng::new(404);
    for i in 0..3 {
        let input = TritTensor::random(&[32, 32, 3], &mut rng, [0.2, 0.5, 0.8][i]);
        let check = golden::check_feedforward(&rt, &model, &net, &input).unwrap();
        assert!(
            check.matched,
            "sim {:?} != xla {:?}",
            check.sim_logits, check.xla_logits
        );
    }
}

#[test]
fn pallas_first_layer_golden() {
    // The interpret-mode Pallas kernel, lowered to HLO, loaded by PJRT,
    // vs the cycle-level datapath on the same layer.
    if !have("cifar9_96_l1_pallas.hlo.txt") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(artifacts().join("cifar9_96_l1_pallas.hlo.txt")).unwrap();
    let net = loader::load_network(artifacts().join("cifar9_96.json")).unwrap();
    let layer = &net.layers[0];
    let mut rng = Rng::new(405);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);

    let xla_out = to_trits(&model.run_trits(&input).unwrap()).unwrap();

    let cfg = CutieConfig::kraken();
    let sim =
        tcn_cutie::cutie::datapath::run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
    assert_eq!(sim.output.unpack_data(), xla_out, "pallas kernel vs datapath");

    let refo = reference::run_conv_layer(layer, &input);
    assert_eq!(refo.data, xla_out, "pallas kernel vs reference executor");
}

#[test]
fn dvs_hybrid_golden() {
    if !have("dvs_hybrid_96_cnn.hlo.txt") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let cnn = rt.load(artifacts().join("dvs_hybrid_96_cnn.hlo.txt")).unwrap();
    let tcn = rt.load(artifacts().join("dvs_hybrid_96_tcn.hlo.txt")).unwrap();
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();
    let mut rng = Rng::new(406);
    let frames = TritTensor::random(&[5, 64, 64, 2], &mut rng, 0.85);
    let check = golden::check_hybrid(&cnn, &tcn, &net, &frames).unwrap();
    assert!(
        check.matched,
        "sim {:?} != xla {:?}",
        check.sim_logits, check.xla_logits
    );
}

#[test]
fn trained_mini_net_golden() {
    if !have("cifar9_mini.hlo.txt") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(artifacts().join("cifar9_mini.hlo.txt")).unwrap();
    let net = loader::load_network(artifacts().join("cifar9_mini.json")).unwrap();
    let mut rng = Rng::new(407);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.4);
    let check = golden::check_feedforward(&rt, &model, &net, &input).unwrap();
    assert!(check.matched);
}
