//! Integration tests of the sharded serving fleet: live migration over
//! the snapshot path must be byte-identical to never migrating (labels,
//! fc_wakeups, every energy ledger's f64 bits, latency quantiles — in
//! both sim modes, serial and pooled, clean and mid-fault-plan),
//! interleaving sessions across K engines must match serving each
//! alone, back-pressure must be a typed refusal that leaves no partial
//! state, and routing/drain policies must be deterministic while never
//! bending per-session frame order.

use tcn_cutie::coordinator::{
    DrainOrder, DvsSource, Engine, EngineConfig, Fleet, FleetConfig, FleetError, GestureClass,
    ServingReport, SessionStore, ShardPolicy,
};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::fault::{FaultPlan, FaultSurface};
use tcn_cutie::network::{dvs_hybrid_random, Network};

fn source_for(net: &Network, s: usize) -> DvsSource {
    DvsSource::new(net.input_hw, 100 + s as u64, GestureClass(s % 12))
}

fn assert_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}: soc power");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault summary");
}

/// The single-engine oracle: serve `frames` frames of stream `s`,
/// always resident, draining per frame.
fn serve_resident(
    net: &Network,
    mode: SimMode,
    workers: usize,
    s: usize,
    frames: usize,
    plan: Option<FaultPlan>,
) -> ServingReport {
    let cfg = EngineConfig { mode, workers, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.open_session(s).unwrap();
    if let Some(p) = plan {
        engine.set_fault_plan(s, p).unwrap();
    }
    let mut src = source_for(net, s);
    for _ in 0..frames {
        engine.submit(s, src.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    engine.finish_session(s).unwrap()
}

/// Serve `sessions` interleaved streams through a fleet of `engines`,
/// one frame per stream per round; every `migrate_every` rounds, every
/// session live-migrates to the next engine. Returns the per-session
/// reports plus the migration count.
#[allow(clippy::too_many_arguments)]
fn serve_fleet(
    net: &Network,
    mode: SimMode,
    workers: usize,
    sessions: usize,
    engines: usize,
    frames: usize,
    plan: Option<FaultPlan>,
    migrate_every: Option<usize>,
) -> (Vec<(usize, ServingReport)>, u64) {
    let cfg = FleetConfig {
        engines,
        engine: EngineConfig { mode, workers, ..Default::default() },
        ..Default::default()
    };
    let mut fleet = Fleet::new(net, cfg).unwrap();
    for sid in 0..sessions {
        fleet.open_session(sid).unwrap();
        if let Some(p) = plan {
            fleet.set_fault_plan(sid, p).unwrap();
        }
    }
    let mut srcs: Vec<DvsSource> = (0..sessions).map(|s| source_for(net, s)).collect();
    for round in 0..frames {
        for (sid, src) in srcs.iter_mut().enumerate() {
            fleet.submit(sid, src.next_frame()).unwrap();
        }
        fleet.drain().unwrap();
        if let Some(k) = migrate_every {
            if (round + 1) % k == 0 {
                for sid in 0..sessions {
                    let from = fleet.route(sid).unwrap();
                    fleet.migrate(sid, (from + 1) % engines).unwrap();
                }
            }
        }
    }
    let migrations = fleet.report().migrations;
    (fleet.finish_all(), migrations)
}

#[test]
fn migrated_sessions_serve_byte_identically() {
    // The tentpole acceptance gate: a session that live-migrates
    // mid-stream — including mid-fault-plan, the injector's RNG
    // position rides in the snapshot — must close with a report
    // byte-identical to one that never left its first engine.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 6;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1usize, 3] {
            for plan in [None, Some(FaultPlan::with_ber(FaultSurface::TcnMem, 0.05, 13))] {
                let armed = plan.is_some();
                let (reports, migrations) =
                    serve_fleet(&net, mode, workers, 2, 2, frames, plan, Some(2));
                assert!(migrations > 0, "the schedule must actually migrate");
                assert_eq!(reports.len(), 2);
                for (sid, mut rep) in reports {
                    if armed {
                        assert!(rep.faults.injected_flips > 0, "plan must actually draw");
                    }
                    let mut resident = serve_resident(&net, mode, workers, sid, frames, plan);
                    assert_identical(
                        &mut rep,
                        &mut resident,
                        &format!("session {sid} {mode:?} workers={workers} armed={armed}"),
                    );
                }
            }
        }
    }
}

#[test]
fn interleaved_fleet_matches_isolated_per_session_on_k_engines() {
    // Sharding is invisible per session: 5 streams interleaved across K
    // engines close byte-identical to each stream served alone.
    let net = dvs_hybrid_random(16, 5, 0.5);
    for engines in [2usize, 4] {
        let (reports, _) = serve_fleet(&net, SimMode::Fast, 1, 5, engines, 3, None, None);
        assert_eq!(reports.len(), 5);
        for (sid, mut rep) in reports {
            let mut solo = serve_resident(&net, SimMode::Fast, 1, sid, 3, None);
            assert_identical(&mut rep, &mut solo, &format!("{engines} engines, session {sid}"));
        }
    }
}

#[test]
fn fleet_aggregate_is_engine_count_invariant() {
    // The merged FleetReport folds sessions in global id order through
    // the same accumulator a single engine uses, so the aggregate —
    // f64 ledger bits included — does not depend on the engine count or
    // the migration history.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let run = |engines: usize, migrate: Option<usize>| {
        let cfg = FleetConfig {
            engines,
            engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
            ..Default::default()
        };
        let mut fleet = Fleet::new(&net, cfg).unwrap();
        for sid in 0..4 {
            fleet.open_session(sid).unwrap();
        }
        let mut srcs: Vec<DvsSource> = (0..4).map(|s| source_for(&net, s)).collect();
        for round in 0..4 {
            for (sid, src) in srcs.iter_mut().enumerate() {
                fleet.submit(sid, src.next_frame()).unwrap();
            }
            fleet.drain().unwrap();
            if let Some(k) = migrate {
                if (round + 1) % k == 0 {
                    let sid = round % 4;
                    let from = fleet.route(sid).unwrap();
                    fleet.migrate(sid, (from + 1) % engines).unwrap();
                }
            }
        }
        fleet.aggregate_report()
    };
    let mut one = run(1, None);
    for engines in [2usize, 4] {
        let mut many = run(engines, Some(2));
        assert_identical(&mut many, &mut one, &format!("{engines}-engine aggregate"));
        assert_eq!(many.labels, one.labels, "labels fold in global session-id order");
    }
}

#[test]
fn backpressure_is_typed_and_leaves_no_partial_state() {
    // A full submit queue refuses with FleetError::Backpressure and
    // hands the frame back untouched; drain-and-retry must then serve
    // byte-identically to a run that never saw back-pressure — with an
    // armed fault plan, so a leaked injector draw would be caught.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let plan = FaultPlan::with_ber(FaultSurface::ActMem, 0.05, 21);
    let serve = |cap: usize| {
        let cfg = FleetConfig {
            engines: 2,
            queue_cap: cap,
            engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
            ..Default::default()
        };
        let mut fleet = Fleet::new(&net, cfg).unwrap();
        for sid in 0..3 {
            fleet.open_session(sid).unwrap();
            fleet.set_fault_plan(sid, plan).unwrap();
        }
        let mut srcs: Vec<DvsSource> = (0..3).map(|s| source_for(&net, s)).collect();
        let mut rejections = 0u64;
        for _ in 0..4 {
            for (sid, src) in srcs.iter_mut().enumerate() {
                let mut frame = src.next_frame();
                loop {
                    match fleet.submit(sid, frame) {
                        Ok(()) => break,
                        Err(rej) => {
                            let FleetError::Backpressure { engine, depth, cap: c } = rej.reason
                            else {
                                panic!("unexpected refusal: {}", rej.reason);
                            };
                            assert!(engine < 2, "refusal names a real engine");
                            assert_eq!(c, cap);
                            assert_eq!(depth, cap, "refused exactly at the bound");
                            rejections += 1;
                            fleet.drain().unwrap();
                            frame = rej.frame; // the frame came back untouched
                        }
                    }
                }
            }
            fleet.drain().unwrap();
        }
        assert_eq!(fleet.report().rejected_submits, rejections);
        (fleet.finish_all(), rejections)
    };
    let (squeezed, rejections) = serve(1);
    let (roomy, zero) = serve(64);
    assert!(rejections > 0, "cap 1 with 3 streams on 2 engines must back-pressure");
    assert_eq!(zero, 0, "cap 64 never fills at 3 frames per round");
    for ((sid_a, mut a), (sid_b, mut b)) in squeezed.into_iter().zip(roomy) {
        assert_eq!(sid_a, sid_b);
        assert!(a.faults.injected_flips > 0, "the plan must draw in both runs");
        assert_identical(&mut a, &mut b, &format!("session {sid_a} across back-pressure"));
    }
}

#[test]
fn shard_policies_route_deterministically() {
    let net = dvs_hybrid_random(16, 5, 0.5);
    let mk = |policy: ShardPolicy, engines: usize| {
        let cfg = FleetConfig {
            engines,
            policy,
            engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
            ..Default::default()
        };
        Fleet::new(&net, cfg).unwrap()
    };

    // hash: pure in the session id — two fleets agree on every route
    let mut a = mk(ShardPolicy::Hash, 3);
    let mut b = mk(ShardPolicy::Hash, 3);
    for sid in 0..12 {
        a.open_session(sid).unwrap();
        b.open_session(sid).unwrap();
        assert_eq!(a.route(sid), b.route(sid), "hash routing is reproducible");
        assert!(a.route(sid).unwrap() < 3);
    }

    // least-loaded: 12 sequential arrivals on 3 engines balance 4/4/4
    let mut ll = mk(ShardPolicy::LeastLoaded, 3);
    for sid in 0..12 {
        ll.open_session(sid).unwrap();
    }
    let rep = ll.report();
    let loads: Vec<usize> = rep.engines.iter().map(|e| e.routed_sessions).collect();
    assert_eq!(loads, vec![4, 4, 4]);

    // pin: nothing routes implicitly, and a committed route refuses a
    // conflicting repin (migrate moves state; a pin would not)
    let mut pinned = mk(ShardPolicy::Pin, 3);
    match pinned.open_session(7) {
        Err(FleetError::Unpinned { session: 7 }) => {}
        other => panic!("expected Unpinned, got {:?}", other.map(|_| ())),
    }
    pinned.pin_session(7, 2).unwrap();
    pinned.open_session(7).unwrap();
    assert_eq!(pinned.route(7), Some(2));
    match pinned.pin_session(7, 0) {
        Err(FleetError::AlreadyRouted { session: 7, engine: 2 }) => {}
        other => panic!("expected AlreadyRouted, got {other:?}"),
    }
    match pinned.pin_session(8, 9) {
        Err(FleetError::UnknownEngine { engine: 9, engines: 3 }) => {}
        other => panic!("expected UnknownEngine, got {other:?}"),
    }
}

#[test]
fn drain_orders_preserve_per_session_order_and_reports() {
    // Deadline/energy ordering may reorder ACROSS sessions (observable
    // via drain_plan) but every session's own frame sequence — and
    // therefore its report, bit for bit — is untouched.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let serve = |order: DrainOrder, probe: bool| {
        let cfg = FleetConfig {
            engines: 1,
            order,
            engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
            ..Default::default()
        };
        let mut fleet = Fleet::new(&net, cfg).unwrap();
        for sid in 0..3 {
            fleet.open_session(sid).unwrap();
        }
        fleet.set_deadline_slack(2, 0);
        fleet.set_deadline_slack(1, 10);
        let mut srcs: Vec<DvsSource> = (0..3).map(|s| source_for(&net, s)).collect();
        let mut first_plan = None;
        for _ in 0..3 {
            for (sid, src) in srcs.iter_mut().enumerate() {
                fleet.submit(sid, src.next_frame()).unwrap();
            }
            if probe && first_plan.is_none() {
                first_plan = Some(fleet.drain_plan(0));
            }
            fleet.drain().unwrap();
        }
        (first_plan, fleet.finish_all())
    };
    let (dl_plan, dl) = serve(DrainOrder::Deadline, true);
    assert_eq!(dl_plan.unwrap(), vec![2, 1, 0], "tightest deadline first, unset slack last");
    let (fifo_plan, fifo) = serve(DrainOrder::Fifo, true);
    assert_eq!(fifo_plan.unwrap(), vec![0, 1, 2], "fifo keeps submission order");
    let (_, energy) = serve(DrainOrder::Energy, false);
    for (((sid, mut f), (_, mut d)), (_, mut e)) in fifo.into_iter().zip(dl).zip(energy) {
        assert_identical(&mut d, &mut f, &format!("deadline vs fifo, session {sid}"));
        assert_identical(&mut e, &mut f, &format!("energy vs fifo, session {sid}"));
    }
}

#[test]
fn hibernated_sessions_migrate_and_finish_cleanly() {
    // A session parked in its home engine's snapshot store migrates
    // straight out of the store onto the target (resume → re-capture →
    // import), keeps serving there, and still closes byte-identical to
    // an unbroken resident run.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = FleetConfig {
        engines: 2,
        engine: EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
        ..Default::default()
    };
    let mut fleet = Fleet::new(&net, cfg).unwrap();
    for e in 0..2 {
        fleet.engine_mut(e).unwrap().enable_hibernation(SessionStore::in_memory(), None);
    }
    fleet.open_session(0).unwrap();
    let home = fleet.route(0).unwrap();
    let mut src = source_for(&net, 0);
    for _ in 0..2 {
        fleet.submit(0, src.next_frame()).unwrap();
        fleet.drain().unwrap();
    }
    fleet.engine_mut(home).unwrap().hibernate(0).unwrap();
    assert!(fleet.engine(home).unwrap().store().unwrap().contains(0));
    let target = (home + 1) % 2;
    fleet.migrate(0, target).unwrap();
    assert!(fleet.engine(target).unwrap().session(0).is_some(), "resident on the target");
    assert!(!fleet.engine(home).unwrap().store().unwrap().contains(0), "record moved out");
    assert_eq!(fleet.route(0), Some(target));
    for _ in 0..2 {
        fleet.submit(0, src.next_frame()).unwrap();
        fleet.drain().unwrap();
    }
    let mut rep = fleet.finish_session(0).unwrap();
    assert_eq!(rep.hib.hibernates, 1);
    assert_eq!(rep.hib.resumes, 1);
    let mut resident = serve_resident(&net, SimMode::Fast, 1, 0, 4, None);
    assert_identical(&mut rep, &mut resident, "hibernated then migrated session");
}
