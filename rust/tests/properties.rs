//! Property tests (seeded randomized sweeps — proptest is unavailable in
//! this offline environment, same coverage intent): simulator/reference
//! equivalence across random geometries, scheduler state invariants, and
//! energy-model monotonicity laws.

use tcn_cutie::cutie::{CutieConfig, Scheduler, SimMode, TcnStrategy};
use tcn_cutie::energy::{evaluate, fmax_hz, EnergyParams};
use tcn_cutie::network::{self, reference, LayerKind};
use tcn_cutie::tensor::{PackedMap, TritTensor};
use tcn_cutie::util::rng::Rng;

/// Random small hybrid networks: cycle-level simulator must equal the
/// functional reference executor bit-for-bit, for any geometry/sparsity.
#[test]
fn simulator_equals_reference_random_networks() {
    let mut rng = Rng::new(2024);
    for case in 0..10 {
        let ch = [8, 16, 24][case % 3];
        let zf = [0.1, 0.5, 0.8][case % 3];
        let net = network::cifar9_random(ch, 3000 + case as u64, zf);
        let input_zf = rng.f64() * 0.8;
        let input = TritTensor::random(&[32, 32, 3], &mut rng, input_zf);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (sim, _) = sched.run_full(&net, &input).unwrap();
        let want = reference::forward(&net, &input).unwrap();
        assert_eq!(sim, want, "case {case}");
    }
}

/// Mapped and direct TCN strategies must agree on every random stream
/// (§4: the mapping is exactly equivalent).
#[test]
fn tcn_strategies_equivalent_random_streams() {
    let mut rng = Rng::new(77);
    for case in 0..5 {
        let net = network::dvs_hybrid_random(16, 4000 + case, 0.5);
        let mut a = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        let mut b = Scheduler::new(CutieConfig::kraken(), SimMode::Fast)
            .with_tcn_strategy(TcnStrategy::Direct);
        for _ in 0..5 {
            let zf = 0.7 + 0.25 * rng.f64();
            let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, zf));
            let (la, _) = a.serve_frame(&net, &f).unwrap();
            let (lb, _) = b.serve_frame(&net, &f).unwrap();
            assert_eq!(la, lb);
        }
    }
}

/// Scheduler state invariants across a served stream: TCN occupancy is
/// min(frames, depth); weight loads only on first touch; stall-free.
#[test]
fn scheduler_state_invariants() {
    let net = network::dvs_hybrid_random(16, 9, 0.5);
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    let mut rng = Rng::new(5);
    let mut total_weight_cycles = Vec::new();
    for i in 0..30 {
        let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
        let (_, stats) = sched.serve_frame(&net, &f).unwrap();
        assert_eq!(sched.tcn_mem.len(), (i + 1).min(24));
        assert_eq!(stats.stall_cycles(), 0);
        total_weight_cycles
            .push(stats.layers.iter().map(|l| l.weight_load_cycles).sum::<u64>());
        // conservation: every layer's activity is bounded by its clocked
        // positions
        for l in &stats.layers {
            let clocked = (l.active_ocus * 96 * 9) as u64 * l.compute_cycles;
            assert!(l.mac_toggles + l.mac_idle == clocked || l.compute_cycles == 0);
        }
    }
    // steady state: bank switches only (1 cycle per non-dense layer)
    let steady = *total_weight_cycles.last().unwrap();
    let n_switchable = net.layers.iter().filter(|l| l.kind != LayerKind::Dense).count() as u64;
    assert_eq!(steady, n_switchable);
    assert!(total_weight_cycles[0] > steady, "first frame streams weights");
}

/// Energy model laws: monotone in voltage (energy up, efficiency down),
/// monotone in activity, breakdown always sums to total.
#[test]
fn energy_model_monotonicity() {
    let mut rng = Rng::new(6);
    let p = EnergyParams::default();
    for case in 0..6 {
        let net = network::cifar9_random(32, 5000 + case, 0.2 + 0.1 * case as f64);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (_, stats) = sched.run_full(&net, &input).unwrap();
        let mut last_e = 0.0;
        let mut last_eff = f64::INFINITY;
        for v in [0.5, 0.6, 0.7, 0.8, 0.9] {
            let r = evaluate(&stats, v, None, &p).unwrap();
            assert!(r.energy_j > last_e, "energy must rise with V");
            assert!(r.avg_tops_per_watt < last_eff, "efficiency must fall with V");
            assert!((r.breakdown.total() - r.energy_j).abs() < 1e-15);
            assert!(r.freq_hz == fmax_hz(v).unwrap());
            last_e = r.energy_j;
            last_eff = r.avg_tops_per_watt;
        }
    }
}

/// Sparser inputs can only reduce toggling (monotone activity law).
#[test]
fn toggles_monotone_in_sparsity() {
    let mut last = u64::MAX;
    for zf in [0.0, 0.3, 0.6, 0.9] {
        let net = network::cifar9_random(32, 42, zf);
        let mut rng = Rng::new(7);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, zf);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (_, stats) = sched.run_full(&net, &input).unwrap();
        assert!(stats.mac_toggles() < last, "toggles must fall with sparsity");
        last = stats.mac_toggles();
    }
}

/// Cycle counts are input-independent (the datapath is fully unrolled,
/// one pixel per cycle regardless of data) — the paper's constant-time
/// inference property.
#[test]
fn cycles_input_independent() {
    let net = network::cifar9_random(48, 11, 0.33);
    let mut cycles = None;
    let mut rng = Rng::new(8);
    for zf in [0.0, 0.5, 0.95] {
        let input = TritTensor::random(&[32, 32, 3], &mut rng, zf);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        sched.preload_weights(&net);
        let (_, stats) = sched.run_full(&net, &input).unwrap();
        match cycles {
            None => cycles = Some(stats.total_cycles()),
            Some(c) => assert_eq!(stats.total_cycles(), c, "constant-time inference"),
        }
    }
}

/// hw-ops accounting: total hw_ops equals Σ active_ocus·K²·C·2·cycles.
#[test]
fn hw_ops_accounting_consistent() {
    let net = network::cifar9_random(96, 13, 0.33);
    let mut rng = Rng::new(9);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    let (_, stats) = sched.run_full(&net, &input).unwrap();
    for l in &stats.layers {
        assert_eq!(l.hw_ops, (l.active_ocus * 9 * 96 * 2) as u64 * l.compute_cycles);
    }
}
