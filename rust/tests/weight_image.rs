//! Integration tests of the packed weight image (shared-image pass):
//! `.ttn` v1 ⇄ v2 bit-exact round-trips through real artifacts on disk,
//! word-copy boot equivalence down to every LayerStats counter and
//! energy f64 bit in both sim modes, and hostile-input hardening of the
//! full-file parse path.

use std::sync::Arc;

use tcn_cutie::coordinator::{DvsSource, GestureClass};
use tcn_cutie::cutie::{CutieConfig, PreparedNet, Scheduler, SimMode};
use tcn_cutie::energy::{evaluate, EnergyParams};
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random, loader};
use tcn_cutie::tensor::{ttn, TritTensor};
use tcn_cutie::util::rng::Rng;

#[test]
fn v1_v2_roundtrip_is_bit_exact_for_real_artifacts() {
    let dir = std::env::temp_dir().join("tcn_cutie_wimg_roundtrip");
    let cfg = CutieConfig::kraken();
    for (stem, net) in [
        ("dvs", dvs_hybrid_random(16, 41, 0.5)),
        ("cifar", cifar9_random(24, 42, 0.33)),
    ] {
        let (manifest, weights) = loader::save_network(&dir, stem, &net).unwrap();
        let v1 = std::fs::read(&weights).unwrap();

        // pack: v1 bytes verbatim + image section
        let prepared = PreparedNet::new(&net, &cfg);
        let v2 = ttn::upgrade_bytes(&v1, &prepared.to_image()).unwrap();
        assert_eq!(ttn::strip_bytes(&v2).unwrap(), v1, "{stem}: strip must invert upgrade");

        // the packed artifact loads transparently through the manifest
        std::fs::write(&weights, &v2).unwrap();
        let (net_back, image) = loader::load_network_full(&manifest).unwrap();
        assert_eq!(net_back, net, "{stem}: the bundle half of v2 is the v1 content");
        let image = image.expect("v2 artifact must surface its weight image");
        let reloaded = PreparedNet::from_image(&image, &net, &cfg).unwrap();
        assert_eq!(reloaded, prepared, "{stem}: word-copy boot must equal the i8 build");
        assert_eq!(reloaded.fingerprint(), prepared.fingerprint());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn word_copy_boot_is_counter_and_energy_bit_identical() {
    // Serve the same stream from an i8-built scheduler and an
    // image-booted one: logits, every LayerStats counter (RunStats
    // PartialEq) and the energy model's f64 bits must agree, both modes.
    let net = dvs_hybrid_random(16, 43, 0.5);
    let kraken = CutieConfig::kraken();
    let built = PreparedNet::new(&net, &kraken);
    let v2 = ttn::write_bytes_v2(&loader::network_bundle(&net), &built.to_image());
    let (_, img) = ttn::read_bytes_full(&v2).unwrap();
    let loaded = Arc::new(PreparedNet::from_image(&img.unwrap(), &net, &kraken).unwrap());
    let params = EnergyParams::default();

    for mode in [SimMode::Fast, SimMode::Accurate] {
        let mut from_i8 = Scheduler::new(kraken.clone(), mode);
        from_i8.preload_weights(&net);
        let mut from_img = Scheduler::new(kraken.clone(), mode);
        from_img.attach_image(Arc::clone(&loaded));
        from_img.preload_weights(&net);

        let mut src = DvsSource::new(net.input_hw, 90, GestureClass(2));
        for frame in 0..5 {
            let f = src.next_frame();
            let (la, ra) = from_i8.serve_frame(&net, &f).unwrap();
            let (lb, rb) = from_img.serve_frame(&net, &f).unwrap();
            assert_eq!(la, lb, "{mode:?} frame {frame}: logits");
            assert_eq!(ra, rb, "{mode:?} frame {frame}: all LayerStats counters");
            let ea = evaluate(&ra, 0.5, None, &params).unwrap();
            let eb = evaluate(&rb, 0.5, None, &params).unwrap();
            assert_eq!(
                ea.energy_j.to_bits(),
                eb.energy_j.to_bits(),
                "{mode:?} frame {frame}: energy bits"
            );
            assert_eq!(ea.time_s.to_bits(), eb.time_s.to_bits());
        }
        assert!(
            Arc::ptr_eq(from_img.image().unwrap(), &loaded),
            "image-booted scheduler must keep serving from the loaded image"
        );
    }
}

#[test]
fn cifar_feedforward_boots_from_image_too() {
    // The non-TCN path (run_full's classifier branch) through the image.
    let net = cifar9_random(16, 44, 0.33);
    let kraken = CutieConfig::kraken();
    let built = PreparedNet::new(&net, &kraken);
    let loaded = Arc::new(
        PreparedNet::from_image(&built.to_image(), &net, &kraken).unwrap(),
    );
    let mut rng = Rng::new(45);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
    let mut a = Scheduler::new(kraken.clone(), SimMode::Accurate);
    let mut b = Scheduler::new(kraken.clone(), SimMode::Accurate);
    b.attach_image(loaded);
    let (la, ra) = a.run_full(&net, &input).unwrap();
    let (lb, rb) = b.run_full(&net, &input).unwrap();
    assert_eq!(la, lb);
    assert_eq!(ra, rb);
}

#[test]
fn hostile_inputs_error_cleanly_on_real_sized_files() {
    // The unit sweep in tensor/ttn.rs covers every truncation boundary
    // of a tiny file; this covers a realistic multi-layer artifact:
    // sampled truncations and random bit flips over both container
    // versions must yield proper errors (or a still-valid parse), never
    // a panic or an unbounded allocation.
    let net = dvs_hybrid_random(16, 46, 0.5);
    let v1 = ttn::write_bytes(&loader::network_bundle(&net));
    let image = PreparedNet::new(&net, &CutieConfig::kraken()).to_image();
    let v2 = ttn::upgrade_bytes(&v1, &image).unwrap();

    let mut rng = Rng::new(47);
    for bytes in [&v1, &v2] {
        // every strict prefix of a valid file is invalid
        for _ in 0..1500 {
            let cut = rng.below(bytes.len());
            assert!(ttn::read_bytes_full(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        for cut in (bytes.len().saturating_sub(40))..bytes.len() {
            assert!(ttn::read_bytes_full(&bytes[..cut]).is_err(), "tail cut at {cut}");
        }
        // bit flips: error or valid parse, never a panic
        for _ in 0..300 {
            let mut m = (*bytes).clone();
            let bit = rng.below(m.len() * 8);
            m[bit / 8] ^= 1 << (bit % 8);
            let _ = ttn::read_bytes_full(&m);
        }
    }

    // a flipped byte inside the image section can never smuggle an
    // invariant-violating word into a PreparedNet: from_image re-checks
    // geometry and thresholds against the network
    let mut tampered = image.clone();
    tampered.layers[0].lo[0] += 1;
    assert!(
        PreparedNet::from_image(&tampered, &net, &CutieConfig::kraken()).is_err(),
        "tampered thresholds must not boot"
    );
}
