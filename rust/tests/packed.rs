//! Property tests for the packed activation dataflow (perf pass
//! iteration 8): the new packed primitives — branchless vectorized
//! ternarize and bitwise packed max-pooling — against their scalar
//! references across channel widths straddling the 64-bit word
//! boundaries (c ∈ {1, 21, 63, 64, 65, 96, 128}) and sparsities up to
//! 0.95, plus whole-network packed-vs-i8 equivalence: labels, logits
//! and every LayerStats activity counter must be bit-identical between
//! the packed pipeline and the retained i8 window-stationary dataflow.
//! The EXPERIMENTS.md §Anchors workload (seeded `cifar9_random(96, 1,
//! 0.33)`, 0.3-sparse input — 45.14 M MAC toggles, 4 424 activation
//! words, 3 189 cycles) is pinned the same way, so the energy-model
//! calibration cannot drift under the representation change.

use tcn_cutie::cutie::datapath::{run_dense_layer, run_prepared_window, PreparedLayer};
use tcn_cutie::cutie::{CutieConfig, LayerStats, Scheduler, SimMode};
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random, reference, Network};
use tcn_cutie::tensor::{IntTensor, PackedMap, TritTensor};
use tcn_cutie::trit::{ternarize, ternarize_packed};
use tcn_cutie::util::rng::Rng;

const WIDTHS: [usize; 7] = [1, 21, 63, 64, 65, 96, 128];
const SPARSITIES: [f64; 4] = [0.0, 0.33, 0.66, 0.95];

#[test]
fn vectorized_ternarize_matches_scalar_across_word_boundaries() {
    let mut rng = Rng::new(8001);
    for &c in &WIDTHS {
        for case in 0..40 {
            // accumulators in a window around the thresholds, including
            // the empty-zero-region contract lo = hi + 1
            let acc: Vec<i32> = (0..c).map(|_| rng.below(61) as i32 - 30).collect();
            let (lo, hi): (Vec<i32>, Vec<i32>) = (0..c)
                .map(|_| {
                    let hi = rng.below(21) as i32 - 10;
                    let lo = hi + 1 - rng.below(20) as i32;
                    (lo, hi)
                })
                .unzip();
            let packed = ternarize_packed(&acc, &lo, &hi);
            for i in 0..c {
                assert_eq!(
                    packed.get(i),
                    ternarize(acc[i], lo[i], hi[i]),
                    "c={c} case={case} i={i}"
                );
            }
            // invariant the bitwise downstream ops rely on: pos ⊆ mask
            // and no stale bits above channel c
            assert_eq!(packed.unpack(c).len(), c);
            let repacked = tcn_cutie::trit::PackedVec::pack(&packed.unpack(c));
            assert_eq!(packed, repacked, "c={c} case={case}: bits above c must be clear");
        }
    }
}

#[test]
fn packed_maxpool_matches_scalar_across_word_boundaries() {
    let mut rng = Rng::new(8002);
    for &c in &WIDTHS {
        for (case, &zf) in SPARSITIES.iter().enumerate() {
            let h = 2 * (1 + rng.below(6));
            let w = 2 * (1 + rng.below(6));
            let t = TritTensor::random(&[h, w, c], &mut rng, zf);
            let m = PackedMap::from_trit(&t);
            let want = reference::maxpool2x2(&t);
            assert_eq!(m.maxpool2x2().to_trit(), want, "c={c} zf={zf} case={case}");
            let gwant = reference::global_maxpool(&t);
            assert_eq!(m.global_maxpool().unpack_data(), gwant.data, "c={c} zf={zf} global");
        }
    }
}

/// Run a cifar-style network through the retained i8 dataflow: i8 maps
/// between layers, window-stationary loop, scalar pooling — the
/// pre-iteration-8 pipeline, reconstructed layer by layer.
fn run_net_i8(
    net: &Network,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> (IntTensor, Vec<LayerStats>) {
    let mut x = input.clone();
    let mut layers = Vec::new();
    for layer in net.conv_layers() {
        let prep = PreparedLayer::new(layer);
        let r = run_prepared_window(&prep, &x, cfg, mode).unwrap();
        x = r.output;
        layers.push(r.stats);
    }
    let flat = TritTensor::from_vec(&[x.numel()], x.data.clone());
    let dense = net.layers.last().unwrap();
    let (logits, stats) = run_dense_layer(dense, &flat, cfg, mode).unwrap();
    layers.push(stats);
    (logits, layers)
}

/// Datapath-derived counters that must be representation-invariant.
/// (Weight-memory charges and TCN-port reads are scheduler bookkeeping
/// on top of the datapath and are excluded — the i8 chain below runs
/// the bare datapath.)
fn assert_layer_counters_equal(packed: &LayerStats, i8_stats: &LayerStats, ctx: &str) {
    assert_eq!(packed.name, i8_stats.name, "{ctx}: layer order");
    assert_eq!(packed.mac_toggles, i8_stats.mac_toggles, "{ctx}: mac_toggles");
    assert_eq!(packed.mac_idle, i8_stats.mac_idle, "{ctx}: mac_idle");
    assert_eq!(packed.compute_cycles, i8_stats.compute_cycles, "{ctx}: compute_cycles");
    assert_eq!(packed.lb_fill_cycles, i8_stats.lb_fill_cycles, "{ctx}: lb_fill_cycles");
    assert_eq!(packed.drain_cycles, i8_stats.drain_cycles, "{ctx}: drain_cycles");
    assert_eq!(packed.stall_cycles, i8_stats.stall_cycles, "{ctx}: stall_cycles");
    assert_eq!(packed.act_reads, i8_stats.act_reads, "{ctx}: act_reads");
    assert_eq!(packed.act_writes, i8_stats.act_writes, "{ctx}: act_writes");
    assert_eq!(packed.lb_pushes, i8_stats.lb_pushes, "{ctx}: lb_pushes");
    assert_eq!(packed.hw_ops, i8_stats.hw_ops, "{ctx}: hw_ops");
    assert_eq!(packed.alg_macs, i8_stats.alg_macs, "{ctx}: alg_macs");
    assert_eq!(packed.active_ocus, i8_stats.active_ocus, "{ctx}: active_ocus");
    assert_eq!(packed.fanin, i8_stats.fanin, "{ctx}: fanin");
}

/// Whole-network sweep: the packed scheduler pipeline vs the i8 datapath
/// chain — labels, logits and all per-layer activity counters.
#[test]
fn whole_net_packed_vs_i8_equivalence_sweep() {
    let mut rng = Rng::new(8003);
    for (case, &(ch, zf)) in
        [(16usize, 0.0), (24, 0.33), (32, 0.66), (16, 0.95)].iter().enumerate()
    {
        let net = cifar9_random(ch, 8100 + case as u64, zf);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, zf);
        let cfg = CutieConfig::kraken();
        for mode in [SimMode::Accurate, SimMode::Fast] {
            let mut sched = Scheduler::new(cfg.clone(), mode);
            let (packed_logits, packed_run) = sched.run_full(&net, &input).unwrap();
            let (i8_logits, i8_layers) = run_net_i8(&net, &input, &cfg, mode);
            let ctx = format!("ch={ch} zf={zf} mode={mode:?}");
            assert_eq!(packed_logits, i8_logits, "{ctx}: logits");
            assert_eq!(packed_logits.argmax(), i8_logits.argmax(), "{ctx}: label");
            assert_eq!(
                packed_logits,
                reference::forward(&net, &input).unwrap(),
                "{ctx}: reference executor"
            );
            assert_eq!(packed_run.layers.len(), i8_layers.len(), "{ctx}: layer count");
            for (p, w) in packed_run.layers.iter().zip(&i8_layers) {
                assert_layer_counters_equal(p, w, &format!("{ctx} layer {}", p.name));
            }
        }
    }
}

/// Hybrid (CNN→TCN) networks: the packed serving path must agree with
/// the functional reference executor on logits for high-sparsity
/// DVS-like streams (the TCN tail shares the packed conv datapath via
/// the §4 mapping).
#[test]
fn hybrid_packed_serving_matches_reference() {
    let net = dvs_hybrid_random(16, 8200, 0.5);
    let mut rng = Rng::new(8004);
    let input = TritTensor::random(&[6, 64, 64, 2], &mut rng, 0.9);
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
    let (logits, _) = sched.run_full(&net, &input).unwrap();
    let want = reference::forward(&net, &input).unwrap();
    assert_eq!(logits, want);
}

/// The EXPERIMENTS.md §Anchors workload, pinned: the packed pipeline's
/// activity counters on seeded `cifar9_random(96, 1, 0.33)` with the
/// canonical 0.3-sparse input must be bit-identical to the i8 dataflow's
/// — the counters the energy-model calibration (2.72 µJ @0.5 V,
/// 1036 TOp/s/W) is fitted against.
#[test]
fn anchor_workload_counters_bit_exact_vs_i8_path() {
    let (net, input) = tcn_cutie::report::cifar_workload();
    let cfg = CutieConfig::kraken();
    let mut sched = Scheduler::new(cfg.clone(), SimMode::Accurate);
    sched.preload_weights(&net);
    let (packed_logits, packed_run) = sched.run_full(&net, &input).unwrap();
    let (i8_logits, i8_layers) = run_net_i8(&net, &input, &cfg, SimMode::Accurate);

    assert_eq!(packed_logits, i8_logits, "anchor: logits");
    assert_eq!(packed_run.layers.len(), i8_layers.len());
    for (p, w) in packed_run.layers.iter().zip(&i8_layers) {
        assert_layer_counters_equal(p, w, &format!("anchor layer {}", p.name));
    }

    // Aggregate sanity against the published anchor magnitudes (coarse
    // bands only — the exact values are locked by the equality above
    // plus the ±5 % energy anchors in the calibration tests).
    let toggles = packed_run.mac_toggles();
    let (reads, writes) = packed_run.act_accesses();
    let act_words = reads + writes;
    let cycles = packed_run.total_cycles(); // incl. µDMA ingress
    assert!(
        (40_000_000..52_000_000).contains(&toggles),
        "anchor MAC toggles drifted: {toggles}"
    );
    assert!((4_000..5_000).contains(&act_words), "anchor activation words drifted: {act_words}");
    assert!((3_000..3_400).contains(&cycles), "anchor cycles drifted: {cycles}");
}
