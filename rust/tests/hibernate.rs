//! Integration tests of the crash-safe hibernation tier: hibernate →
//! resume cycles must be byte-identical to always-resident serving
//! (labels, fc_wakeups, every energy ledger's f64 bits, latency
//! quantiles — in both sim modes, serial and pooled, clean and
//! mid-fault-plan), idle eviction must be transparent to the serve
//! path, and a corrupt, truncated or forged store record must surface
//! as a typed refusal with visible counters — never a panic, never a
//! silently wrong session.

use std::fs;

use tcn_cutie::coordinator::{
    DvsSource, Engine, EngineConfig, GestureClass, ServingReport, Session, SessionGeometry,
    SessionSnapshot, SessionStore,
};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::fault::{FaultPlan, FaultSurface};
use tcn_cutie::network::{dvs_hybrid_random, Network};

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("tcn_cutie_hib_{name}"))
}

fn source_for(net: &Network, s: usize) -> DvsSource {
    DvsSource::new(net.input_hw, 100 + s as u64, GestureClass(s % 12))
}

/// A DVS-shaped session binding for store-level tests that never touch
/// an engine (the fingerprint is arbitrary but round-trips verbatim).
fn dvs_geometry(tcn_depth: usize, channels: usize) -> SessionGeometry {
    SessionGeometry {
        fingerprint: 0xFEED_0000_0000_0009,
        input_hw: 64,
        input_ch: 2,
        tcn_depth,
        channels,
        has_tcn: true,
    }
}

fn assert_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}: soc power");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
    assert_eq!(a.faults, b.faults, "{ctx}: fault summary");
}

/// Serve `frames` frames of stream `s`, always resident, draining per
/// frame; `plan` optionally arms fault injection.
fn serve_resident(
    net: &Network,
    mode: SimMode,
    workers: usize,
    s: usize,
    frames: usize,
    plan: Option<FaultPlan>,
) -> ServingReport {
    let cfg = EngineConfig { mode, workers, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.open_session(s).unwrap();
    if let Some(p) = plan {
        engine.set_fault_plan(s, p).unwrap();
    }
    let mut src = source_for(net, s);
    for _ in 0..frames {
        engine.submit(s, src.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    engine.finish_session(s).unwrap()
}

/// The same schedule, but the session round-trips through the idle
/// tier after every single frame: submit (transparent resume) → drain
/// → explicit hibernate. The harshest possible cycling.
fn serve_hibernating(
    net: &Network,
    mode: SimMode,
    workers: usize,
    s: usize,
    frames: usize,
    plan: Option<FaultPlan>,
) -> ServingReport {
    let cfg = EngineConfig { mode, workers, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.enable_hibernation(SessionStore::in_memory(), None);
    engine.open_session(s).unwrap();
    if let Some(p) = plan {
        engine.set_fault_plan(s, p).unwrap();
    }
    let mut src = source_for(net, s);
    for _ in 0..frames {
        engine.submit(s, src.next_frame()).unwrap();
        engine.drain().unwrap();
        engine.hibernate(s).unwrap();
    }
    engine.finish_session(s).unwrap()
}

#[test]
fn hibernate_resume_cycles_are_byte_identical() {
    // The tentpole acceptance gate: a session that hibernates after
    // EVERY frame must close with a report byte-identical to one that
    // never left residency — clean and with an armed, actively drawing
    // TcnMem fault plan (the injector's RNG position rides inside the
    // snapshot, so a resumed walk continues mid-plan exactly).
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 4;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1usize, 3] {
            for plan in [None, Some(FaultPlan::with_ber(FaultSurface::TcnMem, 0.05, 13))] {
                let armed = plan.is_some();
                let mut resident = serve_resident(&net, mode, workers, 0, frames, plan);
                let mut cycled = serve_hibernating(&net, mode, workers, 0, frames, plan);
                if armed {
                    assert!(resident.faults.injected_flips > 0, "plan must actually draw");
                }
                assert_identical(
                    &mut cycled,
                    &mut resident,
                    &format!("{mode:?} workers={workers} armed={armed}"),
                );
                // ...while the hibernation ledger records the cycling
                // without leaking into the compared fields above.
                assert_eq!(cycled.hib.hibernates, frames as u64);
                assert_eq!(cycled.hib.resumes, frames as u64);
                assert_eq!(cycled.hib.corrupt_resumes, 0);
                assert!(cycled.hib.snapshot_bytes > 0);
                assert!(cycled.hib.wake_j > 0.0);
                assert!(!resident.hib.any(), "resident run must not touch the idle tier");
            }
        }
    }
}

#[test]
fn idle_eviction_hibernates_and_resumes_transparently() {
    // --hibernate-after semantics: a session idle through N consecutive
    // drains is snapshotted out of residency; its next frame restores
    // it without the caller doing anything.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    engine.enable_hibernation(SessionStore::in_memory(), Some(2));
    let mut src0 = source_for(&net, 0);
    let mut src1 = source_for(&net, 1);

    // round 0: both sessions serve
    engine.submit(0, src0.next_frame()).unwrap();
    engine.submit(1, src1.next_frame()).unwrap();
    engine.drain().unwrap();
    // rounds 1..=3: only session 0 — session 1 idles past the limit
    for _ in 0..3 {
        engine.submit(0, src0.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    assert!(engine.store().unwrap().contains(1), "idle session must be in the store");
    assert!(!engine.store().unwrap().contains(0), "active session stays resident");
    assert!(engine.session(1).is_none());

    // explicit resume consumes the record; a second resume is a no-op
    assert!(engine.resume(1).unwrap(), "record must be consumed");
    assert!(!engine.resume(1).unwrap(), "already resident");
    assert!(!engine.store().unwrap().contains(1));

    // second frame serves as if the eviction never happened
    engine.submit(1, src1.next_frame()).unwrap();
    engine.drain().unwrap();
    let mut rep = engine.finish_session(1).unwrap();
    assert_eq!(rep.hib.hibernates, 1);
    assert_eq!(rep.hib.resumes, 1);
    assert!(rep.hib.retention_word_ticks > 0, "stored drains must pay retention");
    assert!(rep.hib.retention_j > 0.0);

    // byte-identity against a resident run of the same two frames
    let mut resident = serve_resident(&net, SimMode::Fast, 1, 1, 2, None);
    assert_identical(&mut rep, &mut resident, "evicted+resumed session");
}

#[test]
fn resident_budget_evicts_lru_even_when_never_idle() {
    // --resident-sessions semantics: with 4 always-busy streams and a
    // budget of 2, the least-recently-active pair snapshots out after
    // every drain even though nothing ever idles — and every session
    // still closes byte-identical to unbounded residency.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg.clone()).unwrap();
    engine.enable_hibernation(SessionStore::in_memory(), None);
    engine.set_resident_budget(Some(2)).unwrap();
    let mut srcs: Vec<DvsSource> = (0..4).map(|s| source_for(&net, s)).collect();
    let frames = 3;
    for _ in 0..frames {
        for (s, src) in srcs.iter_mut().enumerate() {
            engine.submit(s, src.next_frame()).unwrap();
        }
        engine.drain().unwrap();
        assert!(engine.session_ids().len() <= 2, "residency must respect the budget");
        assert_eq!(engine.store().unwrap().len(), 2, "the excess pair is in the store");
    }
    let reports = engine.finish_all();
    assert_eq!(reports.len(), 4);
    for (s, mut rep) in reports {
        if s < 2 {
            // all four tie on recency every round; the id breaks the
            // tie, so 0 and 1 are the deterministic victims
            assert_eq!(rep.hib.hibernates, frames as u64, "session {s}");
            assert!(rep.hib.resumes >= frames as u64 - 1, "session {s} kept being restored");
        } else {
            assert!(!rep.hib.any(), "session {s} stayed under the budget untouched");
        }
        let mut resident = serve_resident(&net, SimMode::Fast, 1, s, frames, None);
        assert_identical(&mut rep, &mut resident, &format!("budgeted session {s}"));
    }

    // a resident budget without the idle tier is a typed error
    let mut bare = Engine::new(&net, cfg).unwrap();
    assert!(bare.set_resident_budget(Some(1)).is_err());
}

#[test]
fn zero_ber_snapshot_plan_stays_bit_exact() {
    // The fifth fault surface honors the zero-BER contract under real
    // hibernate/resume cycling: an armed-but-inert snapshot plan draws
    // nothing and perturbs nothing.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let plan = FaultPlan::with_ber(FaultSurface::Snapshot, 0.0, 99);
    let mut clean = serve_hibernating(&net, SimMode::Fast, 1, 0, 4, None);
    let mut armed = serve_hibernating(&net, SimMode::Fast, 1, 0, 4, Some(plan));
    assert_identical(&mut armed, &mut clean, "zero-BER snapshot plan");
    assert_eq!(armed.faults.injected_flips, 0);
    assert_eq!(armed.faults.snapshot_corrupt, 0);
    assert_eq!(armed.hib.corrupt_resumes, 0);
}

#[test]
fn snapshot_surface_corruption_reinitializes_visibly() {
    // An actively drawing snapshot plan rots the stored record between
    // hibernate and resume. The CRC refuses it; the session restarts
    // from scratch with every counter raised — and the engine never
    // errors, let alone panics, on the serve path.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    engine.enable_hibernation(SessionStore::in_memory(), None);
    engine.set_fault_plan(0, FaultPlan::with_ber(FaultSurface::Snapshot, 0.05, 9)).unwrap();
    let mut src = source_for(&net, 0);
    for _ in 0..3 {
        engine.submit(0, src.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    engine.hibernate(0).unwrap();
    // transparent (corrupt) resume on the next frame
    engine.submit(0, src.next_frame()).unwrap();
    engine.drain().unwrap();
    let rep = engine.finish_session(0).unwrap();
    assert_eq!(rep.faults.snapshot_corrupt, 1, "the refusal must be visible");
    assert_eq!(rep.hib.corrupt_resumes, 1);
    assert!(rep.faults.injected_flips > 0, "0.05 BER over the record must draw");
    assert_eq!(rep.faults.detected, rep.faults.injected_flips, "every flip is CRC-caught");
    assert!(rep.hib.snapshot_bytes > 0, "the write itself still happened");
    // the record's in-flight history (3 frames) died with the record;
    // only the post-corruption frame survives
    assert_eq!(rep.metrics.frames, 1);
    assert_eq!(rep.labels.len(), 1);
}

#[test]
fn kill_and_reopen_resumes_from_disk() {
    // The crash-safety claim end to end: hibernate two sessions into a
    // file-backed store, drop the engine (the "kill"), reopen the store
    // in a fresh engine and keep serving — the final reports must be
    // byte-identical to never having restarted at all.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let path = tmp_path("kill_reopen.store");
    let _ = fs::remove_file(&path);

    // phase A: 4 frames each, then hibernate everything and "die"
    {
        let mut engine = Engine::new(&net, cfg.clone()).unwrap();
        engine.enable_hibernation(SessionStore::open(&path).unwrap(), None);
        let mut srcs: Vec<DvsSource> = (0..2).map(|s| source_for(&net, s)).collect();
        for _ in 0..4 {
            for (s, src) in srcs.iter_mut().enumerate() {
                engine.submit(s, src.next_frame()).unwrap();
            }
            engine.drain().unwrap();
        }
        engine.hibernate(0).unwrap();
        engine.hibernate(1).unwrap();
        // no graceful shutdown from here: the engine is just dropped
    }
    let disk_image = fs::read(&path).unwrap();

    // phase B: a new process reopens the store and continues serving
    let store = SessionStore::open(&path).unwrap();
    assert!(!store.recovered_torn());
    assert_eq!(store.len(), 2, "both sessions must have survived the restart");
    let mut engine = Engine::new(&net, cfg).unwrap();
    engine.enable_hibernation(store, None);
    let mut srcs: Vec<DvsSource> = (0..2)
        .map(|s| {
            let mut src = source_for(&net, s);
            for _ in 0..4 {
                src.next_frame(); // phase A already consumed these
            }
            src
        })
        .collect();
    for _ in 0..4 {
        for (s, src) in srcs.iter_mut().enumerate() {
            engine.submit(s, src.next_frame()).unwrap();
        }
        engine.drain().unwrap();
    }
    for (s, mut rep) in engine.finish_all() {
        assert_eq!(rep.hib.hibernates, 1, "session {s}");
        assert_eq!(rep.hib.resumes, 1, "session {s}");
        assert_eq!(rep.hib.corrupt_resumes, 0, "session {s}");
        let mut resident = serve_resident(&net, SimMode::Fast, 1, s, 8, None);
        assert_identical(&mut rep, &mut resident, &format!("session {s} across the restart"));
    }

    // phase C: the same disk image with its tail torn off (kill mid-
    // write of the LAST record) keeps every intact record before it.
    let torn = tmp_path("kill_reopen_torn.store");
    fs::write(&torn, &disk_image[..disk_image.len() - 10]).unwrap();
    let torn_store = SessionStore::open(&torn).unwrap();
    assert!(torn_store.recovered_torn(), "the chopped tail must be reported");
    assert_eq!(torn_store.len(), 1, "only the intact record survives");
    assert!(torn_store.contains(0));
    assert!(torn_store.peek(0).unwrap().is_ok(), "the survivor decodes cleanly");
    let _ = fs::remove_file(&path);
    let _ = fs::remove_file(&torn);
}

#[test]
fn truncated_store_files_never_panic() {
    // Chop a healthy 3-record store file at EVERY byte boundary and
    // reopen: each cut yields either a typed error (unreadable prefix)
    // or a store whose surviving records are exactly an intact prefix —
    // decodable, CRC-clean, never a panic.
    let path = tmp_path("trunc_sweep.store");
    let cut_path = tmp_path("trunc_sweep_cut.store");
    let _ = fs::remove_file(&path);
    let mut store = SessionStore::open(&path).unwrap();
    for id in [3u64, 7, 11] {
        let sess = Session::new(id as usize, 0.5, dvs_geometry(8, 16));
        store.insert(id, SessionSnapshot::capture(&sess).encode());
    }
    store.sync().unwrap();
    let bytes = fs::read(&path).unwrap();

    for cut in 0..=bytes.len() {
        fs::write(&cut_path, &bytes[..cut]).unwrap();
        match SessionStore::open(&cut_path) {
            Ok(s) => {
                for id in s.ids() {
                    assert!([3, 7, 11].contains(&id), "cut {cut}: alien record {id}");
                    assert!(
                        s.peek(id).unwrap().is_ok(),
                        "cut {cut}: a kept record must be fully intact"
                    );
                }
                if cut == bytes.len() {
                    assert_eq!(s.len(), 3, "the untruncated file holds everything");
                    assert!(!s.recovered_torn());
                }
            }
            // an unreadable prefix (e.g. a chopped magic) is a typed
            // refusal — also fine, as long as nothing panics
            Err(_) => assert!(cut < bytes.len(), "the full file must open"),
        }
    }
    let _ = fs::remove_file(&path);
    let _ = fs::remove_file(&cut_path);
}

#[test]
fn store_bit_rot_is_always_detected() {
    // Single-bit rot anywhere in a stored record must be caught by the
    // per-record CRC (a 1-bit error never aliases CRC-32), and flipping
    // the same bit back must restore a cleanly decodable record.
    let mut store = SessionStore::in_memory();
    let mut sess = Session::new(1, 0.5, dvs_geometry(8, 16));
    sess.metrics.record_frame(12.5, 3.0, 1.5e-6);
    sess.labels.push(4);
    let payload = SessionSnapshot::capture(&sess).encode();
    let bits = payload.len() as u64 * 8;
    store.insert(1, payload);
    assert!(store.peek(1).unwrap().is_ok());

    let mut addr = 0u64;
    while addr < bits {
        store.flip_bits(1, &[addr]);
        assert!(store.peek(1).unwrap().is_err(), "bit {addr}: rot must be detected");
        store.flip_bits(1, &[addr]);
        assert!(store.peek(1).unwrap().is_ok(), "bit {addr}: flip-back must heal");
        addr += 97;
    }
}

#[test]
fn forged_records_are_refused() {
    // CRC-clean but structurally wrong records — a snapshot filed under
    // another session's id, a foreign magic, an unknown version — are
    // refused by decode validation, not trusted because the checksum
    // happens to match the forged bytes.
    let mut store = SessionStore::in_memory();
    let valid = SessionSnapshot::capture(&Session::new(1, 0.5, dvs_geometry(8, 16))).encode();

    // (a) filed under the wrong id
    store.insert(2, valid.clone());
    assert!(store.peek(2).unwrap().is_err(), "id 1 snapshot must not resume session 2");

    // (b) forged magic
    let mut forged = valid.clone();
    forged[0] ^= 0xFF;
    store.insert(1, forged);
    assert!(store.peek(1).unwrap().is_err(), "foreign magic");

    // (c) unknown version
    let mut forged = valid.clone();
    forged[4] = forged[4].wrapping_add(1);
    store.insert(1, forged);
    assert!(store.peek(1).unwrap().is_err(), "unknown version");

    // (d) the untampered record still decodes
    store.insert(1, valid);
    assert!(store.peek(1).unwrap().is_ok());
}

#[test]
fn hibernate_api_contracts() {
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };

    // without the idle tier, both verbs are typed errors
    let mut engine = Engine::new(&net, cfg.clone()).unwrap();
    engine.open_session(0).unwrap();
    assert!(engine.hibernate(0).is_err(), "hibernation is not enabled");
    assert!(engine.resume(0).is_err(), "hibernation is not enabled");

    let mut engine = Engine::new(&net, cfg).unwrap();
    engine.enable_hibernation(SessionStore::in_memory(), None);
    assert!(engine.hibernate(5).is_err(), "unknown session cannot hibernate");
    assert!(engine.resume(5).is_err(), "no record, no session");

    // pending frames block hibernation (their state is still in flight)
    let mut src = source_for(&net, 0);
    engine.submit(0, src.next_frame()).unwrap();
    assert!(engine.hibernate(0).is_err(), "must drain first");
    engine.drain().unwrap();
    engine.hibernate(0).unwrap();
    assert!(engine.hibernate(0).is_err(), "already hibernated");
}

#[test]
fn kraken_snapshot_size_vs_sram_anchor() {
    // The §Hibernation size claim: at the paper's geometry (24-step,
    // 96-channel TCN window — 576 B of SCM content) a full-ring session
    // snapshot costs a small constant factor over the raw window: 4
    // u64 plane words per step (768 B) plus the fixed SoC/metrics
    // sections, bounded well under 2 KiB.
    let mut sess = Session::new(0, 0.5, dvs_geometry(24, 96));
    let feat: Vec<i8> = (0..96).map(|c| [1i8, -1, 0][c % 3]).collect();
    for _ in 0..24 {
        sess.tcn.push(&feat);
    }
    let payload = SessionSnapshot::capture(&sess).encode();
    assert!(payload.len() > 24 * 32, "a full ring dominates the record");
    assert!(payload.len() < 2048, "snapshot stays within 2 KiB at the Kraken anchor");
    // and it restores bit-exactly, ring content included
    let snap = SessionSnapshot::decode(&payload, 0).unwrap();
    let restored = snap.into_session().unwrap();
    assert_eq!(restored.tcn.len(), 24);
    assert_eq!(
        SessionSnapshot::capture(&restored).encode(),
        payload,
        "re-capture of the restored session is byte-identical"
    );
}
