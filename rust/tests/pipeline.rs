//! Integration tests over the full serving pipeline with the real
//! artifact-loaded networks (SRV experiment) plus failure injection.

use tcn_cutie::coordinator::{
    DvsSource, Engine, EngineConfig, GestureClass, Pipeline, PipelineConfig,
};
use tcn_cutie::cutie::{CutieConfig, Scheduler, SimMode, TcnStrategy};
use tcn_cutie::network::loader;
use tcn_cutie::tensor::TritTensor;

fn artifacts() -> std::path::PathBuf {
    loader::artifacts_dir()
}

fn have_artifacts() -> bool {
    artifacts().join("dvs_hybrid_96.json").exists()
}

#[test]
fn serve_real_dvs_network() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();
    let pipe = Pipeline::new(
        net,
        PipelineConfig { frames: 8, mode: SimMode::Fast, ..Default::default() },
    );
    let mut r = pipe.run_inline().unwrap();
    assert_eq!(r.metrics.frames, 8);
    assert_eq!(r.fc_wakeups, 8);
    assert!(r.metrics.sim_latency_us.quantile(0.5) > 0.0);
    assert!(r.soc_energy_j > 0.0);
    assert!(r.labels.iter().all(|&l| l < 12));
}

#[test]
fn threaded_serving_is_deterministic_vs_inline() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();
    let cfg = PipelineConfig { frames: 6, mode: SimMode::Fast, ..Default::default() };
    let a = Pipeline::new(net.clone(), cfg.clone()).run_inline().unwrap();
    let b = Pipeline::new(net, cfg).run_threaded().unwrap();
    assert_eq!(a.labels, b.labels);
}

#[test]
fn engine_reference_and_multi_stream_on_real_net() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();

    // engine-backed inline policy == retained pre-engine loop, on the
    // real artifact network
    let cfg = PipelineConfig { frames: 4, mode: SimMode::Fast, ..Default::default() };
    let p = Pipeline::new(net.clone(), cfg);
    let a = p.run_reference().unwrap();
    let b = p.run_inline().unwrap();
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.fc_wakeups, b.fc_wakeups);
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits());
    assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits());

    // two interleaved sessions == two isolated runs
    let solo: Vec<_> = (0..2)
        .map(|s| {
            let ecfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
            let mut e = Engine::new(&net, ecfg).unwrap();
            let mut src = DvsSource::new(net.input_hw, 20 + s as u64, GestureClass(s));
            for _ in 0..3 {
                e.submit(s, src.next_frame()).unwrap();
                e.drain().unwrap();
            }
            e.finish_session(s).unwrap()
        })
        .collect();
    let ecfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut e = Engine::new(&net, ecfg).unwrap();
    let mut srcs: Vec<DvsSource> =
        (0..2).map(|s| DvsSource::new(net.input_hw, 20 + s as u64, GestureClass(s))).collect();
    for _ in 0..3 {
        for (s, src) in srcs.iter_mut().enumerate() {
            e.submit(s, src.next_frame()).unwrap();
        }
        e.drain().unwrap();
    }
    for (s, rep) in e.finish_all() {
        assert_eq!(rep.labels, solo[s].labels, "session {s}");
        assert_eq!(rep.soc_energy_j.to_bits(), solo[s].soc_energy_j.to_bits(), "session {s}");
    }
}

#[test]
fn tcn_window_warms_up_over_stream() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    sched.preload_weights(&net);
    let mut src = DvsSource::new(64, 3, GestureClass(1));
    for i in 0..26 {
        let frame = src.next_frame();
        sched.serve_frame(&net, &frame).unwrap();
        assert_eq!(sched.tcn_mem.len(), (i + 1).min(24));
    }
    assert!(sched.tcn_mem.is_full());
}

#[test]
fn direct_vs_mapped_strategy_on_real_net() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = loader::load_network(artifacts().join("dvs_hybrid_96.json")).unwrap();
    let mut mapped = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    let mut direct =
        Scheduler::new(CutieConfig::kraken(), SimMode::Fast).with_tcn_strategy(TcnStrategy::Direct);
    let mut src = DvsSource::new(64, 5, GestureClass(2));
    for _ in 0..3 {
        let f = src.next_frame();
        let (lm, rm) = mapped.serve_frame(&net, &f).unwrap();
        let (ld, rd) = direct.serve_frame(&net, &f).unwrap();
        assert_eq!(lm, ld, "strategies must agree bit-exactly on the real net");
        assert_eq!(rm.stall_cycles(), 0);
        assert!(rd.stall_cycles() > 0);
    }
}

#[test]
fn oversized_input_rejected_cleanly() {
    // failure injection: feature maps beyond the 64x64x96 hardware limit
    // must produce an error, not a wrong answer
    let net = tcn_cutie::network::cifar9_random(96, 1, 0.3);
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    let too_big = TritTensor::zeros(&[128, 128, 3]);
    assert!(sched.run_full(&net, &too_big).is_err());
}

#[test]
fn corrupt_manifest_rejected() {
    // failure injection: loader must reject malformed manifests
    let dir = std::env::temp_dir().join("tcn_cutie_corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let man = dir.join("bad.json");
    std::fs::write(&man, "{\"name\": \"x\"").unwrap();
    assert!(loader::load_network(&man).is_err());
    std::fs::write(&man, "{\"name\": \"x\", \"layers\": []}").unwrap();
    assert!(loader::load_network(&man).is_err());
}

#[test]
fn corrupt_ttn_rejected() {
    // failure injection: truncated/garbage weight files must error
    let dir = std::env::temp_dir().join("tcn_cutie_corrupt2");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("weights.ttn"), [0u8; 16]).unwrap();
    std::fs::write(
        dir.join("net.json"),
        r#"{"name":"x","input_hw":32,"tcn_steps":24,"classes":10,
            "weights_file":"weights.ttn","layers":[
            {"name":"c1","kind":"conv2d","in_ch":3,"out_ch":8,"kernel":3,
             "dilation":1,"pool":false,"global_pool":false,
             "weights":"c1.w","lo":"c1.lo","hi":"c1.hi"}]}"#,
    )
    .unwrap();
    assert!(loader::load_network(dir.join("net.json")).is_err());
}
