//! Property tests for the packed column-stationary datapath (perf pass
//! iterations 7+8): across sizes, sparsities (including 0.95 DVS-like
//! maps) and channel widths C_in ∈ {16, 64, 96, 128}, the packed loop —
//! `PackedMap` in, `PackedMap` out, packed ternarize, packed pooling —
//! must produce the **same output trits and the same activity
//! counters** — `mac_toggles`, `compute_cycles`, `act_reads`,
//! `act_writes`, `mac_idle`, `hw_ops` — as both the retained i8
//! window-stationary loop and the functional reference executor. The
//! equivalence is what lets the energy model stay calibrated while the
//! software loop gets faster. (The whole-network packed-vs-i8 sweep,
//! including the EXPERIMENTS.md anchor workload, lives in
//! `tests/packed.rs`.)

use tcn_cutie::cutie::datapath::{
    run_prepared, run_prepared_window, LayerResult, LayerResultI8, PreparedLayer,
};
use tcn_cutie::cutie::{CutieConfig, SimMode};
use tcn_cutie::network::{reference, Layer, LayerKind};
use tcn_cutie::tensor::{PackedMap, TritTensor};
use tcn_cutie::util::rng::Rng;

fn conv_layer(name: &str, cin: usize, cout: usize, rng: &mut Rng, zf: f64, pool: bool) -> Layer {
    let weights = TritTensor::random(&[3, 3, cin, cout], rng, zf);
    let th = ((0.5 * ((9 * cin) as f64 * (1.0 - zf)).sqrt()) as i32).max(1);
    Layer {
        name: name.to_string(),
        kind: LayerKind::Conv2d,
        in_ch: cin,
        out_ch: cout,
        kernel: 3,
        dilation: 1,
        pool,
        global_pool: false,
        weights,
        lo: vec![-th; cout],
        hi: vec![th; cout],
    }
}

fn assert_equivalent(packed: &LayerResult, i8_run: &LayerResultI8, ctx: &str) {
    assert_eq!(packed.output.to_trit(), i8_run.output, "{ctx}: output trits");
    assert_eq!(packed.stats.mac_toggles, i8_run.stats.mac_toggles, "{ctx}: mac_toggles");
    assert_eq!(packed.stats.mac_idle, i8_run.stats.mac_idle, "{ctx}: mac_idle");
    assert_eq!(packed.stats.compute_cycles, i8_run.stats.compute_cycles, "{ctx}: compute_cycles");
    assert_eq!(packed.stats.act_reads, i8_run.stats.act_reads, "{ctx}: act_reads");
    assert_eq!(packed.stats.act_writes, i8_run.stats.act_writes, "{ctx}: act_writes");
    assert_eq!(packed.stats.lb_fill_cycles, i8_run.stats.lb_fill_cycles, "{ctx}: lb_fill_cycles");
    assert_eq!(packed.stats.lb_pushes, i8_run.stats.lb_pushes, "{ctx}: lb_pushes");
    assert_eq!(packed.stats.hw_ops, i8_run.stats.hw_ops, "{ctx}: hw_ops");
    assert_eq!(packed.stats.alg_macs, i8_run.stats.alg_macs, "{ctx}: alg_macs");
    assert_eq!(packed.stats.drain_cycles, i8_run.stats.drain_cycles, "{ctx}: drain_cycles");
    assert_eq!(packed.stats.stall_cycles, i8_run.stats.stall_cycles, "{ctx}: stall_cycles");
}

/// The headline property: output AND counters match the i8 window loop
/// and the reference executor across channel widths and sparsities.
#[test]
fn packed_matches_i8_window_and_reference_across_geometries() {
    let mut rng = Rng::new(7001);
    for &cin in &[16usize, 64, 96, 128] {
        // widen the datapath for the 128-channel case (original CUTIE
        // configuration); Kraken's 96 otherwise
        let channels = cin.max(96);
        let cfg = CutieConfig { channels, ..CutieConfig::kraken() };
        for (case, &zf) in [0.0, 0.33, 0.66, 0.95].iter().enumerate() {
            let cout = 1 + rng.below(cin);
            let pool = case % 2 == 1;
            let layer = conv_layer(&format!("c{cin}_{case}"), cin, cout, &mut rng, zf, pool);
            let hw = 2 * (2 + rng.below(6)); // even (pooling-safe), 4..14
            let input = TritTensor::random(&[hw, hw, cin], &mut rng, zf);
            let packed_in = PackedMap::from_trit(&input);
            let prep = PreparedLayer::new(&layer);
            for mode in [SimMode::Accurate, SimMode::Fast] {
                let ctx = format!("cin={cin} zf={zf} hw={hw} cout={cout} mode={mode:?}");
                let packed = run_prepared(&prep, &packed_in, &cfg, mode).unwrap();
                let win = run_prepared_window(&prep, &input, &cfg, mode).unwrap();
                assert_equivalent(&packed, &win, &ctx);
                let want = reference::run_conv_layer(&layer, &input);
                assert_eq!(packed.output.to_trit(), want, "{ctx}: reference executor");
            }
        }
    }
}

/// Degenerate and rectangular geometries: single-row, single-column and
/// narrow maps exercise the column loop's output-column clipping.
#[test]
fn packed_loop_edge_geometries() {
    let mut rng = Rng::new(7002);
    let cfg = CutieConfig::kraken();
    for &(h, w) in &[(1usize, 1usize), (1, 5), (5, 1), (2, 7), (7, 2), (3, 3)] {
        for &zf in &[0.2, 0.95] {
            let cin = 1 + rng.below(96);
            let cout = 1 + rng.below(96);
            let layer = conv_layer(&format!("e{h}x{w}"), cin, cout, &mut rng, zf, false);
            let input = TritTensor::random(&[h, w, cin], &mut rng, zf);
            let prep = PreparedLayer::new(&layer);
            let ctx = format!("h={h} w={w} cin={cin} cout={cout} zf={zf}");
            let packed =
                run_prepared(&prep, &PackedMap::from_trit(&input), &cfg, SimMode::Accurate)
                    .unwrap();
            let win = run_prepared_window(&prep, &input, &cfg, SimMode::Accurate).unwrap();
            assert_equivalent(&packed, &win, &ctx);
            assert_eq!(packed.output.to_trit(), reference::run_conv_layer(&layer, &input), "{ctx}");
        }
    }
}

/// All-zero inputs and all-zero weights: the whole-column sparsity skip
/// must leave both acc and toggle counters at exactly zero activity.
#[test]
fn packed_loop_zero_operands() {
    let mut rng = Rng::new(7003);
    let cfg = CutieConfig::kraken();
    let layer = conv_layer("z", 32, 16, &mut rng, 0.3, false);
    let zeros = PackedMap::zeros(6, 6, 32);
    let prep = PreparedLayer::new(&layer);
    let packed = run_prepared(&prep, &zeros, &cfg, SimMode::Accurate).unwrap();
    assert_eq!(packed.stats.mac_toggles, 0);
    assert!(packed.output.unpack_data().iter().all(|&t| t == 0));

    let zero_w = Layer {
        weights: TritTensor::zeros(&[3, 3, 32, 16]),
        ..conv_layer("zw", 32, 16, &mut rng, 0.3, false)
    };
    let input = TritTensor::random(&[6, 6, 32], &mut rng, 0.2);
    let prep_zw = PreparedLayer::new(&zero_w);
    let packed_zw =
        run_prepared(&prep_zw, &PackedMap::from_trit(&input), &cfg, SimMode::Accurate).unwrap();
    let win_zw = run_prepared_window(&prep_zw, &input, &cfg, SimMode::Accurate).unwrap();
    assert_eq!(packed_zw.stats.mac_toggles, 0);
    assert_equivalent(&packed_zw, &win_zw, "zero weights");
}

/// Multi-row sharding must not change results or counters: force maps
/// large enough to shard, then compare against the single-threaded run.
#[test]
fn packed_loop_sharding_invariant() {
    let mut rng = Rng::new(7004);
    let parallel = CutieConfig::kraken();
    let serial = CutieConfig { max_threads: 1, ..CutieConfig::kraken() };
    let layer = conv_layer("s", 96, 96, &mut rng, 0.33, false);
    let input = PackedMap::from_trit(&TritTensor::random(&[32, 32, 96], &mut rng, 0.4));
    let prep = PreparedLayer::new(&layer);
    let par = run_prepared(&prep, &input, &parallel, SimMode::Accurate).unwrap();
    let ser = run_prepared(&prep, &input, &serial, SimMode::Accurate).unwrap();
    assert_eq!(par.output, ser.output, "sharded vs serial: output");
    assert_eq!(par.stats.mac_toggles, ser.stats.mac_toggles, "sharded vs serial: mac_toggles");
    assert_eq!(par.stats.mac_idle, ser.stats.mac_idle, "sharded vs serial: mac_idle");
    assert_eq!(par.stats.compute_cycles, ser.stats.compute_cycles, "sharded vs serial: cycles");
    assert_eq!(par.stats.act_reads, ser.stats.act_reads, "sharded vs serial: act_reads");
    assert_eq!(par.stats.act_writes, ser.stats.act_writes, "sharded vs serial: act_writes");
}
