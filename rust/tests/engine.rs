//! Integration tests of the multi-stream serving engine: interleaved vs
//! isolated session determinism (the api_redesign acceptance gate), the
//! packed word-stream replay path, source plumbing, and the
//! shared-weight-image guarantees (one `Arc<PreparedNet>` across the
//! whole pool; packed-image boot byte-identical to i8 boot).

use std::sync::Arc;

use tcn_cutie::coordinator::{
    DvsSource, Engine, EngineConfig, FrameSource, GestureClass, MixedSource, PackedStream,
    ServingReport,
};
use tcn_cutie::cutie::{dma_ingress_bytes, CutieConfig, PreparedNet, SimMode};
use tcn_cutie::network::{dvs_hybrid_random, loader, Network};
use tcn_cutie::tensor::{ttn, PackedMap};

fn source_for(net: &Network, s: usize) -> DvsSource {
    DvsSource::new(net.input_hw, 100 + s as u64, GestureClass(s % 12))
}

fn assert_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}: soc power");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
}

/// Serve `frames` frames of stream `s` alone on a fresh engine.
fn serve_isolated(net: &Network, mode: SimMode, s: usize, frames: usize) -> ServingReport {
    let cfg = EngineConfig { mode, workers: 1, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    engine.open_session(s).unwrap();
    let mut src = source_for(net, s);
    for _ in 0..frames {
        engine.submit(s, src.next_frame()).unwrap();
        engine.drain().unwrap();
    }
    engine.finish_session(s).unwrap()
}

#[test]
fn interleaved_sessions_match_isolated() {
    // The multi-stream determinism guarantee: round-robin interleaving K
    // sessions through one engine must be byte-identical, per session,
    // to serving each stream alone — for K ∈ {1, 2, 5} and both modes.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 4;
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for k in [1usize, 2, 5] {
            let mut solo: Vec<ServingReport> =
                (0..k).map(|s| serve_isolated(&net, mode, s, frames)).collect();

            let cfg = EngineConfig { mode, workers: 1, ..Default::default() };
            let mut engine = Engine::new(&net, cfg).unwrap();
            let mut srcs: Vec<DvsSource> = (0..k).map(|s| source_for(&net, s)).collect();
            for f in 0..frames {
                for (s, src) in srcs.iter_mut().enumerate() {
                    engine.submit(s, src.next_frame()).unwrap();
                }
                // drain on a ragged cadence so batches mix sessions
                if f % 2 == 0 {
                    engine.drain().unwrap();
                }
            }
            engine.drain().unwrap();

            let agg = engine.aggregate_report();
            assert_eq!(agg.metrics.frames, (k * frames) as u64);
            for (s, mut rep) in engine.finish_all() {
                assert_identical(&mut rep, &mut solo[s], &format!("{mode:?} K={k} session {s}"));
            }
        }
    }
}

#[test]
fn worker_pool_matches_serial_engine_across_sessions() {
    // Sharding the CNN front-end across a pool must not perturb any
    // session's counters (the engine's sharding-invariance argument).
    let net = dvs_hybrid_random(16, 5, 0.5);
    let k = 3;
    let frames = 4;
    let mut solo: Vec<ServingReport> =
        (0..k).map(|s| serve_isolated(&net, SimMode::Fast, s, frames)).collect();

    let cfg = EngineConfig { mode: SimMode::Fast, workers: 3, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    let mut srcs: Vec<DvsSource> = (0..k).map(|s| source_for(&net, s)).collect();
    for _ in 0..frames {
        for (s, src) in srcs.iter_mut().enumerate() {
            engine.submit(s, src.next_frame()).unwrap();
        }
    }
    assert_eq!(engine.pending_frames(), k * frames);
    assert_eq!(engine.drain().unwrap(), k * frames);
    assert_eq!(engine.pending_frames(), 0);
    for (s, mut rep) in engine.finish_all() {
        assert_identical(&mut rep, &mut solo[s], &format!("pooled session {s}"));
    }
}

#[test]
fn replayed_word_stream_serves_identically_to_live_source() {
    // Record the camera payload as a flat word-stream, round-trip it
    // through bytes, and serve the decoded stream: the word-stream is a
    // faithful µDMA payload twin, so the report must be byte-identical.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let frames = 5;
    let mut live = serve_isolated(&net, SimMode::Fast, 0, frames);

    let mut src = source_for(&net, 0);
    let stream = PackedStream::capture(&mut src, frames).unwrap();
    assert_eq!(stream.frame_payload_bytes(), dma_ingress_bytes(net.input_hw * net.input_hw * 2));
    let mut replay = PackedStream::decode(&stream.encode()).unwrap();

    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    engine.open_session(0).unwrap();
    // submit_from pulls until the finite stream dries up
    assert_eq!(engine.submit_from(0, &mut replay, usize::MAX).unwrap(), frames);
    assert_eq!(replay.next_frame(), None, "stream must be exhausted");
    engine.drain().unwrap();
    let mut rep = engine.finish_session(0).unwrap();
    assert_identical(&mut rep, &mut live, "replayed word-stream");
}

#[test]
fn mixed_source_feeds_engine_deterministically() {
    // A mixer is just another FrameSource: two engines fed from
    // identically constructed mixers must agree byte for byte.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let serve = |seed: u64| -> ServingReport {
        let mut mixer = MixedSource::of_gestures(net.input_hw, seed, &[1, 7, 10]);
        let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
        let mut engine = Engine::new(&net, cfg).unwrap();
        engine.open_session(0).unwrap();
        engine.submit_from(0, &mut mixer, 6).unwrap();
        engine.drain().unwrap();
        engine.finish_session(0).unwrap()
    };
    let mut a = serve(40);
    let mut b = serve(40);
    assert_eq!(a.metrics.frames, 6);
    assert_identical(&mut a, &mut b, "mixer determinism");
    // seed sensitivity: differently seeded mixers must emit different
    // frame streams (labels may coincide; pixels essentially cannot)
    let mut m40 = MixedSource::of_gestures(net.input_hw, 40, &[1, 7, 10]);
    let mut m41 = MixedSource::of_gestures(net.input_hw, 41, &[1, 7, 10]);
    assert_ne!(m40.next_frame(), m41.next_frame(), "mixer must honor its seed");
}

#[test]
fn pool_shares_exactly_one_weight_image() {
    // The shared-image acceptance gate: a K-worker engine holds exactly
    // one Arc'd PreparedNet — engine + tail + K workers all borrow the
    // same allocation, and serving never makes any of them rebuild a
    // private copy.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let k = 4;
    let cfg = EngineConfig { mode: SimMode::Fast, workers: k, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    assert_eq!(engine.pool_size(), k);
    assert_eq!(
        Arc::strong_count(engine.image()),
        k + 2,
        "one image, borrowed by the engine, the tail and {k} workers"
    );
    assert_eq!(engine.image().counts(), (9, 1), "5 conv + 4 mapped TCN, 1 classifier");

    let mut srcs: Vec<DvsSource> = (0..3).map(|s| source_for(&net, s)).collect();
    for _ in 0..3 {
        for (s, src) in srcs.iter_mut().enumerate() {
            engine.submit(s, src.next_frame()).unwrap();
        }
    }
    engine.drain().unwrap();
    assert_eq!(
        Arc::strong_count(engine.image()),
        k + 2,
        "serving must not clone or rebuild the weight image"
    );

    // serial engines hold the same single image (no pool refs)
    let serial = Engine::new(
        &net,
        EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() },
    )
    .unwrap();
    assert_eq!(serial.pool_size(), 0);
    assert_eq!(Arc::strong_count(serial.image()), 2);
}

#[test]
fn packed_image_boot_serves_byte_identically() {
    // Round-trip the weight image through actual TTN2 bytes, boot an
    // engine from it, and serve the same streams as an i8-booted engine:
    // labels, fc_wakeups, both energy ledgers' f64 bits and latency
    // quantiles must match, in both sim modes, serial and pooled.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let kraken = CutieConfig::kraken();
    let built = PreparedNet::new(&net, &kraken);
    let v2 = ttn::write_bytes_v2(&loader::network_bundle(&net), &built.to_image());
    let (_, img) = ttn::read_bytes_full(&v2).unwrap();
    let loaded = Arc::new(PreparedNet::from_image(&img.unwrap(), &net, &kraken).unwrap());
    assert_eq!(*loaded, built, "word-copy boot must equal the i8 build");

    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1usize, 3] {
            let cfg = EngineConfig { mode, workers, ..Default::default() };
            let mut from_i8 = Engine::new(&net, cfg.clone()).unwrap();
            let mut from_img = Engine::with_image(&net, cfg, Arc::clone(&loaded)).unwrap();
            let k = 2;
            let frames = 3;
            let mut srcs: Vec<DvsSource> = (0..k).map(|s| source_for(&net, s)).collect();
            for _ in 0..frames {
                for (s, src) in srcs.iter_mut().enumerate() {
                    let f = src.next_frame();
                    from_i8.submit(s, f.clone()).unwrap();
                    from_img.submit(s, f).unwrap();
                }
            }
            from_i8.drain().unwrap();
            from_img.drain().unwrap();
            let a = from_i8.finish_all();
            let b = from_img.finish_all();
            for ((s, mut ra), (_, mut rb)) in a.into_iter().zip(b) {
                assert_identical(
                    &mut rb,
                    &mut ra,
                    &format!("{mode:?} workers={workers} session {s}: packed vs i8 boot"),
                );
            }
        }
    }
}

#[test]
fn mismatched_image_is_a_boot_error() {
    let net16 = dvs_hybrid_random(16, 5, 0.5);
    let net32 = dvs_hybrid_random(32, 5, 0.5);
    let image = Arc::new(PreparedNet::new(&net32, &CutieConfig::kraken()));
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    assert!(
        Engine::with_image(&net16, cfg.clone(), image).is_err(),
        "serving a network from another network's weight image must fail at boot"
    );

    // same name + geometry but different thresholds: the boot-time
    // content validation must catch it (an undetected mismatch would
    // change every ternarization decision and serve wrong labels)
    let mut tampered = net16.clone();
    tampered.layers[5].lo[0] -= 1; // a TCN layer's threshold
    let image = Arc::new(PreparedNet::new(&tampered, &CutieConfig::kraken()));
    assert!(
        Engine::with_image(&net16, cfg, image).is_err(),
        "threshold-divergent image must fail boot validation"
    );
}

#[test]
fn empty_and_unknown_sessions_behave() {
    let net = dvs_hybrid_random(16, 5, 0.5);
    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    assert_eq!(engine.drain().unwrap(), 0, "empty drain is a no-op");
    assert!(engine.finish_session(9).is_none(), "unknown session has no report");
    engine.open_session(2).unwrap();
    let rep = engine.finish_session(2).unwrap();
    assert_eq!(rep.metrics.frames, 0);
    assert!(rep.labels.is_empty());
    assert_eq!(rep.soc_energy_j, 0.0);
}

#[test]
fn session_state_is_isolated_not_shared() {
    // Two sessions fed the SAME frames from cold start must produce the
    // same labels as each other (isolated recurrent state), and a session
    // fed twice as many frames must have advanced its own TCN window.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let mut src = source_for(&net, 0);
    let frames: Vec<PackedMap> = (0..4).map(|_| src.next_frame()).collect();

    let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, ..Default::default() };
    let mut engine = Engine::new(&net, cfg).unwrap();
    for f in &frames {
        engine.submit(0, f.clone()).unwrap();
        engine.submit(1, f.clone()).unwrap();
    }
    engine.drain().unwrap();
    assert_eq!(engine.session(0).unwrap().tcn.len(), 4);
    assert_eq!(engine.session(1).unwrap().tcn.len(), 4);
    let reports = engine.finish_all();
    assert_eq!(reports[0].1.labels, reports[1].1.labels, "same input, same cold start");
}
