//! Lane-batching byte-identity: serving through the cross-session
//! lane-batched CNN front-end (`EngineConfig::lanes > 1`) must be
//! byte-identical, per session, to serial serving (`lanes = 1`) —
//! across batch widths (including ragged last groups), both sim modes,
//! serial and pooled engines, mixed-net registries (same-geometry
//! sessions bound to different nets must never share a lane unit) and
//! with a fault plan armed mid-fleet.

use std::sync::Arc;

use tcn_cutie::coordinator::{
    DvsSource, Engine, EngineConfig, GestureClass, NetRegistry, ServingReport,
};
use tcn_cutie::cutie::SimMode;
use tcn_cutie::fault::{FaultPlan, FaultSurface};
use tcn_cutie::network::{dvs_hybrid_random, Network};

fn source_for(net: &Network, s: usize) -> DvsSource {
    DvsSource::new(net.input_hw, 300 + s as u64, GestureClass(s % 12))
}

fn assert_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
    assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits(), "{ctx}: soc energy");
    assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}: soc power");
    assert_eq!(
        a.metrics.core_energy_j.to_bits(),
        b.metrics.core_energy_j.to_bits(),
        "{ctx}: core energy"
    );
    assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}: sim time");
    assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}: frames");
    assert_eq!(a.faults, b.faults, "{ctx}: fault ledger");
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(
            a.metrics.sim_latency_us.quantile(q).to_bits(),
            b.metrics.sim_latency_us.quantile(q).to_bits(),
            "{ctx}: sim latency q{q}"
        );
    }
}

/// Serve `k` round-robin sessions × `frames` frames through one engine
/// configured with `lanes`, draining once per round (so every drain's
/// pending set holds one frame per session — the lane grouper's
/// steady-state shape). Optionally arms `fault` on session 0.
fn serve(
    net: &Network,
    mode: SimMode,
    workers: usize,
    lanes: usize,
    k: usize,
    frames: usize,
    fault: Option<FaultPlan>,
) -> Vec<(usize, ServingReport)> {
    let cfg = EngineConfig { mode, workers, lanes, ..Default::default() };
    let mut engine = Engine::new(net, cfg).unwrap();
    if let Some(plan) = fault {
        engine.open_session(0).unwrap();
        engine.set_fault_plan(0, plan).unwrap();
    }
    let mut srcs: Vec<DvsSource> = (0..k).map(|s| source_for(net, s)).collect();
    for _ in 0..frames {
        for (s, src) in srcs.iter_mut().enumerate() {
            engine.submit(s, src.next_frame()).unwrap();
        }
        engine.drain().unwrap();
    }
    engine.finish_all()
}

#[test]
fn lane_batched_serving_matches_serial() {
    // The tentpole byte-identity gate: K ∈ {1, 2, 3, 5, 8} sessions
    // through the 8-lane front-end vs lanes = 1, both sim modes, serial
    // and pooled engines — every per-session ledger bit must agree.
    let net = dvs_hybrid_random(16, 5, 0.5);
    for mode in [SimMode::Fast, SimMode::Accurate] {
        for workers in [1usize, 3] {
            for k in [1usize, 2, 3, 5, 8] {
                let serial = serve(&net, mode, workers, 1, k, 3, None);
                let batched = serve(&net, mode, workers, 8, k, 3, None);
                assert_eq!(serial.len(), batched.len());
                for ((s, mut rs), (_, mut rb)) in serial.into_iter().zip(batched) {
                    assert_identical(
                        &mut rb,
                        &mut rs,
                        &format!("{mode:?} workers={workers} K={k} session {s}"),
                    );
                }
            }
        }
    }
}

#[test]
fn ragged_lane_groups_match_serial() {
    // lanes = 3 with K ∈ {5, 8} same-net sessions chunks the drain into
    // full units plus a ragged last group (3+2, 3+3+2); raggedness must
    // not perturb a single bit, serial or pooled.
    let net = dvs_hybrid_random(16, 5, 0.5);
    for workers in [1usize, 3] {
        for k in [5usize, 8] {
            let serial = serve(&net, SimMode::Fast, workers, 1, k, 3, None);
            let ragged = serve(&net, SimMode::Fast, workers, 3, k, 3, None);
            for ((s, mut rs), (_, mut rb)) in serial.into_iter().zip(ragged) {
                assert_identical(
                    &mut rb,
                    &mut rs,
                    &format!("ragged lanes=3 workers={workers} K={k} session {s}"),
                );
            }
        }
    }
}

#[test]
fn mixed_net_sessions_never_share_a_lane() {
    // Two registered nets with identical geometry but different
    // fingerprints: the lane grouper must key on the fingerprint, so
    // alternately-bound sessions lane-batch only within their own net
    // and the reports stay byte-identical to serial serving.
    let net_a = dvs_hybrid_random(16, 5, 0.5);
    let net_b = dvs_hybrid_random(16, 6, 0.5);
    let mut reg = NetRegistry::new();
    let fp_a = reg.add(net_a.clone()).unwrap();
    let fp_b = reg.add(net_b).unwrap();
    assert_ne!(fp_a, fp_b, "different weights must fingerprint differently");
    let registry = Arc::new(reg);

    let serve_mixed = |lanes: usize| -> Vec<(usize, ServingReport)> {
        let cfg = EngineConfig { mode: SimMode::Fast, workers: 1, lanes, ..Default::default() };
        let mut engine = Engine::with_registry(Arc::clone(&registry), cfg).unwrap();
        for s in 0..6 {
            engine.open_session_on(s, if s % 2 == 0 { fp_a } else { fp_b }).unwrap();
        }
        let mut srcs: Vec<DvsSource> = (0..6).map(|s| source_for(&net_a, s)).collect();
        for _ in 0..3 {
            for (s, src) in srcs.iter_mut().enumerate() {
                engine.submit(s, src.next_frame()).unwrap();
            }
            engine.drain().unwrap();
        }
        engine.finish_all()
    };
    let serial = serve_mixed(1);
    let batched = serve_mixed(8);
    for ((s, mut rs), (_, mut rb)) in serial.into_iter().zip(batched) {
        assert_identical(&mut rb, &mut rs, &format!("mixed-net session {s}"));
    }
}

#[test]
fn armed_fault_plan_serves_identically_lane_batched() {
    // A fault plan armed on one session of a lane-batched fleet: the
    // injection path (phase 2, per-session state surfaces) must see the
    // same pre-fault words whether the CNN ran lane-batched or serial,
    // so the whole fault ledger agrees bit for bit — and actually fires.
    let net = dvs_hybrid_random(16, 5, 0.5);
    let plan = FaultPlan::with_ber(FaultSurface::ActMem, 2e-3, 99);
    let serial = serve(&net, SimMode::Fast, 1, 1, 5, 4, Some(plan));
    let batched = serve(&net, SimMode::Fast, 1, 8, 5, 4, Some(plan));
    assert!(
        batched.iter().any(|(_, r)| r.faults.injected_flips > 0),
        "the armed plan must actually inject at this BER"
    );
    for ((s, mut rs), (_, mut rb)) in serial.into_iter().zip(batched) {
        assert_identical(&mut rb, &mut rs, &format!("faulted lane fleet session {s}"));
    }
}
