//! Equivalence suite for the packed TCN tail (perf pass iteration 9):
//! the (pos, mask) feature words now flow from the CNN's 1×1 feature
//! map through the TCN memory ring, the §4 wrap images and the
//! classifier's last-step read without ever round-tripping through i8.
//! Every test here pins the packed path bit-exact against the retained
//! i8 reference — `TcnMemory::window` + `mapping::map_input` +
//! `Scheduler::run_tcn_i8` — the same retained-oracle methodology as
//! the PR 2 packed-dataflow suite (`tests/packed.rs`):
//!
//! 1. a seeded property sweep over (depth, channels, feature width,
//!    dilation, sparsity, occupancy) — cold-start, exactly-full and
//!    post-eviction windows — asserting the packed window and the
//!    port-built wrap image equal the i8 `window`/`map_input` chain
//!    word for word, with identical read charges;
//! 2. whole-net packed-vs-i8 serving equivalence on the EXPERIMENTS
//!    §Anchors DVS workload (`report::dvs_workload`): logits, labels,
//!    every per-layer activity counter (incl. `tcn_reads`), the TCN
//!    memory's own `pushes`/`reads`/`shift_toggles` and the energy
//!    model's f64 bits, per frame, through cold start and eviction;
//! 3. the mapped-vs-direct strategy cross-check on the same workload.

use tcn_cutie::cutie::{CutieConfig, LayerStats, Scheduler, SimMode, TcnMemory};
use tcn_cutie::energy::{evaluate, EnergyParams};
use tcn_cutie::mapping;
use tcn_cutie::report;
use tcn_cutie::tensor::{PackedMap, TritTensor};
use tcn_cutie::trit::PackedVec;
use tcn_cutie::util::rng::Rng;

/// Slice the (T, C_hw) i8 window down to `feat_ch` channels as a
/// (T, 1, C_f) tensor — the reference the packed window must match.
fn slice_window(w: &TritTensor, feat_ch: usize) -> TritTensor {
    let (t_len, chw) = (w.dims[0], w.dims[1]);
    let mut out = TritTensor::zeros(&[t_len, 1, feat_ch]);
    for t in 0..t_len {
        for c in 0..feat_ch {
            out.data[t * feat_ch + c] = w.data[t * chw + c];
        }
    }
    out
}

#[test]
fn packed_window_and_wrap_image_match_i8_path_sweep() {
    let mut rng = Rng::new(9001);
    for case in 0..120 {
        let depth = 1 + rng.below(24);
        let channels = [4, 21, 64, 96, 128][rng.below(5)];
        let feat_ch = 1 + rng.below(channels);
        let zf = [0.0, 0.33, 0.66, 0.95][case % 4];
        // occupancy grid: cold start (< depth), exactly full, and
        // post-eviction (> depth pushes)
        let pushes = [0, 1, depth.saturating_sub(1).max(1), depth, depth + 1 + rng.below(6)]
            [case % 5];

        let mut pm = TcnMemory::new(depth, channels);
        let mut im = TcnMemory::new(depth, channels);
        for p in 0..pushes {
            // alternate realistic pushes (non-zero only below feat_ch,
            // as the CNN produces) with adversarial full-width ones
            // (junk above feat_ch that the port must mask off, matching
            // the i8 path's channel slice)
            let width = if p % 3 == 2 { channels } else { feat_ch };
            let mut v = vec![0i8; channels];
            for t in v.iter_mut().take(width) {
                *t = rng.trit(zf);
            }
            im.push(&v);
            pm.push_packed(PackedVec::pack(&v));
        }
        assert_eq!(pm.len(), im.len());
        assert_eq!(pm.is_full(), pushes >= depth);
        assert_eq!(pm.shift_toggles, im.shift_toggles, "case {case}: shift toggles");

        // packed window == sliced i8 window, with identical read charges
        let w = im.window();
        let pw = pm.packed_window(feat_ch);
        let ctx = format!("case {case} depth={depth} ch={channels} f={feat_ch} n={pushes}");
        assert_eq!(pw, PackedMap::from_trit(&slice_window(&w, feat_ch)), "{ctx}: window");
        assert_eq!(pm.reads, im.reads, "{ctx}: port reads");

        // the port-built wrap image == pack(map_input(sliced window)),
        // for every DVS dilation that fits, charging window-equal reads
        for d in [1, 2, 4, 8] {
            let reads_p = pm.reads;
            let z = pm.wrap_image(d, feat_ch);
            let seq = TritTensor::from_vec(
                &[depth, feat_ch],
                slice_window(&w, feat_ch).data.clone(),
            );
            let zi = mapping::map_input(&seq, d);
            assert_eq!(z, PackedMap::from_trit(&zi), "{ctx}: wrap d={d}");
            // the port charges one read per resident step, like window()
            assert_eq!(pm.reads - reads_p, pm.len() as u64, "{ctx}: wrap reads d={d}");
            // the packed wrapper over an explicit sequence agrees too
            let pseq = PackedMap::from_trit(&TritTensor::from_vec(
                &[depth, 1, feat_ch],
                seq.data.clone(),
            ));
            assert_eq!(mapping::map_input_packed(&pseq, d), z, "{ctx}: map_input_packed d={d}");
        }
    }
}

/// Datapath + scheduler counters that must be representation-invariant
/// between the packed tail and the retained i8 marshalling tail.
fn assert_layer_counters_equal(p: &LayerStats, i: &LayerStats, ctx: &str) {
    assert_eq!(p.name, i.name, "{ctx}: layer order");
    assert_eq!(p.mac_toggles, i.mac_toggles, "{ctx}: mac_toggles");
    assert_eq!(p.mac_idle, i.mac_idle, "{ctx}: mac_idle");
    assert_eq!(p.compute_cycles, i.compute_cycles, "{ctx}: compute_cycles");
    assert_eq!(p.lb_fill_cycles, i.lb_fill_cycles, "{ctx}: lb_fill_cycles");
    assert_eq!(p.drain_cycles, i.drain_cycles, "{ctx}: drain_cycles");
    assert_eq!(p.stall_cycles, i.stall_cycles, "{ctx}: stall_cycles");
    assert_eq!(p.weight_load_cycles, i.weight_load_cycles, "{ctx}: weight_load_cycles");
    assert_eq!(p.weight_words, i.weight_words, "{ctx}: weight_words");
    assert_eq!(p.act_reads, i.act_reads, "{ctx}: act_reads");
    assert_eq!(p.act_writes, i.act_writes, "{ctx}: act_writes");
    assert_eq!(p.lb_pushes, i.lb_pushes, "{ctx}: lb_pushes");
    assert_eq!(p.tcn_reads, i.tcn_reads, "{ctx}: tcn_reads");
    assert_eq!(p.tcn_pushes, i.tcn_pushes, "{ctx}: tcn_pushes");
    assert_eq!(p.hw_ops, i.hw_ops, "{ctx}: hw_ops");
    assert_eq!(p.alg_macs, i.alg_macs, "{ctx}: alg_macs");
    assert_eq!(p.active_ocus, i.active_ocus, "{ctx}: active_ocus");
    assert_eq!(p.fanin, i.fanin, "{ctx}: fanin");
}

/// Whole-net serving equivalence pinned on the EXPERIMENTS §Anchors DVS
/// workload: 30 frames (> the 24-step window: cold start, fill-up and
/// post-eviction steady state) served by the packed tail vs the same
/// CNN + the retained i8 marshalling tail. Logits, all per-layer
/// counters, the TCN memory's own ledger and the energy model's f64
/// bits must be identical frame by frame, in both sim modes.
#[test]
fn dvs_serving_packed_tail_bit_exact_vs_i8_reference() {
    let (net, frames) = report::dvs_workload(30);
    let params = EnergyParams::default();
    for mode in [SimMode::Accurate, SimMode::Fast] {
        let mut packed = Scheduler::new(CutieConfig::kraken(), mode);
        let mut i8ref = Scheduler::new(CutieConfig::kraken(), mode);
        packed.preload_weights(&net);
        i8ref.preload_weights(&net);
        for (i, f) in frames.iter().enumerate() {
            let ctx = format!("mode={mode:?} frame={i}");
            let (lp, rp) = packed.serve_frame(&net, f).unwrap();
            // i8 reference: identical CNN front-end, then the retained
            // marshalling tail (i8 push, window, map_input, i8 slice)
            let (feat, mut ri) = i8ref.run_cnn(&net, f).unwrap();
            let mut padded = feat.pixel(0, 0).unpack(feat.c);
            padded.resize(96, 0);
            i8ref.tcn_mem.push(&padded);
            let (li, rt) = i8ref.run_tcn_i8(&net).unwrap();
            ri.merge(rt);

            assert_eq!(lp, li, "{ctx}: logits");
            assert_eq!(lp.argmax(), li.argmax(), "{ctx}: label");
            assert_eq!(rp.dma_cycles, ri.dma_cycles, "{ctx}: dma_cycles");
            assert_eq!(rp.dma_bytes, ri.dma_bytes, "{ctx}: dma_bytes");
            assert_eq!(rp.layers.len(), ri.layers.len(), "{ctx}: layer count");
            for (p, w) in rp.layers.iter().zip(&ri.layers) {
                assert_layer_counters_equal(p, w, &format!("{ctx} layer {}", p.name));
            }
            // the TCN memory's own activity ledger
            assert_eq!(packed.tcn_mem.pushes, i8ref.tcn_mem.pushes, "{ctx}: tcn pushes");
            assert_eq!(packed.tcn_mem.reads, i8ref.tcn_mem.reads, "{ctx}: tcn reads");
            assert_eq!(
                packed.tcn_mem.shift_toggles,
                i8ref.tcn_mem.shift_toggles,
                "{ctx}: tcn shift toggles"
            );
            // energy model consumes only the counters above — f64-bit equal
            let ep = evaluate(&rp, 0.5, None, &params).unwrap();
            let ei = evaluate(&ri, 0.5, None, &params).unwrap();
            assert_eq!(ep.energy_j.to_bits(), ei.energy_j.to_bits(), "{ctx}: energy bits");
            assert_eq!(ep.time_s.to_bits(), ei.time_s.to_bits(), "{ctx}: time bits");
        }
        assert!(packed.tcn_mem.is_full(), "30 frames must fill the 24-step window");
    }
}

/// The A2 cross-check on the same workload: the direct-strided strategy
/// (which routes through the i8 reference tail) must agree with the
/// packed mapped tail on every label, while stalling.
#[test]
fn dvs_packed_mapped_agrees_with_direct_strategy() {
    let (net, frames) = report::dvs_workload(8);
    let mut mapped = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
    let mut direct = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate)
        .with_tcn_strategy(tcn_cutie::cutie::TcnStrategy::Direct);
    let mut stalls_d = 0;
    for (i, f) in frames.iter().enumerate() {
        let (lm, rm) = mapped.serve_frame(&net, f).unwrap();
        let (ld, rd) = direct.serve_frame(&net, f).unwrap();
        assert_eq!(lm, ld, "frame {i}: strategies must agree bitwise");
        assert_eq!(rm.stall_cycles(), 0, "frame {i}: mapped must be stall-free");
        stalls_d += rd.stall_cycles();
    }
    assert!(stalls_d > 0, "direct strided access must stall");
}
