//! Autonomous serving coordinator — the §5 data-to-label flow as a
//! runnable system: a (synthetic) DVS camera streams event frames over
//! µDMA; each frame triggers a CNN inference whose feature vector shifts
//! into the TCN memory; the TCN back-end classifies the 24-step window;
//! CUTIE's done-interrupt wakes the fabric controller for label readout.
//!
//! The coordinator owns the event loop, the process topology (producer /
//! inference threads over bounded channels — tokio is unavailable in this
//! offline environment, std threads are used), metrics, and the SoC
//! energy ledger.

pub mod metrics;
pub mod pipeline;
pub mod source;

pub use metrics::ServingMetrics;
pub use pipeline::{Pipeline, PipelineConfig, ServingReport};
pub use source::{DvsSource, GestureClass};
