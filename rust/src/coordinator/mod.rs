//! Autonomous serving coordinator — the §5 data-to-label flow as a
//! runnable system: a (synthetic) DVS camera streams event frames over
//! µDMA; each frame triggers a CNN inference whose feature vector shifts
//! into the TCN memory; the TCN back-end classifies the 24-step window;
//! CUTIE's done-interrupt wakes the fabric controller for label readout.
//!
//! The coordinator owns the serving surface (api_redesign pass): frame
//! production behind the [`FrameSource`] trait (synthetic camera,
//! replayable packed word-streams, mixers), per-stream recurrent state
//! in [`Session`]s, and the multi-stream [`Engine`] whose
//! submit/drain path every topology policy — inline, threaded
//! producer/consumer (std threads over bounded channels; tokio is
//! unavailable in this offline environment), batched worker-pool — is a
//! thin wrapper over. Scaling past one simulated accelerator, the
//! [`Fleet`] shards sessions across N engines (one shared net registry,
//! pluggable routing, typed back-pressure) and live-migrates sessions
//! between them over the hibernation snapshot path, byte-identically.
//! Multi-workload serving routes every frame through the [`NetRegistry`]
//! (fingerprint → net + prepared image): each session binds one
//! registered net, and heterogeneous streams — the paper's DVS-gesture
//! TCN next to its cifar9 CNN — interleave through the same engines.

pub mod engine;
pub mod fleet;
pub mod hibernate;
pub mod metrics;
pub mod pipeline;
pub mod registry;
pub mod session;
pub mod source;
pub mod stream;

pub use engine::{Engine, EngineConfig};
pub use fleet::{
    DrainOrder, EngineLoad, Fleet, FleetConfig, FleetError, FleetReport, Rejected, ShardPolicy,
    DEFAULT_QUEUE_CAP,
};
pub use hibernate::{HibernationStats, SessionSnapshot, SessionStore, SnapshotError};
pub use metrics::{NetUsage, ReportAccumulator, ServingMetrics, ServingReport};
pub use pipeline::{Pipeline, PipelineConfig};
pub use registry::{BindingError, NetEntry, NetRegistry, SessionGeometry};
pub use session::{Session, FAILURE_LIMIT};
pub use source::{DvsSource, FrameSource, GestureClass, MixedSource, SyntheticSource};
pub use stream::PackedStream;
