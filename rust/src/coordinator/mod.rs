//! Autonomous serving coordinator — the §5 data-to-label flow as a
//! runnable system: a (synthetic) DVS camera streams event frames over
//! µDMA; each frame triggers a CNN inference whose feature vector shifts
//! into the TCN memory; the TCN back-end classifies the 24-step window;
//! CUTIE's done-interrupt wakes the fabric controller for label readout.
//!
//! The coordinator owns the serving surface (api_redesign pass): frame
//! production behind the [`FrameSource`] trait (synthetic camera,
//! replayable packed word-streams, mixers), per-stream recurrent state
//! in [`Session`]s, and the multi-stream [`Engine`] whose
//! submit/drain path every topology policy — inline, threaded
//! producer/consumer (std threads over bounded channels; tokio is
//! unavailable in this offline environment), batched worker-pool — is a
//! thin wrapper over.

pub mod engine;
pub mod hibernate;
pub mod metrics;
pub mod pipeline;
pub mod session;
pub mod source;
pub mod stream;

pub use engine::{Engine, EngineConfig};
pub use hibernate::{HibernationStats, SessionSnapshot, SessionStore, SnapshotError};
pub use metrics::{ServingMetrics, ServingReport};
pub use pipeline::{Pipeline, PipelineConfig};
pub use session::{Session, FAILURE_LIMIT};
pub use source::{DvsSource, FrameSource, GestureClass, MixedSource};
pub use stream::PackedStream;
