//! Multi-workload net registry (ISSUE 9 tentpole): the immutable map
//! from prepared-image fingerprint → (network geometry, shared
//! [`Arc<PreparedNet>`] image) that every serving layer routes through.
//!
//! A [`NetRegistry`] is built once at boot and shared by all engines of
//! a fleet — the multi-net generalization of PR 5's "one Arc'd image per
//! engine". Each [`crate::coordinator::Session`] carries a
//! [`SessionGeometry`] binding (fingerprint + the input/window dims
//! every frame is checked against), hibernation snapshots record the
//! bound fingerprint, and resume/migration re-binds through this map —
//! a fingerprint absent from the registry is a typed [`BindingError`],
//! never a silent resume onto the wrong weights.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::cutie::{CutieConfig, PreparedNet};
use crate::network::Network;

/// Typed serving-binding failures: every way a session, frame or
/// snapshot can disagree with the registry about which net it runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BindingError {
    /// The fingerprint names no registered net.
    UnknownNet { fingerprint: u64 },
    /// A submitted frame's dims don't match the session's bound net.
    FrameShape {
        session: usize,
        got: (usize, usize, usize),
        want: (usize, usize, usize),
    },
    /// The session is already bound to a different net.
    Rebind { session: usize, bound: u64, requested: u64 },
    /// A hibernated snapshot is bound to a net this registry does not
    /// hold — the record is refused (and left in the store), not
    /// resumed onto the wrong weights.
    SnapshotNet { session: usize, fingerprint: u64 },
}

impl fmt::Display for BindingError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BindingError::UnknownNet { fingerprint } => {
                write!(f, "net {fingerprint:#018x} is not in the serving registry")
            }
            BindingError::FrameShape { session, got, want } => write!(
                f,
                "session {session}: frame is {}x{}x{}, bound net wants {}x{}x{}",
                got.0, got.1, got.2, want.0, want.1, want.2
            ),
            BindingError::Rebind { session, bound, requested } => write!(
                f,
                "session {session} is bound to net {bound:#018x}, \
                 cannot rebind to {requested:#018x}"
            ),
            BindingError::SnapshotNet { session, fingerprint } => write!(
                f,
                "session {session}: snapshot is bound to net {fingerprint:#018x}, \
                 which is not in the serving registry"
            ),
        }
    }
}

impl std::error::Error for BindingError {}

/// Per-session geometry derived from the bound net + hardware config —
/// the typed replacement for the loose `(tcn_depth, channels)` scalars
/// `Session::new` used to take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionGeometry {
    /// Content fingerprint of the bound prepared image.
    pub fingerprint: u64,
    /// Input frame side length (frames are square).
    pub input_hw: usize,
    /// Input frame channel count.
    pub input_ch: usize,
    /// Hardware TCN ring depth (time steps) backing the session window.
    pub tcn_depth: usize,
    /// Hardware datapath channel width backing the session window.
    pub channels: usize,
    /// Whether the bound net has a recurrent TCN tail (DVS-style) or is
    /// pure feed-forward (cifar9-style — the classifier reads the CNN
    /// feature map directly, nothing is pushed into the ring).
    pub has_tcn: bool,
}

impl SessionGeometry {
    /// Derive a session binding from `net` served on `cfg` hardware.
    /// The TCN window dims are the *hardware* ring (depth × datapath
    /// channels), not the net's — exactly what the engine always
    /// allocated per session.
    pub fn of(net: &Network, cfg: &CutieConfig, fingerprint: u64) -> Self {
        SessionGeometry {
            fingerprint,
            input_hw: net.input_hw,
            input_ch: net.layers.first().map_or(0, |l| l.in_ch),
            tcn_depth: cfg.tcn_depth,
            channels: cfg.channels,
            has_tcn: net.has_tcn(),
        }
    }
}

/// One registered workload: the network (geometry + i8 weights for the
/// oracle paths) and its shared prepared image.
#[derive(Debug)]
pub struct NetEntry {
    net: Network,
    image: Arc<PreparedNet>,
    geometry: SessionGeometry,
}

impl NetEntry {
    pub fn net(&self) -> &Network {
        &self.net
    }

    pub fn image(&self) -> &Arc<PreparedNet> {
        &self.image
    }

    pub fn geometry(&self) -> SessionGeometry {
        self.geometry
    }

    pub fn fingerprint(&self) -> u64 {
        self.geometry.fingerprint
    }
}

/// Immutable fingerprint → net map, built once and shared (behind an
/// `Arc`) by every engine of a fleet. The first registered net is the
/// default binding for sessions that don't name one, which is how every
/// pre-registry single-net path keeps its exact behavior.
#[derive(Debug, Default)]
pub struct NetRegistry {
    entries: Vec<NetEntry>,
    by_fp: BTreeMap<u64, usize>,
}

impl NetRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// One-net registry — the single-workload serving setup.
    pub fn single(net: Network) -> Result<Self> {
        let mut reg = Self::new();
        reg.add(net)?;
        Ok(reg)
    }

    /// One-net registry behind an existing image (packed `.ttn` boot).
    pub fn single_with_image(net: Network, image: Arc<PreparedNet>) -> Result<Self> {
        let mut reg = Self::new();
        reg.add_with_image(net, image)?;
        Ok(reg)
    }

    /// Register a net, packing its prepared image from the i8 weights.
    pub fn add(&mut self, net: Network) -> Result<u64> {
        let image = Arc::new(PreparedNet::new(&net, &CutieConfig::kraken()));
        self.add_with_image(net, image)
    }

    /// Register a net behind an existing prepared image. The image is
    /// fully validated against the network (coverage, geometry,
    /// thresholds) — a stale or foreign image is a boot error.
    pub fn add_with_image(&mut self, net: Network, image: Arc<PreparedNet>) -> Result<u64> {
        image
            .validate_against(&net)
            .with_context(|| format!("registering net '{}'", net.name))?;
        ensure!(
            image.matches(&net),
            "prepared image '{}' does not match network '{}'",
            image.net_name(),
            net.name
        );
        let fp = image.fingerprint();
        ensure!(
            !self.by_fp.contains_key(&fp),
            "net '{}' ({fp:#018x}) is already registered",
            net.name
        );
        ensure!(
            self.by_name(&net.name).is_none(),
            "a different net named '{}' is already registered",
            net.name
        );
        let geometry = SessionGeometry::of(&net, &CutieConfig::kraken(), fp);
        self.by_fp.insert(fp, self.entries.len());
        self.entries.push(NetEntry { net, image, geometry });
        Ok(fp)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The net sessions bind to when none is named: first registered.
    pub fn default_fingerprint(&self) -> u64 {
        self.default_entry().fingerprint()
    }

    pub fn default_entry(&self) -> &NetEntry {
        &self.entries[0]
    }

    pub fn get(&self, fingerprint: u64) -> Option<&NetEntry> {
        self.by_fp.get(&fingerprint).map(|&i| &self.entries[i])
    }

    pub fn contains(&self, fingerprint: u64) -> bool {
        self.by_fp.contains_key(&fingerprint)
    }

    /// Typed lookup for the serving path.
    pub fn entry(&self, fingerprint: u64) -> Result<&NetEntry, BindingError> {
        self.get(fingerprint).ok_or(BindingError::UnknownNet { fingerprint })
    }

    pub fn by_name(&self, name: &str) -> Option<&NetEntry> {
        self.entries.iter().find(|e| e.net.name == name)
    }

    /// Entries in registration order (the boot/preload order).
    pub fn entries(&self) -> impl Iterator<Item = &NetEntry> {
        self.entries.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{cifar9_random, dvs_hybrid_random};

    #[test]
    fn registry_holds_nets_in_registration_order() {
        let dvs = dvs_hybrid_random(16, 40, 0.5);
        let cifar = cifar9_random(16, 41, 0.33);
        let mut reg = NetRegistry::new();
        assert!(reg.is_empty());
        let fp_dvs = reg.add(dvs.clone()).unwrap();
        let fp_cifar = reg.add(cifar.clone()).unwrap();
        assert_eq!(reg.len(), 2);
        assert_ne!(fp_dvs, fp_cifar);
        assert_eq!(reg.default_fingerprint(), fp_dvs, "first registered is the default");
        let order: Vec<&str> = reg.entries().map(|e| e.net().name.as_str()).collect();
        assert_eq!(order, [dvs.name.as_str(), cifar.name.as_str()]);
        assert!(reg.contains(fp_cifar));
        assert_eq!(reg.entry(fp_cifar).unwrap().net().name, cifar.name);
        assert_eq!(reg.by_name(&dvs.name).unwrap().fingerprint(), fp_dvs);
        assert_eq!(reg.entry(7).unwrap_err(), BindingError::UnknownNet { fingerprint: 7 });
    }

    #[test]
    fn duplicate_and_mismatched_registrations_are_errors() {
        let net = dvs_hybrid_random(16, 42, 0.5);
        let mut reg = NetRegistry::single(net.clone()).unwrap();
        assert!(reg.add(net.clone()).is_err(), "same image twice must be refused");
        // an image packed for a different net must not register
        let other = Arc::new(PreparedNet::new(
            &dvs_hybrid_random(32, 43, 0.5),
            &CutieConfig::kraken(),
        ));
        assert!(reg.add_with_image(net, other).is_err());
    }

    #[test]
    fn session_geometry_derives_from_the_bound_net() {
        let cfg = CutieConfig::kraken();
        let dvs = dvs_hybrid_random(16, 44, 0.5);
        let g = SessionGeometry::of(&dvs, &cfg, 9);
        assert_eq!(
            g,
            SessionGeometry {
                fingerprint: 9,
                input_hw: 64,
                input_ch: 2,
                tcn_depth: cfg.tcn_depth,
                channels: cfg.channels,
                has_tcn: true,
            }
        );
        let cifar = cifar9_random(16, 45, 0.33);
        let g = SessionGeometry::of(&cifar, &cfg, 3);
        assert_eq!((g.input_hw, g.input_ch, g.has_tcn), (32, 3, false));
    }

    #[test]
    fn binding_errors_name_the_contract() {
        let e = BindingError::UnknownNet { fingerprint: 0xAB };
        assert!(e.to_string().contains("0x00000000000000ab"));
        let e = BindingError::FrameShape { session: 3, got: (64, 64, 2), want: (32, 32, 3) };
        assert!(e.to_string().contains("64x64x2") && e.to_string().contains("32x32x3"));
        let e = BindingError::Rebind { session: 1, bound: 1, requested: 2 };
        assert!(e.to_string().contains("cannot rebind"));
        let e = BindingError::SnapshotNet { session: 5, fingerprint: 1 };
        assert!(e.to_string().contains("snapshot"));
        // BindingError is a std error, so `?` lifts it into anyhow.
        let as_any: anyhow::Error = e.into();
        assert!(as_any.downcast_ref::<BindingError>().is_some());
    }
}
