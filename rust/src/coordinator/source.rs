//! Frame producers for the serving engine.
//!
//! [`FrameSource`] is the engine's packed-native producer abstraction:
//! anything that can hand out bit-packed [`PackedMap`]s frame by frame —
//! the synthetic [`DvsSource`] camera, a replayed
//! [`super::stream::PackedStream`] word-stream, or the deterministic
//! multi-gesture [`MixedSource`]. Sources never touch i8: a frame is
//! born in the representation the µDMA ships and the activation SRAM
//! stores (perf pass iteration 8).
//!
//! [`DvsSource`] itself is the DESIGN.md §2 substitution for the DVS128
//! camera: per-class moving-blob "gestures" (12 directions/arm motions
//! like the DVS128 task) over Poisson background noise, rendered as
//! 2-channel (ON/OFF polarity) ternary frames with the high unstructured
//! sparsity event sensors produce.

use crate::tensor::PackedMap;
use crate::util::rng::Rng;

/// A pluggable producer of packed event frames.
///
/// `None` means the stream is exhausted (finite sources such as replayed
/// word-streams); camera-like generators never exhaust. Implementations
/// must be deterministic given their construction parameters — the
/// engine's multi-stream determinism guarantee (interleaved == isolated,
/// byte-identical) is only as strong as its sources'.
pub trait FrameSource {
    /// Pull the next packed frame, or `None` once the stream has dried.
    fn next_frame(&mut self) -> Option<PackedMap>;
}

/// 12 gesture classes ≈ the DVS128 label set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GestureClass(pub usize);

pub const NUM_CLASSES: usize = 12;

pub struct DvsSource {
    pub hw: usize,
    /// Events per pixel per frame from background noise (Poisson-ish).
    pub noise_rate: f64,
    /// Blob radius in pixels.
    pub blob_r: f64,
    rng: Rng,
    class: GestureClass,
    t: usize,
    cx: f64,
    cy: f64,
}

impl DvsSource {
    pub fn new(hw: usize, seed: u64, class: GestureClass) -> Self {
        assert!(class.0 < NUM_CLASSES);
        let mut rng = Rng::new(seed);
        let cx = hw as f64 * (0.3 + 0.4 * rng.f64());
        let cy = hw as f64 * (0.3 + 0.4 * rng.f64());
        DvsSource { hw, noise_rate: 0.02, blob_r: 4.0, rng, class, t: 0, cx, cy }
    }

    /// Direction/speed signature of a gesture class: 8 linear directions +
    /// 4 circular motions (2 radii × 2 spins).
    fn velocity(&self) -> (f64, f64) {
        let c = self.class.0;
        if c < 8 {
            let ang = std::f64::consts::TAU * c as f64 / 8.0;
            (2.2 * ang.cos(), 2.2 * ang.sin())
        } else {
            let spin = if c % 2 == 0 { 1.0 } else { -1.0 };
            let radius = if c < 10 { 8.0 } else { 16.0 };
            let phase = spin * 0.45 * self.t as f64;
            (-radius * 0.45 * phase.sin(), radius * 0.45 * phase.cos())
        }
    }

    /// Render the next event frame: (hw, hw, 2) packed trits, channel 0 =
    /// ON events (+1), channel 1 = OFF events (−1 encoded as −1).
    pub fn next_frame(&mut self) -> PackedMap {
        let hw = self.hw;
        let mut frame = PackedMap::zeros(hw, hw, 2);
        // background noise events
        for y in 0..hw {
            for x in 0..hw {
                if self.rng.bool(self.noise_rate) {
                    let ch = self.rng.below(2);
                    frame.set_trit(y, x, ch, if ch == 0 { 1 } else { -1 });
                }
            }
        }
        // moving blob: leading edge fires ON, trailing edge OFF
        let (vx, vy) = self.velocity();
        self.cx = (self.cx + vx).rem_euclid(hw as f64);
        self.cy = (self.cy + vy).rem_euclid(hw as f64);
        let r2 = self.blob_r * self.blob_r;
        let speed = (vx * vx + vy * vy).sqrt().max(1e-6);
        let (dx, dy) = (vx / speed, vy / speed);
        for y in 0..hw {
            for x in 0..hw {
                let ddx = wrapped_delta(x as f64, self.cx, hw as f64);
                let ddy = wrapped_delta(y as f64, self.cy, hw as f64);
                let d2 = ddx * ddx + ddy * ddy;
                if d2 < r2 && self.rng.bool(0.8) {
                    // project onto motion direction: front = ON, back = OFF
                    let along = ddx * dx + ddy * dy;
                    if along >= 0.0 {
                        frame.set_trit(y, x, 0, 1);
                    } else {
                        frame.set_trit(y, x, 1, -1);
                    }
                }
            }
        }
        self.t += 1;
        frame
    }

    pub fn class(&self) -> GestureClass {
        self.class
    }
}

impl FrameSource for DvsSource {
    /// The synthetic camera never runs dry.
    fn next_frame(&mut self) -> Option<PackedMap> {
        Some(DvsSource::next_frame(self))
    }
}

/// Deterministic dense-frame generator for arbitrary input geometries —
/// the camera substitute for workloads that are not event streams (e.g.
/// the cifar9 CNN's 32×32×3 images, CUTIE's second headline workload).
/// Frames are seeded ternary noise at a fixed zero fraction; like every
/// source, the stream is a pure function of its construction parameters.
pub struct SyntheticSource {
    hw: usize,
    ch: usize,
    /// Fraction of zero trits per frame (1 − density).
    pub zero_frac: f64,
    rng: Rng,
}

impl SyntheticSource {
    pub fn new(hw: usize, ch: usize, seed: u64) -> Self {
        SyntheticSource { hw, ch, zero_frac: 0.7, rng: Rng::new(seed) }
    }

    /// Render the next (hw, hw, ch) packed frame.
    pub fn next_frame(&mut self) -> PackedMap {
        let t = crate::tensor::TritTensor::random(
            &[self.hw, self.hw, self.ch],
            &mut self.rng,
            self.zero_frac,
        );
        PackedMap::from_trit(&t)
    }
}

impl FrameSource for SyntheticSource {
    /// The synthetic generator never runs dry.
    fn next_frame(&mut self) -> Option<PackedMap> {
        Some(SyntheticSource::next_frame(self))
    }
}

/// Deterministic multi-gesture mixer: round-robins over its inner
/// sources, skipping exhausted ones, until every source has dried. The
/// schedule depends only on construction order, so a mixed stream is as
/// replayable as its parts.
pub struct MixedSource {
    sources: Vec<Box<dyn FrameSource>>,
    next: usize,
}

impl MixedSource {
    pub fn new(sources: Vec<Box<dyn FrameSource>>) -> Self {
        MixedSource { sources, next: 0 }
    }

    /// One synthetic DVS generator per gesture class in `classes`, seeded
    /// `seed`, `seed + 1`, … in order.
    pub fn of_gestures(hw: usize, seed: u64, classes: &[usize]) -> Self {
        let sources = classes
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                Box::new(DvsSource::new(hw, seed + i as u64, GestureClass(c)))
                    as Box<dyn FrameSource>
            })
            .collect();
        MixedSource::new(sources)
    }
}

impl FrameSource for MixedSource {
    fn next_frame(&mut self) -> Option<PackedMap> {
        for _ in 0..self.sources.len() {
            let i = self.next;
            self.next = (self.next + 1) % self.sources.len();
            if let Some(f) = self.sources[i].next_frame() {
                return Some(f);
            }
        }
        None
    }
}

fn wrapped_delta(a: f64, b: f64, period: f64) -> f64 {
    let mut d = a - b;
    if d > period / 2.0 {
        d -= period;
    }
    if d < -period / 2.0 {
        d += period;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_are_sparse_and_ternary() {
        let mut src = DvsSource::new(64, 7, GestureClass(3));
        for _ in 0..5 {
            let f = src.next_frame();
            assert_eq!((f.h, f.w, f.c), (64, 64, 2));
            let sparsity = f.sparsity();
            assert!(sparsity > 0.9, "DVS frames must be sparse, got {sparsity}");
            assert!(f.unpack_data().iter().all(|t| (-1..=1).contains(t)));
            // polarity encoding: ch0 ∈ {0,1}, ch1 ∈ {-1,0}
            for y in 0..64 {
                for x in 0..64 {
                    assert!(f.get_trit(y, x, 0) >= 0);
                    assert!(f.get_trit(y, x, 1) <= 0);
                }
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = DvsSource::new(32, 42, GestureClass(0));
        let mut b = DvsSource::new(32, 42, GestureClass(0));
        assert_eq!(a.next_frame(), b.next_frame());
    }

    #[test]
    fn synthetic_source_matches_its_geometry_and_seed() {
        let mut a = SyntheticSource::new(32, 3, 11);
        let mut b = SyntheticSource::new(32, 3, 11);
        let f = a.next_frame();
        assert_eq!((f.h, f.w, f.c), (32, 32, 3));
        assert!(f.unpack_data().iter().all(|t| (-1..=1).contains(t)));
        assert_eq!(f, b.next_frame());
        assert_ne!(a.next_frame(), f, "the stream advances");
        let mut c = SyntheticSource::new(32, 3, 12);
        assert_ne!(c.next_frame(), f, "seeds decorrelate streams");
    }

    #[test]
    fn classes_produce_different_streams() {
        let mut a = DvsSource::new(32, 42, GestureClass(0));
        let mut b = DvsSource::new(32, 42, GestureClass(4));
        // advance a few frames; the motion signatures must diverge
        let mut diff = 0usize;
        for _ in 0..4 {
            let fa = a.next_frame();
            let fb = b.next_frame();
            diff += fa.pixels.iter().zip(&fb.pixels).filter(|(x, y)| x != y).count();
        }
        assert!(diff > 0);
    }

    #[test]
    fn mixer_round_robins_deterministically() {
        // The mixer must interleave its inner streams in construction
        // order, frame for frame identical to driving clones by hand.
        let mut mixed = MixedSource::of_gestures(16, 50, &[0, 4, 9]);
        let mut a = DvsSource::new(16, 50, GestureClass(0));
        let mut b = DvsSource::new(16, 51, GestureClass(4));
        let mut c = DvsSource::new(16, 52, GestureClass(9));
        for _ in 0..4 {
            assert_eq!(FrameSource::next_frame(&mut mixed), Some(a.next_frame()));
            assert_eq!(FrameSource::next_frame(&mut mixed), Some(b.next_frame()));
            assert_eq!(FrameSource::next_frame(&mut mixed), Some(c.next_frame()));
        }
    }

    #[test]
    fn mixer_skips_exhausted_sources() {
        struct Finite(usize);
        impl FrameSource for Finite {
            fn next_frame(&mut self) -> Option<PackedMap> {
                if self.0 == 0 {
                    return None;
                }
                self.0 -= 1;
                Some(PackedMap::zeros(2, 2, 1))
            }
        }
        let mut m = MixedSource::new(vec![
            Box::new(Finite(1)) as Box<dyn FrameSource>,
            Box::new(Finite(3)),
        ]);
        let mut served = 0;
        while FrameSource::next_frame(&mut m).is_some() {
            served += 1;
        }
        assert_eq!(served, 4);
        assert!(FrameSource::next_frame(&mut m).is_none());
    }

    #[test]
    fn blob_moves() {
        let mut src = DvsSource::new(64, 9, GestureClass(2));
        src.noise_rate = 0.0;
        let f1 = src.next_frame();
        let mut last_same = true;
        for _ in 0..3 {
            let f2 = src.next_frame();
            if f1 != f2 {
                last_same = false;
            }
        }
        assert!(!last_same, "blob must move between frames");
    }
}
