//! The multi-stream serving engine — the one serve path every topology
//! policy (inline / threaded / batched, `N`-stream CLI serving) is a
//! thin wrapper over.
//!
//! Split of responsibilities (the api_redesign tentpole):
//!
//! * *who produces frames* — any [`FrameSource`] (live synthetic camera,
//!   replayed word-stream, mixer); the engine never constructs sources;
//! * *which stream a frame belongs to* — the `session_id` of
//!   [`Engine::submit`]; each [`Session`] owns its stream's recurrent
//!   state (TCN window, SoC ledger, labels, metrics);
//! * *how work is scheduled* — [`Engine::drain`] runs the stateless CNN
//!   front-end of all pending frames across a pool of preloaded worker
//!   [`Scheduler`]s (round-robin sharding, the dominant per-frame cost),
//!   then reduces each frame's stateful tail — TCN-window push + TCN
//!   inference + SoC timeline — in submission order, which preserves
//!   per-session frame order.
//!
//! Determinism: every counter the energy model consumes is
//! sharding-invariant (the datapath's counters are analytic in the
//! geometry and toggle sums are order-independent), workers adopt the
//! tail's booted weight banks so their accesses are the same
//! steady-state bank switches the inline scheduler charges, and all
//! cross-frame recurrent state is per-session (checked out into the
//! tail scheduler per frame via [`Scheduler::swap_tcn`]). Interleaving
//! K sessions through one engine is therefore byte-identical to serving
//! each stream alone — asserted for K ∈ {1, 2, 5} and both [`SimMode`]s
//! in `tests/engine.rs`.
//!
//! Weight image (shared-image pass): the engine holds **exactly one**
//! [`PreparedNet`] behind an [`Arc`] — built once from the network (or
//! word-copy-loaded from a packed `.ttn` v2 via [`Engine::with_image`])
//! and borrowed by the tail and every pool worker. Spawning a worker no
//! longer re-packs or clones a single weight word, which is what makes
//! wide pools (and, next, multi-engine sharding) cheap — the software
//! twin of CUTIE's boot-once, stay-resident OCU weight buffers.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::metrics::{ServingMetrics, ServingReport};
use super::session::{FaultState, Session};
use super::source::FrameSource;
use crate::cutie::{CutieConfig, PreparedNet, RunStats, Scheduler, SimMode};
use crate::energy::{evaluate, EnergyParams};
use crate::fault::{FaultPlan, FaultSummary, FaultSurface, FrameFaults, Injector};
use crate::network::Network;
use crate::tensor::PackedMap;

/// Attempts the stateful TCN tail gets per frame before the frame is
/// declared a terminal failure (one retry).
const TCN_ATTEMPTS: u32 = 2;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub voltage: f64,
    /// Clock override (None → fmax(V)).
    pub freq_hz: Option<f64>,
    pub mode: SimMode,
    /// CNN front-end pool width: 1 → serial (fully inline), 0 → one
    /// worker per available core.
    pub workers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { voltage: 0.5, freq_hz: None, mode: SimMode::Accurate, workers: 1 }
    }
}

pub struct Engine<'n> {
    net: &'n Network,
    cfg: EngineConfig,
    params: EnergyParams,
    /// The one prepared-weight image every scheduler in this engine
    /// borrows (tail + all pool workers share this `Arc`).
    image: Arc<PreparedNet>,
    /// Stateful tail executor: per-session TCN windows are swapped into
    /// it frame by frame; also runs the CNN when the pool is serial.
    tail: Scheduler,
    /// CNN workers borrowing the shared image (empty when `cfg.workers`
    /// resolves to 1).
    workers: Vec<Scheduler>,
    sessions: BTreeMap<usize, Session>,
    /// Submitted, not yet drained (session, frame, injection ledger)
    /// triples in arrival order. Frame-surface faults (ActMem, µDMA) are
    /// injected at submit time so the ledger rides with its frame.
    pending: Vec<(usize, PackedMap, FrameFaults)>,
}

impl<'n> Engine<'n> {
    pub fn new(net: &'n Network, cfg: EngineConfig) -> Self {
        let image = Arc::new(PreparedNet::new(net, &CutieConfig::kraken()));
        Self::with_image(net, cfg, image).expect("engine config and image valid for this network")
    }

    /// Boot from a pre-built weight image — e.g. one word-copy-loaded
    /// from a packed `.ttn` v2 file, or one shared with other engines.
    /// The image is fully validated against `net` (coverage, geometry,
    /// pooling flags, per-OCU thresholds) before any scheduler borrows
    /// it; only the plane words themselves are taken on trust — see
    /// [`PreparedNet::validate_against`] for that contract.
    pub fn with_image(
        net: &'n Network,
        cfg: EngineConfig,
        image: Arc<PreparedNet>,
    ) -> Result<Self> {
        image.validate_against(net)?;
        ensure!(
            image.matches(net),
            "prepared image '{}' does not match network '{}'",
            image.net_name(),
            net.name
        );
        // Boot-time clock validation: with no explicit clock the energy
        // model derives f_max(V), which has no fit below the device
        // threshold — reject the config here rather than erroring on the
        // first drain. (Sub-0.5 V supplies themselves are legal: that is
        // the fault-injection operating region.)
        if cfg.freq_hz.is_none() {
            crate::energy::fmax_hz(cfg.voltage)?;
        }
        let pool = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        // The tail boots the image into its weight banks (the one
        // modeled weight-streaming charge)...
        let mut tail = Scheduler::new(CutieConfig::kraken(), cfg.mode);
        tail.attach_image(Arc::clone(&image));
        tail.preload_weights(net);
        let workers = if pool <= 1 {
            Vec::new()
        } else {
            // Layer-level row sharding is pinned off inside pool workers
            // (max_threads = 1): frame-level parallelism replaces it
            // without oversubscription. Counters are sharding-invariant.
            let wcfg = CutieConfig { max_threads: 1, ..CutieConfig::kraken() };
            (0..pool)
                .map(|_| {
                    // ...and every worker borrows that image and adopts
                    // the already-filled banks: spawning a worker moves
                    // no weight data, modeled or host-side.
                    let mut s = Scheduler::new(wcfg.clone(), cfg.mode);
                    s.attach_image(Arc::clone(&image));
                    s.adopt_weights(net);
                    s
                })
                .collect()
        };
        Ok(Engine {
            net,
            cfg,
            params: EnergyParams::default(),
            image,
            tail,
            workers,
            sessions: BTreeMap::new(),
            pending: Vec::new(),
        })
    }

    /// The engine's one shared prepared-weight image. `Arc::strong_count`
    /// on it is 2 + pool width (engine + tail + workers) — asserted by
    /// the pool-sharing tests.
    pub fn image(&self) -> &Arc<PreparedNet> {
        &self.image
    }

    /// Pool width (0 workers = serial: the tail runs the CNN too).
    pub fn pool_size(&self) -> usize {
        self.workers.len()
    }

    /// Register (or fetch) a stream's session. `submit` opens sessions
    /// implicitly; opening one explicitly matters only for zero-frame
    /// streams that still want a (empty) report.
    pub fn open_session(&mut self, id: usize) -> &mut Session {
        let voltage = self.cfg.voltage;
        let (depth, channels) = (self.tail.cfg.tcn_depth, self.tail.cfg.channels);
        self.sessions.entry(id).or_insert_with(|| Session::new(id, voltage, depth, channels))
    }

    /// Arm (or replace) a session's fault plan. The injector is seeded
    /// by the plan's seed mixed with the session id, so one plan applied
    /// to many sessions decorrelates their flip streams while every
    /// stream stays individually deterministic. A BER-0 plan is armed
    /// but structurally side-effect-free (no RNG draws, no scrubs).
    pub fn set_fault_plan(&mut self, session_id: usize, plan: FaultPlan) {
        let seed = plan.seed ^ (session_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.open_session(session_id).fault =
            Some(FaultState { plan, inj: Injector::new(plan.ber, seed) });
    }

    /// The session's armed plan, if any.
    pub fn fault_plan(&self, session_id: usize) -> Option<FaultPlan> {
        self.sessions.get(&session_id).and_then(|s| s.fault.as_ref().map(|f| f.plan))
    }

    /// Enqueue one frame on a stream. Work happens at the next `drain`.
    ///
    /// Frame-surface fault injection happens here, in submission order:
    /// an armed ActMem plan corrupts the frame's words as stored in the
    /// activation SRAM and charges a scrub scan over them (detected
    /// orphans are clamped, silent mask flips ride through); an armed
    /// µDMA plan corrupts the words in flight, where the ingress
    /// decoder's plane-invariant check catches orphans for free (no
    /// scrub charge) but silent flips still land.
    pub fn submit(&mut self, session_id: usize, frame: PackedMap) {
        let sess = self.open_session(session_id);
        let mut frame = frame;
        let mut ff = FrameFaults::default();
        if let Some(fs) = sess.fault.as_mut() {
            if fs.plan.is_active() {
                match fs.plan.surface {
                    FaultSurface::ActMem => {
                        ff.flips += fs.inj.corrupt_map(&mut frame);
                        ff.scrub_words += frame.pixels.len() as u64;
                        ff.detected += frame.scrub();
                    }
                    FaultSurface::DmaStream => {
                        ff.flips += fs.inj.corrupt_map(&mut frame);
                        ff.detected += frame.scrub();
                    }
                    FaultSurface::TcnMem | FaultSurface::WeightMem => {}
                }
            }
        }
        self.pending.push((session_id, frame, ff));
    }

    /// Pull up to `max_frames` frames from a source onto a stream;
    /// returns how many the source yielded before drying up.
    pub fn submit_from(
        &mut self,
        session_id: usize,
        src: &mut dyn FrameSource,
        max_frames: usize,
    ) -> usize {
        let mut n = 0;
        while n < max_frames {
            match src.next_frame() {
                Some(f) => {
                    self.submit(session_id, f);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    pub fn pending_frames(&self) -> usize {
        self.pending.len()
    }

    pub fn session_ids(&self) -> Vec<usize> {
        self.sessions.keys().copied().collect()
    }

    pub fn session(&self, id: usize) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Serve every pending frame; returns how many were served (dropped
    /// or terminally failed frames don't count).
    ///
    /// Phase 1 (stateless, parallel): CNN front-ends across the worker
    /// pool. Phase 2 (stateful, sequential): per-frame TCN/SoC tail in
    /// submission order — per-session frame order is preserved because
    /// submission order is.
    ///
    /// Resilience contract: a frame that errors — or a pool worker that
    /// panics — costs at most that frame (and, for a panic, a serial
    /// recompute of the worker's shard on the tail); it never aborts the
    /// drain or poisons other sessions. Failures land in the owning
    /// session's [`FaultSummary`]; at [`super::session::FAILURE_LIMIT`]
    /// the session is quarantined and its remaining frames are dropped.
    pub fn drain(&mut self) -> Result<usize> {
        if self.pending.is_empty() {
            return Ok(0);
        }
        let wall0 = Instant::now();
        let pending = std::mem::take(&mut self.pending);

        // Phase 1: CNN front-end. A frame whose CNN errors leaves its
        // slot None (noted as a failure in phase 2).
        let mut cnn: Vec<Option<(PackedMap, RunStats)>> = vec![None; pending.len()];
        let net = self.net;
        if self.workers.is_empty() {
            for (i, (_, frame, _)) in pending.iter().enumerate() {
                cnn[i] = self.tail.run_cnn(net, frame).ok();
            }
        } else {
            let nw = self.workers.len();
            let (results, poisoned) = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (wi, sched) in self.workers.iter_mut().enumerate() {
                    let pending = &pending;
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = wi;
                        while i < pending.len() {
                            out.push((i, sched.run_cnn(net, &pending[i].1)));
                            i += nw;
                        }
                        out
                    }));
                }
                // Join manually: a panicked worker must cost only its own
                // shard, not (via scope's implicit re-panic) the process.
                let mut results = Vec::new();
                let mut poisoned = Vec::new();
                for (wi, h) in handles.into_iter().enumerate() {
                    match h.join() {
                        Ok(out) => results.push(out),
                        Err(_) => poisoned.push(wi),
                    }
                }
                (results, poisoned)
            });
            for (i, r) in results.into_iter().flatten() {
                cnn[i] = r.ok();
            }
            // Recompute a poisoned worker's shard serially on the tail —
            // the frames, not the worker, are what sessions are owed.
            for wi in poisoned {
                let mut i = wi;
                while i < pending.len() {
                    cnn[i] = self.tail.run_cnn(net, &pending[i].1).ok();
                    i += nw;
                }
            }
        }

        // Phase 2: stateful per-session tail, in submission order.
        let mut served: Vec<(usize, f64, f64)> = Vec::with_capacity(pending.len());
        for ((sid, frame, mut ff), slot) in pending.into_iter().zip(cnn.into_iter()) {
            let Some(sess) = self.sessions.get_mut(&sid) else { continue };
            if sess.is_quarantined() {
                sess.faults.dropped_frames += 1;
                continue;
            }
            let Some((feat, mut run)) = slot else {
                sess.faults.record(&ff, ff.flips > 0);
                sess.note_failure();
                continue;
            };
            // State-surface injection (TCN ring / weight banks), one
            // exposure per frame.
            let mut degraded = ff.flips > 0;
            degraded |= inject_state_surfaces(&self.image, &mut self.tail, sess, &mut ff);
            // Check the stream's recurrent TCN window out into the tail;
            // the packed feature word moves into it as-is (no unpack).
            // Bounded retry: the feature is pushed at most once (a push
            // that landed is not replayed on retry).
            let mut pushed = false;
            let mut tcn_result = Err(anyhow::anyhow!("tcn tail not attempted"));
            for attempt in 0..TCN_ATTEMPTS {
                self.tail.swap_tcn(&mut sess.tcn);
                let r = if pushed { Ok(()) } else { self.tail.push_feature(&feat) };
                let r = match r {
                    Ok(()) => {
                        pushed = true;
                        self.tail.run_tcn(net)
                    }
                    Err(e) => Err(e),
                };
                self.tail.swap_tcn(&mut sess.tcn); // check back in, even on error
                match r {
                    Ok(v) => {
                        tcn_result = Ok(v);
                        break;
                    }
                    Err(e) => {
                        tcn_result = Err(e);
                        if attempt + 1 < TCN_ATTEMPTS {
                            sess.faults.retries += 1;
                        }
                    }
                }
            }
            sess.faults.record(&ff, degraded);
            let (logits, r) = match tcn_result {
                Ok(v) => v,
                Err(_) => {
                    sess.note_failure();
                    continue;
                }
            };
            // A frame lands on the SoC ledger only once it is actually
            // served: ingest + settle stay paired, so a failed frame
            // leaves no dangling frame-ready IRQ behind.
            sess.ingest(&frame);
            run.merge(r);
            // The synthetic fault layer rides only when it has content,
            // so a clean frame's stats are byte-identical to fault-free.
            if ff.any() {
                run.layers.push(ff.to_layer_stats());
            }
            let report = evaluate(&run, self.cfg.voltage, self.cfg.freq_hz, &self.params)?;
            sess.settle(report.time_s, report.energy_j);
            sess.labels.push(logits.argmax());
            served.push((sid, report.time_s * 1e6, report.energy_j));
        }

        // Host wall-clock is a measurement, not a simulation output:
        // amortize the drain across its frames (a 1-frame drain is the
        // inline policy's per-frame latency).
        let n = served.len();
        let wall_us = wall0.elapsed().as_secs_f64() * 1e6 / n.max(1) as f64;
        for (sid, sim_us, core_j) in served {
            if let Some(sess) = self.sessions.get_mut(&sid) {
                sess.metrics.record_frame(sim_us, wall_us, core_j);
            }
        }
        Ok(n)
    }

    /// Close one session into its final report (removes it).
    pub fn finish_session(&mut self, id: usize) -> Option<ServingReport> {
        self.sessions.remove(&id).map(Session::into_report)
    }

    /// Close every session, in session-id order.
    pub fn finish_all(&mut self) -> Vec<(usize, ServingReport)> {
        let ids = self.session_ids();
        ids.into_iter().filter_map(|id| self.finish_session(id).map(|r| (id, r))).collect()
    }

    /// Cross-session roll-up (latency samples concatenate, energies,
    /// wakeups and fault counters sum, labels concatenate in session-id
    /// order). Average SoC power is total energy over total simulated
    /// SoC time.
    pub fn aggregate_report(&self) -> ServingReport {
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        let mut faults = FaultSummary::default();
        let mut energy_j = 0.0;
        let mut fc_wakeups = 0u64;
        let mut now_ns = 0u64;
        for sess in self.sessions.values() {
            metrics.merge(&sess.metrics);
            faults.merge(&sess.faults);
            energy_j += sess.soc.energy_j();
            fc_wakeups += sess.soc.fc_wakeups();
            now_ns += sess.soc.now_ns();
            labels.extend_from_slice(&sess.labels);
        }
        metrics.soc_energy_j = energy_j;
        ServingReport {
            soc_energy_j: energy_j,
            soc_avg_power_w: if now_ns == 0 { 0.0 } else { energy_j / (now_ns as f64 * 1e-9) },
            fc_wakeups,
            metrics,
            labels,
            faults,
        }
    }
}

/// One frame's exposure of an armed state-surface plan (TCN ring or
/// weight banks). A free function so the `&mut Session` (borrowed out of
/// the engine's session map) can coexist with the engine's `tail` and
/// `image` fields. Returns true when the frame's data is degraded —
/// silent corruption survived the scrub pass (repaired weight faults
/// leave the frame clean).
fn inject_state_surfaces(
    image: &PreparedNet,
    tail: &mut Scheduler,
    sess: &mut Session,
    ff: &mut FrameFaults,
) -> bool {
    let Some(fs) = sess.fault.as_mut() else { return false };
    if !fs.plan.is_active() {
        return false;
    }
    match fs.plan.surface {
        FaultSurface::TcnMem => {
            // Corrupt the resident ring words, then run the inter-frame
            // scrub pass over the ring: orphans are clamped (detected),
            // silent flips stay resident — the degraded-accuracy path.
            let (len, channels) = (sess.tcn.len(), sess.tcn.channels);
            ff.flips += fs.inj.corrupt_slots(sess.tcn.words_mut(), len, channels);
            ff.detected += sess.tcn.words_mut().map(|w| u64::from(w.scrub())).sum::<u64>();
            ff.scrub_words += len as u64;
            ff.flips > 0
        }
        FaultSurface::WeightMem => {
            // The shared image is immutable (and golden): model upsets in
            // this engine's resident banks instead. Any hit raises the
            // parity interrupt, which triggers a fingerprint scrub of the
            // whole resident image; the affected layers then re-adopt
            // their words from the `Arc`'d image. `adopt` early-returns
            // for resident banks, so repair perturbs no LRU state and
            // co-sessions stay byte-identical. Repaired → not degraded.
            let inventory = image.scrub_inventory();
            let total: u64 = inventory.iter().map(|(_, w)| *w).sum();
            let faults = fs.inj.faulted_bits(total * 256);
            if !faults.is_empty() {
                ff.flips += faults.len() as u64;
                ff.detected += faults.len() as u64;
                ff.scrub_words += total;
                // Map sorted flip addresses (256 plane bits per word) to
                // their layers via the cumulative word inventory.
                let mut affected: Vec<usize> = Vec::new();
                for &a in &faults {
                    let word = a / 256;
                    let mut base = 0u64;
                    for (li, (_, words)) in inventory.iter().enumerate() {
                        if word < base + words {
                            if affected.last() != Some(&li) {
                                affected.push(li);
                            }
                            break;
                        }
                        base += words;
                    }
                }
                ff.repair_words += affected.iter().map(|&li| inventory[li].1).sum::<u64>();
                tail.scrub_weights(affected.iter().map(|&li| inventory[li].0.as_str()));
            }
            false
        }
        FaultSurface::ActMem | FaultSurface::DmaStream => false,
    }
}
