//! The multi-stream serving engine — the one serve path every topology
//! policy (inline / threaded / batched, `N`-stream CLI serving) is a
//! thin wrapper over.
//!
//! Split of responsibilities (the api_redesign tentpole):
//!
//! * *who produces frames* — any [`FrameSource`] (live synthetic camera,
//!   replayed word-stream, mixer); the engine never constructs sources;
//! * *which stream a frame belongs to* — the `session_id` of
//!   [`Engine::submit`]; each [`Session`] owns its stream's recurrent
//!   state (TCN window, SoC ledger, labels, metrics);
//! * *how work is scheduled* — [`Engine::drain`] runs the stateless CNN
//!   front-end of all pending frames across a pool of preloaded worker
//!   [`Scheduler`]s (round-robin sharding, the dominant per-frame cost),
//!   then reduces each frame's stateful tail — TCN-window push + TCN
//!   inference + SoC timeline — in submission order, which preserves
//!   per-session frame order.
//!
//! Determinism: every counter the energy model consumes is
//! sharding-invariant (the datapath's counters are analytic in the
//! geometry and toggle sums are order-independent), workers adopt the
//! tail's booted weight banks so their accesses are the same
//! steady-state bank switches the inline scheduler charges, and all
//! cross-frame recurrent state is per-session (checked out into the
//! tail scheduler per frame via [`Scheduler::swap_tcn`]). Interleaving
//! K sessions through one engine is therefore byte-identical to serving
//! each stream alone — asserted for K ∈ {1, 2, 5} and both [`SimMode`]s
//! in `tests/engine.rs`.
//!
//! Weight images (multi-workload pass): the engine routes every frame
//! through a shared [`NetRegistry`] — the immutable fingerprint → (net,
//! `Arc<PreparedNet>`) map built once at boot. Each session binds one
//! registered net ([`super::registry::SessionGeometry`]); the tail and
//! every pool worker check the bound image in per frame via
//! [`Scheduler::swap_image`], which parks the displaced image's
//! weight-bank residency so interleaving sessions of different nets
//! stays byte-identical to serving each net alone. A single-net
//! registry (the [`Engine::new`] / [`Engine::with_image`] boots)
//! degenerates to PR 5's one-`Arc`'d-image engine exactly — the
//! software twin of CUTIE's boot-once, stay-resident OCU weight
//! buffers.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use super::hibernate::{HibernationStats, SessionSnapshot, SessionStore};
use super::metrics::{ReportAccumulator, ServingReport};
use super::registry::{BindingError, NetRegistry};
use super::session::{FaultState, Session};
use super::source::FrameSource;
use crate::cutie::{CutieConfig, PreparedNet, RunStats, Scheduler, SimMode};
use crate::energy::{evaluate, EnergyParams};
use crate::fault::{FaultPlan, FaultSurface, FrameFaults, Injector};
use crate::network::Network;
use crate::tensor::PackedMap;

/// Attempts the stateful TCN tail gets per frame before the frame is
/// declared a terminal failure (one retry).
const TCN_ATTEMPTS: u32 = 2;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub voltage: f64,
    /// Clock override (None → fmax(V)).
    pub freq_hz: Option<f64>,
    pub mode: SimMode,
    /// CNN front-end pool width: 1 → serial (fully inline), 0 → one
    /// worker per available core.
    pub workers: usize,
    /// Cross-session lane-batching width for the drain's CNN phase:
    /// pending frames bound to the same net fingerprint and input
    /// geometry batch into SoA lane groups of up to this many frames
    /// (clamped to the 8-lane ceiling) and run the front-end in one
    /// kernel invocation. ≤ 1 disables batching (every frame serves
    /// serially). Lane-batched output is byte-identical to serial
    /// serving — this knob trades wall-clock only.
    pub lanes: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { voltage: 0.5, freq_hz: None, mode: SimMode::Accurate, workers: 1, lanes: 8 }
    }
}

/// SoA lane ceiling for the batched CNN front-end (the paper-facing
/// "2–8 sessions per lane group" rule).
const MAX_LANES: usize = 8;

pub struct Engine {
    /// The fingerprint → (net, image) map every frame routes through —
    /// built once, shared (same `Arc`) by every engine of a fleet.
    registry: Arc<NetRegistry>,
    cfg: EngineConfig,
    params: EnergyParams,
    /// Stateful tail executor: per-session TCN windows are swapped into
    /// it frame by frame; also runs the CNN when the pool is serial.
    tail: Scheduler,
    /// CNN workers borrowing the shared images (empty when `cfg.workers`
    /// resolves to 1).
    workers: Vec<Scheduler>,
    sessions: BTreeMap<usize, Session>,
    /// Submitted, not yet drained, in arrival order. Frame-surface
    /// faults (ActMem, µDMA) are injected at submit time so the ledger
    /// rides with its frame.
    pending: Vec<PendingFrame>,
    /// The state-retentive idle tier (None = always-resident serving).
    hib: Option<HibernateTier>,
    /// Monotonic drain counter — the engine's coarse clock for
    /// least-recently-active accounting (`Session::last_active`).
    drains: u64,
}

/// One submitted frame: its stream, the net it is bound to (stamped at
/// submit from the session's binding, so a drain never consults the
/// session map to route work), the payload, and its injection ledger.
struct PendingFrame {
    session: usize,
    fingerprint: u64,
    frame: PackedMap,
    ff: FrameFaults,
}

/// The engine's idle tier: the snapshot store plus the eviction policy.
struct HibernateTier {
    store: SessionStore,
    /// Hibernate a session once it sits idle through this many
    /// consecutive drains (None = explicit hibernation only).
    after: Option<u64>,
    /// Resident-session capacity: after each drain, least-recently-
    /// active sessions above this count are hibernated even if they
    /// were never idle (None = unbounded residency).
    budget: Option<usize>,
    /// Engine-side per-record accruals that cannot live inside the CRC'd
    /// record itself (retention ticks, write volume, injected flips).
    /// Merged into the session at resume. Lost across a process restart:
    /// the hibernation *ledger* is at-least-once, the serving *state*
    /// exactly-once.
    pending: BTreeMap<usize, PendingHib>,
}

#[derive(Default)]
struct PendingHib {
    stats: HibernationStats,
    /// Snapshot-surface plane bits flipped in the stored record.
    flips: u64,
}

impl Engine {
    /// Boot a single-workload engine, building (and validating) the
    /// prepared-weight image from the network. Errors instead of
    /// panicking on an invalid config/image pairing — e.g. a
    /// sub-threshold supply with no explicit clock — so serving callers
    /// surface a typed error.
    pub fn new(net: &Network, cfg: EngineConfig) -> Result<Self> {
        Self::with_registry(Arc::new(NetRegistry::single(net.clone())?), cfg)
    }

    /// Boot a single-workload engine from a pre-built weight image —
    /// e.g. one word-copy-loaded from a packed `.ttn` v2 file. The image
    /// is fully validated against `net` (coverage, geometry, pooling
    /// flags, per-OCU thresholds) before any scheduler borrows it; only
    /// the plane words themselves are taken on trust — see
    /// [`PreparedNet::validate_against`] for that contract.
    pub fn with_image(net: &Network, cfg: EngineConfig, image: Arc<PreparedNet>) -> Result<Self> {
        Self::with_registry(Arc::new(NetRegistry::single_with_image(net.clone(), image)?), cfg)
    }

    /// Boot a multi-workload engine over a shared net registry. The tail
    /// boots every registered image into its own weight banks (the one
    /// modeled weight-streaming charge per net — each net's residency
    /// model is per image, parked across switches), every pool worker
    /// adopts the already-filled banks (spawning a worker moves no
    /// weight data, modeled or host-side), and all schedulers park at
    /// the registry's default net.
    pub fn with_registry(registry: Arc<NetRegistry>, cfg: EngineConfig) -> Result<Self> {
        ensure!(!registry.is_empty(), "serving needs at least one registered net");
        // Boot-time clock validation: with no explicit clock the energy
        // model derives f_max(V), which has no fit below the device
        // threshold — reject the config here rather than erroring on the
        // first drain. (Sub-0.5 V supplies themselves are legal: that is
        // the fault-injection operating region.)
        if cfg.freq_hz.is_none() {
            crate::energy::fmax_hz(cfg.voltage)?;
        }
        let pool = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        let mut tail = Scheduler::new(CutieConfig::kraken(), cfg.mode);
        for entry in registry.entries() {
            tail.swap_image(Arc::clone(entry.image()));
            tail.preload_weights(entry.net());
        }
        tail.swap_image(Arc::clone(registry.default_entry().image()));
        let workers = if pool <= 1 {
            Vec::new()
        } else {
            // Layer-level row sharding is pinned off inside pool workers
            // (max_threads = 1): frame-level parallelism replaces it
            // without oversubscription. Counters are sharding-invariant.
            let wcfg = CutieConfig { max_threads: 1, ..CutieConfig::kraken() };
            (0..pool)
                .map(|_| {
                    let mut s = Scheduler::new(wcfg.clone(), cfg.mode);
                    for entry in registry.entries() {
                        s.swap_image(Arc::clone(entry.image()));
                        s.adopt_weights(entry.net());
                    }
                    s.swap_image(Arc::clone(registry.default_entry().image()));
                    s
                })
                .collect()
        };
        Ok(Engine {
            registry,
            cfg,
            params: EnergyParams::default(),
            tail,
            workers,
            sessions: BTreeMap::new(),
            pending: Vec::new(),
            hib: None,
            drains: 0,
        })
    }

    /// Switch on the state-retentive idle tier: snapshots go to `store`
    /// (in-memory or file-backed), and — when `after` is set — a session
    /// hibernates automatically once it sits idle through that many
    /// consecutive drains, resuming transparently on its next `submit`.
    pub fn enable_hibernation(&mut self, store: SessionStore, after: Option<u64>) {
        self.hib = Some(HibernateTier { store, after, budget: None, pending: BTreeMap::new() });
    }

    /// Cap resident sessions (capacity-driven hibernation): after each
    /// drain, the least-recently-active sessions above `budget` are
    /// snapshotted out through the idle-tier path — even when they are
    /// never idle — and resume transparently on their next submit.
    /// Requires [`Engine::enable_hibernation`] first (the snapshots need
    /// a store). `None` removes the cap.
    pub fn set_resident_budget(&mut self, budget: Option<usize>) -> Result<()> {
        let Some(tier) = self.hib.as_mut() else {
            bail!("a resident-session budget needs hibernation enabled first");
        };
        tier.budget = budget;
        Ok(())
    }

    /// The idle tier's snapshot store, when hibernation is enabled.
    pub fn store(&self) -> Option<&SessionStore> {
        self.hib.as_ref().map(|t| &t.store)
    }

    /// Mutable store access (fault campaigns corrupt records through
    /// this; serving code never needs it).
    pub fn store_mut(&mut self) -> Option<&mut SessionStore> {
        self.hib.as_mut().map(|t| &mut t.store)
    }

    /// Persist the snapshot store if it is file-backed and dirty.
    pub fn sync_store(&mut self) -> Result<()> {
        match self.hib.as_mut() {
            Some(tier) => tier.store.sync(),
            None => Ok(()),
        }
    }

    /// The default net's shared prepared-weight image. With every
    /// scheduler parked on the default net, `Arc::strong_count` on it is
    /// 2 + pool width (registry + tail + workers) — asserted by the
    /// pool-sharing tests.
    pub fn image(&self) -> &Arc<PreparedNet> {
        self.registry.default_entry().image()
    }

    /// The net registry this engine serves from.
    pub fn registry(&self) -> &Arc<NetRegistry> {
        &self.registry
    }

    /// Pool width (0 workers = serial: the tail runs the CNN too).
    pub fn pool_size(&self) -> usize {
        self.workers.len()
    }

    /// Register (or fetch) a stream's session, bound to the registry's
    /// default net. `submit` opens sessions implicitly; opening one
    /// explicitly matters only for zero-frame streams that still want a
    /// (empty) report, or to bind a non-default net via
    /// [`Engine::open_session_on`]. A hibernated session resumes
    /// transparently here (every serve-path entry point — submit, fault
    /// arming, finish — funnels through this); an existing session is
    /// returned with whatever binding it has.
    pub fn open_session(&mut self, id: usize) -> Result<&mut Session, BindingError> {
        self.ensure_resident(id)?;
        let voltage = self.cfg.voltage;
        let geometry = self.registry.default_entry().geometry();
        Ok(self.sessions.entry(id).or_insert_with(|| Session::new(id, voltage, geometry)))
    }

    /// Register (or fetch) a stream's session bound to the registered
    /// net `fingerprint`. Typed errors: an unknown fingerprint, or an
    /// existing session bound to a *different* net (bindings are fixed
    /// for a session's lifetime — re-opening on the same net is fine).
    pub fn open_session_on(
        &mut self,
        id: usize,
        fingerprint: u64,
    ) -> Result<&mut Session, BindingError> {
        let geometry = self.registry.entry(fingerprint)?.geometry();
        self.ensure_resident(id)?;
        let voltage = self.cfg.voltage;
        let sess = self.sessions.entry(id).or_insert_with(|| Session::new(id, voltage, geometry));
        if sess.geometry.fingerprint != fingerprint {
            return Err(BindingError::Rebind {
                session: id,
                bound: sess.geometry.fingerprint,
                requested: fingerprint,
            });
        }
        Ok(sess)
    }

    /// Snapshot a session into the idle tier and evict it from residency
    /// (the explicit entry point; idle eviction calls the same path).
    /// The store is synced before returning, so a crash after this call
    /// cannot lose the record.
    pub fn hibernate(&mut self, id: usize) -> Result<()> {
        self.hibernate_one(id)?;
        self.sync_store()
    }

    /// Wake a hibernated session explicitly. `Ok(false)` when it was
    /// already resident; `Ok(true)` when a record was consumed (restored
    /// bit-exactly, or refused-and-reinitialized if corrupt — see the
    /// session's `faults.snapshot_corrupt` / `hib.corrupt_resumes`).
    pub fn resume(&mut self, id: usize) -> Result<bool> {
        ensure!(self.hib.is_some(), "hibernation is not enabled on this engine");
        if self.sessions.contains_key(&id) {
            return Ok(false);
        }
        self.ensure_resident(id)?;
        ensure!(self.sessions.contains_key(&id), "session {id} has no hibernation record");
        Ok(true)
    }

    /// Remove a session from this engine and hand back its complete
    /// state — the live-migration egress. The capture is a pure read of
    /// the (resumed-if-hibernated) session: no serving counter moves, so
    /// a migrated schedule stays byte-identical to an unmigrated one.
    /// The session must have no pending frames (drain first).
    pub fn export_session(&mut self, id: usize) -> Result<SessionSnapshot> {
        ensure!(
            !self.pending.iter().any(|pf| pf.session == id),
            "session {id} has pending frames; drain before exporting"
        );
        self.ensure_resident(id)?;
        let sess = self
            .sessions
            .remove(&id)
            .with_context(|| format!("session {id} is not on this engine"))?;
        Ok(SessionSnapshot::capture(&sess))
    }

    /// Adopt a migrated session — the live-migration ingress. Refused
    /// (typed error, nothing half-adopted) when the id is already held
    /// here, the snapshot is bound to a net this engine's registry does
    /// not hold, or the snapshot's geometry/operating point does not
    /// match this engine; restoring any of these would be silently wrong.
    pub fn import_session(&mut self, snap: SessionSnapshot) -> Result<()> {
        let id = snap.session_id as usize;
        ensure!(!self.sessions.contains_key(&id), "session {id} is already resident here");
        if !self.registry.contains(snap.fingerprint) {
            return Err(
                BindingError::SnapshotNet { session: id, fingerprint: snap.fingerprint }.into()
            );
        }
        if let Some(tier) = &self.hib {
            ensure!(
                !tier.store.contains(id as u64),
                "session {id} already has a hibernation record here"
            );
        }
        let (depth, channels) = (self.tail.cfg.tcn_depth, self.tail.cfg.channels);
        ensure!(
            snap.tcn.depth as usize == depth && snap.tcn.channels as usize == channels,
            "snapshot TCN geometry {}x{} does not fit this engine's {}x{}",
            snap.tcn.depth,
            snap.tcn.channels,
            depth,
            channels
        );
        ensure!(
            snap.voltage.to_bits() == self.cfg.voltage.to_bits(),
            "snapshot supply {} V does not match this engine's {} V",
            snap.voltage,
            self.cfg.voltage
        );
        let mut sess = snap
            .into_session()
            .map_err(|e| anyhow::anyhow!("restoring migrated session {id}: {e}"))?;
        // Arrival counts as activity on this engine's LRU clock.
        sess.last_active = self.drains;
        self.sessions.insert(id, sess);
        Ok(())
    }

    /// Snapshot + evict, without syncing the store (batched by callers).
    fn hibernate_one(&mut self, id: usize) -> Result<()> {
        let Some(tier) = self.hib.as_mut() else {
            bail!("hibernation is not enabled on this engine");
        };
        ensure!(
            !self.pending.iter().any(|pf| pf.session == id),
            "session {id} has pending frames; drain before hibernating"
        );
        let Some(mut sess) = self.sessions.remove(&id) else {
            bail!("session {id} is not resident (unknown, or already hibernated)");
        };
        sess.hib.hibernates += 1;
        sess.idle_drains = 0;
        // Snapshot-surface injection: one exposure of the record's bits
        // per hibernation. The draws advance the injector BEFORE the
        // final capture, so the consumed randomness rides inside the
        // record and a resumed walk continues exactly where it left off.
        // (The record's length does not depend on RNG state values, so
        // the probe encode sizes the real record exactly.)
        let armed_on_store = matches!(
            &sess.fault,
            Some(fs) if fs.plan.is_active() && fs.plan.surface == FaultSurface::Snapshot
        );
        let mut flip_addrs = Vec::new();
        if armed_on_store {
            let bits = SessionSnapshot::capture(&sess).encode().len() as u64 * 8;
            if let Some(fs) = sess.fault.as_mut() {
                flip_addrs = fs.inj.faulted_bits(bits);
            }
        }
        let payload = SessionSnapshot::capture(&sess).encode();
        let pend = tier.pending.entry(id).or_default();
        pend.stats.snapshot_bytes += payload.len() as u64;
        pend.flips += flip_addrs.len() as u64;
        tier.store.insert(id as u64, payload);
        tier.store.flip_bits(id as u64, &flip_addrs);
        Ok(())
    }

    /// Restore a hibernated session into residency, if it has a record.
    /// A corrupt or geometry-mismatched record is refused with counters
    /// raised and the session re-initialized (the serve path must not
    /// lose the stream), but a *valid* record bound to a net this
    /// registry does not hold is a typed [`BindingError::SnapshotNet`]:
    /// the record stays in the store untouched — a session can never
    /// silently resume onto the wrong weights, and migrating the store
    /// to an engine that does hold the net still works.
    fn ensure_resident(&mut self, id: usize) -> Result<(), BindingError> {
        if self.sessions.contains_key(&id) {
            return Ok(());
        }
        let Some(tier) = self.hib.as_mut() else { return Ok(()) };
        let bytes = match tier.store.record_bytes(id as u64) {
            Some(b) => b as u64,
            None => return Ok(()),
        };
        // Peek before consuming: the net-binding refusal must leave the
        // record in the store, unlike the corrupt-record path (where the
        // bits are already worthless).
        let mut reinit_geom = self.registry.default_entry().geometry();
        if let Some(Ok(snap)) = tier.store.peek(id as u64) {
            match self.registry.get(snap.fingerprint) {
                Some(entry) => reinit_geom = entry.geometry(),
                None => {
                    return Err(BindingError::SnapshotNet {
                        session: id,
                        fingerprint: snap.fingerprint,
                    });
                }
            }
        }
        let outcome = match tier.store.take(id as u64) {
            Some(o) => o,
            None => return Ok(()),
        };
        let pend = tier.pending.remove(&id).unwrap_or_default();
        let (depth, channels) = (self.tail.cfg.tcn_depth, self.tail.cfg.channels);
        let voltage = self.cfg.voltage;
        let restored = match outcome {
            Ok(snap) => {
                // A structurally valid record from a different engine
                // geometry or operating point is refused the same way as
                // a corrupt one: restoring it would be silently wrong.
                let fits = snap.tcn.depth as usize == depth
                    && snap.tcn.channels as usize == channels
                    && snap.voltage.to_bits() == voltage.to_bits();
                if fits {
                    snap.into_session().ok()
                } else {
                    None
                }
            }
            Err(_) => None,
        };
        let mut sess = match restored {
            Some(mut sess) => {
                sess.hib.resumes += 1;
                sess.hib.merge(&pend.stats);
                sess.faults.injected_flips += pend.flips;
                // Wake re-load: every stored word streams back into the
                // engine at the operating supply. Charged to the
                // hibernation ledger, never the SoC/core ledgers — the
                // byte-identity oracle and the calibration anchors stay
                // untouched by the idle tier.
                let words = bytes.div_ceil(8);
                sess.hib.wake_j +=
                    words as f64 * self.params.e_wake * self.params.dyn_scale(voltage);
                sess
            }
            None => {
                // The CRC (or decode validation) refused the record: the
                // session restarts from scratch, visibly. The record's
                // in-flight history (labels, ledgers) is lost with it.
                // It restarts on the binding the record named when that
                // was readable, else on the default net.
                let mut sess = Session::new(id, voltage, reinit_geom);
                sess.faults.snapshot_corrupt += 1;
                sess.faults.injected_flips += pend.flips;
                sess.faults.detected += pend.flips;
                sess.hib.corrupt_resumes += 1;
                sess.hib.merge(&pend.stats);
                sess
            }
        };
        // A resume counts as activity on this engine's LRU clock — a
        // just-woken session is not the next capacity-eviction victim.
        sess.last_active = self.drains;
        self.sessions.insert(id, sess);
        Ok(())
    }

    /// End-of-drain bookkeeping: the engine's drain clock ticks and the
    /// sessions this drain served stamp it (least-recently-active
    /// accounting); then every stored record pays its per-word retention
    /// cost for this tick, sessions that sat idle through `after`
    /// consecutive drains are hibernated, and — when a resident budget
    /// is set — least-recently-active sessions above it are hibernated
    /// even if never idle.
    fn hibernate_idle(&mut self, active: &BTreeSet<usize>) -> Result<()> {
        self.drains += 1;
        for &sid in active {
            if let Some(sess) = self.sessions.get_mut(&sid) {
                sess.last_active = self.drains;
            }
        }
        let Some(tier) = self.hib.as_mut() else { return Ok(()) };
        // Retention is flat (the retentive rail is fixed, not the
        // dynamic supply), accrued engine-side: the record's own bytes
        // must stay exactly as written.
        for id in tier.store.ids() {
            let words = tier.store.record_bytes(id).unwrap_or(0).div_ceil(8) as u64;
            let pend = tier.pending.entry(id as usize).or_default();
            pend.stats.retention_word_ticks += words;
            pend.stats.retention_j += words as f64 * self.params.e_retention;
        }
        let after = tier.after;
        let budget = tier.budget;
        let mut evict = Vec::new();
        if let Some(n) = after {
            for (&sid, sess) in self.sessions.iter_mut() {
                if active.contains(&sid) {
                    sess.idle_drains = 0;
                } else {
                    sess.idle_drains += 1;
                    // n = 0 behaves as 1: a session is never evicted on
                    // the very drain that served it.
                    if sess.idle_drains >= n.max(1) {
                        evict.push(sid);
                    }
                }
            }
        }
        for sid in evict {
            self.hibernate_one(sid)?;
        }
        // Capacity budget: residency over the cap — not idleness — is
        // the trigger, so sessions hot on every drain still spill once
        // the engine is over-subscribed. Victims are least-recently-
        // active first, ties broken by session id (deterministic, so
        // budgeted schedules stay reproducible).
        if let Some(b) = budget {
            if self.sessions.len() > b {
                let mut order: Vec<(u64, usize)> =
                    self.sessions.iter().map(|(&sid, s)| (s.last_active, sid)).collect();
                order.sort_unstable();
                let excess = self.sessions.len() - b;
                for &(_, sid) in order.iter().take(excess) {
                    self.hibernate_one(sid)?;
                }
            }
        }
        self.sync_store()
    }

    /// Arm (or replace) a session's fault plan. The injector is seeded
    /// by the plan's seed mixed with the session id, so one plan applied
    /// to many sessions decorrelates their flip streams while every
    /// stream stays individually deterministic. A BER-0 plan is armed
    /// but structurally side-effect-free (no RNG draws, no scrubs).
    pub fn set_fault_plan(
        &mut self,
        session_id: usize,
        plan: FaultPlan,
    ) -> Result<(), BindingError> {
        let seed = plan.seed ^ (session_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.open_session(session_id)?.fault =
            Some(FaultState { plan, inj: Injector::new(plan.ber, seed) });
        Ok(())
    }

    /// The session's armed plan, if any.
    pub fn fault_plan(&self, session_id: usize) -> Option<FaultPlan> {
        self.sessions.get(&session_id).and_then(|s| s.fault.as_ref().map(|f| f.plan))
    }

    /// Enqueue one frame on a stream. Work happens at the next `drain`.
    ///
    /// The frame's dims are checked against the session's net binding
    /// first — a mismatch is a typed [`BindingError::FrameShape`] that
    /// advances no injector RNG and enqueues nothing.
    ///
    /// Frame-surface fault injection happens here, in submission order:
    /// an armed ActMem plan corrupts the frame's words as stored in the
    /// activation SRAM and charges a scrub scan over them (detected
    /// orphans are clamped, silent mask flips ride through); an armed
    /// µDMA plan corrupts the words in flight, where the ingress
    /// decoder's plane-invariant check catches orphans for free (no
    /// scrub charge) but silent flips still land.
    pub fn submit(&mut self, session_id: usize, frame: PackedMap) -> Result<(), BindingError> {
        let sess = self.open_session(session_id)?;
        let geom = sess.geometry;
        let got = (frame.h, frame.w, frame.c);
        let want = (geom.input_hw, geom.input_hw, geom.input_ch);
        if got != want {
            return Err(BindingError::FrameShape { session: session_id, got, want });
        }
        let mut frame = frame;
        let mut ff = FrameFaults::default();
        if let Some(fs) = sess.fault.as_mut() {
            if fs.plan.is_active() {
                match fs.plan.surface {
                    FaultSurface::ActMem => {
                        ff.flips += fs.inj.corrupt_map(&mut frame);
                        ff.scrub_words += frame.pixels.len() as u64;
                        ff.detected += frame.scrub();
                    }
                    FaultSurface::DmaStream => {
                        ff.flips += fs.inj.corrupt_map(&mut frame);
                        ff.detected += frame.scrub();
                    }
                    FaultSurface::TcnMem | FaultSurface::WeightMem | FaultSurface::Snapshot => {}
                }
            }
        }
        self.pending.push(PendingFrame {
            session: session_id,
            fingerprint: geom.fingerprint,
            frame,
            ff,
        });
        Ok(())
    }

    /// Pull up to `max_frames` frames from a source onto a stream;
    /// returns how many the source yielded before drying up.
    pub fn submit_from(
        &mut self,
        session_id: usize,
        src: &mut dyn FrameSource,
        max_frames: usize,
    ) -> Result<usize, BindingError> {
        let mut n = 0;
        while n < max_frames {
            match src.next_frame() {
                Some(f) => {
                    self.submit(session_id, f)?;
                    n += 1;
                }
                None => break,
            }
        }
        Ok(n)
    }

    pub fn pending_frames(&self) -> usize {
        self.pending.len()
    }

    pub fn session_ids(&self) -> Vec<usize> {
        self.sessions.keys().copied().collect()
    }

    pub fn session(&self, id: usize) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Serve every pending frame; returns how many were served (dropped
    /// or terminally failed frames don't count).
    ///
    /// Phase 1 (stateless, parallel): CNN front-ends across the worker
    /// pool. Phase 2 (stateful, sequential): per-frame TCN/SoC tail in
    /// submission order — per-session frame order is preserved because
    /// submission order is.
    ///
    /// Resilience contract: a frame that errors — or a pool worker that
    /// panics — costs at most that frame (and, for a panic, a serial
    /// recompute of the worker's shard on the tail); it never aborts the
    /// drain or poisons other sessions. Failures land in the owning
    /// session's [`FaultSummary`]; at [`super::session::FAILURE_LIMIT`]
    /// the session is quarantined and its remaining frames are dropped.
    pub fn drain(&mut self) -> Result<usize> {
        if self.pending.is_empty() {
            return Ok(0);
        }
        let wall0 = Instant::now();
        let pending = std::mem::take(&mut self.pending);
        // Sessions touched by this drain: their idle clocks reset; every
        // other resident session ages toward idle eviction.
        let active: BTreeSet<usize> = pending.iter().map(|pf| pf.session).collect();

        // Phase 1: CNN front-end. Pending frames are first grouped into
        // lane units — chunks of ≤ cfg.lanes frames sharing a net
        // fingerprint and input geometry (the LaneBlock grouping rule) —
        // so the batched kernel serves 2–8 sessions per invocation;
        // singletons, mixed-net leftovers and `--lanes 1` take the
        // serial per-frame path. Each scheduler checks the unit's bound
        // image in (`swap_image` — a no-op while consecutive units share
        // a net) before running it. A frame whose CNN errors leaves its
        // slot None (noted as a failure in phase 2).
        let units = lane_units(&pending, self.cfg.lanes);
        let mut cnn: Vec<Option<(PackedMap, RunStats)>> = vec![None; pending.len()];
        let registry = &self.registry;
        if self.workers.is_empty() {
            for unit in &units {
                for (i, r) in run_unit(registry, &mut self.tail, &pending, unit) {
                    cnn[i] = r.ok();
                }
            }
        } else {
            let nw = self.workers.len();
            let (results, poisoned) = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (wi, sched) in self.workers.iter_mut().enumerate() {
                    let pending = &pending;
                    let units = &units;
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut u = wi;
                        while u < units.len() {
                            out.extend(run_unit(registry, sched, pending, &units[u]));
                            u += nw;
                        }
                        out
                    }));
                }
                // Join manually: a panicked worker must cost only its own
                // shard, not (via scope's implicit re-panic) the process.
                let mut results = Vec::new();
                let mut poisoned = Vec::new();
                for (wi, h) in handles.into_iter().enumerate() {
                    match h.join() {
                        Ok(out) => results.push(out),
                        Err(_) => poisoned.push(wi),
                    }
                }
                (results, poisoned)
            });
            for (i, r) in results.into_iter().flatten() {
                cnn[i] = r.ok();
            }
            // Recompute a poisoned worker's units serially on the tail —
            // the frames, not the worker, are what sessions are owed.
            for wi in poisoned {
                let mut u = wi;
                while u < units.len() {
                    for (i, r) in run_unit(registry, &mut self.tail, &pending, &units[u]) {
                        cnn[i] = r.ok();
                    }
                    u += nw;
                }
            }
        }

        // Phase 2: stateful per-session tail, in submission order.
        let mut served: Vec<(usize, f64, f64)> = Vec::with_capacity(pending.len());
        for (pf, slot) in pending.into_iter().zip(cnn.into_iter()) {
            let PendingFrame { session: sid, fingerprint, frame, mut ff } = pf;
            let Some(sess) = self.sessions.get_mut(&sid) else { continue };
            if sess.is_quarantined() {
                sess.faults.dropped_frames += 1;
                continue;
            }
            let Ok(entry) = registry.entry(fingerprint) else {
                sess.faults.record(&ff, ff.flips > 0);
                sess.note_failure();
                continue;
            };
            let Some((feat, mut run)) = slot else {
                sess.faults.record(&ff, ff.flips > 0);
                sess.note_failure();
                continue;
            };
            // The tail serves this frame on its session's bound image
            // (no-op between frames of the same net).
            self.tail.swap_image(Arc::clone(entry.image()));
            // State-surface injection (TCN ring / weight banks), one
            // exposure per frame; weight scrub/self-heal is keyed to the
            // bound image via the swap above.
            let mut degraded = ff.flips > 0;
            degraded |= inject_state_surfaces(entry.image(), &mut self.tail, sess, &mut ff);
            // Bounded retry around the stateful tail: for a recurrent
            // net, check the stream's TCN window out into the tail (the
            // packed feature word moves into it as-is, no unpack; a push
            // that landed is not replayed on retry); for a feed-forward
            // net, the classifier reads the CNN feature map directly —
            // nothing is pushed into any ring.
            let mut pushed = false;
            let mut tail_result = Err(anyhow::anyhow!("stateful tail not attempted"));
            for attempt in 0..TCN_ATTEMPTS {
                let r = if sess.geometry.has_tcn {
                    self.tail.swap_tcn(&mut sess.tcn);
                    let r = if pushed { Ok(()) } else { self.tail.push_feature(&feat) };
                    let r = match r {
                        Ok(()) => {
                            pushed = true;
                            self.tail.run_tcn(entry.net())
                        }
                        Err(e) => Err(e),
                    };
                    self.tail.swap_tcn(&mut sess.tcn); // check back in, even on error
                    r
                } else {
                    self.tail.run_classifier(entry.net(), &feat)
                };
                match r {
                    Ok(v) => {
                        tail_result = Ok(v);
                        break;
                    }
                    Err(e) => {
                        tail_result = Err(e);
                        if attempt + 1 < TCN_ATTEMPTS {
                            sess.faults.retries += 1;
                        }
                    }
                }
            }
            sess.faults.record(&ff, degraded);
            let (logits, r) = match tail_result {
                Ok(v) => v,
                Err(_) => {
                    sess.note_failure();
                    continue;
                }
            };
            // A frame lands on the SoC ledger only once it is actually
            // served: ingest + settle stay paired, so a failed frame
            // leaves no dangling frame-ready IRQ behind.
            sess.ingest(&frame);
            run.merge(r);
            // The synthetic fault layer rides only when it has content,
            // so a clean frame's stats are byte-identical to fault-free.
            if ff.any() {
                run.layers.push(ff.to_layer_stats());
            }
            let report = evaluate(&run, self.cfg.voltage, self.cfg.freq_hz, &self.params)?;
            sess.settle(report.time_s, report.energy_j);
            sess.labels.push(logits.argmax());
            served.push((sid, report.time_s * 1e6, report.energy_j));
        }

        // Host wall-clock is a measurement, not a simulation output:
        // amortize the drain across its frames (a 1-frame drain is the
        // inline policy's per-frame latency).
        let n = served.len();
        let wall_us = wall0.elapsed().as_secs_f64() * 1e6 / n.max(1) as f64;
        for (sid, sim_us, core_j) in served {
            if let Some(sess) = self.sessions.get_mut(&sid) {
                sess.metrics.record_frame(sim_us, wall_us, core_j);
            }
        }
        self.hibernate_idle(&active)?;
        Ok(n)
    }

    /// Close one session into its final report (removes it; a hibernated
    /// session is resumed first so its report is complete). A stored
    /// record bound to a net this registry does not hold yields `None` —
    /// the record stays in the store for an engine that can serve it.
    pub fn finish_session(&mut self, id: usize) -> Option<ServingReport> {
        let _ = self.ensure_resident(id);
        self.sessions.remove(&id).map(Session::into_report)
    }

    /// Close every session — resident or hibernated — in session-id
    /// order.
    pub fn finish_all(&mut self) -> Vec<(usize, ServingReport)> {
        self.all_session_ids()
            .into_iter()
            .filter_map(|id| self.finish_session(id).map(|r| (id, r)))
            .collect()
    }

    /// Every session this engine holds anything for — resident, stored
    /// in the idle tier, or with engine-side hibernation accruals
    /// pending — ascending, deduplicated. The shared id enumeration
    /// under [`Engine::finish_all`] / [`Engine::aggregate_report`] and
    /// the fleet's cross-engine roll-up.
    pub fn all_session_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.sessions.keys().copied().collect();
        if let Some(tier) = &self.hib {
            ids.extend(tier.store.ids().into_iter().map(|id| id as usize));
            ids.extend(tier.pending.keys().copied());
        }
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Fold one session's contribution into a cross-session (possibly
    /// cross-engine) accumulator; returns whether this engine held
    /// anything for `id`. Hibernated sessions contribute through their
    /// stored records without being resumed; a record the CRC refuses
    /// here contributes nothing beyond the engine-side accruals (the
    /// refusal itself surfaces at resume, when counters have a session
    /// to land on). The caller drives ids in global order — the f64
    /// sums are order-sensitive, and one ordering rule everywhere is
    /// what keeps a fleet aggregate bit-identical to a single engine's.
    pub fn accumulate_session(&self, id: usize, acc: &mut ReportAccumulator) -> bool {
        if let Some(sess) = self.sessions.get(&id) {
            acc.add_for_net(
                self.net_tag(sess.geometry.fingerprint),
                &sess.metrics,
                &sess.labels,
                &sess.faults,
                &sess.hib,
                sess.soc.energy_j(),
                sess.soc.fc_wakeups(),
                sess.soc.now_ns(),
            );
            return true;
        }
        let Some(tier) = &self.hib else { return false };
        let mut held = false;
        // Engine-side accruals exist even when the record is corrupt
        // (retention was paid regardless of what the bits now say).
        if let Some(pend) = tier.pending.get(&id) {
            acc.add_hibernation(&pend.stats);
            held = true;
        }
        if tier.store.contains(id as u64) {
            held = true;
            if let Some(Ok(snap)) = tier.store.peek(id as u64) {
                acc.add_for_net(
                    self.net_tag(snap.fingerprint),
                    &snap.metrics,
                    &snap.labels,
                    &snap.faults,
                    &snap.hib,
                    snap.soc.energy_j,
                    snap.soc.fc_wakeups,
                    snap.soc.now_ns,
                );
            }
        }
        held
    }

    /// Per-net aggregation tag for a bound fingerprint: its registered
    /// name, or "unknown" for a fingerprint this registry does not hold
    /// (a foreign stored record still counts toward the shared ledgers).
    fn net_tag(&self, fingerprint: u64) -> Option<(u64, &str)> {
        Some((
            fingerprint,
            self.registry.get(fingerprint).map_or("unknown", |e| e.net().name.as_str()),
        ))
    }

    /// Cross-session roll-up (latency samples concatenate, energies,
    /// wakeups and fault counters sum, labels concatenate in session-id
    /// order); see [`Engine::accumulate_session`] for how hibernated
    /// sessions contribute. Average SoC power is total energy over
    /// total simulated SoC time.
    pub fn aggregate_report(&self) -> ServingReport {
        let mut acc = ReportAccumulator::default();
        for id in self.all_session_ids() {
            self.accumulate_session(id, &mut acc);
        }
        acc.finish()
    }
}

/// Group a drain's pending frames into lane units for the batched CNN
/// front-end — the engine's `LaneBlock` construction: frames sharing a
/// (net fingerprint, input geometry) key batch together in submission
/// order and split into chunks of at most `lanes` frames (clamped to
/// the [`MAX_LANES`] SoA ceiling), so the last chunk of a group may be
/// ragged and frames of other nets are never pulled into a block.
/// `lanes <= 1` disables batching — every frame is its own unit.
/// Grouping only reorders the *stateless* phase-1 front-end; phase 2
/// consumes result slots in submission order, so serving output is
/// byte-identical whichever way the units are cut.
fn lane_units(pending: &[PendingFrame], lanes: usize) -> Vec<Vec<usize>> {
    let cap = lanes.min(MAX_LANES);
    if cap <= 1 {
        return (0..pending.len()).map(|i| vec![i]).collect();
    }
    let mut groups: Vec<((u64, usize, usize, usize), Vec<usize>)> = Vec::new();
    for (i, pf) in pending.iter().enumerate() {
        let key = (pf.fingerprint, pf.frame.h, pf.frame.w, pf.frame.c);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((key, vec![i])),
        }
    }
    let mut units = Vec::new();
    for (_, idxs) in groups {
        for chunk in idxs.chunks(cap) {
            units.push(chunk.to_vec());
        }
    }
    units
}

/// Serve one lane unit (same-net, same-geometry pending frames) on one
/// scheduler, returning each frame's CNN result keyed by its pending
/// index. Multi-lane units run the batched front-end once
/// ([`Scheduler::run_cnn_lanes`]); a unit whose batched run errors is
/// re-served frame by frame, so *which* frames fail matches serial
/// serving exactly. A free function so the pool workers and the tail
/// share it without borrowing the engine whole.
fn run_unit(
    registry: &NetRegistry,
    sched: &mut Scheduler,
    pending: &[PendingFrame],
    unit: &[usize],
) -> Vec<(usize, Result<(PackedMap, RunStats)>)> {
    let entry = match registry.entry(pending[unit[0]].fingerprint) {
        Ok(e) => e,
        // every lane of a unit shares the fingerprint, so all share the error
        Err(e) => return unit.iter().map(|&i| (i, Err(e.into()))).collect(),
    };
    sched.swap_image(Arc::clone(entry.image()));
    if unit.len() > 1 {
        let frames: Vec<&PackedMap> = unit.iter().map(|&i| &pending[i].frame).collect();
        if let Ok(results) = sched.run_cnn_lanes(entry.net(), &frames) {
            return unit.iter().copied().zip(results.into_iter().map(Ok)).collect();
        }
    }
    unit.iter().map(|&i| (i, sched.run_cnn(entry.net(), &pending[i].frame))).collect()
}

/// One frame's exposure of an armed state-surface plan (TCN ring or
/// weight banks). A free function so the `&mut Session` (borrowed out of
/// the engine's session map) can coexist with the engine's `tail` and
/// `image` fields. Returns true when the frame's data is degraded —
/// silent corruption survived the scrub pass (repaired weight faults
/// leave the frame clean).
fn inject_state_surfaces(
    image: &PreparedNet,
    tail: &mut Scheduler,
    sess: &mut Session,
    ff: &mut FrameFaults,
) -> bool {
    let Some(fs) = sess.fault.as_mut() else { return false };
    if !fs.plan.is_active() {
        return false;
    }
    match fs.plan.surface {
        FaultSurface::TcnMem => {
            // Corrupt the resident ring words, then run the inter-frame
            // scrub pass over the ring: orphans are clamped (detected),
            // silent flips stay resident — the degraded-accuracy path.
            let (len, channels) = (sess.tcn.len(), sess.tcn.channels);
            ff.flips += fs.inj.corrupt_slots(sess.tcn.words_mut(), len, channels);
            ff.detected += sess.tcn.words_mut().map(|w| u64::from(w.scrub())).sum::<u64>();
            ff.scrub_words += len as u64;
            ff.flips > 0
        }
        FaultSurface::WeightMem => {
            // The shared image is immutable (and golden): model upsets in
            // this engine's resident banks instead. Any hit raises the
            // parity interrupt, which triggers a fingerprint scrub of the
            // whole resident image; the affected layers then re-adopt
            // their words from the `Arc`'d image. `adopt` early-returns
            // for resident banks, so repair perturbs no LRU state and
            // co-sessions stay byte-identical. Repaired → not degraded.
            let inventory = image.scrub_inventory();
            let total: u64 = inventory.iter().map(|(_, w)| *w).sum();
            let faults = fs.inj.faulted_bits(total * 256);
            if !faults.is_empty() {
                ff.flips += faults.len() as u64;
                ff.detected += faults.len() as u64;
                ff.scrub_words += total;
                // Map sorted flip addresses (256 plane bits per word) to
                // their layers via the cumulative word inventory.
                let mut affected: Vec<usize> = Vec::new();
                for &a in &faults {
                    let word = a / 256;
                    let mut base = 0u64;
                    for (li, (_, words)) in inventory.iter().enumerate() {
                        if word < base + words {
                            if affected.last() != Some(&li) {
                                affected.push(li);
                            }
                            break;
                        }
                        base += words;
                    }
                }
                ff.repair_words += affected.iter().map(|&li| inventory[li].1).sum::<u64>();
                tail.scrub_weights(affected.iter().map(|&li| inventory[li].0.as_str()));
            }
            false
        }
        // Frame surfaces inject at submit; the snapshot surface injects
        // at hibernation (records at rest, not per-frame exposure).
        FaultSurface::ActMem | FaultSurface::DmaStream | FaultSurface::Snapshot => false,
    }
}
