//! The multi-stream serving engine — the one serve path every topology
//! policy (inline / threaded / batched, `N`-stream CLI serving) is a
//! thin wrapper over.
//!
//! Split of responsibilities (the api_redesign tentpole):
//!
//! * *who produces frames* — any [`FrameSource`] (live synthetic camera,
//!   replayed word-stream, mixer); the engine never constructs sources;
//! * *which stream a frame belongs to* — the `session_id` of
//!   [`Engine::submit`]; each [`Session`] owns its stream's recurrent
//!   state (TCN window, SoC ledger, labels, metrics);
//! * *how work is scheduled* — [`Engine::drain`] runs the stateless CNN
//!   front-end of all pending frames across a pool of preloaded worker
//!   [`Scheduler`]s (round-robin sharding, the dominant per-frame cost),
//!   then reduces each frame's stateful tail — TCN-window push + TCN
//!   inference + SoC timeline — in submission order, which preserves
//!   per-session frame order.
//!
//! Determinism: every counter the energy model consumes is
//! sharding-invariant (the datapath's counters are analytic in the
//! geometry and toggle sums are order-independent), workers adopt the
//! tail's booted weight banks so their accesses are the same
//! steady-state bank switches the inline scheduler charges, and all
//! cross-frame recurrent state is per-session (checked out into the
//! tail scheduler per frame via [`Scheduler::swap_tcn`]). Interleaving
//! K sessions through one engine is therefore byte-identical to serving
//! each stream alone — asserted for K ∈ {1, 2, 5} and both [`SimMode`]s
//! in `tests/engine.rs`.
//!
//! Weight image (shared-image pass): the engine holds **exactly one**
//! [`PreparedNet`] behind an [`Arc`] — built once from the network (or
//! word-copy-loaded from a packed `.ttn` v2 via [`Engine::with_image`])
//! and borrowed by the tail and every pool worker. Spawning a worker no
//! longer re-packs or clones a single weight word, which is what makes
//! wide pools (and, next, multi-engine sharding) cheap — the software
//! twin of CUTIE's boot-once, stay-resident OCU weight buffers.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::metrics::{ServingMetrics, ServingReport};
use super::session::Session;
use super::source::FrameSource;
use crate::cutie::{CutieConfig, PreparedNet, RunStats, Scheduler, SimMode};
use crate::energy::{evaluate, EnergyParams};
use crate::network::Network;
use crate::tensor::PackedMap;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub voltage: f64,
    /// Clock override (None → fmax(V)).
    pub freq_hz: Option<f64>,
    pub mode: SimMode,
    /// CNN front-end pool width: 1 → serial (fully inline), 0 → one
    /// worker per available core.
    pub workers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { voltage: 0.5, freq_hz: None, mode: SimMode::Accurate, workers: 1 }
    }
}

pub struct Engine<'n> {
    net: &'n Network,
    cfg: EngineConfig,
    params: EnergyParams,
    /// The one prepared-weight image every scheduler in this engine
    /// borrows (tail + all pool workers share this `Arc`).
    image: Arc<PreparedNet>,
    /// Stateful tail executor: per-session TCN windows are swapped into
    /// it frame by frame; also runs the CNN when the pool is serial.
    tail: Scheduler,
    /// CNN workers borrowing the shared image (empty when `cfg.workers`
    /// resolves to 1).
    workers: Vec<Scheduler>,
    sessions: BTreeMap<usize, Session>,
    /// Submitted, not yet drained (session, frame) pairs in arrival order.
    pending: Vec<(usize, PackedMap)>,
}

impl<'n> Engine<'n> {
    pub fn new(net: &'n Network, cfg: EngineConfig) -> Self {
        let image = Arc::new(PreparedNet::new(net, &CutieConfig::kraken()));
        Self::with_image(net, cfg, image).expect("freshly built image matches its network")
    }

    /// Boot from a pre-built weight image — e.g. one word-copy-loaded
    /// from a packed `.ttn` v2 file, or one shared with other engines.
    /// The image is fully validated against `net` (coverage, geometry,
    /// pooling flags, per-OCU thresholds) before any scheduler borrows
    /// it; only the plane words themselves are taken on trust — see
    /// [`PreparedNet::validate_against`] for that contract.
    pub fn with_image(
        net: &'n Network,
        cfg: EngineConfig,
        image: Arc<PreparedNet>,
    ) -> Result<Self> {
        image.validate_against(net)?;
        ensure!(
            image.matches(net),
            "prepared image '{}' does not match network '{}'",
            image.net_name(),
            net.name
        );
        let pool = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        // The tail boots the image into its weight banks (the one
        // modeled weight-streaming charge)...
        let mut tail = Scheduler::new(CutieConfig::kraken(), cfg.mode);
        tail.attach_image(Arc::clone(&image));
        tail.preload_weights(net);
        let workers = if pool <= 1 {
            Vec::new()
        } else {
            // Layer-level row sharding is pinned off inside pool workers
            // (max_threads = 1): frame-level parallelism replaces it
            // without oversubscription. Counters are sharding-invariant.
            let wcfg = CutieConfig { max_threads: 1, ..CutieConfig::kraken() };
            (0..pool)
                .map(|_| {
                    // ...and every worker borrows that image and adopts
                    // the already-filled banks: spawning a worker moves
                    // no weight data, modeled or host-side.
                    let mut s = Scheduler::new(wcfg.clone(), cfg.mode);
                    s.attach_image(Arc::clone(&image));
                    s.adopt_weights(net);
                    s
                })
                .collect()
        };
        Ok(Engine {
            net,
            cfg,
            params: EnergyParams::default(),
            image,
            tail,
            workers,
            sessions: BTreeMap::new(),
            pending: Vec::new(),
        })
    }

    /// The engine's one shared prepared-weight image. `Arc::strong_count`
    /// on it is 2 + pool width (engine + tail + workers) — asserted by
    /// the pool-sharing tests.
    pub fn image(&self) -> &Arc<PreparedNet> {
        &self.image
    }

    /// Pool width (0 workers = serial: the tail runs the CNN too).
    pub fn pool_size(&self) -> usize {
        self.workers.len()
    }

    /// Register (or fetch) a stream's session. `submit` opens sessions
    /// implicitly; opening one explicitly matters only for zero-frame
    /// streams that still want a (empty) report.
    pub fn open_session(&mut self, id: usize) -> &mut Session {
        let voltage = self.cfg.voltage;
        let (depth, channels) = (self.tail.cfg.tcn_depth, self.tail.cfg.channels);
        self.sessions.entry(id).or_insert_with(|| Session::new(id, voltage, depth, channels))
    }

    /// Enqueue one frame on a stream. Work happens at the next `drain`.
    pub fn submit(&mut self, session_id: usize, frame: PackedMap) {
        self.open_session(session_id);
        self.pending.push((session_id, frame));
    }

    /// Pull up to `max_frames` frames from a source onto a stream;
    /// returns how many the source yielded before drying up.
    pub fn submit_from(
        &mut self,
        session_id: usize,
        src: &mut dyn FrameSource,
        max_frames: usize,
    ) -> usize {
        let mut n = 0;
        while n < max_frames {
            match src.next_frame() {
                Some(f) => {
                    self.submit(session_id, f);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    pub fn pending_frames(&self) -> usize {
        self.pending.len()
    }

    pub fn session_ids(&self) -> Vec<usize> {
        self.sessions.keys().copied().collect()
    }

    pub fn session(&self, id: usize) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Serve every pending frame; returns how many were served.
    ///
    /// Phase 1 (stateless, parallel): CNN front-ends across the worker
    /// pool. Phase 2 (stateful, sequential): per-frame TCN/SoC tail in
    /// submission order — per-session frame order is preserved because
    /// submission order is.
    pub fn drain(&mut self) -> Result<usize> {
        if self.pending.is_empty() {
            return Ok(0);
        }
        let wall0 = Instant::now();
        let pending = std::mem::take(&mut self.pending);

        // Phase 1: CNN front-end.
        let mut cnn: Vec<Option<(PackedMap, RunStats)>> = vec![None; pending.len()];
        if self.workers.is_empty() {
            for (i, (_, frame)) in pending.iter().enumerate() {
                cnn[i] = Some(self.tail.run_cnn(self.net, frame)?);
            }
        } else {
            let net = self.net;
            let nw = self.workers.len();
            let results: Vec<Vec<(usize, Result<(PackedMap, RunStats)>)>> =
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for (wi, sched) in self.workers.iter_mut().enumerate() {
                        let pending = &pending;
                        handles.push(scope.spawn(move || {
                            let mut out = Vec::new();
                            let mut i = wi;
                            while i < pending.len() {
                                out.push((i, sched.run_cnn(net, &pending[i].1)));
                                i += nw;
                            }
                            out
                        }));
                    }
                    handles.into_iter().map(|h| h.join().expect("cnn worker")).collect()
                });
            for (i, r) in results.into_iter().flatten() {
                cnn[i] = Some(r?);
            }
        }

        // Phase 2: stateful per-session tail, in submission order.
        let mut served: Vec<(usize, f64, f64)> = Vec::with_capacity(pending.len());
        for ((sid, frame), slot) in pending.into_iter().zip(cnn.into_iter()) {
            let (feat, mut run) = slot.expect("all frames dispatched");
            let sess = self.sessions.get_mut(&sid).expect("submit opened the session");
            sess.ingest(&frame);
            // check the stream's recurrent TCN window out into the tail;
            // the packed feature word moves into it as-is (no unpack)
            self.tail.swap_tcn(&mut sess.tcn);
            let tcn_result = match self.tail.push_feature(&feat) {
                Ok(()) => self.tail.run_tcn(self.net),
                Err(e) => Err(e),
            };
            self.tail.swap_tcn(&mut sess.tcn); // check back in, even on error
            let (logits, r) = tcn_result?;
            run.merge(r);
            let report = evaluate(&run, self.cfg.voltage, self.cfg.freq_hz, &self.params);
            sess.settle(report.time_s, report.energy_j);
            sess.labels.push(logits.argmax());
            served.push((sid, report.time_s * 1e6, report.energy_j));
        }

        // Host wall-clock is a measurement, not a simulation output:
        // amortize the drain across its frames (a 1-frame drain is the
        // inline policy's per-frame latency).
        let n = served.len();
        let wall_us = wall0.elapsed().as_secs_f64() * 1e6 / n.max(1) as f64;
        for (sid, sim_us, core_j) in served {
            let sess = self.sessions.get_mut(&sid).expect("session exists");
            sess.metrics.record_frame(sim_us, wall_us, core_j);
        }
        Ok(n)
    }

    /// Close one session into its final report (removes it).
    pub fn finish_session(&mut self, id: usize) -> Option<ServingReport> {
        self.sessions.remove(&id).map(Session::into_report)
    }

    /// Close every session, in session-id order.
    pub fn finish_all(&mut self) -> Vec<(usize, ServingReport)> {
        let ids = self.session_ids();
        ids.into_iter().map(|id| (id, self.finish_session(id).expect("listed id"))).collect()
    }

    /// Cross-session roll-up (latency samples concatenate, energies and
    /// wakeups sum, labels concatenate in session-id order). Average SoC
    /// power is total energy over total simulated SoC time.
    pub fn aggregate_report(&self) -> ServingReport {
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        let mut energy_j = 0.0;
        let mut fc_wakeups = 0u64;
        let mut now_ns = 0u64;
        for sess in self.sessions.values() {
            metrics.merge(&sess.metrics);
            energy_j += sess.soc.energy_j();
            fc_wakeups += sess.soc.fc_wakeups();
            now_ns += sess.soc.now_ns();
            labels.extend_from_slice(&sess.labels);
        }
        metrics.soc_energy_j = energy_j;
        ServingReport {
            soc_energy_j: energy_j,
            soc_avg_power_w: if now_ns == 0 { 0.0 } else { energy_j / (now_ns as f64 * 1e-9) },
            fc_wakeups,
            metrics,
            labels,
        }
    }
}
