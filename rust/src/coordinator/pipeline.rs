//! The serving pipeline: producer thread (DVS source → bounded channel,
//! i.e. backpressure) + inference loop (scheduler + SoC model + metrics).
//! Frames travel as bit-packed [`PackedMap`]s end to end (perf pass
//! iteration 8): the source emits packed, the queue carries packed, and
//! the scheduler serves packed — i8 never appears on the serving path.
//!
//! Three modes:
//! * [`Pipeline::run_inline`] — single-threaded, fully deterministic;
//! * [`Pipeline::run_threaded`] — producer/consumer over
//!   `std::sync::mpsc::sync_channel`, the process topology a real
//!   deployment would use (tokio is unavailable offline);
//! * [`Pipeline::run_batched`] — the multi-frame serving engine: the
//!   CNN front-end (the dominant per-frame cost) is sharded round-robin
//!   across a pool of worker schedulers, then the *stateful* tail — TCN
//!   window, SoC ledger, metrics — reduces sequentially in frame order.
//!   Labels, interrupt counts and energy ledgers are byte-identical to
//!   `run_inline` (asserted in tests); only host wall-clock changes.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use super::metrics::ServingMetrics;
use super::source::{DvsSource, GestureClass};
use crate::cutie::{dma_ingress_bytes, CutieConfig, RunStats, Scheduler, SimMode};
use crate::energy::{evaluate, EnergyParams};
use crate::network::Network;
use crate::soc::{Irq, KrakenSoc};
use crate::tensor::PackedMap;

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub voltage: f64,
    /// Clock override (None → fmax(V)).
    pub freq_hz: Option<f64>,
    /// Frames to serve.
    pub frames: usize,
    /// Bounded channel depth for the threaded mode (backpressure).
    pub queue_depth: usize,
    pub seed: u64,
    pub gesture: usize,
    pub mode: SimMode,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            voltage: 0.5,
            freq_hz: None,
            frames: 32,
            queue_depth: 4,
            seed: 7,
            gesture: 3,
            mode: SimMode::Accurate,
        }
    }
}

#[derive(Debug)]
pub struct ServingReport {
    pub metrics: ServingMetrics,
    pub soc_energy_j: f64,
    pub soc_avg_power_w: f64,
    pub fc_wakeups: u64,
    pub labels: Vec<usize>,
}

pub struct Pipeline {
    pub net: Network,
    pub cfg: PipelineConfig,
}

impl Pipeline {
    pub fn new(net: Network, cfg: PipelineConfig) -> Self {
        Pipeline { net, cfg }
    }

    fn serve_one(
        &self,
        sched: &mut Scheduler,
        soc: &mut KrakenSoc,
        params: &EnergyParams,
        metrics: &mut ServingMetrics,
        labels: &mut Vec<usize>,
        frame: &PackedMap,
    ) -> Result<()> {
        let wall0 = Instant::now();
        // µDMA ingress (SoC timeline) + frame-ready IRQ starts CUTIE
        soc.dma_ingest(dma_ingress_bytes(frame.numel()));
        soc.raise_irq(Irq::FrameReady);

        // accelerator: CNN → TCN memory → TCN window → logits
        let (logits, stats) = sched.serve_frame(&self.net, frame)?;
        let report = evaluate(&stats, self.cfg.voltage, self.cfg.freq_hz, params);

        // advance the SoC timeline by the accelerator's busy time and add
        // the core energy on top of the domain baseline
        soc.advance_ns((report.time_s * 1e9) as u64);
        soc.add_core_energy(report.energy_j);
        soc.raise_irq(Irq::CutieDone);
        soc.fc_service_done();

        labels.push(logits.argmax());
        let wall_us = wall0.elapsed().as_secs_f64() * 1e6;
        metrics.record_frame(report.time_s * 1e6, wall_us, report.energy_j);
        Ok(())
    }

    /// Deterministic single-threaded serving run.
    pub fn run_inline(&self) -> Result<ServingReport> {
        let params = EnergyParams::default();
        let mut sched = Scheduler::new(CutieConfig::kraken(), self.cfg.mode);
        sched.preload_weights(&self.net);
        let mut soc = KrakenSoc::new(self.cfg.voltage);
        let mut src = DvsSource::new(self.net.input_hw, self.cfg.seed, GestureClass(self.cfg.gesture));
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        for _ in 0..self.cfg.frames {
            let frame = src.next_frame();
            self.serve_one(&mut sched, &mut soc, &params, &mut metrics, &mut labels, &frame)?;
        }
        metrics.soc_energy_j = soc.ledger.energy_j;
        Ok(ServingReport {
            soc_energy_j: soc.ledger.energy_j,
            soc_avg_power_w: soc.avg_power_w(),
            fc_wakeups: soc.ledger.fc_wakeups,
            metrics,
            labels,
        })
    }

    /// Batched multi-frame serving: shard the CNN front-end across
    /// `workers` scheduler clones (0 → one per available core), then
    /// reduce the stateful TCN window + SoC ledger + metrics sequentially
    /// in frame order.
    ///
    /// Determinism argument: every per-frame counter the energy model
    /// consumes is sharding-invariant (the datapath's counters are
    /// analytic in the geometry, and toggle sums are order-independent),
    /// and each worker preloads the network so its weight accesses are
    /// the same steady-state bank switches the preloaded inline
    /// scheduler charges. The sequential reduce then replays exactly the
    /// operation sequence of [`Pipeline::run_inline`]'s serve loop, so
    /// labels, `fc_wakeups`, per-frame sim latencies and both energy
    /// ledgers come out byte-identical. Host wall-clock latency is a
    /// measurement, not a simulation output, and is amortized over the
    /// batch.
    pub fn run_batched(&self, workers: usize) -> Result<ServingReport> {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        if workers <= 1 {
            return self.run_inline();
        }
        let wall0 = Instant::now();

        // Same deterministic frame stream as run_inline.
        let mut src =
            DvsSource::new(self.net.input_hw, self.cfg.seed, GestureClass(self.cfg.gesture));
        let frames: Vec<PackedMap> = (0..self.cfg.frames).map(|_| src.next_frame()).collect();

        // Phase 1: CNN front-end on the worker pool. Layer-level row
        // sharding is pinned off inside workers (max_threads = 1) —
        // frame-level parallelism replaces it without oversubscription.
        let worker_cfg = CutieConfig { max_threads: 1, ..CutieConfig::kraken() };
        let net = &self.net;
        let mode = self.cfg.mode;
        let mut cnn: Vec<Option<(PackedMap, RunStats)>> = vec![None; frames.len()];
        let results: Vec<Vec<(usize, Result<(PackedMap, RunStats)>)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for wi in 0..workers {
                    let frames = &frames;
                    let wcfg = worker_cfg.clone();
                    handles.push(scope.spawn(move || {
                        let mut sched = Scheduler::new(wcfg, mode);
                        sched.preload_weights(net);
                        let mut out = Vec::new();
                        let mut i = wi;
                        while i < frames.len() {
                            out.push((i, sched.run_cnn(net, &frames[i])));
                            i += workers;
                        }
                        out
                    }));
                }
                handles.into_iter().map(|h| h.join().expect("cnn worker")).collect()
            });
        for (i, r) in results.into_iter().flatten() {
            cnn[i] = Some(r?);
        }

        // Phase 2: stateful reduce in frame order — exactly the inline
        // serve loop's operation sequence.
        let params = EnergyParams::default();
        let mut sched = Scheduler::new(CutieConfig::kraken(), self.cfg.mode);
        sched.preload_weights(&self.net);
        let mut soc = KrakenSoc::new(self.cfg.voltage);
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        let mut frame_reports = Vec::with_capacity(frames.len());
        for (frame, slot) in frames.iter().zip(cnn.into_iter()) {
            let (feat, mut run) = slot.expect("all frames dispatched");
            soc.dma_ingest(dma_ingress_bytes(frame.numel()));
            soc.raise_irq(Irq::FrameReady);
            sched.push_feature(&feat);
            let (logits, r) = sched.run_tcn(&self.net)?;
            run.merge(r);
            let report = evaluate(&run, self.cfg.voltage, self.cfg.freq_hz, &params);
            soc.advance_ns((report.time_s * 1e9) as u64);
            soc.add_core_energy(report.energy_j);
            soc.raise_irq(Irq::CutieDone);
            soc.fc_service_done();
            labels.push(logits.argmax());
            frame_reports.push((report.time_s * 1e6, report.energy_j));
        }
        let wall_us = wall0.elapsed().as_secs_f64() * 1e6 / frames.len().max(1) as f64;
        for (sim_us, core_j) in frame_reports {
            metrics.record_frame(sim_us, wall_us, core_j);
        }
        metrics.soc_energy_j = soc.ledger.energy_j;
        Ok(ServingReport {
            soc_energy_j: soc.ledger.energy_j,
            soc_avg_power_w: soc.avg_power_w(),
            fc_wakeups: soc.ledger.fc_wakeups,
            metrics,
            labels,
        })
    }

    /// Producer/consumer topology with a bounded frame queue.
    pub fn run_threaded(&self) -> Result<ServingReport> {
        let (tx, rx) = mpsc::sync_channel::<PackedMap>(self.cfg.queue_depth);
        let hw = self.net.input_hw;
        let seed = self.cfg.seed;
        let gesture = self.cfg.gesture;
        let frames = self.cfg.frames;
        let producer = std::thread::spawn(move || {
            let mut src = DvsSource::new(hw, seed, GestureClass(gesture));
            for _ in 0..frames {
                // send blocks when the queue is full → backpressure on
                // the (synthetic) camera, like µDMA flow control
                if tx.send(src.next_frame()).is_err() {
                    break;
                }
            }
        });

        let params = EnergyParams::default();
        let mut sched = Scheduler::new(CutieConfig::kraken(), self.cfg.mode);
        sched.preload_weights(&self.net);
        let mut soc = KrakenSoc::new(self.cfg.voltage);
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        while let Ok(frame) = rx.recv() {
            self.serve_one(&mut sched, &mut soc, &params, &mut metrics, &mut labels, &frame)?;
        }
        producer.join().expect("producer thread");
        metrics.soc_energy_j = soc.ledger.energy_j;
        Ok(ServingReport {
            soc_energy_j: soc.ledger.energy_j,
            soc_avg_power_w: soc.avg_power_w(),
            fc_wakeups: soc.ledger.fc_wakeups,
            metrics,
            labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::dvs_hybrid_random;

    fn small_pipeline(frames: usize) -> Pipeline {
        let net = dvs_hybrid_random(16, 5, 0.5);
        Pipeline::new(
            net,
            PipelineConfig { frames, mode: SimMode::Fast, ..Default::default() },
        )
    }

    #[test]
    fn inline_and_threaded_agree() {
        let p = small_pipeline(6);
        let a = p.run_inline().unwrap();
        let b = p.run_threaded().unwrap();
        assert_eq!(a.labels, b.labels, "topology must not change results");
        assert_eq!(a.fc_wakeups, b.fc_wakeups);
        assert_eq!(a.metrics.frames, 6);
    }

    #[test]
    fn batched_is_byte_identical_to_inline() {
        let p = small_pipeline(8);
        let mut a = p.run_inline().unwrap();
        for workers in [1, 2, 3] {
            let mut b = p.run_batched(workers).unwrap();
            assert_eq!(a.labels, b.labels, "workers {workers}: labels must match");
            assert_eq!(a.fc_wakeups, b.fc_wakeups, "workers {workers}");
            assert_eq!(
                a.soc_energy_j.to_bits(),
                b.soc_energy_j.to_bits(),
                "workers {workers}: SoC ledger must be byte-identical"
            );
            assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits());
            assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits());
            assert_eq!(a.metrics.frames, b.metrics.frames);
            // per-frame simulated latency distribution identical too
            for q in [0.0, 0.5, 1.0] {
                assert_eq!(
                    a.metrics.sim_latency_us.quantile(q).to_bits(),
                    b.metrics.sim_latency_us.quantile(q).to_bits(),
                    "workers {workers} q {q}"
                );
            }
        }
    }

    #[test]
    fn batched_accurate_mode_matches_inline_energy() {
        // Accurate mode exercises the toggle-counting path end to end;
        // toggle sums are order-independent so the energy ledger must
        // still be byte-identical.
        let net = dvs_hybrid_random(16, 5, 0.5);
        let p = Pipeline::new(
            net,
            PipelineConfig { frames: 5, mode: SimMode::Accurate, ..Default::default() },
        );
        let a = p.run_inline().unwrap();
        let b = p.run_batched(2).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits());
        assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits());
    }

    #[test]
    fn fc_wakes_once_per_frame() {
        let p = small_pipeline(5);
        let r = p.run_inline().unwrap();
        assert_eq!(r.fc_wakeups, 5);
        assert_eq!(r.labels.len(), 5);
    }

    #[test]
    fn energy_accumulates() {
        let p = small_pipeline(4);
        let r = p.run_inline().unwrap();
        assert!(r.soc_energy_j > 0.0);
        assert!(r.metrics.core_energy_j > 0.0);
        assert!(r.soc_energy_j > r.metrics.core_energy_j, "SoC adds baseline power");
    }
}
