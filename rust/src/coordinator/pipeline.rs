//! Single-stream serving policies — thin topology wrappers over the one
//! [`Engine`] serve path (api_redesign pass; the three previously
//! copy-pasted serve loops are gone):
//!
//! * [`Pipeline::run_inline`] — submit + drain one frame at a time on a
//!   serial engine: fully deterministic, per-frame wall latency;
//! * [`Pipeline::run_threaded`] — producer/consumer over
//!   `std::sync::mpsc::sync_channel` (bounded queue = µDMA-style
//!   backpressure on the synthetic camera; tokio is unavailable
//!   offline), consuming into the same serial engine;
//! * [`Pipeline::run_batched`] — submit the whole stream, drain once
//!   with a CNN worker pool: the multi-frame throughput policy.
//!
//! All three produce byte-identical [`ServingReport`]s (labels,
//! `fc_wakeups`, both energy ledgers, per-frame sim latencies) — the
//! engine's determinism argument lives in [`super::engine`]. As the
//! equivalence oracle, the pre-engine single-scheduler serve loop is
//! retained verbatim as [`Pipeline::run_reference`] and the tests assert
//! the engine path against it bit for bit, the same pattern as the
//! retained i8 window-stationary datapath loop.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

pub use super::metrics::ServingReport;
use super::engine::{Engine, EngineConfig};
use super::metrics::ServingMetrics;
use super::source::{DvsSource, GestureClass};
use crate::cutie::{dma_ingress_bytes, CutieConfig, PreparedNet, Scheduler, SimMode};
use crate::energy::{evaluate, EnergyParams};
use crate::network::Network;
use crate::soc::{Irq, KrakenSoc};
use crate::tensor::PackedMap;

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub voltage: f64,
    /// Clock override (None → fmax(V)).
    pub freq_hz: Option<f64>,
    /// Frames to serve.
    pub frames: usize,
    /// Bounded channel depth for the threaded mode (backpressure).
    pub queue_depth: usize,
    pub seed: u64,
    pub gesture: usize,
    pub mode: SimMode,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            voltage: 0.5,
            freq_hz: None,
            frames: 32,
            queue_depth: 4,
            seed: 7,
            gesture: 3,
            mode: SimMode::Accurate,
        }
    }
}

pub struct Pipeline {
    pub net: Network,
    pub cfg: PipelineConfig,
    /// The one prepared-weight image every policy run's engine borrows
    /// (shared-image pass) — built once per pipeline, not per policy.
    image: Arc<PreparedNet>,
}

impl Pipeline {
    pub fn new(net: Network, cfg: PipelineConfig) -> Self {
        let image = Arc::new(PreparedNet::new(&net, &CutieConfig::kraken()));
        Pipeline { net, cfg, image }
    }

    /// Construct over a pre-built weight image (e.g. word-copy-loaded
    /// from a packed `.ttn` v2 file). The image is fully validated
    /// against `net` (coverage, geometry, pooling flags, thresholds —
    /// see [`PreparedNet::validate_against`] for what the check cannot
    /// cover) before any policy serves from it.
    pub fn with_image(net: Network, cfg: PipelineConfig, image: Arc<PreparedNet>) -> Result<Self> {
        image.validate_against(&net)?;
        ensure!(
            image.matches(&net),
            "prepared image '{}' does not match network '{}'",
            image.net_name(),
            net.name
        );
        Ok(Pipeline { net, cfg, image })
    }

    /// The engine this pipeline's policies are wrappers over. The image
    /// was validated at pipeline construction, but boot can still fail
    /// legitimately (e.g. a sub-threshold supply with no explicit
    /// clock) — surfaced as a typed error, not a serving-path panic.
    fn engine(&self, workers: usize) -> Result<Engine> {
        Engine::with_image(
            &self.net,
            EngineConfig {
                voltage: self.cfg.voltage,
                freq_hz: self.cfg.freq_hz,
                mode: self.cfg.mode,
                workers,
                ..EngineConfig::default()
            },
            Arc::clone(&self.image),
        )
        .context("booting the serving engine")
    }

    /// This pipeline's deterministic synthetic gesture stream.
    fn source(&self) -> DvsSource {
        DvsSource::new(self.net.input_hw, self.cfg.seed, GestureClass(self.cfg.gesture))
    }

    /// Deterministic single-threaded serving run: one session, one frame
    /// submitted and drained at a time.
    pub fn run_inline(&self) -> Result<ServingReport> {
        let mut engine = self.engine(1)?;
        engine.open_session(0)?;
        let mut src = self.source();
        for _ in 0..self.cfg.frames {
            engine.submit(0, src.next_frame())?;
            engine.drain()?;
        }
        engine.finish_session(0).context("session 0 was never opened")
    }

    /// Producer/consumer topology with a bounded frame queue feeding the
    /// serial engine — the process topology a real deployment would use.
    pub fn run_threaded(&self) -> Result<ServingReport> {
        let (tx, rx) = mpsc::sync_channel::<PackedMap>(self.cfg.queue_depth);
        let mut src = self.source();
        let frames = self.cfg.frames;
        let producer = std::thread::spawn(move || {
            for _ in 0..frames {
                // send blocks when the queue is full → backpressure on
                // the (synthetic) camera, like µDMA flow control
                if tx.send(src.next_frame()).is_err() {
                    break;
                }
            }
        });

        let mut engine = self.engine(1)?;
        engine.open_session(0)?;
        while let Ok(frame) = rx.recv() {
            engine.submit(0, frame)?;
            engine.drain()?;
        }
        producer.join().map_err(|_| anyhow!("frame producer thread panicked"))?;
        engine.finish_session(0).context("session 0 was never opened")
    }

    /// Batched multi-frame serving: submit the whole stream, then one
    /// drain with the CNN front-end sharded across `workers` scheduler
    /// clones (0 → one per available core). Labels, interrupt counts and
    /// energy ledgers are byte-identical to `run_inline` (asserted in
    /// tests); only host wall-clock changes.
    pub fn run_batched(&self, workers: usize) -> Result<ServingReport> {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        if workers <= 1 {
            return self.run_inline();
        }
        let mut engine = self.engine(workers)?;
        engine.open_session(0)?;
        let mut src = self.source();
        for _ in 0..self.cfg.frames {
            engine.submit(0, src.next_frame())?;
        }
        engine.drain()?;
        engine.finish_session(0).context("session 0 was never opened")
    }

    /// The retained pre-engine serve loop: one scheduler, one SoC, the §5
    /// per-frame sequence written out long-hand. Kept verbatim as the
    /// equivalence oracle the engine path is asserted byte-identical
    /// against (`engine_path_matches_reference_loop`), not used for
    /// serving. Deliberately builds its own private weight image instead
    /// of borrowing the pipeline's shared one, so it also oracles the
    /// shared-image path.
    pub fn run_reference(&self) -> Result<ServingReport> {
        let params = EnergyParams::default();
        let mut sched = Scheduler::new(CutieConfig::kraken(), self.cfg.mode);
        sched.preload_weights(&self.net);
        let mut soc = KrakenSoc::new(self.cfg.voltage);
        let mut src = self.source();
        let mut metrics = ServingMetrics::default();
        let mut labels = Vec::new();
        for _ in 0..self.cfg.frames {
            let frame = src.next_frame();
            let wall0 = Instant::now();
            // µDMA ingress (SoC timeline) + frame-ready IRQ starts CUTIE
            soc.dma_ingest(dma_ingress_bytes(frame.numel()));
            soc.raise_irq(Irq::FrameReady);

            // accelerator: CNN → TCN memory → TCN window → logits
            let (logits, stats) = sched.serve_frame(&self.net, &frame)?;
            let report = evaluate(&stats, self.cfg.voltage, self.cfg.freq_hz, &params)?;

            // advance the SoC timeline by the accelerator's busy time and
            // add the core energy on top of the domain baseline
            soc.advance_ns((report.time_s * 1e9) as u64);
            soc.add_core_energy(report.energy_j);
            soc.raise_irq(Irq::CutieDone);
            soc.fc_service_done();

            labels.push(logits.argmax());
            let wall_us = wall0.elapsed().as_secs_f64() * 1e6;
            metrics.record_frame(report.time_s * 1e6, wall_us, report.energy_j);
        }
        Ok(ServingReport::from_parts(
            metrics,
            &soc,
            labels,
            crate::fault::FaultSummary::default(),
            super::hibernate::HibernationStats::default(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::dvs_hybrid_random;

    fn small_pipeline(frames: usize) -> Pipeline {
        let net = dvs_hybrid_random(16, 5, 0.5);
        Pipeline::new(
            net,
            PipelineConfig { frames, mode: SimMode::Fast, ..Default::default() },
        )
    }

    fn assert_byte_identical(a: &mut ServingReport, b: &mut ServingReport, ctx: &str) {
        assert_eq!(a.labels, b.labels, "{ctx}: labels must match");
        assert_eq!(a.fc_wakeups, b.fc_wakeups, "{ctx}: fc_wakeups");
        assert_eq!(
            a.soc_energy_j.to_bits(),
            b.soc_energy_j.to_bits(),
            "{ctx}: SoC ledger must be byte-identical"
        );
        assert_eq!(a.metrics.soc_energy_j.to_bits(), b.metrics.soc_energy_j.to_bits(), "{ctx}");
        assert_eq!(a.soc_avg_power_w.to_bits(), b.soc_avg_power_w.to_bits(), "{ctx}");
        assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits(), "{ctx}");
        assert_eq!(a.metrics.sim_time_s.to_bits(), b.metrics.sim_time_s.to_bits(), "{ctx}");
        assert_eq!(a.metrics.frames, b.metrics.frames, "{ctx}");
        // per-frame simulated latency distribution identical too
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(
                a.metrics.sim_latency_us.quantile(q).to_bits(),
                b.metrics.sim_latency_us.quantile(q).to_bits(),
                "{ctx} q {q}"
            );
        }
    }

    #[test]
    fn engine_path_matches_reference_loop() {
        // The acceptance gate of the api_redesign pass: the engine-backed
        // policies must reproduce the pre-engine serve loop bit for bit,
        // in both sim modes.
        for mode in [SimMode::Fast, SimMode::Accurate] {
            let net = dvs_hybrid_random(16, 5, 0.5);
            let p = Pipeline::new(net, PipelineConfig { frames: 5, mode, ..Default::default() });
            let mut want = p.run_reference().unwrap();
            let mut inline = p.run_inline().unwrap();
            assert_byte_identical(&mut inline, &mut want, &format!("inline {mode:?}"));
            let mut batched = p.run_batched(2).unwrap();
            assert_byte_identical(&mut batched, &mut want, &format!("batched {mode:?}"));
            let mut threaded = p.run_threaded().unwrap();
            assert_byte_identical(&mut threaded, &mut want, &format!("threaded {mode:?}"));
        }
    }

    #[test]
    fn inline_and_threaded_agree() {
        let p = small_pipeline(6);
        let a = p.run_inline().unwrap();
        let b = p.run_threaded().unwrap();
        assert_eq!(a.labels, b.labels, "topology must not change results");
        assert_eq!(a.fc_wakeups, b.fc_wakeups);
        assert_eq!(a.metrics.frames, 6);
    }

    #[test]
    fn batched_is_byte_identical_to_inline() {
        let p = small_pipeline(8);
        let mut a = p.run_inline().unwrap();
        for workers in [1, 2, 3] {
            let mut b = p.run_batched(workers).unwrap();
            assert_byte_identical(&mut b, &mut a, &format!("workers {workers}"));
        }
    }

    #[test]
    fn batched_accurate_mode_matches_inline_energy() {
        // Accurate mode exercises the toggle-counting path end to end;
        // toggle sums are order-independent so the energy ledger must
        // still be byte-identical.
        let net = dvs_hybrid_random(16, 5, 0.5);
        let p = Pipeline::new(
            net,
            PipelineConfig { frames: 5, mode: SimMode::Accurate, ..Default::default() },
        );
        let a = p.run_inline().unwrap();
        let b = p.run_batched(2).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.soc_energy_j.to_bits(), b.soc_energy_j.to_bits());
        assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits());
    }

    #[test]
    fn fc_wakes_once_per_frame() {
        let p = small_pipeline(5);
        let r = p.run_inline().unwrap();
        assert_eq!(r.fc_wakeups, 5);
        assert_eq!(r.labels.len(), 5);
    }

    #[test]
    fn zero_frame_run_yields_empty_report() {
        let p = small_pipeline(0);
        let r = p.run_inline().unwrap();
        assert_eq!(r.metrics.frames, 0);
        assert_eq!(r.fc_wakeups, 0);
        assert!(r.labels.is_empty());
        assert_eq!(r.soc_energy_j, 0.0);
    }

    #[test]
    fn energy_accumulates() {
        let p = small_pipeline(4);
        let r = p.run_inline().unwrap();
        assert!(r.soc_energy_j > 0.0);
        assert!(r.metrics.core_energy_j > 0.0);
        assert!(r.soc_energy_j > r.metrics.core_energy_j, "SoC adds baseline power");
    }
}
