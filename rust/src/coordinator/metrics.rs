//! Serving metrics: latency percentiles (both simulated-hardware time and
//! host wallclock), throughput, the energy ledger summary, and the
//! [`ServingReport`] every serving policy returns.

use std::collections::BTreeMap;

use crate::fault::FaultSummary;
use crate::soc::KrakenSoc;
use crate::util::stats::Percentiles;

use super::hibernate::HibernationStats;

/// Per-net aggregate of a serving run (multi-workload pass): how much of
/// the fleet's work each registered net carried. Sums only — the f64
/// fields fold in global session-id order like every other ledger, so a
/// sharded fleet's per-net rows are bit-identical to one engine's.
#[derive(Debug, Default, Clone)]
pub struct NetUsage {
    /// Content fingerprint of the net's prepared image.
    pub fingerprint: u64,
    /// The net's name as registered.
    pub name: String,
    pub sessions: u64,
    pub frames: u64,
    pub labels: u64,
    pub core_energy_j: f64,
    pub soc_energy_j: f64,
    pub sim_time_s: f64,
}

#[derive(Debug, Default, Clone)]
pub struct ServingMetrics {
    /// Simulated on-chip latency per served frame (µs).
    pub sim_latency_us: Percentiles,
    /// Host wallclock per served frame (µs) — the simulator's own speed.
    pub wall_latency_us: Percentiles,
    pub frames: u64,
    pub labels_emitted: u64,
    /// Simulated accelerator-core energy (J).
    pub core_energy_j: f64,
    /// Simulated total SoC energy (J).
    pub soc_energy_j: f64,
    /// Total simulated time (s).
    pub sim_time_s: f64,
}

impl ServingMetrics {
    pub fn record_frame(&mut self, sim_us: f64, wall_us: f64, core_j: f64) {
        self.sim_latency_us.record(sim_us);
        self.wall_latency_us.record(wall_us);
        self.frames += 1;
        self.labels_emitted += 1;
        self.core_energy_j += core_j;
        self.sim_time_s += sim_us * 1e-6;
    }

    /// Roll up the metrics of another, independent serving run (e.g.
    /// per-gesture pipelines benched separately). Counts and energies
    /// are sums; the latency histograms concatenate their sample sets,
    /// so percentiles over the merged set do not depend on merge order.
    /// (`run_batched` itself needs no merge: it records its frames
    /// sequentially into one `ServingMetrics`.)
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.sim_latency_us.absorb(&other.sim_latency_us);
        self.wall_latency_us.absorb(&other.wall_latency_us);
        self.frames += other.frames;
        self.labels_emitted += other.labels_emitted;
        self.core_energy_j += other.core_energy_j;
        self.soc_energy_j += other.soc_energy_j;
        self.sim_time_s += other.sim_time_s;
    }

    /// Simulated inferences per second (sustained).
    pub fn sim_inf_per_s(&self) -> f64 {
        if self.sim_time_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.sim_time_s
    }

    pub fn summary(&mut self) -> String {
        if self.frames == 0 {
            return "no frames served".to_string();
        }
        format!(
            "frames {}  sim-latency p50/p95/p99 {:.1}/{:.1}/{:.1} µs  \
             sim rate {:.0} inf/s  core {:.2} µJ/inf  wall p50 {:.1} µs",
            self.frames,
            self.sim_latency_us.quantile(0.5),
            self.sim_latency_us.quantile(0.95),
            self.sim_latency_us.quantile(0.99),
            self.sim_inf_per_s(),
            self.core_energy_j / self.frames as f64 * 1e6,
            self.wall_latency_us.quantile(0.5),
        )
    }
}

/// Final result of a serving run (one session's stream, or a
/// cross-session aggregate).
#[derive(Debug)]
pub struct ServingReport {
    pub metrics: ServingMetrics,
    pub soc_energy_j: f64,
    pub soc_avg_power_w: f64,
    pub fc_wakeups: u64,
    pub labels: Vec<usize>,
    /// Fault-injection/resilience ledger: exactly `Default` for a run
    /// with no armed fault plan (the zero-BER bit-exactness contract).
    pub faults: FaultSummary,
    /// Hibernation ledger: exactly `Default` for an always-resident run.
    /// Retention/wake energy lives here, never in `soc_energy_j` — the
    /// idle tier must not perturb the calibrated serving ledgers.
    pub hib: HibernationStats,
    /// Per-net usage rows, fingerprint-sorted. Empty for single-session
    /// reports assembled via [`ServingReport::from_parts`]; aggregate
    /// reports folded through [`ReportAccumulator::add_for_net`] carry
    /// one row per net that served at least one session.
    pub nets: Vec<NetUsage>,
    /// The packed-kernel backend that was active when the report was
    /// assembled (`"scalar"` or `"avx2"`) — attribution only; both
    /// backends produce bit-identical ledgers.
    pub backend: &'static str,
}

impl ServingReport {
    /// The one place report fields are assembled from a finished SoC
    /// ledger (previously triplicated across the three `run_*` serve
    /// loops; any field drift now fails every path at once).
    pub fn from_parts(
        mut metrics: ServingMetrics,
        soc: &KrakenSoc,
        labels: Vec<usize>,
        faults: FaultSummary,
        hib: HibernationStats,
    ) -> Self {
        metrics.soc_energy_j = soc.energy_j();
        ServingReport {
            soc_energy_j: soc.energy_j(),
            soc_avg_power_w: soc.avg_power_w(),
            fc_wakeups: soc.fc_wakeups(),
            metrics,
            labels,
            faults,
            hib,
            nets: Vec::new(),
            backend: crate::trit::simd::active_name(),
        }
    }
}

/// Order-preserving builder for cross-session (and cross-engine)
/// aggregate reports.
///
/// The f64 ledger sums are sensitive to accumulation order; every
/// aggregation path — `Engine::aggregate_report`, the fleet's merged
/// `FleetReport` — must fold sessions through this one type in global
/// session-id order so a 3-engine fleet's aggregate is bit-identical to
/// the same sessions served on one engine.
#[derive(Debug, Default)]
pub struct ReportAccumulator {
    metrics: ServingMetrics,
    labels: Vec<usize>,
    faults: FaultSummary,
    hib: HibernationStats,
    energy_j: f64,
    fc_wakeups: u64,
    now_ns: u64,
    nets: BTreeMap<u64, NetUsage>,
}

impl ReportAccumulator {
    /// Fold one session's full contribution. The merge order within a
    /// session (metrics, faults, hib, energy, wakeups, time, labels) is
    /// fixed — do not reorder, it is part of the bit-identity contract.
    pub fn add(
        &mut self,
        metrics: &ServingMetrics,
        labels: &[usize],
        faults: &FaultSummary,
        hib: &HibernationStats,
        soc_energy_j: f64,
        fc_wakeups: u64,
        now_ns: u64,
    ) {
        self.metrics.merge(metrics);
        self.faults.merge(faults);
        self.hib.merge(hib);
        self.energy_j += soc_energy_j;
        self.fc_wakeups += fc_wakeups;
        self.now_ns += now_ns;
        self.labels.extend_from_slice(labels);
    }

    /// [`ReportAccumulator::add`], plus fold the session's totals into
    /// its net's usage row. `net` is the session's binding (fingerprint +
    /// registered name); `None` folds the session with no per-net row —
    /// the pre-registry aggregation, byte-identical because the shared
    /// ledgers never see the row map.
    #[allow(clippy::too_many_arguments)]
    pub fn add_for_net(
        &mut self,
        net: Option<(u64, &str)>,
        metrics: &ServingMetrics,
        labels: &[usize],
        faults: &FaultSummary,
        hib: &HibernationStats,
        soc_energy_j: f64,
        fc_wakeups: u64,
        now_ns: u64,
    ) {
        self.add(metrics, labels, faults, hib, soc_energy_j, fc_wakeups, now_ns);
        if let Some((fingerprint, name)) = net {
            let row = self.nets.entry(fingerprint).or_insert_with(|| NetUsage {
                fingerprint,
                name: name.to_string(),
                ..NetUsage::default()
            });
            row.sessions += 1;
            row.frames += metrics.frames;
            row.labels += metrics.labels_emitted;
            row.core_energy_j += metrics.core_energy_j;
            row.soc_energy_j += soc_energy_j;
            row.sim_time_s += metrics.sim_time_s;
        }
    }

    /// Fold a hibernation-ledger-only contribution: engine-side accruals
    /// (retention ticks, wake charges) for a stored session whose
    /// snapshot payload is not being decoded here.
    pub fn add_hibernation(&mut self, hib: &HibernationStats) {
        self.hib.merge(hib);
    }

    pub fn finish(mut self) -> ServingReport {
        self.metrics.soc_energy_j = self.energy_j;
        ServingReport {
            soc_energy_j: self.energy_j,
            soc_avg_power_w: if self.now_ns == 0 {
                0.0
            } else {
                self.energy_j / (self.now_ns as f64 * 1e-9)
            },
            fc_wakeups: self.fc_wakeups,
            metrics: self.metrics,
            labels: self.labels,
            faults: self.faults,
            hib: self.hib,
            nets: self.nets.into_values().collect(),
            backend: crate::trit::simd::active_name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_fields_come_from_the_ledger() {
        let mut soc = KrakenSoc::new(0.5);
        soc.dma_ingest(256);
        soc.raise_irq(crate::soc::Irq::FrameReady);
        soc.advance_ns(10_000);
        soc.add_core_energy(1e-6);
        soc.raise_irq(crate::soc::Irq::CutieDone);
        soc.fc_service_done();
        let mut m = ServingMetrics::default();
        m.record_frame(10.0, 5.0, 1e-6);
        let r = ServingReport::from_parts(
            m,
            &soc,
            vec![3],
            FaultSummary::default(),
            HibernationStats::default(),
        );
        assert_eq!(r.soc_energy_j.to_bits(), soc.energy_j().to_bits());
        assert!(!r.faults.any(), "clean run carries an all-zero fault ledger");
        assert!(!r.hib.any(), "always-resident run carries an all-zero hibernation ledger");
        assert_eq!(r.metrics.soc_energy_j.to_bits(), soc.energy_j().to_bits());
        assert_eq!(r.soc_avg_power_w.to_bits(), soc.avg_power_w().to_bits());
        assert_eq!(r.fc_wakeups, 1);
        assert_eq!(r.labels, vec![3]);
    }

    #[test]
    fn rates_and_energy() {
        let mut m = ServingMetrics::default();
        for _ in 0..10 {
            m.record_frame(100.0, 5.0, 1e-6);
        }
        assert_eq!(m.frames, 10);
        assert!((m.sim_inf_per_s() - 10_000.0).abs() < 1.0);
        assert!((m.core_energy_j - 1e-5).abs() < 1e-12);
        assert!(m.summary().contains("frames 10"));
    }

    #[test]
    fn accumulator_matches_single_session_assembly() {
        let mut soc = KrakenSoc::new(0.5);
        soc.dma_ingest(256);
        soc.raise_irq(crate::soc::Irq::FrameReady);
        soc.advance_ns(10_000);
        soc.add_core_energy(1e-6);
        soc.raise_irq(crate::soc::Irq::CutieDone);
        soc.fc_service_done();
        let mut m = ServingMetrics::default();
        m.record_frame(10.0, 5.0, 1e-6);
        let direct = ServingReport::from_parts(
            m.clone(),
            &soc,
            vec![3],
            FaultSummary::default(),
            HibernationStats::default(),
        );
        let mut acc = ReportAccumulator::default();
        acc.add(
            &m,
            &[3],
            &FaultSummary::default(),
            &HibernationStats::default(),
            soc.energy_j(),
            soc.fc_wakeups(),
            soc.now_ns(),
        );
        let folded = acc.finish();
        assert_eq!(folded.soc_energy_j.to_bits(), direct.soc_energy_j.to_bits());
        assert_eq!(folded.soc_avg_power_w.to_bits(), direct.soc_avg_power_w.to_bits());
        assert_eq!(folded.fc_wakeups, direct.fc_wakeups);
        assert_eq!(folded.labels, direct.labels);
        assert_eq!(
            folded.metrics.soc_energy_j.to_bits(),
            direct.metrics.soc_energy_j.to_bits()
        );
    }

    #[test]
    fn per_net_rows_ride_alongside_the_shared_ledgers() {
        let mut m_dvs = ServingMetrics::default();
        m_dvs.record_frame(10.0, 5.0, 1e-6);
        m_dvs.record_frame(12.0, 5.0, 1e-6);
        let mut m_cif = ServingMetrics::default();
        m_cif.record_frame(20.0, 5.0, 3e-6);

        let mut plain = ReportAccumulator::default();
        let mut tagged = ReportAccumulator::default();
        for (net, m, e) in [
            (Some((7u64, "dvs")), &m_dvs, 4e-6),
            (Some((3u64, "cifar9")), &m_cif, 5e-6),
            (Some((7u64, "dvs")), &m_dvs, 4e-6),
        ] {
            plain.add(
                m,
                &[1],
                &FaultSummary::default(),
                &HibernationStats::default(),
                e,
                1,
                10_000,
            );
            tagged.add_for_net(
                net,
                m,
                &[1],
                &FaultSummary::default(),
                &HibernationStats::default(),
                e,
                1,
                10_000,
            );
        }
        let (plain, tagged) = (plain.finish(), tagged.finish());
        // the shared ledgers never see the row map
        assert_eq!(plain.soc_energy_j.to_bits(), tagged.soc_energy_j.to_bits());
        assert_eq!(plain.soc_avg_power_w.to_bits(), tagged.soc_avg_power_w.to_bits());
        assert_eq!(plain.metrics.frames, tagged.metrics.frames);
        assert!(plain.nets.is_empty());
        // rows are fingerprint-sorted with summed usage
        assert_eq!(tagged.nets.len(), 2);
        assert_eq!(tagged.nets[0].name, "cifar9");
        assert_eq!((tagged.nets[0].sessions, tagged.nets[0].frames), (1, 1));
        assert_eq!(tagged.nets[1].name, "dvs");
        assert_eq!((tagged.nets[1].sessions, tagged.nets[1].frames), (2, 4));
        assert!((tagged.nets[1].soc_energy_j - 8e-6).abs() < 1e-18);
    }

    #[test]
    fn merge_is_shard_order_independent() {
        let mut shard_a = ServingMetrics::default();
        let mut shard_b = ServingMetrics::default();
        for i in 0..5 {
            shard_a.record_frame(100.0 + i as f64, 5.0, 1e-6);
            shard_b.record_frame(200.0 + i as f64, 7.0, 2e-6);
        }
        let mut ab = ServingMetrics::default();
        ab.merge(&shard_a);
        ab.merge(&shard_b);
        let mut ba = ServingMetrics::default();
        ba.merge(&shard_b);
        ba.merge(&shard_a);
        assert_eq!(ab.frames, 10);
        assert_eq!(ab.frames, ba.frames);
        assert_eq!(ab.core_energy_j.to_bits(), ba.core_energy_j.to_bits());
        assert_eq!(
            ab.sim_latency_us.quantile(0.5).to_bits(),
            ba.sim_latency_us.quantile(0.5).to_bits()
        );
        assert_eq!(ab.sim_latency_us.quantile(1.0), 204.0);
    }
}
