//! Serving metrics: latency percentiles (both simulated-hardware time and
//! host wallclock), throughput, and the energy ledger summary.

use crate::util::stats::Percentiles;

#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Simulated on-chip latency per served frame (µs).
    pub sim_latency_us: Percentiles,
    /// Host wallclock per served frame (µs) — the simulator's own speed.
    pub wall_latency_us: Percentiles,
    pub frames: u64,
    pub labels_emitted: u64,
    /// Simulated accelerator-core energy (J).
    pub core_energy_j: f64,
    /// Simulated total SoC energy (J).
    pub soc_energy_j: f64,
    /// Total simulated time (s).
    pub sim_time_s: f64,
}

impl ServingMetrics {
    pub fn record_frame(&mut self, sim_us: f64, wall_us: f64, core_j: f64) {
        self.sim_latency_us.record(sim_us);
        self.wall_latency_us.record(wall_us);
        self.frames += 1;
        self.labels_emitted += 1;
        self.core_energy_j += core_j;
        self.sim_time_s += sim_us * 1e-6;
    }

    /// Simulated inferences per second (sustained).
    pub fn sim_inf_per_s(&self) -> f64 {
        if self.sim_time_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.sim_time_s
    }

    pub fn summary(&mut self) -> String {
        if self.frames == 0 {
            return "no frames served".to_string();
        }
        format!(
            "frames {}  sim-latency p50/p95/p99 {:.1}/{:.1}/{:.1} µs  \
             sim rate {:.0} inf/s  core {:.2} µJ/inf  wall p50 {:.1} µs",
            self.frames,
            self.sim_latency_us.quantile(0.5),
            self.sim_latency_us.quantile(0.95),
            self.sim_latency_us.quantile(0.99),
            self.sim_inf_per_s(),
            self.core_energy_j / self.frames as f64 * 1e6,
            self.wall_latency_us.quantile(0.5),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_and_energy() {
        let mut m = ServingMetrics::default();
        for _ in 0..10 {
            m.record_frame(100.0, 5.0, 1e-6);
        }
        assert_eq!(m.frames, 10);
        assert!((m.sim_inf_per_s() - 10_000.0).abs() < 1.0);
        assert!((m.core_energy_j - 1e-5).abs() < 1e-12);
        assert!(m.summary().contains("frames 10"));
    }
}
