//! Per-stream serving state. One [`Session`] = one user's frame stream:
//! its own 24-step TCN window (the recurrent state of the hybrid
//! network, held as packed (pos, mask) feature words — it checks out
//! into the tail scheduler via `swap_tcn` and back in without ever
//! leaving the 2-bit encoding), its own [`KrakenSoc`] energy/time
//! ledger, label history and latency metrics. Sessions share the
//! engine's stateless compute — the scheduler pool, the weight-bank
//! residency model, and the engine's one `Arc`'d prepared-weight image
//! (shared-image pass) — but never each other's recurrent state, so N
//! streams can interleave through one engine with byte-identical
//! results to serving each alone.

use crate::cutie::TcnMemory;
use crate::fault::{FaultPlan, FaultSummary, Injector};
use crate::soc::KrakenSoc;
use crate::tensor::PackedMap;

use super::hibernate::HibernationStats;
use super::metrics::{ServingMetrics, ServingReport};
use super::registry::SessionGeometry;

/// Terminal frame failures a session absorbs before it is quarantined
/// (further frames are dropped instead of served).
pub const FAILURE_LIMIT: u64 = 2;

/// A session's armed fault plan plus its private injector stream. The
/// injector lives with the session so its RNG consumption follows the
/// per-session frame order, whatever the drain cadence.
pub(crate) struct FaultState {
    pub(crate) plan: FaultPlan,
    pub(crate) inj: Injector,
}

pub struct Session {
    pub id: usize,
    /// The session's net binding (multi-workload pass): the fingerprint
    /// of the prepared image every frame routes through, plus the typed
    /// input/window dims submitted frames are checked against. Fixed for
    /// the session's lifetime and recorded in hibernation snapshots so
    /// resume/migration re-binds the same net.
    pub geometry: SessionGeometry,
    /// The stream's recurrent TCN window (a packed-word ring); checked
    /// out into the tail scheduler for the duration of each of this
    /// session's frames.
    pub tcn: TcnMemory,
    /// The stream's SoC timeline: µDMA ingress, IRQs, FC wakeups, energy.
    pub soc: KrakenSoc,
    pub metrics: ServingMetrics,
    pub labels: Vec<usize>,
    /// Armed fault-injection state (None = clean session).
    pub(crate) fault: Option<FaultState>,
    /// Fault/resilience ledger (exactly `Default` for a clean session).
    pub faults: FaultSummary,
    /// Hibernate/resume/retention ledger (exactly `Default` for an
    /// always-resident session). Rides through snapshots so a session's
    /// full idle-tier history survives its own hibernation.
    pub hib: HibernationStats,
    /// Consecutive engine drains this session sat idle through (resets
    /// on activity; drives idle eviction). Deliberately NOT snapshotted:
    /// a freshly resumed session restarts its idle clock.
    pub(crate) idle_drains: u64,
    /// Engine drain-counter value when this session last served a frame
    /// (0 = never). Drives least-recently-active eviction under a
    /// resident-session budget. Like `idle_drains`, deliberately NOT
    /// snapshotted — recency is a property of this engine's timeline,
    /// not of the session's architectural state.
    pub(crate) last_active: u64,
}

impl Session {
    pub fn new(id: usize, voltage: f64, geometry: SessionGeometry) -> Self {
        Session {
            id,
            geometry,
            tcn: TcnMemory::new(geometry.tcn_depth, geometry.channels),
            soc: KrakenSoc::new(voltage),
            metrics: ServingMetrics::default(),
            labels: Vec::new(),
            fault: None,
            faults: FaultSummary::default(),
            hib: HibernationStats::default(),
            idle_drains: 0,
            last_active: 0,
        }
    }

    /// Frames served so far (== labels emitted).
    pub fn frames_served(&self) -> u64 {
        self.metrics.frames
    }

    /// True once the session tripped [`FAILURE_LIMIT`]: its pending
    /// frames are dropped instead of served, so one misbehaving stream
    /// cannot keep hitting the shared tail.
    pub fn is_quarantined(&self) -> bool {
        self.faults.quarantined > 0
    }

    /// Record one terminal frame failure; trips quarantine at the limit.
    pub(crate) fn note_failure(&mut self) {
        self.faults.failures += 1;
        if self.faults.failures >= FAILURE_LIMIT {
            self.faults.quarantined = 1;
        }
    }

    /// Close the session into its final report.
    pub fn into_report(self) -> ServingReport {
        ServingReport::from_parts(self.metrics, &self.soc, self.labels, self.faults, self.hib)
    }

    /// The per-frame SoC preamble of the §5 autonomous flow: µDMA ingress
    /// of the packed payload, then the frame-ready IRQ that starts CUTIE.
    pub(crate) fn ingest(&mut self, frame: &PackedMap) {
        self.soc.dma_ingest(crate::cutie::dma_ingress_bytes(frame.numel()));
        self.soc.raise_irq(crate::soc::Irq::FrameReady);
    }

    /// The per-frame SoC postamble: advance the timeline by the
    /// accelerator's busy time, add core energy on the domain baseline,
    /// then the done-IRQ → FC readout → back to sleep.
    pub(crate) fn settle(&mut self, time_s: f64, energy_j: f64) {
        self.soc.advance_ns((time_s * 1e9) as u64);
        self.soc.add_core_energy(energy_j);
        self.soc.raise_irq(crate::soc::Irq::CutieDone);
        self.soc.fc_service_done();
    }
}
