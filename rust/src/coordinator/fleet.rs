//! The sharded serving fleet — one front door over N engines.
//!
//! The paper's 8000 inf/s @ 12.2 mW corner is a *per-chip* number;
//! scaling past it means replication, exactly how CUTIE itself scales
//! (a fully-unrolled datapath replicated per output channel, not a
//! bigger unit). A [`Fleet`] owns N [`Engine`]s — each one the software
//! twin of an accelerator instance with its own worker pool — all
//! serving from the **same** `Arc<NetRegistry>` (the multi-workload
//! generalization of PR 5's shared image: one prepared image per
//! registered net, shared by every engine), and routes
//! `submit(session_id, frame)` by a pluggable [`ShardPolicy`].
//!
//! The pieces, and their contracts:
//!
//! * **Routing** is sticky: a session's first accepted work commits it
//!   to an engine; every later frame follows, until [`Fleet::migrate`]
//!   moves it. Policies only pick the *first* engine (hash of the id,
//!   least-loaded at open, or an explicit [`Fleet::pin_session`]).
//! * **Live migration** rides the hibernation snapshot path: drain the
//!   source engine's in-flight frames, [`Engine::export_session`] (a
//!   pure read — no serving counter moves), [`Engine::import_session`]
//!   on the target, reroute. Because per-session state is total in the
//!   snapshot and per-session frame order is preserved end-to-end, a
//!   migrated session serves **byte-identically** to one that never
//!   moved — labels, FC wakeups, both energy ledgers' f64 bits, latency
//!   quantiles — including mid-fault-plan (the injector's RNG position
//!   rides in the snapshot). Asserted in `tests/fleet.rs`.
//! * **Back-pressure** is typed, not implicit: each engine has a
//!   bounded submit queue; a full queue rejects with
//!   [`FleetError::Backpressure`] wrapped in [`Rejected`], which hands
//!   the frame back untouched. A rejected submit leaves **no partial
//!   state** — no session opened, no route committed, no injector RNG
//!   advanced — so reject-then-retry serves byte-identically to a run
//!   that was never rejected.
//! * **Drain ordering** ([`DrainOrder`]) may reorder *across* sessions
//!   (tightest deadline first, or least-energy-spent first); per-session
//!   frame order is the only hard constraint and is preserved by
//!   construction (every ordering key is constant per session within a
//!   flush, with submission sequence as the tiebreak).
//! * **Reports** merge through the same [`ReportAccumulator`] in global
//!   session-id order as a single engine's `aggregate_report`, so an
//!   N-engine fleet's aggregate is bit-identical to the same sessions
//!   served on one engine.

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::engine::{Engine, EngineConfig};
use super::metrics::{ReportAccumulator, ServingReport};
use super::registry::{BindingError, NetRegistry};
use super::session::Session;
use crate::cutie::PreparedNet;
use crate::fault::FaultPlan;
use crate::network::Network;
use crate::tensor::PackedMap;

/// Default per-engine submit-queue bound.
pub const DEFAULT_QUEUE_CAP: usize = 64;

/// How a session's *first* engine is chosen (routing is sticky after).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Multiplicative hash of the session id — stateless, reproducible
    /// across fleets, no coordination.
    Hash,
    /// The engine with the fewest routed sessions at first contact
    /// (ties to the lowest index) — balances slowly-arriving sessions.
    LeastLoaded,
    /// Explicit placement only: a session must be
    /// [`Fleet::pin_session`]ed before any work is accepted for it.
    Pin,
}

impl FromStr for ShardPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Ok(ShardPolicy::Hash),
            "least-loaded" | "leastloaded" => Ok(ShardPolicy::LeastLoaded),
            "pin" => Ok(ShardPolicy::Pin),
            other => anyhow::bail!(
                "unknown shard policy {other:?} (expected hash|least-loaded|pin)"
            ),
        }
    }
}

impl fmt::Display for ShardPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ShardPolicy::Hash => "hash",
            ShardPolicy::LeastLoaded => "least-loaded",
            ShardPolicy::Pin => "pin",
        })
    }
}

/// Cross-session serve order within one engine's queue flush.
/// Per-session frame order is preserved under every variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainOrder {
    /// Global submission order (the single-engine behavior).
    Fifo,
    /// Tightest deadline first: a frame's deadline is its submission
    /// sequence plus the session's slack ([`Fleet::set_deadline_slack`];
    /// unset sessions are unconstrained and go last).
    Deadline,
    /// Least simulated energy spent first — starvation-resistant
    /// energy-fairness: sessions that have consumed the least SoC
    /// energy so far serve first.
    Energy,
}

impl FromStr for DrainOrder {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Ok(DrainOrder::Fifo),
            "deadline" => Ok(DrainOrder::Deadline),
            "energy" => Ok(DrainOrder::Energy),
            other => anyhow::bail!(
                "unknown drain order {other:?} (expected fifo|deadline|energy)"
            ),
        }
    }
}

impl fmt::Display for DrainOrder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DrainOrder::Fifo => "fifo",
            DrainOrder::Deadline => "deadline",
            DrainOrder::Energy => "energy",
        })
    }
}

#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of engines (simulated accelerator instances). Must be ≥ 1.
    pub engines: usize,
    pub policy: ShardPolicy,
    pub order: DrainOrder,
    /// Per-engine submit-queue bound; a full queue rejects with
    /// [`FleetError::Backpressure`]. Must be ≥ 1.
    pub queue_cap: usize,
    /// Per-engine configuration (every engine is identical — the fleet
    /// shards homogeneous replicas, like CUTIE's replicated OCUs).
    pub engine: EngineConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            engines: 1,
            policy: ShardPolicy::Hash,
            order: DrainOrder::Fifo,
            queue_cap: DEFAULT_QUEUE_CAP,
            engine: EngineConfig::default(),
        }
    }
}

/// Typed routing/back-pressure refusals. None of these leaves partial
/// state behind: a refused operation is a no-op on every ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetError {
    /// The target engine's submit queue is full. Drain (or wait) and
    /// retry with the returned frame.
    Backpressure { engine: usize, depth: usize, cap: usize },
    UnknownEngine { engine: usize, engines: usize },
    /// The pin policy routes nothing implicitly; pin the session first.
    Unpinned { session: usize },
    /// Repinning a routed session is refused — use [`Fleet::migrate`],
    /// which moves the state along with the route.
    AlreadyRouted { session: usize, engine: usize },
    /// A net-binding refusal from the routed engine (unknown net,
    /// fixed-binding conflict, frame-shape mismatch, foreign snapshot).
    Binding(BindingError),
}

impl fmt::Display for FleetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FleetError::Backpressure { engine, depth, cap } => write!(
                f,
                "engine {engine} queue is full ({depth}/{cap} frames): \
                 back-pressure, drain and retry"
            ),
            FleetError::UnknownEngine { engine, engines } => {
                write!(f, "engine {engine} out of range (fleet has {engines} engines)")
            }
            FleetError::Unpinned { session } => write!(
                f,
                "session {session} is not pinned (the pin policy routes nothing implicitly)"
            ),
            FleetError::AlreadyRouted { session, engine } => write!(
                f,
                "session {session} is already routed to engine {engine} (migrate instead)"
            ),
            FleetError::Binding(e) => e.fmt(f),
        }
    }
}

impl From<BindingError> for FleetError {
    fn from(e: BindingError) -> Self {
        FleetError::Binding(e)
    }
}

impl std::error::Error for FleetError {}

/// A refused submit: the typed reason plus the frame, handed back
/// untouched so the caller can retry after draining.
pub struct Rejected {
    pub reason: FleetError,
    pub frame: PackedMap,
}

impl fmt::Debug for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Rejected").field("reason", &self.reason).finish_non_exhaustive()
    }
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (the frame is returned to the caller)", self.reason)
    }
}

impl std::error::Error for Rejected {}

/// One frame waiting in an engine's bounded submit queue.
struct QueuedFrame {
    session: usize,
    frame: PackedMap,
    /// Global submission sequence — the FIFO key and every ordering's
    /// tiebreak (which is what preserves per-session frame order).
    seq: u64,
    /// `seq` + the session's deadline slack (saturating).
    deadline: u64,
}

/// Per-engine lifetime counters (fleet-side observability; none of
/// these feed the serving ledgers).
#[derive(Debug, Default, Clone, Copy)]
struct Counters {
    submitted: u64,
    served: u64,
    rejected: u64,
    migrations_in: u64,
    migrations_out: u64,
    peak_queue: usize,
}

/// One engine's load snapshot inside a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct EngineLoad {
    pub engine: usize,
    /// Sessions currently resident in the engine's session map.
    pub resident_sessions: usize,
    /// Sessions currently in the engine's snapshot store.
    pub hibernated_sessions: usize,
    /// Sessions the fleet routes to this engine.
    pub routed_sessions: usize,
    pub queue_depth: usize,
    pub peak_queue_depth: usize,
    pub submitted: u64,
    pub served: u64,
    pub rejected: u64,
    pub migrations_in: u64,
    pub migrations_out: u64,
}

/// The fleet-wide roll-up: the cross-engine aggregate serving report
/// (bit-identical to a single-engine run of the same sessions) plus
/// per-engine load and migration counters.
#[derive(Debug)]
pub struct FleetReport {
    pub aggregate: ServingReport,
    pub engines: Vec<EngineLoad>,
    pub migrations: u64,
    pub rejected_submits: u64,
}

pub struct Fleet {
    cfg: FleetConfig,
    engines: Vec<Engine>,
    /// Bounded per-engine submit queues, flushed (in [`DrainOrder`]) at
    /// each drain.
    queues: Vec<Vec<QueuedFrame>>,
    /// Sticky session → engine routing table.
    routes: BTreeMap<usize, usize>,
    /// Per-session deadline slack, in submission-sequence units.
    slack: BTreeMap<usize, u64>,
    counters: Vec<Counters>,
    seq: u64,
    migrations: u64,
    rejected: u64,
}

impl Fleet {
    /// Boot a single-workload fleet, building the net's registry (one
    /// prepared image) once and handing every engine the same `Arc`.
    pub fn new(net: &Network, cfg: FleetConfig) -> Result<Self> {
        Self::with_registry(Arc::new(NetRegistry::single(net.clone())?), cfg)
    }

    /// Boot a single-workload fleet from a pre-built weight image (e.g.
    /// word-copy-loaded from a packed `.ttn` v2 file). All N engines
    /// adopt this one `Arc`; no per-engine repack or clone of a single
    /// weight word.
    pub fn with_image(net: &Network, cfg: FleetConfig, image: Arc<PreparedNet>) -> Result<Self> {
        Self::with_registry(Arc::new(NetRegistry::single_with_image(net.clone(), image)?), cfg)
    }

    /// Boot a multi-workload fleet over a shared net registry: every
    /// engine serves the same fingerprint → (net, image) map, which is
    /// also what makes [`Fleet::migrate`] net-safe — a session's bound
    /// net exists wherever it lands.
    pub fn with_registry(registry: Arc<NetRegistry>, cfg: FleetConfig) -> Result<Self> {
        ensure!(cfg.engines >= 1, "a fleet needs at least one engine");
        ensure!(cfg.queue_cap >= 1, "the submit-queue bound must be at least 1");
        let mut engines = Vec::with_capacity(cfg.engines);
        for _ in 0..cfg.engines {
            engines.push(Engine::with_registry(Arc::clone(&registry), cfg.engine.clone())?);
        }
        let queues = (0..cfg.engines).map(|_| Vec::new()).collect();
        let counters = vec![Counters::default(); cfg.engines];
        Ok(Fleet {
            cfg,
            engines,
            queues,
            routes: BTreeMap::new(),
            slack: BTreeMap::new(),
            counters,
            seq: 0,
            migrations: 0,
            rejected: 0,
        })
    }

    pub fn engine_count(&self) -> usize {
        self.engines.len()
    }

    pub fn engine(&self, e: usize) -> Option<&Engine> {
        self.engines.get(e)
    }

    /// Direct engine access (per-engine hibernation setup, tests).
    pub fn engine_mut(&mut self, e: usize) -> Option<&mut Engine> {
        self.engines.get_mut(e)
    }

    /// The engine a session is (stickily) routed to, if any yet.
    pub fn route(&self, session: usize) -> Option<usize> {
        self.routes.get(&session).copied()
    }

    /// Where a not-yet-routed session would land (or did land). The
    /// only fallible case is the pin policy with no pin.
    fn choose_engine(&self, session: usize) -> Result<usize, FleetError> {
        if let Some(&e) = self.routes.get(&session) {
            return Ok(e);
        }
        match self.cfg.policy {
            ShardPolicy::Hash => {
                let h = (session as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33;
                Ok((h as usize) % self.engines.len())
            }
            ShardPolicy::LeastLoaded => {
                let mut load = vec![0usize; self.engines.len()];
                for &e in self.routes.values() {
                    load[e] += 1;
                }
                let mut best = 0;
                for (e, &l) in load.iter().enumerate() {
                    if l < load[best] {
                        best = e;
                    }
                }
                Ok(best)
            }
            ShardPolicy::Pin => Err(FleetError::Unpinned { session }),
        }
    }

    /// Pin a session to an engine (required under [`ShardPolicy::Pin`],
    /// allowed as a pre-placement under any policy). Refused once the
    /// session is routed elsewhere — migrate instead, pins do not move
    /// state.
    pub fn pin_session(&mut self, session: usize, engine: usize) -> Result<(), FleetError> {
        if engine >= self.engines.len() {
            return Err(FleetError::UnknownEngine { engine, engines: self.engines.len() });
        }
        if let Some(&cur) = self.routes.get(&session) {
            if cur != engine {
                return Err(FleetError::AlreadyRouted { session, engine: cur });
            }
            return Ok(());
        }
        self.routes.insert(session, engine);
        Ok(())
    }

    /// Set a session's deadline slack (submission-sequence units) for
    /// [`DrainOrder::Deadline`]: a queued frame's deadline is its
    /// sequence number plus this slack. Unset sessions are
    /// unconstrained (they sort last).
    pub fn set_deadline_slack(&mut self, session: usize, slack: u64) {
        self.slack.insert(session, slack);
    }

    /// Open (or fetch) a session on its routed engine, committing the
    /// route on first contact. The session binds the registry's default
    /// net; use [`Fleet::open_session_on`] for a non-default binding.
    pub fn open_session(&mut self, session: usize) -> Result<&mut Session, FleetError> {
        let e = self.choose_engine(session)?;
        self.routes.insert(session, e);
        Ok(self.engines[e].open_session(session)?)
    }

    /// Open (or fetch) a session bound to the registered net
    /// `fingerprint`, on its routed engine (route committed on first
    /// contact). Typed refusals ride in [`FleetError::Binding`].
    pub fn open_session_on(
        &mut self,
        session: usize,
        fingerprint: u64,
    ) -> Result<&mut Session, FleetError> {
        let e = self.choose_engine(session)?;
        self.routes.insert(session, e);
        Ok(self.engines[e].open_session_on(session, fingerprint)?)
    }

    /// Arm a fault plan on the session's routed engine (committing the
    /// route on first contact).
    pub fn set_fault_plan(&mut self, session: usize, plan: FaultPlan) -> Result<(), FleetError> {
        let e = self.choose_engine(session)?;
        self.routes.insert(session, e);
        self.engines[e].set_fault_plan(session, plan)?;
        Ok(())
    }

    /// Enqueue one frame for the session's engine. On refusal the frame
    /// comes back inside [`Rejected`], and **nothing** happened: no
    /// session opened, no route committed, no injector RNG advanced —
    /// the engine was not touched at all. Work reaches the engine at
    /// the next [`Fleet::drain`].
    pub fn submit(&mut self, session: usize, frame: PackedMap) -> Result<(), Rejected> {
        let e = match self.choose_engine(session) {
            Ok(e) => e,
            Err(reason) => return Err(Rejected { reason, frame }),
        };
        let depth = self.queues[e].len();
        if depth >= self.cfg.queue_cap {
            self.counters[e].rejected += 1;
            self.rejected += 1;
            let reason = FleetError::Backpressure { engine: e, depth, cap: self.cfg.queue_cap };
            return Err(Rejected { reason, frame });
        }
        self.routes.insert(session, e);
        let seq = self.seq;
        self.seq += 1;
        let slack = self.slack.get(&session).copied().unwrap_or(u64::MAX);
        let deadline = seq.saturating_add(slack);
        self.queues[e].push(QueuedFrame { session, frame, seq, deadline });
        self.counters[e].submitted += 1;
        self.counters[e].peak_queue = self.counters[e].peak_queue.max(self.queues[e].len());
        Ok(())
    }

    /// The order (session per queued frame) in which one engine's queue
    /// would flush right now — [`DrainOrder`] made observable for tests
    /// and debugging.
    pub fn drain_plan(&self, engine: usize) -> Vec<usize> {
        if engine >= self.queues.len() {
            return Vec::new();
        }
        self.ordered_indices(engine)
            .into_iter()
            .map(|i| self.queues[engine][i].session)
            .collect()
    }

    /// Queue indices in serve order. Every ordering key is constant per
    /// session within one flush (deadline slack is per-session; the
    /// energy key is snapshotted before any of this flush's frames
    /// serve), and `seq` breaks ties — together that preserves
    /// per-session frame order, the one hard constraint.
    fn ordered_indices(&self, e: usize) -> Vec<usize> {
        let q = &self.queues[e];
        let mut idx: Vec<usize> = (0..q.len()).collect();
        match self.cfg.order {
            DrainOrder::Fifo => {}
            DrainOrder::Deadline => idx.sort_by_key(|&i| (q[i].deadline, q[i].seq)),
            DrainOrder::Energy => {
                // Non-negative f64 → to_bits is order-preserving; a
                // session with no resident state yet has spent nothing.
                let key = |s: usize| {
                    self.engines[e]
                        .session(s)
                        .map(|sess| sess.soc.energy_j().to_bits())
                        .unwrap_or(0)
                };
                idx.sort_by_key(|&i| (key(q[i].session), q[i].seq));
            }
        }
        idx
    }

    /// Hand one engine's queued frames to it, in [`DrainOrder`]. A
    /// binding refusal (e.g. a queued frame whose dims don't match its
    /// session's net) surfaces as a typed error; already-handed frames
    /// stay with the engine.
    fn flush_queue(&mut self, e: usize) -> Result<()> {
        if self.queues[e].is_empty() {
            return Ok(());
        }
        let idx = self.ordered_indices(e);
        let mut slots: Vec<Option<QueuedFrame>> =
            std::mem::take(&mut self.queues[e]).into_iter().map(Some).collect();
        for i in idx {
            if let Some(qf) = slots[i].take() {
                self.engines[e]
                    .submit(qf.session, qf.frame)
                    .with_context(|| format!("flushing engine {e} queue"))?;
            }
        }
        Ok(())
    }

    /// Flush every queue and drain every engine; returns total frames
    /// served across the fleet.
    pub fn drain(&mut self) -> Result<usize> {
        let mut served = 0;
        for e in 0..self.engines.len() {
            self.flush_queue(e)?;
            let n = self.engines[e].drain()?;
            self.counters[e].served += n as u64;
            served += n;
        }
        Ok(served)
    }

    /// Live-migrate a session to another engine: drain the source's
    /// in-flight frames, move the session's complete state over the
    /// snapshot path (hibernated sessions migrate straight out of the
    /// store), reroute. A migration is invisible in the session's
    /// serving ledgers — the migrated schedule is byte-identical to an
    /// unmigrated one. Migrating a session onto its own engine is a
    /// no-op.
    pub fn migrate(&mut self, session: usize, to: usize) -> Result<()> {
        ensure!(
            to < self.engines.len(),
            "engine {to} out of range (fleet has {} engines)",
            self.engines.len()
        );
        let from = *self
            .routes
            .get(&session)
            .with_context(|| format!("session {session} is not routed to any engine"))?;
        if from == to {
            return Ok(());
        }
        // The snapshot must capture a settled session: serve whatever
        // is in flight on the source first.
        if !self.queues[from].is_empty() || self.engines[from].pending_frames() > 0 {
            self.flush_queue(from)?;
            let n = self.engines[from].drain()?;
            self.counters[from].served += n as u64;
        }
        // A route the source never materialized (e.g. a pin with no
        // work yet) moves as a pure reroute; otherwise the state rides
        // the snapshot.
        let holds = self.engines[from].session(session).is_some()
            || self.engines[from].store().is_some_and(|s| s.contains(session as u64));
        if holds {
            let snap = self.engines[from].export_session(session)?;
            self.engines[to].import_session(snap)?;
        }
        self.routes.insert(session, to);
        self.counters[from].migrations_out += 1;
        self.counters[to].migrations_in += 1;
        self.migrations += 1;
        Ok(())
    }

    /// Every session the fleet knows: routed, resident, hibernated, or
    /// with engine-side accruals — ascending, deduplicated.
    pub fn session_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.routes.keys().copied().collect();
        for e in &self.engines {
            ids.extend(e.all_session_ids());
        }
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Close one session into its final report, wherever it lives (a
    /// session is held by at most one engine — `import_session` refuses
    /// duplicates).
    pub fn finish_session(&mut self, session: usize) -> Option<ServingReport> {
        self.engines.iter_mut().find_map(|e| e.finish_session(session))
    }

    /// Close every session, in global session-id order.
    pub fn finish_all(&mut self) -> Vec<(usize, ServingReport)> {
        self.session_ids()
            .into_iter()
            .filter_map(|id| self.finish_session(id).map(|r| (id, r)))
            .collect()
    }

    /// The cross-engine aggregate: sessions fold in global id order
    /// through the same [`ReportAccumulator`] a single engine uses, so
    /// the result is bit-identical to serving the same sessions on one
    /// engine — whatever the sharding or migration history.
    pub fn aggregate_report(&self) -> ServingReport {
        let mut acc = ReportAccumulator::default();
        for id in self.session_ids() {
            for e in &self.engines {
                if e.accumulate_session(id, &mut acc) {
                    break;
                }
            }
        }
        acc.finish()
    }

    /// The full fleet roll-up: aggregate serving report + per-engine
    /// load/queue/migration counters.
    pub fn report(&self) -> FleetReport {
        let engines = (0..self.engines.len())
            .map(|e| {
                let c = &self.counters[e];
                EngineLoad {
                    engine: e,
                    resident_sessions: self.engines[e].session_ids().len(),
                    hibernated_sessions: self.engines[e].store().map(|s| s.len()).unwrap_or(0),
                    routed_sessions: self.routes.values().filter(|&&r| r == e).count(),
                    queue_depth: self.queues[e].len(),
                    peak_queue_depth: c.peak_queue,
                    submitted: c.submitted,
                    served: c.served,
                    rejected: c.rejected,
                    migrations_in: c.migrations_in,
                    migrations_out: c.migrations_out,
                }
            })
            .collect();
        FleetReport {
            aggregate: self.aggregate_report(),
            engines,
            migrations: self.migrations,
            rejected_submits: self.rejected,
        }
    }

    /// Persist every engine's snapshot store (file-backed ones).
    pub fn sync_stores(&mut self) -> Result<()> {
        for e in &mut self.engines {
            e.sync_store()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_and_order_parse_and_print() {
        assert_eq!("hash".parse::<ShardPolicy>().unwrap(), ShardPolicy::Hash);
        assert_eq!("least-loaded".parse::<ShardPolicy>().unwrap(), ShardPolicy::LeastLoaded);
        assert_eq!("leastloaded".parse::<ShardPolicy>().unwrap(), ShardPolicy::LeastLoaded);
        assert_eq!("PIN".parse::<ShardPolicy>().unwrap(), ShardPolicy::Pin);
        assert!("round-robin".parse::<ShardPolicy>().is_err());
        assert_eq!(ShardPolicy::LeastLoaded.to_string(), "least-loaded");
        assert_eq!("fifo".parse::<DrainOrder>().unwrap(), DrainOrder::Fifo);
        assert_eq!("deadline".parse::<DrainOrder>().unwrap(), DrainOrder::Deadline);
        assert_eq!("energy".parse::<DrainOrder>().unwrap(), DrainOrder::Energy);
        assert!("lifo".parse::<DrainOrder>().is_err());
        assert_eq!(DrainOrder::Energy.to_string(), "energy");
    }

    #[test]
    fn fleet_errors_name_the_contract() {
        let e = FleetError::Backpressure { engine: 2, depth: 64, cap: 64 };
        let msg = e.to_string();
        assert!(msg.contains("engine 2") && msg.contains("64"), "got: {msg}");
        assert!(FleetError::Unpinned { session: 7 }.to_string().contains('7'));
        let msg = FleetError::UnknownEngine { engine: 9, engines: 3 }.to_string();
        assert!(msg.contains('9') && msg.contains('3'), "got: {msg}");
        assert!(FleetError::AlreadyRouted { session: 1, engine: 0 }
            .to_string()
            .contains("migrate"));
        let msg = FleetError::Binding(BindingError::UnknownNet { fingerprint: 5 }).to_string();
        assert!(msg.contains("registry"), "got: {msg}");
    }
}
