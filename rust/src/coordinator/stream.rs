//! `PackedStream` — the flat (pos, mask) word-stream frame format: the
//! software twin of the camera µDMA payload (ROADMAP "packed µDMA
//! payloads" item). A recorded stream is replayable byte-for-byte, so a
//! serving run can be captured once and re-served deterministically, and
//! a producer can write the payload words straight into the activation
//! buffer — no struct marshalling on the ingress path.
//!
//! ## Format (little-endian u64 words)
//!
//! ```text
//! stream := MAGIC u64 | h u64 | w u64 | c u64 | frame*
//! frame  := payload_bytes u64 | word{⌈payload_bytes/8⌉}
//! ```
//!
//! Within a frame payload, trit `i` (flattened `y·(w·c) + x·c + ch`
//! order — the activation SRAM's HWC order) occupies payload bits
//! `[2i, 2i+2)`: bit `2i` is the *mask* plane (non-zero), bit `2i+1` the
//! *pos* plane (+1). Pairs are 2-bit aligned so a trit never straddles a
//! word. `payload_bytes` is therefore exactly
//! [`dma_ingress_bytes`]`(h·w·c)` — the frame record's length prefix IS
//! the µDMA ingress byte count the SoC timeline charges, asserted by the
//! round-trip tests.

use anyhow::{bail, ensure, Result};

use super::source::FrameSource;
use crate::cutie::dma_ingress_bytes;
use crate::tensor::PackedMap;

/// `b"TCNPKS1\0"` as a little-endian u64.
pub const MAGIC: u64 = u64::from_le_bytes(*b"TCNPKS1\0");

/// Decode-side sanity cap on trits per frame (64 Mtrit ≈ 16 MiB payload
/// — far above any real feature map, small enough that a corrupt or
/// crafted header cannot overflow the size math or drive a huge
/// allocation before the length checks run).
const MAX_FRAME_TRITS: u64 = 1 << 26;

/// A replayable sequence of packed frames with one shared geometry.
///
/// Implements [`FrameSource`]: frames are served in order, then the
/// stream reports exhaustion (`None`). [`PackedStream::rewind`] restarts
/// it; a `clone` preserves the cursor.
#[derive(Debug, Clone)]
pub struct PackedStream {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    frames: Vec<PackedMap>,
    cursor: usize,
}

impl PackedStream {
    /// Wrap frames that already exist in memory. All frames must share
    /// one geometry (a stream is one camera's payload).
    pub fn from_frames(frames: Vec<PackedMap>) -> Result<Self> {
        ensure!(!frames.is_empty(), "a packed stream needs at least one frame");
        let (h, w, c) = (frames[0].h, frames[0].w, frames[0].c);
        for (i, f) in frames.iter().enumerate() {
            ensure!(
                (f.h, f.w, f.c) == (h, w, c),
                "frame {i} geometry {}x{}x{} != stream {h}x{w}x{c}",
                f.h,
                f.w,
                f.c
            );
        }
        Ok(PackedStream { h, w, c, frames, cursor: 0 })
    }

    /// Record up to `n` frames from a live source (stops early if the
    /// source dries up; errors if it produces nothing).
    pub fn capture(src: &mut dyn FrameSource, n: usize) -> Result<Self> {
        let mut frames = Vec::with_capacity(n);
        while frames.len() < n {
            match src.next_frame() {
                Some(f) => frames.push(f),
                None => break,
            }
        }
        Self::from_frames(frames)
    }

    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Restart replay from the first frame.
    pub fn rewind(&mut self) {
        self.cursor = 0;
    }

    /// Tight per-frame payload size: exactly the µDMA ingress bytes the
    /// SoC model charges for one frame of this geometry.
    pub fn frame_payload_bytes(&self) -> u64 {
        dma_ingress_bytes(self.h * self.w * self.c)
    }

    /// Serialize to the flat word-stream form (see module docs).
    pub fn encode(&self) -> Vec<u8> {
        let payload_bytes = self.frame_payload_bytes();
        let words_per_frame = (payload_bytes as usize).div_ceil(8);
        let mut out = Vec::with_capacity(32 + self.frames.len() * (8 + 8 * words_per_frame));
        for v in [MAGIC, self.h as u64, self.w as u64, self.c as u64] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for frame in &self.frames {
            out.extend_from_slice(&payload_bytes.to_le_bytes());
            let mut words = vec![0u64; words_per_frame];
            let mut bit = 0usize;
            for px in &frame.pixels {
                for ch in 0..self.c {
                    // 2-bit aligned, so both plane bits land in one word
                    match px.get(ch) {
                        0 => {}
                        1 => words[bit / 64] |= 0b11 << (bit % 64),
                        _ => words[bit / 64] |= 0b01 << (bit % 64),
                    }
                    bit += 2;
                }
            }
            for w in words {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        out
    }

    /// Parse a flat word-stream back into frames.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut rd = Reader { bytes, at: 0 };
        ensure!(rd.u64()? == MAGIC, "not a packed frame stream (bad magic)");
        let (h64, w64, c64) = (rd.u64()?, rd.u64()?, rd.u64()?);
        let numel64 = h64
            .checked_mul(w64)
            .and_then(|hw| hw.checked_mul(c64))
            .filter(|&n| n > 0 && n <= MAX_FRAME_TRITS);
        ensure!(
            c64 >= 1 && c64 <= 128 && numel64.is_some(),
            "bad stream geometry {h64}x{w64}x{c64}"
        );
        let (h, w, c) = (h64 as usize, w64 as usize, c64 as usize);
        let payload_bytes = dma_ingress_bytes(h * w * c);
        let words_per_frame = (payload_bytes as usize).div_ceil(8);
        let mut frames = Vec::new();
        while !rd.done() {
            let prefix = rd.u64()?;
            ensure!(
                prefix == payload_bytes,
                "frame {} length prefix {prefix} != {payload_bytes} for {h}x{w}x{c}",
                frames.len()
            );
            let mut words = Vec::with_capacity(words_per_frame);
            for _ in 0..words_per_frame {
                words.push(rd.u64()?);
            }
            let mut m = PackedMap::zeros(h, w, c);
            let mut bit = 0usize;
            for y in 0..h {
                for x in 0..w {
                    for ch in 0..c {
                        let pair = (words[bit / 64] >> (bit % 64)) & 0b11;
                        match pair {
                            0b00 => {}
                            0b11 => m.set_trit(y, x, ch, 1),
                            0b01 => m.set_trit(y, x, ch, -1),
                            _ => bail!("invalid trit encoding (pos without mask) at bit {bit}"),
                        }
                        bit += 2;
                    }
                }
            }
            frames.push(m);
        }
        Self::from_frames(frames)
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.encode())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Self::decode(&bytes)
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn u64(&mut self) -> Result<u64> {
        ensure!(self.at + 8 <= self.bytes.len(), "truncated stream at byte {}", self.at);
        let mut word = [0u8; 8];
        word.copy_from_slice(&self.bytes[self.at..self.at + 8]);
        self.at += 8;
        Ok(u64::from_le_bytes(word))
    }

    fn done(&self) -> bool {
        self.at == self.bytes.len()
    }
}

impl FrameSource for PackedStream {
    fn next_frame(&mut self) -> Option<PackedMap> {
        let f = self.frames.get(self.cursor)?.clone();
        self.cursor += 1;
        Some(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::source::{DvsSource, GestureClass};
    use crate::tensor::TritTensor;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_across_geometries() {
        let mut rng = Rng::new(60);
        for &(h, w, c, n) in &[(1usize, 1usize, 1usize, 1usize), (4, 6, 17, 3), (8, 8, 96, 2), (2, 3, 128, 4)] {
            let frames: Vec<PackedMap> = (0..n)
                .map(|_| PackedMap::from_trit(&TritTensor::random(&[h, w, c], &mut rng, 0.5)))
                .collect();
            let s = PackedStream::from_frames(frames.clone()).unwrap();
            let bytes = s.encode();
            // container overhead: 4 header words + 1 prefix word per frame
            let words_per_frame = (s.frame_payload_bytes() as usize).div_ceil(8);
            assert_eq!(bytes.len(), 32 + n * (8 + 8 * words_per_frame));
            let d = PackedStream::decode(&bytes).unwrap();
            assert_eq!((d.h, d.w, d.c, d.len()), (h, w, c, n));
            let mut d = d;
            for f in &frames {
                assert_eq!(FrameSource::next_frame(&mut d).as_ref(), Some(f));
            }
            assert!(FrameSource::next_frame(&mut d).is_none());
        }
    }

    #[test]
    fn length_prefix_is_dma_ingress_bytes() {
        // The frame record's length prefix must be the exact µDMA ingress
        // byte count — the payload IS what the camera DMA would ship.
        let mut src = DvsSource::new(16, 9, GestureClass(5));
        let s = PackedStream::capture(&mut src, 3).unwrap();
        assert_eq!(s.frame_payload_bytes(), dma_ingress_bytes(16 * 16 * 2));
        let bytes = s.encode();
        let prefix = u64::from_le_bytes(bytes[32..40].try_into().unwrap());
        assert_eq!(prefix, dma_ingress_bytes(16 * 16 * 2));
    }

    #[test]
    fn rewind_replays_identically() {
        let mut src = DvsSource::new(16, 10, GestureClass(2));
        let mut s = PackedStream::capture(&mut src, 4).unwrap();
        let first: Vec<_> = std::iter::from_fn(|| FrameSource::next_frame(&mut s)).collect();
        assert_eq!(first.len(), 4);
        s.rewind();
        let again: Vec<_> = std::iter::from_fn(|| FrameSource::next_frame(&mut s)).collect();
        assert_eq!(first, again);
    }

    #[test]
    fn corrupt_streams_rejected() {
        let mut src = DvsSource::new(8, 11, GestureClass(0));
        let s = PackedStream::capture(&mut src, 2).unwrap();
        let good = s.encode();
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(PackedStream::decode(&bad).is_err());
        // truncated mid-frame
        assert!(PackedStream::decode(&good[..good.len() - 3]).is_err());
        // pos-without-mask is not a trit
        let mut bad = good.clone();
        bad[40] = 0b10; // first payload byte: pair (pos=1, mask=0)
        assert!(PackedStream::decode(&bad).is_err());
        // absurd header geometry must be a clean decode error, not an
        // overflow panic or a huge up-front allocation
        let mut crafted = Vec::new();
        for v in [MAGIC, 1u64 << 32, 1u64 << 32, 2u64] {
            crafted.extend_from_slice(&v.to_le_bytes());
        }
        let e = PackedStream::decode(&crafted).unwrap_err().to_string();
        assert!(e.contains("bad stream geometry"), "got: {e}");
        // mixed geometry refused at construction
        assert!(PackedStream::from_frames(vec![
            PackedMap::zeros(2, 2, 4),
            PackedMap::zeros(2, 2, 5),
        ])
        .is_err());
    }
}
