//! Crash-safe session hibernation — the state-retentive idle tier.
//!
//! A session's entire recurrent state is a few hundred bytes: the packed
//! (pos, mask) TCN ring words (576 B at the Kraken anchor), the SoC
//! ledger, metrics samples, label history and — when a fault plan is
//! armed — the injector's exact RNG position. TinyVers (PAPERS.md) holds
//! exactly this class of state in state-retentive eMRAM across deep
//! sleep; this module is the software twin:
//!
//! * [`SessionSnapshot`] — a versioned snapshot of one [`Session`],
//!   with a bit-exact binary codec built on the hardened TTN wire
//!   readers (`tensor::ttn`): take-before-alloc, checked arithmetic,
//!   every decoded invariant re-validated so a forged or rotted record
//!   surfaces as a typed [`SnapshotError`], never a panic or a silently
//!   wrong state.
//! * [`SessionStore`] — the record store (in-memory or file-backed)
//!   with per-record CRC-32, incremental append-only syncs with
//!   tombstoned removals, and automatic compaction (atomic
//!   write-then-rename) once dead weight outgrows the live records, so
//!   long-lived store files stay bounded. Reopening after a crash keeps
//!   every intact record and skips a half-written tail
//!   ([`SessionStore::recovered_torn`]).
//! * [`HibernationStats`] — the hibernate/resume/retention ledger
//!   surfaced in every [`super::ServingReport`].
//!
//! The engine-facing contract (asserted in `tests/hibernate.rs`): any
//! hibernate/resume schedule serves **byte-identically** to an
//! always-resident run — labels, FC wakeups, both energy ledgers' f64
//! bits, latency quantiles — including a resume mid-fault-plan, because
//! the snapshot carries the injector's geometric-gap walk position.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::cutie::TcnMemory;
use crate::fault::{FaultPlan, FaultSummary, FaultSurface, Injector};
use crate::soc::{Domain, FcState, KrakenSoc, PowerState, SocLedger};
use crate::tensor::ttn;
use crate::trit::{PackedVec, MAX_CHANNELS};
use crate::util::crc::crc32;
use crate::util::stats::Percentiles;

use super::metrics::ServingMetrics;
use super::registry::SessionGeometry;
use super::session::{FaultState, Session};

/// Snapshot record magic: "SSN1" little-endian.
pub const SNAPSHOT_MAGIC: u32 = 0x314E_5353;
/// Snapshot format version this build writes and reads. v2 added the
/// net-binding block (image fingerprint + typed input dims) right after
/// the supply voltage, so resume/migration re-binds the exact net the
/// session was serving.
pub const SNAPSHOT_VERSION: u32 = 2;
/// Store file magic ("TCNHIB1\0").
pub const STORE_MAGIC: u64 = u64::from_le_bytes(*b"TCNHIB1\0");
/// Decode guard: no modeled TCN memory is deeper than this.
const MAX_SNAPSHOT_TCN_DEPTH: u32 = 4096;
/// Decode guard: no modeled input frame is wider than this.
const MAX_SNAPSHOT_INPUT_HW: u32 = 4096;

/// Canonical domain order of the SoC section (all four power domains,
/// always present, in `Domain`'s `Ord` order).
const DOMAINS: [Domain; 4] = [Domain::Soc, Domain::Cluster, Domain::Ehwpe, Domain::Accel2];

/// Typed decode/verify failure for a snapshot record. Every corrupt,
/// truncated or forged record lands on one of these — the store never
/// panics on bad bytes and never hands back a silently wrong session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotError {
    BadMagic(u32),
    BadVersion(u32),
    /// The stored CRC does not match the record bytes (bit rot, torn
    /// write inside a record, or injected snapshot-surface faults).
    Crc { want: u32, got: u32 },
    /// The record ended before a field it promised.
    Truncated { wanted: usize, have: usize },
    /// Structurally well-formed bytes encoding an invalid state.
    Malformed(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::BadMagic(m) => {
                write!(f, "bad snapshot magic {m:#010x} (expected SSN1)")
            }
            SnapshotError::BadVersion(v) => write!(f, "unsupported snapshot version {v}"),
            SnapshotError::Crc { want, got } => {
                write!(f, "snapshot CRC mismatch (stored {want:#010x}, computed {got:#010x})")
            }
            SnapshotError::Truncated { wanted, have } => {
                write!(f, "snapshot truncated (wanted {wanted} more bytes, have {have})")
            }
            SnapshotError::Malformed(why) => write!(f, "malformed snapshot: {why}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

type SnapResult<T> = Result<T, SnapshotError>;

fn malformed(why: impl Into<String>) -> SnapshotError {
    SnapshotError::Malformed(why.into())
}

// ---------------------------------------------------------------------
// wire helpers (the TTN readers, mapped onto the typed error)
// ---------------------------------------------------------------------

fn take<'a>(b: &mut &'a [u8], n: usize) -> SnapResult<&'a [u8]> {
    let have = b.len();
    ttn::take(b, n).map_err(|_| SnapshotError::Truncated { wanted: n, have })
}

fn read_u8(b: &mut &[u8]) -> SnapResult<u8> {
    let have = b.len();
    ttn::read_u8(b).map_err(|_| SnapshotError::Truncated { wanted: 1, have })
}

fn read_u32(b: &mut &[u8]) -> SnapResult<u32> {
    let have = b.len();
    ttn::read_u32(b).map_err(|_| SnapshotError::Truncated { wanted: 4, have })
}

fn read_u64(b: &mut &[u8]) -> SnapResult<u64> {
    let have = b.len();
    ttn::read_u64(b).map_err(|_| SnapshotError::Truncated { wanted: 8, have })
}

fn read_f64_bits(b: &mut &[u8]) -> SnapResult<f64> {
    Ok(f64::from_bits(read_u64(b)?))
}

/// Take-before-alloc read of `n` f64s stored as raw bit patterns.
fn read_f64s(b: &mut &[u8], n: usize) -> SnapResult<Vec<f64>> {
    let bytes = n.checked_mul(8).ok_or_else(|| malformed("f64 run length overflows"))?;
    let raw = take(b, bytes)?;
    Ok(raw
        .chunks_exact(8)
        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
        .collect())
}

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64_bits(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

// ---------------------------------------------------------------------
// enum codecs
// ---------------------------------------------------------------------

fn domain_code(d: Domain) -> u8 {
    match d {
        Domain::Soc => 0,
        Domain::Cluster => 1,
        Domain::Ehwpe => 2,
        Domain::Accel2 => 3,
    }
}

fn domain_from(code: u8) -> SnapResult<Domain> {
    DOMAINS
        .get(code as usize)
        .copied()
        .ok_or_else(|| malformed(format!("unknown power domain code {code}")))
}

fn power_state_code(s: PowerState) -> u8 {
    match s {
        PowerState::Gated => 0,
        PowerState::Idle => 1,
        PowerState::Active => 2,
    }
}

fn power_state_from(code: u8) -> SnapResult<PowerState> {
    match code {
        0 => Ok(PowerState::Gated),
        1 => Ok(PowerState::Idle),
        2 => Ok(PowerState::Active),
        other => Err(malformed(format!("unknown power state code {other}"))),
    }
}

fn fc_state_code(s: FcState) -> u8 {
    match s {
        FcState::Sleep => 0,
        FcState::Readout => 1,
        FcState::Arm => 2,
    }
}

fn fc_state_from(code: u8) -> SnapResult<FcState> {
    match code {
        0 => Ok(FcState::Sleep),
        1 => Ok(FcState::Readout),
        2 => Ok(FcState::Arm),
        other => Err(malformed(format!("unknown FC state code {other}"))),
    }
}

fn surface_code(s: FaultSurface) -> u8 {
    match s {
        FaultSurface::ActMem => 0,
        FaultSurface::TcnMem => 1,
        FaultSurface::WeightMem => 2,
        FaultSurface::DmaStream => 3,
        FaultSurface::Snapshot => 4,
    }
}

fn surface_from(code: u8) -> SnapResult<FaultSurface> {
    match code {
        0 => Ok(FaultSurface::ActMem),
        1 => Ok(FaultSurface::TcnMem),
        2 => Ok(FaultSurface::WeightMem),
        3 => Ok(FaultSurface::DmaStream),
        4 => Ok(FaultSurface::Snapshot),
        other => Err(malformed(format!("unknown fault surface code {other}"))),
    }
}

fn valid_ber(b: f64) -> bool {
    (0.0..=0.5).contains(&b)
}

// ---------------------------------------------------------------------
// snapshot sections
// ---------------------------------------------------------------------

/// Hibernate/resume/retention ledger. Per-session inside [`Session`]
/// (and its snapshot), field-wise summed into the report aggregate.
/// Deliberately **not** part of the byte-identity oracle: retention and
/// wake energy live here, never in the SoC or core ledgers, so an
/// eviction schedule cannot perturb the calibrated anchors.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HibernationStats {
    /// Snapshots taken (idle eviction or explicit `hibernate`).
    pub hibernates: u64,
    /// Records restored bit-exactly.
    pub resumes: u64,
    /// Resume attempts that hit a corrupt/invalid record (the session
    /// was re-initialized; the CRC refusal counts in `FaultSummary`).
    pub corrupt_resumes: u64,
    /// Snapshot-word × idle-drain-tick retention exposure.
    pub retention_word_ticks: u64,
    /// Total bytes written into the snapshot store.
    pub snapshot_bytes: u64,
    /// Retention energy (J), charged per word per idle tick.
    pub retention_j: f64,
    /// Wake re-load energy (J), charged per word at resume.
    pub wake_j: f64,
}

impl HibernationStats {
    pub fn merge(&mut self, o: &HibernationStats) {
        self.hibernates += o.hibernates;
        self.resumes += o.resumes;
        self.corrupt_resumes += o.corrupt_resumes;
        self.retention_word_ticks += o.retention_word_ticks;
        self.snapshot_bytes += o.snapshot_bytes;
        self.retention_j += o.retention_j;
        self.wake_j += o.wake_j;
    }

    pub fn any(&self) -> bool {
        *self != HibernationStats::default()
    }
}

/// The TCN ring section: geometry, counters, and the resident packed
/// words oldest-first.
#[derive(Debug, Clone)]
pub struct TcnSnap {
    pub depth: u32,
    pub channels: u32,
    pub pushes: u64,
    pub reads: u64,
    pub shift_toggles: u64,
    pub words: Vec<PackedVec>,
}

/// One FLL's mutable state (the name is fixed by the SoC constructor).
#[derive(Debug, Clone, Copy)]
pub struct FllSnap {
    pub freq_hz: f64,
    pub lock_time_ns: u64,
    pub retargets: u64,
}

/// The SoC section: everything `KrakenSoc::new(voltage)` does not
/// re-derive from the supply (FSM states, FLL positions, the ledger).
/// Kept field-accessible so `aggregate_report` can fold a hibernated
/// session's energy/wakeups without materializing a `KrakenSoc`.
#[derive(Debug, Clone)]
pub struct SocSnap {
    pub fc_state: FcState,
    pub dma_bits: u32,
    /// Power state per domain, in [`DOMAINS`] order.
    pub states: [PowerState; 4],
    pub soc_fll: FllSnap,
    pub ehwpe_fll: FllSnap,
    pub now_ns: u64,
    pub energy_j: f64,
    /// Per-domain energy entries, in domain order. Presence-preserving:
    /// a `BTreeMap` entry exists only once its domain was touched, and a
    /// restored ledger must match bit-for-bit including entry presence.
    pub per_domain: Vec<(Domain, f64)>,
    pub irq_count: u64,
    pub fc_wakeups: u64,
    pub frames_ingested: u64,
}

/// An armed fault plan plus its injector's exact position.
#[derive(Debug, Clone, Copy)]
pub struct FaultSnap {
    pub surface: FaultSurface,
    pub plan_ber: f64,
    pub seed: u64,
    pub inj_ber: f64,
    pub rng: [u64; 4],
}

/// Full per-session state, capturable from and restorable into a live
/// [`Session`] bit-exactly.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pub session_id: u64,
    pub voltage: f64,
    /// Fingerprint of the prepared image the session was bound to (v2).
    /// Resume/migration refuses a fingerprint the target registry does
    /// not hold — a session can never silently land on other weights.
    pub fingerprint: u64,
    /// Bound input frame side length (v2).
    pub input_hw: u32,
    /// Bound input frame channel count (v2).
    pub input_ch: u32,
    /// Whether the bound net has a recurrent TCN tail (v2).
    pub has_tcn: bool,
    pub tcn: TcnSnap,
    pub soc: SocSnap,
    pub metrics: ServingMetrics,
    pub labels: Vec<usize>,
    pub faults: FaultSummary,
    pub hib: HibernationStats,
    pub fault: Option<FaultSnap>,
}

impl SessionSnapshot {
    /// Snapshot a live session. Pure read: no counter on the session
    /// moves (snapshotting is not a functional access of the memories).
    pub fn capture(sess: &Session) -> SessionSnapshot {
        let soc = &sess.soc;
        SessionSnapshot {
            session_id: sess.id as u64,
            voltage: soc.voltage,
            fingerprint: sess.geometry.fingerprint,
            input_hw: sess.geometry.input_hw as u32,
            input_ch: sess.geometry.input_ch as u32,
            has_tcn: sess.geometry.has_tcn,
            tcn: TcnSnap {
                depth: sess.tcn.depth as u32,
                channels: sess.tcn.channels as u32,
                pushes: sess.tcn.pushes,
                reads: sess.tcn.reads,
                shift_toggles: sess.tcn.shift_toggles,
                words: sess.tcn.words().copied().collect(),
            },
            soc: SocSnap {
                fc_state: soc.fc_state,
                dma_bits: soc.dma_bits as u32,
                states: DOMAINS.map(|d| soc.states[&d]),
                soc_fll: FllSnap {
                    freq_hz: soc.soc_fll.freq_hz,
                    lock_time_ns: soc.soc_fll.lock_time_ns,
                    retargets: soc.soc_fll.retargets,
                },
                ehwpe_fll: FllSnap {
                    freq_hz: soc.ehwpe_fll.freq_hz,
                    lock_time_ns: soc.ehwpe_fll.lock_time_ns,
                    retargets: soc.ehwpe_fll.retargets,
                },
                now_ns: soc.ledger.now_ns,
                energy_j: soc.ledger.energy_j,
                per_domain: soc.ledger.per_domain.iter().map(|(&d, &e)| (d, e)).collect(),
                irq_count: soc.ledger.irq_count,
                fc_wakeups: soc.ledger.fc_wakeups,
                frames_ingested: soc.ledger.frames_ingested,
            },
            metrics: sess.metrics.clone(),
            labels: sess.labels.clone(),
            faults: sess.faults,
            hib: sess.hib,
            fault: sess.fault.as_ref().map(|fs| {
                let (inj_ber, rng) = fs.inj.state();
                FaultSnap {
                    surface: fs.plan.surface,
                    plan_ber: fs.plan.ber,
                    seed: fs.plan.seed,
                    inj_ber,
                    rng,
                }
            }),
        }
    }

    /// Serialize to the versioned record payload (the bytes the store
    /// CRCs). Deterministic: a pure function of the snapshotted state,
    /// and its length does not depend on RNG state values — the
    /// snapshot fault surface relies on that to size its draw space
    /// before the final capture.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            256 + self.tcn.words.len() * 32
                + (self.metrics.sim_latency_us.len() + self.metrics.wall_latency_us.len()) * 8
                + self.labels.len() * 4,
        );
        put_u32(&mut out, SNAPSHOT_MAGIC);
        put_u32(&mut out, SNAPSHOT_VERSION);
        put_u64(&mut out, self.session_id);
        put_f64_bits(&mut out, self.voltage);

        // net binding (v2)
        put_u64(&mut out, self.fingerprint);
        put_u32(&mut out, self.input_hw);
        put_u32(&mut out, self.input_ch);
        put_u8(&mut out, self.has_tcn as u8);

        // TCN ring
        put_u32(&mut out, self.tcn.depth);
        put_u32(&mut out, self.tcn.channels);
        put_u32(&mut out, self.tcn.words.len() as u32);
        put_u64(&mut out, self.tcn.pushes);
        put_u64(&mut out, self.tcn.reads);
        put_u64(&mut out, self.tcn.shift_toggles);
        for w in &self.tcn.words {
            for word in w.to_words() {
                put_u64(&mut out, word);
            }
        }

        // SoC
        put_u8(&mut out, fc_state_code(self.soc.fc_state));
        put_u32(&mut out, self.soc.dma_bits);
        for s in self.soc.states {
            put_u8(&mut out, power_state_code(s));
        }
        for fll in [&self.soc.soc_fll, &self.soc.ehwpe_fll] {
            put_f64_bits(&mut out, fll.freq_hz);
            put_u64(&mut out, fll.lock_time_ns);
            put_u64(&mut out, fll.retargets);
        }
        put_u64(&mut out, self.soc.now_ns);
        put_f64_bits(&mut out, self.soc.energy_j);
        put_u64(&mut out, self.soc.irq_count);
        put_u64(&mut out, self.soc.fc_wakeups);
        put_u64(&mut out, self.soc.frames_ingested);
        put_u32(&mut out, self.soc.per_domain.len() as u32);
        for &(d, e) in &self.soc.per_domain {
            put_u8(&mut out, domain_code(d));
            put_f64_bits(&mut out, e);
        }

        // metrics
        put_u64(&mut out, self.metrics.frames);
        put_u64(&mut out, self.metrics.labels_emitted);
        put_f64_bits(&mut out, self.metrics.core_energy_j);
        put_f64_bits(&mut out, self.metrics.soc_energy_j);
        put_f64_bits(&mut out, self.metrics.sim_time_s);
        for hist in [&self.metrics.sim_latency_us, &self.metrics.wall_latency_us] {
            put_u32(&mut out, hist.len() as u32);
            for &s in hist.samples() {
                put_f64_bits(&mut out, s);
            }
        }

        // labels
        put_u32(&mut out, self.labels.len() as u32);
        for &l in &self.labels {
            put_u32(&mut out, l as u32);
        }

        // fault summary
        let f = &self.faults;
        for v in [
            f.injected_flips,
            f.detected,
            f.degraded_frames,
            f.scrub_words,
            f.repair_words,
            f.retries,
            f.failures,
            f.quarantined,
            f.dropped_frames,
            f.snapshot_corrupt,
        ] {
            put_u64(&mut out, v);
        }

        // hibernation ledger
        let h = &self.hib;
        for v in [
            h.hibernates,
            h.resumes,
            h.corrupt_resumes,
            h.retention_word_ticks,
            h.snapshot_bytes,
        ] {
            put_u64(&mut out, v);
        }
        put_f64_bits(&mut out, h.retention_j);
        put_f64_bits(&mut out, h.wake_j);

        // armed fault plan
        match &self.fault {
            None => put_u8(&mut out, 0),
            Some(fs) => {
                put_u8(&mut out, 1);
                put_u8(&mut out, surface_code(fs.surface));
                put_f64_bits(&mut out, fs.plan_ber);
                put_u64(&mut out, fs.seed);
                put_f64_bits(&mut out, fs.inj_ber);
                for w in fs.rng {
                    put_u64(&mut out, w);
                }
            }
        }
        out
    }

    /// Decode a record payload, re-validating every invariant. `id` is
    /// the store-level record id; a mismatch with the embedded session
    /// id (e.g. a flipped id field) is refused as malformed.
    pub fn decode(payload: &[u8], id: u64) -> SnapResult<SessionSnapshot> {
        let mut b = payload;
        let magic = read_u32(&mut b)?;
        if magic != SNAPSHOT_MAGIC {
            return Err(SnapshotError::BadMagic(magic));
        }
        let version = read_u32(&mut b)?;
        if version != SNAPSHOT_VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let session_id = read_u64(&mut b)?;
        if session_id != id {
            return Err(malformed(format!(
                "record {id} embeds session id {session_id}"
            )));
        }
        let voltage = read_f64_bits(&mut b)?;
        if !voltage.is_finite() || voltage <= 0.0 {
            return Err(malformed(format!("non-physical supply voltage {voltage}")));
        }

        // net binding (v2)
        let fingerprint = read_u64(&mut b)?;
        let input_hw = read_u32(&mut b)?;
        let input_ch = read_u32(&mut b)?;
        if input_hw == 0 || input_hw > MAX_SNAPSHOT_INPUT_HW {
            return Err(malformed(format!("input side length {input_hw} out of range")));
        }
        if input_ch == 0 || input_ch as usize > MAX_CHANNELS {
            return Err(malformed(format!("input channel count {input_ch} out of range")));
        }
        let has_tcn = match read_u8(&mut b)? {
            0 => false,
            1 => true,
            other => return Err(malformed(format!("bad has-tcn flag {other}"))),
        };

        // TCN ring
        let depth = read_u32(&mut b)?;
        let channels = read_u32(&mut b)?;
        let occupancy = read_u32(&mut b)?;
        if depth == 0 || depth > MAX_SNAPSHOT_TCN_DEPTH {
            return Err(malformed(format!("TCN depth {depth} out of range")));
        }
        if channels == 0 || channels as usize > MAX_CHANNELS {
            return Err(malformed(format!("TCN channel count {channels} out of range")));
        }
        if occupancy > depth {
            return Err(malformed(format!(
                "TCN occupancy {occupancy} exceeds depth {depth}"
            )));
        }
        let pushes = read_u64(&mut b)?;
        let reads = read_u64(&mut b)?;
        let shift_toggles = read_u64(&mut b)?;
        let mut words = Vec::with_capacity(occupancy as usize);
        for i in 0..occupancy {
            let mut w = [0u64; 4];
            for slot in &mut w {
                *slot = read_u64(&mut b)?;
            }
            let v = PackedVec::from_words(w)
                .ok_or_else(|| malformed(format!("TCN step {i} violates pos ⊆ mask")))?;
            if v.masked(channels as usize) != v {
                return Err(malformed(format!(
                    "TCN step {i} has plane bits beyond {channels} channels"
                )));
            }
            words.push(v);
        }
        let tcn = TcnSnap { depth, channels, pushes, reads, shift_toggles, words };

        // SoC
        let fc_state = fc_state_from(read_u8(&mut b)?)?;
        let dma_bits = read_u32(&mut b)?;
        if dma_bits == 0 || dma_bits % 8 != 0 || dma_bits > 1024 {
            return Err(malformed(format!("implausible µDMA bus width {dma_bits}")));
        }
        let mut states = [PowerState::Gated; 4];
        for s in &mut states {
            *s = power_state_from(read_u8(&mut b)?)?;
        }
        if states[0] == PowerState::Gated {
            return Err(malformed("the SoC domain is always-on, cannot be gated"));
        }
        let mut flls = [FllSnap { freq_hz: 0.0, lock_time_ns: 0, retargets: 0 }; 2];
        for fll in &mut flls {
            fll.freq_hz = read_f64_bits(&mut b)?;
            fll.lock_time_ns = read_u64(&mut b)?;
            fll.retargets = read_u64(&mut b)?;
            if !fll.freq_hz.is_finite() || fll.freq_hz < 0.0 {
                return Err(malformed(format!("non-physical FLL frequency {}", fll.freq_hz)));
            }
        }
        let now_ns = read_u64(&mut b)?;
        let energy_j = read_f64_bits(&mut b)?;
        let irq_count = read_u64(&mut b)?;
        let fc_wakeups = read_u64(&mut b)?;
        let frames_ingested = read_u64(&mut b)?;
        let n_domains = read_u32(&mut b)?;
        if n_domains > 4 {
            return Err(malformed(format!("{n_domains} per-domain energy entries")));
        }
        let mut per_domain = Vec::with_capacity(n_domains as usize);
        for _ in 0..n_domains {
            let d = domain_from(read_u8(&mut b)?)?;
            let e = read_f64_bits(&mut b)?;
            if let Some(&(last, _)) = per_domain.last() {
                if domain_code(d) <= domain_code(last) {
                    return Err(malformed("per-domain entries out of order"));
                }
            }
            per_domain.push((d, e));
        }
        let soc = SocSnap {
            fc_state,
            dma_bits,
            states,
            soc_fll: flls[0],
            ehwpe_fll: flls[1],
            now_ns,
            energy_j,
            per_domain,
            irq_count,
            fc_wakeups,
            frames_ingested,
        };

        // metrics
        let frames = read_u64(&mut b)?;
        let labels_emitted = read_u64(&mut b)?;
        let core_energy_j = read_f64_bits(&mut b)?;
        let soc_energy_j = read_f64_bits(&mut b)?;
        let sim_time_s = read_f64_bits(&mut b)?;
        let n_sim = read_u32(&mut b)?;
        let sim = read_f64s(&mut b, n_sim as usize)?;
        let n_wall = read_u32(&mut b)?;
        let wall = read_f64s(&mut b, n_wall as usize)?;
        let metrics = ServingMetrics {
            sim_latency_us: Percentiles::from_samples(sim),
            wall_latency_us: Percentiles::from_samples(wall),
            frames,
            labels_emitted,
            core_energy_j,
            soc_energy_j,
            sim_time_s,
        };

        // labels
        let n_labels = read_u32(&mut b)?;
        let raw = take(
            &mut b,
            (n_labels as usize)
                .checked_mul(4)
                .ok_or_else(|| malformed("label run length overflows"))?,
        )?;
        let labels: Vec<usize> = raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();

        // fault summary
        let mut fsum = [0u64; 10];
        for v in &mut fsum {
            *v = read_u64(&mut b)?;
        }
        let faults = FaultSummary {
            injected_flips: fsum[0],
            detected: fsum[1],
            degraded_frames: fsum[2],
            scrub_words: fsum[3],
            repair_words: fsum[4],
            retries: fsum[5],
            failures: fsum[6],
            quarantined: fsum[7],
            dropped_frames: fsum[8],
            snapshot_corrupt: fsum[9],
        };

        // hibernation ledger
        let mut hsum = [0u64; 5];
        for v in &mut hsum {
            *v = read_u64(&mut b)?;
        }
        let hib = HibernationStats {
            hibernates: hsum[0],
            resumes: hsum[1],
            corrupt_resumes: hsum[2],
            retention_word_ticks: hsum[3],
            snapshot_bytes: hsum[4],
            retention_j: read_f64_bits(&mut b)?,
            wake_j: read_f64_bits(&mut b)?,
        };

        // armed fault plan
        let fault = match read_u8(&mut b)? {
            0 => None,
            1 => {
                let surface = surface_from(read_u8(&mut b)?)?;
                let plan_ber = read_f64_bits(&mut b)?;
                let seed = read_u64(&mut b)?;
                let inj_ber = read_f64_bits(&mut b)?;
                if !valid_ber(plan_ber) || !valid_ber(inj_ber) {
                    return Err(malformed(format!(
                        "BER out of range (plan {plan_ber}, injector {inj_ber})"
                    )));
                }
                let mut rng = [0u64; 4];
                for w in &mut rng {
                    *w = read_u64(&mut b)?;
                }
                Some(FaultSnap { surface, plan_ber, seed, inj_ber, rng })
            }
            other => return Err(malformed(format!("bad fault-presence flag {other}"))),
        };

        if !b.is_empty() {
            return Err(malformed(format!("{} trailing bytes", b.len())));
        }
        Ok(SessionSnapshot {
            session_id,
            voltage,
            fingerprint,
            input_hw,
            input_ch,
            has_tcn,
            tcn,
            soc,
            metrics,
            labels,
            faults,
            hib,
            fault,
        })
    }

    /// Materialize the live session. Re-runs the TCN push invariants on
    /// the way (a snapshot cannot construct a state no push sequence
    /// produces); the SoC is rebuilt from the voltage — its power table
    /// is a pure function of the supply — then every mutable field is
    /// overwritten bit-exactly from the snapshot.
    pub fn into_session(self) -> SnapResult<Session> {
        let tcn = TcnMemory::from_parts(
            self.tcn.depth as usize,
            self.tcn.channels as usize,
            self.tcn.words,
            self.tcn.pushes,
            self.tcn.reads,
            self.tcn.shift_toggles,
        )
        .map_err(|e| malformed(e.to_string()))?;
        let mut soc = KrakenSoc::new(self.voltage);
        soc.fc_state = self.soc.fc_state;
        soc.dma_bits = self.soc.dma_bits as usize;
        for (d, s) in DOMAINS.iter().zip(self.soc.states) {
            soc.states.insert(*d, s);
        }
        soc.soc_fll.freq_hz = self.soc.soc_fll.freq_hz;
        soc.soc_fll.lock_time_ns = self.soc.soc_fll.lock_time_ns;
        soc.soc_fll.retargets = self.soc.soc_fll.retargets;
        soc.ehwpe_fll.freq_hz = self.soc.ehwpe_fll.freq_hz;
        soc.ehwpe_fll.lock_time_ns = self.soc.ehwpe_fll.lock_time_ns;
        soc.ehwpe_fll.retargets = self.soc.ehwpe_fll.retargets;
        soc.ledger = SocLedger {
            now_ns: self.soc.now_ns,
            energy_j: self.soc.energy_j,
            per_domain: self.soc.per_domain.into_iter().collect(),
            irq_count: self.soc.irq_count,
            fc_wakeups: self.soc.fc_wakeups,
            frames_ingested: self.soc.frames_ingested,
        };
        Ok(Session {
            id: self.session_id as usize,
            geometry: SessionGeometry {
                fingerprint: self.fingerprint,
                input_hw: self.input_hw as usize,
                input_ch: self.input_ch as usize,
                tcn_depth: self.tcn.depth as usize,
                channels: self.tcn.channels as usize,
                has_tcn: self.has_tcn,
            },
            tcn,
            soc,
            metrics: self.metrics,
            labels: self.labels,
            fault: self.fault.map(|f| FaultState {
                plan: FaultPlan { surface: f.surface, ber: f.plan_ber, seed: f.seed },
                inj: Injector::from_state(f.inj_ber, f.rng),
            }),
            faults: self.faults,
            hib: self.hib,
            idle_drains: 0,
            last_active: 0,
        })
    }
}

// ---------------------------------------------------------------------
// the store
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct StoredRecord {
    crc: u32,
    payload: Vec<u8>,
}

/// Tombstone record marker in the `len` header slot: a removal is
/// persisted as a header-only record (id, `u32::MAX`, crc 0) appended to
/// the log; replaying the file drops the id. A real payload can never be
/// this long (snapshots are hundreds of bytes).
const TOMBSTONE_LEN: u32 = u32::MAX;

/// The snapshot record store: a `BTreeMap` of CRC'd payloads, optionally
/// mirrored to a file. Mutations touch only memory; [`SessionStore::sync`]
/// is the sole writer. Long-lived stores stay bounded by a two-mode
/// writer: normally a sync **appends** only the records that changed
/// (plus header-only tombstones for removals — replaying the log keeps
/// the newest entry per id), and once the superseded dead weight
/// outgrows the live set — or a torn tail was recovered, since
/// appending after garbage would be unreadable — the sync degenerates
/// to [`SessionStore::compact`]: the full live image serialized to a
/// `.tmp` sibling and atomically renamed over the file, so the on-disk
/// state is always either the previous complete log or the new image —
/// a crash mid-compaction can tear at most the throwaway `.tmp`, and a
/// crash mid-append tears at most the tail (which `open` recovers).
#[derive(Debug)]
pub struct SessionStore {
    path: Option<PathBuf>,
    records: BTreeMap<u64, StoredRecord>,
    dirty: bool,
    recovered_torn: bool,
    /// Ids whose in-memory record changed since the last sync (inserted,
    /// replaced, or bit-rotted) — the append set.
    dirty_ids: BTreeSet<u64>,
    /// Ids removed since the last sync — the tombstone set (disjoint
    /// from `dirty_ids` by construction).
    tombstones: BTreeSet<u64>,
    /// Bytes of each id's newest on-disk image (header + payload).
    on_disk: BTreeMap<u64, usize>,
    /// Total bytes of the backing file.
    file_bytes: usize,
    /// File bytes held by superseded images and tombstones (reclaimed
    /// by compaction).
    dead_bytes: usize,
    /// Force a full rewrite on the next sync (set after torn-tail
    /// recovery: the garbage tail must not survive an append).
    needs_compact: bool,
}

impl SessionStore {
    /// A store with no backing file (records die with the process).
    pub fn in_memory() -> SessionStore {
        SessionStore {
            path: None,
            records: BTreeMap::new(),
            dirty: false,
            recovered_torn: false,
            dirty_ids: BTreeSet::new(),
            tombstones: BTreeSet::new(),
            on_disk: BTreeMap::new(),
            file_bytes: 0,
            dead_bytes: 0,
            needs_compact: false,
        }
    }

    /// Open (or create) a file-backed store. A missing or empty file is
    /// an empty store; a half-written tail — the kill-mid-write case —
    /// is skipped while every intact record before it is kept
    /// ([`SessionStore::recovered_torn`] reports the skip); a file that
    /// does not carry this store's magic is refused outright rather
    /// than silently clobbered.
    pub fn open(path: impl AsRef<Path>) -> anyhow::Result<SessionStore> {
        let path = path.as_ref().to_path_buf();
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(anyhow!("reading session store {}: {e}", path.display())),
        };
        let mut store = SessionStore::in_memory();
        store.path = Some(path.clone());
        if bytes.is_empty() {
            return Ok(store);
        }
        anyhow::ensure!(
            bytes.len() >= 8 && bytes[..8] == STORE_MAGIC.to_le_bytes(),
            "{} is not a session store (bad magic)",
            path.display()
        );
        store.file_bytes = bytes.len();
        let mut b = &bytes[8..];
        while !b.is_empty() {
            // record header: id u64, len u32, crc u32
            if b.len() < 16 {
                store.recovered_torn = true;
                break;
            }
            let id = u64::from_le_bytes(b[..8].try_into().unwrap());
            let len_raw = u32::from_le_bytes(b[8..12].try_into().unwrap());
            let crc = u32::from_le_bytes(b[12..16].try_into().unwrap());
            b = &b[16..];
            if len_raw == TOMBSTONE_LEN {
                // header-only removal marker: the id's earlier image is
                // dead, and so is the tombstone itself
                store.dead_bytes += 16;
                if let Some(prev) = store.on_disk.remove(&id) {
                    store.dead_bytes += prev;
                }
                store.records.remove(&id);
                continue;
            }
            let len = len_raw as usize;
            if b.len() < len {
                store.recovered_torn = true;
                break;
            }
            // log replay: a later image for the same id supersedes the
            // earlier one, which becomes dead weight
            if let Some(prev) = store.on_disk.insert(id, 16 + len) {
                store.dead_bytes += prev;
            }
            store.records.insert(id, StoredRecord { crc, payload: b[..len].to_vec() });
            b = &b[len..];
        }
        // appending after a garbage tail would bury the new records
        // behind unparseable bytes — force a rewrite instead
        store.needs_compact = store.recovered_torn;
        Ok(store)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// True when `open` skipped a half-written tail.
    pub fn recovered_torn(&self) -> bool {
        self.recovered_torn
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.records.contains_key(&id)
    }

    /// Record ids, ascending.
    pub fn ids(&self) -> Vec<u64> {
        self.records.keys().copied().collect()
    }

    /// Stored payload size of one record, in bytes.
    pub fn record_bytes(&self, id: u64) -> Option<usize> {
        self.records.get(&id).map(|r| r.payload.len())
    }

    /// Insert (or replace) a record. The CRC is computed over the clean
    /// payload here — later bit rot (or injected snapshot-surface
    /// faults) is exactly what the CRC check at read time catches.
    pub fn insert(&mut self, id: u64, payload: Vec<u8>) {
        let crc = crc32(&payload);
        self.records.insert(id, StoredRecord { crc, payload });
        self.tombstones.remove(&id);
        self.dirty_ids.insert(id);
        self.dirty = true;
    }

    /// Flip stored plane bits of one record (the snapshot fault
    /// surface). `bit_addrs` index the payload's bits little-endian;
    /// addresses beyond the record are ignored. The stored CRC is left
    /// at its write-time value — rot happens after a healthy write.
    pub fn flip_bits(&mut self, id: u64, bit_addrs: &[u64]) {
        let Some(rec) = self.records.get_mut(&id) else { return };
        for &a in bit_addrs {
            let (byte, bit) = ((a / 8) as usize, (a % 8) as u8);
            if byte < rec.payload.len() {
                rec.payload[byte] ^= 1 << bit;
            }
        }
        self.dirty_ids.insert(id);
        self.dirty = true;
    }

    fn verify(id: u64, rec: &StoredRecord) -> SnapResult<SessionSnapshot> {
        let got = crc32(&rec.payload);
        if got != rec.crc {
            return Err(SnapshotError::Crc { want: rec.crc, got });
        }
        SessionSnapshot::decode(&rec.payload, id)
    }

    /// Validate and decode a record without removing it.
    pub fn peek(&self, id: u64) -> Option<SnapResult<SessionSnapshot>> {
        self.records.get(&id).map(|rec| Self::verify(id, rec))
    }

    /// Remove a record and validate/decode it. The record leaves the
    /// store either way: a corrupt record is consumed (and reported as
    /// the typed error) rather than retried forever.
    pub fn take(&mut self, id: u64) -> Option<SnapResult<SessionSnapshot>> {
        let rec = self.records.remove(&id)?;
        self.dirty_ids.remove(&id);
        self.tombstones.insert(id);
        self.dirty = true;
        Some(Self::verify(id, &rec))
    }

    /// Bytes of the backing file holding superseded images/tombstones
    /// (reclaimed by the next compaction). 0 for in-memory stores.
    pub fn dead_bytes(&self) -> usize {
        self.dead_bytes
    }

    /// Total size of the backing file as of the last open/sync (0 for
    /// in-memory or never-synced stores).
    pub fn file_bytes(&self) -> usize {
        self.file_bytes
    }

    /// True when the accumulated dead weight outgrew the live records —
    /// the auto-GC trigger checked on every sync. The small floor keeps
    /// near-empty stores from compacting on every removal.
    fn gc_due(&self) -> bool {
        let live: usize = self.on_disk.values().sum();
        self.dead_bytes > live.max(64)
    }

    /// Persist pending changes. Fast path: append only the changed
    /// records (and header-only tombstones for removals) to the log.
    /// Falls back to a full [`SessionStore::compact`] when the file does
    /// not exist yet, a torn tail was recovered, or [`Self::gc_due`]
    /// says the dead weight outgrew the live set. No-op when nothing
    /// changed or the store is memory-only.
    pub fn sync(&mut self) -> anyhow::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        if self.path.is_none() {
            self.dirty = false;
            self.dirty_ids.clear();
            self.tombstones.clear();
            return Ok(());
        }
        if self.file_bytes == 0 || self.needs_compact || self.gc_due() {
            return self.compact();
        }
        let mut out = Vec::new();
        let killed: Vec<u64> =
            self.tombstones.iter().copied().filter(|id| self.on_disk.contains_key(id)).collect();
        for id in killed {
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&TOMBSTONE_LEN.to_le_bytes());
            out.extend_from_slice(&0u32.to_le_bytes());
            self.dead_bytes += 16;
            if let Some(prev) = self.on_disk.remove(&id) {
                self.dead_bytes += prev;
            }
        }
        let append_ids: Vec<u64> = self.dirty_ids.iter().copied().collect();
        for id in append_ids {
            let Some(rec) = self.records.get(&id) else { continue };
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(rec.payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&rec.crc.to_le_bytes());
            out.extend_from_slice(&rec.payload);
            if let Some(prev) = self.on_disk.insert(id, 16 + rec.payload.len()) {
                self.dead_bytes += prev;
            }
        }
        if !out.is_empty() {
            use std::io::Write;
            let path = self.path.as_ref().unwrap();
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(path)
                .with_context(|| format!("appending to {}", path.display()))?;
            f.write_all(&out).with_context(|| format!("appending to {}", path.display()))?;
            self.file_bytes += out.len();
        }
        self.dirty = false;
        self.dirty_ids.clear();
        self.tombstones.clear();
        Ok(())
    }

    /// Rewrite the backing file to exactly the live record set:
    /// serialize everything to a `.tmp` sibling, then atomically rename
    /// over the store file. Superseded images, tombstones and any
    /// recovered torn tail are all dropped. No-op for in-memory stores.
    pub fn compact(&mut self) -> anyhow::Result<()> {
        self.dirty = false;
        self.dirty_ids.clear();
        self.tombstones.clear();
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut out = Vec::with_capacity(
            8 + self.records.values().map(|r| 16 + r.payload.len()).sum::<usize>(),
        );
        out.extend_from_slice(&STORE_MAGIC.to_le_bytes());
        for (&id, rec) in &self.records {
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(rec.payload.len() as u32).to_le_bytes());
            out.extend_from_slice(&rec.crc.to_le_bytes());
            out.extend_from_slice(&rec.payload);
        }
        let mut tmp_os = path.as_os_str().to_os_string();
        tmp_os.push(".tmp");
        let tmp = PathBuf::from(tmp_os);
        std::fs::write(&tmp, &out).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
        self.on_disk = self.records.iter().map(|(&id, r)| (id, 16 + r.payload.len())).collect();
        self.file_bytes = out.len();
        self.dead_bytes = 0;
        self.needs_compact = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A session with every snapshotted field away from its default.
    fn busy_session() -> Session {
        let geom = SessionGeometry {
            fingerprint: 0xFEED_0000_0000_0009,
            input_hw: 64,
            input_ch: 2,
            tcn_depth: 8,
            channels: 16,
            has_tcn: true,
        };
        let mut s = Session::new(3, 0.5, geom);
        for step in 0..5u8 {
            let odd = if step % 2 == 0 { 1 } else { -1 };
            s.tcn.push_packed(PackedVec::pack(&[1, -1, 0, 1, odd]));
        }
        s.soc.dma_ingest(256);
        s.soc.raise_irq(crate::soc::Irq::FrameReady);
        s.soc.advance_ns(10_000);
        s.soc.add_core_energy(1.5e-6);
        s.soc.raise_irq(crate::soc::Irq::CutieDone);
        s.soc.fc_service_done();
        // leave the FSM mid-flight so non-default states hit the codec
        s.soc.raise_irq(crate::soc::Irq::FrameReady);
        s.soc.advance_ns(7_500);
        s.soc.raise_irq(crate::soc::Irq::CutieDone);
        s.metrics.record_frame(12.5, 3.25, 1.5e-6);
        s.labels.push(4);
        s.labels.push(9);
        s.faults.retries = 2;
        s.hib.hibernates = 1;
        s.fault = Some(FaultState {
            plan: FaultPlan::with_ber(FaultSurface::TcnMem, 0.01, 42),
            inj: Injector::new(0.01, 42),
        });
        s
    }

    fn assert_sessions_identical(a: &Session, b: &Session) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.geometry, b.geometry);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.hib, b.hib);
        assert_eq!(a.tcn.pushes, b.tcn.pushes);
        assert_eq!(a.tcn.shift_toggles, b.tcn.shift_toggles);
        let wa: Vec<_> = a.tcn.words().copied().collect();
        let wb: Vec<_> = b.tcn.words().copied().collect();
        assert_eq!(wa, wb);
        assert_eq!(a.soc.ledger.energy_j.to_bits(), b.soc.ledger.energy_j.to_bits());
        assert_eq!(a.soc.ledger.now_ns, b.soc.ledger.now_ns);
        assert_eq!(a.soc.ledger.fc_wakeups, b.soc.ledger.fc_wakeups);
        assert_eq!(a.soc.ledger.per_domain, b.soc.ledger.per_domain);
        assert_eq!(a.soc.fc_state, b.soc.fc_state);
        assert_eq!(a.soc.states, b.soc.states);
        assert_eq!(a.metrics.frames, b.metrics.frames);
        assert_eq!(a.metrics.core_energy_j.to_bits(), b.metrics.core_energy_j.to_bits());
        assert_eq!(a.metrics.sim_latency_us.samples(), b.metrics.sim_latency_us.samples());
    }

    #[test]
    fn snapshot_round_trips_bit_exactly() {
        let sess = busy_session();
        let payload = SessionSnapshot::capture(&sess).encode();
        let back = SessionSnapshot::decode(&payload, 3).unwrap().into_session().unwrap();
        assert_sessions_identical(&sess, &back);
        // the armed injector resumes at its exact position
        let (mut ia, mut ib) = (sess.fault.unwrap().inj, back.fault.unwrap().inj);
        assert_eq!(ia.faulted_bits(100_000), ib.faulted_bits(100_000));
        // and a re-capture of the restored session is byte-identical
        assert_eq!(payload, SessionSnapshot::capture(&busy_session()).encode());
    }

    #[test]
    fn decode_refuses_wrong_id_magic_version() {
        let payload = SessionSnapshot::capture(&busy_session()).encode();
        assert!(matches!(
            SessionSnapshot::decode(&payload, 99),
            Err(SnapshotError::Malformed(_))
        ));
        let mut bad = payload.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(SessionSnapshot::decode(&bad, 3), Err(SnapshotError::BadMagic(_))));
        let mut bad = payload;
        bad[4] = 0x7F;
        assert!(matches!(SessionSnapshot::decode(&bad, 3), Err(SnapshotError::BadVersion(_))));
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let payload = SessionSnapshot::capture(&busy_session()).encode();
        for cut in 0..payload.len() {
            match SessionSnapshot::decode(&payload[..cut], 3) {
                Err(_) => {}
                Ok(_) => panic!("truncation at {cut}/{} decoded", payload.len()),
            }
        }
    }

    #[test]
    fn store_crc_catches_bit_flips() {
        let mut store = SessionStore::in_memory();
        let payload = SessionSnapshot::capture(&busy_session()).encode();
        store.insert(3, payload.clone());
        assert!(store.peek(3).unwrap().is_ok());
        store.flip_bits(3, &[137]);
        assert!(matches!(store.peek(3), Some(Err(SnapshotError::Crc { .. }))));
        // take consumes the record either way
        assert!(matches!(store.take(3), Some(Err(SnapshotError::Crc { .. }))));
        assert!(store.take(3).is_none());
    }

    #[test]
    fn file_store_round_trips_and_recovers_torn_tail() {
        let path = std::env::temp_dir().join("tcn_cutie_hib_store_unit.bin");
        let _ = std::fs::remove_file(&path);
        let mut store = SessionStore::open(&path).unwrap();
        let p1 = SessionSnapshot::capture(&busy_session()).encode();
        let mut other = busy_session();
        other.id = 7;
        let p2 = SessionSnapshot::capture(&other).encode();
        store.insert(3, p1);
        store.insert(7, p2.clone());
        store.sync().unwrap();

        let reopened = SessionStore::open(&path).unwrap();
        assert_eq!(reopened.ids(), vec![3, 7]);
        assert!(!reopened.recovered_torn());
        assert!(reopened.peek(3).unwrap().is_ok());

        // kill mid-write: chop the file inside record 7's payload
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - p2.len() / 2]).unwrap();
        let torn = SessionStore::open(&path).unwrap();
        assert!(torn.recovered_torn(), "half-written tail must be reported");
        assert_eq!(torn.ids(), vec![3], "intact records before the tear survive");
        assert!(torn.peek(3).unwrap().is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn foreign_file_is_refused_not_clobbered() {
        let path = std::env::temp_dir().join("tcn_cutie_hib_store_foreign.bin");
        std::fs::write(&path, b"definitely not a session store").unwrap();
        assert!(SessionStore::open(&path).is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"definitely not a session store");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_only_sync_supersedes_and_tombstones() {
        let path = std::env::temp_dir().join("tcn_cutie_hib_store_gc.bin");
        let _ = std::fs::remove_file(&path);
        let mut store = SessionStore::open(&path).unwrap();
        let p3 = SessionSnapshot::capture(&busy_session()).encode();
        let mut other = busy_session();
        other.id = 7;
        let p7 = SessionSnapshot::capture(&other).encode();
        store.insert(3, p3.clone());
        store.insert(7, p7.clone());
        store.sync().unwrap();
        let full = std::fs::read(&path).unwrap().len();
        assert_eq!(store.file_bytes(), full);
        assert_eq!(store.dead_bytes(), 0);

        // a removal appends a 16 B header-only tombstone...
        let _ = store.take(3);
        store.sync().unwrap();
        assert_eq!(std::fs::read(&path).unwrap().len(), full + 16);
        assert!(store.dead_bytes() > 16, "the tombstone kills the old image too");
        // ...and replaying the log drops the id
        let re = SessionStore::open(&path).unwrap();
        assert_eq!(re.ids(), vec![7]);
        assert!(re.peek(7).unwrap().is_ok());
        assert!(re.dead_bytes() > 0);

        // re-inserting the id lands it back (append or auto-GC,
        // whichever the dead-weight trigger picks)
        store.insert(3, p3.clone());
        store.sync().unwrap();
        let re = SessionStore::open(&path).unwrap();
        assert_eq!(re.ids(), vec![3, 7]);

        // explicit compaction rewrites to exactly the live set
        store.compact().unwrap();
        assert_eq!(store.dead_bytes(), 0);
        assert_eq!(store.file_bytes(), 8 + 32 + p3.len() + p7.len());
        assert_eq!(std::fs::read(&path).unwrap().len(), store.file_bytes());
        let re = SessionStore::open(&path).unwrap();
        assert_eq!(re.ids(), vec![3, 7]);
        assert!(re.peek(3).unwrap().is_ok());
        assert!(re.peek(7).unwrap().is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gc_keeps_long_lived_store_files_bounded() {
        let path = std::env::temp_dir().join("tcn_cutie_hib_store_bounded.bin");
        let _ = std::fs::remove_file(&path);
        let mut store = SessionStore::open(&path).unwrap();
        let p = SessionSnapshot::capture(&busy_session()).encode();
        store.insert(3, p.clone());
        store.sync().unwrap();
        let one = std::fs::read(&path).unwrap().len();
        // a hibernate/resume churn cycle per sync: without GC the log
        // would grow by one image every iteration
        for round in 0..20 {
            let _ = store.take(3);
            store.insert(3, p.clone());
            store.sync().unwrap();
            let sz = std::fs::read(&path).unwrap().len();
            assert!(
                sz <= one * 4,
                "round {round}: file must stay bounded ({sz} B vs 1 record = {one} B)"
            );
            let re = SessionStore::open(&path).unwrap();
            assert_eq!(re.ids(), vec![3]);
            assert!(re.peek(3).unwrap().is_ok(), "round {round}: live record must survive GC");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_after_torn_recovery_compacts_first() {
        let path = std::env::temp_dir().join("tcn_cutie_hib_store_torn_append.bin");
        let _ = std::fs::remove_file(&path);
        let mut store = SessionStore::open(&path).unwrap();
        let p3 = SessionSnapshot::capture(&busy_session()).encode();
        let mut other = busy_session();
        other.id = 7;
        let p7 = SessionSnapshot::capture(&other).encode();
        store.insert(3, p3);
        store.insert(7, p7.clone());
        store.sync().unwrap();
        // kill mid-write inside record 7, then reopen and keep serving
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - p7.len() / 2]).unwrap();
        let mut store = SessionStore::open(&path).unwrap();
        assert!(store.recovered_torn());
        assert_eq!(store.ids(), vec![3]);
        // the next sync must NOT append after the garbage tail — it
        // compacts first, so every record replays cleanly
        let mut nine = busy_session();
        nine.id = 9;
        store.insert(9, SessionSnapshot::capture(&nine).encode());
        store.sync().unwrap();
        let re = SessionStore::open(&path).unwrap();
        assert!(!re.recovered_torn(), "the garbage tail must be gone");
        assert_eq!(re.ids(), vec![3, 9]);
        assert!(re.peek(3).unwrap().is_ok());
        assert!(re.peek(9).unwrap().is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hibernation_stats_merge_and_any() {
        let mut h = HibernationStats::default();
        assert!(!h.any());
        let one = HibernationStats {
            hibernates: 2,
            resumes: 1,
            retention_word_ticks: 72,
            snapshot_bytes: 640,
            retention_j: 1e-12,
            wake_j: 2e-12,
            ..Default::default()
        };
        h.merge(&one);
        h.merge(&one);
        assert_eq!(h.hibernates, 4);
        assert_eq!(h.retention_word_ticks, 144);
        assert_eq!(h.retention_j.to_bits(), (2e-12f64).to_bits());
        assert!(h.any());
    }
}
