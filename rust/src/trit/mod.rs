//! Ternary value representation.
//!
//! Trits are `i8 ∈ {-1, 0, +1}` at API boundaries. The simulator hot path
//! uses **bitplane packing**: a channel vector of up to 128 trits is two
//! 128-bit masks, and the ternary dot product reduces to AND/XOR +
//! popcount — the software analogue of CUTIE's wide adder trees, and
//! simultaneously the source of the switching-activity statistics the
//! energy model consumes (a non-zero partial product is a toggling
//! multiplier in the RTL; see [1] §V).
//!
//! Encoding (perf pass iteration 1, see EXPERIMENTS.md §Perf): planes are
//! (`pos`, `mask`) with `pos ⊆ mask`; `mask` flags non-zero trits and
//! `pos` flags +1. For channels where both operands are non-zero
//! (`nz = a.mask & b.mask`) the product is −1 exactly when the sign bits
//! differ (`diff = nz & (a.pos ^ b.pos)`), so
//!
//! ```text
//! dot     = popcount(nz) − 2·popcount(diff)
//! toggles = popcount(nz)
//! ```
//!
//! — two popcounts per word instead of the four the (pos, neg) encoding
//! needs, and the toggle count comes for free.
//!
//! The kernels below are the portable scalar backend; [`simd`] holds the
//! runtime-dispatched AVX2 twins (bit-identical words and counters) and
//! the process-wide backend selection.

pub mod simd;

pub const MAX_CHANNELS: usize = 128;
const WORDS: usize = MAX_CHANNELS / 64;

/// One (pos, mask) word pair's contribution to a fused dot — the shared
/// `nz`/`diff` two-popcount idiom from the module doc, in exactly one
/// place. Returns `(popcount(nz) − 2·popcount(diff), popcount(nz))`;
/// every dot variant and both SIMD backends reduce to this kernel.
#[inline]
pub(crate) fn word_dot(a_pos: u64, a_mask: u64, b_pos: u64, b_mask: u64) -> (i32, u32) {
    let nz = a_mask & b_mask;
    let diff = nz & (a_pos ^ b_pos);
    let n = nz.count_ones();
    (n as i32 - 2 * diff.count_ones() as i32, n)
}

/// A packed vector of up to 128 trits (CUTIE's channel dimension).
/// Invariant: `pos & !mask == 0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PackedVec {
    /// Bit i set ⇔ trit i == +1.
    pub pos: [u64; WORDS],
    /// Bit i set ⇔ trit i != 0.
    pub mask: [u64; WORDS],
}

impl PackedVec {
    pub const ZERO: PackedVec = PackedVec { pos: [0; WORDS], mask: [0; WORDS] };

    /// Pack a slice of trits (len <= 128). Panics on non-trit values.
    pub fn pack(trits: &[i8]) -> PackedVec {
        assert!(trits.len() <= MAX_CHANNELS, "at most {MAX_CHANNELS} channels");
        let mut v = PackedVec::ZERO;
        for (i, &t) in trits.iter().enumerate() {
            match t {
                0 => {}
                1 => {
                    v.pos[i / 64] |= 1 << (i % 64);
                    v.mask[i / 64] |= 1 << (i % 64);
                }
                -1 => v.mask[i / 64] |= 1 << (i % 64),
                other => panic!("non-trit value {other}"),
            }
        }
        v
    }

    /// Unpack the first `n` trits.
    pub fn unpack(&self, n: usize) -> Vec<i8> {
        (0..n).map(|i| self.get(i)).collect()
    }

    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        let (w, b) = (i / 64, i % 64);
        if (self.mask[w] >> b) & 1 == 0 {
            0
        } else if (self.pos[w] >> b) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, t: i8) {
        let (w, b) = (i / 64, i % 64);
        self.pos[w] &= !(1 << b);
        self.mask[w] &= !(1 << b);
        match t {
            1 => {
                self.pos[w] |= 1 << b;
                self.mask[w] |= 1 << b;
            }
            -1 => self.mask[w] |= 1 << b,
            0 => {}
            other => panic!("non-trit value {other}"),
        }
    }

    /// Number of non-zero trits.
    #[inline]
    pub fn count_nonzero(&self) -> u32 {
        self.mask.iter().map(|w| w.count_ones()).sum()
    }

    /// True if every trit is zero (cheap; used for sparsity skipping).
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.mask[0] == 0 && self.mask[1] == 0
    }

    /// Ternary dot product + non-zero-partial-product count (the toggling
    /// proxy). acc = Σ a_i * b_i; toggles = #{i : a_i*b_i != 0}.
    #[inline]
    pub fn dot(&self, other: &PackedVec) -> (i32, u32) {
        let mut acc = 0i32;
        let mut toggles = 0u32;
        for w in 0..WORDS {
            let (d, n) = word_dot(self.pos[w], self.mask[w], other.pos[w], other.mask[w]);
            acc += d;
            toggles += n;
        }
        (acc, toggles)
    }

    /// Single-word dot product: valid when both operands only populate
    /// channels 0..64 (perf pass iteration 6 — halves the popcount work
    /// for narrow layers like the DVS front-end).
    #[inline]
    pub fn dot_narrow(&self, other: &PackedVec) -> (i32, u32) {
        debug_assert!(self.mask[1] == 0 || other.mask[1] == 0);
        word_dot(self.pos[0], self.mask[0], other.pos[0], other.mask[0])
    }

    /// Plain dot product (no activity reporting — same cost with this
    /// encoding, kept for API compatibility of the fast path).
    #[inline]
    pub fn dot_fast(&self, other: &PackedVec) -> i32 {
        let mut acc = 0i32;
        for w in 0..WORDS {
            acc += word_dot(self.pos[w], self.mask[w], other.pos[w], other.mask[w]).0;
        }
        acc
    }

    /// Copy with every plane bit at positions ≥ `n` cleared — the packed
    /// twin of slicing a channel vector down to its first `n` channels
    /// (the RTL ties unused channels to zero). Used by the TCN memory's
    /// read port to present a hardware-width word as a `feat_ch`-wide
    /// one (perf pass iteration 9).
    #[inline]
    pub fn masked(&self, n: usize) -> PackedVec {
        debug_assert!(n <= MAX_CHANNELS, "at most {MAX_CHANNELS} channels");
        let mut out = *self;
        if n >= MAX_CHANNELS {
            return out;
        }
        let (w, b) = (n / 64, n % 64);
        let keep = (1u64 << b) - 1;
        out.pos[w] &= keep;
        out.mask[w] &= keep;
        for i in (w + 1)..WORDS {
            out.pos[i] = 0;
            out.mask[i] = 0;
        }
        out
    }

    /// Serialize the planes as four u64s in `(pos0, pos1, mask0, mask1)`
    /// order — the on-disk word layout of the packed `.ttn` v2
    /// weight-image section (4 words ⇔ `MAX_CHANNELS` = 128 trits).
    #[inline]
    pub fn to_words(&self) -> [u64; 4] {
        [self.pos[0], self.pos[1], self.mask[0], self.mask[1]]
    }

    /// Rebuild from `(pos0, pos1, mask0, mask1)` words, validating the
    /// `pos ⊆ mask` invariant — a bit-flipped or hostile weight file
    /// must surface as a load error, never as a silently-wrong dot
    /// product. `None` when the invariant is violated.
    #[inline]
    pub fn from_words(w: [u64; 4]) -> Option<PackedVec> {
        let v = PackedVec { pos: [w[0], w[1]], mask: [w[2], w[3]] };
        if v.pos[0] & !v.mask[0] != 0 || v.pos[1] & !v.mask[1] != 0 {
            return None;
        }
        Some(v)
    }

    /// Flip one plane bit in place — the SRAM soft-error primitive of the
    /// fault-injection layer ([`crate::fault`]). The two planes are the
    /// two physical bitcells per trit and upset independently, so a flip
    /// may violate the `pos ⊆ mask` invariant; [`Self::scrub`] is the
    /// matching detector.
    #[inline]
    pub fn flip_plane_bit(&mut self, pos_plane: bool, bit: usize) {
        debug_assert!(bit < MAX_CHANNELS);
        let (w, b) = (bit / 64, bit % 64);
        if pos_plane {
            self.pos[w] ^= 1 << b;
        } else {
            self.mask[w] ^= 1 << b;
        }
    }

    /// Scrub pass: detect and clamp `pos ⊄ mask` orphans (a +1 plane bit
    /// whose non-zero flag is clear — a state no legal write produces, so
    /// it is proof of corruption). Returns the number of orphan bits
    /// cleared; zero on any legally-constructed word.
    #[inline]
    pub fn scrub(&mut self) -> u32 {
        let mut fixed = 0;
        for w in 0..WORDS {
            let orphan = self.pos[w] & !self.mask[w];
            fixed += orphan.count_ones();
            self.pos[w] &= self.mask[w];
        }
        fixed
    }

    /// Channel-wise ternary max — the packed pooling primitive (perf pass
    /// iteration 8). On the (pos, mask) planes `max(a, b)` is two bitwise
    /// ops per word: the result is +1 iff either operand is +1
    /// (`pos = a.pos | b.pos`) and non-zero unless one operand is 0 and
    /// neither is +1 (`mask = pos | (a.mask & b.mask)` — both-(−1) keeps
    /// the mask bit, anything touching a 0 clears it). Dispatches to the
    /// active [`simd`] backend (both produce identical words).
    #[inline]
    pub fn max(&self, other: &PackedVec) -> PackedVec {
        simd::vec_max(self, other)
    }
}

/// Words in a dense 3-row column vector (3 × MAX_CHANNELS bits).
pub const COL_WORDS: usize = 3 * MAX_CHANNELS / 64;

/// OR the low `nbits` (≤ 128) of a two-word bitplane into `dst` starting
/// at bit offset `shift`. The column-vector packing primitive (perf pass
/// iteration 7, see EXPERIMENTS.md §Perf).
#[inline]
fn or_shifted(dst: &mut [u64; COL_WORDS], src: &[u64; WORDS], shift: usize, nbits: usize) {
    let w = shift / 64;
    let b = shift % 64;
    let m0 = if nbits >= 64 { u64::MAX } else { (1u64 << nbits) - 1 };
    let m1 = if nbits <= 64 {
        0
    } else if nbits >= 128 {
        u64::MAX
    } else {
        (1u64 << (nbits - 64)) - 1
    };
    let s0 = src[0] & m0;
    let s1 = src[1] & m1;
    if b == 0 {
        dst[w] |= s0;
        if s1 != 0 {
            dst[w + 1] |= s1;
        }
    } else {
        dst[w] |= s0 << b;
        dst[w + 1] |= (s0 >> (64 - b)) | (s1 << b);
        if s1 != 0 {
            dst[w + 2] |= s1 >> (64 - b);
        }
    }
}

/// A densely packed 3-row column of trit channel vectors — the operand of
/// the fused column dot product the column-stationary datapath runs once
/// per (input column, kernel column) instead of three separate
/// per-position dots. Row r's channels occupy bits [r·C_in, (r+1)·C_in),
/// so a C_in-channel column needs ⌈3·C_in/64⌉ dense words instead of the
/// 3·⌈C_in/64⌉ a row-per-word layout costs (e.g. 5 vs 6 at C_in = 96,
/// 1 vs 3 at C_in ≤ 21) — fewer popcounts for the same bit-exact result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TritCol {
    /// Bit i set ⇔ trit i == +1 (dense row-major layout).
    pub pos: [u64; COL_WORDS],
    /// Bit i set ⇔ trit i != 0.
    pub mask: [u64; COL_WORDS],
}

impl TritCol {
    pub const ZERO: TritCol = TritCol { pos: [0; COL_WORDS], mask: [0; COL_WORDS] };

    /// Dense words a C_in-channel column occupies (≥ 1).
    #[inline]
    pub fn words(cin: usize) -> usize {
        (3 * cin).div_ceil(64).max(1)
    }

    /// Pack three pixel words (kernel rows top→bottom) into one dense
    /// column vector. Bits ≥ C_in per row must be zero in `rows`, which
    /// always holds for vectors from [`PackedVec::pack`] /
    /// `TritTensor::pack_pixel` over C_in channels.
    #[inline]
    pub fn pack_rows(rows: &[PackedVec; 3], cin: usize) -> TritCol {
        let mut c = TritCol::ZERO;
        for (r, row) in rows.iter().enumerate() {
            or_shifted(&mut c.pos, &row.pos, r * cin, cin);
            or_shifted(&mut c.mask, &row.mask, r * cin, cin);
        }
        c
    }

    /// Fused ternary column dot product + toggle count over the first
    /// `nwords` dense words. Bit-exact equal to the sum of the three
    /// per-row [`PackedVec::dot`]s: the dense layout only concatenates
    /// disjoint bit ranges, and both acc and popcount are additive.
    /// Dispatches to the active [`simd`] backend; integer accumulation
    /// keeps both backends' results identical, counters included.
    #[inline]
    pub fn dot(&self, other: &TritCol, nwords: usize) -> (i32, u32) {
        simd::col_dot(self, other, nwords)
    }

    /// True if every trit in the first `nwords` words is zero (whole-column
    /// sparsity skip; contributes neither acc nor toggles, so bit-exact).
    #[inline]
    pub fn is_zero(&self, nwords: usize) -> bool {
        self.mask[..nwords].iter().all(|&w| w == 0)
    }

    /// Read back row r's trit at channel ci (test/debug helper).
    pub fn get(&self, r: usize, ci: usize, cin: usize) -> i8 {
        let bit = r * cin + ci;
        let (w, b) = (bit / 64, bit % 64);
        if (self.mask[w] >> b) & 1 == 0 {
            0
        } else if (self.pos[w] >> b) & 1 == 1 {
            1
        } else {
            -1
        }
    }
}

/// Scalar reference dot product (used by tests to validate the packed path).
pub fn dot_scalar(a: &[i8], b: &[i8]) -> (i32, u32) {
    assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    let mut toggles = 0u32;
    for (&x, &y) in a.iter().zip(b) {
        let p = (x as i32) * (y as i32);
        acc += p;
        if p != 0 {
            toggles += 1;
        }
    }
    (acc, toggles)
}

/// Ternarize an accumulator with the two-threshold contract
/// (`lo <= hi + 1`; `lo == hi + 1` encodes an empty zero-region):
/// +1 if acc > hi, -1 if acc < lo, else 0.
#[inline]
pub fn ternarize(acc: i32, lo: i32, hi: i32) -> i8 {
    debug_assert!(lo <= hi + 1, "threshold contract violated: lo {lo} hi {hi}");
    if acc > hi {
        1
    } else if acc < lo {
        -1
    } else {
        0
    }
}

/// Branchless vectorized [`ternarize`] (perf pass iteration 8): threshold
/// one pixel's accumulator row (≤ 128 channels, one accumulator per
/// active OCU) straight into (pos, mask) bitplanes. Channel i of the
/// result is +1 iff `acc[i] > hi[i]` and non-zero iff it is +1 or
/// `acc[i] < lo[i]` — exactly the scalar two-threshold contract, but the
/// output trits are written as packed words with no per-trit branch or
/// i8 store. With the contract `lo <= hi + 1` the two comparisons are
/// mutually exclusive, so `pos ⊆ mask` holds by construction. Dispatches
/// to the active [`simd`] backend (identical output words).
#[inline]
pub fn ternarize_packed(acc: &[i32], lo: &[i32], hi: &[i32]) -> PackedVec {
    debug_assert!(acc.len() <= MAX_CHANNELS, "at most {MAX_CHANNELS} channels");
    debug_assert_eq!(acc.len(), lo.len());
    debug_assert_eq!(acc.len(), hi.len());
    debug_assert!(
        lo.iter().zip(hi).all(|(&l, &h)| l <= h + 1),
        "threshold contract violated"
    );
    simd::ternarize(acc, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let trits: Vec<i8> = (0..n).map(|_| rng.trit(0.3)).collect();
            let packed = PackedVec::pack(&trits);
            assert_eq!(packed.unpack(n), trits);
        }
    }

    #[test]
    fn get_set() {
        let mut v = PackedVec::ZERO;
        v.set(5, 1);
        v.set(70, -1);
        assert_eq!(v.get(5), 1);
        assert_eq!(v.get(70), -1);
        assert_eq!(v.get(0), 0);
        v.set(5, -1);
        assert_eq!(v.get(5), -1);
        v.set(5, 0);
        assert_eq!(v.get(5), 0);
        assert!(PackedVec::ZERO.is_zero());
        assert!(!v.is_zero() || v.count_nonzero() == 0);
    }

    #[test]
    fn invariant_pos_subset_mask() {
        let mut rng = Rng::new(8);
        for _ in 0..100 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let trits: Vec<i8> = (0..n).map(|_| rng.trit(0.3)).collect();
            let v = PackedVec::pack(&trits);
            for w in 0..2 {
                assert_eq!(v.pos[w] & !v.mask[w], 0);
            }
        }
    }

    #[test]
    fn dot_matches_scalar_property() {
        // Property test (seeded sweep): packed dot == scalar dot, with
        // matching toggle counts, across lengths and sparsities.
        let mut rng = Rng::new(2);
        for case in 0..500 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
            let a: Vec<i8> = (0..n).map(|_| rng.trit(zf)).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.trit(zf)).collect();
            let (acc_s, tog_s) = dot_scalar(&a, &b);
            let (acc_p, tog_p) = PackedVec::pack(&a).dot(&PackedVec::pack(&b));
            assert_eq!(acc_p, acc_s);
            assert_eq!(tog_p, tog_s);
            assert_eq!(PackedVec::pack(&a).dot_fast(&PackedVec::pack(&b)), acc_s);
        }
    }

    #[test]
    fn dot_bounds() {
        let ones = vec![1i8; 96];
        let v = PackedVec::pack(&ones);
        assert_eq!(v.dot(&v), (96, 96));
        let negs = vec![-1i8; 96];
        let w = PackedVec::pack(&negs);
        assert_eq!(v.dot(&w), (-96, 96));
    }

    #[test]
    fn ternarize_contract() {
        assert_eq!(ternarize(3, -2, 2), 1);
        assert_eq!(ternarize(-3, -2, 2), -1);
        assert_eq!(ternarize(2, -2, 2), 0);
        assert_eq!(ternarize(-2, -2, 2), 0);
        assert_eq!(ternarize(0, -2, 2), 0);
        // empty zero-region: lo = hi + 1
        assert_eq!(ternarize(3, 4, 3), -1);
        assert_eq!(ternarize(4, 4, 3), 1);
    }

    #[test]
    #[should_panic(expected = "non-trit")]
    fn pack_rejects_non_trits() {
        PackedVec::pack(&[0, 2]);
    }

    #[test]
    fn tritcol_dot_matches_three_row_dots_property() {
        // Seeded sweep across channel widths (incl. the 42/43 and 64
        // word-boundary straddles) and sparsities: the fused column dot
        // must equal the sum of three per-row packed dots, acc and
        // toggles both.
        let mut rng = Rng::new(91);
        for case in 0..400 {
            let cin = 1 + rng.below(MAX_CHANNELS);
            let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
            let xr: Vec<Vec<i8>> = (0..3).map(|_| (0..cin).map(|_| rng.trit(zf)).collect()).collect();
            let wr: Vec<Vec<i8>> = (0..3).map(|_| (0..cin).map(|_| rng.trit(zf)).collect()).collect();
            let xp = [PackedVec::pack(&xr[0]), PackedVec::pack(&xr[1]), PackedVec::pack(&xr[2])];
            let wp = [PackedVec::pack(&wr[0]), PackedVec::pack(&wr[1]), PackedVec::pack(&wr[2])];
            let mut want_acc = 0i32;
            let mut want_tog = 0u32;
            for r in 0..3 {
                let (a, t) = wp[r].dot(&xp[r]);
                want_acc += a;
                want_tog += t;
            }
            let xc = TritCol::pack_rows(&xp, cin);
            let wc = TritCol::pack_rows(&wp, cin);
            let nw = TritCol::words(cin);
            let (acc, tog) = wc.dot(&xc, nw);
            assert_eq!(acc, want_acc, "cin {cin} case {case}");
            assert_eq!(tog, want_tog, "cin {cin} case {case}");
            assert_eq!(xc.is_zero(nw), xr.iter().all(|r| r.iter().all(|&t| t == 0)));
        }
    }

    #[test]
    fn tritcol_roundtrip_and_word_count() {
        let mut rng = Rng::new(92);
        for &cin in &[1, 2, 21, 22, 42, 43, 64, 96, 128] {
            let rows: Vec<Vec<i8>> =
                (0..3).map(|_| (0..cin).map(|_| rng.trit(0.3)).collect()).collect();
            let packed = [
                PackedVec::pack(&rows[0]),
                PackedVec::pack(&rows[1]),
                PackedVec::pack(&rows[2]),
            ];
            let col = TritCol::pack_rows(&packed, cin);
            for r in 0..3 {
                for ci in 0..cin {
                    assert_eq!(col.get(r, ci, cin), rows[r][ci], "cin {cin} r {r} ci {ci}");
                }
            }
            assert_eq!(TritCol::words(cin), (3 * cin).div_ceil(64).max(1));
        }
        // 96-channel column: 288 bits in 5 words, not 6
        assert_eq!(TritCol::words(96), 5);
        assert_eq!(TritCol::words(128), 6);
        assert_eq!(TritCol::words(2), 1);
    }

    #[test]
    fn ternary_max_matches_scalar() {
        let mut rng = Rng::new(14);
        for case in 0..300 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
            let a: Vec<i8> = (0..n).map(|_| rng.trit(zf)).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.trit(zf)).collect();
            let want: Vec<i8> = a.iter().zip(&b).map(|(&x, &y)| x.max(y)).collect();
            let got = PackedVec::pack(&a).max(&PackedVec::pack(&b));
            assert_eq!(got.unpack(n), want, "n {n} case {case}");
            for w in 0..2 {
                assert_eq!(got.pos[w] & !got.mask[w], 0, "pos ⊆ mask violated");
            }
        }
    }

    #[test]
    fn ternarize_packed_matches_scalar() {
        let mut rng = Rng::new(15);
        for case in 0..300 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let acc: Vec<i32> =
                (0..n).map(|_| rng.below(41) as i32 - 20).collect();
            let (lo, hi): (Vec<i32>, Vec<i32>) = (0..n)
                .map(|_| {
                    let hi = rng.below(9) as i32 - 4;
                    // exercise the empty zero-region (lo = hi + 1) too
                    let lo = hi + 1 - rng.below(8) as i32;
                    (lo, hi)
                })
                .unzip();
            let want: Vec<i8> =
                (0..n).map(|i| ternarize(acc[i], lo[i], hi[i])).collect();
            let got = ternarize_packed(&acc, &lo, &hi);
            assert_eq!(got.unpack(n), want, "n {n} case {case}");
            for w in 0..2 {
                assert_eq!(got.pos[w] & !got.mask[w], 0, "pos ⊆ mask violated");
            }
        }
    }

    #[test]
    fn masked_equals_truncated_repack() {
        // Property: masking to n channels == packing only the first n
        // trits, across word-boundary widths (incl. 0, 64, 128).
        let mut rng = Rng::new(16);
        for case in 0..200 {
            let len = 1 + rng.below(MAX_CHANNELS);
            let trits: Vec<i8> = (0..len).map(|_| rng.trit(0.3)).collect();
            let v = PackedVec::pack(&trits);
            for &n in &[0, 1, 21, 63, 64, 65, 96, 127, 128] {
                let m = v.masked(n);
                let kept = &trits[..n.min(len)];
                assert_eq!(m, PackedVec::pack(kept), "len {len} n {n} case {case}");
                for w in 0..2 {
                    assert_eq!(m.pos[w] & !m.mask[w], 0, "pos ⊆ mask violated");
                }
            }
        }
    }

    #[test]
    fn word_serde_roundtrip_and_invariant() {
        let mut rng = Rng::new(21);
        for _ in 0..200 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let trits: Vec<i8> = (0..n).map(|_| rng.trit(0.3)).collect();
            let v = PackedVec::pack(&trits);
            assert_eq!(PackedVec::from_words(v.to_words()), Some(v));
        }
        // pos bit outside mask must be rejected, not decoded
        assert_eq!(PackedVec::from_words([1, 0, 0, 0]), None);
        assert_eq!(PackedVec::from_words([0, 1 << 63, 0, 0]), None);
        assert_eq!(PackedVec::from_words([0, 0, 1, 0]).map(|v| v.get(0)), Some(-1));
    }

    #[test]
    fn flip_and_scrub() {
        let mut v = PackedVec::pack(&[1, -1, 0, 0, 1]);
        // mask flip on a zero channel: silent −1, no invariant violation
        v.flip_plane_bit(false, 2);
        assert_eq!(v.get(2), -1);
        assert_eq!(v.scrub(), 0, "legal word must scrub clean");
        // pos flip on a zero channel: orphan, detected and clamped
        v.flip_plane_bit(true, 3);
        assert_eq!(v.pos[0] & !v.mask[0], 1 << 3);
        assert_eq!(v.scrub(), 1);
        assert_eq!(v.get(3), 0, "orphan clamps back to zero");
        // pos flip on a +1 channel: silent demotion to −1
        v.flip_plane_bit(true, 0);
        assert_eq!(v.get(0), -1);
        assert_eq!(v.scrub(), 0);
        // high-word orphan
        let mut w = PackedVec::ZERO;
        w.flip_plane_bit(true, 100);
        assert_eq!(w.scrub(), 1);
        assert!(w.is_zero());
    }

    #[test]
    fn count_nonzero_matches() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let n = 1 + rng.below(MAX_CHANNELS);
            let a: Vec<i8> = (0..n).map(|_| rng.trit(0.5)).collect();
            let expected = a.iter().filter(|&&t| t != 0).count() as u32;
            assert_eq!(PackedVec::pack(&a).count_nonzero(), expected);
        }
    }
}
