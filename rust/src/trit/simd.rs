//! Runtime-dispatched SIMD backend for the packed kernels.
//!
//! The (pos, mask) bitplane layouts were chosen in the packed-activation
//! pass so vectorization would be a drop-in change: every hot kernel is
//! word-parallel AND/XOR/popcount with integer accumulators, so a vector
//! backend produces **bit-identical words and counters** to the scalar
//! loops — not merely numerically-close results. The AVX2 paths here are
//! the software analogue of CUTIE's completely-unrolled OCU adder trees:
//! four 64-bit plane words per 256-bit `vpand`/`vpxor`, popcounts via the
//! classic `vpshufb` nibble-table + `vpsadbw` horizontal byte sum.
//!
//! Dispatch is resolved once per process, in precedence order: an
//! explicit [`set_backend`] call (the `--simd` CLI flag), the `TCN_SIMD`
//! environment variable (how CI forces a whole test-suite run scalar),
//! then `is_x86_feature_detected!("avx2")` auto-detection. Non-x86
//! targets compile the scalar backend only. The resolved choice is
//! stamped into every `ServingReport` and bench-ledger entry
//! ([`active_name`]) so recorded runs are attributable to the backend
//! that produced them.

use std::sync::atomic::{AtomicU8, Ordering};

use super::{word_dot, PackedVec, TritCol};

/// Backend selection for the packed kernels (`--simd auto|scalar|avx2`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdBackend {
    /// Probe the host once and take the widest available backend.
    Auto,
    /// Portable u64 scalar loops (the reference implementation).
    Scalar,
    /// 256-bit AVX2 kernels. Requesting this on a host without AVX2 is a
    /// typed error, never a silent fallback.
    Avx2,
}

impl std::str::FromStr for SimdBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(SimdBackend::Auto),
            "scalar" => Ok(SimdBackend::Scalar),
            "avx2" => Ok(SimdBackend::Avx2),
            other => Err(format!("unknown SIMD backend {other:?} (expected auto|scalar|avx2)")),
        }
    }
}

const UNRESOLVED: u8 = 0;
const SCALAR: u8 = 1;
const AVX2: u8 = 2;

/// The process-wide resolved backend (0 = not yet resolved). Relaxed
/// ordering is enough: both backends are bit-identical, so a racing
/// reader at worst takes the scalar path for one call — a perf nuance,
/// never a correctness one.
static ACTIVE: AtomicU8 = AtomicU8::new(UNRESOLVED);

#[inline]
fn active() -> u8 {
    match ACTIVE.load(Ordering::Relaxed) {
        UNRESOLVED => resolve(),
        b => b,
    }
}

#[cold]
fn resolve() -> u8 {
    let b = match std::env::var("TCN_SIMD").ok().as_deref() {
        Some("scalar") => SCALAR,
        Some("avx2") if avx2_available() => AVX2,
        // "auto", unset, unrecognized, or an unsatisfiable request all
        // fall through to detection — the CLI path (`set_backend`) is
        // the one with typed errors.
        _ => {
            if avx2_available() {
                AVX2
            } else {
                SCALAR
            }
        }
    };
    ACTIVE.store(b, Ordering::Relaxed);
    b
}

/// True when the host can execute the AVX2 backend.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Pin the backend for this process (the `--simd` flag). Requesting AVX2
/// on a host without it is an error — a measurement run must never
/// silently execute a different backend than the one it will be
/// attributed to. Returns the resolved backend name.
pub fn set_backend(req: SimdBackend) -> Result<&'static str, String> {
    let b = match req {
        SimdBackend::Scalar => SCALAR,
        SimdBackend::Avx2 => {
            if !avx2_available() {
                return Err("--simd avx2 requested but the host CPU lacks AVX2".to_string());
            }
            AVX2
        }
        SimdBackend::Auto => {
            if avx2_available() {
                AVX2
            } else {
                SCALAR
            }
        }
    };
    ACTIVE.store(b, Ordering::Relaxed);
    Ok(backend_name(b))
}

/// Name of the backend kernels are currently dispatching to — stamped
/// into `ServingReport`s and bench-ledger entries for attribution.
pub fn active_name() -> &'static str {
    backend_name(active())
}

fn backend_name(b: u8) -> &'static str {
    if b == AVX2 {
        "avx2"
    } else {
        "scalar"
    }
}

/// Fused ternary column dot + toggle count over the first `nwords` dense
/// words — the dispatch point behind [`TritCol::dot`].
#[inline]
pub fn col_dot(a: &TritCol, b: &TritCol, nwords: usize) -> (i32, u32) {
    #[cfg(target_arch = "x86_64")]
    if active() == AVX2 {
        // SAFETY: AVX2 is only ever selected after `avx2_available()`
        // confirmed the host feature.
        return unsafe { avx2::col_dot(a, b, nwords) };
    }
    col_dot_scalar(a, b, nwords)
}

/// Portable reference column dot (the pre-SIMD loop, verbatim).
#[inline]
pub fn col_dot_scalar(a: &TritCol, b: &TritCol, nwords: usize) -> (i32, u32) {
    let mut acc = 0i32;
    let mut toggles = 0u32;
    for w in 0..nwords {
        let (d, n) = word_dot(a.pos[w], a.mask[w], b.pos[w], b.mask[w]);
        acc += d;
        toggles += n;
    }
    (acc, toggles)
}

/// AVX2 column dot behind an availability check — `None` on hosts
/// without AVX2 (or non-x86 builds). The direct-call form the
/// equivalence tests and the bench A/B entries use, so neither has to
/// mutate the process-wide backend.
pub fn col_dot_avx2(a: &TritCol, b: &TritCol, nwords: usize) -> Option<(i32, u32)> {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return Some(unsafe { avx2::col_dot(a, b, nwords) });
    }
    let _ = (a, b, nwords);
    None
}

/// Threshold one accumulator row into (pos, mask) planes — the dispatch
/// point behind [`super::ternarize_packed`].
#[inline]
pub fn ternarize(acc: &[i32], lo: &[i32], hi: &[i32]) -> PackedVec {
    #[cfg(target_arch = "x86_64")]
    if active() == AVX2 {
        // SAFETY: see `col_dot`.
        return unsafe { avx2::ternarize(acc, lo, hi) };
    }
    ternarize_scalar(acc, lo, hi)
}

/// Portable reference ternarization (the pre-SIMD loop, verbatim).
#[inline]
pub fn ternarize_scalar(acc: &[i32], lo: &[i32], hi: &[i32]) -> PackedVec {
    let mut v = PackedVec::ZERO;
    for (i, &a) in acc.iter().enumerate() {
        let p = (a > hi[i]) as u64;
        let nz = p | ((a < lo[i]) as u64);
        v.pos[i / 64] |= p << (i % 64);
        v.mask[i / 64] |= nz << (i % 64);
    }
    v
}

/// AVX2 ternarization behind an availability check (see [`col_dot_avx2`]).
pub fn ternarize_avx2(acc: &[i32], lo: &[i32], hi: &[i32]) -> Option<PackedVec> {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return Some(unsafe { avx2::ternarize(acc, lo, hi) });
    }
    let _ = (acc, lo, hi);
    None
}

/// Channel-wise ternary max — the dispatch point behind
/// [`PackedVec::max`] (and with it the word maxpool).
#[inline]
pub fn vec_max(a: &PackedVec, b: &PackedVec) -> PackedVec {
    #[cfg(target_arch = "x86_64")]
    if active() == AVX2 {
        // SAFETY: see `col_dot`.
        return unsafe { avx2::vec_max(a, b) };
    }
    vec_max_scalar(a, b)
}

/// Portable reference ternary max (the pre-SIMD loop, verbatim).
#[inline]
pub fn vec_max_scalar(a: &PackedVec, b: &PackedVec) -> PackedVec {
    let mut out = PackedVec::ZERO;
    for w in 0..super::WORDS {
        let pos = a.pos[w] | b.pos[w];
        out.pos[w] = pos;
        out.mask[w] = pos | (a.mask[w] & b.mask[w]);
    }
    out
}

/// AVX2 ternary max behind an availability check (see [`col_dot_avx2`]).
pub fn vec_max_avx2(a: &PackedVec, b: &PackedVec) -> Option<PackedVec> {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        return Some(unsafe { avx2::vec_max(a, b) });
    }
    let _ = (a, b);
    None
}

/// Bulk (pos, mask) word copy — the `wrap_image` read-port primitive.
/// Panics when the slices differ in length (same contract as
/// `copy_from_slice`).
#[inline]
pub fn copy_words(dst: &mut [PackedVec], src: &[PackedVec]) {
    assert_eq!(dst.len(), src.len(), "copy_words length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active() == AVX2 {
        // SAFETY: see `col_dot`.
        unsafe { avx2::copy_words(dst, src) };
        return;
    }
    dst.copy_from_slice(src);
}

/// Bulk (pos, mask) word copy with the channel clamp fused in: each
/// copied word is `src[i].masked(n)` — the TCN memory's wrap-image /
/// packed-window read port, which presents hardware-width ring words as
/// `feat_ch`-wide ones while copying them out. Panics when the slices
/// differ in length.
#[inline]
pub fn copy_words_masked(dst: &mut [PackedVec], src: &[PackedVec], n: usize) {
    assert_eq!(dst.len(), src.len(), "copy_words_masked length mismatch");
    let keep = keep_planes(n);
    #[cfg(target_arch = "x86_64")]
    if active() == AVX2 {
        // SAFETY: see `col_dot`.
        unsafe { avx2::copy_words_masked(dst, src, &keep) };
        return;
    }
    for (d, s) in dst.iter_mut().zip(src) {
        *d = PackedVec {
            pos: [s.pos[0] & keep[0], s.pos[1] & keep[1]],
            mask: [s.mask[0] & keep[0], s.mask[1] & keep[1]],
        };
    }
}

/// Per-word keep masks equivalent to `PackedVec::masked(n)`: bits at
/// channel indices ≥ `n` clear, everything below survives.
#[inline]
fn keep_planes(n: usize) -> [u64; 2] {
    debug_assert!(n <= super::MAX_CHANNELS, "at most {} channels", super::MAX_CHANNELS);
    match n {
        0..=63 => [(1u64 << n) - 1, 0],
        64 => [u64::MAX, 0],
        65..=127 => [u64::MAX, (1u64 << (n - 64)) - 1],
        _ => [u64::MAX, u64::MAX],
    }
}

/// The AVX2 backend. Every function is `#[target_feature(enable =
/// "avx2")]` and must only be reached through the dispatchers above (or
/// the `_avx2` availability-checked wrappers).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    use super::super::{word_dot, PackedVec, TritCol};

    /// Σ popcount over the four u64 lanes of `v`: `vpshufb` nibble-table
    /// lookups summed with `vpsadbw` — the vector path never touches the
    /// scalar `popcnt` unit.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcount256(v: __m256i) -> u32 {
        #[rustfmt::skip]
        let table = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_shuffle_epi8(table, _mm256_and_si256(v, low));
        let hi = _mm256_shuffle_epi8(table, _mm256_and_si256(_mm256_srli_epi16(v, 4), low));
        let sums = _mm256_sad_epu8(_mm256_add_epi8(lo, hi), _mm256_setzero_si256());
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, sums);
        (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32
    }

    /// Four dense words per iteration (`vpand` + `vpxor` + table
    /// popcount), scalar `word_dot` tail for the ≤ 3 leftover words.
    /// Popcount sums are order-independent integers, so the result is
    /// bit-identical to the scalar loop.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn col_dot(a: &TritCol, b: &TritCol, nwords: usize) -> (i32, u32) {
        let mut acc = 0i32;
        let mut toggles = 0u32;
        let mut w = 0;
        while w + 4 <= nwords {
            let ap = _mm256_loadu_si256(a.pos.as_ptr().add(w) as *const __m256i);
            let am = _mm256_loadu_si256(a.mask.as_ptr().add(w) as *const __m256i);
            let bp = _mm256_loadu_si256(b.pos.as_ptr().add(w) as *const __m256i);
            let bm = _mm256_loadu_si256(b.mask.as_ptr().add(w) as *const __m256i);
            let nz = _mm256_and_si256(am, bm);
            let diff = _mm256_and_si256(nz, _mm256_xor_si256(ap, bp));
            let n = popcount256(nz);
            acc += n as i32 - 2 * popcount256(diff) as i32;
            toggles += n;
            w += 4;
        }
        while w < nwords {
            let (d, n) = word_dot(a.pos[w], a.mask[w], b.pos[w], b.mask[w]);
            acc += d;
            toggles += n;
            w += 1;
        }
        (acc, toggles)
    }

    /// Eight channels per iteration: two `vpcmpgtd` compares produce the
    /// +1 and non-zero lane masks, `vmovmskps` collapses each to 8 plane
    /// bits. Chunks are 8-aligned so a chunk never straddles a u64 word.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn ternarize(acc: &[i32], lo: &[i32], hi: &[i32]) -> PackedVec {
        let n = acc.len();
        let mut v = PackedVec::ZERO;
        let mut i = 0;
        while i + 8 <= n {
            let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            let l = _mm256_loadu_si256(lo.as_ptr().add(i) as *const __m256i);
            let h = _mm256_loadu_si256(hi.as_ptr().add(i) as *const __m256i);
            let p = _mm256_cmpgt_epi32(a, h);
            let nz = _mm256_or_si256(p, _mm256_cmpgt_epi32(l, a));
            let pb = _mm256_movemask_ps(_mm256_castsi256_ps(p)) as u32 as u64;
            let nzb = _mm256_movemask_ps(_mm256_castsi256_ps(nz)) as u32 as u64;
            v.pos[i / 64] |= pb << (i % 64);
            v.mask[i / 64] |= nzb << (i % 64);
            i += 8;
        }
        for j in i..n {
            let p = (acc[j] > hi[j]) as u64;
            let nz = p | ((acc[j] < lo[j]) as u64);
            v.pos[j / 64] |= p << (j % 64);
            v.mask[j / 64] |= nz << (j % 64);
        }
        v
    }

    /// One 256-bit op pair over the word layout `[pos0, pos1, mask0,
    /// mask1]`: `or` yields the pos planes, `vpermq` replays them over
    /// the mask lanes so `mask = pos | (a.mask & b.mask)` lands in a
    /// single blend.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vec_max(a: &PackedVec, b: &PackedVec) -> PackedVec {
        let aw = [a.pos[0], a.pos[1], a.mask[0], a.mask[1]];
        let bw = [b.pos[0], b.pos[1], b.mask[0], b.mask[1]];
        let av = _mm256_loadu_si256(aw.as_ptr() as *const __m256i);
        let bv = _mm256_loadu_si256(bw.as_ptr() as *const __m256i);
        let or = _mm256_or_si256(av, bv);
        let and = _mm256_and_si256(av, bv);
        // lanes [pos0, pos1, pos0, pos1]: pos replayed over the mask half
        let pos2 = _mm256_permute4x64_epi64::<0b01_00_01_00>(or);
        let res = _mm256_blend_epi32::<0b1111_0000>(or, _mm256_or_si256(and, pos2));
        let mut out = [0u64; 4];
        _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, res);
        PackedVec { pos: [out[0], out[1]], mask: [out[2], out[3]] }
    }

    /// Plane words moved through 128-bit vector loads/stores (`vmovdqu`
    /// under VEX) — the wrap-image word-copy primitive.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn copy_words(dst: &mut [PackedVec], src: &[PackedVec]) {
        for (d, s) in dst.iter_mut().zip(src) {
            let p = _mm_loadu_si128(s.pos.as_ptr() as *const __m128i);
            let m = _mm_loadu_si128(s.mask.as_ptr() as *const __m128i);
            _mm_storeu_si128(d.pos.as_mut_ptr() as *mut __m128i, p);
            _mm_storeu_si128(d.mask.as_mut_ptr() as *mut __m128i, m);
        }
    }

    /// `copy_words` with a broadcast channel clamp `vpand`-ed into every
    /// copied word pair — the wrap-image masked-copy primitive.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn copy_words_masked(dst: &mut [PackedVec], src: &[PackedVec], keep: &[u64; 2]) {
        let kv = _mm_loadu_si128(keep.as_ptr() as *const __m128i);
        for (d, s) in dst.iter_mut().zip(src) {
            let p = _mm_and_si128(_mm_loadu_si128(s.pos.as_ptr() as *const __m128i), kv);
            let m = _mm_and_si128(_mm_loadu_si128(s.mask.as_ptr() as *const __m128i), kv);
            _mm_storeu_si128(d.pos.as_mut_ptr() as *mut __m128i, p);
            _mm_storeu_si128(d.mask.as_mut_ptr() as *mut __m128i, m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trit::{ternarize, MAX_CHANNELS};
    use crate::util::rng::Rng;

    /// The width sweep from the satellite spec: word-boundary straddles
    /// on both the 2-word vectors and the up-to-6-word dense columns.
    const WIDTHS: [usize; 7] = [1, 21, 63, 64, 65, 96, 128];

    fn trits(rng: &mut Rng, n: usize, zf: f64) -> Vec<i8> {
        (0..n).map(|_| rng.trit(zf)).collect()
    }

    #[test]
    fn backend_parse_round_trip() {
        assert_eq!("auto".parse(), Ok(SimdBackend::Auto));
        assert_eq!("scalar".parse(), Ok(SimdBackend::Scalar));
        assert_eq!("avx2".parse(), Ok(SimdBackend::Avx2));
        let err = "sse9".parse::<SimdBackend>().unwrap_err();
        assert!(err.contains("sse9") && err.contains("auto|scalar|avx2"), "{err}");
    }

    #[test]
    fn avx2_col_dot_matches_scalar_across_widths_and_sparsities() {
        // Direct kernel-vs-kernel sweep (no global-backend mutation, so
        // it cannot race the rest of the multi-threaded test binary).
        let mut rng = Rng::new(41);
        for &cin in &WIDTHS {
            for case in 0..200 {
                let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
                let xp = [
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                ];
                let wp = [
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                    PackedVec::pack(&trits(&mut rng, cin, zf)),
                ];
                let xc = TritCol::pack_rows(&xp, cin);
                let wc = TritCol::pack_rows(&wp, cin);
                let nw = TritCol::words(cin);
                let want = col_dot_scalar(&wc, &xc, nw);
                assert_eq!(col_dot(&wc, &xc, nw), want, "dispatcher, cin {cin} case {case}");
                if let Some(got) = col_dot_avx2(&wc, &xc, nw) {
                    assert_eq!(got, want, "avx2, cin {cin} case {case}");
                }
            }
        }
    }

    #[test]
    fn avx2_ternarize_matches_scalar_across_widths() {
        let mut rng = Rng::new(42);
        for &n in &WIDTHS {
            for case in 0..100 {
                let acc: Vec<i32> = (0..n).map(|_| rng.below(41) as i32 - 20).collect();
                let (lo, hi): (Vec<i32>, Vec<i32>) = (0..n)
                    .map(|_| {
                        let hi = rng.below(9) as i32 - 4;
                        let lo = hi + 1 - rng.below(8) as i32;
                        (lo, hi)
                    })
                    .unzip();
                let want = ternarize_scalar(&acc, &lo, &hi);
                let scalar_ref: Vec<i8> =
                    (0..n).map(|i| ternarize(acc[i], lo[i], hi[i])).collect();
                assert_eq!(want.unpack(n), scalar_ref, "n {n} case {case}");
                if let Some(got) = ternarize_avx2(&acc, &lo, &hi) {
                    assert_eq!(got, want, "avx2, n {n} case {case}");
                    assert_eq!(got.pos[0] & !got.mask[0], 0);
                    assert_eq!(got.pos[1] & !got.mask[1], 0);
                }
            }
        }
    }

    #[test]
    fn avx2_max_and_copy_match_scalar() {
        let mut rng = Rng::new(43);
        for &n in &WIDTHS {
            for case in 0..100 {
                let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
                let a = PackedVec::pack(&trits(&mut rng, n, zf));
                let b = PackedVec::pack(&trits(&mut rng, n, zf));
                let want = vec_max_scalar(&a, &b);
                assert_eq!(vec_max(&a, &b), want, "dispatcher, n {n} case {case}");
                if let Some(got) = vec_max_avx2(&a, &b) {
                    assert_eq!(got, want, "avx2, n {n} case {case}");
                }
            }
        }
        let src: Vec<PackedVec> =
            (0..37).map(|_| PackedVec::pack(&trits(&mut rng, MAX_CHANNELS, 0.4))).collect();
        let mut dst = vec![PackedVec::ZERO; src.len()];
        copy_words(&mut dst, &src);
        assert_eq!(dst, src);
        for &n in WIDTHS.iter().chain(&[0]) {
            let want: Vec<PackedVec> = src.iter().map(|v| v.masked(n)).collect();
            copy_words_masked(&mut dst, &src, n);
            assert_eq!(dst, want, "masked copy, n {n}");
        }
    }

    #[test]
    fn backend_pinning_round_trip() {
        // The one test that touches the process-wide backend. Safe to
        // run alongside the rest of the suite: both backends produce
        // identical words, so concurrent readers only vary in speed.
        assert_eq!(set_backend(SimdBackend::Scalar).unwrap(), "scalar");
        assert_eq!(active_name(), "scalar");
        let auto = set_backend(SimdBackend::Auto).unwrap();
        assert_eq!(auto, if avx2_available() { "avx2" } else { "scalar" });
        assert_eq!(active_name(), auto);
        if !avx2_available() {
            assert!(set_backend(SimdBackend::Avx2).is_err());
        }
    }
}
