//! `tcn-cutie` — leader entrypoint/CLI for the TCN-CUTIE digital twin.
//!
//! Subcommands:
//!   info                         accelerator + calibration summary
//!   run    [--net M] [--voltage V] [--freq MHZ] run one inference + report
//!   serve  [--frames N] [--voltage V] [--streams K] multi-stream serving
//!   pack-weights [--net M|--synthetic DIR] convert `.ttn` v1 → packed v2
//!   golden [--net STEM]          co-simulate simulator vs PJRT artifact
//!   report table1|fig5|fig6|soa|sparsity|mapping|config|layers|all

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Context, Result};

use tcn_cutie::coordinator::source::NUM_CLASSES;
use tcn_cutie::coordinator::{
    DrainOrder, DvsSource, Engine, EngineConfig, Fleet, FleetConfig, FleetError, FrameSource,
    GestureClass, NetRegistry, PackedStream, Pipeline, PipelineConfig, ServingReport,
    SessionStore, ShardPolicy, SyntheticSource, DEFAULT_QUEUE_CAP,
};
use tcn_cutie::cutie::{CutieConfig, PreparedNet, Scheduler, SimMode};
use tcn_cutie::energy::{evaluate, EnergyParams};
use tcn_cutie::fault::{FaultPlan, FaultSurface};
use tcn_cutie::network::{cifar9_random, dvs_hybrid_random, loader, Network};
use tcn_cutie::report;
use tcn_cutie::runtime::{golden, Runtime};
use tcn_cutie::tensor::{ttn, TritTensor};
use tcn_cutie::util::cli::Args;
use tcn_cutie::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: tcn-cutie <info|run|serve|pack-weights|golden|report> [options]
  run    --net artifacts/cifar9_96.json --voltage 0.5 [--freq MHZ] [--seed N]
         [--simd auto|scalar|avx2]
  serve  --frames 32 --voltage 0.5 [--threaded|--batch N] [--gesture 0..11]
         [--streams K] [--replay FILE|--record FILE] [--net synthetic]
         [--simd auto|scalar|avx2] [--lanes K]
         [--fault-surface actmem|tcnmem|weightmem|dma|snapshot]
         [--fault-ber P | --fault-voltage V] [--fault-seed N]
         [--hibernate-after N] [--session-store FILE]
         [--engines N] [--shard-policy hash|least-loaded|pin]
         [--drain-order fifo|deadline|energy] [--queue-cap N]
         [--migrate-every K] [--resident-sessions B]
         [--workload NAME=MANIFEST ...] [--session-net round-robin|NAME]
  pack-weights --net MANIFEST [--out FILE] | --synthetic DIR [--seed N]
  golden --net cifar9_96
  report <table1|fig5|fig6|soa|sparsity|mapping|config|layers|all>

serve streams frames per session through the engine: session s uses
gesture (gesture+s) mod 12 and seed seed+s, or replays FILE (a packed
(pos, mask) word-stream; --record FILE captures one to replay).
--net synthetic serves the random-weight DVS hybrid network (no
artifacts needed).

--workload NAME=MANIFEST (repeatable) serves several networks from one
shared registry: each session binds exactly one net. --session-net
round-robin (the default) stripes sessions across the workloads in
registration order; --session-net NAME binds every session to that
workload. MANIFEST is a net manifest path, or `synthetic` /
`synthetic-cifar` for the random-weight DVS hybrid / cifar9 CNN.
Recurrent (TCN) workloads stream gesture frames; feed-forward ones get
dense synthetic frames matching their input geometry. The report gains
per-net rows when more than one net actually serves. --replay and
--record stay single-net.

--simd picks the packed-kernel backend: auto (the default) dispatches
to the AVX2 kernels when the host CPU has them and to the portable
scalar kernels otherwise; scalar forces the portable path (the
TCN_SIMD env var is the lower-precedence equivalent). Both backends
produce bit-identical words, counters and reports — the choice trades
wall-clock only, and every report/bench entry records which backend
ran. --lanes K batches up to K same-net, same-geometry sessions
through one CNN front-end invocation per drain (default 8, clamped to
8; 1 disables); reports stay byte-identical to serial serving.

--fault-ber P (explicit bit-error rate) or --fault-voltage V (rate the
SRAM model predicts at supply V, zero at/above 0.5 V) arms a
deterministic bit-flip plan on every session's chosen surface; the
report gains a per-session fault/scrub/quarantine summary.

--hibernate-after N snapshots a session into the state-retentive idle
tier once it sits idle through N consecutive drains (serving then walks
the streams one per round, so sessions actually idle); it resumes
bit-exactly on its next frame. --session-store FILE persists the
snapshots (CRC-guarded records, atomic rename) across serve
invocations; without it the store is in-memory.
--resident-sessions B caps how many sessions stay resident per engine:
past the budget, the least-recently-active sessions snapshot out even
if they were never idle.

--engines N shards the sessions across a fleet of N engines (all
adopting the one shared packed weight image), routed by --shard-policy;
--migrate-every K live-migrates one session to the next engine every K
rounds over the hibernation snapshot path — per-session and aggregate
reports stay byte-identical to --engines 1. A full engine submit queue
(--queue-cap, default 64) back-pressures: serve drains the fleet and
retries the returned frame. --drain-order picks the cross-session serve
order at each drain (per-session frame order always holds).

pack-weights upgrades a manifest's `.ttn` weights to the TTN2 container
(same bundle + a packed (pos, mask) weight-image section) in place, or
to --out FILE; --synthetic DIR first writes a random-weight DVS artifact
pair into DIR and packs that. run/serve boot word-for-word from packed
artifacts automatically.";

fn run() -> Result<()> {
    let args = Args::from_env(&["threaded", "json", "fast"]);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("info");
    match cmd {
        "info" => info(),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "pack-weights" => cmd_pack_weights(&args),
        "golden" => cmd_golden(&args),
        "report" => cmd_report(&args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn info() -> Result<()> {
    let cfg = CutieConfig::kraken();
    println!("TCN-CUTIE digital twin (Kraken SoC, GF 22FDX)");
    println!("  OCUs/channels      : {}", cfg.channels);
    println!("  max feature map    : {0}x{0}", cfg.max_hw);
    println!("  TCN memory         : {} steps = {} B SCM", cfg.tcn_depth, cfg.tcn_mem_bytes());
    println!("  activation memory  : {} KiB x2 (double-buffered)", cfg.act_mem_bytes() / 1024);
    println!("  peak datapath      : {} Op/cycle", cfg.hw_ops_per_cycle(cfg.channels));
    for v in [0.5, 0.7, 0.9] {
        let f = tcn_cutie::energy::fmax_hz(v)?;
        println!(
            "  fmax({v:.1} V)        : {:.0} MHz → {:.1} TOp/s peak",
            f / 1e6,
            cfg.hw_ops_per_cycle(96) as f64 * f / 1e12
        );
    }
    Ok(())
}

/// Default artifact path as a UTF-8 string — a non-UTF-8 artifacts
/// directory is a proper error, not a panic (PR 3 CLI-hardening pass).
fn default_net_path(file: &str) -> Result<String> {
    let p = loader::artifacts_dir().join(file);
    Ok(p.to_str()
        .with_context(|| format!("artifacts path {} is not valid UTF-8", p.display()))?
        .to_string())
}

/// Load a manifest and, when its weights file is a packed TTN2
/// container, the word-copy-deserialized prepared image.
fn load_net_and_image(manifest: &str) -> Result<(Network, Option<Arc<PreparedNet>>)> {
    let (net, image) =
        loader::load_network_full(manifest).with_context(|| format!("loading {manifest}"))?;
    let image = match image {
        Some(img) => {
            Some(Arc::new(PreparedNet::from_image(&img, &net, &CutieConfig::kraken())?))
        }
        None => None,
    };
    Ok((net, image))
}

/// Resolve `--simd auto|scalar|avx2` and pin the packed-kernel backend
/// before anything touches a kernel. Returns the resolved backend name
/// (what actually dispatches, never "auto").
fn apply_simd(args: &Args) -> Result<&'static str> {
    use tcn_cutie::trit::simd;
    let req = args.opt_parsed::<simd::SimdBackend>("simd")?.unwrap_or(simd::SimdBackend::Auto);
    simd::set_backend(req).map_err(|e| anyhow!(e))
}

fn cmd_run(args: &Args) -> Result<()> {
    apply_simd(args)?;
    let manifest = args.opt_or("net", &default_net_path("cifar9_96.json")?);
    let v = args.opt_f64("voltage", 0.5)?;
    let freq = args.opt_parsed::<f64>("freq")?.map(|mhz| mhz * 1e6);
    let seed = args.opt_u64("seed", 2)?;
    let mode = if args.flag("fast") { SimMode::Fast } else { SimMode::Accurate };

    let (net, image) = load_net_and_image(&manifest)?;
    let mut rng = Rng::new(seed);
    let input = if net.has_tcn() {
        TritTensor::random(&[net.tcn_steps, net.input_hw, net.input_hw, 2], &mut rng, 0.85)
    } else {
        TritTensor::random(&[net.input_hw, net.input_hw, 3], &mut rng, 0.3)
    };
    let mut sched = Scheduler::new(CutieConfig::kraken(), mode);
    if let Some(img) = image {
        sched.attach_image(img);
    }
    sched.preload_weights(&net);
    let (logits, stats) = sched.run_full(&net, &input)?;
    println!("net {}  predicted class {}", net.name, logits.argmax());
    println!("logits: {:?}", logits.data);
    let p = EnergyParams::default();
    let r = evaluate(&stats, v, freq, &p)?;
    report::print_energy_report("inference", &r);
    println!(
        "  cycles: {} total ({} compute, {} lb-fill, {} weights, {} dma)",
        stats.total_cycles(),
        stats.compute_cycles(),
        stats.layers.iter().map(|l| l.lb_fill_cycles).sum::<u64>(),
        stats.layers.iter().map(|l| l.weight_load_cycles).sum::<u64>(),
        stats.dma_cycles,
    );
    println!("  toggle rate: {:.3}", stats.toggle_rate());
    Ok(())
}

fn serve_net(args: &Args, seed: u64) -> Result<(Network, Option<Arc<PreparedNet>>)> {
    let manifest = args.opt_or("net", &default_net_path("dvs_hybrid_96.json")?);
    if manifest == "synthetic" {
        // random-weight DVS hybrid geometry — lets serving (and the CI
        // smoke) run without compiled artifacts
        return Ok((dvs_hybrid_random(96, seed, 0.5), None));
    }
    load_net_and_image(&manifest)
}

/// One `--workload NAME=MANIFEST` binding: the CLI alias and the
/// fingerprint its net registered under.
struct Workload {
    alias: String,
    fingerprint: u64,
}

/// Build the serving registry from every `--workload NAME=MANIFEST`
/// occurrence (in argv order — registration order is the round-robin
/// order). `Ok(None)` when no `--workload` was given (single-net
/// serving). Manifests `synthetic` / `synthetic-cifar` register the
/// artifact-free random-weight nets; anything else is a manifest path.
fn parse_workloads(args: &Args, seed: u64) -> Result<Option<(Arc<NetRegistry>, Vec<Workload>)>> {
    let specs = args.opt_all("workload");
    if specs.is_empty() {
        return Ok(None);
    }
    ensure!(args.opt("net").is_none(), "--workload and --net are mutually exclusive");
    let mut reg = NetRegistry::new();
    let mut workloads: Vec<Workload> = Vec::new();
    for s in specs {
        let (name, manifest) = s
            .split_once('=')
            .ok_or_else(|| anyhow!("invalid --workload value {s:?}: expected NAME=MANIFEST"))?;
        ensure!(!name.is_empty(), "invalid --workload value {s:?}: empty NAME");
        ensure!(!manifest.is_empty(), "invalid --workload value {s:?}: empty MANIFEST");
        ensure!(
            workloads.iter().all(|w| w.alias != name),
            "duplicate --workload name {name:?}"
        );
        let fingerprint = match manifest {
            "synthetic" => reg.add(dvs_hybrid_random(96, seed, 0.5))?,
            "synthetic-cifar" => reg.add(cifar9_random(96, seed, 0.33))?,
            path => {
                let (net, image) = load_net_and_image(path)?;
                match image {
                    Some(img) => reg.add_with_image(net, img)?,
                    None => reg.add(net)?,
                }
            }
        };
        workloads.push(Workload { alias: name.to_string(), fingerprint });
    }
    Ok(Some((Arc::new(reg), workloads)))
}

/// Resolve `--session-net` into one bound fingerprint per session:
/// `round-robin` (default) stripes sessions across the workloads in
/// registration order, a workload NAME binds every session to it.
fn session_bindings(args: &Args, workloads: &[Workload], streams: usize) -> Result<Vec<u64>> {
    match args.opt("session-net").unwrap_or("round-robin") {
        "round-robin" => {
            Ok((0..streams).map(|s| workloads[s % workloads.len()].fingerprint).collect())
        }
        name => {
            let w = workloads
                .iter()
                .find(|w| w.alias == name)
                .with_context(|| format!("--session-net {name:?} names no --workload"))?;
            Ok(vec![w.fingerprint; streams])
        }
    }
}

/// Per-net aggregate rows — only when more than one net actually
/// served, so single-workload output stays byte-identical.
fn print_net_rows(r: &ServingReport) {
    if r.nets.len() < 2 {
        return;
    }
    for n in &r.nets {
        println!(
            "  [net {} {:#018x}] {} sessions, {} frames, {} labels, core {:.2} µJ, \
             SoC {:.2} µJ, sim {:.3} ms",
            n.name,
            n.fingerprint,
            n.sessions,
            n.frames,
            n.labels,
            n.core_energy_j * 1e6,
            n.soc_energy_j * 1e6,
            n.sim_time_s * 1e3
        );
    }
}

fn print_report(tag: &str, r: &mut ServingReport) {
    println!("{tag}: {}", r.metrics.summary());
    println!(
        "  SoC energy {:.2} µJ  avg power {:.2} mW  FC wakeups {}",
        r.soc_energy_j * 1e6,
        r.soc_avg_power_w * 1e3,
        r.fc_wakeups
    );
    println!("  labels: {:?}", &r.labels[..r.labels.len().min(16)]);
    if r.faults.any() {
        let f = &r.faults;
        println!(
            "  faults: {} flips ({} detected), {} degraded frames, \
             scrub {}+{} words, {} retries, {} failures, {} quarantined, {} dropped",
            f.injected_flips,
            f.detected,
            f.degraded_frames,
            f.scrub_words,
            f.repair_words,
            f.retries,
            f.failures,
            f.quarantined,
            f.dropped_frames
        );
    }
    if r.hib.any() {
        let h = &r.hib;
        println!(
            "  hibernation: {} hibernates, {} resumes ({} corrupt), {} snapshot B, \
             retention {:.3} nJ / {} word-ticks, wake {:.3} nJ",
            h.hibernates,
            h.resumes,
            h.corrupt_resumes,
            h.snapshot_bytes,
            h.retention_j * 1e9,
            h.retention_word_ticks,
            h.wake_j * 1e9
        );
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let backend = apply_simd(args)?;
    let voltage = args.opt_f64("voltage", 0.5)?;
    let freq_hz = args.opt_parsed::<f64>("freq")?.map(|mhz| mhz * 1e6);
    if freq_hz.is_none() {
        // a sub-threshold supply with no explicit clock is a CLI error,
        // not a boot-time panic inside Engine::new
        tcn_cutie::energy::fmax_hz(voltage)?;
    }
    let frames = args.opt_usize("frames", 32)?;
    let seed = args.opt_u64("seed", 7)?;
    let gesture = args.opt_usize("gesture", 3)?;
    let streams = args.opt_usize("streams", 1)?;
    ensure!(streams >= 1, "--streams must be at least 1");
    ensure!(gesture < NUM_CLASSES, "--gesture must be 0..{}", NUM_CLASSES - 1);
    let mode = if args.flag("fast") { SimMode::Fast } else { SimMode::Accurate };
    let threaded = args.flag("threaded");
    // --batch N shards the CNN front-end across N workers (0 = one per
    // core); results are byte-identical to inline serving.
    let batch = args.opt_parsed::<usize>("batch")?;
    let replay = args.opt("replay");
    // --fault-*: arm a deterministic per-session bit-flip plan.
    let fault_surface =
        args.opt_parsed::<FaultSurface>("fault-surface")?.unwrap_or(FaultSurface::ActMem);
    let fault_seed = args.opt_u64("fault-seed", seed)?;
    let fault_ber = args.opt_parsed::<f64>("fault-ber")?;
    let fault_voltage = args.opt_parsed::<f64>("fault-voltage")?;
    let fault_plan = match (fault_ber, fault_voltage) {
        (Some(_), Some(_)) => bail!("--fault-ber and --fault-voltage are mutually exclusive"),
        (Some(b), None) => Some(FaultPlan::with_ber(fault_surface, b, fault_seed)),
        (None, Some(fv)) => Some(FaultPlan::at_voltage(fault_surface, fv, fault_seed)),
        (None, None) => None,
    };
    // --hibernate-after / --session-store / --resident-sessions: the
    // state-retentive idle tier (and its capacity budget).
    let hibernate_after = args.opt_parsed::<u64>("hibernate-after")?;
    let session_store = args.opt("session-store");
    let resident_sessions = args.opt_parsed::<usize>("resident-sessions")?;
    if let Some(b) = resident_sessions {
        ensure!(b >= 1, "--resident-sessions must be at least 1");
    }
    let hibernate =
        hibernate_after.is_some() || session_store.is_some() || resident_sessions.is_some();
    // --engines / --shard-policy / --drain-order / --queue-cap /
    // --migrate-every: the sharded serving fleet.
    let engines = args.opt_usize("engines", 1)?;
    ensure!(engines >= 1, "--engines must be at least 1");
    let shard_policy = args.opt_parsed::<ShardPolicy>("shard-policy")?.unwrap_or(ShardPolicy::Hash);
    let drain_order = args.opt_parsed::<DrainOrder>("drain-order")?.unwrap_or(DrainOrder::Fifo);
    let queue_cap = args.opt_usize("queue-cap", DEFAULT_QUEUE_CAP)?;
    ensure!(queue_cap >= 1, "--queue-cap must be at least 1");
    let migrate_every = args.opt_parsed::<usize>("migrate-every")?;
    if let Some(k) = migrate_every {
        ensure!(k >= 1, "--migrate-every must be at least 1");
    }
    // --lanes K: cross-session lane batching width for the CNN
    // front-end (clamped to the engine's 8-lane ceiling; 1 disables).
    let lanes = args.opt_usize("lanes", EngineConfig::default().lanes)?;
    ensure!(lanes >= 1, "--lanes must be at least 1");
    let fleet_mode = engines > 1 || migrate_every.is_some();
    if threaded && batch.is_some() {
        bail!("--threaded and --batch are mutually exclusive");
    }
    // --workload NAME=MANIFEST (repeatable): multi-net serving through
    // one shared registry. Always engine-path; replay/record stay
    // single-net (one recorded geometry can't feed every binding).
    let workloads = parse_workloads(args, seed)?;
    if workloads.is_none() && args.opt("session-net").is_some() {
        bail!("--session-net requires at least one --workload");
    }
    if workloads.is_some() {
        ensure!(replay.is_none(), "--replay is single-net; drop --workload to replay");
        ensure!(args.opt("record").is_none(), "--record is single-net; drop --workload");
    }
    let needs_engine = streams > 1
        || replay.is_some()
        || fault_plan.is_some()
        || hibernate
        || fleet_mode
        || workloads.is_some();
    if threaded && needs_engine {
        bail!("--threaded serves a single live stream; drop it or use --batch");
    }
    // Single-net serving resolves --net (or the default artifact path)
    // exactly as before; multi-workload boots from the registry alone
    // and never touches the default artifact path.
    let single = match &workloads {
        None => Some(serve_net(args, seed)?),
        Some(_) => None,
    };

    // --record FILE: capture the stream-0 gesture source as a replayable
    // packed word-stream (the µDMA payload twin), then serve as usual.
    if let Some(path) = args.opt("record") {
        let (net, _) = single.as_ref().expect("--record is single-net");
        let mut src = DvsSource::new(net.input_hw, seed, GestureClass(gesture));
        let stream = PackedStream::capture(&mut src, frames)?;
        stream.save(path)?;
        println!(
            "recorded {} frames ({} B/frame payload) -> {path}",
            stream.len(),
            stream.frame_payload_bytes()
        );
    }

    // Single gesture stream, no replay, no fault plan, no idle tier:
    // the classic topology policies (all thin wrappers over the same
    // engine path). A fault plan or hibernation always routes through
    // the engine, which owns the per-session injectors and the store.
    if workloads.is_none()
        && streams == 1
        && replay.is_none()
        && fault_plan.is_none()
        && !hibernate
        && !fleet_mode
    {
        let (net, image) = single.expect("single-net serving has a resolved net");
        let cfg = PipelineConfig {
            voltage,
            freq_hz,
            frames,
            seed,
            gesture,
            mode,
            ..Default::default()
        };
        let pipe = match image {
            Some(img) => Pipeline::with_image(net, cfg, img)?,
            None => Pipeline::new(net, cfg),
        };
        let (label, mut r) = if let Some(b) = batch {
            (format!("batched x{b}"), pipe.run_batched(b)?)
        } else if threaded {
            ("threaded".to_string(), pipe.run_threaded()?)
        } else {
            ("inline".to_string(), pipe.run_inline()?)
        };
        print_report(&format!("serving ({label}, simd {backend})"), &mut r);
        return Ok(());
    }

    // Everything below drives the engine; fold single-net serving into
    // a one-entry registry so multi-stream serving has exactly one path.
    let (registry, session_fp): (Arc<NetRegistry>, Vec<u64>) = match workloads {
        Some((registry, aliases)) => {
            let fps = session_bindings(args, &aliases, streams)?;
            (registry, fps)
        }
        None => {
            let (net, image) = single.expect("single-net serving has a resolved net");
            let reg = match image {
                Some(img) => NetRegistry::single_with_image(net, img)?,
                None => NetRegistry::single(net)?,
            };
            let fp = reg.default_fingerprint();
            (Arc::new(reg), vec![fp; streams])
        }
    };

    // Multi-stream (or replayed) serving: drive the engine directly.
    let replay_stream = match replay {
        Some(path) => {
            let ps = PackedStream::load(path)?;
            let net = registry.default_entry().net();
            ensure!(
                (ps.h, ps.w, ps.c) == (net.input_hw, net.input_hw, 2),
                "replay stream is {}x{}x{} but {} expects {}x{}x2 frames",
                ps.h,
                ps.w,
                ps.c,
                net.name,
                net.input_hw,
                net.input_hw
            );
            Some(ps)
        }
        None => None,
    };
    // Per-session sources follow the binding: recurrent (TCN) nets get
    // the gesture camera, feed-forward nets the dense synthetic
    // generator matching their input geometry.
    let mut sources: Vec<Box<dyn FrameSource>> = Vec::with_capacity(streams);
    for s in 0..streams {
        sources.push(match &replay_stream {
            // every session replays the same recorded payload
            Some(ps) => Box::new(ps.clone()) as Box<dyn FrameSource>,
            None => {
                let geom = registry.entry(session_fp[s])?.geometry();
                if geom.has_tcn {
                    Box::new(DvsSource::new(
                        geom.input_hw,
                        seed + s as u64,
                        GestureClass((gesture + s) % NUM_CLASSES),
                    )) as Box<dyn FrameSource>
                } else {
                    Box::new(SyntheticSource::new(geom.input_hw, geom.input_ch, seed + s as u64))
                }
            }
        });
    }

    // Sharded fleet serving: N engines behind one router, live
    // migrations every K rounds, byte-identical to --engines 1.
    if fleet_mode {
        ensure!(
            session_store.is_none(),
            "--session-store is single-engine; fleet engines use per-engine in-memory stores"
        );
        let fcfg = FleetConfig {
            engines,
            policy: shard_policy,
            order: drain_order,
            queue_cap,
            engine: EngineConfig { voltage, freq_hz, mode, workers: batch.unwrap_or(1), lanes },
        };
        let mut fleet = Fleet::with_registry(Arc::clone(&registry), fcfg)?;
        if hibernate {
            for e in 0..engines {
                let eng = fleet.engine_mut(e).expect("engine index in range");
                eng.enable_hibernation(SessionStore::in_memory(), hibernate_after);
                eng.set_resident_budget(resident_sessions)?;
            }
        }
        for sid in 0..streams {
            if shard_policy == ShardPolicy::Pin {
                // explicit placement: stripe the sessions across engines
                fleet.pin_session(sid, sid % engines)?;
            }
            fleet.open_session_on(sid, session_fp[sid])?;
            if let Some(plan) = fault_plan {
                fleet.set_fault_plan(sid, plan)?;
            }
        }
        let mut served = 0;
        for round in 0..frames {
            if hibernate_after.is_some() {
                let sid = round % streams;
                if let Some(f) = sources[sid].next_frame() {
                    served += fleet_submit(&mut fleet, sid, f)?;
                }
            } else {
                for (sid, src) in sources.iter_mut().enumerate() {
                    if let Some(f) = src.next_frame() {
                        served += fleet_submit(&mut fleet, sid, f)?;
                    }
                }
            }
            served += fleet.drain()?;
            // deterministic live migrations: every K rounds, move one
            // session to the next engine over the snapshot path
            if let Some(k) = migrate_every {
                if (round + 1) % k == 0 {
                    let sid = (round / k) % streams;
                    if let Some(from) = fleet.route(sid) {
                        fleet.migrate(sid, (from + 1) % engines)?;
                    }
                }
            }
        }
        let rep = fleet.report();
        println!(
            "serving (fleet: {engines} engines, {shard_policy} routing, {drain_order} drain, \
             {streams} streams, {served} frames, {} migrations, simd {backend})",
            rep.migrations
        );
        for l in &rep.engines {
            println!(
                "fleet engine[{}]: {} routed, {} resident, {} hibernated, peak queue {}, \
                 {} submitted, {} served, {} rejected, migrations in/out {}/{}",
                l.engine,
                l.routed_sessions,
                l.resident_sessions,
                l.hibernated_sessions,
                l.peak_queue_depth,
                l.submitted,
                l.served,
                l.rejected,
                l.migrations_in,
                l.migrations_out
            );
        }
        let mut agg = rep.aggregate;
        for (sid, mut r) in fleet.finish_all() {
            print_report(&format!("  [session {sid}]"), &mut r);
        }
        print_report("aggregate", &mut agg);
        print_net_rows(&agg);
        fleet.sync_stores()?;
        return Ok(());
    }

    let ecfg = EngineConfig { voltage, freq_hz, mode, workers: batch.unwrap_or(1), lanes };
    let pool = ecfg.workers;
    let mut engine = Engine::with_registry(Arc::clone(&registry), ecfg)?;
    if hibernate {
        let store = match session_store {
            Some(path) => SessionStore::open(path)?,
            None => SessionStore::in_memory(),
        };
        if store.recovered_torn() {
            println!("session store: recovered a torn tail (incomplete final record dropped)");
        }
        engine.enable_hibernation(store, hibernate_after);
        engine.set_resident_budget(resident_sessions)?;
    }
    // deterministic round-robin interleave across sessions
    for sid in 0..streams {
        engine.open_session_on(sid, session_fp[sid])?;
        if let Some(plan) = fault_plan {
            engine.set_fault_plan(sid, plan)?;
        }
    }
    // Drain each round-robin round: memory stays bounded to one frame
    // per stream and wall latency gets a sample per round (the engine's
    // determinism tests prove reports are drain-cadence-invariant).
    // With an idle tier armed, walk the streams one per round instead —
    // round-robin keeps every session busy every drain and nothing
    // would ever idle long enough to hibernate.
    let mut served = 0;
    for round in 0..frames {
        if hibernate_after.is_some() {
            let sid = round % streams;
            if let Some(f) = sources[sid].next_frame() {
                engine.submit(sid, f)?;
            }
        } else {
            for (sid, src) in sources.iter_mut().enumerate() {
                if let Some(f) = src.next_frame() {
                    engine.submit(sid, f)?;
                }
            }
        }
        served += engine.drain()?;
    }
    println!(
        "serving (engine: {streams} streams, {} workers, {served} frames{}, simd {backend})",
        if pool == 0 { "auto".to_string() } else { pool.to_string() },
        if replay_stream.is_some() { ", replayed" } else { "" }
    );
    let mut agg = engine.aggregate_report();
    for (sid, mut r) in engine.finish_all() {
        print_report(&format!("  [session {sid}]"), &mut r);
    }
    print_report("aggregate", &mut agg);
    print_net_rows(&agg);
    // finishing consumed every stored snapshot; persist the (now empty)
    // store so a later invocation reopens a consistent file
    engine.sync_store()?;
    Ok(())
}

/// Submit one frame to the fleet, absorbing back-pressure: a refused
/// submit hands the frame back untouched, so drain the fleet and retry
/// it. Returns the number of frames the forced drain served (0 on the
/// happy path). Any non-back-pressure refusal is a real routing error.
fn fleet_submit(
    fleet: &mut Fleet,
    sid: usize,
    frame: tcn_cutie::tensor::PackedMap,
) -> Result<usize> {
    match fleet.submit(sid, frame) {
        Ok(()) => Ok(0),
        Err(rej) => match rej.reason {
            FleetError::Backpressure { .. } => {
                let served = fleet.drain()?;
                fleet
                    .submit(sid, rej.frame)
                    .map_err(|r| anyhow::anyhow!("resubmit after forced drain refused: {r}"))?;
                Ok(served)
            }
            other => bail!("routing session {sid}: {other}"),
        },
    }
}

/// Convert a manifest's `.ttn` weights to the packed TTN2 container:
/// the original bundle bytes verbatim plus the (pos, mask) weight-image
/// section the word-copy boot path consumes. The conversion is verified
/// in memory before anything touches disk: v2 → v1 must strip back
/// bit-exactly, and the word-copy reload must equal the i8-built image.
fn cmd_pack_weights(args: &Args) -> Result<()> {
    let manifest = if let Some(dir) = args.opt("synthetic") {
        // write a random-weight DVS artifact pair first, then pack it —
        // the artifact-free path the CI smoke uses
        let net = dvs_hybrid_random(96, args.opt_u64("seed", 7)?, 0.5);
        let (manifest, weights) = loader::save_network(dir, "dvs_synth", &net)?;
        println!("wrote synthetic artifact: {} + {}", manifest.display(), weights.display());
        manifest
            .to_str()
            .with_context(|| format!("path {} is not valid UTF-8", manifest.display()))?
            .to_string()
    } else {
        args.opt("net")
            .map(str::to_string)
            .context("pack-weights needs --net MANIFEST or --synthetic DIR")?
    };

    let (net, existing) = loader::load_network_full(&manifest)?;
    if existing.is_some() {
        println!("{manifest}: weights are already packed (TTN2)");
        return Ok(());
    }
    let wpath = loader::weights_path(&manifest)?;
    let v1 = std::fs::read(&wpath).with_context(|| format!("reading {}", wpath.display()))?;

    let cfg = CutieConfig::kraken();
    let prepared = PreparedNet::new(&net, &cfg);
    let image = prepared.to_image();
    let v2 = ttn::upgrade_bytes(&v1, &image)?;
    ensure!(ttn::strip_bytes(&v2)? == v1, "v2 → v1 strip is not bit-exact");
    let (_, img_back) = ttn::read_bytes_full(&v2)?;
    let reloaded =
        PreparedNet::from_image(&img_back.context("image section missing")?, &net, &cfg)?;
    ensure!(reloaded == prepared, "word-copy reload differs from the i8-built image");

    let out = match args.opt("out") {
        Some(p) => p.to_string(),
        None => wpath
            .to_str()
            .with_context(|| format!("path {} is not valid UTF-8", wpath.display()))?
            .to_string(),
    };
    std::fs::write(&out, &v2).with_context(|| format!("writing {out}"))?;
    println!(
        "packed {} layer records for '{}' ({} B TTN1 -> {} B TTN2, image {:016x}) -> {}",
        image.layers.len(),
        net.name,
        v1.len(),
        v2.len(),
        prepared.fingerprint(),
        out
    );
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let stem = args.opt_or("net", "cifar9_96");
    let dir = loader::artifacts_dir();
    let net = loader::load_network(dir.join(format!("{stem}.json")))?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = Rng::new(args.opt_u64("seed", 1)?);
    let check = if net.has_tcn() {
        let cnn = rt.load(dir.join(format!("{stem}_cnn.hlo.txt")))?;
        let tcn = rt.load(dir.join(format!("{stem}_tcn.hlo.txt")))?;
        let frames = TritTensor::random(&[5, net.input_hw, net.input_hw, 2], &mut rng, 0.85);
        golden::check_hybrid(&cnn, &tcn, &net, &frames)?
    } else {
        let model = rt.load(dir.join(format!("{stem}.hlo.txt")))?;
        let input = TritTensor::random(&[net.input_hw, net.input_hw, 3], &mut rng, 0.3);
        golden::check_feedforward(&rt, &model, &net, &input)?
    };
    println!("simulator logits: {:?}", check.sim_logits);
    println!("XLA logits:       {:?}", check.xla_logits);
    if check.matched {
        println!("co-simulation MATCH");
        Ok(())
    } else {
        bail!("co-simulation MISMATCH")
    }
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let all = what == "all";
    if all || what == "table1" {
        println!("\n== Table 1: SoA comparison (CIFAR-10, 9-layer CNN) ==");
        report::table1()?.print();
    }
    if all || what == "fig5" {
        println!("\n== Figure 5: energy/inference + inf/s vs voltage ==");
        report::fig5_table(&report::fig5()?).print();
    }
    if all || what == "fig6" {
        println!("\n== Figure 6: peak efficiency + throughput vs voltage (CIFAR L1) ==");
        report::fig6_table(&report::fig6()?).print();
    }
    if all || what == "soa" {
        let s = report::soa()?;
        println!("\n== §8 comparisons ==");
        println!("  our DVS inference      : {:.2} µJ", s.our_dvs_uj);
        println!("  our energy/op          : {:.3} pJ", s.our_energy_per_op_pj);
        println!(
            "  TCN-KWS [10] energy/op : {:.3} pJ → {:.1}x ours (paper: 5-15x)",
            s.kws_energy_per_op_pj, s.kws_ratio
        );
        println!("  TrueNorth [2] ratio    : {:.0}x (paper: 3250x)", s.truenorth_ratio);
        println!("  Loihi [11] ratio       : {:.1}x (paper: 63.4x)", s.loihi_ratio);
    }
    if all || what == "sparsity" {
        println!("\n== A1: sparsity ablation ([1]: ~36% energy reduction) ==");
        let mut t = tcn_cutie::util::bench::Table::new(&["zero frac", "µJ/inf", "toggle rate"]);
        for pt in report::sparsity_sweep(&[0.0, 0.2, 0.33, 0.5, 0.7, 0.9])? {
            t.row(&[
                format!("{:.2}", pt.zero_frac),
                format!("{:.2}", pt.energy_uj),
                format!("{:.3}", pt.toggle_rate),
            ]);
        }
        t.print();
    }
    if all || what == "layers" {
        println!("\n== per-layer breakdown (CIFAR-9/96 @0.5 V) ==");
        report::layer_breakdown()?.print();
    }
    if all || what == "config" {
        println!("\n== A3: CUTIE configuration width ==");
        let mut t = tcn_cutie::util::bench::Table::new(&["channels", "µJ/inf", "peak TOp/s", "peak TOp/s/W"]);
        for p in report::config_sweep(&[48, 96, 128])? {
            t.row(&[
                p.channels.to_string(),
                format!("{:.2}", p.energy_uj),
                format!("{:.1}", p.peak_tops),
                format!("{:.0}", p.peak_tops_w),
            ]);
        }
        t.print();
    }
    if all || what == "mapping" {
        println!("\n== A2: §4 mapping vs direct strided TCN execution ==");
        let a = report::mapping_ablation()?;
        println!(
            "  mapped: {} cycles ({} stalls), {:.3} µJ",
            a.mapped_tcn_cycles, a.mapped_stalls, a.mapped_tcn_uj
        );
        println!(
            "  direct: {} cycles ({} stalls), {:.3} µJ",
            a.direct_tcn_cycles, a.direct_stalls, a.direct_tcn_uj
        );
        println!(
            "  mapping wins: {:.2}x cycles, {:.2}x energy",
            a.direct_tcn_cycles as f64 / a.mapped_tcn_cycles as f64,
            a.direct_tcn_uj / a.mapped_tcn_uj
        );
    }
    Ok(())
}
