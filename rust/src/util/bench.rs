//! Micro-benchmark harness (criterion is unavailable offline). Used by all
//! `benches/*.rs` (harness = false) and the performance pass: warmup,
//! timed iterations, median + MAD, and simple aligned table output for the
//! paper-table reproductions.

use std::time::Instant;

use super::stats::median_mad;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>12} /iter (±{}, n={})",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mad_s),
            self.iters
        );
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with `warmup` unrecorded and `iters` timed iterations.
/// The closure's return value is black-boxed to keep the work alive.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (median_s, mad_s) = median_mad(&times);
    let r = BenchResult { name: name.to_string(), iters, median_s, mad_s };
    r.report();
    r
}

/// Identity that the optimizer must assume is opaque.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for paper-table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:<w$} ", c, w = widths[i]));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.median_s > 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".to_string()]);
    }
}
