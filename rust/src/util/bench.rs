//! Micro-benchmark harness (criterion is unavailable offline). Used by all
//! `benches/*.rs` (harness = false) and the performance pass: warmup,
//! timed iterations, median + MAD, simple aligned table output for the
//! paper-table reproductions, and the machine-readable [`BenchSuite`]
//! ledger (`BENCH_*.json`) tracking the perf trajectory across PRs.

use std::collections::BTreeMap;
use std::time::Instant;

use super::json::Json;
use super::stats::median_mad;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>12} /iter (±{}, n={})",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mad_s),
            self.iters
        );
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with `warmup` unrecorded and `iters` timed iterations.
/// The closure's return value is black-boxed to keep the work alive.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (median_s, mad_s) = median_mad(&times);
    let r = BenchResult { name: name.to_string(), iters, median_s, mad_s };
    r.report();
    r
}

/// Identity that the optimizer must assume is opaque.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

struct SuiteEntry {
    name: String,
    median_s: f64,
    mad_s: f64,
    iters: usize,
    /// Speedup over the recorded baseline (baseline.median / this.median).
    speedup: Option<f64>,
    /// The packed-kernel backend active when the entry was pushed
    /// ("scalar" or "avx2") — lets the CI regression checker compare
    /// ledger entries like-for-like across hosts.
    backend: &'static str,
}

/// Machine-readable bench ledger: collects [`BenchResult`]s (optionally
/// with a speedup against a named baseline run) and serializes them to
/// the `BENCH_*.json` files CI archives, so the perf trajectory is
/// comparable across PRs (EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct BenchSuite {
    entries: Vec<SuiteEntry>,
}

impl BenchSuite {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a result, stamped with the backend active right now.
    pub fn push(&mut self, r: &BenchResult) {
        self.entries.push(SuiteEntry {
            name: r.name.clone(),
            median_s: r.median_s,
            mad_s: r.mad_s,
            iters: r.iters,
            speedup: None,
            backend: crate::trit::simd::active_name(),
        });
    }

    /// Record a result together with its speedup over `baseline`.
    pub fn push_speedup(&mut self, r: &BenchResult, baseline: &BenchResult) {
        self.entries.push(SuiteEntry {
            name: r.name.clone(),
            median_s: r.median_s,
            mad_s: r.mad_s,
            iters: r.iters,
            speedup: Some(baseline.median_s / r.median_s),
            backend: crate::trit::simd::active_name(),
        });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let benches: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("median_s".to_string(), Json::Float(e.median_s));
                m.insert("mad_s".to_string(), Json::Float(e.mad_s));
                m.insert("iters".to_string(), Json::Int(e.iters as i64));
                m.insert("backend".to_string(), Json::Str(e.backend.to_string()));
                if let Some(s) = e.speedup {
                    m.insert("speedup".to_string(), Json::Float(s));
                }
                Json::Object(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("benches".to_string(), Json::Array(benches));
        Json::Object(root)
    }

    /// Write the suite as pretty-printed JSON; returns the serialized
    /// text (also useful for asserting in tests).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<String> {
        let text = self.to_json().to_string_pretty(2);
        std::fs::write(path, &text)?;
        Ok(text)
    }
}

/// Aligned table printer for paper-table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:<w$} ", c, w = widths[i]));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.median_s > 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".to_string()]);
    }

    #[test]
    fn suite_serializes_names_medians_and_speedups() {
        let base = BenchResult { name: "base".into(), iters: 5, median_s: 0.2, mad_s: 0.01 };
        let fast = BenchResult { name: "fast".into(), iters: 5, median_s: 0.05, mad_s: 0.002 };
        let mut suite = BenchSuite::new();
        suite.push(&base);
        suite.push_speedup(&fast, &base);
        assert_eq!(suite.len(), 2);
        let text = suite.to_json().to_string_pretty(2);
        assert!(text.contains("\"name\": \"fast\""));
        assert!(text.contains("\"median_s\""));
        assert!(text.contains("\"speedup\": 4"));
        // parse back and check the speedup value numerically
        let j = crate::util::json::Json::parse(&text).unwrap();
        let benches = j.get("benches").unwrap().as_array().unwrap();
        assert_eq!(benches.len(), 2);
        assert!(benches[0].get("speedup").is_none());
        // every entry is stamped with the resolved kernel backend
        for b in benches {
            let tag = b.get("backend").unwrap().as_str().unwrap();
            assert!(tag == "scalar" || tag == "avx2", "backend tag {tag:?}");
        }
        let s = benches[1].get("speedup").unwrap().as_f64().unwrap();
        assert!((s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn suite_writes_json_file() {
        let r = BenchResult { name: "x".into(), iters: 1, median_s: 1e-3, mad_s: 0.0 };
        let mut suite = BenchSuite::new();
        suite.push(&r);
        let path = std::env::temp_dir().join("tcn_cutie_bench_suite_test.json");
        let text = suite.write_json(&path).unwrap();
        let on_disk = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(text, on_disk);
        assert!(crate::util::json::Json::parse(&on_disk).is_ok());
    }
}
