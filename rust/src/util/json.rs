//! Minimal JSON: enough for network manifests, configs and report output.
//! Not a general-purpose library: UTF-8 only, no \u surrogate pairs, i64 +
//! f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize; `indent > 0` pretty-prints.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, indent, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Float(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: usize, depth: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            text.parse::<f64>().map(Json::Float).map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>().map(Json::Int).map_err(|_| self.err("bad number"))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"name": "net", "layers": [{"kind": "conv2d", "pool": true,
                      "in_ch": 3}], "classes": 10, "scale": 1.5e-3}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("net"));
        assert_eq!(j.get("classes").unwrap().as_i64(), Some(10));
        let layers = j.get("layers").unwrap().as_array().unwrap();
        assert_eq!(layers[0].get("pool").unwrap().as_bool(), Some(true));
        assert!((j.get("scale").unwrap().as_f64().unwrap() - 1.5e-3).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\n",null,true],"b":{"c":-7}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty(0)).unwrap();
        assert_eq!(j, again);
        let pretty = Json::parse(&j.to_string_pretty(2)).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é\t""#).unwrap();
        assert_eq!(j.as_str(), Some("é\t"));
    }
}
