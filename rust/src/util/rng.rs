//! Deterministic PRNG (xoshiro256** seeded via splitmix64). Every random
//! quantity in the simulator, workload generators and property tests flows
//! through this so runs are exactly reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free for our (non-cryptographic) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i32
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Random trit with P(0) = zero_frac, +/-1 equiprobable otherwise.
    pub fn trit(&mut self, zero_frac: f64) -> i8 {
        if self.f64() < zero_frac {
            0
        } else if self.bool(0.5) {
            1
        } else {
            -1
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fork a child generator (for parallel deterministic streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw xoshiro state, for exact-position checkpointing (session
    /// hibernation snapshots the armed fault injector mid-stream).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact saved position: the next draw
    /// equals what the snapshotted generator would have drawn.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn trit_sparsity() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let zeros = (0..n).filter(|_| r.trit(0.4) == 0).count();
        let frac = zeros as f64 / n as f64;
        assert!((frac - 0.4).abs() < 0.02, "zero fraction {frac}");
    }

    #[test]
    fn state_round_trip_resumes_mid_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
