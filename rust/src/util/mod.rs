//! Self-contained substrates for the offline build environment (crates.io
//! is unreachable here; see DESIGN.md §3): a minimal JSON parser/emitter, a
//! deterministic PRNG, a CLI argument parser, a micro-benchmark harness and
//! small statistics helpers.

pub mod bench;
pub mod cli;
pub mod crc;
pub mod json;
pub mod rng;
pub mod stats;
