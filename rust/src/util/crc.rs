//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) over byte
//! slices. The snapshot store uses it as the per-record integrity check:
//! a single-bit error anywhere in a record is guaranteed detected, and
//! burst errors up to 32 bits likewise — exactly the corruption classes
//! the snapshot fault surface injects. Table built in a `const fn` so
//! there is no runtime init and no dependency (crates.io is unreachable
//! in this build environment).

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (init 0xFFFF_FFFF, final xor 0xFFFF_FFFF — the
/// standard zlib/PNG/Ethernet parameterization).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value for this parameterization.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let payload: Vec<u8> = (0u16..64).map(|i| (i * 37 % 256) as u8).collect();
        let clean = crc32(&payload);
        for byte in 0..payload.len() {
            for bit in 0..8 {
                let mut dirty = payload.clone();
                dirty[byte] ^= 1 << bit;
                assert_ne!(crc32(&dirty), clean, "flip at byte {byte} bit {bit} undetected");
            }
        }
    }
}
