//! Small statistics helpers shared by the bench harness and the
//! coordinator's latency metrics.

/// Online latency histogram with exact percentiles (stores samples; fine at
/// the request rates the serving example produces).
#[derive(Debug, Default, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// q in [0, 1]; nearest-rank.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(!self.samples.is_empty());
        self.ensure_sorted();
        let idx = ((self.samples.len() as f64 - 1.0) * q).round() as usize;
        self.samples[idx]
    }

    /// Merge another histogram's samples. Percentiles over the union do
    /// not depend on the merge order (the set is re-sorted on query).
    pub fn absorb(&mut self, other: &Percentiles) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }

    /// The raw sample set in insertion-or-sorted order (whichever the
    /// histogram currently holds). Quantiles depend only on the multiset,
    /// so round-tripping through [`Percentiles::from_samples`] preserves
    /// every quantile bit-exactly.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Rebuild a histogram from a saved sample set.
    pub fn from_samples(samples: Vec<f64>) -> Percentiles {
        Percentiles { samples, sorted: false }
    }
}

/// Median and median-absolute-deviation of a sample set.
pub fn median_mad(samples: &[f64]) -> (f64, f64) {
    assert!(!samples.is_empty());
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    let med = s[s.len() / 2];
    let mut devs: Vec<f64> = s.iter().map(|x| (x - med).abs()).collect();
    devs.sort_by(f64::total_cmp);
    (med, devs[devs.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_basic() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.record(i as f64);
        }
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        let p50 = p.quantile(0.5);
        assert!((49.0..=51.0).contains(&p50));
        assert!((p.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn absorb_merges_sample_sets() {
        let mut a = Percentiles::new();
        let mut b = Percentiles::new();
        for i in 1..=50 {
            a.record(i as f64);
            b.record((i + 50) as f64);
        }
        a.absorb(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.quantile(0.0), 1.0);
        assert_eq!(a.quantile(1.0), 100.0);
    }

    #[test]
    fn median_mad_basic() {
        let (m, d) = median_mad(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(m, 3.0);
        assert_eq!(d, 1.0); // robust to the outlier
    }
}
