//! Tiny CLI argument parser (clap is unavailable offline): positional
//! subcommand + `--key value` options + `--flag` booleans. Typed option
//! accessors return `Result` — malformed values (`--freq zap`) surface
//! as proper CLI errors, never panics.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Every `--key value` occurrence in argv order. `options` keeps only
    /// the last value per key; repeatable options (`--workload A=.. --workload
    /// B=..`) read all of them via [`Args::opt_all`].
    pub pairs: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse; `flag_names` lists options that take no value.
    pub fn parse(argv: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    out.pairs.push((k.to_string(), v.to_string()));
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(name.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.options.insert(name.to_string(), v.clone());
                        out.pairs.push((name.to_string(), v));
                    }
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Parse `--key value` into any `FromStr` type; `Ok(None)` when the
    /// option is absent, `Err` (naming the flag and the offending value)
    /// when it does not parse.
    pub fn opt_parsed<T>(&self, key: &str) -> Result<Option<T>>
    where
        T: std::str::FromStr,
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("invalid --{key} value {s:?}: {e}")),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.opt_parsed(key)?.unwrap_or(default))
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.opt_parsed(key)?.unwrap_or(default))
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.opt_parsed(key)?.unwrap_or(default))
    }

    /// Every value given for a repeatable `--key`, in argv order.
    pub fn opt_all(&self, key: &str) -> Vec<&str> {
        self.pairs.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    /// Parse every occurrence of a repeatable `--key value` into a
    /// `FromStr` type; the first malformed value is the error.
    pub fn opt_all_parsed<T>(&self, key: &str) -> Result<Vec<T>>
    where
        T: std::str::FromStr,
        T::Err: std::fmt::Display,
    {
        self.opt_all(key)
            .into_iter()
            .map(|s| s.parse::<T>().map_err(|e| anyhow!("invalid --{key} value {s:?}: {e}")))
            .collect()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            argv(&["report", "table1", "--voltage", "0.5", "--json", "--seed=7"]),
            &["json"],
        );
        assert_eq!(a.positional, vec!["report", "table1"]);
        assert_eq!(a.opt("voltage"), Some("0.5"));
        assert_eq!(a.opt("seed"), Some("7"));
        assert!(a.flag("json"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(argv(&["run", "--check"]), &[]);
        assert!(a.flag("check"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(&[]), &[]);
        assert_eq!(a.opt_f64("voltage", 0.5).unwrap(), 0.5);
        assert_eq!(a.opt_usize("n", 3).unwrap(), 3);
    }

    #[test]
    fn repeated_options_keep_every_value_in_order() {
        let a = Args::parse(
            argv(&["serve", "--workload", "dvs=synthetic", "--workload=cif=synthetic-cifar"]),
            &[],
        );
        // BTreeMap keeps only the last value; pairs keep them all, ordered.
        assert_eq!(a.opt("workload"), Some("cif=synthetic-cifar"));
        assert_eq!(a.opt_all("workload"), ["dvs=synthetic", "cif=synthetic-cifar"]);
        assert!(a.opt_all("net").is_empty());
        let n: Vec<u64> = Args::parse(argv(&["x", "--n", "3", "--n", "5"]), &[])
            .opt_all_parsed("n")
            .unwrap();
        assert_eq!(n, [3, 5]);
        let e = Args::parse(argv(&["x", "--n", "3", "--n", "zap"]), &[])
            .opt_all_parsed::<u64>("n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("--n") && e.contains("zap"), "got: {e}");
    }

    #[test]
    fn malformed_values_are_errors_not_panics() {
        let a = Args::parse(argv(&["run", "--freq", "zap", "--frames", "-3"]), &[]);
        let e = a.opt_parsed::<f64>("freq").unwrap_err().to_string();
        assert!(e.contains("--freq") && e.contains("zap"), "got: {e}");
        assert!(a.opt_f64("freq", 100.0).is_err());
        assert!(a.opt_usize("frames", 1).is_err(), "negative usize must not parse");
        // absent keys still fall back to defaults
        assert_eq!(a.opt_u64("seed", 9).unwrap(), 9);
    }
}
