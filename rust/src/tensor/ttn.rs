//! `.ttn` binary interchange reader/writer — the Rust half of
//! `python/compile/ttn.py`. All little-endian.
//!
//! Two container versions coexist:
//!
//! * **TTN1** — the original tensor bundle (named i8-trit / i32
//!   tensors); the format of `python/compile/aot.py` artifacts.
//! * **TTN2** — the same bundle body byte-for-byte, followed by a
//!   **packed weight-image section** (`WIMG`): per prepared layer the
//!   (pos, mask) u64 plane words in the exact layout the OCU weight
//!   buffers (and [`crate::cutie`]'s `PreparedLayer` / `PreparedDense`)
//!   hold, plus dims/flags/thresholds. Boot from a TTN2 file is a
//!   word-copy deserialization — no i8 re-packing (see
//!   EXPERIMENTS.md §Weights for the format spec and the boot-cost
//!   A/B). `tcn-cutie pack-weights` converts v1 → v2;
//!   [`strip_bytes`] is the exact inverse, so v1 ⇄ v2 round-trips
//!   bit-exactly.
//!
//! Parsing is hardened against hostile input (truncation, bit flips,
//! forged length prefixes): every length is bounds-checked against the
//! remaining buffer *before* any allocation, element counts use checked
//! arithmetic, and the plane words are validated against the
//! `pos ⊆ mask` and channel-width invariants the dot kernels rely on.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::trit::{PackedVec, MAX_CHANNELS};

use super::{IntTensor, TritTensor};

pub const MAGIC: u32 = 0x314E5454; // "TTN1"
pub const MAGIC2: u32 = 0x324E5454; // "TTN2" = TTN1 bundle + packed weight image
const IMG_MAGIC: u32 = 0x474D4957; // "WIMG"

/// Caps applied while parsing the weight-image section so a forged
/// count can never drive an oversized allocation or loop.
const MAX_IMG_LAYERS: usize = 4096;
const MAX_KERNEL: usize = 16;
const MAX_DENSE_FANIN: usize = 1 << 20;

#[derive(Debug, Clone)]
pub enum Tensor {
    Trit(TritTensor),
    Int(IntTensor),
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::Trit(t) => &t.dims,
            Tensor::Int(t) => &t.dims,
        }
    }

    pub fn as_trit(&self) -> Result<&TritTensor> {
        match self {
            Tensor::Trit(t) => Ok(t),
            Tensor::Int(_) => bail!("expected trit tensor, found i32"),
        }
    }

    pub fn as_int(&self) -> Result<&IntTensor> {
        match self {
            Tensor::Int(t) => Ok(t),
            Tensor::Trit(_) => bail!("expected i32 tensor, found trit"),
        }
    }
}

pub type Bundle = BTreeMap<String, Tensor>;

/// One prepared layer's serialized form in the weight-image section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackedLayerTag {
    /// A conv2d kernel set (`PreparedLayer`, position-major words).
    Conv,
    /// A TCN layer already projected through the §4 mapping onto a 3×3
    /// kernel set (`PreparedLayer`, position-major words).
    MappedTcn,
    /// A classifier (`PreparedDense`, chunk-major words).
    Dense,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayerRecord {
    pub name: String,
    pub tag: PackedLayerTag,
    pub in_ch: usize,
    pub out_ch: usize,
    /// Kernel size (conv / mapped records; 0 for dense).
    pub k: usize,
    pub pool: bool,
    pub global_pool: bool,
    /// Per-OCU thresholds (empty for dense).
    pub lo: Vec<i32>,
    pub hi: Vec<i32>,
    /// conv/mapped: position-major `[kk · out_ch + co]`; dense:
    /// chunk-major `[chunk · out_ch + co]`.
    pub words: Vec<PackedVec>,
}

/// The parsed weight-image section of a TTN2 file: one record per
/// prepared layer, in network order.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightImage {
    /// Datapath channel width the dense chunks were packed for.
    pub chunk_channels: usize,
    pub layers: Vec<PackedLayerRecord>,
}

pub fn read_file(path: impl AsRef<Path>) -> Result<Bundle> {
    Ok(read_file_full(path)?.0)
}

/// Read a `.ttn` file of either version, returning the tensor bundle
/// and, for TTN2, the packed weight-image section.
pub fn read_file_full(path: impl AsRef<Path>) -> Result<(Bundle, Option<WeightImage>)> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_bytes_full(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn read_bytes(b: &[u8]) -> Result<Bundle> {
    Ok(read_bytes_full(b)?.0)
}

/// Parse bytes of either container version. TTN1 yields
/// `(bundle, None)`; TTN2 additionally parses and validates the weight
/// image.
pub fn read_bytes_full(mut b: &[u8]) -> Result<(Bundle, Option<WeightImage>)> {
    let magic = read_u32(&mut b)?;
    match magic {
        MAGIC => {
            let bundle = read_bundle(&mut b)?;
            if !b.is_empty() {
                bail!("{} trailing bytes", b.len());
            }
            Ok((bundle, None))
        }
        MAGIC2 => {
            let bundle = read_bundle(&mut b)?;
            let image = decode_image(&mut b)?;
            if !b.is_empty() {
                bail!("{} trailing bytes after the weight image", b.len());
            }
            Ok((bundle, Some(image)))
        }
        other => bail!("bad magic {other:#x} (expected TTN1 or TTN2)"),
    }
}

fn read_bundle(b: &mut &[u8]) -> Result<Bundle> {
    let n = read_u32(b)? as usize;
    let mut out = Bundle::new();
    for _ in 0..n {
        let name_len = read_u16(b)? as usize;
        let name = String::from_utf8(take(b, name_len)?.to_vec())?;
        let dtype = read_u8(b)?;
        let ndim = read_u8(b)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(b)? as usize);
        }
        // a forged dim list must not overflow into a tiny (or huge)
        // element count — checked product, proper error
        let count = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .with_context(|| format!("tensor '{name}': element count overflows"))?;
        let tensor = match dtype {
            0 => {
                let raw = take(b, count)?;
                let data: Vec<i8> = raw.iter().map(|&x| x as i8).collect();
                if let Some(bad) = data.iter().find(|t| !(-1..=1).contains(*t)) {
                    bail!("tensor '{name}': non-trit value {bad}");
                }
                Tensor::Trit(TritTensor::from_vec(&dims, data))
            }
            1 => {
                let bytes = count
                    .checked_mul(4)
                    .with_context(|| format!("tensor '{name}': byte count overflows"))?;
                let raw = take(b, bytes)?;
                let data: Vec<i32> =
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
                Tensor::Int(IntTensor::from_vec(&dims, data))
            }
            other => bail!("tensor '{name}': unknown dtype {other}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

fn decode_image(b: &mut &[u8]) -> Result<WeightImage> {
    let magic = read_u32(b).context("weight image: missing section")?;
    ensure!(magic == IMG_MAGIC, "weight image: bad section magic {magic:#x}");
    let chunk_channels = read_u32(b)? as usize;
    ensure!(
        (1..=MAX_CHANNELS).contains(&chunk_channels),
        "weight image: chunk width {chunk_channels}"
    );
    let n = read_u32(b)? as usize;
    ensure!(n <= MAX_IMG_LAYERS, "weight image: {n} layer records");
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u16(b)? as usize;
        let name = String::from_utf8(take(b, name_len)?.to_vec())?;
        let tag = match read_u8(b)? {
            0 => PackedLayerTag::Conv,
            1 => PackedLayerTag::MappedTcn,
            2 => PackedLayerTag::Dense,
            t => bail!("record '{name}': unknown layer tag {t}"),
        };
        let in_ch = read_u32(b)? as usize;
        let out_ch = read_u32(b)? as usize;
        ensure!(
            out_ch >= 1 && out_ch <= MAX_CHANNELS,
            "record '{name}': {out_ch} output channels"
        );
        let (k, pool, global_pool, lo, hi, nwords) = if tag == PackedLayerTag::Dense {
            ensure!(
                in_ch >= 1 && in_ch <= MAX_DENSE_FANIN,
                "record '{name}': classifier fan-in {in_ch}"
            );
            let nwords = in_ch
                .div_ceil(chunk_channels)
                .checked_mul(out_ch)
                .with_context(|| format!("record '{name}': word count overflows"))?;
            (0usize, false, false, Vec::new(), Vec::new(), nwords)
        } else {
            ensure!(
                in_ch >= 1 && in_ch <= MAX_CHANNELS,
                "record '{name}': {in_ch} input channels"
            );
            let k = read_u32(b)? as usize;
            ensure!(k >= 1 && k <= MAX_KERNEL, "record '{name}': kernel size {k}");
            ensure!(
                tag == PackedLayerTag::Conv || k == 3,
                "record '{name}': mapped TCN kernels are 3×3, got {k}"
            );
            let flags = read_u8(b)?;
            ensure!(flags & !0b11 == 0, "record '{name}': unknown flag bits {flags:#x}");
            let lo = read_i32s(b, out_ch)?;
            let hi = read_i32s(b, out_ch)?;
            for co in 0..out_ch {
                ensure!(
                    (lo[co] as i64) <= (hi[co] as i64) + 1,
                    "record '{name}': channel {co} violates lo <= hi + 1"
                );
            }
            // k ≤ 16, out_ch ≤ 128: the word count cannot overflow
            (k, flags & 0b01 != 0, flags & 0b10 != 0, lo, hi, k * k * out_ch)
        };
        // words are read through `take`, so a forged count is bounded by
        // the actual buffer before any allocation happens
        let raw = take(
            b,
            nwords.checked_mul(32).with_context(|| format!("record '{name}': byte count"))?,
        )?;
        let mut words = Vec::with_capacity(nwords);
        for quad in raw.chunks_exact(32) {
            let w = [
                u64::from_le_bytes(quad[0..8].try_into().unwrap()),
                u64::from_le_bytes(quad[8..16].try_into().unwrap()),
                u64::from_le_bytes(quad[16..24].try_into().unwrap()),
                u64::from_le_bytes(quad[24..32].try_into().unwrap()),
            ];
            let v = PackedVec::from_words(w)
                .with_context(|| format!("record '{name}': pos plane escapes the mask plane"))?;
            words.push(v);
        }
        // channel-width hygiene: stale bits beyond a word's channel span
        // would poison whole-word dots downstream
        if tag == PackedLayerTag::Dense {
            for (i, w) in words.iter().enumerate() {
                let chunk = i / out_ch;
                let width = (in_ch - chunk * chunk_channels).min(chunk_channels);
                ensure!(
                    w.masked(width) == *w,
                    "record '{name}': stale bits beyond chunk {chunk}'s {width} channels"
                );
            }
        } else {
            for w in &words {
                ensure!(
                    w.masked(in_ch) == *w,
                    "record '{name}': stale bits beyond {in_ch} channels"
                );
            }
        }
        layers.push(PackedLayerRecord {
            name,
            tag,
            in_ch,
            out_ch,
            k,
            pool,
            global_pool,
            lo,
            hi,
            words,
        });
    }
    Ok(WeightImage { chunk_channels, layers })
}

fn encode_image(img: &WeightImage) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&IMG_MAGIC.to_le_bytes());
    out.extend_from_slice(&(img.chunk_channels as u32).to_le_bytes());
    out.extend_from_slice(&(img.layers.len() as u32).to_le_bytes());
    for r in &img.layers {
        out.extend_from_slice(&(r.name.len() as u16).to_le_bytes());
        out.extend_from_slice(r.name.as_bytes());
        out.push(match r.tag {
            PackedLayerTag::Conv => 0,
            PackedLayerTag::MappedTcn => 1,
            PackedLayerTag::Dense => 2,
        });
        out.extend_from_slice(&(r.in_ch as u32).to_le_bytes());
        out.extend_from_slice(&(r.out_ch as u32).to_le_bytes());
        if r.tag != PackedLayerTag::Dense {
            out.extend_from_slice(&(r.k as u32).to_le_bytes());
            out.push((r.pool as u8) | ((r.global_pool as u8) << 1));
            for v in &r.lo {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for v in &r.hi {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        for w in &r.words {
            for word in w.to_words() {
                out.extend_from_slice(&word.to_le_bytes());
            }
        }
    }
    out
}

fn bundle_body(tensors: &Bundle) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        match t {
            Tensor::Trit(tt) => {
                out.push(0u8);
                out.push(tt.dims.len() as u8);
                for d in &tt.dims {
                    out.extend_from_slice(&(*d as u32).to_le_bytes());
                }
                out.extend(tt.data.iter().map(|&x| x as u8));
            }
            Tensor::Int(it) => {
                out.push(1u8);
                out.push(it.dims.len() as u8);
                for d in &it.dims {
                    out.extend_from_slice(&(*d as u32).to_le_bytes());
                }
                for v in &it.data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    out
}

/// Serialize a bundle as TTN1 bytes.
pub fn write_bytes(tensors: &Bundle) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&bundle_body(tensors));
    out
}

pub fn write_file(path: impl AsRef<Path>, tensors: &Bundle) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, write_bytes(tensors))
        .with_context(|| format!("writing {}", path.display()))
}

/// Serialize a bundle plus its packed weight image as TTN2 bytes.
pub fn write_bytes_v2(tensors: &Bundle, image: &WeightImage) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC2.to_le_bytes());
    out.extend_from_slice(&bundle_body(tensors));
    out.extend_from_slice(&encode_image(image));
    out
}

pub fn write_file_v2(path: impl AsRef<Path>, tensors: &Bundle, image: &WeightImage) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, write_bytes_v2(tensors, image))
        .with_context(|| format!("writing {}", path.display()))
}

/// Upgrade raw TTN1 bytes to TTN2 by appending a weight-image section.
/// The bundle body is carried over **verbatim** (not re-encoded), so
/// [`strip_bytes`] inverts this bit-exactly for any valid v1 input —
/// including files whose tensor order is not the canonical one this
/// writer emits.
pub fn upgrade_bytes(v1: &[u8], image: &WeightImage) -> Result<Vec<u8>> {
    let mut b = v1;
    let magic = read_u32(&mut b)?;
    ensure!(magic != MAGIC2, "already a TTN2 file");
    ensure!(magic == MAGIC, "bad magic {magic:#x} (expected TTN1)");
    let _ = read_bundle(&mut b)?; // validate before stamping v2 on it
    ensure!(b.is_empty(), "{} trailing bytes", b.len());
    let mut out = Vec::with_capacity(v1.len() + 64);
    out.extend_from_slice(&MAGIC2.to_le_bytes());
    out.extend_from_slice(&v1[4..]);
    out.extend_from_slice(&encode_image(image));
    Ok(out)
}

/// Strip TTN2 bytes back to the original TTN1 bytes (the exact inverse
/// of [`upgrade_bytes`]); the image section is validated on the way.
pub fn strip_bytes(v2: &[u8]) -> Result<Vec<u8>> {
    let mut b = v2;
    let magic = read_u32(&mut b)?;
    ensure!(magic == MAGIC2, "bad magic {magic:#x} (expected TTN2)");
    let before = b.len();
    let _ = read_bundle(&mut b)?;
    let body_len = before - b.len();
    let _ = decode_image(&mut b)?;
    ensure!(b.is_empty(), "{} trailing bytes after the weight image", b.len());
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&v2[4..4 + body_len]);
    Ok(out)
}

// The wire-format readers are shared with the hibernation snapshot codec
// (`coordinator::hibernate`), which reuses this hardened take-before-alloc
// machinery for its own sections.
pub(crate) fn take<'a>(b: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if b.len() < n {
        bail!("unexpected eof (wanted {n}, have {})", b.len());
    }
    let (head, rest) = b.split_at(n);
    *b = rest;
    Ok(head)
}

pub(crate) fn read_u8(b: &mut &[u8]) -> Result<u8> {
    Ok(take(b, 1)?[0])
}

fn read_u16(b: &mut &[u8]) -> Result<u16> {
    Ok(u16::from_le_bytes(take(b, 2)?.try_into().unwrap()))
}

pub(crate) fn read_u32(b: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(b, 4)?.try_into().unwrap()))
}

pub(crate) fn read_u64(b: &mut &[u8]) -> Result<u64> {
    Ok(u64::from_le_bytes(take(b, 8)?.try_into().unwrap()))
}

fn read_i32s(b: &mut &[u8], n: usize) -> Result<Vec<i32>> {
    let raw = take(b, n.checked_mul(4).context("i32 run length overflows")?)?;
    Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_property() {
        let mut rng = Rng::new(17);
        for case in 0..20 {
            let mut bundle = Bundle::new();
            for t in 0..1 + case % 4 {
                let ndim = 1 + rng.below(3);
                let dims: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(5)).collect();
                let n: usize = dims.iter().product();
                if rng.bool(0.5) {
                    let data: Vec<i8> = (0..n).map(|_| rng.trit(0.3)).collect();
                    bundle.insert(format!("t{t}"), Tensor::Trit(TritTensor::from_vec(&dims, data)));
                } else {
                    let data: Vec<i32> =
                        (0..n).map(|_| rng.range_i32(-1_000_000, 1_000_000)).collect();
                    bundle.insert(format!("t{t}"), Tensor::Int(IntTensor::from_vec(&dims, data)));
                }
            }
            let dir = std::env::temp_dir().join(format!("ttn_test_{case}.ttn"));
            write_file(&dir, &bundle).unwrap();
            let back = read_file(&dir).unwrap();
            std::fs::remove_file(&dir).ok();
            assert_eq!(bundle.len(), back.len());
            for (k, v) in &bundle {
                match (v, &back[k]) {
                    (Tensor::Trit(a), Tensor::Trit(b)) => assert_eq!(a, b),
                    (Tensor::Int(a), Tensor::Int(b)) => assert_eq!(a, b),
                    _ => panic!("dtype changed in roundtrip"),
                }
            }
        }
    }

    fn tiny_image() -> WeightImage {
        // 1 conv record (2 in, 2 out, 3×3) + 1 dense record (5 in, 3 out)
        let mut rng = Rng::new(33);
        let conv_words: Vec<PackedVec> = (0..9 * 2)
            .map(|_| PackedVec::pack(&[rng.trit(0.3), rng.trit(0.3)]))
            .collect();
        let dense_words: Vec<PackedVec> = (0..3)
            .map(|_| PackedVec::pack(&(0..5).map(|_| rng.trit(0.3)).collect::<Vec<_>>()))
            .collect();
        WeightImage {
            chunk_channels: 96,
            layers: vec![
                PackedLayerRecord {
                    name: "c0".into(),
                    tag: PackedLayerTag::Conv,
                    in_ch: 2,
                    out_ch: 2,
                    k: 3,
                    pool: true,
                    global_pool: false,
                    lo: vec![-1, 0],
                    hi: vec![1, 2],
                    words: conv_words,
                },
                PackedLayerRecord {
                    name: "fc".into(),
                    tag: PackedLayerTag::Dense,
                    in_ch: 5,
                    out_ch: 3,
                    k: 0,
                    pool: false,
                    global_pool: false,
                    lo: vec![],
                    hi: vec![],
                    words: dense_words,
                },
            ],
        }
    }

    #[test]
    fn v2_roundtrip_and_strip_are_exact() {
        let mut bundle = Bundle::new();
        bundle.insert("x".into(), Tensor::Trit(TritTensor::from_vec(&[4], vec![1, 0, -1, 1])));
        bundle.insert("y".into(), Tensor::Int(IntTensor::from_vec(&[2], vec![7, -9])));
        let image = tiny_image();

        let v1 = write_bytes(&bundle);
        let v2 = upgrade_bytes(&v1, &image).unwrap();
        assert_eq!(strip_bytes(&v2).unwrap(), v1, "strip must invert upgrade bit-exactly");
        assert!(upgrade_bytes(&v2, &image).is_err(), "double upgrade is an error");

        let (back, img) = read_bytes_full(&v2).unwrap();
        assert_eq!(back.len(), bundle.len());
        assert_eq!(img.as_ref(), Some(&image), "image section must round-trip");
        // the dedicated writer agrees with the verbatim upgrade path on
        // canonical (writer-ordered) bundles
        assert_eq!(write_bytes_v2(&bundle, &image), v2);
        // v1 read path still ignores nothing: plain read_bytes works on v2
        assert_eq!(read_bytes(&v2).unwrap().len(), bundle.len());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_bytes(&[0, 0, 0, 0, 0, 0, 0, 0]).is_err());
        // a plausible-looking future version is an error, not a guess
        let mut v3 = write_bytes(&Bundle::new());
        v3[3] = b'3';
        assert!(read_bytes(&v3).is_err());
    }

    #[test]
    fn rejects_truncated_v1_and_v2_at_every_boundary() {
        let mut bundle = Bundle::new();
        bundle.insert("x".into(), Tensor::Trit(TritTensor::from_vec(&[4], vec![1, 0, -1, 1])));
        bundle.insert("y".into(), Tensor::Int(IntTensor::from_vec(&[2], vec![3, 4])));
        let v1 = write_bytes(&bundle);
        let v2 = upgrade_bytes(&v1, &tiny_image()).unwrap();
        for bytes in [&v1, &v2] {
            for cut in 0..bytes.len() {
                assert!(
                    read_bytes_full(&bytes[..cut]).is_err(),
                    "truncation to {cut} of {} must error",
                    bytes.len()
                );
            }
        }
    }

    #[test]
    fn bit_flips_never_panic() {
        // A flipped bit may still parse (e.g. inside an i32 payload or a
        // weight word); it must never panic, OOM or violate the plane
        // invariants of anything returned.
        let mut bundle = Bundle::new();
        let trits = TritTensor::from_vec(&[6], vec![1, 0, -1, 1, 0, 0]);
        bundle.insert("x".into(), Tensor::Trit(trits));
        bundle.insert("y".into(), Tensor::Int(IntTensor::from_vec(&[3], vec![5, -5, 0])));
        let v2 = upgrade_bytes(&write_bytes(&bundle), &tiny_image()).unwrap();
        let mut rng = Rng::new(55);
        for _ in 0..400 {
            let mut m = v2.clone();
            let bit = rng.below(m.len() * 8);
            m[bit / 8] ^= 1 << (bit % 8);
            if let Ok((_, Some(img))) = read_bytes_full(&m) {
                for r in &img.layers {
                    for w in &r.words {
                        assert_eq!(PackedVec::from_words(w.to_words()), Some(*w));
                    }
                }
            }
        }
    }

    #[test]
    fn hostile_length_prefixes_error_without_alloc() {
        // tensor count far beyond the buffer
        let mut b = MAGIC.to_le_bytes().to_vec();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_bytes(&b).is_err());

        // dim list whose product overflows usize
        let mut b = MAGIC.to_le_bytes().to_vec();
        b.extend_from_slice(&1u32.to_le_bytes()); // 1 tensor
        b.extend_from_slice(&1u16.to_le_bytes()); // name "a"
        b.push(b'a');
        b.push(0); // dtype trit
        b.push(4); // ndim
        for _ in 0..4 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let e = read_bytes(&b).unwrap_err().to_string();
        assert!(e.contains("overflow"), "got: {e}");

        // name length prefix beyond the buffer
        let mut b = MAGIC.to_le_bytes().to_vec();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&u16::MAX.to_le_bytes());
        b.push(b'a');
        assert!(read_bytes(&b).is_err());

        // image section with a forged OCU count
        let mut img = tiny_image();
        img.layers[0].out_ch = 100_000;
        let v2 = write_bytes_v2(&Bundle::new(), &img);
        assert!(read_bytes_full(&v2).is_err());
    }

    #[test]
    fn image_section_invariants_are_enforced() {
        let bundle = Bundle::new();
        // pos bit outside mask in a weight word
        let mut img = tiny_image();
        img.layers[0].words[0].pos[0] |= 1 << 1;
        img.layers[0].words[0].mask[0] &= !(1 << 1); // pos bit 1 now escapes mask
        let v2 = write_bytes_v2(&bundle, &img);
        let e = read_bytes_full(&v2).unwrap_err().to_string();
        assert!(e.contains("pos plane"), "got: {e}");

        // stale channel bits beyond in_ch
        let mut img = tiny_image();
        img.layers[0].words[0].mask[0] |= 1 << 7; // in_ch = 2
        let v2 = write_bytes_v2(&bundle, &img);
        let e = read_bytes_full(&v2).unwrap_err().to_string();
        assert!(e.contains("stale bits"), "got: {e}");

        // threshold contract violation
        let mut img = tiny_image();
        img.layers[0].lo[0] = 5;
        img.layers[0].hi[0] = 3;
        let v2 = write_bytes_v2(&bundle, &img);
        let e = read_bytes_full(&v2).unwrap_err().to_string();
        assert!(e.contains("lo <= hi + 1"), "got: {e}");

        // mapped-TCN records are pinned to 3×3
        let mut img = tiny_image();
        img.layers[0].tag = PackedLayerTag::MappedTcn;
        img.layers[0].k = 5;
        img.layers[0].words = vec![PackedVec::ZERO; 25 * 2];
        let v2 = write_bytes_v2(&bundle, &img);
        assert!(read_bytes_full(&v2).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut bundle = Bundle::new();
        bundle.insert(
            "x".into(),
            Tensor::Trit(TritTensor::from_vec(&[4], vec![1, 0, -1, 1])),
        );
        let path = std::env::temp_dir().join("ttn_trunc.ttn");
        write_file(&path, &bundle).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(read_bytes(&bytes[..bytes.len() - 2]).is_err());
    }
}
