//! `.ttn` binary interchange reader/writer — the Rust half of
//! `python/compile/ttn.py`. Format documented there; all little-endian.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{IntTensor, TritTensor};

pub const MAGIC: u32 = 0x314E5454; // "TTN1"

#[derive(Debug, Clone)]
pub enum Tensor {
    Trit(TritTensor),
    Int(IntTensor),
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::Trit(t) => &t.dims,
            Tensor::Int(t) => &t.dims,
        }
    }

    pub fn as_trit(&self) -> Result<&TritTensor> {
        match self {
            Tensor::Trit(t) => Ok(t),
            Tensor::Int(_) => bail!("expected trit tensor, found i32"),
        }
    }

    pub fn as_int(&self) -> Result<&IntTensor> {
        match self {
            Tensor::Int(t) => Ok(t),
            Tensor::Trit(_) => bail!("expected i32 tensor, found trit"),
        }
    }
}

pub type Bundle = BTreeMap<String, Tensor>;

pub fn read_file(path: impl AsRef<Path>) -> Result<Bundle> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn read_bytes(mut b: &[u8]) -> Result<Bundle> {
    let magic = read_u32(&mut b)?;
    if magic != MAGIC {
        bail!("bad magic {magic:#x}");
    }
    let n = read_u32(&mut b)? as usize;
    let mut out = Bundle::new();
    for _ in 0..n {
        let name_len = read_u16(&mut b)? as usize;
        let name = String::from_utf8(take(&mut b, name_len)?.to_vec())?;
        let dtype = read_u8(&mut b)?;
        let ndim = read_u8(&mut b)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut b)? as usize);
        }
        let count: usize = dims.iter().product();
        let tensor = match dtype {
            0 => {
                let raw = take(&mut b, count)?;
                let data: Vec<i8> = raw.iter().map(|&x| x as i8).collect();
                if let Some(bad) = data.iter().find(|t| !(-1..=1).contains(*t)) {
                    bail!("tensor '{name}': non-trit value {bad}");
                }
                Tensor::Trit(TritTensor::from_vec(&dims, data))
            }
            1 => {
                let raw = take(&mut b, count * 4)?;
                let data: Vec<i32> =
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
                Tensor::Int(IntTensor::from_vec(&dims, data))
            }
            other => bail!("tensor '{name}': unknown dtype {other}"),
        };
        out.insert(name, tensor);
    }
    if !b.is_empty() {
        bail!("{} trailing bytes", b.len());
    }
    Ok(out)
}

pub fn write_file(path: impl AsRef<Path>, tensors: &Bundle) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        match t {
            Tensor::Trit(tt) => {
                out.push(0u8);
                out.push(tt.dims.len() as u8);
                for d in &tt.dims {
                    out.extend_from_slice(&(*d as u32).to_le_bytes());
                }
                out.extend(tt.data.iter().map(|&x| x as u8));
            }
            Tensor::Int(it) => {
                out.push(1u8);
                out.push(it.dims.len() as u8);
                for d in &it.dims {
                    out.extend_from_slice(&(*d as u32).to_le_bytes());
                }
                for v in &it.data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    f.write_all(&out)?;
    Ok(())
}

fn take<'a>(b: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if b.len() < n {
        bail!("unexpected eof (wanted {n}, have {})", b.len());
    }
    let (head, rest) = b.split_at(n);
    *b = rest;
    Ok(head)
}

fn read_u8(b: &mut &[u8]) -> Result<u8> {
    Ok(take(b, 1)?[0])
}

fn read_u16(b: &mut &[u8]) -> Result<u16> {
    Ok(u16::from_le_bytes(take(b, 2)?.try_into().unwrap()))
}

fn read_u32(b: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(b, 4)?.try_into().unwrap()))
}

// Suppress unused-import warning for Read (used via trait in some builds).
#[allow(unused)]
fn _assert_read_usable(r: &mut dyn Read) {
    let _ = r;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_property() {
        let mut rng = Rng::new(17);
        for case in 0..20 {
            let mut bundle = Bundle::new();
            for t in 0..1 + case % 4 {
                let ndim = 1 + rng.below(3);
                let dims: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(5)).collect();
                let n: usize = dims.iter().product();
                if rng.bool(0.5) {
                    let data: Vec<i8> = (0..n).map(|_| rng.trit(0.3)).collect();
                    bundle.insert(format!("t{t}"), Tensor::Trit(TritTensor::from_vec(&dims, data)));
                } else {
                    let data: Vec<i32> =
                        (0..n).map(|_| rng.range_i32(-1_000_000, 1_000_000)).collect();
                    bundle.insert(format!("t{t}"), Tensor::Int(IntTensor::from_vec(&dims, data)));
                }
            }
            let dir = std::env::temp_dir().join(format!("ttn_test_{case}.ttn"));
            write_file(&dir, &bundle).unwrap();
            let back = read_file(&dir).unwrap();
            std::fs::remove_file(&dir).ok();
            assert_eq!(bundle.len(), back.len());
            for (k, v) in &bundle {
                match (v, &back[k]) {
                    (Tensor::Trit(a), Tensor::Trit(b)) => assert_eq!(a, b),
                    (Tensor::Int(a), Tensor::Int(b)) => assert_eq!(a, b),
                    _ => panic!("dtype changed in roundtrip"),
                }
            }
        }
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_bytes(&[0, 0, 0, 0, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut bundle = Bundle::new();
        bundle.insert(
            "x".into(),
            Tensor::Trit(TritTensor::from_vec(&[4], vec![1, 0, -1, 1])),
        );
        let path = std::env::temp_dir().join("ttn_trunc.ttn");
        write_file(&path, &bundle).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(read_bytes(&bytes[..bytes.len() - 2]).is_err());
    }
}
