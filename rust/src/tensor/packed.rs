//! Bit-packed activation maps: the native inter-layer currency of the
//! simulator since perf pass iteration 8 (see EXPERIMENTS.md §Perf).
//!
//! A `PackedMap` is an H×W feature map whose pixels are (pos, mask)
//! bitplane channel vectors ([`PackedVec`]) — the same 2-bit-per-trit
//! encoding the activation SRAM holds in silicon and the dot kernels
//! already consume. Keeping feature maps packed end to end removes the
//! per-pixel i8↔bitplane conversion tax the i8 `TritTensor` currency
//! paid on every linebuffer fetch and every ternarization write-back,
//! and shrinks inter-layer memory traffic to the hardware's 2·C bits
//! per pixel. i8 tensors remain the representation at API edges only
//! (network weights, the reference executor, `.ttn` interchange).

use crate::trit::{PackedVec, MAX_CHANNELS};

use super::TritTensor;

/// H×W pixels of packed C-channel trit vectors (HWC feature map).
///
/// Invariants: `pixels.len() == h * w`, and every pixel's plane bits at
/// positions ≥ `c` are clear (so whole-word bitwise ops — pooling, dots,
/// column packing — never see stale channels).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major pixel words; one `PackedVec` = one activation-SRAM word.
    pub pixels: Vec<PackedVec>,
}

impl PackedMap {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        assert!(c <= MAX_CHANNELS, "at most {MAX_CHANNELS} channels");
        PackedMap { h, w, c, pixels: vec![PackedVec::ZERO; h * w] }
    }

    /// Pack an i8 map (API-edge conversion). Accepts an (H, W, C) feature
    /// map or a flat (C,) feature vector, which becomes a 1×1 map.
    pub fn from_trit(t: &TritTensor) -> Self {
        match t.dims.as_slice() {
            &[h, w, c] => {
                let mut m = PackedMap::zeros(h, w, c);
                for y in 0..h {
                    for x in 0..w {
                        m.pixels[y * w + x] = t.pack_pixel(y, x);
                    }
                }
                m
            }
            &[c] => PackedMap { h: 1, w: 1, c, pixels: vec![PackedVec::pack(&t.data)] },
            other => panic!("PackedMap::from_trit: unsupported dims {other:?}"),
        }
    }

    /// Unpack to an i8 (H, W, C) tensor (API-edge conversion).
    pub fn to_trit(&self) -> TritTensor {
        TritTensor::from_vec(&[self.h, self.w, self.c], self.unpack_data())
    }

    /// Unpack to flat i8 trits in HWC order (the flatten the classifier
    /// consumes).
    pub fn unpack_data(&self) -> Vec<i8> {
        let mut data = Vec::with_capacity(self.numel());
        for px in &self.pixels {
            data.extend(px.unpack(self.c));
        }
        data
    }

    /// Trits in the map (h·w·c).
    pub fn numel(&self) -> usize {
        self.h * self.w * self.c
    }

    #[inline]
    pub fn pixel(&self, y: usize, x: usize) -> &PackedVec {
        &self.pixels[y * self.w + x]
    }

    /// Borrow input row `y` — the zero-copy linebuffer access path.
    #[inline]
    pub fn row(&self, y: usize) -> &[PackedVec] {
        &self.pixels[y * self.w..(y + 1) * self.w]
    }

    #[inline]
    pub fn get_trit(&self, y: usize, x: usize, ch: usize) -> i8 {
        debug_assert!(ch < self.c);
        self.pixel(y, x).get(ch)
    }

    #[inline]
    pub fn set_trit(&mut self, y: usize, x: usize, ch: usize, v: i8) {
        debug_assert!(ch < self.c);
        self.pixels[y * self.w + x].set(ch, v);
    }

    /// Fraction of zero trits.
    pub fn sparsity(&self) -> f64 {
        if self.pixels.is_empty() || self.c == 0 {
            return 0.0;
        }
        let nz: u64 = self.pixels.iter().map(|p| p.count_nonzero() as u64).sum();
        1.0 - nz as f64 / self.numel() as f64
    }

    /// Scrub every pixel word: detect and clamp `pos ⊄ mask` orphan bits
    /// (see [`PackedVec::scrub`]) — the activation-SRAM half of the
    /// fault-injection layer's detection pass. Returns the number of
    /// orphans cleared; zero on any legally-constructed map.
    pub fn scrub(&mut self) -> u64 {
        self.pixels.iter_mut().map(|p| p.scrub() as u64).sum()
    }

    /// 2×2/2 max-pool on packed planes: two bitwise ops per word per
    /// pairwise ternary max ([`PackedVec::max`]), no unpacking. Matches
    /// `reference::maxpool2x2` trit for trit.
    pub fn maxpool2x2(&self) -> PackedMap {
        assert!(self.h % 2 == 0 && self.w % 2 == 0, "odd pooling input {}x{}", self.h, self.w);
        let (oh, ow) = (self.h / 2, self.w / 2);
        let mut out = PackedMap::zeros(oh, ow, self.c);
        for y in 0..oh {
            for x in 0..ow {
                let top = self.pixel(2 * y, 2 * x).max(self.pixel(2 * y, 2 * x + 1));
                let bot = self.pixel(2 * y + 1, 2 * x).max(self.pixel(2 * y + 1, 2 * x + 1));
                out.pixels[y * ow + x] = top.max(&bot);
            }
        }
        out
    }

    /// Global max-pool to a 1×1 map (the CNN→TCN feature vector).
    /// Matches `reference::global_maxpool` trit for trit.
    pub fn global_maxpool(&self) -> PackedMap {
        let mut acc = self.pixels[0];
        for px in &self.pixels[1..] {
            acc = acc.max(px);
        }
        PackedMap { h: 1, w: 1, c: self.c, pixels: vec![acc] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::reference;
    use crate::util::rng::Rng;

    #[test]
    fn pack_roundtrip_and_accessors() {
        let mut rng = Rng::new(41);
        for &(h, w, c) in &[(1usize, 1usize, 1usize), (4, 6, 17), (5, 3, 96), (2, 2, 128)] {
            let t = TritTensor::random(&[h, w, c], &mut rng, 0.4);
            let m = PackedMap::from_trit(&t);
            assert_eq!(m.to_trit(), t);
            assert_eq!(m.numel(), t.numel());
            for y in 0..h {
                for x in 0..w {
                    assert_eq!(*m.pixel(y, x), t.pack_pixel(y, x));
                    assert_eq!(m.row(y)[x], t.pack_pixel(y, x));
                    for ch in 0..c {
                        assert_eq!(m.get_trit(y, x, ch), t.get3(y, x, ch));
                    }
                }
            }
            assert!((m.sparsity() - t.sparsity()).abs() < 1e-12);
        }
    }

    #[test]
    fn vector_packs_as_single_pixel() {
        let t = TritTensor::from_vec(&[5], vec![1, -1, 0, 0, 1]);
        let m = PackedMap::from_trit(&t);
        assert_eq!((m.h, m.w, m.c), (1, 1, 5));
        assert_eq!(m.unpack_data(), t.data);
    }

    #[test]
    fn set_trit_roundtrip() {
        let mut m = PackedMap::zeros(3, 3, 8);
        m.set_trit(1, 2, 5, -1);
        m.set_trit(2, 0, 0, 1);
        assert_eq!(m.get_trit(1, 2, 5), -1);
        assert_eq!(m.get_trit(2, 0, 0), 1);
        m.set_trit(1, 2, 5, 0);
        assert_eq!(m.get_trit(1, 2, 5), 0);
    }

    #[test]
    fn packed_maxpool_matches_reference() {
        let mut rng = Rng::new(42);
        for case in 0..40 {
            let h = 2 * (1 + rng.below(5));
            let w = 2 * (1 + rng.below(5));
            let c = 1 + rng.below(MAX_CHANNELS);
            let zf = [0.0, 0.3, 0.6, 0.95][case % 4];
            let t = TritTensor::random(&[h, w, c], &mut rng, zf);
            let want = reference::maxpool2x2(&t);
            let got = PackedMap::from_trit(&t).maxpool2x2();
            assert_eq!(got.to_trit(), want, "h {h} w {w} c {c} case {case}");
        }
    }

    #[test]
    fn packed_global_maxpool_matches_reference() {
        let mut rng = Rng::new(43);
        for case in 0..40 {
            let h = 1 + rng.below(8);
            let w = 1 + rng.below(8);
            let c = 1 + rng.below(MAX_CHANNELS);
            let zf = [0.0, 0.5, 0.95, 1.0][case % 4];
            let t = TritTensor::random(&[h, w, c], &mut rng, zf);
            let want = reference::global_maxpool(&t); // dims (C,)
            let got = PackedMap::from_trit(&t).global_maxpool();
            assert_eq!((got.h, got.w, got.c), (1, 1, c));
            assert_eq!(got.unpack_data(), want.data, "h {h} w {w} c {c} case {case}");
        }
    }

    #[test]
    #[should_panic(expected = "odd pooling input")]
    fn maxpool_rejects_odd() {
        PackedMap::zeros(3, 4, 2).maxpool2x2();
    }

    #[test]
    fn scrub_detects_orphans_only() {
        let mut rng = Rng::new(44);
        let t = TritTensor::random(&[4, 4, 20], &mut rng, 0.4);
        let mut m = PackedMap::from_trit(&t);
        assert_eq!(m.scrub(), 0, "legal map must scrub clean");
        assert_eq!(m.to_trit(), t, "scrub must not disturb legal data");
        // plant two orphans (pos plane bit on known-zero channels)
        m.set_trit(0, 3, 2, 0);
        m.set_trit(2, 1, 19, 0);
        let clean = m.clone();
        m.pixels[3].flip_plane_bit(true, 2);
        m.pixels[9].flip_plane_bit(true, 19);
        assert_ne!(m, clean);
        assert_eq!(m.scrub(), 2);
        assert_eq!(m, clean, "orphans clamp back to the clean value");
    }
}
