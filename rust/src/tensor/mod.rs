//! Dense tensors: `TritTensor` (i8 trits) and `IntTensor` (i32
//! accumulators), row-major with HWC layout for feature maps, the
//! bit-packed activation map (`packed` submodule) that is the
//! simulator's native inter-layer currency, plus the `.ttn` interchange
//! reader/writer (`ttn` submodule).

pub mod packed;
pub mod ttn;

pub use packed::PackedMap;

use crate::trit::PackedVec;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TritTensor {
    pub dims: Vec<usize>,
    pub data: Vec<i8>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntTensor {
    pub dims: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

impl TritTensor {
    pub fn zeros(dims: &[usize]) -> Self {
        TritTensor { dims: dims.to_vec(), data: vec![0; numel(dims)] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(numel(dims), data.len(), "shape/data mismatch");
        debug_assert!(data.iter().all(|t| (-1..=1).contains(t)), "non-trit data");
        TritTensor { dims: dims.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Flat index for a 3D (H, W, C) tensor.
    #[inline]
    pub fn idx3(&self, y: usize, x: usize, c: usize) -> usize {
        debug_assert_eq!(self.dims.len(), 3);
        (y * self.dims[1] + x) * self.dims[2] + c
    }

    #[inline]
    pub fn get3(&self, y: usize, x: usize, c: usize) -> i8 {
        self.data[self.idx3(y, x, c)]
    }

    #[inline]
    pub fn set3(&mut self, y: usize, x: usize, c: usize, v: i8) {
        let i = self.idx3(y, x, c);
        self.data[i] = v;
    }

    /// Pack the channel vector at pixel (y, x) of an HWC map.
    pub fn pack_pixel(&self, y: usize, x: usize) -> PackedVec {
        let c = self.dims[2];
        let base = (y * self.dims[1] + x) * c;
        PackedVec::pack(&self.data[base..base + c])
    }

    /// Fraction of zero entries.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&t| t == 0).count() as f64 / self.data.len() as f64
    }

    /// Fill with seeded random trits (P(zero) = zero_frac).
    pub fn random(dims: &[usize], rng: &mut crate::util::rng::Rng, zero_frac: f64) -> Self {
        let data = (0..numel(dims)).map(|_| rng.trit(zero_frac)).collect();
        TritTensor { dims: dims.to_vec(), data }
    }
}

impl IntTensor {
    pub fn zeros(dims: &[usize]) -> Self {
        IntTensor { dims: dims.to_vec(), data: vec![0; numel(dims)] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(dims), data.len(), "shape/data mismatch");
        IntTensor { dims: dims.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn idx3(&self, y: usize, x: usize, c: usize) -> usize {
        debug_assert_eq!(self.dims.len(), 3);
        (y * self.dims[1] + x) * self.dims[2] + c
    }

    /// argmax with lowest-index tie-break (the classifier contract).
    pub fn argmax(&self) -> usize {
        let mut best = 0usize;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn indexing_hwc() {
        let mut t = TritTensor::zeros(&[2, 3, 4]);
        t.set3(1, 2, 3, -1);
        assert_eq!(t.get3(1, 2, 3), -1);
        assert_eq!(t.data[(1 * 3 + 2) * 4 + 3], -1);
    }

    #[test]
    fn pack_pixel_matches_channels() {
        let mut rng = Rng::new(5);
        let t = TritTensor::random(&[4, 4, 17], &mut rng, 0.4);
        let p = t.pack_pixel(2, 3);
        for c in 0..17 {
            assert_eq!(p.get(c), t.get3(2, 3, c));
        }
    }

    #[test]
    fn sparsity_estimate() {
        let mut rng = Rng::new(6);
        let t = TritTensor::random(&[32, 32, 96], &mut rng, 0.5);
        assert!((t.sparsity() - 0.5).abs() < 0.02);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        let t = IntTensor::from_vec(&[4], vec![3, 5, 5, 1]);
        assert_eq!(t.argmax(), 1);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        TritTensor::from_vec(&[2, 2], vec![0; 5]);
    }
}
