//! Paper-artifact generators: every table and figure of the evaluation
//! (§7–§8) regenerated from the simulator + energy model. Shared by the
//! benches, the examples and the `tcn-cutie report` CLI.
//!
//! Experiment index (DESIGN.md §4): T1 = Table 1, F5 = Figure 5,
//! F6 = Figure 6, S8 = §8 comparisons, A1/A2 = ablations.

use anyhow::Result;

use crate::baselines;
use crate::cutie::{CutieConfig, RunStats, Scheduler, SimMode, TcnStrategy};
use crate::energy::{self, evaluate, EnergyParams, EnergyReport};
use crate::network::{cifar9_random, dvs_hybrid_random, Network};
use crate::tensor::{PackedMap, TritTensor};
use crate::util::bench::Table;
use crate::util::rng::Rng;

/// Canonical benchmark workloads (seeded; sparsities chosen to match
/// trained ternary nets — weights ~1/3 zero, DVS inputs ~90% sparse).
pub fn cifar_workload() -> (Network, TritTensor) {
    let net = cifar9_random(96, 1, 0.33);
    let mut rng = Rng::new(2);
    let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
    (net, input)
}

pub fn dvs_workload(frames: usize) -> (Network, Vec<PackedMap>) {
    let net = dvs_hybrid_random(96, 3, 0.5);
    let mut src = crate::coordinator::DvsSource::new(64, 11, crate::coordinator::GestureClass(3));
    let frames = (0..frames).map(|_| src.next_frame()).collect();
    (net, frames)
}

/// Run the CIFAR workload once (steady state: weights preloaded).
pub fn cifar_stats(mode: SimMode) -> Result<RunStats> {
    let (net, input) = cifar_workload();
    let mut s = Scheduler::new(CutieConfig::kraken(), mode);
    s.preload_weights(&net);
    Ok(s.run_full(&net, &input)?.1)
}

/// Serve `n` DVS frames; returns per-frame stats (steady state reached
/// once the TCN window is warm).
pub fn dvs_stats(mode: SimMode, n: usize) -> Result<Vec<RunStats>> {
    let (net, frames) = dvs_workload(n);
    let mut s = Scheduler::new(CutieConfig::kraken(), mode);
    s.preload_weights(&net);
    frames.iter().map(|f| Ok(s.serve_frame(&net, f)?.1)).collect()
}

// ---------------------------------------------------------------------------
// T1 — Table 1
// ---------------------------------------------------------------------------

pub struct Table1Row {
    pub row: baselines::BaselineRow,
}

/// Our rows at the two corners, measured from the simulator.
pub fn cutie_rows(stats: &RunStats, p: &EnergyParams) -> Result<Vec<baselines::BaselineRow>> {
    [0.5, 0.9]
        .iter()
        .map(|&v| {
            let r = evaluate(stats, v, None, p)?;
            Ok(baselines::BaselineRow {
                name: if v == 0.5 { "This work @0.5V" } else { "This work @0.9V" },
                computation: "digital",
                weight_precision: "ternary",
                act_precision: "ternary",
                tech_nm: 22,
                dataset: "CIFAR-10",
                accuracy_pct: 86.0, // paper's trained accuracy (substituted net, see EXPERIMENTS.md)
                energy_per_inf_uj: r.energy_j * 1e6,
                core_area_mm2: 2.96,
                voltage_v: v,
                throughput_tops: r.peak_tops,
                peak_eff_tops_w: r.peak_tops_per_watt,
            })
        })
        .collect()
}

pub fn table1() -> Result<Table> {
    let stats = cifar_stats(SimMode::Accurate)?;
    let p = EnergyParams::default();
    let mut rows = vec![baselines::binareye(), baselines::knag_bnn(true), baselines::knag_bnn(false)];
    rows.extend(cutie_rows(&stats, &p)?);

    let mut t = Table::new(&[
        "Design", "Method", "W", "A", "Tech", "Acc%", "E/inf [µJ]", "Area [mm²]", "V", "TOp/s",
        "TOp/s/W",
    ]);
    for r in rows {
        t.row(&[
            r.name.to_string(),
            r.computation.to_string(),
            r.weight_precision.to_string(),
            r.act_precision.to_string(),
            format!("{} nm", r.tech_nm),
            format!("{:.0}", r.accuracy_pct),
            format!("{:.2}", r.energy_per_inf_uj),
            format!("{:.2}", r.core_area_mm2),
            format!("{:.2}", r.voltage_v),
            format!("{:.1}", r.throughput_tops),
            format!("{:.0}", r.peak_eff_tops_w),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// F5 — Figure 5: energy/inference + inf/s vs voltage, both networks
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig5Point {
    pub voltage: f64,
    pub freq_mhz: f64,
    pub cifar_uj: f64,
    pub cifar_inf_s: f64,
    pub dvs_uj: f64,
    pub dvs_inf_s: f64,
}

pub fn fig5() -> Result<Vec<Fig5Point>> {
    let p = EnergyParams::default();
    let cifar = cifar_stats(SimMode::Accurate)?;
    // steady-state DVS frame (warm TCN window): last of a short stream
    let dvs_all = dvs_stats(SimMode::Accurate, 6)?;
    let dvs = dvs_all.last().unwrap();

    energy::vf::sweep_points()
        .into_iter()
        .map(|v| {
            let rc = evaluate(&cifar, v, None, &p)?;
            let rd = evaluate(dvs, v, None, &p)?;
            Ok(Fig5Point {
                voltage: v,
                freq_mhz: rc.freq_hz / 1e6,
                cifar_uj: rc.energy_j * 1e6,
                cifar_inf_s: 1.0 / rc.time_s,
                dvs_uj: rd.energy_j * 1e6,
                dvs_inf_s: 1.0 / rd.time_s,
            })
        })
        .collect()
}

pub fn fig5_table(points: &[Fig5Point]) -> Table {
    let mut t = Table::new(&[
        "V", "fmax [MHz]", "CIFAR µJ/inf", "CIFAR inf/s", "DVS µJ/inf", "DVS inf/s",
    ]);
    for pt in points {
        t.row(&[
            format!("{:.2}", pt.voltage),
            format!("{:.0}", pt.freq_mhz),
            format!("{:.2}", pt.cifar_uj),
            format!("{:.0}", pt.cifar_inf_s),
            format!("{:.2}", pt.dvs_uj),
            format!("{:.0}", pt.dvs_inf_s),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// F6 — Figure 6: peak efficiency + peak throughput vs voltage (CIFAR L1)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig6Point {
    pub voltage: f64,
    pub peak_tops: f64,
    pub peak_tops_w: f64,
}

pub fn fig6() -> Result<Vec<Fig6Point>> {
    let p = EnergyParams::default();
    let stats = cifar_stats(SimMode::Accurate)?;
    energy::vf::sweep_points()
        .into_iter()
        .map(|v| {
            let r = evaluate(&stats, v, None, &p)?;
            Ok(Fig6Point { voltage: v, peak_tops: r.peak_tops, peak_tops_w: r.peak_tops_per_watt })
        })
        .collect()
}

pub fn fig6_table(points: &[Fig6Point]) -> Table {
    let mut t = Table::new(&["V", "Peak TOp/s", "Peak TOp/s/W"]);
    for pt in points {
        t.row(&[
            format!("{:.2}", pt.voltage),
            format!("{:.1}", pt.peak_tops),
            format!("{:.0}", pt.peak_tops_w),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// S8 — §8 comparisons (TCN-KWS, TrueNorth, Loihi)
// ---------------------------------------------------------------------------

pub struct SoaComparison {
    pub our_dvs_uj: f64,
    pub our_energy_per_op_pj: f64,
    pub kws_energy_per_op_pj: f64,
    pub kws_ratio: f64,
    pub truenorth_ratio: f64,
    pub loihi_ratio: f64,
}

pub fn soa() -> Result<SoaComparison> {
    let p = EnergyParams::default();
    let dvs_all = dvs_stats(SimMode::Accurate, 6)?;
    let dvs = dvs_all.last().unwrap();
    let r = evaluate(dvs, 0.5, None, &p)?;
    let our_uj = r.energy_j * 1e6;
    // average energy per (algorithmic) op, the §8 TCN comparison metric
    let our_e_op = r.energy_j / (dvs.alg_macs() as f64 * 2.0);
    let kws = baselines::TcnKws::published();
    Ok(SoaComparison {
        our_dvs_uj: our_uj,
        our_energy_per_op_pj: our_e_op * 1e12,
        kws_energy_per_op_pj: kws.energy_per_op_j() * 1e12,
        kws_ratio: kws.energy_per_op_j() / our_e_op,
        truenorth_ratio: baselines::truenorth().energy_per_inf_uj / our_uj,
        loihi_ratio: baselines::loihi().energy_per_inf_uj / our_uj,
    })
}

// ---------------------------------------------------------------------------
// A1 — sparsity ablation ([1]: sparse nets cut inference energy ~36%)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SparsityPoint {
    pub zero_frac: f64,
    pub energy_uj: f64,
    pub toggle_rate: f64,
}

pub fn sparsity_sweep(fracs: &[f64]) -> Result<Vec<SparsityPoint>> {
    let p = EnergyParams::default();
    fracs
        .iter()
        .map(|&zf| {
            let net = cifar9_random(96, 1, zf);
            let mut rng = Rng::new(2);
            let input = TritTensor::random(&[32, 32, 3], &mut rng, zf);
            let mut s = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
            s.preload_weights(&net);
            let (_, stats) = s.run_full(&net, &input)?;
            let r = evaluate(&stats, 0.5, None, &p)?;
            Ok(SparsityPoint {
                zero_frac: zf,
                energy_uj: r.energy_j * 1e6,
                toggle_rate: stats.toggle_rate(),
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// A2 — mapping ablation (§4: mapped vs direct strided TCN execution)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct MappingAblation {
    pub mapped_tcn_cycles: u64,
    pub direct_tcn_cycles: u64,
    pub mapped_stalls: u64,
    pub direct_stalls: u64,
    pub mapped_tcn_uj: f64,
    pub direct_tcn_uj: f64,
}

fn tcn_only(stats: &RunStats) -> RunStats {
    RunStats {
        layers: stats.layers.iter().filter(|l| l.name.starts_with('l') && l.fanin <= 3 * 96 || l.name.starts_with('t')).cloned().collect(),
        ..Default::default()
    }
}

pub fn mapping_ablation() -> Result<MappingAblation> {
    let (net, frames) = dvs_workload(4);
    let p = EnergyParams::default();

    let run = |strategy| -> Result<RunStats> {
        let mut s = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate).with_tcn_strategy(strategy);
        s.preload_weights(&net);
        let mut last = None;
        for f in &frames {
            last = Some(s.serve_frame(&net, f)?.1);
        }
        Ok(last.unwrap())
    };
    let mapped = run(TcnStrategy::Mapped)?;
    let direct = run(TcnStrategy::Direct)?;

    // isolate the TCN layers (names t*/l5..l8 in the random net)
    let tcn_names: Vec<String> = net
        .layers
        .iter()
        .filter(|l| l.kind == crate::network::LayerKind::Tcn)
        .map(|l| l.name.clone())
        .collect();
    let filter = |stats: &RunStats| -> RunStats {
        RunStats {
            layers: stats.layers.iter().filter(|l| tcn_names.contains(&l.name)).cloned().collect(),
            ..Default::default()
        }
    };
    let m = filter(&mapped);
    let d = filter(&direct);
    let rm = evaluate(&m, 0.5, None, &p)?;
    let rd = evaluate(&d, 0.5, None, &p)?;
    let _ = tcn_only;
    Ok(MappingAblation {
        mapped_tcn_cycles: m.total_cycles(),
        direct_tcn_cycles: d.total_cycles(),
        mapped_stalls: m.stall_cycles(),
        direct_stalls: d.stall_cycles(),
        mapped_tcn_uj: rm.energy_j * 1e6,
        direct_tcn_uj: rd.energy_j * 1e6,
    })
}

// ---------------------------------------------------------------------------
// Shared report printing
// ---------------------------------------------------------------------------

pub fn print_energy_report(label: &str, r: &EnergyReport) {
    println!(
        "{label}: V={:.2}  f={:.0} MHz  {} cycles  {:.2} µs  {:.3} µJ  {:.2} mW  \
         avg {:.2} TOp/s  peak {:.1} TOp/s  peak {:.0} TOp/s/W (layer {})",
        r.voltage,
        r.freq_hz / 1e6,
        r.cycles,
        r.time_s * 1e6,
        r.energy_j * 1e6,
        r.power_w * 1e3,
        r.avg_tops,
        r.peak_tops,
        r.peak_tops_per_watt,
        r.peak_layer,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_matches_paper() {
        let pts = fig5().unwrap();
        assert_eq!(pts.len(), 9);
        // energy rises with voltage, rate rises with voltage — Fig. 5's shape
        assert!(pts.last().unwrap().cifar_uj > pts[0].cifar_uj * 2.0);
        assert!(pts.last().unwrap().cifar_inf_s > pts[0].cifar_inf_s * 2.0);
        assert!(pts.last().unwrap().dvs_uj > pts[0].dvs_uj * 2.0);
        // 0.5 V is the energy-optimal corner (paper's headline)
        let min = pts.iter().map(|p| p.cifar_uj).fold(f64::INFINITY, f64::min);
        assert_eq!(min, pts[0].cifar_uj);
    }

    #[test]
    fn fig6_shape_matches_paper() {
        let pts = fig6().unwrap();
        // throughput up, efficiency down with voltage
        assert!(pts.last().unwrap().peak_tops > 3.0 * pts[0].peak_tops);
        assert!(pts[0].peak_tops_w > 2.0 * pts.last().unwrap().peak_tops_w);
        // endpoints near the paper anchors
        assert!((pts[0].peak_tops_w - 1036.0).abs() / 1036.0 < 0.05);
        assert!((pts[8].peak_tops_w - 318.0).abs() / 318.0 < 0.05);
    }

    #[test]
    fn sparsity_reduces_energy_like_cutie_paper() {
        // [1] reports ~36% energy reduction for very sparse ternary nets;
        // our sweep must show a monotone, same-order effect.
        let pts = sparsity_sweep(&[0.1, 0.5, 0.9]).unwrap();
        assert!(pts[0].energy_uj > pts[1].energy_uj);
        assert!(pts[1].energy_uj > pts[2].energy_uj);
        let reduction = 1.0 - pts[2].energy_uj / pts[0].energy_uj;
        assert!(reduction > 0.25, "sparsity 0.1→0.9 reduction {reduction}");
        assert!(pts[0].toggle_rate > pts[2].toggle_rate);
    }

    #[test]
    fn mapping_beats_direct() {
        let a = mapping_ablation().unwrap();
        assert_eq!(a.mapped_stalls, 0);
        assert!(a.direct_stalls > 0);
        assert!(a.direct_tcn_uj > a.mapped_tcn_uj * 0.9, "direct should not be cheaper");
    }

    #[test]
    fn config_sweep_larger_width_more_throughput() {
        // A3: wider datapath = more peak TOp/s; efficiency stays within
        // the same order (the paper picked 96 for the efficiency corner).
        let pts = config_sweep(&[48, 96]).unwrap();
        assert!(pts[1].peak_tops > pts[0].peak_tops * 1.5);
        assert!(pts[1].energy_uj > pts[0].energy_uj);
    }

    #[test]
    fn layer_breakdown_has_all_layers() {
        let t = layer_breakdown().unwrap();
        let _ = t; // printable table; 9 layers checked via cifar_stats
        let stats = cifar_stats(SimMode::Fast).unwrap();
        assert_eq!(stats.layers.len(), 9);
    }

    #[test]
    fn soa_ratios_match_paper_claims() {
        let s = soa().unwrap();
        // §8: "5-15× lower" energy/op than the TCN-KWS accelerator
        assert!(s.kws_ratio > 3.0, "kws ratio {}", s.kws_ratio);
        // TrueNorth ~3250× and Loihi ~63× at our measured DVS energy —
        // our DVS energy may differ from 5.5 µJ, the ratio scales with it
        assert!(s.truenorth_ratio > 500.0);
        assert!(s.loihi_ratio > 10.0);
    }
}

// ---------------------------------------------------------------------------
// A3 — configuration-size ablation (§8: "we improve on these
// characteristics by ... using a smaller CUTIE configuration" — the
// Kraken instance is 96-channel vs the original CUTIE paper's 128)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ConfigPoint {
    pub channels: usize,
    pub energy_uj: f64,
    pub peak_tops: f64,
    pub peak_tops_w: f64,
    pub cycles: u64,
}

/// Sweep the accelerator channel width on a matched CIFAR-9 network
/// (in/out channels scale with the datapath; same 0.33 sparsity).
pub fn config_sweep(widths: &[usize]) -> Result<Vec<ConfigPoint>> {
    let p = EnergyParams::default();
    widths
        .iter()
        .map(|&c| {
            let net = cifar9_random(c, 1, 0.33);
            let mut rng = Rng::new(2);
            let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
            let cfg = CutieConfig { channels: c, ..CutieConfig::kraken() };
            let mut s = Scheduler::new(cfg, SimMode::Accurate);
            s.preload_weights(&net);
            let (_, stats) = s.run_full(&net, &input)?;
            let r = evaluate(&stats, 0.5, None, &p)?;
            Ok(ConfigPoint {
                channels: c,
                energy_uj: r.energy_j * 1e6,
                peak_tops: r.peak_tops,
                peak_tops_w: r.peak_tops_per_watt,
                cycles: stats.total_cycles(),
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Per-layer breakdown (`tcn-cutie report layers`)
// ---------------------------------------------------------------------------

/// Per-layer cycle/activity/energy table for the CIFAR workload — the
/// drill-down behind the Figure 6 "peak layer" story.
pub fn layer_breakdown() -> Result<Table> {
    let p = EnergyParams::default();
    let stats = cifar_stats(SimMode::Accurate)?;
    let mut t = Table::new(&[
        "layer", "cycles", "act OCUs", "toggles", "toggle rate", "hw GOp", "µJ @0.5V", "TOp/s/W",
    ]);
    for l in &stats.layers {
        let one = RunStats { layers: vec![l.clone()], ..Default::default() };
        let r = evaluate(&one, 0.5, None, &p)?;
        let clocked = l.mac_toggles + l.mac_idle;
        t.row(&[
            l.name.clone(),
            l.total_cycles().to_string(),
            l.active_ocus.to_string(),
            l.mac_toggles.to_string(),
            format!("{:.3}", if clocked > 0 { l.mac_toggles as f64 / clocked as f64 } else { 0.0 }),
            format!("{:.2}", l.hw_ops as f64 / 1e9),
            format!("{:.3}", r.energy_j * 1e6),
            format!("{:.0}", r.peak_tops_per_watt),
        ]);
    }
    Ok(t)
}
