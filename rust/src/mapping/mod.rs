//! §4 of the paper: offline mapping of dilated 1D convolutions onto the
//! undilated 3x3 2D datapath. Bit-for-bit mirror of
//! `python/compile/tcn_mapping.py` (derivation documented there):
//!
//!   z[q, m] = x~[q*D + m];  prepend one zero row;  standard same-padded
//!   3x3 conv with the 1D taps bottom-aligned in the middle column;
//!   y[n] = out[n / D, n % D].

use crate::tensor::{IntTensor, PackedMap, TritTensor};

/// Rows of the wrapped map z (excluding the causal pad row).
pub fn wrapped_rows(t_len: usize, dilation: usize) -> usize {
    t_len.div_ceil(dilation)
}

/// Wrap a (T, C) sequence into the (R+1, D, C) dense 2D map (leading zero
/// row = causal padding, white cells of Fig. 3).
pub fn map_input(x: &TritTensor, dilation: usize) -> TritTensor {
    assert_eq!(x.dims.len(), 2, "expected (T, C)");
    let (t_len, c) = (x.dims[0], x.dims[1]);
    let rows = wrapped_rows(t_len, dilation);
    let mut z = TritTensor::zeros(&[rows + 1, dilation, c]);
    for n in 0..t_len {
        let (q, m) = (n / dilation, n % dilation);
        for ch in 0..c {
            z.set3(q + 1, m, ch, x.data[n * c + ch]);
        }
    }
    z
}

/// Packed twin of [`map_input`] (perf pass iteration 9): wrap a
/// (T, 1, C) packed feature sequence into the (R+1, D, C) wrapped map
/// with pure word-level copies — leading causal zero row included,
/// nothing round-trips through i8. Bit-identical to
/// `PackedMap::from_trit(&map_input(seq_i8, d))`; the property sweep in
/// `tests/tcn_packed.rs` enforces it.
pub fn map_input_packed(seq: &PackedMap, dilation: usize) -> PackedMap {
    assert_eq!(seq.w, 1, "expected a (T, 1, C) packed sequence");
    let (t_len, c) = (seq.h, seq.c);
    let rows = wrapped_rows(t_len, dilation);
    let mut z = PackedMap::zeros(rows + 1, dilation, c);
    for n in 0..t_len {
        let (q, m) = (n / dilation, n % dilation);
        z.pixels[(q + 1) * dilation + m] = seq.pixels[n];
    }
    z
}

/// Packed twin of the §4 un-mapping: gather y[n] = z2d[n / D, n % D]
/// back into a (T, 1, C_out) packed sequence — address arithmetic and
/// whole-word gathers only, no cycles, no data conversion (the ternary
/// wrapped-map outputs stay in their (pos, mask) encoding between TCN
/// layers).
pub fn unmap_output_packed(acc2d: &PackedMap, t_len: usize, dilation: usize) -> PackedMap {
    assert_eq!(acc2d.w, dilation, "wrapped map width must equal the dilation");
    let mut out = PackedMap::zeros(t_len, 1, acc2d.c);
    for n in 0..t_len {
        let (q, m) = (n / dilation, n % dilation);
        out.pixels[n] = acc2d.pixels[q * dilation + m];
    }
    out
}

/// Project (N, Cin, Cout) 1D taps into the middle column of a 3x3 kernel,
/// bottom-aligned: W[3-N+j][1] = w[j].
pub fn map_weights(w: &TritTensor) -> TritTensor {
    assert_eq!(w.dims.len(), 3, "expected (N, Cin, Cout)");
    let (n, cin, cout) = (w.dims[0], w.dims[1], w.dims[2]);
    assert!(n <= 3, "CUTIE supports kernels up to 3 taps, got {n}");
    let mut out = TritTensor::zeros(&[3, 3, cin, cout]);
    for j in 0..n {
        for ci in 0..cin {
            for co in 0..cout {
                let src = (j * cin + ci) * cout + co;
                let dst = (((3 - n + j) * 3 + 1) * cin + ci) * cout + co;
                out.data[dst] = w.data[src];
            }
        }
    }
    out
}

/// Extract the (T, Cout) outputs: y[n] = acc2d[n / D, n % D, :].
pub fn unmap_output(acc2d: &IntTensor, t_len: usize, dilation: usize) -> IntTensor {
    assert_eq!(acc2d.dims.len(), 3);
    let (d, cout) = (acc2d.dims[1], acc2d.dims[2]);
    assert_eq!(d, dilation);
    let mut out = IntTensor::zeros(&[t_len, cout]);
    for n in 0..t_len {
        let (q, m) = (n / dilation, n % dilation);
        for co in 0..cout {
            out.data[n * cout + co] = acc2d.data[(q * d + m) * cout + co];
        }
    }
    out
}

/// Receptive field of a stack of causal dilated conv layers.
pub fn receptive_field(n_taps: usize, dilations: &[usize]) -> usize {
    1 + dilations.iter().map(|d| (n_taps - 1) * d).sum::<usize>()
}

/// Number of memory accesses a *direct* strided implementation would issue
/// non-contiguously per output step (N-1 strided reads; the mapped version
/// issues zero). Used by the A2 mapping ablation.
pub fn direct_strided_accesses(n_taps: usize) -> usize {
    n_taps.saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Eq. (1) transcribed literally.
    fn naive_dilated_conv1d(x: &TritTensor, w: &TritTensor, d: usize) -> IntTensor {
        let (t_len, cin) = (x.dims[0], x.dims[1]);
        let (n, _, cout) = (w.dims[0], w.dims[1], w.dims[2]);
        let mut out = IntTensor::zeros(&[t_len, cout]);
        for t in 0..t_len {
            for k in 1..=n {
                let shift = (k - 1) * d;
                if t >= shift {
                    let src = t - shift;
                    for ci in 0..cin {
                        let xv = x.data[src * cin + ci] as i32;
                        if xv == 0 {
                            continue;
                        }
                        for co in 0..cout {
                            out.data[t * cout + co] +=
                                xv * w.data[((n - k) * cin + ci) * cout + co] as i32;
                        }
                    }
                }
            }
        }
        out
    }

    /// Plain same-padded 3x3 ternary conv (scalar, for the test only).
    fn conv2d_naive(x: &TritTensor, w: &TritTensor) -> IntTensor {
        let (h, wid, cin) = (x.dims[0], x.dims[1], x.dims[2]);
        let (kh, kw, _, cout) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
        let (ph, pw) = (kh / 2, kw / 2);
        let mut out = IntTensor::zeros(&[h, wid, cout]);
        for y in 0..h {
            for xx in 0..wid {
                for dy in 0..kh {
                    for dx in 0..kw {
                        let sy = y as isize + dy as isize - ph as isize;
                        let sx = xx as isize + dx as isize - pw as isize;
                        if sy < 0 || sx < 0 || sy >= h as isize || sx >= wid as isize {
                            continue;
                        }
                        for ci in 0..cin {
                            let xv = x.get3(sy as usize, sx as usize, ci) as i32;
                            if xv == 0 {
                                continue;
                            }
                            let obase = out.idx3(y, xx, 0);
                            for co in 0..cout {
                                out.data[obase + co] +=
                                    xv * w.data[((dy * kw + dx) * cin + ci) * cout + co] as i32;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn mapping_equals_dilated_1d_property() {
        // Seeded sweep over T, D, N, channels — the paper's exactness claim.
        let mut rng = Rng::new(42);
        for case in 0..120 {
            let t_len = 1 + rng.below(30);
            let d = 1 + rng.below(9);
            let n = 1 + rng.below(3);
            let cin = 1 + rng.below(6);
            let cout = 1 + rng.below(6);
            let zf = [0.0, 0.4, 0.8][case % 3];
            let x = TritTensor::random(&[t_len, cin], &mut rng, zf);
            let w = TritTensor::random(&[n, cin, cout], &mut rng, zf);

            let z = map_input(&x, d);
            assert_eq!(z.dims, vec![wrapped_rows(t_len, d) + 1, d, cin]);
            let w2d = map_weights(&w);
            let acc2d = conv2d_naive(&z, &w2d);
            let got = unmap_output(&acc2d, t_len, d);

            let want = naive_dilated_conv1d(&x, &w, d);
            assert_eq!(got, want, "t={t_len} d={d} n={n} cin={cin} cout={cout}");
        }
    }

    #[test]
    fn packed_wrap_matches_i8_wrap_property() {
        // Seeded sweep: word-copy wrapping == pack(map_input(i8)), and
        // the packed unmap inverts the placement (row q readout).
        let mut rng = Rng::new(43);
        for case in 0..150 {
            let t_len = 1 + rng.below(30);
            let d = 1 + rng.below(9);
            let c = 1 + rng.below(96);
            let zf = [0.0, 0.4, 0.8, 0.95][case % 4];
            let x = TritTensor::random(&[t_len, c], &mut rng, zf);
            let seq = PackedMap::from_trit(&TritTensor::from_vec(&[t_len, 1, c], x.data.clone()));
            let zp = map_input_packed(&seq, d);
            let zi = map_input(&x, d);
            assert_eq!(
                zp,
                PackedMap::from_trit(&zi),
                "wrap t={t_len} d={d} c={c} case={case}"
            );
            // unmap gathers row q — same addressing as unmap_output
            let un = unmap_output_packed(&zp, t_len, d);
            assert_eq!((un.h, un.w, un.c), (t_len, 1, c));
            for n in 0..t_len {
                let (q, m) = (n / d, n % d);
                assert_eq!(*un.pixel(n, 0), *zp.pixel(q, m), "unmap n={n}");
            }
        }
    }

    #[test]
    fn map_weights_layout() {
        // Fig. 3 configuration: N=2 taps bottom-aligned in middle column.
        let w = TritTensor::from_vec(&[2, 1, 1], vec![1, -1]);
        let w2d = map_weights(&w);
        assert_eq!(w2d.dims, vec![3, 3, 1, 1]);
        let at = |r: usize, c: usize| w2d.data[(r * 3 + c) * 1];
        assert_eq!(at(0, 1), 0);
        assert_eq!(at(1, 1), 1);
        assert_eq!(at(2, 1), -1);
        for r in 0..3 {
            assert_eq!(at(r, 0), 0);
            assert_eq!(at(r, 2), 0);
        }
    }

    #[test]
    fn receptive_field_paper() {
        assert_eq!(receptive_field(3, &[1, 2, 4, 8]), 31);
        assert_eq!(receptive_field(3, &[1; 12]), 25); // 12 undilated layers cover 24+
    }

    #[test]
    fn dvs_maps_fit_hardware() {
        // All DVS TCN layers must produce maps within the 64x64 limit.
        for d in [1, 2, 4, 8] {
            assert!(wrapped_rows(24, d) + 1 <= 64);
        }
    }
}
