//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client via the
//! `xla` crate. Python never runs here — the artifacts are self-contained.
//!
//! In this reproduction the runtime plays the role of the **golden model**
//! in a classic hardware/software co-simulation flow: the cycle-level
//! CUTIE simulator's outputs are checked against the XLA execution of the
//! very same network (lowered from the same JAX source the Pallas kernels
//! live in). See `golden` and the `golden_pjrt` integration test.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod golden;

use std::path::Path;

use anyhow::{Context, Result};

use crate::tensor::TritTensor;

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModel {
            name: path.file_stem().unwrap().to_string_lossy().into_owned(),
            exe,
        })
    }
}

impl LoadedModel {
    /// Execute with one f32 input of shape `dims`; returns the flat f32
    /// output (artifacts are lowered with return_tuple=True and a single
    /// result).
    pub fn run_f32(&self, input: &[f32], dims: &[usize]) -> Result<Vec<f32>> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims_i64)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with a trit tensor (converted to f32 — the artifact ABI).
    pub fn run_trits(&self, t: &TritTensor) -> Result<Vec<f32>> {
        let input: Vec<f32> = t.data.iter().map(|&x| x as f32).collect();
        self.run_f32(&input, &t.dims)
    }
}

/// Round a f32 artifact output back to i32 (values are exact small ints).
pub fn to_i32(v: &[f32]) -> Vec<i32> {
    v.iter().map(|&x| x.round() as i32).collect()
}

/// Round a f32 artifact output back to trits, validating the range.
pub fn to_trits(v: &[f32]) -> Result<Vec<i8>> {
    v.iter()
        .map(|&x| {
            let r = x.round() as i32;
            anyhow::ensure!((-1..=1).contains(&r), "non-trit output {x}");
            Ok(r as i8)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_i32_rounds_exactly() {
        assert_eq!(to_i32(&[1.0, -3.0, 0.0]), vec![1, -3, 0]);
    }

    #[test]
    fn to_trits_validates() {
        assert!(to_trits(&[1.0, 0.0, -1.0]).is_ok());
        assert!(to_trits(&[2.0]).is_err());
    }
}
