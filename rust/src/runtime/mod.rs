//! PJRT golden-model runtime interface.
//!
//! In the full environment this loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client (the
//! `xla` PJRT bindings), playing the golden-model role of a classic
//! hardware/software co-simulation flow: the cycle-level CUTIE
//! simulator's outputs are checked against the XLA execution of the very
//! same network. See `golden` and the `golden_pjrt` integration test.
//!
//! The build environment for this repository is fully offline (crates.io
//! and the `xla_extension` binary distribution are unreachable), so the
//! PJRT client is **stubbed**: the API surface is kept intact — the
//! golden tests and examples gate on the presence of the AOT artifacts
//! and skip cleanly when they are absent — but constructing a [`Runtime`]
//! reports an explanatory error instead of linking XLA. Swapping the stub
//! back for the real bindings only touches this file.

pub mod golden;

use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::TritTensor;

/// Error text shared by every stubbed entry point.
const OFFLINE_MSG: &str = "PJRT/XLA runtime unavailable in this offline build: \
     the `xla` bindings and `xla_extension` runtime are not vendored. \
     Golden co-simulation requires the full environment (see runtime/mod.rs)";

/// Handle to a PJRT client (stub: carries only the platform label).
pub struct Runtime {
    platform: String,
}

/// A loaded + compiled HLO artifact (stub: never constructed).
pub struct LoadedModel {
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client. Always errors in the offline build.
    pub fn cpu() -> Result<Runtime> {
        bail!("{OFFLINE_MSG}")
    }

    pub fn platform(&self) -> String {
        self.platform.clone()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<LoadedModel> {
        bail!("cannot load {}: {OFFLINE_MSG}", path.as_ref().display())
    }
}

impl LoadedModel {
    /// Execute with one f32 input of shape `dims`; returns the flat f32
    /// output (artifacts are lowered with return_tuple=True and a single
    /// result).
    pub fn run_f32(&self, _input: &[f32], _dims: &[usize]) -> Result<Vec<f32>> {
        bail!("cannot execute '{}': {OFFLINE_MSG}", self.name)
    }

    /// Execute with a trit tensor (converted to f32 — the artifact ABI).
    pub fn run_trits(&self, t: &TritTensor) -> Result<Vec<f32>> {
        let input: Vec<f32> = t.data.iter().map(|&x| x as f32).collect();
        self.run_f32(&input, &t.dims)
    }
}

/// Round a f32 artifact output back to i32 (values are exact small ints).
pub fn to_i32(v: &[f32]) -> Vec<i32> {
    v.iter().map(|&x| x.round() as i32).collect()
}

/// Round a f32 artifact output back to trits, validating the range.
pub fn to_trits(v: &[f32]) -> Result<Vec<i8>> {
    v.iter()
        .map(|&x| {
            let r = x.round() as i32;
            anyhow::ensure!((-1..=1).contains(&r), "non-trit output {x}");
            Ok(r as i8)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_i32_rounds_exactly() {
        assert_eq!(to_i32(&[1.0, -3.0, 0.0]), vec![1, -3, 0]);
    }

    #[test]
    fn to_trits_validates() {
        assert!(to_trits(&[1.0, 0.0, -1.0]).is_ok());
        assert!(to_trits(&[2.0]).is_err());
    }

    #[test]
    fn offline_stub_reports_clearly() {
        let err = Runtime::cpu().unwrap_err().to_string();
        assert!(err.contains("offline"), "unexpected error text: {err}");
    }
}
