//! Golden-model co-simulation: run the same input through (a) the
//! cycle-level CUTIE simulator and (b) the XLA execution of the
//! JAX-authored network, and require identical integer outputs.

use anyhow::{ensure, Result};

use super::{to_i32, LoadedModel, Runtime};
use crate::cutie::{CutieConfig, Scheduler, SimMode};
use crate::network::Network;
use crate::tensor::{PackedMap, TritTensor};

/// Result of one co-simulation check.
#[derive(Debug)]
pub struct GoldenCheck {
    pub sim_logits: Vec<i32>,
    pub xla_logits: Vec<i32>,
    pub matched: bool,
}

/// cifar-style network: one (H, W, C) input → logits.
pub fn check_feedforward(
    rt: &Runtime,
    model: &LoadedModel,
    net: &Network,
    input: &TritTensor,
) -> Result<GoldenCheck> {
    let _ = rt;
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
    let (logits, _) = sched.run_full(net, input)?;
    let xla_out = to_i32(&model.run_trits(input)?);
    ensure!(xla_out.len() == logits.data.len(), "logit arity mismatch");
    let matched = xla_out == logits.data;
    Ok(GoldenCheck { sim_logits: logits.data.clone(), xla_logits: xla_out, matched })
}

/// Hybrid network served frame-by-frame: the simulator drives its TCN
/// memory; the XLA side gets the equivalent (T, C) window for the
/// back-end artifact.
pub fn check_hybrid(
    cnn: &LoadedModel,
    tcn: &LoadedModel,
    net: &Network,
    frames: &TritTensor,
) -> Result<GoldenCheck> {
    ensure!(frames.dims.len() == 4, "frames must be (T, H, W, C)");
    let (t_len, h, w, c) = (frames.dims[0], frames.dims[1], frames.dims[2], frames.dims[3]);
    ensure!(t_len > 0, "hybrid co-simulation needs at least one frame");
    let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);

    // XLA window accumulates CNN features exactly like the TCN memory.
    let tcn_head = net.tcn_layers().next();
    let feat_ch = tcn_head.ok_or_else(|| anyhow::anyhow!("network has no TCN layers"))?.in_ch;
    let mut window = vec![0f32; net.tcn_steps * feat_ch];
    let mut sim_logits = None;
    for t in 0..t_len {
        let frame = TritTensor::from_vec(
            &[h, w, c],
            frames.data[t * h * w * c..(t + 1) * h * w * c].to_vec(),
        );
        let (logits, _) = sched.serve_frame(net, &PackedMap::from_trit(&frame))?;
        sim_logits = Some(logits);
        let feat = cnn.run_trits(&frame)?;
        ensure!(feat.len() == feat_ch, "cnn artifact feature width");
        // shift the window like the 24-deep shift register
        window.drain(..feat_ch);
        window.extend_from_slice(&feat);
    }
    let xla_logits = to_i32(&tcn.run_f32(&window, &[net.tcn_steps, feat_ch])?);
    let sim = sim_logits.expect("t_len > 0 checked above").data;
    let matched = sim == xla_logits;
    Ok(GoldenCheck { sim_logits: sim, xla_logits, matched })
}
