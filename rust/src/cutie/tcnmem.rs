//! The paper's TCN memory (§4): a 576-byte flip-flop shift register
//! holding 24 time-step feature vectors of 96 trits. Each CNN inference
//! pushes one vector; the TCN front reads the whole window as the wrapped
//! 2D map, with "the output of the TCN memory [having] the same size as
//! the activation memory... achieved by multiplexing three time steps
//! according to the address of the first required pixel" — i.e. reads are
//! address-multiplexed, never marshalled.
//!
//! Since perf pass iteration 9 the memory is **packed-native**: it stores
//! the CNN's (pos, mask) feature words as-is ([`TcnMemory::push_packed`])
//! and its read port produces the §4 wrapped map directly as a
//! [`PackedMap`] ([`TcnMemory::wrap_image`]) — causal zero row,
//! cold-start zero padding and (q+1, m) placement are pure word-level
//! copies, exactly the no-marshalling property the silicon's multiplexed
//! read port has. The i8 entry points ([`TcnMemory::push`],
//! [`TcnMemory::window`]) survive as the reference/ablation edge and the
//! equivalence-test baseline. The ring evicts with a `pop_front`, never
//! an O(depth) element shift (same fix class as the PR 2 linebuffer).

use std::collections::VecDeque;

use crate::tensor::{PackedMap, TritTensor};
use crate::trit::{simd, PackedVec};

pub struct TcnMemory {
    pub depth: usize,
    pub channels: usize,
    /// Newest-last ring of packed feature words (front = oldest).
    steps: VecDeque<PackedVec>,
    pub pushes: u64,
    pub reads: u64,
    /// Trit positions that changed value on shift (flip-flop toggle proxy).
    pub shift_toggles: u64,
}

impl TcnMemory {
    pub fn new(depth: usize, channels: usize) -> Self {
        TcnMemory {
            depth,
            channels,
            steps: VecDeque::with_capacity(depth),
            pushes: 0,
            reads: 0,
            shift_toggles: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.steps.len() == self.depth
    }

    /// Push one packed feature word straight off the CNN's 1×1 feature
    /// map — the word IS the stored SCM content, nothing is unpacked or
    /// re-packed. Plane bits at positions ≥ the word's channel width are
    /// clear by the `PackedMap` invariant, so narrow features ride
    /// zero-padded for free (unused channels are tied off, as in the
    /// RTL). Oldest step drops once full. Counts flip-flop toggle
    /// activity: every occupied slot shifts by one position.
    pub fn push_packed(&mut self, v: PackedVec) {
        // the packed twin of the old i8 width assert: a word with plane
        // bits at positions ≥ the memory's channel count would silently
        // lose them on the i8 window() read
        assert!(v.masked(self.channels) == v, "feature word wider than the {}-channel memory", self.channels);
        // toggle proxy: each resident vector moves one slot; charge the
        // non-zero trits that physically flip wires.
        for s in &self.steps {
            self.shift_toggles += s.count_nonzero() as u64;
        }
        if self.steps.len() == self.depth {
            self.steps.pop_front();
        }
        self.steps.push_back(v);
        self.pushes += 1;
    }

    /// i8-edge push (reference executor and tests): packs, then stores.
    pub fn push(&mut self, feat: &[i8]) {
        assert_eq!(feat.len(), self.channels, "feature width");
        self.push_packed(PackedVec::pack(feat));
    }

    /// Read the window as a (T, C) sequence, zero-padded at the old end if
    /// fewer than `depth` steps have been pushed (cold start). i8
    /// reference path; the frame loop reads [`wrap_image`] instead.
    pub fn window(&mut self) -> TritTensor {
        self.reads += self.steps.len() as u64;
        let mut out = TritTensor::zeros(&[self.depth, self.channels]);
        let pad = self.depth - self.steps.len();
        for (i, s) in self.steps.iter().enumerate() {
            for c in 0..self.channels {
                out.data[(pad + i) * self.channels + c] = s.get(c);
            }
        }
        out
    }

    /// Read the window as a (T, 1, C_f) packed column of feature words —
    /// the packed twin of [`window`] sliced to `feat_ch` channels
    /// (word-level masking replaces the slice), charging the same read
    /// activity.
    pub fn packed_window(&mut self, feat_ch: usize) -> PackedMap {
        self.reads += self.steps.len() as u64;
        let mut out = PackedMap::zeros(self.depth, 1, feat_ch);
        let pad = self.depth - self.steps.len();
        // resident step i lands at pixel pad + i: one contiguous run,
        // masked-copied through the SIMD backend (ring words carry
        // hardware-width plane bits; the read port clamps to feat_ch)
        let (a, b) = self.steps.as_slices();
        simd::copy_words_masked(&mut out.pixels[pad..pad + a.len()], a, feat_ch);
        simd::copy_words_masked(&mut out.pixels[pad + a.len()..self.depth], b, feat_ch);
        out
    }

    /// The §4 address-multiplexed read port: produce the wrapped
    /// (R+1, D, C_f) map for dilation `d` directly from the ring.
    /// Leading causal zero row, cold-start zero padding and the
    /// z[q+1, m] = x[q·D + m] placement are all word-level copies — no
    /// (T, C) window is materialized and nothing round-trips through i8.
    /// Charges the same read activity as [`window`] (one read per
    /// resident step: the port multiplexes, it does not copy).
    pub fn wrap_image(&mut self, d: usize, feat_ch: usize) -> PackedMap {
        self.reads += self.steps.len() as u64;
        let rows = self.depth.div_ceil(d);
        let mut z = PackedMap::zeros(rows + 1, d, feat_ch);
        let pad = self.depth - self.steps.len();
        // step n = pad + i lands at (q+1, m) = pixel (n/d + 1)·d + n%d
        // = n + d: the whole wrap is ONE contiguous run starting after
        // the causal row, masked-copied through the SIMD backend
        let (a, b) = self.steps.as_slices();
        let base = d + pad;
        simd::copy_words_masked(&mut z.pixels[base..base + a.len()], a, feat_ch);
        simd::copy_words_masked(&mut z.pixels[base + a.len()..d + self.depth], b, feat_ch);
        z
    }

    /// Mutable access to the resident ring words, oldest first — the
    /// fault-injection surface over the flip-flop ring (and the scrub
    /// pass's scan path). Exposes exactly the `len()` occupied slots;
    /// counters are untouched, the caller charges its own scrub costs.
    pub fn words_mut(&mut self) -> impl Iterator<Item = &mut PackedVec> + '_ {
        self.steps.iter_mut()
    }

    /// Read-only view of the resident ring words, oldest first — the
    /// hibernation snapshot path. Counters are untouched: snapshotting is
    /// not a functional read of the memory.
    pub fn words(&self) -> impl Iterator<Item = &PackedVec> + '_ {
        self.steps.iter()
    }

    /// Rebuild a memory from snapshotted parts, re-validating the push
    /// invariants (occupancy ≤ depth, every word masked to the channel
    /// width) so a forged or corrupted snapshot cannot materialize a
    /// state no legal push sequence produces.
    pub fn from_parts(
        depth: usize,
        channels: usize,
        steps: Vec<PackedVec>,
        pushes: u64,
        reads: u64,
        shift_toggles: u64,
    ) -> anyhow::Result<TcnMemory> {
        anyhow::ensure!(
            steps.len() <= depth,
            "snapshot holds {} steps but the memory is {depth} deep",
            steps.len()
        );
        for (i, s) in steps.iter().enumerate() {
            anyhow::ensure!(
                s.masked(channels) == *s,
                "snapshot step {i} has plane bits beyond the {channels}-channel width"
            );
        }
        Ok(TcnMemory { depth, channels, steps: steps.into(), pushes, reads, shift_toggles })
    }

    /// Memory size in bytes (2-bit trits) — §5 sizes this at 576 B.
    /// Rounded up per step, so channel widths that are not a multiple of
    /// 4 don't under-report (e.g. depth=4, channels=3 is 4 B, not the
    /// truncated 3 B).
    pub fn size_bytes(&self) -> usize {
        self.depth * (self.channels * 2).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kraken_is_576_bytes() {
        let m = TcnMemory::new(24, 96);
        assert_eq!(m.size_bytes(), 576);
    }

    #[test]
    fn size_bytes_rounds_up_per_step() {
        // 3 channels = 6 bits/step → 1 byte/step × 4 steps = 4 B; the
        // old whole-memory truncation (4·3·2/8) under-reported.
        assert_eq!(TcnMemory::new(4, 3).size_bytes(), 4);
        assert_eq!(TcnMemory::new(24, 1).size_bytes(), 24);
        assert_eq!(TcnMemory::new(2, 5).size_bytes(), 2 * 2);
    }

    #[test]
    fn fifo_semantics() {
        let mut m = TcnMemory::new(3, 4);
        m.push(&[1, 0, 0, 0]);
        m.push(&[0, 1, 0, 0]);
        m.push(&[0, 0, 1, 0]);
        assert!(m.is_full());
        m.push(&[0, 0, 0, 1]); // evicts the first
        let w = m.window();
        assert_eq!(w.dims, vec![3, 4]);
        assert_eq!(&w.data[0..4], &[0, 1, 0, 0]);
        assert_eq!(&w.data[8..12], &[0, 0, 0, 1]);
    }

    #[test]
    fn packed_push_matches_i8_push() {
        let mut a = TcnMemory::new(3, 4);
        let mut b = TcnMemory::new(3, 4);
        for step in [[1i8, -1, 0, 0], [0, 0, 1, 0], [-1, -1, -1, 1], [0, 1, 0, 0]] {
            a.push(&step);
            b.push_packed(PackedVec::pack(&step));
            assert_eq!(a.window().data, b.window().data);
            assert_eq!(a.pushes, b.pushes);
            assert_eq!(a.shift_toggles, b.shift_toggles);
        }
    }

    #[test]
    fn cold_start_zero_pads_old_end() {
        let mut m = TcnMemory::new(4, 2);
        m.push(&[1, -1]);
        let w = m.window();
        assert_eq!(w.data, vec![0, 0, 0, 0, 0, 0, 1, -1]);
        // packed twin: same padding, same content, as packed words
        let p = m.packed_window(2);
        assert_eq!((p.h, p.w, p.c), (4, 1, 2));
        assert_eq!(p.unpack_data(), w.data);
    }

    #[test]
    fn wrap_image_places_causal_row_and_cold_start() {
        // depth 4, one resident step [1, -1], dilation 2: n = 3 lands at
        // (q+1, m) = (2, 1); rows 0 (causal) and all padded cells zero.
        let mut m = TcnMemory::new(4, 2);
        m.push(&[1, -1]);
        let z = m.wrap_image(2, 2);
        assert_eq!((z.h, z.w, z.c), (3, 2, 2));
        for y in 0..3 {
            for x in 0..2 {
                let want: &[i8] = if (y, x) == (2, 1) { &[1, -1] } else { &[0, 0] };
                assert_eq!(z.pixel(y, x).unpack(2), want, "({y}, {x})");
            }
        }
        assert_eq!(m.reads, 1, "one resident step multiplexed once");
    }

    #[test]
    fn packed_window_masks_to_feature_width() {
        // A full-width i8 push with junk above feat_ch must read back
        // masked, matching the i8 path's channel slice.
        let mut m = TcnMemory::new(2, 6);
        m.push(&[1, -1, 0, 1, 1, -1]);
        let p = m.packed_window(3);
        assert_eq!(p.c, 3);
        assert_eq!(p.pixel(1, 0).unpack(6), vec![1, -1, 0, 0, 0, 0]);
    }

    #[test]
    fn shift_toggles_grow_with_occupancy() {
        let mut m = TcnMemory::new(8, 4);
        m.push(&[1, 1, 1, 1]);
        assert_eq!(m.shift_toggles, 0); // nothing resident before first push
        m.push(&[1, 0, 0, 0]);
        assert_eq!(m.shift_toggles, 4); // one full vector shifted
        m.push(&[0, 0, 0, 0]);
        assert_eq!(m.shift_toggles, 4 + 4 + 1);
    }
}
