//! The paper's TCN memory (§4): a 576-byte flip-flop shift register
//! holding 24 time-step feature vectors of 96 trits. Each CNN inference
//! pushes one vector; the TCN front reads the whole window as the wrapped
//! 2D map, with "the output of the TCN memory [having] the same size as
//! the activation memory... achieved by multiplexing three time steps
//! according to the address of the first required pixel" — i.e. reads are
//! address-multiplexed, never marshalled.

use crate::tensor::TritTensor;
use crate::trit::PackedVec;

pub struct TcnMemory {
    pub depth: usize,
    pub channels: usize,
    /// Newest-last ring of feature vectors.
    steps: Vec<PackedVec>,
    pub pushes: u64,
    pub reads: u64,
    /// Trit positions that changed value on shift (flip-flop toggle proxy).
    pub shift_toggles: u64,
}

impl TcnMemory {
    pub fn new(depth: usize, channels: usize) -> Self {
        TcnMemory { depth, channels, steps: Vec::new(), pushes: 0, reads: 0, shift_toggles: 0 }
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.steps.len() == self.depth
    }

    /// Push one feature vector (oldest drops once full). Counts flip-flop
    /// toggle activity: every occupied slot shifts by one position.
    pub fn push(&mut self, feat: &[i8]) {
        assert_eq!(feat.len(), self.channels, "feature width");
        let v = PackedVec::pack(feat);
        // toggle proxy: each resident vector moves one slot; charge the
        // non-zero trits that physically flip wires.
        for s in &self.steps {
            self.shift_toggles += s.count_nonzero() as u64;
        }
        if self.steps.len() == self.depth {
            self.steps.remove(0);
        }
        self.steps.push(v);
        self.pushes += 1;
    }

    /// Read the window as a (T, C) sequence, zero-padded at the old end if
    /// fewer than `depth` steps have been pushed (cold start).
    pub fn window(&mut self) -> TritTensor {
        self.reads += self.steps.len() as u64;
        let mut out = TritTensor::zeros(&[self.depth, self.channels]);
        let pad = self.depth - self.steps.len();
        for (i, s) in self.steps.iter().enumerate() {
            for c in 0..self.channels {
                out.data[(pad + i) * self.channels + c] = s.get(c);
            }
        }
        out
    }

    /// Memory size in bytes (2-bit trits) — §5 sizes this at 576 B.
    pub fn size_bytes(&self) -> usize {
        self.depth * self.channels * 2 / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kraken_is_576_bytes() {
        let m = TcnMemory::new(24, 96);
        assert_eq!(m.size_bytes(), 576);
    }

    #[test]
    fn fifo_semantics() {
        let mut m = TcnMemory::new(3, 4);
        m.push(&[1, 0, 0, 0]);
        m.push(&[0, 1, 0, 0]);
        m.push(&[0, 0, 1, 0]);
        assert!(m.is_full());
        m.push(&[0, 0, 0, 1]); // evicts the first
        let w = m.window();
        assert_eq!(w.dims, vec![3, 4]);
        assert_eq!(&w.data[0..4], &[0, 1, 0, 0]);
        assert_eq!(&w.data[8..12], &[0, 0, 0, 1]);
    }

    #[test]
    fn cold_start_zero_pads_old_end() {
        let mut m = TcnMemory::new(4, 2);
        m.push(&[1, -1]);
        let w = m.window();
        assert_eq!(w.data, vec![0, 0, 0, 0, 0, 0, 1, -1]);
    }

    #[test]
    fn shift_toggles_grow_with_occupancy() {
        let mut m = TcnMemory::new(8, 4);
        m.push(&[1, 1, 1, 1]);
        assert_eq!(m.shift_toggles, 0); // nothing resident before first push
        m.push(&[1, 0, 0, 0]);
        assert_eq!(m.shift_toggles, 4); // one full vector shifted
        m.push(&[0, 0, 0, 0]);
        assert_eq!(m.shift_toggles, 4 + 4 + 1);
    }
}
