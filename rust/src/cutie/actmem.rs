//! Double-buffered activation SRAM. One word = one pixel = 2·C bits —
//! and since perf pass iteration 8 the buffers hold exactly that
//! representation: ping-ponged [`PackedMap`]s whose per-pixel (pos, mask)
//! bitplanes are the SRAM words, so the access counters below count real
//! packed words and feature maps never exist in i8 form between layers.
//! The datapath reads layer N's input from one buffer and writes layer
//! N's output to the other; buffers swap between layers (ping-pong), so
//! feature maps never move. Below 0.5 V the macros bit-error (§7) — the
//! model exposes `min_voltage`.

use anyhow::{ensure, Result};

use crate::tensor::PackedMap;

pub struct ActivationMemory {
    pub max_hw: usize,
    pub channels: usize,
    /// Ping-pong buffers as whole packed feature maps.
    buf: [Option<PackedMap>; 2],
    /// Which buffer the next layer reads from.
    front: usize,
    pub reads: u64,
    pub writes: u64,
}

/// SRAM macros bit-error below this supply (§7: "Below 0.5 V, the
/// integrated SRAM macros start exhibiting bit errors").
pub const MIN_SRAM_VOLTAGE: f64 = 0.5;

impl ActivationMemory {
    pub fn new(max_hw: usize, channels: usize) -> Self {
        ActivationMemory { max_hw, channels, buf: [None, None], front: 0, reads: 0, writes: 0 }
    }

    /// Capacity check for an H×W×C feature map.
    pub fn fits(&self, h: usize, w: usize, c: usize) -> bool {
        h <= self.max_hw && w <= self.max_hw && c <= self.channels
    }

    /// Typed form of [`fits`](Self::fits) — shared by the loads below
    /// and by the lane-batched CNN path, whose per-lane maps ping-pong
    /// outside these buffers (the K lanes time-multiplex one physical
    /// SRAM) but must still respect the modeled geometry.
    pub fn ensure_fits(&self, h: usize, w: usize, c: usize) -> Result<()> {
        ensure!(
            self.fits(h, w, c),
            "feature map {h}×{w}×{c} exceeds {}² × {}",
            self.max_hw,
            self.channels
        );
        Ok(())
    }

    /// DMA or front-end write of a whole input map into the front buffer.
    pub fn load_input(&mut self, map: PackedMap) -> Result<()> {
        self.ensure_fits(map.h, map.w, map.c)?;
        self.writes += (map.h * map.w) as u64;
        self.buf[self.front] = Some(map);
        Ok(())
    }

    /// The map the next layer reads.
    pub fn front(&self) -> Option<&PackedMap> {
        self.buf[self.front].as_ref()
    }

    /// Record `n` pixel-word reads from the front buffer.
    pub fn count_reads(&mut self, n: u64) {
        self.reads += n;
    }

    /// Write a layer's output map to the back buffer and swap.
    pub fn store_output_and_swap(&mut self, map: PackedMap) -> Result<()> {
        ensure!(
            self.fits(map.h, map.w, map.c),
            "output {}×{}×{} too large",
            map.h,
            map.w,
            map.c
        );
        self.writes += (map.h * map.w) as u64;
        let back = 1 - self.front;
        self.buf[back] = Some(map);
        self.front = back;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TritTensor;
    use crate::util::rng::Rng;

    fn random_map(dims: &[usize], rng: &mut Rng, zf: f64) -> PackedMap {
        PackedMap::from_trit(&TritTensor::random(dims, rng, zf))
    }

    #[test]
    fn ping_pong_swaps() {
        let mut rng = Rng::new(31);
        let mut mem = ActivationMemory::new(8, 16);
        let a = random_map(&[4, 4, 8], &mut rng, 0.3);
        let b = random_map(&[2, 2, 16], &mut rng, 0.3);
        mem.load_input(a.clone()).unwrap();
        assert_eq!(mem.front().unwrap(), &a);
        mem.store_output_and_swap(b.clone()).unwrap();
        assert_eq!(mem.front().unwrap(), &b);
        assert_eq!(mem.writes, 16 + 4);
    }

    #[test]
    fn rejects_oversized() {
        let mut mem = ActivationMemory::new(4, 8);
        let big = PackedMap::zeros(8, 8, 8);
        assert!(mem.load_input(big).is_err());
        let wide = PackedMap::zeros(2, 2, 16);
        assert!(mem.load_input(wide).is_err());
    }

    #[test]
    fn kraken_capacity() {
        let mem = ActivationMemory::new(64, 96);
        assert!(mem.fits(64, 64, 96));
        assert!(!mem.fits(65, 64, 96));
    }
}
