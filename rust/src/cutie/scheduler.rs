//! Multi-layer scheduler: owns the weight memory, activation memory and
//! TCN memory, sequences layers, charges weight/DMA cycles, and implements
//! the two TCN execution strategies:
//!
//! * `mapped` (the paper's §4 contribution): dilated 1D convs are
//!   projected offline onto plain 3×3 layers — zero stalls;
//! * `direct` (the ablation A2 baseline): dilated taps are fetched with
//!   stride D straight from memory, which breaks the linebuffer and
//!   serializes one word access per tap.
//!
//! Since the shared-image pass the scheduler no longer owns any weight
//! state: all prepared kernels live in an immutable [`PreparedNet`]
//! behind an [`Arc`], either attached by the engine (one copy shared
//! across a whole worker pool) or built lazily on first use for
//! standalone schedulers. [`WeightMemory`] stays as the
//! residency/cycle-charging model over that shared image.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Result};

use super::actmem::ActivationMemory;
use super::datapath::{
    run_dense_packed, run_dense_prepared, run_prepared, run_prepared_lanes, PreparedLayer,
};
use super::prepared::PreparedNet;
use super::stats::{LayerStats, RunStats};
use super::tcnmem::TcnMemory;
use super::weightmem::{WeightAccess, WeightMemory};
use super::{CutieConfig, SimMode};
use crate::network::{Layer, LayerKind, Network};
use crate::tensor::{IntTensor, PackedMap, TritTensor};
use crate::trit::ternarize;

/// How TCN layers are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TcnStrategy {
    /// §4 mapping — the paper's system.
    Mapped,
    /// Direct strided access — the baseline the mapping replaces.
    Direct,
}

pub struct Scheduler {
    pub cfg: CutieConfig,
    pub mode: SimMode,
    pub tcn_strategy: TcnStrategy,
    weights: WeightMemory,
    pub tcn_mem: TcnMemory,
    actmem: ActivationMemory,
    /// The immutable prepared-weight image this scheduler serves from —
    /// the software analogue of the weights staying resident in the OCU
    /// buffers. Engine-attached schedulers share one `Arc`'d copy across
    /// the whole pool (shared-image pass); standalone schedulers build
    /// their own on first use.
    image: Option<Arc<PreparedNet>>,
    /// Weight-bank residency states parked per image fingerprint
    /// (multi-workload pass): when [`Scheduler::swap_image`] checks a
    /// different net's image in, the current `weights` model is parked
    /// here and the incoming image's model is restored (or started
    /// fresh). Each net's residency therefore evolves exactly as it
    /// would serving alone — interleaving workloads cannot thrash the
    /// modeled banks of either — while the host-side switch cost stays
    /// a couple of map moves.
    parked_weights: BTreeMap<u64, WeightMemory>,
}

impl Scheduler {
    pub fn new(cfg: CutieConfig, mode: SimMode) -> Self {
        let weights = WeightMemory::new(cfg.weight_banks, cfg.channels);
        let tcn_mem = TcnMemory::new(cfg.tcn_depth, cfg.channels);
        let actmem = ActivationMemory::new(cfg.max_hw, cfg.channels);
        Scheduler {
            cfg,
            mode,
            tcn_strategy: TcnStrategy::Mapped,
            weights,
            tcn_mem,
            actmem,
            image: None,
            parked_weights: BTreeMap::new(),
        }
    }

    pub fn with_tcn_strategy(mut self, s: TcnStrategy) -> Self {
        self.tcn_strategy = s;
        self
    }

    /// Attach a shared prepared-weight image (the engine's one copy).
    /// Subsequent inferences on a matching network serve straight from
    /// it; a non-matching network rebuilds a private image (same
    /// staleness contract as the OCU buffers: resident until rewritten).
    /// The per-frame match is geometry-only — callers wanting the full
    /// content gate (thresholds, pooling flags) should run
    /// [`PreparedNet::validate_against`] first, as the engine and
    /// pipeline `with_image` constructors do.
    pub fn attach_image(&mut self, image: Arc<PreparedNet>) {
        self.image = Some(image);
    }

    /// The currently attached/built image, if any.
    pub fn image(&self) -> Option<&Arc<PreparedNet>> {
        self.image.as_ref()
    }

    /// Check a different prepared image in (the multi-workload analogue
    /// of [`Scheduler::swap_tcn`]): the current image's weight-bank
    /// residency model is parked under its fingerprint and the incoming
    /// image's model is restored — or started cold if this scheduler has
    /// never served that image. Re-attaching the image already being
    /// served (same `Arc` or same fingerprint) is a no-op, so every
    /// single-net path is byte-identical to the pre-registry code.
    pub fn swap_image(&mut self, image: Arc<PreparedNet>) {
        if let Some(cur) = &self.image {
            if Arc::ptr_eq(cur, &image) || cur.fingerprint() == image.fingerprint() {
                self.image = Some(image);
                return;
            }
            let old_fp = cur.fingerprint();
            let fresh = self
                .parked_weights
                .remove(&image.fingerprint())
                .unwrap_or_else(|| WeightMemory::new(self.cfg.weight_banks, self.cfg.channels));
            let old = std::mem::replace(&mut self.weights, fresh);
            self.parked_weights.insert(old_fp, old);
        }
        self.image = Some(image);
    }

    /// Fetch the image serving `net`, building (and keeping) one if none
    /// is attached or the attached one is for a different network. The
    /// match check is geometry-only and O(layers) — negligible per
    /// frame.
    fn image_for(&mut self, net: &Network) -> Arc<PreparedNet> {
        if let Some(img) = &self.image {
            if img.matches(net) {
                return Arc::clone(img);
            }
        }
        let img = Arc::new(PreparedNet::new(net, &self.cfg));
        // route through the checkout so the displaced image's residency
        // model is parked, not clobbered
        self.swap_image(Arc::clone(&img));
        img
    }

    /// Swap a per-session TCN window in or out (the serving engine's
    /// checkout). The window is the scheduler's only cross-frame
    /// recurrent state — the weight memory and the shared prepared image
    /// are session-independent (steady-state bank switches and pure
    /// packed forms of the network) — so swapping the window is all a
    /// multi-stream engine needs to time-multiplex streams over one
    /// scheduler with byte-identical counters.
    pub fn swap_tcn(&mut self, mem: &mut TcnMemory) {
        std::mem::swap(&mut self.tcn_mem, mem);
    }

    /// Number of prepared layers in the image this scheduler serves
    /// from: (conv/TCN kernels, classifiers). Observability hook for the
    /// caching tests; (0, 0) until an image is attached or built.
    pub fn cached_layers(&self) -> (usize, usize) {
        self.image.as_ref().map(|i| i.counts()).unwrap_or((0, 0))
    }

    /// Pre-load every layer's weights (boot). Returns boot cycles; after
    /// this, inference only performs 1-cycle bank switches (Kraken keeps
    /// the whole network resident).
    pub fn preload_weights(&mut self, net: &Network) -> u64 {
        let mut cycles = 0;
        for l in &net.layers {
            if l.kind == LayerKind::Dense {
                continue;
            }
            if let WeightAccess::Load { cycles: c, .. } =
                self.weights.prepare(&l.name, self.cfg.kernel * self.cfg.kernel, l.in_ch, l.out_ch)
            {
                cycles += c;
            }
        }
        cycles
    }

    /// Mark every layer's weights resident **without** charging boot
    /// cycles — the pool-worker attach path: the engine boots the shared
    /// image once (tail preload) and every other scheduler adopts the
    /// already-filled banks, so spawning a worker costs no modeled (or
    /// host) weight movement while steady-state accesses still report
    /// the same 1-cycle bank switches.
    pub fn adopt_weights(&mut self, net: &Network) {
        for l in &net.layers {
            if l.kind != LayerKind::Dense {
                self.weights.adopt(&l.name);
            }
        }
    }

    /// Re-adopt the named layers' weight banks from the shared image —
    /// the repair half of a weight-scrub pass (the software twin of a
    /// scrubbing re-boot after a parity interrupt). Adoption is
    /// idempotent: banks already resident stay resident with their LRU
    /// order untouched, so sessions sharing this scheduler observe no
    /// counter change; the scrub/repair cost is charged by the caller
    /// through its frame's fault ledger.
    pub fn scrub_weights<'a>(&mut self, layers: impl IntoIterator<Item = &'a str>) {
        for name in layers {
            self.weights.adopt(name);
        }
    }

    fn charge_weights(&mut self, layer: &Layer, stats: &mut LayerStats) {
        let access = self.weights.prepare(
            &layer.name,
            self.cfg.kernel * self.cfg.kernel,
            layer.in_ch,
            layer.out_ch,
        );
        match access {
            WeightAccess::Switch => {
                stats.weight_load_cycles = 1;
                stats.weight_words = layer.out_ch as u64; // bank-select per OCU
            }
            WeightAccess::Load { cycles, words } => {
                stats.weight_load_cycles = cycles;
                stats.weight_words = words;
            }
        }
    }

    /// µDMA ingress of an input frame (2-bit trits over a `dma_bits` bus).
    fn dma_in(&self, numel: usize) -> (u64, u64) {
        let bytes = super::dma_ingress_bytes(numel);
        let cycles = bytes.div_ceil((self.cfg.dma_bits / 8) as u64);
        (cycles, bytes)
    }

    /// Run the CNN front-end on one packed frame. Ends either in the
    /// pre-classifier map (cifar9) or a per-step feature vector (hybrid).
    /// The frame lands in the activation memory once and every layer
    /// reads its input straight out of the ping-pong buffer — no i8
    /// conversion, no per-layer map clone, and (shared-image pass) no
    /// per-scheduler weight copy anywhere in the loop.
    pub fn run_cnn(&mut self, net: &Network, frame: &PackedMap) -> Result<(PackedMap, RunStats)> {
        let image = self.image_for(net);
        let mut run = RunStats::default();
        let (dc, db) = self.dma_in(frame.numel());
        run.dma_cycles = dc;
        run.dma_bytes = db;
        self.actmem.load_input(frame.clone())?;

        // Globally pooled maps bypass the activation SRAM (they leave the
        // datapath as feature vectors), so they are carried by value.
        let mut carried: Option<PackedMap> = None;
        for layer in net.layers.iter().filter(|l| l.kind == LayerKind::Conv2d) {
            let prep = image.conv_layer(&layer.name)?;
            let mut result = {
                let input = match carried.as_ref() {
                    Some(m) => m,
                    None => self.actmem.front().expect("input frame loaded"),
                };
                run_prepared(prep, input, &self.cfg, self.mode)?
            };
            self.charge_weights(layer, &mut result.stats);
            run.layers.push(result.stats);
            if layer.global_pool {
                carried = Some(result.output);
            } else {
                self.actmem.store_output_and_swap(result.output)?;
                carried = None;
            }
        }
        let feat = match carried {
            Some(m) => m,
            None => self.actmem.front().expect("at least the input frame").clone(),
        };
        Ok((feat, run))
    }

    /// Run the CNN front-end over K co-resident session frames in one
    /// lane-batched invocation — the scheduler half of the engine's
    /// `LaneBlock` drain path. All frames must be bound to the same net
    /// and share geometry (the engine's grouping rule); each lane's
    /// returned feature map and [`RunStats`] are **bit-identical** to a
    /// serial [`Self::run_cnn`] call on that frame alone. The K per-lane
    /// activation maps ping-pong outside the modeled SRAM buffers (the
    /// lanes time-multiplex one physical activation memory), but every
    /// map is still validated against the modeled geometry
    /// ([`ActivationMemory::ensure_fits`]), and weight cycles are
    /// charged in serial frame-major order so the bank-residency model
    /// evolves exactly as if the frames had been served one by one.
    pub fn run_cnn_lanes(
        &mut self,
        net: &Network,
        frames: &[&PackedMap],
    ) -> Result<Vec<(PackedMap, RunStats)>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        let image = self.image_for(net);
        let lanes = frames.len();
        let mut runs: Vec<RunStats> = frames
            .iter()
            .map(|f| {
                let mut run = RunStats::default();
                let (dc, db) = self.dma_in(f.numel());
                run.dma_cycles = dc;
                run.dma_bytes = db;
                run
            })
            .collect();
        for f in frames {
            self.actmem.ensure_fits(f.h, f.w, f.c)?;
        }

        // Per-lane ping-pong state: `carried` for globally pooled maps
        // (which bypass the SRAM in the serial path too), `resident`
        // standing in for the lane's turn in the ping-pong buffer.
        let mut carried: Vec<Option<PackedMap>> = vec![None; lanes];
        let mut resident: Vec<PackedMap> = frames.iter().map(|f| (*f).clone()).collect();
        let conv_layers: Vec<&Layer> =
            net.layers.iter().filter(|l| l.kind == LayerKind::Conv2d).collect();
        for layer in &conv_layers {
            let prep = image.conv_layer(&layer.name)?;
            let inputs: Vec<&PackedMap> =
                (0..lanes).map(|l| carried[l].as_ref().unwrap_or(&resident[l])).collect();
            let results = run_prepared_lanes(prep, &inputs, &self.cfg, self.mode)?;
            for (l, result) in results.into_iter().enumerate() {
                runs[l].layers.push(result.stats);
                if layer.global_pool {
                    carried[l] = Some(result.output);
                } else {
                    let out = result.output;
                    self.actmem.ensure_fits(out.h, out.w, out.c)?;
                    resident[l] = out;
                    carried[l] = None;
                }
            }
        }
        // Weight cycles in serial frame-major order (frame 0's layers,
        // then frame 1's, ...) so the bank model's access sequence — and
        // with it every per-lane Switch/Load split — matches K serial
        // `run_cnn` calls exactly, even from a cold bank state.
        for run in runs.iter_mut() {
            for (layer, stats) in conv_layers.iter().copied().zip(run.layers.iter_mut()) {
                self.charge_weights(layer, stats);
            }
        }
        Ok(carried
            .into_iter()
            .zip(resident)
            .zip(runs)
            .map(|((c, r), run)| (c.unwrap_or(r), run))
            .collect())
    }

    /// Push a CNN feature vector (a 1×1 packed map) into the TCN memory
    /// (§4) — the (pos, mask) word moves as-is, no unpack/re-pack
    /// (perf pass iteration 9). Vectors narrower than the hardware's
    /// channel width ride zero-padded for free (plane bits ≥ `c` are
    /// clear by the `PackedMap` invariant — unused channels are tied
    /// off, as in the RTL); wider ones are rejected instead of being
    /// silently truncated to the hardware width, which would serve
    /// plausible-looking but wrong labels.
    pub fn push_feature(&mut self, feat: &PackedMap) -> Result<()> {
        // an HxW map silently collapsed to pixel (0,0) would also serve
        // plausible-looking but wrong labels — reject it outright
        ensure!(
            feat.h == 1 && feat.w == 1,
            "CNN must end in a 1×1 feature vector, got {}×{}",
            feat.h,
            feat.w
        );
        ensure!(
            feat.c <= self.tcn_mem.channels,
            "feature vector of {} channels exceeds the {}-channel TCN memory",
            feat.c,
            self.tcn_mem.channels
        );
        self.tcn_mem.push_packed(*feat.pixel(0, 0));
        Ok(())
    }

    /// Feature width of the TCN tail: the first TCN layer's input
    /// channels (the RTL's channels above it are tied to zero).
    fn feat_width(&self, net: &Network) -> usize {
        net.tcn_layers().next().map(|l| l.in_ch).unwrap_or(self.cfg.channels)
    }

    /// Run the TCN back-end + classifier over the TCN memory window.
    /// The §4 mapped strategy is packed-native end to end (perf pass
    /// iteration 9): the wrap images come off the TCN memory's
    /// multiplexed read port / the packed wrapper as `PackedMap`s, the
    /// inter-layer sequences stay (pos, mask) words, and the classifier
    /// consumes the last-step word directly. The direct ablation
    /// strategy routes through the retained i8 reference tail
    /// ([`run_tcn_i8`]).
    pub fn run_tcn(&mut self, net: &Network) -> Result<(IntTensor, RunStats)> {
        match self.tcn_strategy {
            TcnStrategy::Mapped => self.run_tcn_packed(net),
            TcnStrategy::Direct => self.run_tcn_i8(net),
        }
    }

    /// The packed-native §4 tail (the iteration 9 tentpole): no i8
    /// unpack/re-pack anywhere between the CNN's final feature map and
    /// the classifier's logits. Counter-identical to [`run_tcn_i8`]
    /// with the mapped strategy — asserted across the DVS serving
    /// workload by `tests/tcn_packed.rs`.
    fn run_tcn_packed(&mut self, net: &Network) -> Result<(IntTensor, RunStats)> {
        let image = self.image_for(net);
        let mut run = RunStats::default();
        let feat_ch = self.feat_width(net);
        // None until the first TCN layer runs: that layer reads its wrap
        // image straight off the memory's address-multiplexed port.
        let mut seq: Option<PackedMap> = None;
        let mut first = true;
        for layer in &net.layers {
            match layer.kind {
                LayerKind::Conv2d => continue,
                LayerKind::Tcn => {
                    let reads_before = self.tcn_mem.reads;
                    let z = match seq.as_ref() {
                        None => self.tcn_mem.wrap_image(layer.dilation, feat_ch),
                        Some(s) => crate::mapping::map_input_packed(s, layer.dilation),
                    };
                    let prep = image.mapped_layer(&layer.name)?;
                    let (out, mut stats) = self.run_tcn_mapped_packed(prep, layer, &z)?;
                    if first {
                        // first TCN layer reads straight out of the TCN
                        // memory's multiplexed port
                        stats.tcn_reads = self.tcn_mem.reads - reads_before;
                        first = false;
                    }
                    self.charge_weights(layer, &mut stats);
                    run.layers.push(stats);
                    seq = Some(out);
                }
                LayerKind::Dense => {
                    let last = match seq.as_ref() {
                        Some(s) => {
                            ensure!(
                                s.c == layer.in_ch,
                                "{}: classifier input {} != {}",
                                layer.name,
                                s.c,
                                layer.in_ch
                            );
                            *s.pixel(s.h - 1, 0)
                        }
                        // no TCN layers: the classifier reads the newest
                        // step off the memory's packed window
                        None => {
                            let w = self.tcn_mem.packed_window(feat_ch);
                            ensure!(
                                feat_ch == layer.in_ch,
                                "{}: classifier input {} != {}",
                                layer.name,
                                feat_ch,
                                layer.in_ch
                            );
                            *w.pixel(w.h - 1, 0)
                        }
                    };
                    let prep = image.dense_layer(&layer.name)?;
                    // one last-step word == one chunk (tail widths are
                    // ≤ the datapath's channel count by construction)
                    let (logits, stats) = run_dense_packed(prep, &[last], &self.cfg, self.mode)?;
                    run.layers.push(stats);
                    return Ok((logits, run));
                }
            }
        }
        anyhow::bail!("network has no classifier layer")
    }

    /// Retained i8 reference tail — the pre-iteration-9 marshalling
    /// dataflow (window → (T, C) i8 sequence → per-layer `map_input`
    /// wrap → i8 unwrap → i8 last-step slice). Serves as the A/B
    /// equivalence baseline for the packed tail (`tests/tcn_packed.rs`,
    /// the hotpath bench) and hosts the direct-strided A2 ablation.
    /// Reads its mapped kernels from the same shared image as the packed
    /// tail, so the two cannot diverge on prepared weights.
    pub fn run_tcn_i8(&mut self, net: &Network) -> Result<(IntTensor, RunStats)> {
        let image = self.image_for(net);
        let mut run = RunStats::default();
        let reads_before = self.tcn_mem.reads;
        let window = self.tcn_mem.window();
        let window_reads = self.tcn_mem.reads - reads_before;
        // Slice the hardware-width window down to the network's feature
        // width (the RTL's unused channels are tied to zero).
        let feat_ch = self.feat_width(net);
        let mut seq = TritTensor::zeros(&[self.cfg.tcn_depth, feat_ch]);
        for t in 0..self.cfg.tcn_depth {
            for c in 0..feat_ch {
                seq.data[t * feat_ch + c] = window.data[t * self.cfg.channels + c];
            }
        }
        let mut first = true;
        for layer in &net.layers {
            match layer.kind {
                LayerKind::Conv2d => continue,
                LayerKind::Tcn => {
                    let (out, mut stats) = match self.tcn_strategy {
                        TcnStrategy::Mapped => {
                            let prep = image.mapped_layer(&layer.name)?;
                            self.run_tcn_mapped(prep, layer, &seq)?
                        }
                        TcnStrategy::Direct => self.run_tcn_direct(layer, &seq)?,
                    };
                    if first {
                        // first TCN layer reads straight out of the TCN
                        // memory's multiplexed port
                        stats.tcn_reads = window_reads;
                        first = false;
                    }
                    self.charge_weights(layer, &mut stats);
                    run.layers.push(stats);
                    seq = out;
                }
                LayerKind::Dense => {
                    let t_len = seq.dims[0];
                    let c = seq.dims[1];
                    let last = TritTensor::from_vec(&[c], seq.data[(t_len - 1) * c..].to_vec());
                    let prep = image.dense_layer(&layer.name)?;
                    let (logits, stats) = run_dense_prepared(prep, &last, &self.cfg, self.mode)?;
                    run.layers.push(stats);
                    return Ok((logits, run));
                }
            }
        }
        anyhow::bail!("network has no classifier layer")
    }

    /// §4 mapping: wrap → plain 3×3 layer on the datapath → unwrap. The
    /// mapped kernels arrive from the shared image.
    fn run_tcn_mapped(
        &self,
        prep: &PreparedLayer,
        layer: &Layer,
        seq: &TritTensor,
    ) -> Result<(TritTensor, LayerStats)> {
        let t_len = seq.dims[0];
        let z = PackedMap::from_trit(&crate::mapping::map_input(seq, layer.dilation));
        let result = run_prepared(prep, &z, &self.cfg, self.mode)?;
        let mut stats = result.stats;
        // unmap: address arithmetic only, no cycles, no data movement —
        // the whole point of the §4 contribution.
        let acc_trits = result.output;
        let cout = layer.out_ch;
        let mut out = TritTensor::zeros(&[t_len, cout]);
        for n in 0..t_len {
            let (q, m) = (n / layer.dilation, n % layer.dilation);
            for co in 0..cout {
                out.data[n * cout + co] = acc_trits.get_trit(q, m, co);
            }
        }
        stats.name = layer.name.clone();
        Ok((out, stats))
    }

    /// §4 mapping, packed-native (perf pass iteration 9): the wrap image
    /// arrives as a `PackedMap` (built by the TCN memory's multiplexed
    /// read port or [`crate::mapping::map_input_packed`]), runs the
    /// packed column-stationary loop, and the un-mapping gathers whole
    /// (pos, mask) words — address arithmetic only, no cycles, no i8.
    /// Shares the image's mapped kernels with the i8 twin
    /// ([`Self::run_tcn_mapped`]); only the marshalling differs.
    fn run_tcn_mapped_packed(
        &self,
        prep: &PreparedLayer,
        layer: &Layer,
        z: &PackedMap,
    ) -> Result<(PackedMap, LayerStats)> {
        let result = run_prepared(prep, z, &self.cfg, self.mode)?;
        let mut stats = result.stats;
        stats.name = layer.name.clone();
        let out =
            crate::mapping::unmap_output_packed(&result.output, self.cfg.tcn_depth, layer.dilation);
        Ok((out, stats))
    }

    /// Ablation baseline: direct strided execution of Eq. (1). Functionally
    /// identical, but every output step issues N single-word strided
    /// activation reads that the linebuffer cannot coalesce — each is a
    /// stall cycle on top of the compute cycle (§4: "non-contiguous or
    /// strided accesses lead to stalling").
    fn run_tcn_direct(&self, layer: &Layer, seq: &TritTensor) -> Result<(TritTensor, LayerStats)> {
        let t_len = seq.dims[0];
        let cin = seq.dims[1];
        let n_taps = layer.weights.dims[0];
        let cout = layer.out_ch;
        ensure!(cin == layer.in_ch);

        let mut stats = LayerStats {
            name: layer.name.clone(),
            active_ocus: cout,
            fanin: n_taps * cin,
            ..Default::default()
        };

        let ocus = super::ocu::build_ocus(
            // treat the (N, Cin, Cout) tensor as an N-tap "window"
            &TritTensor::from_vec(
                &[1, n_taps, cin, cout],
                layer.weights.data.clone(),
            ),
            &layer.lo,
            &layer.hi,
        );

        let mut out = TritTensor::zeros(&[t_len, cout]);
        let mut window = vec![crate::trit::PackedVec::ZERO; n_taps];
        for t in 0..t_len {
            // N strided reads (t, t-D, t-2D, ...): one word each, no reuse.
            for (k, slot) in window.iter_mut().enumerate() {
                let shift = (n_taps - 1 - k) * layer.dilation;
                *slot = if t >= shift {
                    let src = t - shift;
                    crate::trit::PackedVec::pack(&seq.data[src * cin..(src + 1) * cin])
                } else {
                    crate::trit::PackedVec::ZERO
                };
            }
            stats.act_reads += n_taps as u64;
            stats.stall_cycles += (n_taps - 1) as u64; // non-overlapped fetches
            for (co, ocu) in ocus.iter().enumerate() {
                match self.mode {
                    SimMode::Accurate => {
                        let (acc, tog) = ocu.compute(&window);
                        stats.mac_toggles += tog as u64;
                        out.data[t * cout + co] = ternarize(acc, layer.lo[co], layer.hi[co]);
                    }
                    SimMode::Fast => {
                        let acc = ocu.compute_fast(&window);
                        out.data[t * cout + co] = ternarize(acc, layer.lo[co], layer.hi[co]);
                    }
                }
            }
        }
        stats.compute_cycles = t_len as u64;
        stats.drain_cycles = 1;
        stats.act_writes = t_len as u64;
        stats.hw_ops = self.cfg.hw_ops_per_cycle(cout) * stats.compute_cycles;
        stats.alg_macs = (t_len * n_taps * cin * cout) as u64;
        let clocked =
            (cout * self.cfg.channels * self.cfg.kernel * self.cfg.kernel) as u64 * stats.compute_cycles;
        stats.mac_idle = clocked.saturating_sub(stats.mac_toggles);
        Ok((out, stats))
    }

    /// Full inference from an i8 input (API edge — the one place a whole
    /// frame is packed): cifar-style nets take (H, W, C); hybrid nets
    /// take a (T, H, W, C) frame stack that streams through CNN → TCN
    /// memory → TCN (the logits correspond to the last frame's window).
    pub fn run_full(&mut self, net: &Network, input: &TritTensor) -> Result<(IntTensor, RunStats)> {
        if net.has_tcn() {
            ensure!(input.dims.len() == 4, "hybrid input must be (T, H, W, C)");
            let (t_len, h, w, c) = (input.dims[0], input.dims[1], input.dims[2], input.dims[3]);
            let mut run = RunStats::default();
            for t in 0..t_len {
                let frame = PackedMap::from_trit(&TritTensor::from_vec(
                    &[h, w, c],
                    input.data[t * h * w * c..(t + 1) * h * w * c].to_vec(),
                ));
                let (feat, r) = self.run_cnn(net, &frame)?;
                run.merge(r);
                self.push_feature(&feat)?;
            }
            let (logits, r) = self.run_tcn(net)?;
            run.merge(r);
            Ok((logits, run))
        } else {
            ensure!(input.dims.len() == 3, "input must be (H, W, C)");
            let mut run = RunStats::default();
            let (feat, r) = self.run_cnn(net, &PackedMap::from_trit(input))?;
            run.merge(r);
            let (logits, r) = self.run_classifier(net, &feat)?;
            run.merge(r);
            Ok((logits, run))
        }
    }

    /// Feed-forward classifier tail (cifar9-style nets, no TCN): flatten
    /// the CNN's final feature map and run the packed classifier. This
    /// is the per-frame serving tail the engine uses for sessions bound
    /// to a TCN-less net — nothing touches the TCN memory.
    pub fn run_classifier(
        &mut self,
        net: &Network,
        feat: &PackedMap,
    ) -> Result<(IntTensor, RunStats)> {
        let mut run = RunStats::default();
        let flat = TritTensor::from_vec(&[feat.numel()], feat.unpack_data());
        let dense = net.layers.last().unwrap();
        let image = self.image_for(net);
        let prep = image.dense_layer(&dense.name)?;
        let (logits, stats) = run_dense_prepared(prep, &flat, &self.cfg, self.mode)?;
        run.layers.push(stats);
        Ok((logits, run))
    }

    /// One serving step of the hybrid pipeline: packed frame in → CNN →
    /// TCN memory push → TCN window inference → logits. This is the §5
    /// autonomous data-to-label flow.
    pub fn serve_frame(&mut self, net: &Network, frame: &PackedMap) -> Result<(IntTensor, RunStats)> {
        let (feat, mut run) = self.run_cnn(net, frame)?;
        self.push_feature(&feat)?;
        let (logits, r) = self.run_tcn(net)?;
        run.merge(r);
        Ok((logits, run))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{cifar9_random, dvs_hybrid_random, reference};
    use crate::util::rng::Rng;

    #[test]
    fn cifar_matches_reference_executor() {
        let net = cifar9_random(16, 81, 0.33);
        let mut rng = Rng::new(82);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (logits, stats) = sched.run_full(&net, &input).unwrap();
        let want = reference::forward(&net, &input).unwrap();
        assert_eq!(logits, want);
        assert_eq!(stats.layers.len(), 9);
        assert!(stats.total_cycles() > 0);
        assert_eq!(stats.stall_cycles(), 0, "mapped execution must be stall-free");
    }

    #[test]
    fn hybrid_matches_reference_executor() {
        let net = dvs_hybrid_random(16, 83, 0.5);
        let mut rng = Rng::new(84);
        let input = TritTensor::random(&[6, 64, 64, 2], &mut rng, 0.85);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let (logits, _) = sched.run_full(&net, &input).unwrap();
        // reference gets the same cold-start zero padding: feed the same
        // 6 frames into a fresh 24-window
        let mut ref_seq = TritTensor::zeros(&[24, 16]);
        for t in 0..6 {
            let frame = TritTensor::from_vec(
                &[64, 64, 2],
                input.data[t * 64 * 64 * 2..(t + 1) * 64 * 64 * 2].to_vec(),
            );
            let feat = reference::forward_cnn(&net, &frame).unwrap();
            for c in 0..16 {
                ref_seq.data[(18 + t) * 16 + c] = feat.data[c];
            }
        }
        let want = reference::forward_tcn(&net, &ref_seq).unwrap();
        assert_eq!(logits, want);
    }

    #[test]
    fn direct_strategy_same_result_more_stalls() {
        let net = dvs_hybrid_random(16, 85, 0.4);
        let mut rng = Rng::new(86);
        let seqs: Vec<PackedMap> = (0..4)
            .map(|_| PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.8)))
            .collect();

        let mut mapped = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let mut direct = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate)
            .with_tcn_strategy(TcnStrategy::Direct);

        let mut logits_m = None;
        let mut logits_d = None;
        let mut stalls_m = 0;
        let mut stalls_d = 0;
        for f in &seqs {
            let (lm, rm) = mapped.serve_frame(&net, f).unwrap();
            let (ld, rd) = direct.serve_frame(&net, f).unwrap();
            stalls_m += rm.stall_cycles();
            stalls_d += rd.stall_cycles();
            logits_m = Some(lm);
            logits_d = Some(ld);
        }
        assert_eq!(logits_m.unwrap(), logits_d.unwrap(), "strategies must agree bitwise");
        assert_eq!(stalls_m, 0);
        assert!(stalls_d > 0, "direct strided access must stall");
    }

    #[test]
    fn weight_residency_after_first_inference() {
        let net = cifar9_random(32, 87, 0.33);
        let mut rng = Rng::new(88);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        let (_, first) = sched.run_full(&net, &input).unwrap();
        let (_, second) = sched.run_full(&net, &input).unwrap();
        let first_w: u64 = first.layers.iter().map(|l| l.weight_load_cycles).sum();
        let second_w: u64 = second.layers.iter().map(|l| l.weight_load_cycles).sum();
        assert!(first_w > second_w, "first {first_w} vs steady {second_w}");
        assert_eq!(second_w, 8); // 8 conv layers × 1-cycle bank switch
    }

    #[test]
    fn serve_frame_pushes_tcn_memory() {
        let net = dvs_hybrid_random(16, 89, 0.5);
        let mut rng = Rng::new(90);
        let frame = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        assert!(sched.tcn_mem.is_empty());
        sched.serve_frame(&net, &frame).unwrap();
        assert_eq!(sched.tcn_mem.len(), 1);
        for _ in 0..30 {
            sched.serve_frame(&net, &frame).unwrap();
        }
        assert!(sched.tcn_mem.is_full());
        assert_eq!(sched.tcn_mem.len(), 24);
    }

    #[test]
    fn push_feature_rejects_bad_shapes() {
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        // wider than the hardware channel count: silently truncating
        // would serve wrong labels — must be a proper error
        assert!(sched.push_feature(&PackedMap::zeros(1, 1, 128)).is_err());
        // not a 1×1 feature vector
        assert!(sched.push_feature(&PackedMap::zeros(2, 2, 4)).is_err());
        assert_eq!(sched.tcn_mem.len(), 0, "rejected features must not be stored");
        // narrow features ride zero-padded
        assert!(sched.push_feature(&PackedMap::zeros(1, 1, 16)).is_ok());
        assert_eq!(sched.tcn_mem.len(), 1);
    }

    #[test]
    fn packed_tail_matches_i8_reference_tail() {
        // The in-module smoke check; the exhaustive sweep (counters,
        // energy bits, cold start → post-eviction) is tests/tcn_packed.rs.
        let net = dvs_hybrid_random(16, 97, 0.5);
        let mut rng = Rng::new(98);
        let mut packed = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        let mut i8ref = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        for _ in 0..4 {
            let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
            let (lp, _) = packed.serve_frame(&net, &f).unwrap();
            let (feat, _) = i8ref.run_cnn(&net, &f).unwrap();
            i8ref.push_feature(&feat).unwrap();
            let (li, _) = i8ref.run_tcn_i8(&net).unwrap();
            assert_eq!(lp, li, "packed and i8 tails must agree bitwise");
        }
        assert_eq!(packed.tcn_mem.shift_toggles, i8ref.tcn_mem.shift_toggles);
        assert_eq!(packed.tcn_mem.reads, i8ref.tcn_mem.reads);
    }

    #[test]
    fn dense_weights_packed_once_and_cached() {
        let net = cifar9_random(16, 93, 0.33);
        let mut rng = Rng::new(94);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        assert_eq!(sched.cached_layers(), (0, 0));
        let (a, _) = sched.run_full(&net, &input).unwrap();
        // 8 conv kernels + 1 packed classifier now resident
        assert_eq!(sched.cached_layers(), (8, 1));
        let image_before = Arc::clone(sched.image().expect("image built on first run"));
        let (b, _) = sched.run_full(&net, &input).unwrap();
        assert_eq!(sched.cached_layers(), (8, 1), "steady state must not re-prepare");
        assert!(
            Arc::ptr_eq(&image_before, sched.image().unwrap()),
            "steady state must reuse the same image, not rebuild it"
        );
        assert_eq!(a, b);
        assert_eq!(a, reference::forward(&net, &input).unwrap());
    }

    #[test]
    fn hybrid_caches_mapped_and_dense_layers() {
        let net = dvs_hybrid_random(16, 95, 0.5);
        let mut rng = Rng::new(96);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
        sched.serve_frame(&net, &f).unwrap();
        // 5 conv + 4 mapped-TCN kernels, 1 packed classifier
        assert_eq!(sched.cached_layers(), (9, 1));
        sched.serve_frame(&net, &f).unwrap();
        assert_eq!(sched.cached_layers(), (9, 1));
    }

    #[test]
    fn attached_image_is_served_from_not_rebuilt() {
        // The shared-image contract: a scheduler with an attached image
        // for the right network serves from it (no private rebuild), and
        // produces the same results as one that built its own.
        let net = dvs_hybrid_random(16, 99, 0.5);
        let mut rng = Rng::new(100);
        let shared = Arc::new(PreparedNet::new(&net, &CutieConfig::kraken()));

        let mut with_img = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        with_img.attach_image(Arc::clone(&shared));
        let mut own = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);

        for _ in 0..3 {
            let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
            let (la, ra) = with_img.serve_frame(&net, &f).unwrap();
            let (lb, rb) = own.serve_frame(&net, &f).unwrap();
            assert_eq!(la, lb);
            assert_eq!(ra, rb, "shared and private images must serve identical counters");
        }
        assert!(
            Arc::ptr_eq(with_img.image().unwrap(), &shared),
            "attached image must still be the shared one"
        );
        // 1 (here) + 1 (scheduler) strong refs
        assert_eq!(Arc::strong_count(&shared), 2);
    }

    #[test]
    fn preload_makes_first_inference_switch_only() {
        let net = cifar9_random(32, 91, 0.33);
        let mut rng = Rng::new(92);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut sched = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        let boot = sched.preload_weights(&net);
        assert!(boot > 0);
        let (_, run) = sched.run_full(&net, &input).unwrap();
        let w: u64 = run.layers.iter().map(|l| l.weight_load_cycles).sum();
        assert_eq!(w, 8);
    }

    #[test]
    fn adopted_weights_match_preloaded_counters() {
        // An adopting scheduler (pool worker) must charge the same
        // steady-state weight cycles as a preloaded one from the very
        // first frame.
        let net = dvs_hybrid_random(16, 101, 0.5);
        let mut rng = Rng::new(102);
        let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
        let mut pre = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        pre.preload_weights(&net);
        let mut adopt = Scheduler::new(CutieConfig::kraken(), SimMode::Fast);
        adopt.adopt_weights(&net);
        let (la, ra) = pre.serve_frame(&net, &f).unwrap();
        let (lb, rb) = adopt.serve_frame(&net, &f).unwrap();
        assert_eq!(la, lb);
        assert_eq!(ra, rb, "adopt must be counter-identical to preload");
    }

    #[test]
    fn swap_image_parks_and_restores_per_net_residency() {
        // Serving two workloads through one scheduler must charge each
        // net exactly the weight cycles it would see serving alone:
        // residency is parked per image, not thrashed through one LRU.
        let dvs = dvs_hybrid_random(16, 103, 0.5);
        let cifar = cifar9_random(16, 104, 0.33);
        let cfg = CutieConfig::kraken();
        let img_d = Arc::new(PreparedNet::new(&dvs, &cfg));
        let img_c = Arc::new(PreparedNet::new(&cifar, &cfg));
        let mut rng = Rng::new(105);
        let fd = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));
        let fc = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);

        // isolated oracles, preloaded like the engine tail
        let mut alone_d = Scheduler::new(cfg.clone(), SimMode::Fast);
        alone_d.swap_image(Arc::clone(&img_d));
        alone_d.preload_weights(&dvs);
        let mut alone_c = Scheduler::new(cfg.clone(), SimMode::Fast);
        alone_c.swap_image(Arc::clone(&img_c));
        alone_c.preload_weights(&cifar);

        let mut shared = Scheduler::new(cfg.clone(), SimMode::Fast);
        shared.swap_image(Arc::clone(&img_d));
        shared.preload_weights(&dvs);
        shared.swap_image(Arc::clone(&img_c));
        shared.preload_weights(&cifar);
        shared.swap_image(Arc::clone(&img_d));

        for round in 0..3 {
            let (la, ra) = alone_d.serve_frame(&dvs, &fd).unwrap();
            shared.swap_image(Arc::clone(&img_d));
            let (lb, rb) = shared.serve_frame(&dvs, &fd).unwrap();
            assert_eq!(la, lb, "round {round}: DVS labels");
            assert_eq!(ra, rb, "round {round}: DVS counters");

            let (la, ra) = alone_c.run_full(&cifar, &fc).unwrap();
            shared.swap_image(Arc::clone(&img_c));
            let (lb, rb) = shared.run_full(&cifar, &fc).unwrap();
            assert_eq!(la, lb, "round {round}: cifar labels");
            assert_eq!(ra, rb, "round {round}: cifar counters");
        }
    }

    #[test]
    fn lane_batched_cnn_matches_serial() {
        // The scheduler-level contract behind the engine's LaneBlock
        // drain: K lanes through one run_cnn_lanes call produce the same
        // feature words and counters as K serial run_cnn calls — from a
        // preloaded bank state (the engine's steady state) AND from a
        // cold one (frame-major weight charging).
        let net = dvs_hybrid_random(16, 108, 0.5);
        let mut rng = Rng::new(109);
        for preload in [true, false] {
            for k in [1usize, 2, 3, 5, 8] {
                let frames: Vec<PackedMap> = (0..k)
                    .map(|_| {
                        PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85))
                    })
                    .collect();
                let mut serial = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
                let mut lanes = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
                if preload {
                    serial.preload_weights(&net);
                    lanes.preload_weights(&net);
                }
                let refs: Vec<&PackedMap> = frames.iter().collect();
                let got = lanes.run_cnn_lanes(&net, &refs).unwrap();
                assert_eq!(got.len(), k);
                for (f, (feat, run)) in frames.iter().zip(got) {
                    let (wf, wr) = serial.run_cnn(&net, f).unwrap();
                    assert_eq!(feat, wf, "K {k} preload {preload}: feature map");
                    assert_eq!(run, wr, "K {k} preload {preload}: counters");
                }
            }
        }
    }

    #[test]
    fn swap_image_same_fingerprint_is_a_noop() {
        let net = dvs_hybrid_random(16, 106, 0.5);
        let cfg = CutieConfig::kraken();
        let img = Arc::new(PreparedNet::new(&net, &cfg));
        let twin = Arc::new(PreparedNet::new(&net, &cfg));
        let mut rng = Rng::new(107);
        let f = PackedMap::from_trit(&TritTensor::random(&[64, 64, 2], &mut rng, 0.85));

        let mut sched = Scheduler::new(cfg.clone(), SimMode::Fast);
        sched.swap_image(Arc::clone(&img));
        sched.preload_weights(&net);
        let (_, warm) = sched.serve_frame(&net, &f).unwrap();
        // same Arc and same-fingerprint twin both keep the residency
        sched.swap_image(Arc::clone(&img));
        let (_, a) = sched.serve_frame(&net, &f).unwrap();
        sched.swap_image(Arc::clone(&twin));
        let (_, b) = sched.serve_frame(&net, &f).unwrap();
        let loads = |r: &RunStats| r.layers.iter().map(|l| l.weight_load_cycles).sum::<u64>();
        assert_eq!(loads(&a), loads(&warm), "same-Arc swap must keep banks resident");
        assert_eq!(loads(&b), loads(&warm), "same-fingerprint swap must keep banks resident");
        assert!(Arc::ptr_eq(sched.image().unwrap(), &twin));
    }
}
