//! Stall-free linebuffer (§3): holds K rows of packed pixels and serves a
//! full K×K×C window every cycle. Streaming rows in from the activation
//! memory overlaps with compute, so the only non-hidden cost is the
//! initial fill (K-1 rows + K-1 pixels). Zero padding at the edges is
//! produced combinationally (no memory access).
//!
//! Two variants share the residency/push accounting semantics:
//!
//! * [`LineBuffer`] — the legacy i8 ingest path: rows are packed from an
//!   i8 `TritTensor` on fetch (the per-pixel conversion tax the packed
//!   dataflow eliminates) and held in a `VecDeque` ring (perf pass
//!   iteration 8 satellite: scrolling used to `Vec::remove(0)`-shift
//!   every retained row, O(rows·W) per output row).
//! * [`PackedLineBuffer`] — the packed dataflow path: the activation
//!   memory already holds [`PackedMap`] rows in the datapath's native
//!   encoding, so the buffer borrows them zero-copy and only tracks
//!   residency for the push (shift-register activity) ledger.
//!
//! The ablation A2 ("direct strided access", what a dilated conv would do
//! *without* the §4 mapping) is modelled in
//! [`crate::cutie::scheduler`], which charges explicit stall cycles per
//! non-contiguous fetch; this module is always the stall-free variant.

use std::collections::VecDeque;

use crate::tensor::{PackedMap, TritTensor};
use crate::trit::{PackedVec, TritCol};

pub struct LineBuffer {
    k: usize,
    width: usize,
    /// `rows[r]` is input row `base_row + r`, packed per pixel. Ring
    /// buffer: scroll-out is a pop_front, never an element shift.
    rows: VecDeque<Vec<PackedVec>>,
    base_row: isize,
    /// Pixel pushes (shift-register activity for the energy model).
    pub pushes: u64,
}

impl LineBuffer {
    pub fn new(k: usize, width: usize) -> Self {
        LineBuffer { k, width, rows: VecDeque::new(), base_row: 0, pushes: 0 }
    }

    /// Load the window rows needed to produce output row `y` of an
    /// H-row image: input rows y-pad .. y+pad clipped to [0, H).
    /// Returns the number of *new* rows fetched (1 in steady state).
    pub fn advance_to(&mut self, y: usize, input: &TritTensor) -> usize {
        let h = input.dims[0] as isize;
        let pad = (self.k / 2) as isize;
        let lo = (y as isize - pad).max(0);
        let hi = (y as isize + pad).min(h - 1);
        let mut fetched = 0;
        if self.rows.is_empty() || lo > self.base_row + self.rows.len() as isize - 1 {
            // (re)fill from scratch
            self.rows.clear();
            self.base_row = lo;
            for r in lo..=hi {
                let row = self.fetch_row(r as usize, input);
                self.rows.push_back(row);
                fetched += 1;
            }
        } else {
            // drop rows that scrolled out
            while self.base_row < lo {
                self.rows.pop_front();
                self.base_row += 1;
            }
            // fetch rows that scrolled in
            while self.base_row + (self.rows.len() as isize) <= hi {
                let r = self.base_row + self.rows.len() as isize;
                let row = self.fetch_row(r as usize, input);
                self.rows.push_back(row);
                fetched += 1;
            }
        }
        fetched
    }

    fn fetch_row(&mut self, r: usize, input: &TritTensor) -> Vec<PackedVec> {
        self.pushes += self.width as u64;
        (0..self.width).map(|x| input.pack_pixel(r, x)).collect()
    }

    /// Extract the K×K window centred at (y, x); zero padding outside.
    /// `window` must have length K².
    pub fn window(&self, y: usize, x: usize, h: usize, window: &mut [PackedVec]) {
        let pad = (self.k / 2) as isize;
        for ky in 0..self.k {
            let sy = y as isize + ky as isize - pad;
            for kx in 0..self.k {
                let sx = x as isize + kx as isize - pad;
                let idx = ky * self.k + kx;
                if sy < 0 || sy >= h as isize || sx < 0 || sx >= self.width as isize {
                    window[idx] = PackedVec::ZERO;
                } else {
                    window[idx] = self.rows[(sy - self.base_row) as usize][sx as usize];
                }
            }
        }
    }

    /// Cycles to prime the buffer before the first window: (K-1) rows plus
    /// (K-1) pixels of the next row, matching the RTL fill behaviour.
    pub fn fill_cycles(&self, input_w: usize) -> u64 {
        ((self.k - 1) * input_w + (self.k - 1)) as u64
    }
}

/// Zero-copy linebuffer over a packed activation map (perf pass
/// iteration 8): the map's rows *are* the buffer contents, so residency
/// is pure index bookkeeping and `col` reads pixels straight out of the
/// borrowed map — no per-pixel packing, no row copies. `advance_to` and
/// `pushes` follow [`LineBuffer`]'s accounting exactly (every input
/// pixel enters the shift registers once), keeping the energy-model
/// counters bit-identical to the i8 ingest path.
pub struct PackedLineBuffer<'a> {
    k: usize,
    map: &'a PackedMap,
    /// Resident rows are `base_row .. base_row + rows` of the map.
    base_row: isize,
    rows: usize,
    pub pushes: u64,
}

impl<'a> PackedLineBuffer<'a> {
    pub fn new(k: usize, map: &'a PackedMap) -> Self {
        PackedLineBuffer { k, map, base_row: 0, rows: 0, pushes: 0 }
    }

    /// Mark the window rows for output row `y` resident; returns the
    /// number of newly fetched rows (1 in steady state).
    pub fn advance_to(&mut self, y: usize) -> usize {
        let h = self.map.h as isize;
        let pad = (self.k / 2) as isize;
        let lo = (y as isize - pad).max(0);
        let hi = (y as isize + pad).min(h - 1);
        let width = self.map.w as u64;
        let mut fetched = 0;
        if self.rows == 0 || lo > self.base_row + self.rows as isize - 1 {
            // (re)fill from scratch
            self.base_row = lo;
            self.rows = (hi - lo + 1) as usize;
            fetched = self.rows;
            self.pushes += self.rows as u64 * width;
        } else {
            // drop rows that scrolled out
            if self.base_row < lo {
                self.rows -= (lo - self.base_row) as usize;
                self.base_row = lo;
            }
            // fetch rows that scrolled in
            while self.base_row + self.rows as isize <= hi {
                self.rows += 1;
                fetched += 1;
                self.pushes += width;
            }
        }
        fetched
    }

    /// Extract the K-row input column at x for output row y (input rows
    /// y-pad..y+pad, zero-padded outside the map). `out` must have
    /// length K. This is the column-stationary datapath's access
    /// pattern: one fresh column per output pixel.
    pub fn col(&self, y: usize, x: usize, out: &mut [PackedVec]) {
        let h = self.map.h as isize;
        let pad = (self.k / 2) as isize;
        for (ky, slot) in out.iter_mut().enumerate() {
            let sy = y as isize + ky as isize - pad;
            *slot = if sy < 0 || sy >= h {
                PackedVec::ZERO
            } else {
                debug_assert!(
                    sy >= self.base_row && sy < self.base_row + self.rows as isize,
                    "row {sy} not resident"
                );
                *self.map.pixel(sy as usize, x)
            };
        }
    }

    /// Same fill-cost model as [`LineBuffer::fill_cycles`].
    pub fn fill_cycles(&self, input_w: usize) -> u64 {
        ((self.k - 1) * input_w + (self.k - 1)) as u64
    }
}

/// Per-lane fan-out of [`PackedLineBuffer`] for the cross-session lane
/// batching path: one zero-copy buffer per lane over that lane's input
/// map, all advanced in lock-step. Each lane keeps its own `pushes`
/// counter, so per-lane shift-register accounting stays bit-identical
/// to a serial run over that lane alone.
pub struct LaneBuffers<'a> {
    lanes: Vec<PackedLineBuffer<'a>>,
}

impl<'a> LaneBuffers<'a> {
    /// One buffer per lane map. All maps must share (h, w, c) — the
    /// lane-grouping rule the engine enforces before batching.
    pub fn new(k: usize, maps: &[&'a PackedMap]) -> Self {
        LaneBuffers { lanes: maps.iter().map(|m| PackedLineBuffer::new(k, m)).collect() }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Advance every lane's residency window to output row `y`.
    pub fn advance_to(&mut self, y: usize) {
        for lb in &mut self.lanes {
            lb.advance_to(y);
        }
    }

    /// The SoA transpose step: pack every lane's 3-row input column at
    /// (y, x) into a dense [`TritCol`] (`xcols[l]`, `zero[l]` describe
    /// lane l). Returns true when every lane's column is zero, i.e. the
    /// whole (y, x) step can be skipped for all lanes at once.
    pub fn pack_cols(
        &self,
        y: usize,
        x: usize,
        cin: usize,
        col_words: usize,
        xcols: &mut [TritCol],
        zero: &mut [bool],
    ) -> bool {
        let mut col = [PackedVec::ZERO; 3];
        let mut all_zero = true;
        for (l, lb) in self.lanes.iter().enumerate() {
            debug_assert_eq!(lb.k, 3, "lane batching is 3×3-only");
            lb.col(y, x, &mut col);
            xcols[l] = TritCol::pack_rows(&col, cin);
            zero[l] = xcols[l].is_zero(col_words);
            all_zero &= zero[l];
        }
        all_zero
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn windows_match_direct_indexing() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let h = 3 + rng.below(10);
            let w = 3 + rng.below(10);
            let c = 1 + rng.below(32);
            let img = TritTensor::random(&[h, w, c], &mut rng, 0.3);
            let mut lb = LineBuffer::new(3, w);
            let mut window = vec![PackedVec::ZERO; 9];
            for y in 0..h {
                lb.advance_to(y, &img);
                for x in 0..w {
                    lb.window(y, x, h, &mut window);
                    for ky in 0..3usize {
                        for kx in 0..3usize {
                            let sy = y as isize + ky as isize - 1;
                            let sx = x as isize + kx as isize - 1;
                            let got = &window[ky * 3 + kx];
                            if sy < 0 || sx < 0 || sy >= h as isize || sx >= w as isize {
                                assert_eq!(*got, PackedVec::ZERO);
                            } else {
                                assert_eq!(*got, img.pack_pixel(sy as usize, sx as usize));
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn packed_cols_match_window_columns() {
        let mut rng = Rng::new(24);
        for _ in 0..10 {
            let h = 3 + rng.below(8);
            let w = 3 + rng.below(8);
            let c = 1 + rng.below(32);
            let img = TritTensor::random(&[h, w, c], &mut rng, 0.4);
            let map = PackedMap::from_trit(&img);
            let mut lb = LineBuffer::new(3, w);
            let mut plb = PackedLineBuffer::new(3, &map);
            let mut window = vec![PackedVec::ZERO; 9];
            let mut col = [PackedVec::ZERO; 3];
            for y in 0..h {
                let fetched = lb.advance_to(y, &img);
                assert_eq!(plb.advance_to(y), fetched, "y {y}: fetch accounting");
                assert_eq!(plb.pushes, lb.pushes, "y {y}: push accounting");
                for x in 0..w {
                    lb.window(y, x, h, &mut window);
                    plb.col(y, x, &mut col);
                    // col(y, x) is the middle column (kx = 1) of the
                    // window centred at (y, x)
                    for ky in 0..3 {
                        assert_eq!(col[ky], window[ky * 3 + 1], "y {y} x {x} ky {ky}");
                    }
                }
            }
        }
    }

    #[test]
    fn steady_state_fetches_one_row() {
        let mut rng = Rng::new(22);
        let img = TritTensor::random(&[8, 5, 4], &mut rng, 0.3);
        let mut lb = LineBuffer::new(3, 5);
        assert_eq!(lb.advance_to(0, &img), 2); // rows 0, 1
        assert_eq!(lb.advance_to(1, &img), 1); // row 2
        assert_eq!(lb.advance_to(2, &img), 1);
        assert_eq!(lb.advance_to(7, &img), 2); // jump: refill rows 6, 7

        let map = PackedMap::from_trit(&img);
        let mut plb = PackedLineBuffer::new(3, &map);
        assert_eq!(plb.advance_to(0), 2);
        assert_eq!(plb.advance_to(1), 1);
        assert_eq!(plb.advance_to(2), 1);
        assert_eq!(plb.advance_to(7), 2);
        assert_eq!(plb.pushes, lb.pushes);
    }

    #[test]
    fn push_accounting() {
        let mut rng = Rng::new(23);
        let img = TritTensor::random(&[4, 6, 2], &mut rng, 0.0);
        let mut lb = LineBuffer::new(3, 6);
        for y in 0..4 {
            lb.advance_to(y, &img);
        }
        // every input row fetched exactly once = 4 rows × 6 px
        assert_eq!(lb.pushes, 24);
    }

    #[test]
    fn fill_cycles_formula() {
        let lb = LineBuffer::new(3, 32);
        assert_eq!(lb.fill_cycles(32), 2 * 32 + 2);
        let map = PackedMap::zeros(4, 32, 2);
        assert_eq!(PackedLineBuffer::new(3, &map).fill_cycles(32), 2 * 32 + 2);
    }
}
