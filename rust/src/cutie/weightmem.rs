//! Per-OCU weight buffers (§3: "each OCU includes weight buffers,
//! minimizing weight data movement"). In Kraken the whole network's
//! kernels fit in the OCU-local banks, so steady-state inference only
//! *switches* banks (1 cycle); streaming loads are charged only when a
//! layer's kernels are not resident (capacity miss or first boot).

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct WeightMemory {
    pub banks: usize,
    pub channels: usize,
    /// Layer names resident per bank slot (LRU ring, front = oldest;
    /// capacity eviction is a `pop_front`, never an element shift —
    /// same fix class as the PR 2 linebuffer).
    resident: VecDeque<String>,
    pub bank_switches: u64,
    pub streamed_words: u64,
}

pub enum WeightAccess {
    /// Bank switch only (weights resident): 1 cycle.
    Switch,
    /// Streaming load: `cycles` cycles, `words` weight words moved.
    Load { cycles: u64, words: u64 },
}

impl WeightMemory {
    pub fn new(banks: usize, channels: usize) -> Self {
        WeightMemory {
            banks,
            channels,
            resident: VecDeque::new(),
            bank_switches: 0,
            streamed_words: 0,
        }
    }

    /// Prepare layer `name` (kernel K²·C_in per OCU, `active` OCUs).
    /// Returns the access type; the scheduler charges cycles.
    pub fn prepare(&mut self, name: &str, kernel_sq: usize, in_ch: usize, active: usize) -> WeightAccess {
        if let Some(pos) = self.resident.iter().position(|r| r == name) {
            // hit: refresh LRU, 1-cycle bank switch
            let n = self.resident.remove(pos).expect("position is in range");
            self.resident.push_back(n);
            self.bank_switches += 1;
            return WeightAccess::Switch;
        }
        // miss: stream the kernels in. All OCUs load in parallel, each
        // receiving one C_in-wide word per cycle → K² · ceil(C_in / C)
        // cycles (C_in <= C in Kraken, so K² cycles).
        while self.resident.len() >= self.banks {
            self.resident.pop_front();
        }
        self.resident.push_back(name.to_string());
        let cycles = (kernel_sq * in_ch.div_ceil(self.channels)) as u64;
        let words = cycles * active as u64;
        self.streamed_words += words;
        WeightAccess::Load { cycles, words }
    }

    /// Mark `name` resident without charging a streaming load or a bank
    /// switch — models attaching another read port to banks an earlier
    /// boot already filled. The engine's pool workers adopt the shared
    /// weight image this way instead of each re-charging a private boot
    /// (shared-image pass): their steady-state accesses are then the
    /// same 1-cycle bank switches a preloaded scheduler reports.
    pub fn adopt(&mut self, name: &str) {
        if self.resident.iter().any(|r| r == name) {
            return;
        }
        while self.resident.len() >= self.banks {
            self.resident.pop_front();
        }
        self.resident.push_back(name.to_string());
    }

    pub fn is_resident(&self, name: &str) -> bool {
        self.resident.iter().any(|r| r == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_loads_then_switches() {
        let mut wm = WeightMemory::new(9, 96);
        match wm.prepare("c1", 9, 96, 96) {
            WeightAccess::Load { cycles, words } => {
                assert_eq!(cycles, 9);
                assert_eq!(words, 9 * 96);
            }
            _ => panic!("expected load"),
        }
        match wm.prepare("c1", 9, 96, 96) {
            WeightAccess::Switch => {}
            _ => panic!("expected switch"),
        }
        assert_eq!(wm.bank_switches, 1);
    }

    #[test]
    fn capacity_evicts_lru() {
        let mut wm = WeightMemory::new(2, 96);
        wm.prepare("a", 9, 96, 96);
        wm.prepare("b", 9, 96, 96);
        wm.prepare("c", 9, 96, 96); // evicts a
        assert!(!wm.is_resident("a"));
        assert!(wm.is_resident("b"));
        assert!(wm.is_resident("c"));
        match wm.prepare("a", 9, 96, 96) {
            WeightAccess::Load { .. } => {}
            _ => panic!("evicted layer must reload"),
        }
    }

    #[test]
    fn adopt_marks_resident_without_charges() {
        let mut wm = WeightMemory::new(9, 96);
        wm.adopt("c1");
        assert!(wm.is_resident("c1"));
        assert_eq!(wm.bank_switches, 0, "adopt must not charge a switch");
        assert_eq!(wm.streamed_words, 0, "adopt must not charge a load");
        // the next prepare is the same steady-state switch a preloaded
        // memory reports
        match wm.prepare("c1", 9, 96, 96) {
            WeightAccess::Switch => {}
            _ => panic!("adopted layer must hit"),
        }
        // adopt still respects capacity (evicts LRU like a load would)
        let mut small = WeightMemory::new(2, 96);
        small.adopt("a");
        small.adopt("b");
        small.adopt("c");
        assert!(!small.is_resident("a"));
        assert!(small.is_resident("b") && small.is_resident("c"));
        small.adopt("b"); // re-adopt is a no-op
        assert!(small.is_resident("c"));
    }

    #[test]
    fn whole_network_resident_after_first_inference() {
        let mut wm = WeightMemory::new(9, 96);
        for l in 0..9 {
            wm.prepare(&format!("l{l}"), 9, 96, 96);
        }
        for l in 0..9 {
            match wm.prepare(&format!("l{l}"), 9, 96, 96) {
                WeightAccess::Switch => {}
                _ => panic!("layer l{l} should be resident"),
            }
        }
    }
}
