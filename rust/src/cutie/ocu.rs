//! Output Channel Compute Unit: one per output channel (§3). Holds the
//! layer's 3×3×C_in ternary kernel in a local buffer, computes the full
//! window dot product through the wide adder tree in a single (pipelined)
//! cycle, then applies the two-threshold ternarization. Sparsity in either
//! operand suppresses partial-product toggling — the effect the energy
//! model charges for.

use crate::tensor::TritTensor;
use crate::trit::{ternarize, PackedVec};

#[derive(Debug, Clone)]
pub struct Ocu {
    /// Kernel taps packed over input channels: `weights[ky*K + kx]`.
    pub weights: Vec<PackedVec>,
    pub lo: i32,
    pub hi: i32,
    /// Non-zero weight trits (precomputed; weight-side activity bound).
    pub weight_nonzero: u32,
}

impl Ocu {
    /// Build one OCU from a (K, K, Cin, Cout) layer weight tensor.
    pub fn from_layer_weights(w: &TritTensor, out_ch: usize, lo: i32, hi: i32) -> Ocu {
        let (kh, kw, cin, cout) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
        let mut weights = Vec::with_capacity(kh * kw);
        let mut nz = 0u32;
        for ky in 0..kh {
            for kx in 0..kw {
                let mut trits = Vec::with_capacity(cin);
                for ci in 0..cin {
                    let t = w.data[((ky * kw + kx) * cin + ci) * cout + out_ch];
                    if t != 0 {
                        nz += 1;
                    }
                    trits.push(t);
                }
                weights.push(PackedVec::pack(&trits));
            }
        }
        Ocu { weights, lo, hi, weight_nonzero: nz }
    }

    /// Full-window accumulate with toggle counting.
    #[inline]
    pub fn compute(&self, window: &[PackedVec]) -> (i32, u32) {
        debug_assert_eq!(window.len(), self.weights.len());
        let mut acc = 0i32;
        let mut toggles = 0u32;
        for (w, x) in self.weights.iter().zip(window) {
            let (a, t) = w.dot(x);
            acc += a;
            toggles += t;
        }
        (acc, toggles)
    }

    /// Accumulate only (fast path).
    #[inline]
    pub fn compute_fast(&self, window: &[PackedVec]) -> i32 {
        let mut acc = 0i32;
        for (w, x) in self.weights.iter().zip(window) {
            acc += w.dot_fast(x);
        }
        acc
    }

    /// Accumulate over a pre-filtered list of non-zero window positions
    /// (perf pass iteration 2: the zero-position list is computed once per
    /// pixel and shared by all OCUs — zero positions contribute neither
    /// accumulator value nor toggles, so skipping them is bit-exact).
    #[inline]
    pub fn compute_active(&self, window: &[PackedVec], active: &[u8]) -> (i32, u32) {
        let mut acc = 0i32;
        let mut toggles = 0u32;
        for &k in active {
            let (a, t) = self.weights[k as usize].dot(&window[k as usize]);
            acc += a;
            toggles += t;
        }
        (acc, toggles)
    }

    /// Fast variant of [`Self::compute_active`].
    #[inline]
    pub fn compute_active_fast(&self, window: &[PackedVec], active: &[u8]) -> i32 {
        let mut acc = 0i32;
        for &k in active {
            acc += self.weights[k as usize].dot_fast(&window[k as usize]);
        }
        acc
    }

    /// Threshold the accumulator to a trit.
    #[inline]
    pub fn threshold(&self, acc: i32) -> i8 {
        ternarize(acc, self.lo, self.hi)
    }
}

/// Build the full OCU array for a layer (one OCU per output channel).
pub fn build_ocus(w: &TritTensor, lo: &[i32], hi: &[i32]) -> Vec<Ocu> {
    let cout = *w.dims.last().unwrap();
    (0..cout)
        .map(|co| {
            let (l, h) = if lo.is_empty() { (i32::MIN + 1, i32::MAX - 1) } else { (lo[co], hi[co]) };
            Ocu::from_layer_weights(w, co, l, h)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn window_from(x: &[Vec<i8>]) -> Vec<PackedVec> {
        x.iter().map(|v| PackedVec::pack(v)).collect()
    }

    #[test]
    fn ocu_matches_scalar_conv() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let cin = 1 + rng.below(96);
            let w = TritTensor::random(&[3, 3, cin, 4], &mut rng, 0.3);
            let ocus = build_ocus(&w, &[-1, -1, -1, -1], &[1, 1, 1, 1]);
            // random window
            let win: Vec<Vec<i8>> =
                (0..9).map(|_| (0..cin).map(|_| rng.trit(0.4)).collect()).collect();
            let window = window_from(&win);
            for (co, ocu) in ocus.iter().enumerate() {
                let (acc, toggles) = ocu.compute(&window);
                // scalar reference
                let mut want = 0i32;
                let mut want_t = 0u32;
                for (k, pix) in win.iter().enumerate() {
                    for (ci, &xv) in pix.iter().enumerate() {
                        let wv = w.data[(k * cin + ci) * 4 + co] as i32;
                        let p = wv * xv as i32;
                        want += p;
                        if p != 0 {
                            want_t += 1;
                        }
                    }
                }
                assert_eq!(acc, want);
                assert_eq!(toggles, want_t);
                assert_eq!(ocu.compute_fast(&window), want);
            }
        }
    }

    #[test]
    fn dense_sentinel_thresholds_pass_raw() {
        // classifier OCUs use sentinel thresholds; threshold() never fires.
        let w = TritTensor::from_vec(&[1, 1, 2, 1], vec![1, -1]);
        let ocus = build_ocus(&w, &[], &[]);
        assert_eq!(ocus[0].threshold(500), 0);
        assert_eq!(ocus[0].threshold(-500), 0);
    }

    #[test]
    fn weight_nonzero_counted() {
        let w = TritTensor::from_vec(&[1, 1, 4, 1], vec![1, 0, -1, 0]);
        let ocu = Ocu::from_layer_weights(&w, 0, -1, 1);
        assert_eq!(ocu.weight_nonzero, 2);
    }
}
