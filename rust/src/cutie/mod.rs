//! Cycle-level digital twin of the CUTIE accelerator with the paper's TCN
//! extensions.
//!
//! Faithful to the architecture of §3–§5: one Output Channel Compute Unit
//! (OCU) per output channel, each consuming a full 3×3×C_in window per
//! cycle (output- and input-stationary, single pipeline stage), a
//! stall-free linebuffer, double-buffered activation SRAM, per-OCU weight
//! buffers, hierarchical clock gating of idle OCUs, and the flip-flop TCN
//! memory holding 24 time-step feature vectors.
//!
//! The simulator produces (a) bit-exact outputs (verified against the JAX
//! oracle, the functional reference executor and the PJRT golden model)
//! and (b) the cycle/access/toggle statistics the [`crate::energy`] model
//! converts into µJ/inference, TOp/s and TOp/s/W.

pub mod actmem;
pub mod config;
pub mod datapath;
pub mod linebuffer;
pub mod ocu;
pub mod prepared;
pub mod scheduler;
pub mod stats;
pub mod tcnmem;
pub mod weightmem;

pub use config::CutieConfig;
pub use prepared::PreparedNet;
pub use scheduler::Scheduler;
pub use scheduler::TcnStrategy;
pub use stats::{LayerStats, Phase, RunStats};
pub use tcnmem::TcnMemory;

/// µDMA ingress footprint of `numel` 2-bit trits, in bytes — the single
/// source of truth for frame-ingress byte math (the scheduler's DMA
/// cycle model and the SoC timeline both consume it; perf pass
/// iteration 8 satellite). With packed frames this is exactly the
/// packed-word payload: ⌈2·numel / 8⌉ bytes.
#[inline]
pub fn dma_ingress_bytes(numel: usize) -> u64 {
    (numel * 2).div_ceil(8) as u64
}

/// Activity-counting mode for the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Count per-MAC toggling activity (needed for the energy model).
    Accurate,
    /// Originally skipped toggle counting; since the (pos, mask) bitplane
    /// encoding (perf pass) activity comes for free, Fast reports the
    /// same counters as Accurate on both the conv datapath and the
    /// classifier (iteration 8 satellite), and differs only on the A2
    /// direct-strided ablation path. Kept as an explicit mode for
    /// benchmarks and API stability.
    Fast,
}
