//! Cycle-level digital twin of the CUTIE accelerator with the paper's TCN
//! extensions.
//!
//! Faithful to the architecture of §3–§5: one Output Channel Compute Unit
//! (OCU) per output channel, each consuming a full 3×3×C_in window per
//! cycle (output- and input-stationary, single pipeline stage), a
//! stall-free linebuffer, double-buffered activation SRAM, per-OCU weight
//! buffers, hierarchical clock gating of idle OCUs, and the flip-flop TCN
//! memory holding 24 time-step feature vectors.
//!
//! The simulator produces (a) bit-exact outputs (verified against the JAX
//! oracle, the functional reference executor and the PJRT golden model)
//! and (b) the cycle/access/toggle statistics the [`crate::energy`] model
//! converts into µJ/inference, TOp/s and TOp/s/W.

pub mod actmem;
pub mod config;
pub mod datapath;
pub mod linebuffer;
pub mod ocu;
pub mod scheduler;
pub mod stats;
pub mod tcnmem;
pub mod weightmem;

pub use config::CutieConfig;
pub use scheduler::Scheduler;
pub use scheduler::TcnStrategy;
pub use stats::{LayerStats, Phase, RunStats};

/// Activity-counting mode for the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Count per-MAC toggling activity (needed for the energy model).
    Accurate,
    /// Originally skipped toggle counting; since the (pos, mask) bitplane
    /// encoding (perf pass) activity comes for free on the conv datapath,
    /// so Fast now differs from Accurate only on the classifier/ablation
    /// paths. Kept as an explicit mode for benchmarks and API stability.
    Fast,
}
