//! The shared prepared-weight image (ISSUE 5 tentpole): every kernel of
//! a network pre-flattened into the packed (pos, mask) bitplane form the
//! datapath consumes — conv layers as position-major [`PreparedLayer`]s,
//! TCN layers already projected through the §4 mapping onto 3×3 kernel
//! sets, classifiers as chunk-major [`PreparedDense`]s.
//!
//! A [`PreparedNet`] is **immutable and built once**: the software twin
//! of CUTIE's OCU weight buffers, which are written at boot and stay
//! resident (TCN-CUTIE §3; weight stationarity is the core energy
//! argument of CUTIE itself). The serving [`crate::coordinator::Engine`]
//! holds exactly one copy behind an [`std::sync::Arc`] and every worker
//! scheduler in its pool borrows it — spawning a worker no longer
//! re-packs (or even clones) a single weight word.
//!
//! Two constructors, one result: [`PreparedNet::new`] packs from i8
//! network weights (the legacy boot), [`PreparedNet::from_image`]
//! word-copies from the packed `.ttn` v2 weight-image section. The two
//! are asserted equal (`PartialEq`, plus counter/energy-bit equivalence
//! of everything they serve) in `tests/weight_image.rs`.

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use super::config::CutieConfig;
use super::datapath::{PreparedDense, PreparedLayer};
use crate::mapping;
use crate::network::{Layer, LayerKind, Network};
use crate::tensor::ttn::{PackedLayerRecord, PackedLayerTag, WeightImage};
use crate::trit::PackedVec;

/// Per-layer geometry signature used for the cheap per-frame
/// [`PreparedNet::matches`] check.
type LayerSig = (String, LayerKind, usize, usize);

#[derive(Debug, PartialEq)]
pub struct PreparedNet {
    net_name: String,
    /// FNV-1a over the image content (names, geometry, thresholds,
    /// plane words) — the identity `pack-weights` prints and the
    /// from-image-vs-from-i8 tests compare.
    fingerprint: u64,
    /// Datapath channel width the classifiers were chunked for.
    channels: usize,
    /// Conv2d kernels, keyed by layer name.
    conv: HashMap<String, PreparedLayer>,
    /// §4-mapped TCN kernels (3×3 by construction), keyed by the
    /// original layer name.
    mapped: HashMap<String, PreparedLayer>,
    /// Packed classifiers, keyed by layer name.
    dense: HashMap<String, PreparedDense>,
    /// Network-order geometry signature for `matches`/`to_image`.
    signature: Vec<LayerSig>,
}

/// Build the §4-mapped 3×3 form of a TCN layer — taps projected into
/// the middle kernel column, bottom-aligned (the offline half of the
/// paper's mapping). This is the one place the mapped form is built, so
/// the packed and i8 execution paths cannot diverge on it.
fn mapped_form(layer: &Layer) -> PreparedLayer {
    debug_assert_eq!(layer.kind, LayerKind::Tcn);
    let mapped = Layer {
        weights: mapping::map_weights(&layer.weights),
        kernel: 3,
        kind: LayerKind::Tcn,
        pool: false,
        global_pool: false,
        ..layer.clone()
    };
    PreparedLayer::new(&mapped)
}

fn signature_of(net: &Network) -> Vec<LayerSig> {
    net.layers
        .iter()
        .map(|l| (l.name.clone(), l.kind, l.in_ch, l.out_ch))
        .collect()
}

fn fnv_mix(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
}

fn fnv_words(h: &mut u64, words: &[PackedVec]) {
    for w in words {
        for word in w.to_words() {
            fnv_mix(h, &word.to_le_bytes());
        }
    }
}

impl PreparedNet {
    /// Build the full image from i8 network weights (the legacy boot
    /// path): pack every conv kernel, project + pack every TCN layer,
    /// chunk every classifier for the `cfg.channels`-wide datapath.
    pub fn new(net: &Network, cfg: &CutieConfig) -> Self {
        let mut conv = HashMap::new();
        let mut mapped = HashMap::new();
        let mut dense = HashMap::new();
        for layer in &net.layers {
            match layer.kind {
                LayerKind::Conv2d => {
                    conv.insert(layer.name.clone(), PreparedLayer::new(layer));
                }
                LayerKind::Tcn => {
                    mapped.insert(layer.name.clone(), mapped_form(layer));
                }
                LayerKind::Dense => {
                    dense.insert(layer.name.clone(), PreparedDense::new(layer, cfg.channels));
                }
            }
        }
        Self::assemble(net.name.clone(), cfg.channels, conv, mapped, dense, signature_of(net))
    }

    /// Word-copy boot from a packed `.ttn` v2 weight image: no i8
    /// re-packing anywhere — plane words are copied as-is and the column
    /// operands re-fused with pure word ops. The image is validated
    /// against `net` (coverage, geometry, thresholds) and against `cfg`
    /// (classifier chunk width), so a stale or mismatched image is a
    /// proper boot error instead of silently-wrong labels.
    pub fn from_image(image: &WeightImage, net: &Network, cfg: &CutieConfig) -> Result<Self> {
        ensure!(
            image.chunk_channels == cfg.channels,
            "weight image packed for a {}-channel datapath, config has {}",
            image.chunk_channels,
            cfg.channels
        );
        let mut conv = HashMap::new();
        let mut mapped = HashMap::new();
        let mut dense = HashMap::new();
        for r in &image.layers {
            match r.tag {
                PackedLayerTag::Conv => {
                    conv.insert(r.name.clone(), prepared_from_record(r, LayerKind::Conv2d)?);
                }
                PackedLayerTag::MappedTcn => {
                    mapped.insert(r.name.clone(), prepared_from_record(r, LayerKind::Tcn)?);
                }
                PackedLayerTag::Dense => {
                    let d = PreparedDense::from_packed(
                        r.name.clone(),
                        r.in_ch,
                        r.out_ch,
                        image.chunk_channels,
                        r.words.clone(),
                    )?;
                    dense.insert(r.name.clone(), d);
                }
            }
        }
        let img =
            Self::assemble(net.name.clone(), cfg.channels, conv, mapped, dense, signature_of(net));
        img.validate_against(net)?;
        Ok(img)
    }

    /// Full content validation against a network: every layer covered,
    /// geometry (channels, kernel, pooling flags) and per-OCU
    /// thresholds equal. This is the boot-time gate behind
    /// [`PreparedNet::from_image`] and the engine/pipeline `with_image`
    /// constructors. The one thing it cannot check without re-packing
    /// the i8 weights is the plane words themselves — two networks with
    /// identical geometry *and* thresholds but different kernels (e.g.
    /// reseeded random nets) pass; callers who construct images
    /// independently of `net` own that last-mile identity (the supported
    /// packed-boot path loads net and image from the same TTN2 file, so
    /// it cannot diverge; compare [`PreparedNet::fingerprint`]s when in
    /// doubt).
    pub fn validate_against(&self, net: &Network) -> Result<()> {
        ensure!(
            self.net_name == net.name,
            "weight image is for '{}', network is '{}'",
            self.net_name,
            net.name
        );
        for layer in &net.layers {
            match layer.kind {
                LayerKind::Conv2d => {
                    let p = self.conv.get(&layer.name).with_context(|| {
                        format!("weight image has no conv record for '{}'", layer.name)
                    })?;
                    ensure!(
                        p.in_ch == layer.in_ch
                            && p.out_ch == layer.out_ch
                            && p.k == layer.kernel
                            && p.pool == layer.pool
                            && p.global_pool == layer.global_pool,
                        "'{}': image geometry does not match the network",
                        layer.name
                    );
                    ensure!(
                        p.thresholds() == (layer.lo.as_slice(), layer.hi.as_slice()),
                        "'{}': image thresholds differ from the network",
                        layer.name
                    );
                }
                LayerKind::Tcn => {
                    let p = self.mapped.get(&layer.name).with_context(|| {
                        format!("weight image has no mapped-TCN record for '{}'", layer.name)
                    })?;
                    ensure!(
                        p.in_ch == layer.in_ch && p.out_ch == layer.out_ch && p.k == 3,
                        "'{}': image geometry does not match the network",
                        layer.name
                    );
                    ensure!(
                        p.thresholds() == (layer.lo.as_slice(), layer.hi.as_slice()),
                        "'{}': image thresholds differ from the network",
                        layer.name
                    );
                }
                LayerKind::Dense => {
                    let p = self.dense.get(&layer.name).with_context(|| {
                        format!("weight image has no classifier record for '{}'", layer.name)
                    })?;
                    ensure!(
                        p.in_ch == layer.in_ch && p.classes == layer.out_ch,
                        "'{}': image geometry does not match the network",
                        layer.name
                    );
                }
            }
        }
        Ok(())
    }

    fn assemble(
        net_name: String,
        channels: usize,
        conv: HashMap<String, PreparedLayer>,
        mapped: HashMap<String, PreparedLayer>,
        dense: HashMap<String, PreparedDense>,
        signature: Vec<LayerSig>,
    ) -> Self {
        // One hashing shape for every record kind: tag, name, geometry
        // (channels, kernel, pooling flags), thresholds, plane words —
        // any content difference that can change served labels must
        // change the fingerprint.
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        fnv_mix(&mut h, net_name.as_bytes());
        fnv_mix(&mut h, &(channels as u64).to_le_bytes());
        let hash_prepared = |h: &mut u64, tag: &[u8], n: &String, p: &PreparedLayer| {
            fnv_mix(h, tag);
            fnv_mix(h, n.as_bytes());
            for g in [p.in_ch, p.out_ch, p.k, p.pool as usize, p.global_pool as usize] {
                fnv_mix(h, &(g as u64).to_le_bytes());
            }
            let (lo, hi) = p.thresholds();
            for v in lo.iter().chain(hi) {
                fnv_mix(h, &v.to_le_bytes());
            }
            fnv_words(h, p.flat_words());
        };
        let mut names: Vec<&String> = conv.keys().collect();
        names.sort();
        for n in names {
            hash_prepared(&mut h, b"conv", n, &conv[n]);
        }
        let mut names: Vec<&String> = mapped.keys().collect();
        names.sort();
        for n in names {
            hash_prepared(&mut h, b"tcn", n, &mapped[n]);
        }
        let mut names: Vec<&String> = dense.keys().collect();
        names.sort();
        for n in names {
            let p = &dense[n];
            fnv_mix(&mut h, b"dense");
            fnv_mix(&mut h, n.as_bytes());
            for g in [p.in_ch, p.classes, p.chunk_channels()] {
                fnv_mix(&mut h, &(g as u64).to_le_bytes());
            }
            fnv_words(&mut h, p.chunk_words());
        }
        PreparedNet { net_name, fingerprint: h, channels, conv, mapped, dense, signature }
    }

    /// Serialize as the `.ttn` v2 weight-image section, in network
    /// order (deterministic bytes for a given image).
    pub fn to_image(&self) -> WeightImage {
        let mut layers = Vec::with_capacity(self.signature.len());
        for (name, kind, _, _) in &self.signature {
            let record = match kind {
                LayerKind::Conv2d => {
                    let p = &self.conv[name];
                    let (lo, hi) = p.thresholds();
                    PackedLayerRecord {
                        name: name.clone(),
                        tag: PackedLayerTag::Conv,
                        in_ch: p.in_ch,
                        out_ch: p.out_ch,
                        k: p.k,
                        pool: p.pool,
                        global_pool: p.global_pool,
                        lo: lo.to_vec(),
                        hi: hi.to_vec(),
                        words: p.flat_words().to_vec(),
                    }
                }
                LayerKind::Tcn => {
                    let p = &self.mapped[name];
                    let (lo, hi) = p.thresholds();
                    PackedLayerRecord {
                        name: name.clone(),
                        tag: PackedLayerTag::MappedTcn,
                        in_ch: p.in_ch,
                        out_ch: p.out_ch,
                        k: p.k,
                        pool: false,
                        global_pool: false,
                        lo: lo.to_vec(),
                        hi: hi.to_vec(),
                        words: p.flat_words().to_vec(),
                    }
                }
                LayerKind::Dense => {
                    let p = &self.dense[name];
                    PackedLayerRecord {
                        name: name.clone(),
                        tag: PackedLayerTag::Dense,
                        in_ch: p.in_ch,
                        out_ch: p.classes,
                        k: 0,
                        pool: false,
                        global_pool: false,
                        lo: Vec::new(),
                        hi: Vec::new(),
                        words: p.chunk_words().to_vec(),
                    }
                }
            };
            layers.push(record);
        }
        WeightImage { chunk_channels: self.channels, layers }
    }

    /// Cheap per-frame identity check: does this image serve `net`?
    /// Compares the network name and per-layer geometry (name, kind,
    /// channel widths) — the same staleness contract the old per-name
    /// lazy caches had, made explicit: weights stay resident until a new
    /// image is attached, exactly like the OCU buffers.
    pub fn matches(&self, net: &Network) -> bool {
        self.net_name == net.name
            && self.signature.len() == net.layers.len()
            && self
                .signature
                .iter()
                .zip(&net.layers)
                .all(|(s, l)| s.0 == l.name && s.1 == l.kind && s.2 == l.in_ch && s.3 == l.out_ch)
    }

    /// (conv + mapped-TCN kernels, classifiers) in the image — the
    /// observability hook behind `Scheduler::cached_layers`.
    pub fn counts(&self) -> (usize, usize) {
        (self.conv.len() + self.mapped.len(), self.dense.len())
    }

    pub fn net_name(&self) -> &str {
        &self.net_name
    }

    /// Content fingerprint (FNV-1a over names, geometry, thresholds and
    /// plane words).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Datapath channel width the classifiers were chunked for.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Per-layer scrub inventory, in network order: (layer name, resident
    /// plane words), one "word" being one [`PackedVec`] — the granularity
    /// the weight-scrub pass scans and re-adopts. The sum over all layers
    /// is the entire boot-resident image, i.e. the weight-surface fault
    /// exposure per frame.
    pub fn scrub_inventory(&self) -> Vec<(String, u64)> {
        self.signature
            .iter()
            .map(|(name, kind, _, _)| {
                let words = match kind {
                    LayerKind::Conv2d => self.conv[name].flat_words().len(),
                    LayerKind::Tcn => self.mapped[name].flat_words().len(),
                    LayerKind::Dense => self.dense[name].chunk_words().len(),
                };
                (name.clone(), words as u64)
            })
            .collect()
    }

    /// A conv2d layer's prepared kernels.
    pub fn conv_layer(&self, name: &str) -> Result<&PreparedLayer> {
        self.conv
            .get(name)
            .with_context(|| format!("conv layer '{name}' is not in the prepared image"))
    }

    /// A TCN layer's §4-mapped prepared kernels.
    pub fn mapped_layer(&self, name: &str) -> Result<&PreparedLayer> {
        self.mapped
            .get(name)
            .with_context(|| format!("TCN layer '{name}' is not in the prepared image"))
    }

    /// A classifier's packed chunk words. The one lookup every tail
    /// (packed, i8 reference, cifar-style feed-forward) shares — the
    /// previously triplicated `prepared_dense.entry(..).or_insert_with`
    /// sites collapsed into it.
    pub fn dense_layer(&self, name: &str) -> Result<&PreparedDense> {
        self.dense
            .get(name)
            .with_context(|| format!("classifier '{name}' is not in the prepared image"))
    }
}

fn prepared_from_record(r: &PackedLayerRecord, kind: LayerKind) -> Result<PreparedLayer> {
    PreparedLayer::from_packed(
        r.name.clone(),
        kind,
        r.in_ch,
        r.out_ch,
        r.k,
        r.pool,
        r.global_pool,
        r.words.clone(),
        r.lo.clone(),
        r.hi.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cutie::CutieConfig;
    use crate::network::{cifar9_random, dvs_hybrid_random};

    #[test]
    fn image_roundtrip_equals_i8_build() {
        let cfg = CutieConfig::kraken();
        for net in [dvs_hybrid_random(16, 71, 0.5), cifar9_random(24, 72, 0.33)] {
            let built = PreparedNet::new(&net, &cfg);
            let image = built.to_image();
            let reloaded = PreparedNet::from_image(&image, &net, &cfg).unwrap();
            assert_eq!(reloaded, built, "{}: word-copy boot must equal i8 build", net.name);
            assert_eq!(reloaded.fingerprint(), built.fingerprint());
            assert!(built.matches(&net));
            assert_eq!(image.layers.len(), net.layers.len());
        }
    }

    #[test]
    fn counts_match_network_shape() {
        let cfg = CutieConfig::kraken();
        let net = dvs_hybrid_random(16, 73, 0.5);
        let img = PreparedNet::new(&net, &cfg);
        assert_eq!(img.counts(), (9, 1)); // 5 conv + 4 mapped TCN, 1 classifier
        assert!(img.conv_layer("l0").is_ok());
        assert!(img.mapped_layer("l5").is_ok());
        assert!(img.dense_layer("l9").is_ok());
        assert!(img.conv_layer("nope").is_err());
        assert!(img.mapped_layer("l0").is_err(), "conv layers are not mapped-TCN kernels");
    }

    #[test]
    fn scrub_inventory_covers_whole_image() {
        let cfg = CutieConfig::kraken();
        let net = dvs_hybrid_random(16, 70, 0.5);
        let img = PreparedNet::new(&net, &cfg);
        let inv = img.scrub_inventory();
        assert_eq!(inv.len(), net.layers.len(), "one entry per layer, network order");
        for ((name, words), layer) in inv.iter().zip(&net.layers) {
            assert_eq!(name, &layer.name);
            assert!(*words > 0, "'{name}' must expose resident words");
        }
        // entries agree with the served words, layer by layer
        assert_eq!(inv[0].1, img.conv_layer("l0").unwrap().flat_words().len() as u64);
        let (dense_name, dense_words) = inv.last().unwrap();
        assert_eq!(
            *dense_words,
            img.dense_layer(dense_name).unwrap().chunk_words().len() as u64
        );
    }

    #[test]
    fn matches_rejects_other_geometry() {
        let cfg = CutieConfig::kraken();
        let net16 = dvs_hybrid_random(16, 74, 0.5);
        let net32 = dvs_hybrid_random(32, 74, 0.5);
        let img = PreparedNet::new(&net16, &cfg);
        assert!(img.matches(&net16));
        assert!(!img.matches(&net32), "different channel widths must not match");
        assert!(!img.matches(&cifar9_random(16, 74, 0.3)));
    }

    #[test]
    fn from_image_rejects_mismatches() {
        let cfg = CutieConfig::kraken();
        let net = dvs_hybrid_random(16, 75, 0.5);
        let good = PreparedNet::new(&net, &cfg).to_image();

        // chunk width mismatch
        let mut img = good.clone();
        img.chunk_channels = 48;
        assert!(PreparedNet::from_image(&img, &net, &cfg).is_err());

        // missing record
        let mut img = good.clone();
        img.layers.remove(0);
        assert!(PreparedNet::from_image(&img, &net, &cfg).is_err());

        // tampered thresholds
        let mut img = good.clone();
        img.layers[0].lo[0] -= 1;
        assert!(PreparedNet::from_image(&img, &net, &cfg).is_err());

        // image for a different network
        let other = PreparedNet::new(&dvs_hybrid_random(32, 76, 0.5), &cfg).to_image();
        assert!(PreparedNet::from_image(&other, &net, &cfg).is_err());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let cfg = CutieConfig::kraken();
        let net = dvs_hybrid_random(16, 77, 0.5);
        let a = PreparedNet::new(&net, &cfg);
        let b = PreparedNet::new(&dvs_hybrid_random(16, 77, 0.5), &cfg);
        let c = PreparedNet::new(&dvs_hybrid_random(16, 78, 0.5), &cfg);
        assert_eq!(a.fingerprint(), b.fingerprint(), "same seed, same image");
        assert_ne!(a.fingerprint(), c.fingerprint(), "different weights, different image");
        // every label-affecting field must move the fingerprint: TCN
        // thresholds and conv pooling flags included (not just conv
        // thresholds and plane words)
        let mut tcn_thresh = net.clone();
        tcn_thresh.layers[5].lo[0] -= 1;
        assert_ne!(
            a.fingerprint(),
            PreparedNet::new(&tcn_thresh, &cfg).fingerprint(),
            "a TCN threshold change must change the fingerprint"
        );
        let mut pool_flip = net.clone();
        pool_flip.layers[0].pool = false;
        assert_ne!(
            a.fingerprint(),
            PreparedNet::new(&pool_flip, &cfg).fingerprint(),
            "a pooling-flag change must change the fingerprint"
        );
    }

    #[test]
    fn validate_against_catches_same_shape_threshold_divergence() {
        let cfg = CutieConfig::kraken();
        let net = dvs_hybrid_random(16, 79, 0.5);
        let img = PreparedNet::new(&net, &cfg);
        img.validate_against(&net).unwrap();
        let mut tampered = net.clone();
        tampered.layers[6].hi[2] += 1;
        assert!(
            img.validate_against(&tampered).is_err(),
            "same geometry, different thresholds must not validate"
        );
    }
}
