//! Per-layer cycle loop: the completely unrolled datapath. One output
//! pixel position per cycle; all active OCUs consume the same full
//! 3×3×C_in window from the linebuffer (input-stationary), accumulate in
//! one pipeline stage, threshold, optionally pool, and write back.
//!
//! This is the simulator's hot path (see EXPERIMENTS.md §Perf).

use anyhow::{ensure, Result};

use super::config::CutieConfig;
use super::linebuffer::LineBuffer;
use super::ocu::{build_ocus, Ocu};
use super::stats::LayerStats;
use super::SimMode;
use crate::network::{Layer, LayerKind};
use crate::tensor::{IntTensor, TritTensor};
use crate::trit::PackedVec;

pub struct LayerResult {
    pub output: TritTensor,
    pub stats: LayerStats,
}

/// A layer pre-flattened for the datapath: contiguous position-major
/// packed kernels + threshold arrays (perf pass iteration 5 — built once
/// per layer and cached by the scheduler across frames instead of being
/// re-packed on every inference).
pub struct PreparedLayer {
    pub name: String,
    pub kind: LayerKind,
    pub in_ch: usize,
    pub out_ch: usize,
    pub k: usize,
    pub pool: bool,
    pub global_pool: bool,
    weights_flat: Vec<PackedVec>,
    lo_flat: Vec<i32>,
    hi_flat: Vec<i32>,
}

impl PreparedLayer {
    pub fn new(layer: &Layer) -> Self {
        let ocus: Vec<Ocu> = build_ocus(&layer.weights, &layer.lo, &layer.hi);
        let active = ocus.len();
        let k = layer.weights.dims[0];
        let k2 = k * k;
        let mut weights_flat: Vec<PackedVec> = vec![PackedVec::ZERO; k2 * active];
        for (co, ocu) in ocus.iter().enumerate() {
            for kk in 0..k2 {
                weights_flat[kk * active + co] = ocu.weights[kk];
            }
        }
        PreparedLayer {
            name: layer.name.clone(),
            kind: layer.kind,
            in_ch: layer.in_ch,
            out_ch: layer.out_ch,
            k,
            pool: layer.pool,
            global_pool: layer.global_pool,
            lo_flat: ocus.iter().map(|o| o.lo).collect(),
            hi_flat: ocus.iter().map(|o| o.hi).collect(),
            weights_flat,
        }
    }
}

/// Run one conv2d-style layer (also used for mapped TCN layers, which are
/// plain 3×3 layers by construction). Stateless wrapper: prepares the
/// layer and runs it. The scheduler caches [`PreparedLayer`]s and calls
/// [`run_prepared`] directly (perf pass iteration 5).
pub fn run_conv_layer(
    layer: &Layer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<LayerResult> {
    ensure!(layer.kind == LayerKind::Conv2d || layer.kind == LayerKind::Tcn);
    run_prepared(&PreparedLayer::new(layer), input, cfg, mode)
}

/// Run a prepared layer. Weight-load cycles are charged by the scheduler
/// (it owns the weight memory); this accounts for everything downstream
/// of the weight buffers.
pub fn run_prepared(
    prep: &PreparedLayer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<LayerResult> {
    ensure!(input.dims.len() == 3, "conv input must be (H, W, C)");
    let (h, w, cin) = (input.dims[0], input.dims[1], input.dims[2]);
    ensure!(cin == prep.in_ch, "{}: input channels {cin} != {}", prep.name, prep.in_ch);
    ensure!(cin <= cfg.channels, "{}: {cin} input channels exceed the {} datapath", prep.name, cfg.channels);
    ensure!(prep.out_ch <= cfg.channels, "{}: {} output channels exceed {} OCUs", prep.name, prep.out_ch, cfg.channels);
    ensure!(h <= cfg.max_hw && w <= cfg.max_hw, "{}: {h}×{w} exceeds {}²", prep.name, cfg.max_hw);

    // Mapped TCN weights arrive pre-projected from the scheduler as 3×3
    // kernels; plain conv layers carry their own.
    let k = prep.k;
    ensure!(k == cfg.kernel, "{}: kernel {k} != datapath {}", prep.name, cfg.kernel);
    let k2 = k * k;
    let active = prep.out_ch;
    let weights_flat = &prep.weights_flat;
    let lo_flat = &prep.lo_flat;
    let hi_flat = &prep.hi_flat;

    let mut stats = LayerStats {
        name: prep.name.clone(),
        active_ocus: active,
        fanin: k * k * cin,
        ..Default::default()
    };

    stats.lb_fill_cycles = LineBuffer::new(k, w).fill_cycles(w);

    // Row-parallel compute (perf pass iteration 3): output rows are
    // independent, so they are sharded over threads; each shard drives its
    // own linebuffer. Counters stay exact: toggles are summed across
    // shards, and in the stall-free design every input pixel is fetched
    // exactly once (h·w reads) regardless of sharding.
    let mut out = TritTensor::zeros(&[h, w, active]);
    let threads = if h * w * active * cin >= 64 * 64 * 16 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(h)
    } else {
        1
    };
    let narrow = cin <= 64;
    let _ = mode; // both modes share the loop: toggle counting is free now
    let rows_per = h.div_ceil(threads);
    let mut row_chunks: Vec<&mut [i8]> = out.data.chunks_mut(rows_per * w * active).collect();
    let toggle_counts: Vec<u64> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, chunk) in row_chunks.drain(..).enumerate() {
            let handle = scope.spawn(move || {
                let y0 = t * rows_per;
                let y1 = (y0 + rows_per).min(h);
                let mut lb = LineBuffer::new(k, w);
                let mut window = vec![PackedVec::ZERO; k2];
                let mut acc = vec![0i32; active];
                let mut toggles = 0u64;
                for y in y0..y1 {
                    lb.advance_to(y, input);
                    for x in 0..w {
                        lb.window(y, x, h, &mut window);
                        acc.fill(0);
                        // position-major accumulation: the OCU dimension is
                        // the contiguous inner loop; zero window positions
                        // (common on sparse DVS maps) are skipped outright
                        // — bit-exact, they contribute no acc and no
                        // toggles.
                        for (kk, xw) in window.iter().enumerate() {
                            if xw.is_zero() {
                                continue;
                            }
                            let wrow = &weights_flat[kk * active..(kk + 1) * active];
                            // narrow layers (C_in <= 64) use the
                            // single-word dot; toggle counting is free in
                            // this encoding, so both modes share it
                            if narrow {
                                for (a, wv) in acc.iter_mut().zip(wrow) {
                                    let (d, tog) = wv.dot_narrow(xw);
                                    *a += d;
                                    toggles += tog as u64;
                                }
                            } else {
                                for (a, wv) in acc.iter_mut().zip(wrow) {
                                    let (d, tog) = wv.dot(xw);
                                    *a += d;
                                    toggles += tog as u64;
                                }
                            }
                        }
                        let obase = ((y - y0) * w + x) * active;
                        for co in 0..active {
                            chunk[obase + co] =
                                crate::trit::ternarize(acc[co], lo_flat[co], hi_flat[co]);
                        }
                    }
                }
                toggles
            });
            handles.push(handle);
        }
        handles.into_iter().map(|h| h.join().expect("datapath shard")).collect()
    });
    stats.mac_toggles = toggle_counts.iter().sum();
    stats.compute_cycles = (h * w) as u64;
    stats.drain_cycles = 1; // single OCU pipeline stage (§3, Fig. 2)
    stats.lb_pushes = (h * w) as u64; // every input pixel enters the FFs once
    stats.act_reads = (h * w) as u64; // one word per input pixel
    stats.hw_ops = cfg.hw_ops_per_cycle(active) * stats.compute_cycles;
    stats.alg_macs = (h * w * stats.fanin * active) as u64;
    // Clocked multiplier positions in active OCUs span the full C-channel
    // datapath even when C_in < C (inputs are zero-padded wires).
    let clocked = (active * cfg.channels * k * k) as u64 * stats.compute_cycles;
    stats.mac_idle = clocked.saturating_sub(stats.mac_toggles);

    // On-the-fly pooling in the OCUs (§3): decimates write-back traffic,
    // costs no extra cycles.
    let mut result = out;
    if prep.pool {
        result = crate::network::reference::maxpool2x2(&result);
    }
    if prep.global_pool {
        result = crate::network::reference::global_maxpool(&result);
    }
    stats.act_writes = if result.dims.len() == 3 {
        (result.dims[0] * result.dims[1]) as u64
    } else {
        1
    };

    Ok(LayerResult { output: result, stats })
}

/// Classifier layer: the feature vector streams through the adder trees
/// C-channels per cycle; `classes` OCUs stay active, the rest are gated.
/// Raw accumulators go out over the config port (no ternarization).
pub fn run_dense_layer(
    layer: &Layer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<(IntTensor, LayerStats)> {
    ensure!(layer.kind == LayerKind::Dense);
    let f = layer.in_ch;
    ensure!(input.numel() == f, "{}: classifier input {} != {}", layer.name, input.numel(), f);
    let classes = layer.out_ch;

    let mut stats = LayerStats {
        name: layer.name.clone(),
        active_ocus: classes,
        fanin: f,
        ..Default::default()
    };

    let chunks = f.div_ceil(cfg.channels);
    let mut logits = IntTensor::zeros(&[classes]);
    for chunk in 0..chunks {
        let lo_i = chunk * cfg.channels;
        let hi_i = ((chunk + 1) * cfg.channels).min(f);
        let x = PackedVec::pack(&input.data[lo_i..hi_i]);
        for co in 0..classes {
            // weight slice for this chunk/output
            let trits: Vec<i8> =
                (lo_i..hi_i).map(|i| layer.weights.data[i * classes + co]).collect();
            let wv = PackedVec::pack(&trits);
            match mode {
                SimMode::Accurate => {
                    let (acc, toggles) = wv.dot(&x);
                    logits.data[co] += acc;
                    stats.mac_toggles += toggles as u64;
                }
                SimMode::Fast => {
                    logits.data[co] += wv.dot_fast(&x);
                }
            }
        }
    }
    stats.compute_cycles = chunks as u64;
    stats.drain_cycles = 1;
    stats.act_reads = chunks as u64;
    stats.act_writes = 0; // logits leave via the config port / interrupt
    stats.hw_ops = cfg.hw_ops_per_cycle(classes) * stats.compute_cycles;
    stats.alg_macs = (f * classes) as u64;
    let clocked = (classes * cfg.channels * cfg.kernel * cfg.kernel) as u64 * stats.compute_cycles;
    stats.mac_idle = clocked.saturating_sub(stats.mac_toggles);
    Ok((logits, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::reference;
    use crate::network::{cifar9_random, LayerKind};
    use crate::util::rng::Rng;

    #[test]
    fn datapath_matches_reference_executor() {
        // Property: cycle-level output == functional reference, across
        // sizes, channel counts and sparsities.
        let mut rng = Rng::new(71);
        let cfg = CutieConfig::kraken();
        for case in 0..12 {
            let net = cifar9_random(8 + 8 * (case % 3), 100 + case as u64, [0.0, 0.33, 0.66][case % 3]);
            let layer = &net.layers[case % 8];
            if layer.kind != LayerKind::Conv2d {
                continue;
            }
            let hw = 4 + 2 * rng.below(6);
            let input = TritTensor::random(&[hw, hw, layer.in_ch], &mut rng, 0.4);
            let got = run_conv_layer(layer, &input, &cfg, SimMode::Accurate).unwrap();
            let want = reference::run_conv_layer(layer, &input);
            assert_eq!(got.output, want, "case {case}");
            // Fast mode must agree too.
            let fast = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
            assert_eq!(fast.output, want);
            assert_eq!(fast.stats.compute_cycles, got.stats.compute_cycles);
            // since the (pos, mask) encoding, toggle counting is free and
            // Fast mode reports it too
            assert_eq!(fast.stats.mac_toggles, got.stats.mac_toggles);
        }
    }

    #[test]
    fn cycle_model_shape() {
        let net = cifar9_random(96, 7, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(72);
        let input = TritTensor::random(&[32, 32, 96], &mut rng, 0.4);
        let layer = &net.layers[2]; // 96→96, no pool
        let r = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
        assert_eq!(r.stats.compute_cycles, 32 * 32);
        assert_eq!(r.stats.lb_fill_cycles, 2 * 32 + 2);
        assert_eq!(r.stats.act_reads, 32 * 32); // every pixel read once
        assert_eq!(r.stats.act_writes, 32 * 32);
        assert_eq!(r.stats.hw_ops, 165_888 * 1024);
        assert_eq!(r.stats.alg_macs, 1024 * 9 * 96 * 96);
    }

    #[test]
    fn pooling_decimates_writes_not_cycles() {
        let net = cifar9_random(16, 9, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(73);
        let layer = &net.layers[1]; // pool = true
        let input = TritTensor::random(&[16, 16, 16], &mut rng, 0.3);
        let r = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
        assert_eq!(r.stats.compute_cycles, 256);
        assert_eq!(r.stats.act_writes, 64); // 8×8 after pooling
        assert_eq!(r.output.dims, vec![8, 8, 16]);
    }

    #[test]
    fn toggles_track_sparsity() {
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(74);
        let dense_net = cifar9_random(32, 10, 0.0);
        let sparse_net = cifar9_random(32, 10, 0.8);
        let input_dense = TritTensor::random(&[8, 8, 32], &mut rng, 0.0);
        let input_sparse = TritTensor::random(&[8, 8, 32], &mut rng, 0.8);
        let d = run_conv_layer(&dense_net.layers[2], &input_dense, &cfg, SimMode::Accurate).unwrap();
        let s = run_conv_layer(&sparse_net.layers[2], &input_sparse, &cfg, SimMode::Accurate).unwrap();
        assert!(
            s.stats.mac_toggles * 10 < d.stats.mac_toggles,
            "sparse toggles {} vs dense {}",
            s.stats.mac_toggles,
            d.stats.mac_toggles
        );
    }

    #[test]
    fn dense_layer_matches_reference() {
        let net = cifar9_random(24, 11, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(75);
        let fc = net.layers.last().unwrap();
        let x = TritTensor::random(&[fc.in_ch], &mut rng, 0.4);
        let (logits, stats) = run_dense_layer(fc, &x, &cfg, SimMode::Accurate).unwrap();
        let want = reference::run_dense_layer(fc, &x);
        assert_eq!(logits, want);
        assert_eq!(stats.compute_cycles, (fc.in_ch as u64).div_ceil(96));
    }

    #[test]
    fn rejects_oversized_maps() {
        let net = cifar9_random(96, 12, 0.33);
        let cfg = CutieConfig::kraken();
        let input = TritTensor::zeros(&[65, 65, 96]);
        assert!(run_conv_layer(&net.layers[2], &input, &cfg, SimMode::Fast).is_err());
    }
}
