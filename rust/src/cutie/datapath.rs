//! Per-layer cycle loop: the completely unrolled datapath. One output
//! pixel position per cycle; all active OCUs consume the same 3×3×C_in
//! window from the linebuffer, accumulate in one pipeline stage,
//! threshold, optionally pool, and write back.
//!
//! This is the simulator's hot path (see EXPERIMENTS.md §Perf). Since
//! perf pass iteration 8 the loop is **packed end to end**: feature maps
//! arrive and leave as bit-packed [`PackedMap`]s (the activation SRAM's
//! native 2-bit encoding), the linebuffer borrows packed rows zero-copy
//! ([`PackedLineBuffer`]), ternarization writes (pos, mask) words
//! directly ([`ternarize_packed`]) and pooling is two bitwise ops per
//! word — no i8 conversion anywhere between layers. The loop itself is
//! **column-stationary** (iteration 7): each *input* column is packed
//! once into a dense [`TritCol`] vector and fused-dotted against the
//! three kernel-column vectors; every output pixel is the sum of three
//! cached column partials. Bit-exact by construction: accumulators and
//! popcount-based toggle statistics are additive over partial products,
//! so every counter matches the legacy loop — which is retained below
//! ([`run_prepared_window`]) as the **i8 window-stationary baseline**
//! for the packed-vs-i8 equivalence tests (`tests/column_reuse.rs`,
//! `tests/packed.rs`) and the A/B case in the hotpath bench.

use anyhow::{ensure, Result};

use super::config::CutieConfig;
use super::linebuffer::{LaneBuffers, LineBuffer, PackedLineBuffer};
use super::ocu::{build_ocus, Ocu};
use super::stats::LayerStats;
use super::SimMode;
use crate::network::{Layer, LayerKind};
use crate::tensor::{IntTensor, PackedMap, TritTensor};
use crate::trit::{ternarize, ternarize_packed, PackedVec, TritCol};

/// Result of the packed (default) conv loop.
pub struct LayerResult {
    pub output: PackedMap,
    pub stats: LayerStats,
}

/// Result of the retained i8-currency baseline loop.
pub struct LayerResultI8 {
    pub output: TritTensor,
    pub stats: LayerStats,
}

/// A layer pre-flattened for the datapath: contiguous position-major
/// packed kernels + threshold arrays (perf pass iteration 5 — built once
/// per layer and cached by the scheduler across frames instead of being
/// re-packed on every inference), plus the column-major fused kernel
/// vectors the column-stationary loop consumes (iteration 7). Since the
/// shared-image pass the prepared form is also the `.ttn` v2 on-disk
/// weight currency: [`PreparedLayer::flat_words`] is exactly what the
/// packed weight-image section stores, and
/// [`PreparedLayer::from_packed`] rebuilds the layer from those words
/// without ever touching i8.
#[derive(Debug, PartialEq)]
pub struct PreparedLayer {
    pub name: String,
    pub kind: LayerKind,
    pub in_ch: usize,
    pub out_ch: usize,
    pub k: usize,
    pub pool: bool,
    pub global_pool: bool,
    /// Position-major kernels: `weights_flat[kk * out_ch + co]` (window
    /// loop operand).
    weights_flat: Vec<PackedVec>,
    /// Column-major fused kernels: `wcols[kc * out_ch + co]` packs the
    /// three kernel rows of column kc into one dense [`TritCol`]
    /// (column loop operand; built for 3×3 kernels only).
    wcols: Vec<TritCol>,
    /// Dense words per column vector for this layer's C_in.
    col_words: usize,
    lo_flat: Vec<i32>,
    hi_flat: Vec<i32>,
}

/// Fuse position-major kernel words into the column-major [`TritCol`]
/// operands of the fused column loop (`wcols[kc · active + co]` packs
/// kernel rows kc, 3+kc, 6+kc of OCU co). Pure word-level ops — shared
/// by the i8 build path ([`PreparedLayer::new`]) and the word-copy boot
/// path ([`PreparedLayer::from_packed`]) so the two cannot diverge.
fn fuse_wcols(weights_flat: &[PackedVec], active: usize, in_ch: usize) -> (Vec<TritCol>, usize) {
    let col_words = TritCol::words(in_ch);
    let mut wcols = vec![TritCol::ZERO; 3 * active];
    for co in 0..active {
        for kc in 0..3 {
            let rows = [
                weights_flat[kc * active + co],
                weights_flat[(3 + kc) * active + co],
                weights_flat[(6 + kc) * active + co],
            ];
            wcols[kc * active + co] = TritCol::pack_rows(&rows, in_ch);
        }
    }
    (wcols, col_words)
}

impl PreparedLayer {
    pub fn new(layer: &Layer) -> Self {
        let ocus: Vec<Ocu> = build_ocus(&layer.weights, &layer.lo, &layer.hi);
        let active = ocus.len();
        let k = layer.weights.dims[0];
        let k2 = k * k;
        let mut weights_flat: Vec<PackedVec> = vec![PackedVec::ZERO; k2 * active];
        for (co, ocu) in ocus.iter().enumerate() {
            for kk in 0..k2 {
                weights_flat[kk * active + co] = ocu.weights[kk];
            }
        }
        let (wcols, col_words) = if k == 3 {
            fuse_wcols(&weights_flat, active, layer.in_ch)
        } else {
            (Vec::new(), 0)
        };
        PreparedLayer {
            name: layer.name.clone(),
            kind: layer.kind,
            in_ch: layer.in_ch,
            out_ch: layer.out_ch,
            k,
            pool: layer.pool,
            global_pool: layer.global_pool,
            lo_flat: ocus.iter().map(|o| o.lo).collect(),
            hi_flat: ocus.iter().map(|o| o.hi).collect(),
            weights_flat,
            wcols,
            col_words,
        }
    }

    /// Rebuild a prepared layer straight from serialized (pos, mask)
    /// plane words — the `.ttn` v2 word-copy boot path. `weights_flat`
    /// must be position-major (`[kk · out_ch + co]`) with every word's
    /// plane bits beyond `in_ch` clear; the column operands are re-fused
    /// with the same word-level helper the i8 path uses, so the result
    /// is identical to `PreparedLayer::new` on the unpacked weights.
    #[allow(clippy::too_many_arguments)]
    pub fn from_packed(
        name: String,
        kind: LayerKind,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        pool: bool,
        global_pool: bool,
        weights_flat: Vec<PackedVec>,
        lo_flat: Vec<i32>,
        hi_flat: Vec<i32>,
    ) -> Result<Self> {
        ensure!(kind != LayerKind::Dense, "{name}: dense layers use PreparedDense");
        ensure!(
            in_ch >= 1 && in_ch <= crate::trit::MAX_CHANNELS,
            "{name}: {in_ch} input channels"
        );
        ensure!(
            out_ch >= 1 && out_ch <= crate::trit::MAX_CHANNELS,
            "{name}: {out_ch} output channels"
        );
        ensure!(
            weights_flat.len() == k * k * out_ch,
            "{name}: {} plane words for a {k}×{k}×{out_ch} kernel set",
            weights_flat.len()
        );
        ensure!(
            lo_flat.len() == out_ch && hi_flat.len() == out_ch,
            "{name}: threshold length mismatch"
        );
        for co in 0..out_ch {
            ensure!(
                (lo_flat[co] as i64) <= (hi_flat[co] as i64) + 1,
                "{name}: channel {co} violates lo <= hi + 1"
            );
        }
        for w in &weights_flat {
            ensure!(w.masked(in_ch) == *w, "{name}: stale plane bits beyond {in_ch} channels");
        }
        let (wcols, col_words) = if k == 3 {
            fuse_wcols(&weights_flat, out_ch, in_ch)
        } else {
            (Vec::new(), 0)
        };
        Ok(PreparedLayer {
            name,
            kind,
            in_ch,
            out_ch,
            k,
            pool,
            global_pool,
            lo_flat,
            hi_flat,
            weights_flat,
            wcols,
            col_words,
        })
    }

    /// The position-major plane words (`[kk · out_ch + co]`) — the
    /// layer's serialized form in the packed `.ttn` v2 image section.
    pub fn flat_words(&self) -> &[PackedVec] {
        &self.weights_flat
    }

    /// Per-OCU ternarization thresholds `(lo, hi)`.
    pub fn thresholds(&self) -> (&[i32], &[i32]) {
        (&self.lo_flat, &self.hi_flat)
    }
}

/// Run one conv2d-style layer (also used for mapped TCN layers, which are
/// plain 3×3 layers by construction). Stateless i8-edge wrapper: packs
/// the input, prepares the layer and runs the packed loop. The scheduler
/// caches [`PreparedLayer`]s and calls [`run_prepared`] directly on the
/// packed maps it ping-pongs (perf pass iterations 5 and 8).
pub fn run_conv_layer(
    layer: &Layer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<LayerResult> {
    ensure!(layer.kind == LayerKind::Conv2d || layer.kind == LayerKind::Tcn);
    run_prepared(&PreparedLayer::new(layer), &PackedMap::from_trit(input), cfg, mode)
}

fn check_geometry(
    prep: &PreparedLayer,
    h: usize,
    w: usize,
    cin: usize,
    cfg: &CutieConfig,
) -> Result<()> {
    ensure!(cin == prep.in_ch, "{}: input channels {cin} != {}", prep.name, prep.in_ch);
    ensure!(cin <= cfg.channels, "{}: {cin} input channels exceed the {} datapath", prep.name, cfg.channels);
    ensure!(prep.out_ch <= cfg.channels, "{}: {} output channels exceed {} OCUs", prep.name, prep.out_ch, cfg.channels);
    ensure!(h <= cfg.max_hw && w <= cfg.max_hw, "{}: {h}×{w} exceeds {}²", prep.name, cfg.max_hw);
    ensure!(prep.k == cfg.kernel, "{}: kernel {} != datapath {}", prep.name, prep.k, cfg.kernel);
    Ok(())
}

/// Row-parallel compute (perf pass iteration 3): output rows are
/// independent, so they are sharded over threads; each shard drives its
/// own linebuffer. Counters stay exact: toggles are summed across shards,
/// and in the stall-free design every input pixel is fetched exactly once
/// (h·w reads) regardless of sharding. Iteration 7 also bails to a single
/// thread on small maps (e.g. the 25×1 mapped-TCN wraps) where the
/// spawn/join cost dwarfs the per-shard work.
fn shard_threads(cfg: &CutieConfig, h: usize, w: usize, active: usize, cin: usize) -> usize {
    if cfg.max_threads <= 1 || h * w < 256 || h * w * active * cin < 64 * 64 * 16 {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cfg.max_threads)
        .min(h)
}

fn base_stats(prep: &PreparedLayer, cfg: &CutieConfig, h: usize, w: usize, cin: usize) -> LayerStats {
    let mut stats = LayerStats {
        name: prep.name.clone(),
        active_ocus: prep.out_ch,
        fanin: prep.k * prep.k * cin,
        ..Default::default()
    };
    stats.lb_fill_cycles = LineBuffer::new(prep.k, w).fill_cycles(w);
    stats.compute_cycles = (h * w) as u64;
    stats.drain_cycles = 1; // single OCU pipeline stage (§3, Fig. 2)
    stats.lb_pushes = (h * w) as u64; // every input pixel enters the FFs once
    stats.act_reads = (h * w) as u64; // one word per input pixel
    stats.hw_ops = cfg.hw_ops_per_cycle(prep.out_ch) * stats.compute_cycles;
    stats.alg_macs = (h * w * stats.fanin * prep.out_ch) as u64;
    stats
}

/// Shared tail of the activity ledger — one site for the idle-position
/// model so the packed and i8 loops cannot diverge on it.
fn finish_activity(prep: &PreparedLayer, cfg: &CutieConfig, mac_toggles: u64, stats: &mut LayerStats) {
    stats.mac_toggles = mac_toggles;
    // Clocked multiplier positions in active OCUs span the full C-channel
    // datapath even when C_in < C (inputs are zero-padded wires).
    let clocked =
        (prep.out_ch * cfg.channels * prep.k * prep.k) as u64 * stats.compute_cycles;
    stats.mac_idle = clocked.saturating_sub(stats.mac_toggles);
}

/// On-the-fly pooling in the OCUs (§3): decimates write-back traffic,
/// costs no extra cycles. Finishes the activity ledger. The i8 baseline
/// loop has a scalar twin ([`finalize_conv_i8`]); the packed-vs-i8
/// equivalence tests enforce that the two stay counter-identical.
fn finalize_conv(
    prep: &PreparedLayer,
    cfg: &CutieConfig,
    out: PackedMap,
    mac_toggles: u64,
    mut stats: LayerStats,
) -> LayerResult {
    finish_activity(prep, cfg, mac_toggles, &mut stats);
    let mut result = out;
    if prep.pool {
        result = result.maxpool2x2();
    }
    if prep.global_pool {
        result = result.global_maxpool();
    }
    stats.act_writes = (result.h * result.w) as u64;
    LayerResult { output: result, stats }
}

/// Scalar-pooling twin of [`finalize_conv`] for the i8 baseline loop.
fn finalize_conv_i8(
    prep: &PreparedLayer,
    cfg: &CutieConfig,
    out: TritTensor,
    mac_toggles: u64,
    mut stats: LayerStats,
) -> LayerResultI8 {
    finish_activity(prep, cfg, mac_toggles, &mut stats);
    let mut result = out;
    if prep.pool {
        result = crate::network::reference::maxpool2x2(&result);
    }
    if prep.global_pool {
        result = crate::network::reference::global_maxpool(&result);
    }
    stats.act_writes = if result.dims.len() == 3 {
        (result.dims[0] * result.dims[1]) as u64
    } else {
        1
    };
    LayerResultI8 { output: result, stats }
}

/// Run a prepared layer through the **packed column-stationary** loop
/// (perf pass iterations 7+8, the default): packed map in, packed map
/// out. Weight-load cycles are charged by the scheduler (it owns the
/// weight memory); this accounts for everything downstream of the
/// weight buffers.
pub fn run_prepared(
    prep: &PreparedLayer,
    input: &PackedMap,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<LayerResult> {
    let (h, w, cin) = (input.h, input.w, input.c);
    check_geometry(prep, h, w, cin, cfg)?;
    if prep.k != 3 {
        // the fused column path is hardwired to the 3×3 RTL geometry;
        // non-3×3 configs fall back to the generic window loop (i8 at
        // the edges of this rarely-taken branch only)
        let r = run_prepared_window(prep, &input.to_trit(), cfg, mode)?;
        return Ok(LayerResult { output: PackedMap::from_trit(&r.output), stats: r.stats });
    }
    let k = prep.k;
    let active = prep.out_ch;
    let col_words = prep.col_words;
    let wcols = &prep.wcols;
    let lo_flat = &prep.lo_flat;
    let hi_flat = &prep.hi_flat;
    let stats = base_stats(prep, cfg, h, w, cin);
    let _ = mode; // both modes share the loop: toggle counting is free now

    let mut out = PackedMap::zeros(h, w, active);
    let threads = shard_threads(cfg, h, w, active, cin);
    let rows_per = h.div_ceil(threads);
    let mut row_chunks: Vec<&mut [PackedVec]> = out.pixels.chunks_mut(rows_per * w).collect();
    let toggle_counts: Vec<u64> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, chunk) in row_chunks.drain(..).enumerate() {
            let handle = scope.spawn(move || {
                let y0 = t * rows_per;
                let y1 = (y0 + rows_per).min(h);
                let mut lb = PackedLineBuffer::new(k, input);
                let mut col = [PackedVec::ZERO; 3];
                let mut acc_row = vec![0i32; w * active];
                let mut toggles = 0u64;
                for y in y0..y1 {
                    lb.advance_to(y);
                    acc_row.fill(0);
                    for cx in 0..w {
                        // borrow the 3-row input column zero-copy and
                        // pack it once; it is reused by all three kernel
                        // columns × all OCUs
                        lb.col(y, cx, &mut col);
                        let xcol = TritCol::pack_rows(&col, cin);
                        // whole-zero columns (common on sparse DVS maps)
                        // contribute neither acc nor toggles — bit-exact
                        if xcol.is_zero(col_words) {
                            continue;
                        }
                        for kc in 0..3 {
                            // input column cx feeds kernel column kc of
                            // the output pixel at ox = cx - kc + 1
                            let ox = cx as isize + 1 - kc as isize;
                            if ox < 0 || ox >= w as isize {
                                continue;
                            }
                            let obase = ox as usize * active;
                            let wrow = &wcols[kc * active..(kc + 1) * active];
                            let accs = &mut acc_row[obase..obase + active];
                            for (a, wv) in accs.iter_mut().zip(wrow) {
                                let (d, tog) = wv.dot(&xcol, col_words);
                                *a += d;
                                toggles += tog as u64;
                            }
                        }
                    }
                    // branchless packed write-back: one (pos, mask) word
                    // pair per pixel, straight into the output map
                    let rbase = (y - y0) * w;
                    for x in 0..w {
                        chunk[rbase + x] = ternarize_packed(
                            &acc_row[x * active..(x + 1) * active],
                            lo_flat,
                            hi_flat,
                        );
                    }
                }
                toggles
            });
            handles.push(handle);
        }
        handles.into_iter().map(|h| h.join().expect("datapath shard")).collect()
    });

    Ok(finalize_conv(prep, cfg, out, toggle_counts.iter().sum(), stats))
}

/// Run one prepared layer over K co-resident session lanes in a single
/// invocation — the compute core of the engine's cross-session lane
/// batching (SoA `LaneBlock` drain path). Every lane shares the layer's
/// weight columns: each (y, cx) step packs all K lanes' input columns
/// once (the structure-of-arrays transpose), then streams each weight
/// column over the K lane columns before loading the next — the software
/// analogue of weight-stationary reuse across the paper's OCU array.
/// Lanes keep independent accumulator rows and toggle counters and the
/// per-lane zero-column skip is applied lane-by-lane, so every lane's
/// output words and [`LayerStats`] are **bit-identical** to a serial
/// [`run_prepared`] call on that lane alone (integer accumulation only —
/// no ordering-sensitive arithmetic anywhere in the loop).
pub fn run_prepared_lanes(
    prep: &PreparedLayer,
    inputs: &[&PackedMap],
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<Vec<LayerResult>> {
    let Some(first) = inputs.first() else {
        return Ok(Vec::new());
    };
    let (h, w, cin) = (first.h, first.w, first.c);
    for input in inputs.iter().skip(1) {
        ensure!(
            input.h == h && input.w == w && input.c == cin,
            "{}: lane geometry mismatch ({h}×{w}×{cin} vs {}×{}×{})",
            prep.name,
            input.h,
            input.w,
            input.c
        );
    }
    if prep.k != 3 || inputs.len() == 1 {
        // singleton groups and non-3×3 configs gain nothing from lane
        // interleaving; serve them through the serial loop
        return inputs.iter().map(|m| run_prepared(prep, m, cfg, mode)).collect();
    }
    check_geometry(prep, h, w, cin, cfg)?;
    let k = prep.k;
    let active = prep.out_ch;
    let col_words = prep.col_words;
    let wcols = &prep.wcols;
    let lo_flat = &prep.lo_flat;
    let hi_flat = &prep.hi_flat;
    let lanes = inputs.len();
    let stats: Vec<LayerStats> =
        inputs.iter().map(|_| base_stats(prep, cfg, h, w, cin)).collect();
    let _ = mode; // both modes share the loop: toggle counting is free now

    let mut outs: Vec<PackedMap> = (0..lanes).map(|_| PackedMap::zeros(h, w, active)).collect();
    let threads = shard_threads(cfg, h, w, active, cin);
    let rows_per = h.div_ceil(threads);
    // per-thread bundles of one mutable output row-chunk per lane (the
    // row sharding from `run_prepared`, replicated across lanes)
    let mut bundles: Vec<Vec<&mut [PackedVec]>> = Vec::new();
    for out in outs.iter_mut() {
        for (t, chunk) in out.pixels.chunks_mut(rows_per * w).enumerate() {
            if t == bundles.len() {
                bundles.push(Vec::with_capacity(lanes));
            }
            bundles[t].push(chunk);
        }
    }
    let toggle_counts: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, mut lane_chunks) in bundles.drain(..).enumerate() {
            let handle = scope.spawn(move || {
                let y0 = t * rows_per;
                let y1 = (y0 + rows_per).min(h);
                let mut lbs = LaneBuffers::new(k, inputs);
                // SoA state: lane l's accumulator row starts at
                // l·w·active, its packed input column sits in xcols[l]
                let mut acc = vec![0i32; lanes * w * active];
                let mut xcols = vec![TritCol::ZERO; lanes];
                let mut lane_zero = vec![false; lanes];
                let mut toggles = vec![0u64; lanes];
                for y in y0..y1 {
                    lbs.advance_to(y);
                    acc.fill(0);
                    for cx in 0..w {
                        // transpose: pack every lane's input column once;
                        // the weight-column loads below are then
                        // amortized over all K lanes
                        if lbs.pack_cols(y, cx, cin, col_words, &mut xcols, &mut lane_zero) {
                            continue;
                        }
                        for kc in 0..3 {
                            let ox = cx as isize + 1 - kc as isize;
                            if ox < 0 || ox >= w as isize {
                                continue;
                            }
                            let obase = ox as usize * active;
                            let wrow = &wcols[kc * active..(kc + 1) * active];
                            for (co, wv) in wrow.iter().enumerate() {
                                for l in 0..lanes {
                                    // per-lane zero skip — bit-exact
                                    // with the serial loop's skip
                                    if lane_zero[l] {
                                        continue;
                                    }
                                    let (d, tog) = wv.dot(&xcols[l], col_words);
                                    acc[l * w * active + obase + co] += d;
                                    toggles[l] += tog as u64;
                                }
                            }
                        }
                    }
                    // de-interleave: each lane's accumulator row
                    // ternarizes into that lane's own output chunk
                    let rbase = (y - y0) * w;
                    for (l, chunk) in lane_chunks.iter_mut().enumerate() {
                        let lrow = &acc[l * w * active..(l + 1) * w * active];
                        for x in 0..w {
                            chunk[rbase + x] = ternarize_packed(
                                &lrow[x * active..(x + 1) * active],
                                lo_flat,
                                hi_flat,
                            );
                        }
                    }
                }
                toggles
            });
            handles.push(handle);
        }
        handles.into_iter().map(|h| h.join().expect("lane datapath shard")).collect()
    });

    Ok(outs
        .into_iter()
        .zip(stats)
        .enumerate()
        .map(|(l, (out, stat))| {
            let tog: u64 = toggle_counts.iter().map(|per_lane| per_lane[l]).sum();
            finalize_conv(prep, cfg, out, tog, stat)
        })
        .collect())
}

/// The retained **i8 window-stationary** baseline: i8 map in, i8 map
/// out, full 3×3 window re-evaluated per output pixel (9·OCUs packed
/// dots), per-pixel i8 packing in the linebuffer, scalar ternarize and
/// scalar pooling — the pre-iteration-8 dataflow, kept verbatim as the
/// bit-exactness reference for the packed loop (see
/// `tests/column_reuse.rs` and `tests/packed.rs`) and as the A/B
/// baseline in the hotpath bench.
pub fn run_prepared_window(
    prep: &PreparedLayer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<LayerResultI8> {
    ensure!(input.dims.len() == 3, "conv input must be (H, W, C)");
    let (h, w, cin) = (input.dims[0], input.dims[1], input.dims[2]);
    check_geometry(prep, h, w, cin, cfg)?;
    let k = prep.k;
    let k2 = k * k;
    let active = prep.out_ch;
    let weights_flat = &prep.weights_flat;
    let lo_flat = &prep.lo_flat;
    let hi_flat = &prep.hi_flat;
    let stats = base_stats(prep, cfg, h, w, cin);
    let narrow = cin <= 64;
    let _ = mode; // both modes share the loop: toggle counting is free now

    let mut out = TritTensor::zeros(&[h, w, active]);
    let threads = shard_threads(cfg, h, w, active, cin);
    let rows_per = h.div_ceil(threads);
    let mut row_chunks: Vec<&mut [i8]> = out.data.chunks_mut(rows_per * w * active).collect();
    let toggle_counts: Vec<u64> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, chunk) in row_chunks.drain(..).enumerate() {
            let handle = scope.spawn(move || {
                let y0 = t * rows_per;
                let y1 = (y0 + rows_per).min(h);
                let mut lb = LineBuffer::new(k, w);
                let mut window = vec![PackedVec::ZERO; k2];
                let mut acc = vec![0i32; active];
                let mut toggles = 0u64;
                for y in y0..y1 {
                    lb.advance_to(y, input);
                    for x in 0..w {
                        lb.window(y, x, h, &mut window);
                        acc.fill(0);
                        // position-major accumulation: the OCU dimension is
                        // the contiguous inner loop; zero window positions
                        // are skipped outright — bit-exact, they contribute
                        // no acc and no toggles.
                        for (kk, xw) in window.iter().enumerate() {
                            if xw.is_zero() {
                                continue;
                            }
                            let wrow = &weights_flat[kk * active..(kk + 1) * active];
                            // narrow layers (C_in <= 64) use the
                            // single-word dot
                            if narrow {
                                for (a, wv) in acc.iter_mut().zip(wrow) {
                                    let (d, tog) = wv.dot_narrow(xw);
                                    *a += d;
                                    toggles += tog as u64;
                                }
                            } else {
                                for (a, wv) in acc.iter_mut().zip(wrow) {
                                    let (d, tog) = wv.dot(xw);
                                    *a += d;
                                    toggles += tog as u64;
                                }
                            }
                        }
                        let obase = ((y - y0) * w + x) * active;
                        for co in 0..active {
                            chunk[obase + co] = ternarize(acc[co], lo_flat[co], hi_flat[co]);
                        }
                    }
                }
                toggles
            });
            handles.push(handle);
        }
        handles.into_iter().map(|h| h.join().expect("datapath shard")).collect()
    });

    Ok(finalize_conv_i8(prep, cfg, out, toggle_counts.iter().sum(), stats))
}

/// Classifier weights packed once and cached by the scheduler instead of
/// being re-packed per chunk per output per frame (perf pass iteration 7
/// satellite): `weights[chunk * classes + co]` holds the chunk's channel
/// slice for output class co. Like [`PreparedLayer`], the chunk words
/// are the classifier's `.ttn` v2 on-disk form
/// ([`PreparedDense::chunk_words`] / [`PreparedDense::from_packed`]).
#[derive(Debug, PartialEq)]
pub struct PreparedDense {
    pub name: String,
    pub in_ch: usize,
    pub classes: usize,
    /// Chunk width the weights were packed for (= the datapath's channel
    /// count at preparation time).
    chunk_channels: usize,
    weights: Vec<PackedVec>,
}

impl PreparedDense {
    pub fn new(layer: &Layer, chunk_channels: usize) -> Self {
        debug_assert_eq!(layer.kind, LayerKind::Dense);
        let f = layer.in_ch;
        let classes = layer.out_ch;
        let chunks = f.div_ceil(chunk_channels);
        let mut weights = vec![PackedVec::ZERO; chunks * classes];
        for chunk in 0..chunks {
            let lo_i = chunk * chunk_channels;
            let hi_i = ((chunk + 1) * chunk_channels).min(f);
            for co in 0..classes {
                let trits: Vec<i8> =
                    (lo_i..hi_i).map(|i| layer.weights.data[i * classes + co]).collect();
                weights[chunk * classes + co] = PackedVec::pack(&trits);
            }
        }
        PreparedDense { name: layer.name.clone(), in_ch: f, classes, chunk_channels, weights }
    }

    /// Rebuild a prepared classifier from serialized chunk words
    /// (`[chunk · classes + co]`, chunk i spanning channels
    /// [i·chunk_channels, min((i+1)·chunk_channels, in_ch))) — the
    /// `.ttn` v2 word-copy boot path.
    pub fn from_packed(
        name: String,
        in_ch: usize,
        classes: usize,
        chunk_channels: usize,
        weights: Vec<PackedVec>,
    ) -> Result<Self> {
        ensure!(in_ch >= 1, "{name}: empty classifier fan-in");
        ensure!(
            classes >= 1 && classes <= crate::trit::MAX_CHANNELS,
            "{name}: {classes} output classes"
        );
        ensure!(
            chunk_channels >= 1 && chunk_channels <= crate::trit::MAX_CHANNELS,
            "{name}: chunk width {chunk_channels}"
        );
        let chunks = in_ch.div_ceil(chunk_channels);
        ensure!(
            weights.len() == chunks * classes,
            "{name}: {} chunk words for {chunks}×{classes}",
            weights.len()
        );
        for (i, w) in weights.iter().enumerate() {
            let chunk = i / classes;
            let width = (in_ch - chunk * chunk_channels).min(chunk_channels);
            ensure!(
                w.masked(width) == *w,
                "{name}: stale plane bits beyond chunk {chunk}'s {width} channels"
            );
        }
        Ok(PreparedDense { name, in_ch, classes, chunk_channels, weights })
    }

    /// The chunk-major plane words — the classifier's serialized form in
    /// the packed `.ttn` v2 image section.
    pub fn chunk_words(&self) -> &[PackedVec] {
        &self.weights
    }

    /// Chunk width the weights were packed for (the datapath's channel
    /// count at preparation time).
    pub fn chunk_channels(&self) -> usize {
        self.chunk_channels
    }
}

/// Classifier layer on a prepared weight set: the feature vector streams
/// through the adder trees C-channels per cycle; `classes` OCUs stay
/// active, the rest are gated. Raw accumulators go out over the config
/// port (no ternarization). Since the (pos, mask) encoding, toggle
/// counting is free here too, so Fast and Accurate report identical
/// counters (perf pass iteration 8 satellite — previously Fast skipped
/// toggles and the two modes' `mac_toggles`/`mac_idle` diverged).
pub fn run_dense_prepared(
    prep: &PreparedDense,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<(IntTensor, LayerStats)> {
    let f = prep.in_ch;
    ensure!(input.numel() == f, "{}: classifier input {} != {}", prep.name, input.numel(), f);
    let chunks: Vec<PackedVec> = (0..f.div_ceil(cfg.channels))
        .map(|chunk| {
            let lo_i = chunk * cfg.channels;
            let hi_i = ((chunk + 1) * cfg.channels).min(f);
            PackedVec::pack(&input.data[lo_i..hi_i])
        })
        .collect();
    run_dense_packed(prep, &chunks, cfg, mode)
}

/// Core classifier loop over pre-chunked packed feature words — the
/// packed-native entry the TCN tail feeds directly (the last-step word
/// comes straight out of the packed sequence; perf pass iteration 9).
/// `chunks[i]` must hold channels [i·C, min((i+1)·C, f)) with all
/// higher plane bits clear — true for any word the packed pipeline
/// produces over those channels. Counter-identical to
/// [`run_dense_prepared`] by construction (same words, same skips).
pub fn run_dense_packed(
    prep: &PreparedDense,
    chunks: &[PackedVec],
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<(IntTensor, LayerStats)> {
    let f = prep.in_ch;
    ensure!(
        chunks.len() == f.div_ceil(cfg.channels),
        "{}: classifier chunk count {} != {}",
        prep.name,
        chunks.len(),
        f.div_ceil(cfg.channels)
    );
    ensure!(
        prep.chunk_channels == cfg.channels,
        "{}: weights packed for a {}-channel datapath, config has {}",
        prep.name,
        prep.chunk_channels,
        cfg.channels
    );
    let classes = prep.classes;
    let _ = mode; // both modes share the loop: toggle counting is free now

    let mut stats = LayerStats {
        name: prep.name.clone(),
        active_ocus: classes,
        fanin: f,
        ..Default::default()
    };

    let mut logits = IntTensor::zeros(&[classes]);
    for (chunk, x) in chunks.iter().enumerate() {
        // all-zero feature chunks contribute neither logits nor toggles
        if x.is_zero() {
            continue;
        }
        let wrow = &prep.weights[chunk * classes..(chunk + 1) * classes];
        for (co, wv) in wrow.iter().enumerate() {
            let (acc, toggles) = wv.dot(x);
            logits.data[co] += acc;
            stats.mac_toggles += toggles as u64;
        }
    }
    stats.compute_cycles = chunks.len() as u64;
    stats.drain_cycles = 1;
    stats.act_reads = chunks.len() as u64;
    stats.act_writes = 0; // logits leave via the config port / interrupt
    stats.hw_ops = cfg.hw_ops_per_cycle(classes) * stats.compute_cycles;
    stats.alg_macs = (f * classes) as u64;
    let clocked = (classes * cfg.channels * cfg.kernel * cfg.kernel) as u64 * stats.compute_cycles;
    stats.mac_idle = clocked.saturating_sub(stats.mac_toggles);
    Ok((logits, stats))
}

/// Stateless classifier wrapper: packs the weights and runs. The
/// scheduler caches [`PreparedDense`] and calls [`run_dense_prepared`]
/// directly.
pub fn run_dense_layer(
    layer: &Layer,
    input: &TritTensor,
    cfg: &CutieConfig,
    mode: SimMode,
) -> Result<(IntTensor, LayerStats)> {
    ensure!(layer.kind == LayerKind::Dense);
    run_dense_prepared(&PreparedDense::new(layer, cfg.channels), input, cfg, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::reference;
    use crate::network::{cifar9_random, LayerKind};
    use crate::util::rng::Rng;

    #[test]
    fn datapath_matches_reference_executor() {
        // Property: cycle-level output == functional reference, across
        // sizes, channel counts and sparsities.
        let mut rng = Rng::new(71);
        let cfg = CutieConfig::kraken();
        for case in 0..12 {
            let net = cifar9_random(8 + 8 * (case % 3), 100 + case as u64, [0.0, 0.33, 0.66][case % 3]);
            let layer = &net.layers[case % 8];
            if layer.kind != LayerKind::Conv2d {
                continue;
            }
            let hw = 4 + 2 * rng.below(6);
            let input = TritTensor::random(&[hw, hw, layer.in_ch], &mut rng, 0.4);
            let got = run_conv_layer(layer, &input, &cfg, SimMode::Accurate).unwrap();
            let want = reference::run_conv_layer(layer, &input);
            assert_eq!(got.output.to_trit(), want, "case {case}");
            // Fast mode must agree too.
            let fast = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
            assert_eq!(fast.output.to_trit(), want);
            assert_eq!(fast.stats.compute_cycles, got.stats.compute_cycles);
            // since the (pos, mask) encoding, toggle counting is free and
            // Fast mode reports it too
            assert_eq!(fast.stats.mac_toggles, got.stats.mac_toggles);
        }
    }

    #[test]
    fn packed_loop_matches_i8_window_loop_smoke() {
        // The exhaustive packed-vs-i8 sweep lives in
        // tests/column_reuse.rs; this is the in-module smoke check.
        let mut rng = Rng::new(76);
        let cfg = CutieConfig::kraken();
        let net = cifar9_random(24, 110, 0.33);
        let layer = &net.layers[2];
        let prep = PreparedLayer::new(layer);
        let input = TritTensor::random(&[10, 7, layer.in_ch], &mut rng, 0.5);
        let col = run_prepared(&prep, &PackedMap::from_trit(&input), &cfg, SimMode::Accurate).unwrap();
        let win = run_prepared_window(&prep, &input, &cfg, SimMode::Accurate).unwrap();
        assert_eq!(col.output.to_trit(), win.output);
        assert_eq!(col.stats.mac_toggles, win.stats.mac_toggles);
        assert_eq!(col.stats.mac_idle, win.stats.mac_idle);
        assert_eq!(col.stats.compute_cycles, win.stats.compute_cycles);
        assert_eq!(col.stats.act_reads, win.stats.act_reads);
        assert_eq!(col.stats.act_writes, win.stats.act_writes);
    }

    #[test]
    fn cycle_model_shape() {
        let net = cifar9_random(96, 7, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(72);
        let input = TritTensor::random(&[32, 32, 96], &mut rng, 0.4);
        let layer = &net.layers[2]; // 96→96, no pool
        let r = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
        assert_eq!(r.stats.compute_cycles, 32 * 32);
        assert_eq!(r.stats.lb_fill_cycles, 2 * 32 + 2);
        assert_eq!(r.stats.act_reads, 32 * 32); // every pixel read once
        assert_eq!(r.stats.act_writes, 32 * 32);
        assert_eq!(r.stats.hw_ops, 165_888 * 1024);
        assert_eq!(r.stats.alg_macs, 1024 * 9 * 96 * 96);
    }

    #[test]
    fn pooling_decimates_writes_not_cycles() {
        let net = cifar9_random(16, 9, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(73);
        let layer = &net.layers[1]; // pool = true
        let input = TritTensor::random(&[16, 16, 16], &mut rng, 0.3);
        let r = run_conv_layer(layer, &input, &cfg, SimMode::Fast).unwrap();
        assert_eq!(r.stats.compute_cycles, 256);
        assert_eq!(r.stats.act_writes, 64); // 8×8 after pooling
        assert_eq!((r.output.h, r.output.w, r.output.c), (8, 8, 16));
    }

    #[test]
    fn toggles_track_sparsity() {
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(74);
        let dense_net = cifar9_random(32, 10, 0.0);
        let sparse_net = cifar9_random(32, 10, 0.8);
        let input_dense = TritTensor::random(&[8, 8, 32], &mut rng, 0.0);
        let input_sparse = TritTensor::random(&[8, 8, 32], &mut rng, 0.8);
        let d = run_conv_layer(&dense_net.layers[2], &input_dense, &cfg, SimMode::Accurate).unwrap();
        let s = run_conv_layer(&sparse_net.layers[2], &input_sparse, &cfg, SimMode::Accurate).unwrap();
        assert!(
            s.stats.mac_toggles * 10 < d.stats.mac_toggles,
            "sparse toggles {} vs dense {}",
            s.stats.mac_toggles,
            d.stats.mac_toggles
        );
    }

    #[test]
    fn dense_layer_matches_reference() {
        let net = cifar9_random(24, 11, 0.33);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(75);
        let fc = net.layers.last().unwrap();
        let x = TritTensor::random(&[fc.in_ch], &mut rng, 0.4);
        let (logits, stats) = run_dense_layer(fc, &x, &cfg, SimMode::Accurate).unwrap();
        let want = reference::run_dense_layer(fc, &x);
        assert_eq!(logits, want);
        assert_eq!(stats.compute_cycles, (fc.in_ch as u64).div_ceil(96));
    }

    #[test]
    fn dense_prepared_matches_stateless_wrapper() {
        let net = cifar9_random(32, 14, 0.4);
        let cfg = CutieConfig::kraken();
        let mut rng = Rng::new(77);
        let fc = net.layers.last().unwrap();
        let prep = PreparedDense::new(fc, cfg.channels);
        for case in 0..6 {
            let zf = [0.1, 0.5, 0.9][case % 3];
            let x = TritTensor::random(&[fc.in_ch], &mut rng, zf);
            let (a, sa) = run_dense_layer(fc, &x, &cfg, SimMode::Accurate).unwrap();
            let (b, sb) = run_dense_prepared(&prep, &x, &cfg, SimMode::Accurate).unwrap();
            assert_eq!(a, b, "case {case}");
            assert_eq!(sa.mac_toggles, sb.mac_toggles);
            assert_eq!(sa.compute_cycles, sb.compute_cycles);
            // Fast mode reports the full counter set too (iteration 8
            // satellite): logits AND activity identical to Accurate.
            let (c, sc) = run_dense_prepared(&prep, &x, &cfg, SimMode::Fast).unwrap();
            assert_eq!(a, c);
            assert_eq!(sb.mac_toggles, sc.mac_toggles, "case {case}: Fast must count toggles");
            assert_eq!(sb.mac_idle, sc.mac_idle, "case {case}");
            assert_eq!(sb.compute_cycles, sc.compute_cycles);
        }
        // wrong-config guard
        let narrow_cfg = CutieConfig { channels: 48, ..CutieConfig::kraken() };
        let x = TritTensor::random(&[fc.in_ch], &mut rng, 0.3);
        assert!(run_dense_prepared(&prep, &x, &narrow_cfg, SimMode::Fast).is_err());
    }

    #[test]
    fn rejects_oversized_maps() {
        let net = cifar9_random(96, 12, 0.33);
        let cfg = CutieConfig::kraken();
        let input = TritTensor::zeros(&[65, 65, 96]);
        assert!(run_conv_layer(&net.layers[2], &input, &cfg, SimMode::Fast).is_err());
    }
}
