//! Cycle / access / switching-activity counters. These are the *only*
//! interface between the architectural simulator and the energy model:
//! every Joule in a report traces back to a counter here.

/// Execution phases of one layer on the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Weight-bank switch (weights resident) or streaming load.
    WeightLoad,
    /// Linebuffer priming before the first window is available.
    LinebufferFill,
    /// Steady-state: one output pixel per cycle.
    Compute,
    /// Pipeline drain + output flush.
    Drain,
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerStats {
    pub name: String,
    /// Cycles per phase.
    pub weight_load_cycles: u64,
    pub lb_fill_cycles: u64,
    pub compute_cycles: u64,
    pub drain_cycles: u64,
    /// Stall cycles (zero for the stall-free linebuffer + mapped TCN; the
    /// A2 ablation's direct-strided mode makes this non-zero).
    pub stall_cycles: u64,

    /// OCUs enabled this layer (rest are clock-gated).
    pub active_ocus: usize,
    /// Datapath fan-in actually wired this layer (K²·C_in).
    pub fanin: usize,

    /// Full-datapath ops (2·K²·C_channels per active OCU per compute
    /// cycle) — the paper's throughput convention.
    pub hw_ops: u64,
    /// Algorithmic MACs (fan-in × output pixels × out channels).
    pub alg_macs: u64,
    /// Non-zero partial products (toggling multipliers) — the activity
    /// that costs dynamic energy in the compute units.
    pub mac_toggles: u64,
    /// Clocked-but-idle MAC positions in active OCUs.
    pub mac_idle: u64,

    /// Activation memory words (1 word = 1 pixel = 2·C bits).
    pub act_reads: u64,
    pub act_writes: u64,
    /// Linebuffer pixel pushes (flip-flop shift activity).
    pub lb_pushes: u64,
    /// Weight-buffer words switched/loaded.
    pub weight_words: u64,
    /// TCN memory events.
    pub tcn_pushes: u64,
    pub tcn_reads: u64,

    /// Fault-injection ledger (the synthetic `"fault_scrub"` layer; zero
    /// on every real datapath layer): plane bits flipped, flips caught by
    /// scrub/decoder checks, words scanned by scrub passes, and words
    /// re-adopted from the shared weight image to repair corruption.
    pub fault_flips: u64,
    pub fault_detected: u64,
    pub scrub_words: u64,
    pub scrub_repair_words: u64,
}

impl LayerStats {
    pub fn total_cycles(&self) -> u64 {
        self.weight_load_cycles
            + self.lb_fill_cycles
            + self.compute_cycles
            + self.drain_cycles
            + self.stall_cycles
    }
}

/// Aggregated statistics of one inference (or a batch of layers).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunStats {
    pub layers: Vec<LayerStats>,
    /// µDMA input cycles/bytes (frame ingress into the activation memory).
    pub dma_cycles: u64,
    pub dma_bytes: u64,
}

impl RunStats {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.total_cycles()).sum::<u64>() + self.dma_cycles
    }

    pub fn compute_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.compute_cycles).sum()
    }

    pub fn hw_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.hw_ops).sum()
    }

    pub fn alg_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.alg_macs).sum()
    }

    pub fn mac_toggles(&self) -> u64 {
        self.layers.iter().map(|l| l.mac_toggles).sum()
    }

    pub fn mac_idle(&self) -> u64 {
        self.layers.iter().map(|l| l.mac_idle).sum()
    }

    pub fn act_accesses(&self) -> (u64, u64) {
        (
            self.layers.iter().map(|l| l.act_reads).sum(),
            self.layers.iter().map(|l| l.act_writes).sum(),
        )
    }

    pub fn lb_pushes(&self) -> u64 {
        self.layers.iter().map(|l| l.lb_pushes).sum()
    }

    pub fn weight_words(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_words).sum()
    }

    pub fn tcn_events(&self) -> (u64, u64) {
        (
            self.layers.iter().map(|l| l.tcn_pushes).sum(),
            self.layers.iter().map(|l| l.tcn_reads).sum(),
        )
    }

    pub fn stall_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.stall_cycles).sum()
    }

    pub fn fault_flips(&self) -> u64 {
        self.layers.iter().map(|l| l.fault_flips).sum()
    }

    pub fn fault_detected(&self) -> u64 {
        self.layers.iter().map(|l| l.fault_detected).sum()
    }

    pub fn scrub_words(&self) -> (u64, u64) {
        (
            self.layers.iter().map(|l| l.scrub_words).sum(),
            self.layers.iter().map(|l| l.scrub_repair_words).sum(),
        )
    }

    /// Merge another run (e.g. CNN front-end + TCN back-end).
    pub fn merge(&mut self, other: RunStats) {
        self.layers.extend(other.layers);
        self.dma_cycles += other.dma_cycles;
        self.dma_bytes += other.dma_bytes;
    }

    /// Toggle rate: fraction of clocked MAC positions that switched.
    pub fn toggle_rate(&self) -> f64 {
        let clocked = self.mac_toggles() + self.mac_idle();
        if clocked == 0 {
            return 0.0;
        }
        self.mac_toggles() as f64 / clocked as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let mut a = LayerStats { compute_cycles: 100, lb_fill_cycles: 10, ..Default::default() };
        a.weight_load_cycles = 1;
        a.drain_cycles = 2;
        assert_eq!(a.total_cycles(), 113);
        let mut run = RunStats { layers: vec![a.clone()], dma_cycles: 7, ..Default::default() };
        assert_eq!(run.total_cycles(), 120);
        run.merge(RunStats { layers: vec![a], dma_cycles: 1, dma_bytes: 4, ..Default::default() });
        assert_eq!(run.total_cycles(), 234);
        assert_eq!(run.layers.len(), 2);
    }

    #[test]
    fn toggle_rate_bounds() {
        let l = LayerStats { mac_toggles: 30, mac_idle: 70, ..Default::default() };
        let run = RunStats { layers: vec![l], ..Default::default() };
        assert!((run.toggle_rate() - 0.3).abs() < 1e-12);
    }
}
