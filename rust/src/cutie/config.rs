//! Accelerator geometry. Defaults match the Kraken instantiation (§5):
//! 96 channels, 64×64 max feature maps, 24-step TCN memory, 3×3 kernels.

#[derive(Debug, Clone)]
pub struct CutieConfig {
    /// Number of OCUs == max output channels == max input channels.
    pub channels: usize,
    /// Max feature-map side length the activation memory supports.
    pub max_hw: usize,
    /// TCN memory depth (time steps).
    pub tcn_depth: usize,
    /// Kernel size (the datapath is hardwired 3×3 in Kraken).
    pub kernel: usize,
    /// Kernels each OCU's weight buffer can hold resident. Kraken stores
    /// the full network (weights loaded once, then only bank switches).
    pub weight_banks: usize,
    /// µDMA bus width in bits (frame ingress).
    pub dma_bits: usize,
    /// Host-side cap on row-parallel datapath sharding (simulator knob,
    /// not an architectural parameter; counters are sharding-invariant).
    /// The batched serving engine pins its per-frame workers to 1 so
    /// frame-level parallelism is not oversubscribed by layer-level
    /// parallelism.
    pub max_threads: usize,
}

impl Default for CutieConfig {
    fn default() -> Self {
        CutieConfig {
            channels: 96,
            max_hw: 64,
            tcn_depth: 24,
            kernel: 3,
            weight_banks: 9,
            dma_bits: 32,
            max_threads: usize::MAX,
        }
    }
}

impl CutieConfig {
    pub fn kraken() -> Self {
        Self::default()
    }

    /// Bits of one activation-memory word (one pixel, 2 bits/trit).
    pub fn act_word_bits(&self) -> usize {
        2 * self.channels
    }

    /// Full-datapath ("hardware") ops per compute cycle with `active`
    /// OCUs: each active OCU performs K²·C MACs = 2·K²·C Ops per cycle
    /// (zero-padded input channels included — the paper's peak-throughput
    /// convention; idle OCUs are clock-gated and excluded).
    pub fn hw_ops_per_cycle(&self, active_ocus: usize) -> u64 {
        (active_ocus * self.kernel * self.kernel * self.channels * 2) as u64
    }

    /// TCN memory size in bytes (2-bit trits, depth × channels; rounded
    /// up per step — see `TcnMemory::size_bytes`).
    pub fn tcn_mem_bytes(&self) -> usize {
        self.tcn_depth * (self.channels * 2).div_ceil(8)
    }

    /// Activation memory size in bytes per buffer (double-buffered).
    pub fn act_mem_bytes(&self) -> usize {
        self.max_hw * self.max_hw * self.act_word_bits() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kraken_dimensions_match_paper() {
        let c = CutieConfig::kraken();
        // §4: 24 feature vectors == 576 bytes of SCM.
        assert_eq!(c.tcn_mem_bytes(), 576);
        // peak: 96 OCUs × 96 ch × 9 × 2 = 165,888 Op/cycle.
        assert_eq!(c.hw_ops_per_cycle(96), 165_888);
        // 64×64×96 trits @2b = 98,304 B per activation buffer.
        assert_eq!(c.act_mem_bytes(), 98_304);
    }
}
