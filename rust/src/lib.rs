//! # tcn-cutie
//!
//! Reproduction of *"TCN-CUTIE: A 1036 TOp/s/W, 2.72 µJ/Inference, 12.2 mW
//! All-Digital Ternary Accelerator in 22 nm FDX Technology"* (Scherer et
//! al., 2022) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build time)**: Pallas ternary-conv kernels and JAX network
//!   definitions under `python/compile/`, AOT-lowered to HLO text.
//! - **L3 (runtime, this crate)**: cycle-level digital twin of the CUTIE
//!   accelerator + Kraken SoC ([`cutie`], [`soc`], [`energy`]), the §4
//!   dilated-1D→2D mapping ([`mapping`]), a PJRT golden-model runtime
//!   ([`runtime`]) and the autonomous serving coordinator ([`coordinator`]).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod baselines;
pub mod coordinator;
pub mod cutie;
pub mod energy;
pub mod fault;
pub mod mapping;
pub mod network;
pub mod report;
pub mod runtime;
pub mod soc;
pub mod tensor;
pub mod trit;
pub mod util;
