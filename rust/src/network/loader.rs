//! Load a network from the JSON manifest + `.ttn` weights emitted by
//! `python/compile/aot.py`, and write the manifest + weights pair back
//! out ([`save_network`] — the synthetic-artifact path behind
//! `pack-weights --synthetic` and the packed-boot tests).
//!
//! The weights file may be either container version:
//! [`load_network_full`] additionally surfaces the TTN2 packed
//! weight-image section when present, so boot can be a word-copy
//! deserialization (`cutie::PreparedNet::from_image`) instead of i8
//! re-packing.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Layer, LayerKind, Network};
use crate::tensor::ttn::{self, Bundle, Tensor, WeightImage};
use crate::tensor::IntTensor;
use crate::util::json::Json;

/// Resolve the manifest's `weights_file` relative to its directory.
pub fn weights_path(manifest_path: impl AsRef<Path>) -> Result<PathBuf> {
    let manifest_path = manifest_path.as_ref();
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", manifest_path.display()))?;
    let weights_file = j
        .get("weights_file")
        .and_then(|v| v.as_str())
        .context("manifest missing weights_file")?;
    let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
    Ok(dir.join(weights_file))
}

/// Load `<stem>.json`, resolving the `.ttn` weights file relative to the
/// manifest's directory.
pub fn load_network(manifest_path: impl AsRef<Path>) -> Result<Network> {
    Ok(load_network_full(manifest_path)?.0)
}

/// [`load_network`] plus the packed weight image, when the weights file
/// is a TTN2 container (`None` for plain TTN1 artifacts).
pub fn load_network_full(
    manifest_path: impl AsRef<Path>,
) -> Result<(Network, Option<WeightImage>)> {
    let manifest_path = manifest_path.as_ref();
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", manifest_path.display()))?;

    let weights_file = j
        .get("weights_file")
        .and_then(|v| v.as_str())
        .context("manifest missing weights_file")?;
    let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
    let (bundle, image) = ttn::read_file_full(dir.join(weights_file))?;
    let net = build_network(&j, &bundle)?;
    Ok((net, image))
}

fn build_network(j: &Json, bundle: &Bundle) -> Result<Network> {
    let str_field = |o: &Json, k: &str| -> Result<String> {
        Ok(o.get(k).and_then(|v| v.as_str()).with_context(|| format!("missing {k}"))?.to_string())
    };
    let int_field = |o: &Json, k: &str| -> Result<usize> {
        Ok(o.get(k).and_then(|v| v.as_i64()).with_context(|| format!("missing {k}"))? as usize)
    };
    let bool_field = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_bool()).unwrap_or(false);

    let mut layers = Vec::new();
    for lj in j.get("layers").and_then(|v| v.as_array()).context("manifest missing layers")? {
        let kind = match str_field(lj, "kind")?.as_str() {
            "conv2d" => LayerKind::Conv2d,
            "tcn" => LayerKind::Tcn,
            "dense" => LayerKind::Dense,
            other => bail!("unknown layer kind '{other}'"),
        };
        let name = str_field(lj, "name")?;
        let wname = str_field(lj, "weights")?;
        let weights = bundle
            .get(&wname)
            .with_context(|| format!("weights tensor '{wname}' not in bundle"))?
            .as_trit()?
            .clone();
        let (lo, hi) = if kind == LayerKind::Dense {
            (vec![], vec![])
        } else {
            let lo_name = str_field(lj, "lo")?;
            let hi_name = str_field(lj, "hi")?;
            (
                bundle.get(&lo_name).context("lo tensor missing")?.as_int()?.data.clone(),
                bundle.get(&hi_name).context("hi tensor missing")?.as_int()?.data.clone(),
            )
        };
        layers.push(Layer {
            name,
            kind,
            in_ch: int_field(lj, "in_ch")?,
            out_ch: int_field(lj, "out_ch")?,
            kernel: int_field(lj, "kernel")?,
            dilation: int_field(lj, "dilation")?,
            pool: bool_field(lj, "pool"),
            global_pool: bool_field(lj, "global_pool"),
            weights,
            lo,
            hi,
        });
    }

    let net = Network {
        name: str_field(j, "name")?,
        input_hw: int_field(j, "input_hw")?,
        tcn_steps: int_field(j, "tcn_steps")?,
        classes: int_field(j, "classes")?,
        layers,
    };
    net.validate()?;
    Ok(net)
}

/// The canonical tensor bundle of a network: per layer `{name}_w` (trit
/// weights) and, for non-dense layers, `{name}_lo` / `{name}_hi` (i32
/// thresholds). The inverse of what [`load_network`] consumes.
pub fn network_bundle(net: &Network) -> Bundle {
    let mut bundle = Bundle::new();
    for l in &net.layers {
        bundle.insert(format!("{}_w", l.name), Tensor::Trit(l.weights.clone()));
        if l.kind != LayerKind::Dense {
            bundle.insert(
                format!("{}_lo", l.name),
                Tensor::Int(IntTensor::from_vec(&[l.lo.len()], l.lo.clone())),
            );
            bundle.insert(
                format!("{}_hi", l.name),
                Tensor::Int(IntTensor::from_vec(&[l.hi.len()], l.hi.clone())),
            );
        }
    }
    bundle
}

/// The JSON manifest describing `net`, referencing `weights_file` and
/// the [`network_bundle`] tensor names.
pub fn manifest_json(net: &Network, weights_file: &str) -> Json {
    use std::collections::BTreeMap;
    let layers: Vec<Json> = net
        .layers
        .iter()
        .map(|l| {
            let mut o = BTreeMap::new();
            let kind = match l.kind {
                LayerKind::Conv2d => "conv2d",
                LayerKind::Tcn => "tcn",
                LayerKind::Dense => "dense",
            };
            o.insert("kind".to_string(), Json::Str(kind.to_string()));
            o.insert("name".to_string(), Json::Str(l.name.clone()));
            o.insert("weights".to_string(), Json::Str(format!("{}_w", l.name)));
            if l.kind != LayerKind::Dense {
                o.insert("lo".to_string(), Json::Str(format!("{}_lo", l.name)));
                o.insert("hi".to_string(), Json::Str(format!("{}_hi", l.name)));
            }
            o.insert("in_ch".to_string(), Json::Int(l.in_ch as i64));
            o.insert("out_ch".to_string(), Json::Int(l.out_ch as i64));
            o.insert("kernel".to_string(), Json::Int(l.kernel as i64));
            o.insert("dilation".to_string(), Json::Int(l.dilation as i64));
            o.insert("pool".to_string(), Json::Bool(l.pool));
            o.insert("global_pool".to_string(), Json::Bool(l.global_pool));
            Json::Object(o)
        })
        .collect();
    let mut root = std::collections::BTreeMap::new();
    root.insert("name".to_string(), Json::Str(net.name.clone()));
    root.insert("input_hw".to_string(), Json::Int(net.input_hw as i64));
    root.insert("tcn_steps".to_string(), Json::Int(net.tcn_steps as i64));
    root.insert("classes".to_string(), Json::Int(net.classes as i64));
    root.insert("weights_file".to_string(), Json::Str(weights_file.to_string()));
    root.insert("layers".to_string(), Json::Array(layers));
    Json::Object(root)
}

/// Write `net` as a `<stem>.json` manifest + `<stem>.ttn` (TTN1) weights
/// pair under `dir` (created if needed). Returns (manifest, weights)
/// paths. `load_network` round-trips it exactly.
pub fn save_network(
    dir: impl AsRef<Path>,
    stem: &str,
    net: &Network,
) -> Result<(PathBuf, PathBuf)> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let weights_name = format!("{stem}.ttn");
    let weights = dir.join(&weights_name);
    ttn::write_file(&weights, &network_bundle(net))?;
    let manifest = dir.join(format!("{stem}.json"));
    let text = manifest_json(net, &weights_name).to_string_pretty(2);
    std::fs::write(&manifest, text).with_context(|| format!("writing {}", manifest.display()))?;
    Ok((manifest, weights))
}

/// Locate the artifacts directory: `$TCN_CUTIE_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (for tests run from subdirs).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TCN_CUTIE_ARTIFACTS") {
        return p.into();
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::dvs_hybrid_random;

    #[test]
    fn save_load_roundtrip() {
        let net = dvs_hybrid_random(16, 61, 0.5);
        let dir = std::env::temp_dir().join("tcn_cutie_save_net_test");
        let (manifest, weights) = save_network(&dir, "roundtrip", &net).unwrap();
        assert_eq!(weights_path(&manifest).unwrap(), weights);
        let (back, image) = load_network_full(&manifest).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(back, net, "save → load must be the identity");
        assert!(image.is_none(), "TTN1 artifacts carry no weight image");
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("cifar9_96.json").exists()
    }

    #[test]
    fn loads_cifar9_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let net = load_network(artifacts_dir().join("cifar9_96.json")).unwrap();
        assert_eq!(net.name, "cifar9_96");
        assert_eq!(net.layers.len(), 9);
        assert_eq!(net.input_hw, 32);
        assert_eq!(net.layers[0].weights.dims, vec![3, 3, 3, 96]);
        assert_eq!(net.layers[8].kind, LayerKind::Dense);
    }

    #[test]
    fn loads_dvs_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let net = load_network(artifacts_dir().join("dvs_hybrid_96.json")).unwrap();
        assert!(net.has_tcn());
        assert_eq!(net.tcn_steps, 24);
        assert_eq!(net.classes, 12);
    }
}
