//! Load a network from the JSON manifest + `.ttn` weights emitted by
//! `python/compile/aot.py`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Layer, LayerKind, Network};
use crate::tensor::ttn;
use crate::util::json::Json;

/// Load `<stem>.json`, resolving the `.ttn` weights file relative to the
/// manifest's directory.
pub fn load_network(manifest_path: impl AsRef<Path>) -> Result<Network> {
    let manifest_path = manifest_path.as_ref();
    let text = std::fs::read_to_string(manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", manifest_path.display()))?;

    let weights_file = j
        .get("weights_file")
        .and_then(|v| v.as_str())
        .context("manifest missing weights_file")?;
    let dir = manifest_path.parent().unwrap_or_else(|| Path::new("."));
    let bundle = ttn::read_file(dir.join(weights_file))?;

    let str_field = |o: &Json, k: &str| -> Result<String> {
        Ok(o.get(k).and_then(|v| v.as_str()).with_context(|| format!("missing {k}"))?.to_string())
    };
    let int_field = |o: &Json, k: &str| -> Result<usize> {
        Ok(o.get(k).and_then(|v| v.as_i64()).with_context(|| format!("missing {k}"))? as usize)
    };
    let bool_field = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_bool()).unwrap_or(false);

    let mut layers = Vec::new();
    for lj in j.get("layers").and_then(|v| v.as_array()).context("manifest missing layers")? {
        let kind = match str_field(lj, "kind")?.as_str() {
            "conv2d" => LayerKind::Conv2d,
            "tcn" => LayerKind::Tcn,
            "dense" => LayerKind::Dense,
            other => bail!("unknown layer kind '{other}'"),
        };
        let name = str_field(lj, "name")?;
        let wname = str_field(lj, "weights")?;
        let weights = bundle
            .get(&wname)
            .with_context(|| format!("weights tensor '{wname}' not in bundle"))?
            .as_trit()?
            .clone();
        let (lo, hi) = if kind == LayerKind::Dense {
            (vec![], vec![])
        } else {
            let lo_name = str_field(lj, "lo")?;
            let hi_name = str_field(lj, "hi")?;
            (
                bundle.get(&lo_name).context("lo tensor missing")?.as_int()?.data.clone(),
                bundle.get(&hi_name).context("hi tensor missing")?.as_int()?.data.clone(),
            )
        };
        layers.push(Layer {
            name,
            kind,
            in_ch: int_field(lj, "in_ch")?,
            out_ch: int_field(lj, "out_ch")?,
            kernel: int_field(lj, "kernel")?,
            dilation: int_field(lj, "dilation")?,
            pool: bool_field(lj, "pool"),
            global_pool: bool_field(lj, "global_pool"),
            weights,
            lo,
            hi,
        });
    }

    let net = Network {
        name: str_field(&j, "name")?,
        input_hw: int_field(&j, "input_hw")?,
        tcn_steps: int_field(&j, "tcn_steps")?,
        classes: int_field(&j, "classes")?,
        layers,
    };
    net.validate()?;
    Ok(net)
}

/// Locate the artifacts directory: `$TCN_CUTIE_ARTIFACTS`, else
/// `./artifacts`, else `../artifacts` (for tests run from subdirs).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("TCN_CUTIE_ARTIFACTS") {
        return p.into();
    }
    for cand in ["artifacts", "../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    std::path::PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_dir().join("cifar9_96.json").exists()
    }

    #[test]
    fn loads_cifar9_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let net = load_network(artifacts_dir().join("cifar9_96.json")).unwrap();
        assert_eq!(net.name, "cifar9_96");
        assert_eq!(net.layers.len(), 9);
        assert_eq!(net.input_hw, 32);
        assert_eq!(net.layers[0].weights.dims, vec![3, 3, 3, 96]);
        assert_eq!(net.layers[8].kind, LayerKind::Dense);
    }

    #[test]
    fn loads_dvs_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let net = load_network(artifacts_dir().join("dvs_hybrid_96.json")).unwrap();
        assert!(net.has_tcn());
        assert_eq!(net.tcn_steps, 24);
        assert_eq!(net.classes, 12);
    }
}
