//! Bit-exact functional reference executor: computes network outputs
//! straight from the layer definitions (scalar code, no architecture
//! modelling). Independent of both the JAX oracle and the cycle-level
//! datapath — the middle leg of the three-way verification.

use anyhow::{ensure, Result};

use super::{Layer, LayerKind, Network};
use crate::mapping;
use crate::tensor::{IntTensor, TritTensor};
use crate::trit::ternarize;

/// Same-padded KxK ternary convolution -> i32 accumulators.
pub fn conv2d(x: &TritTensor, w: &TritTensor) -> IntTensor {
    let (h, wid, cin) = (x.dims[0], x.dims[1], x.dims[2]);
    let (kh, kw, wcin, cout) = (w.dims[0], w.dims[1], w.dims[2], w.dims[3]);
    assert_eq!(cin, wcin, "channel mismatch");
    let (ph, pw) = (kh / 2, kw / 2);
    let mut out = IntTensor::zeros(&[h, wid, cout]);
    for y in 0..h {
        for xx in 0..wid {
            for dy in 0..kh {
                let sy = y as isize + dy as isize - ph as isize;
                if sy < 0 || sy >= h as isize {
                    continue;
                }
                for dx in 0..kw {
                    let sx = xx as isize + dx as isize - pw as isize;
                    if sx < 0 || sx >= wid as isize {
                        continue;
                    }
                    for ci in 0..cin {
                        let xv = x.get3(sy as usize, sx as usize, ci) as i32;
                        if xv == 0 {
                            continue;
                        }
                        let wbase = ((dy * kw + dx) * cin + ci) * cout;
                        let obase = out.idx3(y, xx, 0);
                        for co in 0..cout {
                            out.data[obase + co] += xv * w.data[wbase + co] as i32;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Two-threshold ternarization of an (H, W, C) accumulator map.
pub fn ternarize_map(acc: &IntTensor, lo: &[i32], hi: &[i32]) -> TritTensor {
    let c = *acc.dims.last().unwrap();
    assert_eq!(lo.len(), c);
    let mut out = TritTensor::zeros(&acc.dims);
    for (i, &a) in acc.data.iter().enumerate() {
        out.data[i] = ternarize(a, lo[i % c], hi[i % c]);
    }
    out
}

/// 2x2/2 max-pool over trits.
pub fn maxpool2x2(t: &TritTensor) -> TritTensor {
    let (h, w, c) = (t.dims[0], t.dims[1], t.dims[2]);
    assert!(h % 2 == 0 && w % 2 == 0, "odd pooling input {h}x{w}");
    let mut out = TritTensor::zeros(&[h / 2, w / 2, c]);
    for y in 0..h / 2 {
        for x in 0..w / 2 {
            for ch in 0..c {
                let m = t
                    .get3(2 * y, 2 * x, ch)
                    .max(t.get3(2 * y, 2 * x + 1, ch))
                    .max(t.get3(2 * y + 1, 2 * x, ch))
                    .max(t.get3(2 * y + 1, 2 * x + 1, ch));
                out.set3(y, x, ch, m);
            }
        }
    }
    out
}

/// Global max-pool to a (1, 1, C)-shaped (C,) vector.
pub fn global_maxpool(t: &TritTensor) -> TritTensor {
    let (h, w, c) = (t.dims[0], t.dims[1], t.dims[2]);
    let mut out = TritTensor::zeros(&[c]);
    for ch in 0..c {
        let mut m = -1i8;
        for y in 0..h {
            for x in 0..w {
                m = m.max(t.get3(y, x, ch));
            }
        }
        out.data[ch] = m;
    }
    out
}

/// One conv2d layer (conv -> ternarize -> pools).
pub fn run_conv_layer(layer: &Layer, x: &TritTensor) -> TritTensor {
    debug_assert_eq!(layer.kind, LayerKind::Conv2d);
    let acc = conv2d(x, &layer.weights);
    let mut t = ternarize_map(&acc, &layer.lo, &layer.hi);
    if layer.pool {
        t = maxpool2x2(&t);
    }
    if layer.global_pool {
        t = global_maxpool(&t);
    }
    t
}

/// One TCN layer on a (T, C) sequence, through the §4 mapping.
pub fn run_tcn_layer(layer: &Layer, x: &TritTensor) -> TritTensor {
    debug_assert_eq!(layer.kind, LayerKind::Tcn);
    let t_len = x.dims[0];
    let z = mapping::map_input(x, layer.dilation);
    let w2d = mapping::map_weights(&layer.weights);
    let acc2d = conv2d(&z, &w2d);
    let acc = mapping::unmap_output(&acc2d, t_len, layer.dilation);
    // ternarize the (T, Cout) accumulators
    let cout = layer.out_ch;
    let mut out = TritTensor::zeros(&[t_len, cout]);
    for t in 0..t_len {
        for co in 0..cout {
            out.data[t * cout + co] =
                ternarize(acc.data[t * cout + co], layer.lo[co], layer.hi[co]);
        }
    }
    out
}

/// Classifier: flatten + ternary matmul -> raw logits.
pub fn run_dense_layer(layer: &Layer, x: &TritTensor) -> IntTensor {
    debug_assert_eq!(layer.kind, LayerKind::Dense);
    let f = layer.in_ch;
    assert_eq!(x.numel(), f, "classifier input size");
    let classes = layer.out_ch;
    let mut out = IntTensor::zeros(&[classes]);
    for (i, &xv) in x.data.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        for co in 0..classes {
            out.data[co] += xv as i32 * layer.weights.data[i * classes + co] as i32;
        }
    }
    out
}

/// CNN front-end: (H, W, Cin) frame -> feature trits (map or vector).
pub fn forward_cnn(net: &Network, frame: &TritTensor) -> Result<TritTensor> {
    ensure!(frame.dims.len() == 3, "frame must be (H, W, C)");
    let mut x = frame.clone();
    for layer in net.conv_layers() {
        ensure!(
            x.dims[2] == layer.in_ch,
            "layer {}: input channels {} != {}",
            layer.name,
            x.dims[2],
            layer.in_ch
        );
        x = run_conv_layer(layer, &x);
    }
    Ok(x)
}

/// TCN back-end: (T, C) sequence -> (classes,) logits (uses last step).
pub fn forward_tcn(net: &Network, seq: &TritTensor) -> Result<IntTensor> {
    let mut x = seq.clone();
    for layer in &net.layers {
        match layer.kind {
            LayerKind::Conv2d => continue,
            LayerKind::Tcn => x = run_tcn_layer(layer, &x),
            LayerKind::Dense => {
                let t_len = x.dims[0];
                let c = x.dims[1];
                let last = TritTensor::from_vec(&[c], x.data[(t_len - 1) * c..].to_vec());
                return Ok(run_dense_layer(layer, &last));
            }
        }
    }
    anyhow::bail!("network has no classifier layer")
}

/// Full inference. For TCN networks `input` is (T, H, W, C); otherwise
/// (H, W, C).
pub fn forward(net: &Network, input: &TritTensor) -> Result<IntTensor> {
    if net.has_tcn() {
        ensure!(input.dims.len() == 4, "TCN network input must be (T, H, W, C)");
        let (t_len, h, w, c) = (input.dims[0], input.dims[1], input.dims[2], input.dims[3]);
        let feat_ch = net.conv_layers().last().unwrap().out_ch;
        let mut seq = TritTensor::zeros(&[t_len, feat_ch]);
        for t in 0..t_len {
            let frame = TritTensor::from_vec(
                &[h, w, c],
                input.data[t * h * w * c..(t + 1) * h * w * c].to_vec(),
            );
            let feat = forward_cnn(net, &frame)?;
            ensure!(feat.numel() == feat_ch, "CNN must end in a feature vector");
            seq.data[t * feat_ch..(t + 1) * feat_ch].copy_from_slice(&feat.data);
        }
        forward_tcn(net, &seq)
    } else {
        let feat = forward_cnn(net, input)?;
        let flat = TritTensor::from_vec(&[feat.numel()], feat.data.clone());
        let dense = net.layers.last().unwrap();
        Ok(run_dense_layer(dense, &flat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{cifar9_random, dvs_hybrid_random};
    use crate::util::rng::Rng;

    #[test]
    fn conv_identity_kernel() {
        let mut rng = Rng::new(1);
        let x = TritTensor::random(&[6, 6, 4], &mut rng, 0.3);
        let mut w = TritTensor::zeros(&[3, 3, 4, 4]);
        for c in 0..4 {
            w.data[((1 * 3 + 1) * 4 + c) * 4 + c] = 1;
        }
        let acc = conv2d(&x, &w);
        for i in 0..x.numel() {
            assert_eq!(acc.data[i], x.data[i] as i32);
        }
    }

    #[test]
    fn conv_window_counts_at_edges() {
        let x = TritTensor::from_vec(&[5, 5, 2], vec![1; 50]);
        let w = TritTensor::from_vec(&[3, 3, 2, 1], vec![1; 18]);
        let acc = conv2d(&x, &w);
        assert_eq!(acc.data[acc.idx3(2, 2, 0)], 18);
        assert_eq!(acc.data[acc.idx3(0, 0, 0)], 8);
        assert_eq!(acc.data[acc.idx3(0, 2, 0)], 12);
    }

    #[test]
    fn maxpool_trits() {
        let t = TritTensor::from_vec(
            &[4, 4, 1],
            vec![-1, -1, 0, 1, 0, -1, -1, -1, 1, 1, 0, 0, 1, 0, 0, 0],
        );
        let p = maxpool2x2(&t);
        assert_eq!(p.data, vec![0, 1, 1, 0]);
    }

    #[test]
    fn global_pool() {
        let mut t = TritTensor::zeros(&[3, 3, 2]);
        t.set3(1, 1, 0, -1);
        t.set3(2, 0, 1, 1);
        let g = global_maxpool(&t);
        assert_eq!(g.data, vec![0, 1]);
    }

    #[test]
    fn cifar_forward_shapes() {
        let net = cifar9_random(16, 3, 0.33);
        let mut rng = Rng::new(4);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.2);
        let logits = forward(&net, &input).unwrap();
        assert_eq!(logits.dims, vec![10]);
    }

    #[test]
    fn dvs_forward_shapes() {
        let net = dvs_hybrid_random(16, 5, 0.5);
        let mut rng = Rng::new(6);
        let input = TritTensor::random(&[24, 64, 64, 2], &mut rng, 0.8);
        let logits = forward(&net, &input).unwrap();
        assert_eq!(logits.dims, vec![12]);
    }

    #[test]
    fn dense_ignores_zero_inputs() {
        let layer = Layer {
            name: "fc".into(),
            kind: LayerKind::Dense,
            in_ch: 4,
            out_ch: 2,
            kernel: 1,
            dilation: 1,
            pool: false,
            global_pool: false,
            weights: TritTensor::from_vec(&[4, 2], vec![1, -1, 1, 1, -1, 0, 0, 1]),
            lo: vec![],
            hi: vec![],
        };
        let x = TritTensor::from_vec(&[4], vec![1, 0, -1, 1]);
        let logits = run_dense_layer(&layer, &x);
        assert_eq!(logits.data, vec![1 + 1 - 0, -1 - 0 + 1]);
    }
}
