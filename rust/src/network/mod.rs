//! Network description + manifest loader + bit-exact reference executor.
//!
//! The reference executor (`reference` submodule) computes layer outputs
//! straight from the definitions — independently of the cycle-level CUTIE
//! model — so the simulator can be verified three ways:
//! JAX/Pallas oracle (via `.ttn` test vectors) == reference executor ==
//! cycle-level datapath == PJRT golden model.

pub mod loader;
pub mod reference;

use crate::tensor::TritTensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 3x3 (or 1x1) same-padding ternary conv, optional 2x2/2 max-pool and
    /// global max-pool, two-threshold ternarization.
    Conv2d,
    /// Causal dilated 1D conv (N taps), executed through the §4 2D mapping.
    Tcn,
    /// Classifier: flatten + ternary matmul, raw i32 logits.
    Dense,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub in_ch: usize,
    pub out_ch: usize,
    /// Kernel size (conv2d: KxK; tcn: number of taps N <= 3).
    pub kernel: usize,
    pub dilation: usize,
    pub pool: bool,
    pub global_pool: bool,
    /// conv2d: (K, K, Cin, Cout); tcn: (N, Cin, Cout); dense: (F, classes).
    pub weights: TritTensor,
    /// Per-output-channel thresholds (empty for dense).
    pub lo: Vec<i32>,
    pub hi: Vec<i32>,
}

impl Layer {
    /// MAC fan-in of one output pixel/step.
    pub fn fanin(&self) -> usize {
        match self.kind {
            LayerKind::Conv2d => self.kernel * self.kernel * self.in_ch,
            LayerKind::Tcn => 3 * self.in_ch, // mapped onto the 3x3 datapath
            LayerKind::Dense => self.in_ch,
        }
    }

    /// Validate the threshold contract and weight shape.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        let w = &self.weights.dims;
        match self.kind {
            LayerKind::Conv2d => {
                if w != &[self.kernel, self.kernel, self.in_ch, self.out_ch] {
                    bail!("{}: conv2d weight shape {w:?}", self.name);
                }
            }
            LayerKind::Tcn => {
                if w.len() != 3 || w[1] != self.in_ch || w[2] != self.out_ch || w[0] > 3 {
                    bail!("{}: tcn weight shape {w:?}", self.name);
                }
            }
            LayerKind::Dense => {
                if w != &[self.in_ch, self.out_ch] {
                    bail!("{}: dense weight shape {w:?}", self.name);
                }
            }
        }
        if self.kind != LayerKind::Dense {
            if self.lo.len() != self.out_ch || self.hi.len() != self.out_ch {
                bail!("{}: threshold length mismatch", self.name);
            }
            for c in 0..self.out_ch {
                if self.lo[c] > self.hi[c] + 1 {
                    bail!(
                        "{}: channel {c} violates lo <= hi + 1 ({} > {} + 1)",
                        self.name,
                        self.lo[c],
                        self.hi[c]
                    );
                }
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub tcn_steps: usize,
    pub classes: usize,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind == LayerKind::Conv2d)
    }

    pub fn tcn_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind == LayerKind::Tcn)
    }

    pub fn has_tcn(&self) -> bool {
        self.layers.iter().any(|l| l.kind == LayerKind::Tcn)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for l in &self.layers {
            l.validate()?;
        }
        Ok(())
    }

    /// Algorithmic multiply-accumulate count for one inference (2 Op/MAC is
    /// the paper's convention), given the canonical input geometry.
    pub fn macs_per_inference(&self) -> u64 {
        let mut hw = self.input_hw;
        let mut macs = 0u64;
        for l in &self.layers {
            match l.kind {
                LayerKind::Conv2d => {
                    macs += (hw * hw * l.fanin() * l.out_ch) as u64;
                    if l.pool {
                        hw /= 2;
                    }
                    if l.global_pool {
                        hw = 1;
                    }
                }
                LayerKind::Tcn => {
                    macs += (self.tcn_steps * l.kernel * l.in_ch * l.out_ch) as u64;
                }
                LayerKind::Dense => {
                    macs += (l.in_ch * l.out_ch) as u64;
                }
            }
        }
        macs
    }
}

/// Seeded random network with controllable sparsity — used by benches and
/// ablations. Mirrors python `model.init_params` thresholds (same formula).
pub fn random_network(
    name: &str,
    layers: &[(LayerKind, usize, usize, usize, bool, bool)],
    input_hw: usize,
    tcn_steps: usize,
    classes: usize,
    seed: u64,
    zero_frac: f64,
) -> Network {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut out = Vec::new();
    for (i, &(kind, in_ch, out_ch, dilation, pool, global_pool)) in layers.iter().enumerate() {
        let kernel = if kind == LayerKind::Dense { 1 } else { 3 };
        let dims: Vec<usize> = match kind {
            LayerKind::Conv2d => vec![3, 3, in_ch, out_ch],
            LayerKind::Tcn => vec![3, in_ch, out_ch],
            LayerKind::Dense => vec![in_ch, out_ch],
        };
        let weights = TritTensor::random(&dims, &mut rng, zero_frac);
        let fanin = match kind {
            LayerKind::Conv2d | LayerKind::Tcn => 9.min(kernel * kernel) * in_ch,
            LayerKind::Dense => in_ch,
        };
        let th = ((0.5 * ((fanin as f64) * (1.0 - zero_frac)).sqrt()) as i32).max(1);
        let (lo, hi) = if kind == LayerKind::Dense {
            (vec![], vec![])
        } else {
            (vec![-th; out_ch], vec![th; out_ch])
        };
        out.push(Layer {
            name: format!("l{i}"),
            kind,
            in_ch,
            out_ch,
            kernel: if kind == LayerKind::Tcn { 3 } else { kernel },
            dilation,
            pool,
            global_pool,
            weights,
            lo,
            hi,
        });
    }
    let net = Network {
        name: name.to_string(),
        input_hw,
        tcn_steps,
        classes,
        layers: out,
    };
    net.validate().expect("random network must validate");
    net
}

/// The paper's CIFAR-10 benchmark network with random weights (geometry
/// matches `python/compile/model.py::cifar9`).
pub fn cifar9_random(channels: usize, seed: u64, zero_frac: f64) -> Network {
    let c = channels;
    let mut specs = vec![(LayerKind::Conv2d, 3, c, 1, false, false)];
    for i in 2..=8 {
        specs.push((LayerKind::Conv2d, c, c, 1, i % 2 == 0, false));
    }
    specs.push((LayerKind::Dense, 2 * 2 * c, 10, 1, false, false));
    random_network(&format!("cifar9_{c}_rand"), &specs, 32, 24, 10, seed, zero_frac)
}

/// The hybrid DVS network with random weights (geometry matches
/// `python/compile/model.py::dvs_hybrid`).
pub fn dvs_hybrid_random(channels: usize, seed: u64, zero_frac: f64) -> Network {
    let c = channels;
    let chans = [32.min(c), 64.min(c), c, c, c];
    let mut specs = Vec::new();
    let mut in_c = 2;
    for (i, &oc) in chans.iter().enumerate() {
        specs.push((LayerKind::Conv2d, in_c, oc, 1, true, i == 4));
        in_c = oc;
    }
    for d in [1usize, 2, 4, 8] {
        specs.push((LayerKind::Tcn, c, c, d, false, false));
    }
    specs.push((LayerKind::Dense, c, 12, 1, false, false));
    random_network(&format!("dvs_hybrid_{c}_rand"), &specs, 64, 24, 12, seed, zero_frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar9_geometry() {
        let net = cifar9_random(96, 0, 0.33);
        assert_eq!(net.layers.len(), 9);
        assert_eq!(net.conv_layers().count(), 8);
        assert_eq!(net.layers.last().unwrap().in_ch, 2 * 2 * 96);
        net.validate().unwrap();
    }

    #[test]
    fn dvs_geometry() {
        let net = dvs_hybrid_random(96, 1, 0.5);
        assert_eq!(net.tcn_layers().count(), 4);
        let dil: Vec<usize> = net.tcn_layers().map(|l| l.dilation).collect();
        assert_eq!(dil, vec![1, 2, 4, 8]);
        assert!(net.has_tcn());
    }

    #[test]
    fn macs_cifar96_order_of_magnitude() {
        let net = cifar9_random(96, 0, 0.33);
        let macs = net.macs_per_inference();
        // C1 ~ 2.5 MMAC, C2 ~ 85 MMAC, C3/4 ~ 21 MMAC, ... ≈ 0.15 GMAC.
        assert!(macs > 100_000_000 && macs < 300_000_000, "macs = {macs}");
    }

    #[test]
    fn validate_catches_threshold_violation() {
        let mut net = cifar9_random(8, 0, 0.3);
        net.layers[0].lo[0] = net.layers[0].hi[0] + 2;
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_catches_weight_shape() {
        let mut net = cifar9_random(8, 0, 0.3);
        net.layers[0].weights = TritTensor::zeros(&[3, 3, 2, 8]);
        assert!(net.validate().is_err());
    }
}
