//! Kraken SoC model (§2, §6): power domains with gating, run-time
//! configurable FLL clock domains, µDMA frame ingress, the event unit and
//! the fabric-controller FSM implementing the §5 autonomous flow
//! (peripheral IRQ triggers inference; CUTIE's done-IRQ wakes the FC).
//!
//! This is an event-timed model (nanosecond timeline, not cycle-accurate):
//! its job is system-level energy/latency — idle vs active power, power
//! gating, and the duty cycle of the autonomous loop — on top of the
//! cycle-accurate accelerator core model.

use std::collections::BTreeMap;

/// The four core power domains (§2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Domain {
    /// Always-on SoC domain (FC, peripherals, µDMA).
    Soc,
    /// 8-core PULP cluster (unused by this paper's flow; gated).
    Cluster,
    /// EHWPE domain hosting CUTIE.
    Ehwpe,
    /// Second accelerator domain (not discussed in the paper; gated).
    Accel2,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerState {
    Gated,
    Idle,
    Active,
}

/// Per-domain power figures (W) at a given supply point.
#[derive(Debug, Clone, Copy)]
pub struct DomainPower {
    pub leak_w: f64,
    pub idle_w: f64,
    pub active_w: f64,
}

/// Frequency-locked loop: one per clock domain, run-time retargetable.
#[derive(Debug, Clone)]
pub struct Fll {
    pub name: String,
    pub freq_hz: f64,
    /// Lock time after a retarget (µs-scale on Kraken).
    pub lock_time_ns: u64,
    pub retargets: u64,
}

impl Fll {
    pub fn new(name: &str, freq_hz: f64) -> Self {
        Fll { name: name.to_string(), freq_hz, lock_time_ns: 2_000, retargets: 0 }
    }

    /// Retarget; returns the lock latency to charge on the timeline.
    pub fn set_freq(&mut self, freq_hz: f64) -> u64 {
        if (freq_hz - self.freq_hz).abs() / self.freq_hz > 1e-9 {
            self.freq_hz = freq_hz;
            self.retargets += 1;
            self.lock_time_ns
        } else {
            0
        }
    }

    pub fn cycles_to_ns(&self, cycles: u64) -> u64 {
        ((cycles as f64 / self.freq_hz) * 1e9).round() as u64
    }
}

/// Fabric-controller states of the §5 autonomous loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FcState {
    Sleep,
    /// Woken by CUTIE's done-interrupt; reads out the label.
    Readout,
    /// Reconfigures / re-arms the accelerator and goes back to sleep.
    Arm,
}

/// Interrupt lines of the event unit that matter to this flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Irq {
    /// µDMA: a full frame landed in the activation memory.
    FrameReady,
    /// CUTIE: inference done (wakes the FC).
    CutieDone,
}

/// Energy/time ledger of the SoC model.
#[derive(Debug, Clone, Default)]
pub struct SocLedger {
    pub now_ns: u64,
    pub energy_j: f64,
    /// Energy per domain.
    pub per_domain: BTreeMap<Domain, f64>,
    pub irq_count: u64,
    pub fc_wakeups: u64,
    pub frames_ingested: u64,
}

pub struct KrakenSoc {
    pub voltage: f64,
    pub states: BTreeMap<Domain, PowerState>,
    pub power: BTreeMap<Domain, DomainPower>,
    pub soc_fll: Fll,
    pub ehwpe_fll: Fll,
    pub fc_state: FcState,
    pub ledger: SocLedger,
    pub dma_bits: usize,
}

impl KrakenSoc {
    /// Default power figures at 0.5 V; dynamic parts scale (V/0.5)², leak
    /// exponentially (same model as the core calibration).
    pub fn new(voltage: f64) -> Self {
        let s = (voltage / 0.5) * (voltage / 0.5);
        let l = (voltage / 0.5) * ((voltage - 0.5) / 0.187).exp();
        let mut power = BTreeMap::new();
        // Always-on SoC domain: FC sleeping ≈ leakage + RTC-ish idle.
        power.insert(
            Domain::Soc,
            DomainPower { leak_w: 120e-6 * l, idle_w: 250e-6 * s, active_w: 2.4e-3 * s },
        );
        power.insert(
            Domain::Cluster,
            DomainPower { leak_w: 300e-6 * l, idle_w: 900e-6 * s, active_w: 9.0e-3 * s },
        );
        // CUTIE domain: active power comes from the core energy model; the
        // figures here cover the domain's idle clock tree and leakage.
        power.insert(
            Domain::Ehwpe,
            DomainPower { leak_w: 200e-6 * l, idle_w: 400e-6 * s, active_w: 0.0 },
        );
        power.insert(
            Domain::Accel2,
            DomainPower { leak_w: 150e-6 * l, idle_w: 500e-6 * s, active_w: 5.0e-3 * s },
        );
        let mut states = BTreeMap::new();
        states.insert(Domain::Soc, PowerState::Idle); // always-on
        states.insert(Domain::Cluster, PowerState::Gated);
        states.insert(Domain::Ehwpe, PowerState::Idle);
        states.insert(Domain::Accel2, PowerState::Gated);
        KrakenSoc {
            voltage,
            states,
            power,
            soc_fll: Fll::new("soc", 100e6),
            // The FLL's target is informational (it never drives timing:
            // the core's busy time arrives via `advance_ns`); below the
            // VF fit's threshold there is no defined fmax, so park at 0.
            ehwpe_fll: Fll::new("ehwpe", crate::energy::fmax_hz(voltage).unwrap_or(0.0)),
            fc_state: FcState::Sleep,
            ledger: SocLedger::default(),
            dma_bits: 32,
        }
    }

    pub fn set_state(&mut self, d: Domain, s: PowerState) {
        assert!(
            !(d == Domain::Soc && s == PowerState::Gated),
            "the SoC domain is always-on"
        );
        self.states.insert(d, s);
    }

    fn domain_power_w(&self, d: Domain) -> f64 {
        let p = self.power[&d];
        match self.states[&d] {
            PowerState::Gated => 0.0,
            PowerState::Idle => p.leak_w + p.idle_w,
            PowerState::Active => p.leak_w + p.idle_w + p.active_w,
        }
    }

    /// Advance the timeline, integrating state power.
    pub fn advance_ns(&mut self, dt_ns: u64) {
        let dt = dt_ns as f64 * 1e-9;
        for (&d, _) in &self.states.clone() {
            let e = self.domain_power_w(d) * dt;
            self.ledger.energy_j += e;
            *self.ledger.per_domain.entry(d).or_insert(0.0) += e;
        }
        self.ledger.now_ns += dt_ns;
    }

    /// Add accelerator-core energy (from the calibrated core model) on
    /// top of the EHWPE domain's baseline.
    pub fn add_core_energy(&mut self, e_j: f64) {
        self.ledger.energy_j += e_j;
        *self.ledger.per_domain.entry(Domain::Ehwpe).or_insert(0.0) += e_j;
    }

    /// µDMA transfer of `bytes` at the SoC clock; returns the duration.
    pub fn dma_ingest(&mut self, bytes: u64) -> u64 {
        let cycles = bytes.div_ceil((self.dma_bits / 8) as u64);
        let dur = self.soc_fll.cycles_to_ns(cycles);
        self.advance_ns(dur);
        self.ledger.frames_ingested += 1;
        dur
    }

    /// Raise an interrupt; drives the FC FSM of the §5 flow.
    pub fn raise_irq(&mut self, irq: Irq) {
        self.ledger.irq_count += 1;
        match irq {
            Irq::FrameReady => {
                // autonomous: CUTIE starts without FC intervention
                self.set_state(Domain::Ehwpe, PowerState::Active);
            }
            Irq::CutieDone => {
                self.fc_state = FcState::Readout;
                self.ledger.fc_wakeups += 1;
            }
        }
    }

    /// FC readout + re-arm after a done-IRQ (§5): a few hundred SoC
    /// cycles awake, then back to sleep.
    pub fn fc_service_done(&mut self) -> u64 {
        assert_eq!(self.fc_state, FcState::Readout, "no pending done-IRQ");
        self.set_state(Domain::Soc, PowerState::Active);
        let dur = self.soc_fll.cycles_to_ns(300);
        self.advance_ns(dur);
        self.fc_state = FcState::Arm;
        self.set_state(Domain::Soc, PowerState::Idle);
        self.set_state(Domain::Ehwpe, PowerState::Idle);
        self.fc_state = FcState::Sleep;
        dur
    }

    /// Total simulated SoC energy so far (J).
    pub fn energy_j(&self) -> f64 {
        self.ledger.energy_j
    }

    /// Fabric-controller wakeups so far (one per served frame in the §5
    /// autonomous flow).
    pub fn fc_wakeups(&self) -> u64 {
        self.ledger.fc_wakeups
    }

    /// Simulated SoC timeline position (ns since boot).
    pub fn now_ns(&self) -> u64 {
        self.ledger.now_ns
    }

    /// Average power so far (W).
    pub fn avg_power_w(&self) -> f64 {
        if self.ledger.now_ns == 0 {
            return 0.0;
        }
        self.ledger.energy_j / (self.ledger.now_ns as f64 * 1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gated_domains_burn_nothing() {
        let mut soc = KrakenSoc::new(0.5);
        soc.advance_ns(1_000_000);
        let cluster = soc.ledger.per_domain.get(&Domain::Cluster).copied().unwrap_or(0.0);
        let accel2 = soc.ledger.per_domain.get(&Domain::Accel2).copied().unwrap_or(0.0);
        assert_eq!(cluster, 0.0);
        assert_eq!(accel2, 0.0);
        assert!(soc.ledger.energy_j > 0.0, "always-on SoC domain draws power");
    }

    #[test]
    #[should_panic(expected = "always-on")]
    fn soc_domain_cannot_gate() {
        let mut soc = KrakenSoc::new(0.5);
        soc.set_state(Domain::Soc, PowerState::Gated);
    }

    #[test]
    fn autonomous_flow_fsm() {
        let mut soc = KrakenSoc::new(0.5);
        assert_eq!(soc.fc_state, FcState::Sleep);
        soc.dma_ingest(1024);
        soc.raise_irq(Irq::FrameReady);
        assert_eq!(soc.states[&Domain::Ehwpe], PowerState::Active);
        assert_eq!(soc.fc_state, FcState::Sleep, "FC stays asleep during inference (§5)");
        soc.advance_ns(50_000); // inference runs
        soc.raise_irq(Irq::CutieDone);
        assert_eq!(soc.fc_state, FcState::Readout);
        soc.fc_service_done();
        assert_eq!(soc.fc_state, FcState::Sleep);
        assert_eq!(soc.states[&Domain::Ehwpe], PowerState::Idle);
        assert_eq!(soc.ledger.fc_wakeups, 1);
    }

    #[test]
    fn idle_power_scales_with_voltage() {
        let mut lo = KrakenSoc::new(0.5);
        let mut hi = KrakenSoc::new(0.9);
        lo.advance_ns(1_000_000);
        hi.advance_ns(1_000_000);
        assert!(hi.ledger.energy_j > 2.0 * lo.ledger.energy_j);
    }

    #[test]
    fn fll_retarget_counts_and_locks() {
        let mut f = Fll::new("x", 100e6);
        assert_eq!(f.set_freq(100e6), 0);
        assert!(f.set_freq(200e6) > 0);
        assert_eq!(f.retargets, 1);
        assert_eq!(f.cycles_to_ns(200), 1_000);
    }

    #[test]
    fn dma_duration_matches_bus_width() {
        let mut soc = KrakenSoc::new(0.5);
        // 1024 bytes over a 32-bit bus at 100 MHz = 256 cycles = 2560 ns
        let dur = soc.dma_ingest(1024);
        assert_eq!(dur, 2_560);
    }
}
