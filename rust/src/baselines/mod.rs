//! Analytic models of the state-of-the-art comparators (§8, Table 1).
//!
//! Each baseline is reconstructed from its paper's published architecture
//! parameters (datapath width, precision, clock, voltage corners), not
//! just quoted: the models compute energy/throughput from ops-per-cycle ×
//! energy-per-op, and unit tests pin them to the cited numbers. That
//! makes Table 1 regenerable and lets the benches sweep the comparison.

/// One row of the Table-1-style comparison.
#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub name: &'static str,
    pub computation: &'static str,
    pub weight_precision: &'static str,
    pub act_precision: &'static str,
    pub tech_nm: u32,
    pub dataset: &'static str,
    pub accuracy_pct: f64,
    pub energy_per_inf_uj: f64,
    pub core_area_mm2: f64,
    pub voltage_v: f64,
    pub throughput_tops: f64,
    pub peak_eff_tops_w: f64,
}

/// Ops per CIFAR-10 inference of the common 9-layer benchmark network at
/// a given channel width, in the papers' 2-Op/MAC hardware convention
/// (no pooling decimation — full-width layers, the convention [8]/[9]
/// report peak numbers in).
pub fn cifar9_ops(channels: u64) -> f64 {
    // 8 conv layers at 32×32 + classifier, full datapath convention.
    let per_layer = 32.0 * 32.0 * (channels as f64) * (channels as f64) * 9.0 * 2.0;
    8.0 * per_layer
}

/// BinarEye [9]: 28 nm all-on-chip binary CNN processor (Moons et al.,
/// CICC 2018). 256 binary neurons/axis, reported 230 TOp/s/W peak at
/// 0.65 V and 13.86 µJ for the 86%-accuracy CIFAR point.
pub fn binareye() -> BaselineRow {
    // energy/op from peak efficiency; E/inf from the 9-layer 128-ch net
    let eff_tops_w = 230.0;
    let e_per_op_j = 1.0 / (eff_tops_w * 1e12);
    // effective utilization vs peak on the real network (fitted from the
    // paper's own 13.86 µJ): 13.86 µJ / (ops × e_per_op)
    let ops = cifar9_ops(128);
    let utilization = (ops * e_per_op_j) / 13.86e-6;
    debug_assert!(utilization > 0.05 && utilization < 1.0);
    BaselineRow {
        name: "BinarEye [9]",
        computation: "digital",
        weight_precision: "binary",
        act_precision: "binary",
        tech_nm: 28,
        dataset: "CIFAR-10",
        accuracy_pct: 86.0,
        energy_per_inf_uj: ops * e_per_op_j / utilization * 1e6,
        core_area_mm2: 1.4,
        voltage_v: 0.65,
        throughput_tops: 2.8,
        peak_eff_tops_w: eff_tops_w,
    }
}

/// Knag et al. [8]: 10 nm FinFET all-digital BNN accelerator (VLSI 2020).
/// Two corners: 0.37 V / 617 TOp/s/W / 3.4 TOp/s and 0.75 V / 269
/// TOp/s/W / 163 TOp/s; 3.2 µJ CIFAR inference at the low corner.
pub fn knag_bnn(low_voltage: bool) -> BaselineRow {
    let (v, eff, tops) = if low_voltage { (0.37, 617.0, 3.4) } else { (0.75, 269.0, 163.0) };
    let ops = cifar9_ops(128);
    let e_inf = if low_voltage {
        3.2
    } else {
        // scale the published low-corner energy by the efficiency ratio
        3.2 * 617.0 / 269.0
    };
    let _ = ops;
    BaselineRow {
        name: if low_voltage { "10nm BNN [8] @0.37V" } else { "10nm BNN [8] @0.75V" },
        computation: "digital",
        weight_precision: "binary",
        act_precision: "binary",
        tech_nm: 10,
        dataset: "CIFAR-10",
        accuracy_pct: 86.0,
        energy_per_inf_uj: e_inf,
        core_area_mm2: 0.39,
        voltage_v: v,
        throughput_tops: tops,
        peak_eff_tops_w: eff,
    }
}

/// Giraldo et al. [10]: 65 nm TCN keyword-spotting accelerator.
/// 1.5 MOp/inference network at 64 inf/s, 5–15 µW → 6.4–19.2 TOp/s/W
/// average efficiency (§8). We model the midpoint.
pub struct TcnKws {
    pub mop_per_inf: f64,
    pub inf_per_s: f64,
    pub power_uw_lo: f64,
    pub power_uw_hi: f64,
}

impl TcnKws {
    pub fn published() -> Self {
        TcnKws { mop_per_inf: 1.5, inf_per_s: 64.0, power_uw_lo: 5.0, power_uw_hi: 15.0 }
    }

    /// Average energy efficiency band (TOp/s/W).
    pub fn eff_band_tops_w(&self) -> (f64, f64) {
        let ops_per_s = self.mop_per_inf * 1e6 * self.inf_per_s;
        (ops_per_s / (self.power_uw_hi * 1e-6) / 1e12, ops_per_s / (self.power_uw_lo * 1e-6) / 1e12)
    }

    /// Average energy per operation (J), midpoint of the band.
    pub fn energy_per_op_j(&self) -> f64 {
        let (lo, hi) = self.eff_band_tops_w();
        2.0 / ((lo + hi) * 1e12)
    }
}

/// SNN comparison points on DVS-gesture-class tasks (§8).
pub struct SnnPoint {
    pub name: &'static str,
    pub accuracy_pct: f64,
    pub energy_per_inf_uj: f64,
}

/// IBM TrueNorth running DVS128 gestures [2]: 94.6% accuracy; the paper
/// states 3250× more energy per inference than TCN-CUTIE's 5.5 µJ.
pub fn truenorth() -> SnnPoint {
    SnnPoint { name: "TrueNorth [2]", accuracy_pct: 94.6, energy_per_inf_uj: 3250.0 * 5.5 }
}

/// Intel Loihi (14 nm) on the DVS+EMG benchmark [11]: 96.0% accuracy,
/// 63.4× the energy of TCN-CUTIE's 5.5 µJ.
pub fn loihi() -> SnnPoint {
    SnnPoint { name: "Loihi [11]", accuracy_pct: 96.0, energy_per_inf_uj: 63.4 * 5.5 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binareye_matches_cited_numbers() {
        let b = binareye();
        assert!((b.energy_per_inf_uj - 13.86).abs() < 0.01);
        assert_eq!(b.peak_eff_tops_w, 230.0);
        assert_eq!(b.voltage_v, 0.65);
    }

    #[test]
    fn knag_corners() {
        let lo = knag_bnn(true);
        let hi = knag_bnn(false);
        assert_eq!(lo.peak_eff_tops_w, 617.0);
        assert_eq!(hi.throughput_tops, 163.0);
        assert!((lo.energy_per_inf_uj - 3.2).abs() < 1e-9);
        assert!(hi.energy_per_inf_uj > lo.energy_per_inf_uj);
    }

    #[test]
    fn tcn_kws_band_matches_paper() {
        let k = TcnKws::published();
        let (lo, hi) = k.eff_band_tops_w();
        assert!((lo - 6.4).abs() < 0.1, "low end {lo}");
        assert!((hi - 19.2).abs() < 0.1, "high end {hi}");
    }

    #[test]
    fn cutie_beats_every_baseline_on_peak_eff() {
        // the paper's headline claim: 1036 > 617 × 1.67
        let ours = crate::energy::calibration::anchors::PEAK_EFF_05;
        for eff in [binareye().peak_eff_tops_w, knag_bnn(true).peak_eff_tops_w, knag_bnn(false).peak_eff_tops_w] {
            assert!(ours > eff);
        }
        assert!((ours / knag_bnn(true).peak_eff_tops_w - 1.67) < 0.05);
    }

    #[test]
    fn snn_ratios() {
        assert!((truenorth().energy_per_inf_uj / 5.5 - 3250.0).abs() < 1.0);
        assert!((loihi().energy_per_inf_uj / 5.5 - 63.4).abs() < 0.1);
    }
}
