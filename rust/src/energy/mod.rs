//! Energy/power model: converts the simulator's activity counters into
//! µJ/inference, mW, TOp/s and TOp/s/W across the 0.5–0.9 V range.
//!
//! Methodology (DESIGN.md §2): the paper's efficiency argument is
//! activity-based — minimized data movement plus sparsity-suppressed
//! toggling. We charge a calibrated per-event energy to every counter in
//! [`crate::cutie::RunStats`], scale dynamic energy with (V/V₀)² and
//! leakage with an exponential V-dependence, and take fmax(V) from an
//! alpha-power fit anchored on the paper's two reported corners.

pub mod calibration;
pub mod model;
pub mod vf;

pub use model::{evaluate, EnergyBreakdown, EnergyParams, EnergyReport};
pub use vf::{fmax_hz, PAPER_ENERGY_FREQ_HZ, VOLTAGE_RANGE};
