//! Per-event energy accounting. All reference energies are at V₀ = 0.5 V;
//! see `calibration.rs` for how the constants were fitted to the paper's
//! measured corners and for the locked-in regression tests.

use anyhow::Result;

use crate::cutie::{LayerStats, RunStats};

use super::vf;

/// Per-event energies (J) at the 0.5 V reference corner + leakage model.
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// Reference supply for the constants below.
    pub v_ref: f64,
    /// One non-zero ternary partial product (multiplier + its share of the
    /// adder tree switching).
    pub e_mac_toggle: f64,
    /// One clocked-but-silent MAC position (clock + latch load).
    pub e_mac_idle: f64,
    /// One activation-memory word access (192-bit SRAM read or write).
    pub e_act_word: f64,
    /// One pixel pushed through the linebuffer flip-flops.
    pub e_lb_push: f64,
    /// One weight word streamed from the weight memory.
    pub e_weight_word: f64,
    /// One TCN-memory trit flip on shift (SCM flip-flop).
    pub e_tcn_trit: f64,
    /// One µDMA byte moved into the activation memory.
    pub e_dma_byte: f64,
    /// Control/clock-tree overhead per active cycle.
    pub e_cycle_ctrl: f64,
    /// One word scanned or re-adopted by a fault-scrub pass (a read +
    /// invariant/fingerprint compare — cheaper than a full datapath
    /// access, charged only when a scrub actually fires).
    pub e_scrub_word: f64,
    /// Retaining one hibernated snapshot word for one idle drain tick
    /// (TinyVers-style state-retentive eMRAM holding cost). Flat — the
    /// retention corner is a fixed low-voltage rail, not the dynamic
    /// supply, so this does not V²-scale.
    pub e_retention: f64,
    /// Re-loading one snapshot word into the engine on wake (dyn-scaled:
    /// the wake path runs at the operating supply).
    pub e_wake: f64,
    /// CUTIE-domain leakage power (W) at v_ref when powered.
    pub p_leak_ref: f64,
    /// Exponential leakage slope (per volt).
    pub leak_slope: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        super::calibration::calibrated()
    }
}

impl EnergyParams {
    /// Dynamic scale factor at supply `v` (CV² switching energy).
    pub fn dyn_scale(&self, v: f64) -> f64 {
        (v / self.v_ref) * (v / self.v_ref)
    }

    /// Leakage power (W) at supply `v`.
    pub fn p_leak(&self, v: f64) -> f64 {
        self.p_leak_ref * (v / self.v_ref) * ((v - self.v_ref) / self.leak_slope).exp()
    }
}

/// Energy split by component (J).
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    pub compute_toggle: f64,
    pub compute_idle: f64,
    pub act_mem: f64,
    pub linebuffer: f64,
    pub weights: f64,
    pub tcn_mem: f64,
    pub dma: f64,
    pub control: f64,
    /// Fault-scrub traffic (detection scans + weight re-adoption).
    pub scrub: f64,
    pub leakage: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_toggle
            + self.compute_idle
            + self.act_mem
            + self.linebuffer
            + self.weights
            + self.tcn_mem
            + self.dma
            + self.control
            + self.scrub
            + self.leakage
    }
}

/// Full evaluation of one run at an operating point.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub voltage: f64,
    pub freq_hz: f64,
    pub cycles: u64,
    pub time_s: f64,
    pub energy_j: f64,
    pub breakdown: EnergyBreakdown,
    pub power_w: f64,
    /// Full-datapath ops (paper convention, 2 Op per MAC).
    pub hw_ops: u64,
    pub avg_tops: f64,
    pub avg_tops_per_watt: f64,
    /// Best single-layer (TOp/s, TOp/s/W) — the paper's "peak" numbers.
    pub peak_tops: f64,
    pub peak_tops_per_watt: f64,
    pub peak_layer: String,
}

fn layer_dyn_energy(l: &LayerStats, p: &EnergyParams, scale: f64) -> EnergyBreakdown {
    EnergyBreakdown {
        compute_toggle: l.mac_toggles as f64 * p.e_mac_toggle * scale,
        compute_idle: l.mac_idle as f64 * p.e_mac_idle * scale,
        act_mem: (l.act_reads + l.act_writes) as f64 * p.e_act_word * scale,
        linebuffer: l.lb_pushes as f64 * p.e_lb_push * scale,
        weights: l.weight_words as f64 * p.e_weight_word * scale,
        tcn_mem: (l.tcn_pushes + l.tcn_reads) as f64 * p.e_tcn_trit * 96.0 * scale,
        dma: 0.0,
        control: l.total_cycles() as f64 * p.e_cycle_ctrl * scale,
        scrub: (l.scrub_words + l.scrub_repair_words) as f64 * p.e_scrub_word * scale,
        leakage: 0.0,
    }
}

/// Evaluate a run at supply `v`, clock `freq_hz` (defaults to fmax(v)).
/// Errors only on a sub-threshold supply with no explicit clock — a
/// corner where no frequency is defined at all.
pub fn evaluate(
    stats: &RunStats,
    v: f64,
    freq_hz: Option<f64>,
    p: &EnergyParams,
) -> Result<EnergyReport> {
    let freq = match freq_hz {
        Some(f) => f,
        None => vf::fmax_hz(v)?,
    };
    let scale = p.dyn_scale(v);
    let cycles = stats.total_cycles();
    let time_s = cycles as f64 / freq;

    let mut bd = EnergyBreakdown::default();
    let mut peak_tops = 0.0;
    let mut peak_eff = 0.0;
    let mut peak_layer = String::new();
    for l in &stats.layers {
        let lb = layer_dyn_energy(l, p, scale);
        let l_cycles = l.total_cycles();
        let l_time = l_cycles as f64 / freq;
        let l_leak = p.p_leak(v) * l_time;
        let l_energy = lb.total() + l_leak;
        // per-layer throughput/efficiency (compute phase)
        if l.compute_cycles > 0 && l_energy > 0.0 {
            let l_tops = l.hw_ops as f64 / (l.compute_cycles as f64 / freq) / 1e12;
            let l_eff = l.hw_ops as f64 / l_energy / 1e12;
            if l_eff > peak_eff {
                peak_eff = l_eff;
                peak_layer = l.name.clone();
            }
            if l_tops > peak_tops {
                peak_tops = l_tops;
            }
        }
        bd.compute_toggle += lb.compute_toggle;
        bd.compute_idle += lb.compute_idle;
        bd.act_mem += lb.act_mem;
        bd.linebuffer += lb.linebuffer;
        bd.weights += lb.weights;
        bd.tcn_mem += lb.tcn_mem;
        bd.control += lb.control;
        bd.scrub += lb.scrub;
    }
    bd.dma = stats.dma_bytes as f64 * p.e_dma_byte * scale
        + stats.dma_cycles as f64 * p.e_cycle_ctrl * scale * 0.25;
    bd.leakage = p.p_leak(v) * time_s;

    let energy = bd.total();
    let hw_ops = stats.hw_ops();
    let avg_tops = if time_s > 0.0 { hw_ops as f64 / time_s / 1e12 } else { 0.0 };
    let power = if time_s > 0.0 { energy / time_s } else { 0.0 };
    Ok(EnergyReport {
        voltage: v,
        freq_hz: freq,
        cycles,
        time_s,
        energy_j: energy,
        breakdown: bd,
        power_w: power,
        hw_ops,
        avg_tops,
        avg_tops_per_watt: if energy > 0.0 { hw_ops as f64 / energy / 1e12 } else { 0.0 },
        peak_tops,
        peak_tops_per_watt: peak_eff,
        peak_layer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cutie::{CutieConfig, Scheduler, SimMode};
    use crate::network::cifar9_random;
    use crate::tensor::TritTensor;
    use crate::util::rng::Rng;

    fn cifar_run() -> RunStats {
        let net = cifar9_random(96, 1, 0.33);
        let mut rng = Rng::new(2);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut s = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        s.preload_weights(&net);
        s.run_full(&net, &input).unwrap().1
    }

    #[test]
    fn energy_scales_with_voltage() {
        let stats = cifar_run();
        let p = EnergyParams::default();
        let e05 = evaluate(&stats, 0.5, None, &p).unwrap();
        let e09 = evaluate(&stats, 0.9, None, &p).unwrap();
        assert!(e09.energy_j > e05.energy_j * 2.0, "V² scaling");
        assert!(e09.avg_tops > e05.avg_tops * 3.0, "higher clock");
        assert!(e09.avg_tops_per_watt < e05.avg_tops_per_watt, "efficiency drops");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let stats = cifar_run();
        let p = EnergyParams::default();
        let r = evaluate(&stats, 0.6, None, &p).unwrap();
        assert!((r.breakdown.total() - r.energy_j).abs() < 1e-15);
        assert!(r.power_w > 0.0 && r.time_s > 0.0);
    }

    #[test]
    fn subthreshold_without_explicit_clock_is_error() {
        let stats = cifar_run();
        let p = EnergyParams::default();
        assert!(evaluate(&stats, 0.2, None, &p).is_err());
        // with an explicit clock the sub-0.5 V point evaluates fine (the
        // fault sweep's operating mode)
        assert!(evaluate(&stats, 0.45, Some(54.0e6), &p).is_ok());
    }

    #[test]
    fn scrub_words_charge_the_scrub_component() {
        let mut stats = cifar_run();
        let p = EnergyParams::default();
        let clean = evaluate(&stats, 0.5, None, &p).unwrap();
        assert_eq!(clean.breakdown.scrub, 0.0, "no scrub layer → no scrub energy");
        stats.layers.push(LayerStats {
            name: "fault_scrub".to_string(),
            scrub_words: 1000,
            scrub_repair_words: 24,
            ..Default::default()
        });
        let scrubbed = evaluate(&stats, 0.5, None, &p).unwrap();
        let want = 1024.0 * p.e_scrub_word * p.dyn_scale(0.5);
        assert!((scrubbed.breakdown.scrub - want).abs() < 1e-18);
        assert!((scrubbed.energy_j - clean.energy_j - want).abs() < 1e-15);
        assert!((scrubbed.breakdown.total() - scrubbed.energy_j).abs() < 1e-15);
        // the zero-cycle synthetic layer must not perturb peak metrics
        assert_eq!(scrubbed.peak_layer, clean.peak_layer);
    }

    #[test]
    fn peak_layer_is_sparse_first_layer() {
        // C1 has 3/96 input channels toggling → lowest energy per hw-op.
        let stats = cifar_run();
        let p = EnergyParams::default();
        let r = evaluate(&stats, 0.5, None, &p).unwrap();
        assert_eq!(r.peak_layer, "l0");
        assert!(r.peak_tops_per_watt > r.avg_tops_per_watt);
    }

    #[test]
    fn leakage_grows_superlinearly() {
        let p = EnergyParams::default();
        let ratio = p.p_leak(0.9) / p.p_leak(0.5);
        assert!(ratio > 4.0 && ratio < 20.0, "leak ratio {ratio}");
    }
}
