//! Voltage/frequency model of the CUTIE (EHWPE) domain in GF 22FDX.
//!
//! Anchors from the paper: peak throughput 14.9 TOp/s at 0.5 V and
//! 51.7 TOp/s at 0.9 V (§7) over the 165,888 Op/cycle datapath give
//! fmax(0.5 V) ≈ 90 MHz and fmax(0.9 V) ≈ 311 MHz. We fit the standard
//! alpha-power law fmax = k·(V − V_t)^α with V_t = 0.30 V:
//!
//!   α = ln(311/90) / ln(0.6/0.2) = 1.1287
//!   k = 90 MHz / 0.2^1.1287     = 553.6 MHz
//!
//! The 2.72 µJ energy corner is quoted at 54 MHz / 0.5 V (§7); Fig. 5/6
//! use the maximum stable frequency per corner, which is what we default
//! to.

/// Threshold-ish voltage of the fit (V).
pub const V_T: f64 = 0.30;
/// Alpha-power exponent.
pub const ALPHA: f64 = 1.1287;
/// Frequency constant (Hz).
pub const K_HZ: f64 = 553.6e6;

/// Supply range the silicon sustains (§7: SRAM bit-errors below 0.5 V).
pub const VOLTAGE_RANGE: (f64, f64) = (0.5, 0.9);

/// The paper's energy-optimal operating point at 0.5 V.
pub const PAPER_ENERGY_FREQ_HZ: f64 = 54.0e6;

/// Maximum stable clock at supply `v` (V), Hz.
pub fn fmax_hz(v: f64) -> f64 {
    assert!(v > V_T, "supply {v} V below threshold fit range");
    K_HZ * (v - V_T).powf(ALPHA)
}

/// The standard Fig. 5/6 sweep points.
pub fn sweep_points() -> Vec<f64> {
    (0..=8).map(|i| 0.5 + 0.05 * i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        // 0.5 V: 14.9 TOp/s over 165,888 Op/cycle → ~90 MHz
        let f05 = fmax_hz(0.5);
        assert!((f05 - 90.0e6).abs() / 90.0e6 < 0.01, "f(0.5) = {f05}");
        // 0.9 V: 51.7 TOp/s → ~311 MHz
        let f09 = fmax_hz(0.9);
        assert!((f09 - 311.0e6).abs() / 311.0e6 < 0.01, "f(0.9) = {f09}");
    }

    #[test]
    fn monotone_increasing() {
        let pts = sweep_points();
        for w in pts.windows(2) {
            assert!(fmax_hz(w[1]) > fmax_hz(w[0]));
        }
    }

    #[test]
    fn peak_throughput_endpoints() {
        // Peak TOp/s = 165,888 × fmax — the Fig. 6 upper curve endpoints.
        let peak05 = 165_888.0 * fmax_hz(0.5) / 1e12;
        let peak09 = 165_888.0 * fmax_hz(0.9) / 1e12;
        assert!((peak05 - 14.9).abs() < 0.2, "peak(0.5) = {peak05}");
        assert!((peak09 - 51.7).abs() < 0.7, "peak(0.9) = {peak09}");
    }

    #[test]
    #[should_panic]
    fn rejects_subthreshold() {
        fmax_hz(0.2);
    }
}
