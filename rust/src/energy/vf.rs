//! Voltage/frequency model of the CUTIE (EHWPE) domain in GF 22FDX.
//!
//! Anchors from the paper: peak throughput 14.9 TOp/s at 0.5 V and
//! 51.7 TOp/s at 0.9 V (§7) over the 165,888 Op/cycle datapath give
//! fmax(0.5 V) ≈ 90 MHz and fmax(0.9 V) ≈ 311 MHz. We fit the standard
//! alpha-power law fmax = k·(V − V_t)^α with V_t = 0.30 V:
//!
//!   α = ln(311/90) / ln(0.6/0.2) = 1.1287
//!   k = 90 MHz / 0.2^1.1287     = 553.6 MHz
//!
//! The 2.72 µJ energy corner is quoted at 54 MHz / 0.5 V (§7); Fig. 5/6
//! use the maximum stable frequency per corner, which is what we default
//! to.

use anyhow::{ensure, Result};

/// Threshold-ish voltage of the fit (V).
pub const V_T: f64 = 0.30;
/// Alpha-power exponent.
pub const ALPHA: f64 = 1.1287;
/// Frequency constant (Hz).
pub const K_HZ: f64 = 553.6e6;

/// Supply range the silicon sustains (§7: SRAM bit-errors below 0.5 V).
pub const VOLTAGE_RANGE: (f64, f64) = (0.5, 0.9);

/// The paper's energy-optimal operating point at 0.5 V.
pub const PAPER_ENERGY_FREQ_HZ: f64 = 54.0e6;

/// Maximum stable clock at supply `v` (V), Hz. Supplies at or below
/// `V_T` are outside the fit's physical range — the logic simply cannot
/// lock a clock there — and surface as a proper error (the fault sweep
/// evaluates sub-0.5 V points, so this must be recoverable, not a
/// panic).
pub fn fmax_hz(v: f64) -> Result<f64> {
    ensure!(v > V_T, "supply {v} V at or below the {V_T} V threshold fit range");
    Ok(K_HZ * (v - V_T).powf(ALPHA))
}

/// The standard Fig. 5/6 sweep points.
pub fn sweep_points() -> Vec<f64> {
    (0..=8).map(|i| 0.5 + 0.05 * i as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        // 0.5 V: 14.9 TOp/s over 165,888 Op/cycle → ~90 MHz
        let f05 = fmax_hz(0.5).unwrap();
        assert!((f05 - 90.0e6).abs() / 90.0e6 < 0.01, "f(0.5) = {f05}");
        // 0.9 V: 51.7 TOp/s → ~311 MHz
        let f09 = fmax_hz(0.9).unwrap();
        assert!((f09 - 311.0e6).abs() / 311.0e6 < 0.01, "f(0.9) = {f09}");
    }

    #[test]
    fn monotone_increasing() {
        let pts = sweep_points();
        for w in pts.windows(2) {
            assert!(fmax_hz(w[1]).unwrap() > fmax_hz(w[0]).unwrap());
        }
    }

    #[test]
    fn peak_throughput_endpoints() {
        // Peak TOp/s = 165,888 × fmax — the Fig. 6 upper curve endpoints.
        let peak05 = 165_888.0 * fmax_hz(0.5).unwrap() / 1e12;
        let peak09 = 165_888.0 * fmax_hz(0.9).unwrap() / 1e12;
        assert!((peak05 - 14.9).abs() < 0.2, "peak(0.5) = {peak05}");
        assert!((peak09 - 51.7).abs() < 0.7, "peak(0.9) = {peak09}");
    }

    #[test]
    fn rejects_subthreshold_as_error() {
        // Sub-threshold supplies are an error, not a panic: the fault
        // sweep probes below 0.5 V and must keep the process alive.
        assert!(fmax_hz(0.2).is_err());
        assert!(fmax_hz(V_T).is_err());
        assert!(fmax_hz(V_T + 1e-6).is_ok());
    }
}
