//! Calibration of the per-event energies against the paper's measured
//! 0.5 V corner (§7): CIFAR-9/96ch at 2.72 µJ/inference with a peak
//! first-layer core efficiency of 1036 TOp/s/W, and ~318 TOp/s/W at
//! 0.9 V. The constants below were fitted by running the cycle-level
//! simulator on the seeded cifar9_96 benchmark (see
//! `report::calibration_table`, printed by `tcn-cutie report calib`) and
//! solving for the component energies in the same proportions the paper's
//! §8 argument attributes them (compute switching dominant, data movement
//! minimized by design).
//!
//! 22FDX plausibility cross-check: a ternary multiplier + adder-tree slice
//! switching at 0.5 V costs tens of fJ; a 192-bit SRAM access ~10-20 pJ;
//! flip-flop shift ~fJ/bit. The fitted values land inside those ranges.

use super::model::EnergyParams;

/// The fitted parameter set (reference corner 0.5 V).
pub fn calibrated() -> EnergyParams {
    // Least-squares fit (python/scipy, 2026-07-10) of the three paper
    // anchors {CIFAR 2.72 µJ @0.5 V, L1 peak 1036 TOp/s/W @0.5 V, 318
    // TOp/s/W @0.9 V} over the simulator's measured activity counts
    // (toggles 45.1 M, idle 180.5 M, 4.4 k act words, 3.2 k cycles).
    // Residuals < 1e-4 on all three anchors.
    EnergyParams {
        v_ref: 0.5,
        e_mac_toggle: 54.67e-15,
        e_mac_idle: 0.39e-15,
        e_act_word: 14.13e-12,
        e_lb_push: 4.12e-12,
        e_weight_word: 8.0e-12,
        e_tcn_trit: 1.2e-15,
        e_dma_byte: 6.0e-12,
        e_cycle_ctrl: 28.51e-12,
        // Scrub scan/re-adopt word (not part of the fit: scrubs only fire
        // on detected corruption, so the calibrated anchors see zero
        // scrub activity). Sized just under an SRAM word access — a read
        // plus compare, no datapath movement.
        e_scrub_word: 9.0e-12,
        // Hibernation retention/wake words (not part of the fit: the
        // calibrated anchors never hibernate, so they see zero of either).
        // TinyVers-style state-retentive figures — holding an eMRAM-class
        // word across an idle tick is orders cheaper than touching it;
        // the wake re-load is priced like a weight-word stream.
        e_retention: 0.02e-12,
        e_wake: 2.0e-12,
        p_leak_ref: 0.2e-3,
        leak_slope: 0.187,
    }
}

/// Paper anchor values used by the regression tests and EXPERIMENTS.md.
pub mod anchors {
    /// µJ per CIFAR-9/96 inference at 0.5 V.
    pub const CIFAR_UJ_05: f64 = 2.72;
    /// Peak core efficiency at 0.5 V (TOp/s/W, first CIFAR layer).
    pub const PEAK_EFF_05: f64 = 1036.0;
    /// Peak core efficiency at 0.9 V (TOp/s/W; §7 text says 318, Table 1
    /// prints 446 — we anchor on the text).
    pub const PEAK_EFF_09: f64 = 318.0;
    /// Peak throughput (TOp/s) at the two corners (§7 text).
    pub const PEAK_TOPS_05: f64 = 14.9;
    pub const PEAK_TOPS_09: f64 = 51.7;
    /// µJ per DVS-hybrid inference at 0.5 V.
    pub const DVS_UJ_05: f64 = 5.5;
    /// Average power while running CIFAR at 0.5 V (mW).
    pub const POWER_MW_05: f64 = 12.2;
}

#[cfg(test)]
mod tests {
    use super::anchors;
    use crate::cutie::{CutieConfig, Scheduler, SimMode};
    use crate::energy::{evaluate, EnergyParams};
    use crate::network::cifar9_random;
    use crate::tensor::TritTensor;
    use crate::util::rng::Rng;

    /// The headline reproduction: CIFAR energy/inference and peak
    /// efficiency at 0.5 V within a band of the silicon measurements.
    /// (Tolerances are generous: our substrate is a simulator with fitted
    /// event energies, not the authors' tester — see EXPERIMENTS.md.)
    #[test]
    fn cifar_anchors_within_band() {
        let net = cifar9_random(96, 1, 0.33);
        let mut rng = Rng::new(2);
        let input = TritTensor::random(&[32, 32, 3], &mut rng, 0.3);
        let mut s = Scheduler::new(CutieConfig::kraken(), SimMode::Accurate);
        s.preload_weights(&net);
        let (_, stats) = s.run_full(&net, &input).unwrap();
        let p = EnergyParams::default();

        let r05 = evaluate(&stats, 0.5, None, &p).unwrap();
        let uj = r05.energy_j * 1e6;
        assert!(
            (uj - anchors::CIFAR_UJ_05).abs() / anchors::CIFAR_UJ_05 < 0.05,
            "CIFAR energy {uj:.2} µJ vs paper {}",
            anchors::CIFAR_UJ_05
        );
        let eff = r05.peak_tops_per_watt;
        assert!(
            (eff - anchors::PEAK_EFF_05).abs() / anchors::PEAK_EFF_05 < 0.05,
            "peak efficiency {eff:.0} TOp/s/W vs paper {}",
            anchors::PEAK_EFF_05
        );

        let r09 = evaluate(&stats, 0.9, None, &p).unwrap();
        let eff9 = r09.peak_tops_per_watt;
        assert!(
            (eff9 - anchors::PEAK_EFF_09).abs() / anchors::PEAK_EFF_09 < 0.05,
            "peak efficiency @0.9 {eff9:.0} vs paper {}",
            anchors::PEAK_EFF_09
        );
        // throughput anchors come from the VF fit directly
        assert!((r05.peak_tops - anchors::PEAK_TOPS_05).abs() / anchors::PEAK_TOPS_05 < 0.10);
        assert!((r09.peak_tops - anchors::PEAK_TOPS_09).abs() / anchors::PEAK_TOPS_09 < 0.10);
    }
}
