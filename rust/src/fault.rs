//! Voltage-scaled SRAM fault injection and recovery accounting.
//!
//! The paper stops voltage scaling at 0.5 V because the integrated SRAM
//! macros bit-error below it (§7) — a cliff `energy/vf.rs` records in a
//! comment but, until this module, nothing in the simulator could
//! express. Since the (pos, mask) bitplane passes (PR 2–5) every modeled
//! SRAM surface stores exactly two plane bits per trit, which is the
//! granularity real sub-nominal corruption hits: the two bitcells of a
//! trit upset independently. This module provides
//!
//! * a deterministic bit-error-rate model [`ber`] extending the VF fit
//!   below [`MIN_SRAM_VOLTAGE`],
//! * seed-addressable injectors ([`Injector`]) that flip plane bits at a
//!   configurable surface ([`FaultSurface`]) via geometric-gap sampling —
//!   zero RNG draws at BER 0, so an armed-but-clean plan is bit-exact,
//! * the detection currency: a `pos ⊄ mask` orphan (a +1 bit whose
//!   non-zero flag is clear) is a state no legal write produces, so scrub
//!   passes ([`PackedVec::scrub`]) can detect and clamp it; a mask-plane
//!   flip is silent and becomes an accuracy loss instead — exactly the
//!   split the accuracy-vs-voltage sweep measures,
//! * per-frame ([`FrameFaults`]) and per-session ([`FaultSummary`])
//!   ledgers the engine folds into `LayerStats` and the energy model.

use std::fmt;
use std::str::FromStr;

use crate::cutie::actmem::MIN_SRAM_VOLTAGE;
use crate::cutie::LayerStats;
use crate::tensor::PackedMap;
use crate::trit::PackedVec;
use crate::util::rng::Rng;

/// Bit-error rate at the onset voltage (per bit per frame-exposure): the
/// first observable error floor just under 0.5 V.
pub const BER_ONSET: f64 = 1e-9;

/// Exponential BER slope below onset, in decades per volt — roughly one
/// decade per 17 mV of undervolting, a typical near-threshold SRAM
/// retention cliff. Gives 1e-6 at 0.45 V and 1e-3 at 0.40 V.
pub const DECADE_PER_V: f64 = 60.0;

/// Bit-error rate of the modeled SRAM surfaces at supply `v`: exactly
/// zero at and above [`MIN_SRAM_VOLTAGE`] (the silicon's validated
/// range), exponential below it, clamped at 0.5 (a bit that flips with
/// probability one-half carries no information — deep sub-threshold
/// retention is simply lost).
pub fn ber(v: f64) -> f64 {
    if v >= MIN_SRAM_VOLTAGE {
        return 0.0;
    }
    (BER_ONSET * 10f64.powf((MIN_SRAM_VOLTAGE - v) * DECADE_PER_V)).min(0.5)
}

/// Which modeled SRAM surface a [`FaultPlan`] corrupts. One plan targets
/// exactly one surface; the engine keys its injection site off this, so
/// the RNG consumption order is the per-session frame order regardless
/// of drain cadence (the determinism contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSurface {
    /// Activation ping-pong SRAM: the input frame words.
    ActMem,
    /// TCN flip-flop ring: the resident time-step feature words.
    TcnMem,
    /// Per-OCU weight buffers: the boot-resident prepared image.
    WeightMem,
    /// µDMA ingress: frame words in flight (decoder-validated on landing).
    DmaStream,
    /// Hibernation snapshot store: plane bits of records at rest (the
    /// state-retentive idle tier's eMRAM analogue). CRC-detected on
    /// resume; a corrupt record re-initializes the session.
    Snapshot,
}

impl FromStr for FaultSurface {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "actmem" | "act" => Ok(FaultSurface::ActMem),
            "tcnmem" | "tcn" => Ok(FaultSurface::TcnMem),
            "weightmem" | "weights" => Ok(FaultSurface::WeightMem),
            "dma" | "dmastream" => Ok(FaultSurface::DmaStream),
            "snapshot" | "store" => Ok(FaultSurface::Snapshot),
            other => anyhow::bail!(
                "unknown fault surface {other:?} (expected actmem|tcnmem|weightmem|dma|snapshot)"
            ),
        }
    }
}

impl fmt::Display for FaultSurface {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultSurface::ActMem => "actmem",
            FaultSurface::TcnMem => "tcnmem",
            FaultSurface::WeightMem => "weightmem",
            FaultSurface::DmaStream => "dma",
            FaultSurface::Snapshot => "snapshot",
        };
        f.write_str(s)
    }
}

/// A per-session fault-injection configuration: one surface, one BER
/// (direct or derived from a supply voltage), one seed. Deterministic:
/// the same plan over the same frame sequence injects the same flips.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub surface: FaultSurface,
    /// Per-bit upset probability per frame exposure, in [0, 0.5].
    pub ber: f64,
    pub seed: u64,
}

impl FaultPlan {
    /// Plan at the BER the voltage model predicts for supply `v`.
    pub fn at_voltage(surface: FaultSurface, v: f64, seed: u64) -> FaultPlan {
        FaultPlan { surface, ber: ber(v), seed }
    }

    /// Plan with an explicit BER (clamped to the model's [0, 0.5] range).
    pub fn with_ber(surface: FaultSurface, ber: f64, seed: u64) -> FaultPlan {
        FaultPlan { surface, ber: ber.clamp(0.0, 0.5), seed }
    }

    /// False for BER 0 plans — armed but guaranteed side-effect-free.
    pub fn is_active(&self) -> bool {
        self.ber > 0.0
    }

    /// Build this plan's injector (forked per session by the engine).
    pub fn injector(&self) -> Injector {
        Injector::new(self.ber, self.seed)
    }
}

/// Deterministic plane-bit flipper. Upsets are sampled with geometric
/// gaps (`gap = ⌊ln(1−U)/ln(1−p)⌋`), so the cost — and crucially the RNG
/// draw count — scales with the number of actual upsets, and a BER-0
/// injector consumes no randomness at all: the zero-BER bit-exactness
/// guarantee is structural, not probabilistic.
#[derive(Debug, Clone)]
pub struct Injector {
    ber: f64,
    rng: Rng,
}

impl Injector {
    pub fn new(ber: f64, seed: u64) -> Injector {
        Injector { ber: ber.clamp(0.0, 0.5), rng: Rng::new(seed) }
    }

    /// Geometric gap to the next upset (bits skipped before it).
    fn next_gap(&mut self) -> u64 {
        let u = self.rng.f64();
        // u ∈ [0, 1) so 1−u ∈ (0, 1]; `as` saturates on overflow.
        ((1.0 - u).ln() / (1.0 - self.ber).ln()).floor() as u64
    }

    /// Sorted upset addresses in `[0, total_bits)`. Empty (and free of
    /// RNG draws) when the BER is zero or there is nothing to expose.
    pub fn faulted_bits(&mut self, total_bits: u64) -> Vec<u64> {
        let mut out = Vec::new();
        if self.ber <= 0.0 || total_bits == 0 {
            return out;
        }
        let mut at = self.next_gap();
        while at < total_bits {
            out.push(at);
            at = at.checked_add(1 + self.next_gap()).unwrap_or(u64::MAX);
        }
        out
    }

    /// Corrupt a sequence of packed words, each exposing `nbits` channels
    /// over two planes (address space 2·nbits per word: `[0, nbits)` hits
    /// the pos plane, `[nbits, 2·nbits)` the mask plane — the two
    /// physical bitcells per trit upset independently). Returns the flip
    /// count.
    pub fn corrupt_slots<'a, I>(&mut self, slots: I, n_slots: usize, nbits: usize) -> u64
    where
        I: IntoIterator<Item = &'a mut PackedVec>,
    {
        let per_slot = 2 * nbits as u64;
        let faults = self.faulted_bits(n_slots as u64 * per_slot);
        let mut it = faults.iter().peekable();
        let mut flips = 0;
        for (i, slot) in slots.into_iter().enumerate() {
            let base = i as u64 * per_slot;
            while let Some(&&a) = it.peek() {
                if a >= base + per_slot {
                    break;
                }
                let within = (a - base) as usize;
                if within < nbits {
                    slot.flip_plane_bit(true, within);
                } else {
                    slot.flip_plane_bit(false, within - nbits);
                }
                flips += 1;
                it.next();
            }
        }
        flips
    }

    /// Corrupt one packed word over its first `nbits` channels.
    pub fn corrupt_vec(&mut self, v: &mut PackedVec, nbits: usize) -> u64 {
        self.corrupt_slots(std::iter::once(v), 1, nbits)
    }

    /// Corrupt a whole packed feature map (one SRAM word per pixel).
    pub fn corrupt_map(&mut self, m: &mut PackedMap) -> u64 {
        let (n, c) = (m.pixels.len(), m.c);
        self.corrupt_slots(m.pixels.iter_mut(), n, c)
    }

    /// The injector's exact position: (BER, raw RNG state). Hibernation
    /// snapshots this so a mid-fault-plan resume continues the geometric
    /// gap walk where it left off — the byte-identity contract.
    pub fn state(&self) -> (f64, [u64; 4]) {
        (self.ber, self.rng.state())
    }

    /// Rebuild an injector at a saved position (see [`Injector::state`]).
    pub fn from_state(ber: f64, rng: [u64; 4]) -> Injector {
        Injector { ber: ber.clamp(0.0, 0.5), rng: Rng::from_state(rng) }
    }
}

/// Per-frame fault ledger: what was injected, what the scrub passes
/// caught, and what the detection/repair machinery cost. Folded into the
/// frame's `RunStats` as a synthetic `"fault_scrub"` layer **only when
/// non-zero**, so a clean frame's stats are byte-identical to a
/// fault-free run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrameFaults {
    /// Plane bits flipped by injection.
    pub flips: u64,
    /// Flips caught by invariant scrubs or decoder validation.
    pub detected: u64,
    /// Words scanned by scrub passes (charged to the energy ledger).
    pub scrub_words: u64,
    /// Words re-adopted from the shared image to repair weight banks.
    pub repair_words: u64,
}

impl FrameFaults {
    pub fn any(&self) -> bool {
        *self != FrameFaults::default()
    }

    pub fn merge(&mut self, o: &FrameFaults) {
        self.flips += o.flips;
        self.detected += o.detected;
        self.scrub_words += o.scrub_words;
        self.repair_words += o.repair_words;
    }

    /// The synthetic stats layer carrying this frame's fault counters
    /// into the energy ledger (zero cycles: scrubbing is modeled as
    /// memory traffic, not datapath occupancy).
    pub fn to_layer_stats(&self) -> LayerStats {
        LayerStats {
            name: "fault_scrub".to_string(),
            fault_flips: self.flips,
            fault_detected: self.detected,
            scrub_words: self.scrub_words,
            scrub_repair_words: self.repair_words,
            ..Default::default()
        }
    }
}

/// Per-session (and, merged, per-report) fault and resilience summary.
/// All counters are plain sums so session summaries aggregate by
/// field-wise addition; a fault-free session is `Default` exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultSummary {
    /// Plane bits flipped by injection across the session.
    pub injected_flips: u64,
    /// Flips caught by scrub passes / decoder validation.
    pub detected: u64,
    /// Frames served with (possibly) corrupted data.
    pub degraded_frames: u64,
    /// Words scanned by scrub passes.
    pub scrub_words: u64,
    /// Words re-adopted from the shared image (weight repair).
    pub repair_words: u64,
    /// TCN-tail retries that subsequently succeeded.
    pub retries: u64,
    /// Frames that errored terminally (label not produced).
    pub failures: u64,
    /// 1 once the session tripped the failure limit (sums to a
    /// quarantined-session count across a report).
    pub quarantined: u64,
    /// Frames dropped unserved because the session was quarantined.
    pub dropped_frames: u64,
    /// Hibernation snapshot records the CRC refused on resume (the
    /// session was re-initialized rather than restored).
    pub snapshot_corrupt: u64,
}

impl FaultSummary {
    /// Fold one frame's injection ledger in. `degraded` marks frames
    /// whose activation/TCN/DMA data was actually corrupted (repaired
    /// weight faults leave the frame clean).
    pub fn record(&mut self, f: &FrameFaults, degraded: bool) {
        self.injected_flips += f.flips;
        self.detected += f.detected;
        self.scrub_words += f.scrub_words;
        self.repair_words += f.repair_words;
        if degraded {
            self.degraded_frames += 1;
        }
    }

    pub fn merge(&mut self, o: &FaultSummary) {
        self.injected_flips += o.injected_flips;
        self.detected += o.detected;
        self.degraded_frames += o.degraded_frames;
        self.scrub_words += o.scrub_words;
        self.repair_words += o.repair_words;
        self.retries += o.retries;
        self.failures += o.failures;
        self.quarantined += o.quarantined;
        self.dropped_frames += o.dropped_frames;
        self.snapshot_corrupt += o.snapshot_corrupt;
    }

    pub fn any(&self) -> bool {
        *self != FaultSummary::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ber_model_anchors() {
        // Validated range: exactly zero — the silicon's §7 contract.
        assert_eq!(ber(0.9), 0.0);
        assert_eq!(ber(0.5), 0.0);
        // 3 decades per 50 mV: 1e-6 at 0.45 V, 1e-3 at 0.40 V.
        assert!((ber(0.45) / 1e-6 - 1.0).abs() < 1e-9, "ber(0.45) = {}", ber(0.45));
        assert!((ber(0.40) / 1e-3 - 1.0).abs() < 1e-9, "ber(0.40) = {}", ber(0.40));
        // Deep sub-threshold clamps at the information-free 0.5.
        assert_eq!(ber(0.30), 0.5);
        assert_eq!(ber(0.0), 0.5);
    }

    #[test]
    fn ber_monotone_nonincreasing_in_voltage() {
        let mut last = f64::INFINITY;
        for i in 0..=60 {
            let v = 0.30 + 0.005 * i as f64;
            let b = ber(v);
            assert!(b <= last, "ber must fall as the supply rises (v = {v})");
            assert!((0.0..=0.5).contains(&b));
            last = b;
        }
    }

    #[test]
    fn surface_parses_and_prints() {
        for (s, want) in [
            ("actmem", FaultSurface::ActMem),
            ("tcn", FaultSurface::TcnMem),
            ("weightmem", FaultSurface::WeightMem),
            ("dma", FaultSurface::DmaStream),
            ("snapshot", FaultSurface::Snapshot),
            ("store", FaultSurface::Snapshot),
        ] {
            assert_eq!(s.parse::<FaultSurface>().unwrap(), want);
        }
        assert_eq!(FaultSurface::WeightMem.to_string(), "weightmem");
        assert!("cache".parse::<FaultSurface>().is_err());
        // round-trip through Display
        for s in [
            FaultSurface::ActMem,
            FaultSurface::TcnMem,
            FaultSurface::WeightMem,
            FaultSurface::DmaStream,
            FaultSurface::Snapshot,
        ] {
            assert_eq!(s.to_string().parse::<FaultSurface>().unwrap(), s);
        }
    }

    #[test]
    fn zero_ber_injector_is_inert() {
        let plan = FaultPlan::with_ber(FaultSurface::ActMem, 0.0, 7);
        assert!(!plan.is_active());
        let mut inj = plan.injector();
        assert!(inj.faulted_bits(u64::MAX).is_empty());
        let mut v = PackedVec::pack(&[1, -1, 0, 1]);
        let before = v;
        assert_eq!(inj.corrupt_vec(&mut v, 4), 0);
        assert_eq!(v, before, "BER-0 corruption must be a bit-exact no-op");
    }

    #[test]
    fn injection_is_deterministic() {
        let plan = FaultPlan::with_ber(FaultSurface::TcnMem, 0.01, 99);
        let mut a = plan.injector();
        let mut b = plan.injector();
        for total in [10u64, 1000, 100_000] {
            assert_eq!(a.faulted_bits(total), b.faulted_bits(total));
        }
    }

    #[test]
    fn flip_rate_tracks_ber() {
        let mut inj = Injector::new(0.01, 3);
        let total = 200_000u64;
        let n = inj.faulted_bits(total).len() as f64;
        let expect = 0.01 * total as f64;
        assert!((n - expect).abs() < 0.15 * expect, "got {n}, expected ≈{expect}");
    }

    #[test]
    fn faulted_bits_sorted_unique_in_range() {
        let mut inj = Injector::new(0.05, 11);
        let bits = inj.faulted_bits(10_000);
        assert!(!bits.is_empty());
        for w in bits.windows(2) {
            assert!(w[0] < w[1], "addresses must be strictly increasing");
        }
        assert!(*bits.last().unwrap() < 10_000);
    }

    #[test]
    fn corrupt_map_flips_only_live_channels() {
        let mut m = PackedMap::zeros(8, 8, 17);
        let mut inj = Injector::new(0.05, 5);
        let flips = inj.corrupt_map(&mut m);
        assert!(flips > 0, "5% BER over 2176 plane bits must flip something");
        // Plane bits at positions ≥ c stay clear — the PackedMap invariant
        // survives corruption (only live bitcells are modeled).
        for px in &m.pixels {
            assert_eq!(px.masked(17), *px, "no flips outside the live channels");
        }
        // Flips land as mask-plane −1s and pos-plane orphans; scrubbing
        // detects exactly the orphans.
        let detected: u32 = m.pixels.iter_mut().map(|p| p.scrub()).sum();
        assert!(detected as u64 <= flips);
    }

    #[test]
    fn corrupt_slots_matches_vec_by_vec() {
        // One call over n slots must equal n sequential single-vec calls
        // on a cloned injector (same address-space walk).
        let mut words = vec![PackedVec::ZERO; 24];
        let mut a = Injector::new(0.02, 42);
        let mut b = a.clone();
        let mut clone = words.clone();
        let flips = a.corrupt_slots(words.iter_mut(), 24, 96);
        let faults = b.faulted_bits(24 * 2 * 96);
        assert_eq!(flips, faults.len() as u64);
        for &addr in &faults {
            let (slot, within) = ((addr / 192) as usize, (addr % 192) as usize);
            if within < 96 {
                clone[slot].flip_plane_bit(true, within);
            } else {
                clone[slot].flip_plane_bit(false, within - 96);
            }
        }
        assert_eq!(words, clone);
    }

    #[test]
    fn injector_state_round_trip_resumes_mid_walk() {
        let mut a = Injector::new(0.01, 99);
        a.faulted_bits(50_000); // advance partway through the gap walk
        let (ber, rng) = a.state();
        let mut b = Injector::from_state(ber, rng);
        for total in [10u64, 1000, 100_000] {
            assert_eq!(a.faulted_bits(total), b.faulted_bits(total));
        }
    }

    #[test]
    fn frame_faults_fold_into_summary() {
        let mut sum = FaultSummary::default();
        assert!(!sum.any());
        let f = FrameFaults { flips: 3, detected: 1, scrub_words: 64, repair_words: 0 };
        assert!(f.any());
        sum.record(&f, true);
        sum.record(&FrameFaults::default(), false);
        assert_eq!(sum.injected_flips, 3);
        assert_eq!(sum.degraded_frames, 1);
        let mut total = FaultSummary::default();
        total.merge(&sum);
        total.merge(&sum);
        assert_eq!(total.injected_flips, 6);
        assert_eq!(total.scrub_words, 128);
        let ls = f.to_layer_stats();
        assert_eq!(ls.name, "fault_scrub");
        assert_eq!(ls.fault_flips, 3);
        assert_eq!(ls.compute_cycles, 0, "scrubbing occupies no datapath cycles");
    }
}
