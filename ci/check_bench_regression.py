#!/usr/bin/env python3
"""Compare two BENCH_*.json perf ledgers and flag median regressions.

Usage: check_bench_regression.py PREVIOUS.json CURRENT.json [--threshold 0.10]

Benches are matched by name; a bench whose current median_s exceeds the
previous median_s by more than the threshold fraction is flagged and the
script exits non-zero. Benches present in only one ledger (renamed/new
cases) are reported but never flagged. Entries whose "backend" tag
differs between the two ledgers (e.g. a scalar baseline vs an AVX2
current run, or a pre-tag ledger vs a tagged one) are skipped with a
printed reason — a kernel-backend switch is not a regression. A missing
or unparsable previous ledger is treated as "no baseline" and passes, so
the first CI run after the ledger format lands stays green.
"""

import argparse
import json
import sys


def load_benches(path):
    with open(path) as f:
        doc = json.load(f)
    return {b["name"]: b for b in doc.get("benches", []) if "median_s" in b}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("previous")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="flag if current median exceeds previous by this fraction")
    args = ap.parse_args()

    try:
        prev = load_benches(args.previous)
    except (OSError, ValueError, KeyError) as e:
        print(f"no usable previous ledger ({e}); skipping regression check")
        return 0
    try:
        cur = load_benches(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"cannot read current ledger {args.current}: {e}")
        return 1

    regressions = []
    dropped = []
    for name in sorted(set(prev) | set(cur)):
        if name not in prev:
            print(f"  NEW       {name}")
            continue
        if name not in cur:
            print(f"  DROPPED   {name}")
            dropped.append(name)
            continue
        old_backend = prev[name].get("backend")
        new_backend = cur[name].get("backend")
        if old_backend != new_backend:
            print(f"  SKIPPED   {name}: backend changed "
                  f"({old_backend or 'untagged'} -> {new_backend or 'untagged'}); "
                  f"not comparable like-for-like")
            continue
        old = prev[name]["median_s"]
        new = cur[name]["median_s"]
        if old <= 0:
            continue
        delta = new / old - 1.0
        marker = "ok"
        if delta > args.threshold:
            marker = "REGRESSED"
            regressions.append((name, delta))
        print(f"  {marker:<9} {name}: {old:.3e}s -> {new:.3e}s ({delta:+.1%})")

    if dropped:
        # a renamed/deleted bench silently disarms its regression gate —
        # shout so reviewers confirm the rename was intentional
        print(f"\nWARNING: {len(dropped)} bench(es) present in the previous "
              f"ledger have no counterpart in the current one (renamed or "
              f"deleted?): {', '.join(dropped)}")
    if regressions:
        print(f"\n{len(regressions)} bench(es) regressed beyond "
              f"{args.threshold:.0%} on the median:")
        for name, delta in regressions:
            print(f"  {name}: {delta:+.1%}")
        return 1
    print("\nno median regressions beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
