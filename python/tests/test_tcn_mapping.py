"""§4 mapping correctness: the dilated-1D -> undilated-2D mapping must be
*exactly* equivalent to Eq. (1). This is the paper's central algorithmic
claim ("fully equivalent to a 2D convolutional layer").
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import tcn_mapping
from compile.kernels import ref


def rand_trits(rng, shape):
    return rng.integers(-1, 2, size=shape).astype(np.int8)


def naive_dilated_conv1d(x, w, d):
    """Eq. (1) transcribed literally in numpy."""
    t_len, cin = x.shape
    n, _, cout = w.shape
    out = np.zeros((t_len, cout), dtype=np.int64)
    for t in range(t_len):
        for k in range(1, n + 1):
            src = t - (k - 1) * d
            if src >= 0:
                out[t] += x[src].astype(np.int64) @ w[n - k]
    return out.astype(np.int32)


@settings(max_examples=40, deadline=None)
@given(
    t_len=st.integers(1, 30),
    d=st.integers(1, 9),
    n=st.integers(1, 3),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_dilated_matches_naive(t_len, d, n, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (t_len, cin))
    w = rand_trits(rng, (n, cin, cout))
    got = np.asarray(ref.dilated_conv1d(jnp.asarray(x), jnp.asarray(w), d))
    np.testing.assert_array_equal(got, naive_dilated_conv1d(x, w, d))


@settings(max_examples=60, deadline=None)
@given(
    t_len=st.integers(1, 30),
    d=st.integers(1, 9),
    n=st.integers(1, 3),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_2d_mapping_equals_dilated_1d(t_len, d, n, cin, cout, seed):
    """map_input + standard same-pad 3x3 conv + unmap == Eq. (1)."""
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (t_len, cin))
    w = rand_trits(rng, (n, cin, cout))

    z = tcn_mapping.map_input(jnp.asarray(x), d)
    w2d = tcn_mapping.map_weights(jnp.asarray(w))
    acc2d = ref.ternary_conv2d(z, w2d)
    got = np.asarray(tcn_mapping.unmap_output(acc2d, t_len, d))

    want = naive_dilated_conv1d(x, w, d)
    np.testing.assert_array_equal(got, want)


def test_paper_example_d3_n2():
    """The Fig. 3 configuration: D=3, N=2."""
    rng = np.random.default_rng(42)
    x = rand_trits(rng, (11, 2))
    w = rand_trits(rng, (2, 2, 3))
    z = tcn_mapping.map_input(jnp.asarray(x), 3)
    assert z.shape == (tcn_mapping.wrapped_rows(11, 3) + 1, 3, 2)
    w2d = tcn_mapping.map_weights(jnp.asarray(w))
    # taps bottom-aligned in the middle column, everything else zero
    w2d_np = np.asarray(w2d)
    assert np.all(w2d_np[:, 0] == 0) and np.all(w2d_np[:, 2] == 0)
    assert np.all(w2d_np[0, 1] == 0)
    np.testing.assert_array_equal(w2d_np[1:, 1], np.asarray(w))
    acc2d = ref.ternary_conv2d(z, w2d)
    got = np.asarray(tcn_mapping.unmap_output(acc2d, 11, 3))
    np.testing.assert_array_equal(got, naive_dilated_conv1d(x, w, 3))


def test_map_weights_rejects_long_kernels():
    import pytest

    with pytest.raises(ValueError):
        tcn_mapping.map_weights(jnp.zeros((4, 2, 2), dtype=jnp.int8))


def test_receptive_field_paper_numbers():
    # N=3, D_i = 2^i: paper §4 — 24 input steps
    assert tcn_mapping.receptive_field(3, [1, 2, 4, 8]) == 31
    # undilated: 12 layers for 24 steps (paper)
    assert tcn_mapping.layers_needed_undilated(3, 24) == 12
    # dilated with D_i = 2^i: 4 layers reach f=31 >= 24. The paper quotes 5;
    # its own formula f_k = 1 + sum_{i<=k}(N-1)2^i gives f_3 = 31 (4 layers),
    # so we assert the mathematically consistent value and record the delta
    # in EXPERIMENTS.md.
    assert tcn_mapping.layers_needed_dilated(3, 24) == 4


def test_wrapped_map_fits_cutie_constraints():
    """All DVS-network TCN layers must map to maps within 64x64 and 3x3
    kernels (the hardware constraint the mapping is designed for)."""
    for d in (1, 2, 4, 8):
        rows = tcn_mapping.wrapped_rows(24, d) + 1
        assert rows <= 64 and d <= 64
