"""Interchange-format tests: .ttn round-trip and manifest export."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.ttn import read_ttn, write_ttn, export_network


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
def test_ttn_roundtrip(tmp_path_factory, seed, n):
    rng = np.random.default_rng(seed)
    path = str(tmp_path_factory.mktemp("ttn") / "t.ttn")
    tensors = []
    for i in range(n):
        ndim = rng.integers(1, 4)
        shape = tuple(int(s) for s in rng.integers(1, 6, size=ndim))
        if rng.random() < 0.5:
            arr = rng.integers(-1, 2, size=shape).astype(np.int8)
        else:
            arr = rng.integers(-(2**20), 2**20, size=shape).astype(np.int32)
        tensors.append((f"t{i}", arr))
    write_ttn(path, tensors)
    back = read_ttn(path)
    assert len(back) == n
    for name, arr in tensors:
        np.testing.assert_array_equal(back[name], arr)
        assert back[name].dtype == arr.dtype


def test_ttn_rejects_bad_dtype(tmp_path):
    with pytest.raises(TypeError):
        write_ttn(str(tmp_path / "x.ttn"), [("a", np.zeros(3, dtype=np.float32))])


def test_export_network_manifest(tmp_path):
    net = M.cifar9(8)
    params = M.init_params(net, seed=0)
    ttn = str(tmp_path / "net.ttn")
    man = str(tmp_path / "net.json")
    export_network(net, params, ttn, man)
    m = json.load(open(man))
    assert m["name"] == "cifar9_8"
    assert len(m["layers"]) == 9
    assert m["layers"][0]["kind"] == "conv2d"
    assert m["layers"][-1]["kind"] == "dense"
    assert "lo" not in m["layers"][-1]
    tensors = read_ttn(ttn)
    for layer in m["layers"]:
        assert layer["weights"] in tensors
        if "lo" in layer:
            lo, hi = tensors[layer["lo"]], tensors[layer["hi"]]
            assert np.all(lo <= hi + 1)
