"""L2 model-level tests: ternarization semantics, network geometry, and
ref-vs-pallas backend equality on reduced networks."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref
from compile.ternary import ternarize_acc, encode_input_image


def test_ternarize_semantics():
    acc = jnp.asarray([[-5, -2, -1, 0, 1, 2, 5]], dtype=jnp.int32).T
    lo = jnp.asarray([-2], dtype=jnp.int32)
    hi = jnp.asarray([2], dtype=jnp.int32)
    out = np.asarray(ternarize_acc(acc, lo, hi)).ravel()
    #                 -5  -2  -1   0   1   2   5
    np.testing.assert_array_equal(out, [-1, 0, 0, 0, 0, 0, 1])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ternarize_monotone(seed):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-50, 51, size=(16, 4)).astype(np.int32)
    lo = rng.integers(-10, 1, size=(4,)).astype(np.int32)
    hi = rng.integers(0, 11, size=(4,)).astype(np.int32)
    out = np.asarray(ternarize_acc(jnp.asarray(acc), jnp.asarray(lo), jnp.asarray(hi)))
    assert set(np.unique(out)).issubset({-1, 0, 1})
    # monotonicity in acc per channel
    order = np.argsort(acc, axis=0)
    sorted_out = np.take_along_axis(out, order, axis=0)
    assert np.all(np.diff(sorted_out, axis=0) >= 0)


def test_maxpool_trits():
    t = jnp.asarray(
        [[-1, -1, 0, 1], [0, -1, -1, -1], [1, 1, 0, 0], [1, 0, 0, 0]],
        dtype=jnp.int8,
    )[..., None]
    out = np.asarray(ref.maxpool2x2(t))[..., 0]
    np.testing.assert_array_equal(out, [[0, 1], [1, 0]])


def test_encode_input_image_range():
    img = jnp.linspace(0, 1, 16).reshape(4, 4, 1)
    t = np.asarray(encode_input_image(img))
    assert t.shape == (4, 4, 1)
    assert set(np.unique(t)).issubset({-1, 0, 1})
    assert t.ravel()[0] == -1 and t.ravel()[-1] == 1


def test_cifar9_geometry():
    net = M.cifar9(96)
    assert len(net.layers) == 9
    convs = M.cnn_part(net)
    assert len(convs) == 8
    assert sum(1 for l in convs if l.pool) == 4
    assert net.layers[-1].in_ch == 2 * 2 * 96


def test_dvs_geometry():
    net = M.dvs_hybrid(96)
    kinds = [l.kind for l in net.layers]
    assert kinds == ["conv2d"] * 5 + ["tcn"] * 4 + ["dense"]
    assert [l.dilation for l in net.layers if l.kind == "tcn"] == [1, 2, 4, 8]


def test_init_params_sparsity_controllable():
    net = M.cifar9(16)
    for zf in (0.0, 0.5, 0.9):
        params = M.init_params(net, seed=3, zero_frac=zf)
        w = np.asarray(params["c2"]["w"])
        got = (w == 0).mean()
        assert abs(got - zf) < 0.08


def test_forward_int_shapes_small():
    net = M.cifar9(8)
    params = M.init_params(net, seed=0)
    x = jnp.zeros((32, 32, 3), dtype=jnp.int8)
    logits = M.forward_int(net, params, x)
    assert logits.shape == (10,)


def test_forward_dvs_small():
    net = M.dvs_hybrid(8, classes=4)
    # shrink spatial size for speed
    net = M.Network(net.name, net.layers, input_hw=32, tcn_steps=8, classes=4)
    params = M.init_params(net, seed=0)
    x = (jnp.arange(8 * 32 * 32 * 2).reshape(8, 32, 32, 2) % 3 - 1).astype(jnp.int8)
    logits = M.forward_int(net, params, x)
    assert logits.shape == (4,)


def test_backend_equality_cifar_small():
    """ref and pallas backends must agree trit-for-trit."""
    net = M.cifar9(8)
    params = M.init_params(net, seed=5)
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (32, 32, 3), -1, 2, dtype=jnp.int32).astype(jnp.int8)
    a = np.asarray(M.forward_int(net, params, x, backend="ref"))
    b = np.asarray(M.forward_int(net, params, x, backend="pallas"))
    np.testing.assert_array_equal(a, b)


def test_backend_equality_tcn_layer():
    net = M.dvs_hybrid(8, classes=4)
    net = M.Network(net.name, net.layers, input_hw=32, tcn_steps=8, classes=4)
    params = M.init_params(net, seed=6)
    key = jax.random.PRNGKey(1)
    seq = jax.random.randint(key, (8, 8), -1, 2, dtype=jnp.int32).astype(jnp.int8)
    a = np.asarray(M.forward_tcn_int(net, params, seq, backend="ref"))
    b = np.asarray(M.forward_tcn_int(net, params, seq, backend="pallas"))
    np.testing.assert_array_equal(a, b)


def test_predict_tie_breaks_low_index():
    net = M.cifar9(8)
    params = M.init_params(net, seed=0)
    # all-zero input with zero-ish weights can tie; emulate via direct argmax
    logits = jnp.asarray([3, 5, 5, 1], dtype=jnp.int32)
    assert int(jnp.argmax(logits)) == 1
