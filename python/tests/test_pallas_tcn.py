"""Cross-layer property: the L1 Pallas kernel running the §4-mapped TCN
computation must equal the dilated-1D oracle — i.e. the mapping is exact
*through the production kernel*, not just through the jnp reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import tcn_mapping
from compile.kernels import ref
from compile.kernels.ternary_conv import ternary_conv2d_pallas


def rand_trits(rng, shape):
    return rng.integers(-1, 2, size=shape).astype(np.int8)


@settings(max_examples=10, deadline=None)
@given(
    t_len=st.integers(4, 24),
    d=st.sampled_from([1, 2, 4, 8]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_mapped_tcn_equals_dilated_oracle(t_len, d, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (t_len, cin))
    w = rand_trits(rng, (3, cin, cout))

    # oracle: causal dilated conv (Eq. 1)
    want = np.asarray(ref.dilated_conv1d(jnp.asarray(x), jnp.asarray(w), d))

    # production path: wrap -> Pallas 3x3 conv -> unwrap
    z = tcn_mapping.map_input(jnp.asarray(x), d)
    w2d = tcn_mapping.map_weights(jnp.asarray(w))
    acc2d = ternary_conv2d_pallas(
        z.astype(jnp.float32), w2d.astype(jnp.float32)
    )
    got = np.asarray(tcn_mapping.unmap_output(acc2d, t_len, d))
    np.testing.assert_array_equal(got, want)


def test_pallas_mapped_kraken_geometry():
    """The exact Kraken TCN geometry: 24 steps, 96 channels, D=8."""
    rng = np.random.default_rng(0)
    x = rand_trits(rng, (24, 96))
    w = rand_trits(rng, (3, 96, 96))
    want = np.asarray(ref.dilated_conv1d(jnp.asarray(x), jnp.asarray(w), 8))
    z = tcn_mapping.map_input(jnp.asarray(x), 8)
    assert z.shape == (4, 8, 96)  # 3 wrapped rows + 1 causal pad, within 64x64
    acc2d = ternary_conv2d_pallas(
        z.astype(jnp.float32),
        tcn_mapping.map_weights(jnp.asarray(w)).astype(jnp.float32),
    )
    got = np.asarray(tcn_mapping.unmap_output(acc2d, 24, 8))
    np.testing.assert_array_equal(got, want)
