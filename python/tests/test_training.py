"""Trainer tests: loss decreases, exported params obey the integer
contract, folded thresholds reproduce the float ternarization decisions."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import training
from compile.ternary import fold_bn_thresholds, ACT_DELTA


def tiny_net():
    layers = [
        M.LayerSpec("c1", "conv2d", 3, 8, pool=True),
        M.LayerSpec("c2", "conv2d", 8, 8, pool=True),
        M.LayerSpec("fc", "dense", 4 * 4 * 8, 4),
    ]
    return M.Network("tiny", layers, input_hw=16, classes=4)


def test_synth_dataset_separable():
    key = jax.random.PRNGKey(0)
    imgs, labels = training.synth_image_dataset(key, 64, hw=16, classes=4)
    assert imgs.shape == (64, 16, 16, 3)
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert set(np.unique(np.asarray(labels))).issubset(set(range(4)))


def test_training_reduces_loss_and_beats_chance():
    net = tiny_net()
    params, log, test_acc = training.train(
        net, steps=60, batch=32, n_train=512, n_test=128, seed=0, lr=3e-3
    )
    losses = [l for _, l, _ in log]
    assert losses[-1] < losses[0] * 0.9, f"loss did not decrease: {losses}"
    assert test_acc > 0.4, f"test acc {test_acc} not above chance (0.25)"
    # exported params obey the contract
    for spec in net.layers:
        w = np.asarray(params[spec.name]["w"])
        assert w.dtype == np.int8
        assert set(np.unique(w)).issubset({-1, 0, 1})
        if spec.kind != "dense":
            lo = np.asarray(params[spec.name]["lo"])
            hi = np.asarray(params[spec.name]["hi"])
            assert lo.dtype == np.int32 and hi.dtype == np.int32
            assert np.all(lo <= hi + 1)


def test_int_model_matches_float_decisions_reasonably():
    """The folded integer model should classify the eval set well above
    chance (it is a quantization of the float model, not identical)."""
    net = tiny_net()
    params, _, float_acc = training.train(
        net, steps=80, batch=32, n_train=512, n_test=128, seed=1, lr=3e-3
    )
    key = jax.random.PRNGKey(123)
    imgs, labels = training.synth_image_dataset(key, 64, hw=16, classes=4)
    xs = training.encode_dataset(imgs)
    int_acc = training.eval_int(net, params, xs, labels, limit=64)
    assert int_acc > 0.4, f"int acc {int_acc} vs float {float_acc}"


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fold_bn_thresholds_equivalence(seed):
    """For integer accumulators, ternarize((acc-mean)/sigma at +/-delta)
    must equal the two-threshold integer ternarization with folded (lo,hi)
    — except exactly at integer-valued float thresholds (boundary ties),
    which we exclude."""
    rng = np.random.default_rng(seed)
    mean = jnp.asarray(rng.normal(0, 5, size=(6,)).astype(np.float32))
    var = jnp.asarray(rng.uniform(0.5, 40, size=(6,)).astype(np.float32))
    acc = jnp.asarray(rng.integers(-60, 61, size=(40, 6)).astype(np.int32))
    lo, hi = fold_bn_thresholds(mean, var)

    sigma = np.sqrt(np.asarray(var) + 1e-5)
    normed = (np.asarray(acc) - np.asarray(mean)) / sigma
    want = (normed > ACT_DELTA).astype(int) - (normed < -ACT_DELTA).astype(int)
    got = (np.asarray(acc) > np.asarray(hi)).astype(int) - (
        np.asarray(acc) < np.asarray(lo)
    ).astype(int)

    hi_f = np.asarray(mean) + ACT_DELTA * sigma
    lo_f = np.asarray(mean) - ACT_DELTA * sigma
    boundary = (np.abs(hi_f - np.round(hi_f)) < 1e-6) | (
        np.abs(lo_f - np.round(lo_f)) < 1e-6
    )
    mask = ~np.broadcast_to(boundary, got.shape)
    np.testing.assert_array_equal(got[mask], want[mask])
