"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py), plus an
independent naive-numpy double-check of the oracle itself.

Hypothesis sweeps shapes/channels/sparsity per the repro recipe; sizes are
kept small because interpret-mode Pallas is slow on CPU.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ternary_conv import (
    ternary_conv2d_pallas,
    ternary_dense_pallas,
)

jax.config.update("jax_platform_name", "cpu")


def rand_trits(rng, shape):
    return rng.integers(-1, 2, size=shape).astype(np.int8)


def naive_conv2d(x, w):
    """Straight-from-the-definition numpy conv (independent of jnp)."""
    h, wid, cin = x.shape
    kh, kw, _, cout = w.shape
    ph, pw = kh // 2, kw // 2
    out = np.zeros((h, wid, cout), dtype=np.int64)
    for y in range(h):
        for xx in range(wid):
            for dy in range(kh):
                for dx in range(kw):
                    sy, sx = y + dy - ph, xx + dx - pw
                    if 0 <= sy < h and 0 <= sx < wid:
                        out[y, xx] += x[sy, sx].astype(np.int64) @ w[dy, dx]
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# Oracle vs naive numpy
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(3, 10),
    w=st.integers(3, 10),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_conv_matches_naive(h, w, cin, cout, k, seed):
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (h, w, cin))
    wt = rand_trits(rng, (k, k, cin, cout))
    got = np.asarray(ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(wt)))
    np.testing.assert_array_equal(got, naive_conv2d(x, wt))


def test_ref_conv_identity_kernel():
    rng = np.random.default_rng(0)
    x = rand_trits(rng, (6, 6, 4))
    w = np.zeros((3, 3, 4, 4), dtype=np.int8)
    for c in range(4):
        w[1, 1, c, c] = 1
    got = np.asarray(ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int32))


def test_ref_conv_allones_counts_window():
    x = np.ones((5, 5, 2), dtype=np.int8)
    w = np.ones((3, 3, 2, 1), dtype=np.int8)
    got = np.asarray(ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(w)))
    # interior pixel: full 3x3 window * 2 channels
    assert got[2, 2, 0] == 18
    # corner: 2x2 window * 2 channels
    assert got[0, 0, 0] == 8


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(2, 9),
    w=st.integers(2, 9),
    cin=st.integers(1, 12),
    cout=st.integers(1, 12),
    zero_frac=st.sampled_from([0.0, 0.5, 0.9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_conv_matches_ref(h, w, cin, cout, zero_frac, seed):
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (h, w, cin))
    x[rng.random(x.shape) < zero_frac] = 0
    wt = rand_trits(rng, (3, 3, cin, cout))
    want = ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(wt))
    got = ternary_conv2d_pallas(
        jnp.asarray(x, dtype=jnp.float32), jnp.asarray(wt, dtype=jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_conv_tile_boundary():
    """H*W above one TILE_M so the grid has >1 step and padding is exercised."""
    rng = np.random.default_rng(3)
    x = rand_trits(rng, (12, 12, 8))  # 144 pixels > TILE_M=128
    wt = rand_trits(rng, (3, 3, 8, 16))
    want = ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(wt))
    got = ternary_conv2d_pallas(
        jnp.asarray(x, dtype=jnp.float32), jnp.asarray(wt, dtype=jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    f=st.integers(1, 64),
    classes=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_dense_matches_ref(f, classes, seed):
    rng = np.random.default_rng(seed)
    x = rand_trits(rng, (f,))
    wt = rand_trits(rng, (f, classes))
    want = ref.ternary_dense(jnp.asarray(x), jnp.asarray(wt))
    got = ternary_dense_pallas(
        jnp.asarray(x, dtype=jnp.float32), jnp.asarray(wt, dtype=jnp.float32)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Accumulator range (bf16-exactness argument in DESIGN.md)
# ---------------------------------------------------------------------------


def test_acc_bounded_by_fanin():
    rng = np.random.default_rng(1)
    x = rand_trits(rng, (8, 8, 96))
    w = rand_trits(rng, (3, 3, 96, 4))
    acc = np.asarray(ref.ternary_conv2d(jnp.asarray(x), jnp.asarray(w)))
    assert np.abs(acc).max() <= 9 * 96
