"""AOT path: lowering produces parseable HLO text with the right interface."""

import jax
import jax.numpy as jnp

from compile import model as M
from compile.aot import to_hlo_text
from compile.kernels.ternary_conv import ternary_conv2d_pallas
from compile.ternary import ternarize_acc


def test_hlo_text_plain():
    net = M.cifar9(4)
    params = M.init_params(net, seed=0)

    def fwd(x):
        return (M.forward_int(net, params, x.astype(jnp.int8)).astype(jnp.float32),)

    lowered = jax.jit(fwd).lower(jax.ShapeDtypeStruct((32, 32, 3), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "{...}" not in text, "constants must not be elided"
    assert "f32[32,32,3]" in text
    assert "f32[10]" in text


def test_hlo_text_pallas_kernel():
    """The L1 Pallas kernel must lower into plain HLO (interpret mode)."""
    w = jnp.ones((3, 3, 2, 4), dtype=jnp.float32)
    lo = jnp.full((4,), -1, jnp.int32)
    hi = jnp.full((4,), 1, jnp.int32)

    def fwd(x):
        acc = ternary_conv2d_pallas(x, w)
        return (ternarize_acc(acc, lo, hi).astype(jnp.float32),)

    lowered = jax.jit(fwd).lower(jax.ShapeDtypeStruct((8, 8, 2), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # interpret-mode pallas must not emit TPU custom-calls
    assert "mosaic" not in text.lower()
