"""Make `pytest python/tests` work from the repository root: the compile
package lives in python/, which must be importable."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
