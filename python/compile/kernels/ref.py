"""Pure-jnp oracles for every kernel. This file is the single source of
truth for the numerical contract; the Pallas kernels (ternary_conv.py), the
lowered HLO artifacts and the Rust simulator are all checked against it.

Tensor layout: activations are HWC ``(H, W, C)``; 2D conv weights are
``(KH, KW, Cin, Cout)``; 1D TCN inputs are ``(T, C)`` and TCN weights are
``(N, Cin, Cout)`` with taps in natural (causal) order, i.e. tap ``N-1``
multiplies the current time step — exactly Eq. (1) of the paper.
"""

from __future__ import annotations

import jax.numpy as jnp


def ternary_conv2d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """3x3 (or KxK) ternary convolution, zero "same" padding, stride 1.

    x: (H, W, Cin) trits; w: (KH, KW, Cin, Cout) trits.
    Returns (H, W, Cout) int32 accumulators.

    This is CUTIE's OCU contract: each output pixel/channel is the full
    window dot product computed in one cycle.
    """
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = kh // 2, kw // 2
    xi = x.astype(jnp.int32)
    xp = jnp.pad(xi, ((ph, ph), (pw, pw), (0, 0)))
    h, wid = x.shape[0], x.shape[1]
    acc = jnp.zeros((h, wid, w.shape[3]), dtype=jnp.int32)
    for dy in range(kh):
        for dx in range(kw):
            window = xp[dy : dy + h, dx : dx + wid, :]
            acc = acc + jnp.einsum(
                "hwc,co->hwo", window, w[dy, dx].astype(jnp.int32)
            )
    return acc


def maxpool2x2(t: jnp.ndarray) -> jnp.ndarray:
    """2x2/2 max-pool over trits. t: (H, W, C) int8, H and W even."""
    h, w, c = t.shape
    r = t.reshape(h // 2, 2, w // 2, 2, c)
    return r.max(axis=(1, 3))


def global_maxpool(t: jnp.ndarray) -> jnp.ndarray:
    """Global max-pool to (C,) trits."""
    return t.max(axis=(0, 1))


def ternary_dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Classifier layer: x (F,) trits, w (F, classes) trits -> int32 logits."""
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def dilated_conv1d(x: jnp.ndarray, w: jnp.ndarray, dilation: int) -> jnp.ndarray:
    """Causal dilated 1D convolution, Eq. (1) of the paper.

    x: (T, Cin) trits; w: (N, Cin, Cout) trits; returns (T, Cout) int32.

      (w * x)[n] = sum_{k=1..N} x~[n - (k-1) D] . w[N-k]

    i.e. tap w[N-1] reads the current step, w[N-2] reads D steps back, ...
    x~ is the causally zero-padded input.
    """
    t_len, _ = x.shape
    n_taps, _, cout = w.shape
    xi = x.astype(jnp.int32)
    acc = jnp.zeros((t_len, cout), dtype=jnp.int32)
    for k in range(1, n_taps + 1):
        shift = (k - 1) * dilation
        tap = w[n_taps - k].astype(jnp.int32)  # (Cin, Cout)
        if shift == 0:
            shifted = xi
        else:
            shifted = jnp.pad(xi, ((shift, 0), (0, 0)))[:-shift]
        acc = acc + shifted @ tap
    return acc
