"""L1 Pallas kernels: the ternary-convolution hot spot.

CUTIE's datapath ("one OCU per output channel, one full 3x3xCin window per
cycle") is re-thought for the TPU per DESIGN.md §Hardware-Adaptation: the
completely unrolled adder trees become an MXU-shaped matmul over im2col
patches. Trits are carried as f32 (exact integers, |acc| <= 9*Cin << 2^24,
bf16-exact for |acc| <= 256 — the 96-channel configuration peaks at 864, so
f32 accumulate / bf16 operands is the TPU story; in interpret mode we stay
f32 end to end).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and the
BlockSpec structure documents the real-TPU schedule (VMEM tiling analysis in
DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the im2col patch matrix processed per grid step. On a real TPU
# this is the MXU M-tile; 128 matches the systolic array edge.
TILE_M = 128


def _im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """(H, W, Cin) -> (H*W, KH*KW*Cin) patch matrix, zero "same" padding.

    The patch matrix is the software analogue of CUTIE's linebuffer output:
    each row is the full window an OCU consumes in one cycle.
    """
    h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((ph, ph), (pw, pw), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[dy : dy + h, dx : dx + w, :])
    patches = jnp.stack(cols, axis=2)  # (H, W, KH*KW, Cin)
    return patches.reshape(h * w, kh * kw * c)


def _matmul_kernel(p_ref, w_ref, o_ref):
    """One M-tile of patches x the full (K, Cout) weight matrix.

    Weights stay resident across the whole grid (index_map pins block 0) —
    the analogue of CUTIE's weight-stationary per-OCU buffers.
    """
    o_ref[...] = jnp.dot(
        p_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternary_conv2d_pallas(
    x: jnp.ndarray, w: jnp.ndarray, interpret: bool = True
) -> jnp.ndarray:
    """Pallas ternary conv. x: (H, W, Cin) f32 trits; w: (KH, KW, Cin, Cout)
    f32 trits. Returns (H, W, Cout) int32 accumulators.

    Grid: one step per TILE_M output pixels. BlockSpec expresses the
    HBM->VMEM schedule: patch tiles stream, the weight matrix is pinned.
    """
    h, wid, cin = x.shape
    kh, kw, _, cout = w.shape
    patches = _im2col(x, kh, kw)  # (M, K)
    m, k = patches.shape
    wmat = w.reshape(kh * kw * cin, cout)

    m_pad = -m % TILE_M
    if m_pad:
        patches = jnp.pad(patches, ((0, m_pad), (0, 0)))
    grid = (patches.shape[0] // TILE_M,)

    acc = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((patches.shape[0], cout), jnp.float32),
        interpret=interpret,
    )(patches, wmat)

    return acc[:m].reshape(h, wid, cout).astype(jnp.int32)


def _dense_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternary_dense_pallas(
    x: jnp.ndarray, w: jnp.ndarray, interpret: bool = True
) -> jnp.ndarray:
    """Classifier layer as a single-tile Pallas matmul.

    x: (F,) f32 trits; w: (F, classes) f32 trits -> (classes,) int32 logits.
    """
    f, classes = w.shape
    out = pl.pallas_call(
        _dense_kernel,
        out_shape=jax.ShapeDtypeStruct((1, classes), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, f), w)
    return out.reshape(classes).astype(jnp.int32)
