"""Build-time STE training of ternary networks on synthetic data.

CIFAR-10 / DVS128 are not available in this offline environment (see
DESIGN.md §2 substitution table), so the end-to-end validation trains on a
synthetic 10-class image task with the same geometry. The training forward
uses latent float weights with TWN straight-through ternarization, a
parameter-free batchnorm and +/-0.5 activation ternarization; at export the
batchnorm folds into the integer (lo, hi) thresholds of the inference
contract, so the trained network runs bit-exactly on the Rust simulator.

optax is not installed; a minimal Adam lives here.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .model import Network, LayerSpec, cnn_part
from .ternary import (
    ACT_DELTA,
    encode_input_image,
    fold_bn_thresholds,
    ste_ternarize_act,
    ste_ternarize_weights,
)

BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# Synthetic datasets
# ---------------------------------------------------------------------------


def synth_image_dataset(
    key, n: int, hw: int = 32, classes: int = 10
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """10-class synthetic 'tiny CIFAR': fixed low-frequency class templates
    plus per-sample noise, normalized to [0, 1], 3 channels.

    Returns (images (n, hw, hw, 3) float32 in [0,1], labels (n,) int32).

    Class templates are a fixed function of (classes, hw) — independent of
    ``key`` — so separately generated train/test sets share the same task.
    """
    _, klabel, knoise, kamp = jax.random.split(key, 4)
    ktempl = jax.random.PRNGKey(961748927 + classes * 1000003 + hw * 7919)
    # Low-frequency templates: sum of a few random 2D cosines per class/chan.
    yy, xx = jnp.meshgrid(jnp.arange(hw), jnp.arange(hw), indexing="ij")
    freqs = jax.random.uniform(ktempl, (classes, 3, 4, 3), minval=0.3, maxval=3.0)
    phase = freqs[..., 2] * 6.28318
    grid = (
        freqs[..., 0:1, None] * yy[None, None, None] / hw
        + freqs[..., 1:2, None] * xx[None, None, None] / hw
    )
    # (classes, 3, 4, hw, hw) -> (classes, hw, hw, 3)
    waves = jnp.cos(6.28318 * grid + phase[..., None, None])
    templates = waves.sum(axis=2).transpose(0, 2, 3, 1)
    templates = templates / (jnp.abs(templates).max() + 1e-6)

    labels = jax.random.randint(klabel, (n,), 0, classes)
    noise = 0.35 * jax.random.normal(knoise, (n, hw, hw, 3))
    amp = jax.random.uniform(kamp, (n, 1, 1, 1), minval=0.7, maxval=1.3)
    imgs = 0.5 + 0.5 * (amp * templates[labels] + noise)
    return jnp.clip(imgs, 0.0, 1.0), labels


def encode_dataset(imgs: jnp.ndarray) -> jnp.ndarray:
    """Float images -> ternary input trits (vmapped encode)."""
    return jax.vmap(encode_input_image)(imgs)


# ---------------------------------------------------------------------------
# Float STE forward (training path)
# ---------------------------------------------------------------------------


def init_latent(net: Network, seed: int = 0) -> Dict:
    """Latent float weights, He-style scaled."""
    key = jax.random.PRNGKey(seed)
    latent: Dict = {}
    for spec in net.layers:
        key, kw = jax.random.split(key)
        if spec.kind == "conv2d":
            shape = (spec.kernel, spec.kernel, spec.in_ch, spec.out_ch)
        elif spec.kind == "tcn":
            shape = (3, spec.in_ch, spec.out_ch)
        else:
            shape = (spec.in_ch, spec.out_ch)
        fan = 1
        for s in shape[:-1]:
            fan *= s
        latent[spec.name] = jax.random.normal(kw, shape) / jnp.sqrt(fan)
    return latent


def _conv2d_float(x, w):
    """Batched float conv, same padding. x: (B,H,W,Cin), w: (KH,KW,Cin,Cout)."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _maxpool2x2_f(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def forward_train(net: Network, latent: Dict, x: jnp.ndarray):
    """STE float forward over a batch of encoded inputs (B,H,W,Cin trits as
    f32). Returns (logits (B, classes), batch_stats {layer: (mean, var)}).
    Only CNN+dense networks (the trained E2E variant) are supported."""
    stats = {}
    h = x
    for spec in cnn_part(net):
        wt = ste_ternarize_weights(latent[spec.name])
        acc = _conv2d_float(h, wt)
        mean = acc.mean(axis=(0, 1, 2))
        var = acc.var(axis=(0, 1, 2))
        stats[spec.name] = (mean, var)
        normed = (acc - mean) / jnp.sqrt(var + BN_EPS)
        h = ste_ternarize_act(normed)
        if spec.pool:
            h = _maxpool2x2_f(h)
        if spec.global_pool:
            h = h.max(axis=(1, 2))
    fc = net.layers[-1]
    wt = ste_ternarize_weights(latent[fc.name])
    flat = h.reshape(h.shape[0], -1)
    logits = flat @ wt / jnp.sqrt(float(fc.in_ch))
    return logits, stats


def loss_fn(net: Network, latent: Dict, x, y):
    logits, stats = forward_train(net, latent, x)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    acc = (logits.argmax(axis=1) == y).mean()
    return loss, (acc, stats)


# ---------------------------------------------------------------------------
# Minimal Adam
# ---------------------------------------------------------------------------


def adam_init(params: Dict):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Trainer + export
# ---------------------------------------------------------------------------


def train(
    net: Network,
    steps: int = 200,
    batch: int = 64,
    n_train: int = 2048,
    n_test: int = 512,
    seed: int = 0,
    lr: float = 2e-3,
    log_every: int = 10,
) -> Tuple[Dict, List[Tuple[int, float, float]], float]:
    """Train; returns (exported integer params, loss log, test accuracy of
    the float-STE model). The exported params follow the bit-exact contract
    (int8 trit weights + folded int32 thresholds)."""
    kdata, ktest, kperm = jax.random.split(jax.random.PRNGKey(seed), 3)
    imgs, labels = synth_image_dataset(kdata, n_train, hw=net.input_hw, classes=net.classes)
    timgs, tlabels = synth_image_dataset(ktest, n_test, hw=net.input_hw, classes=net.classes)
    x_all = encode_dataset(imgs).astype(jnp.float32)
    xt_all = encode_dataset(timgs).astype(jnp.float32)

    latent = init_latent(net, seed)
    opt = adam_init(latent)

    @jax.jit
    def step_fn(latent, opt, x, y):
        (loss, (acc, stats)), grads = jax.value_and_grad(
            lambda l: loss_fn(net, l, x, y), has_aux=True
        )(latent)
        latent, opt = adam_step(latent, grads, opt, lr=lr)
        return latent, opt, loss, acc, stats

    log: List[Tuple[int, float, float]] = []
    running = None
    for i in range(steps):
        kperm, kb = jax.random.split(kperm)
        idx = jax.random.randint(kb, (batch,), 0, n_train)
        latent, opt, loss, acc, stats = step_fn(latent, opt, x_all[idx], labels[idx])
        # EMA of batchnorm stats for threshold folding.
        if running is None:
            running = stats
        else:
            running = {
                k: (
                    0.9 * running[k][0] + 0.1 * stats[k][0],
                    0.9 * running[k][1] + 0.1 * stats[k][1],
                )
                for k in stats
            }
        if i % log_every == 0 or i == steps - 1:
            log.append((i, float(loss), float(acc)))

    # Float-model test accuracy (uses running stats, mirrors export).
    @jax.jit
    def eval_logits(x):
        h = x
        for spec in cnn_part(net):
            wt = ste_ternarize_weights(latent[spec.name])
            accv = _conv2d_float(h, wt)
            mean, var = running[spec.name]
            normed = (accv - mean) / jnp.sqrt(var + BN_EPS)
            h = ste_ternarize_act(normed)
            if spec.pool:
                h = _maxpool2x2_f(h)
            if spec.global_pool:
                h = h.max(axis=(1, 2))
        wt = ste_ternarize_weights(latent[net.layers[-1].name])
        return h.reshape(h.shape[0], -1) @ wt

    preds = eval_logits(xt_all).argmax(axis=1)
    test_acc = float((preds == tlabels).mean())

    params = export_params(net, latent, running)
    return params, log, test_acc


def export_params(net: Network, latent: Dict, running: Dict) -> Dict:
    """Fold latent weights + running BN stats into the integer contract."""
    params: Dict = {}
    for spec in net.layers:
        wt = ste_ternarize_weights(latent[spec.name]).astype(jnp.int8)
        entry = {"w": wt}
        if spec.kind != "dense":
            mean, var = running[spec.name]
            lo, hi = fold_bn_thresholds(mean, var, eps=BN_EPS)
            entry["lo"] = lo
            entry["hi"] = hi
        params[spec.name] = entry
    return params


def eval_int(net: Network, params: Dict, xs, ys, limit: int = 256) -> float:
    """Bit-exact integer-model accuracy (the number the simulator must
    reproduce exactly)."""
    from .model import forward_int

    n = min(limit, xs.shape[0])
    fwd = jax.jit(lambda x: forward_int(net, params, x))
    correct = 0
    for i in range(n):
        logits = fwd(xs[i].astype(jnp.int8))
        correct += int(jnp.argmax(logits)) == int(ys[i])
    return correct / n
