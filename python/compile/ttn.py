"""`.ttn` — the ternary-tensor binary interchange format between the
Python compile path and the Rust runtime/simulator (reader in
``rust/src/tensor/ttn.rs``).

Layout (all little-endian):

    u32  magic = 0x314E5454  ("TTN1")
    u32  n_tensors
    per tensor:
        u16  name_len, name (utf-8)
        u8   dtype   (0 = i8 trits, 1 = i32)
        u8   ndim
        u32  dims[ndim]
        data (row-major, i8 or i32 LE)
"""

from __future__ import annotations

import json
import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = 0x314E5454


def write_ttn(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<II", MAGIC, len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr)
            if arr.dtype == np.int8:
                dtype = 0
            elif arr.dtype == np.int32:
                dtype = 1
            else:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dtype, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<i1" if dtype == 0 else "<i4").tobytes())


def read_ttn(path: str) -> Dict[str, np.ndarray]:
    """Reader (used by round-trip tests)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic, n = struct.unpack("<II", f.read(8))
        if magic != MAGIC:
            raise ValueError("bad magic")
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            if dtype == 0:
                data = np.frombuffer(f.read(count), dtype="<i1")
            else:
                data = np.frombuffer(f.read(4 * count), dtype="<i4")
            out[name] = data.reshape(dims)
    return out


def export_network(net, params: Dict, ttn_path: str, manifest_path: str) -> None:
    """Write weights/thresholds to .ttn + a JSON manifest the Rust network
    loader consumes."""
    tensors: List[Tuple[str, np.ndarray]] = []
    layers_js = []
    for spec in net.layers:
        p = params[spec.name]
        tensors.append((f"{spec.name}.w", np.asarray(p["w"], dtype=np.int8)))
        entry = {
            "name": spec.name,
            "kind": spec.kind,
            "in_ch": spec.in_ch,
            "out_ch": spec.out_ch,
            "kernel": spec.kernel,
            "dilation": spec.dilation,
            "pool": spec.pool,
            "global_pool": spec.global_pool,
            "weights": f"{spec.name}.w",
        }
        if "lo" in p:
            tensors.append((f"{spec.name}.lo", np.asarray(p["lo"], dtype=np.int32)))
            tensors.append((f"{spec.name}.hi", np.asarray(p["hi"], dtype=np.int32)))
            entry["lo"] = f"{spec.name}.lo"
            entry["hi"] = f"{spec.name}.hi"
        layers_js.append(entry)
    write_ttn(ttn_path, tensors)
    manifest = {
        "name": net.name,
        "input_hw": net.input_hw,
        "tcn_steps": net.tcn_steps,
        "classes": net.classes,
        "weights_file": ttn_path.split("/")[-1],
        "layers": layers_js,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
