"""Offline mapping of dilated 1D convolutions to undilated 2D convolutions
(§4 of the paper, Fig. 3). Mirrored bit-for-bit by ``rust/src/mapping/``.

Derivation. Write the output index as ``n = q*D + m`` (``q = n // D``,
``m = n % D``) and wrap the causally padded input into the dense 2D map

    z[q, m] = x~[q*D + m]            (the paper's  z[n, m] = x~[n*D + m])

Then Eq. (1) becomes a single-column 2D correlation:

    y[q*D + m] = sum_j z[q - (N-1) + j, m] * w[j]      j = 0..N-1

With the 1D taps bottom-aligned into the middle column of a 3x3 kernel
(``W[3-N+j, 1] = w[j]``) and one zero row prepended to ``z`` (the causal
edge padding shown white in Fig. 3), a *standard* zero-padded 3x3
convolution over ``z_pad`` computes exactly ``y``:

    y[n] = conv2d_same(z_pad, W)[n // D, n % D]

because the conv output at row ``r`` of ``z_pad`` reads rows
``r-1, r, r+1`` = ``z[r-2], z[r-1], z[r]`` and zero-padding supplies the
out-of-range causal zeros. All index arithmetic is offline; the hardware
sees a plain 3x3 layer, which is the paper's entire point.
"""

from __future__ import annotations

import jax.numpy as jnp


def wrapped_rows(t_len: int, dilation: int) -> int:
    """Number of rows of the wrapped map z (excluding the causal pad row)."""
    return -(-t_len // dilation)  # ceil


def map_input(x: jnp.ndarray, dilation: int) -> jnp.ndarray:
    """Wrap a (T, C) time series into the (R+1, D, C) dense 2D feature map
    (one leading zero row = causal padding)."""
    t_len, c = x.shape
    rows = wrapped_rows(t_len, dilation)
    pad = rows * dilation - t_len
    flat = jnp.pad(x, ((0, pad), (0, 0)))
    z = flat.reshape(rows, dilation, c)
    return jnp.pad(z, ((1, 0), (0, 0), (0, 0)))


def map_weights(w: jnp.ndarray) -> jnp.ndarray:
    """Project (N, Cin, Cout) 1D taps into the middle column of a
    (3, 3, Cin, Cout) kernel, bottom-aligned: W[3-N+j, 1] = w[j]."""
    n_taps, cin, cout = w.shape
    if n_taps > 3:
        raise ValueError(f"CUTIE supports kernels up to 3 taps, got {n_taps}")
    out = jnp.zeros((3, 3, cin, cout), dtype=w.dtype)
    return out.at[3 - n_taps :, 1].set(w)


def unmap_output(acc2d: jnp.ndarray, t_len: int, dilation: int) -> jnp.ndarray:
    """Extract the (T, Cout) 1D outputs from the (R+1, D, Cout) conv output:
    y[n] = acc2d[n // D, n % D]."""
    rows_pad, d, cout = acc2d.shape
    flat = acc2d.reshape(rows_pad * d, cout)
    return flat[:t_len]


def receptive_field(n_taps: int, dilations) -> int:
    """Receptive field of a stack of causal dilated conv layers."""
    f = 1
    for d in dilations:
        f += (n_taps - 1) * d
    return f


def layers_needed_undilated(n_taps: int, window: int) -> int:
    """Layers needed to cover ``window`` steps without dilation (paper: 12
    for 24 steps with N=3)."""
    layers = 0
    f = 1
    while f < window:
        layers += 1
        f += n_taps - 1
    return layers


def layers_needed_dilated(n_taps: int, window: int) -> int:
    """Layers needed with exponentially increasing dilation D_i = 2^i
    (paper: 5 for 24 steps with N=3)."""
    layers = 0
    f = 1
    while f < window:
        f += (n_taps - 1) * (1 << layers)
        layers += 1
    return layers
